"""Live-mutation serving suite: insert throughput, swap pause, recall
across a re-cluster.

Run via ``python -m benchmarks.run --suite serve_mutation --toy`` — the
CI lane for the ISSUE-9 mutation surface.  Emits a ``mutation`` section
*into* ``BENCH_serve.json`` (``.toy.json`` under ``--toy``), merging with
whatever the ``serve`` suite wrote earlier in the same run so one
artifact carries the whole serving trajectory; run it after ``serve``
(CI does) or standalone (a minimal artifact is created).

Three tracked claims:

* ``insert`` — slot-insert throughput through a serving
  :class:`~repro.serve.ann.AnnServer` (points/s, host wall time), with
  queries interleaved between batches and
  ``retraces_after_warmup == 0`` asserted across the whole mutation run.
* ``delete`` — tombstone throughput plus the query-visible contract:
  the batch dispatched right after a delete contains none of the ids.
* ``swap`` — the warm re-index handoff: live-corpus gather + ``minibatch``
  re-cluster + successor warmup happen off the serving path (reported as
  ``prepare_s``), and the :meth:`~repro.serve.ann.AnnServer.swap` call
  itself — the only moment the serving surface is touched — is the
  ``swap_pause`` row, which must be orders of magnitude below a single
  query step (~0).  Recall@k against brute force over the live corpus is
  reported before and after the re-cluster: the handoff must not cost
  answer quality.
"""

from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path

import numpy as np
import jax

from benchmarks.common import Row
from benchmarks.serve import FULL, OUT_PATH, TOY, TOY_OUT_PATH
from repro.core import EnginePolicy, SuCoConfig, SuCoEngine
from repro.core.suco import build_index
from repro.data import GENERATORS
from repro.serve.ann import AnnServer
from repro.serve.mutation import DriftMonitor, warm_like

# Mutation load relative to the corpus: insert 10%, delete 10%.
MUTATION_FRACTION = 0.10
QUERY_BURSTS = 4  # query batches interleaved between mutation batches


def _brute_recall(engine: SuCoEngine, queries: np.ndarray, k: int) -> float:
    """Mean recall@k of the engine answer vs brute force on live points."""
    res = engine.query(queries, k=k)
    ids = np.asarray(res.ids)
    x = np.asarray(engine.x)
    tomb = np.asarray(engine.index.tombstone)
    live = np.flatnonzero(~tomb)
    hits = 0
    for i, q in enumerate(queries):
        d2 = ((x[live] - q[None]) ** 2).sum(axis=1)
        want = set(live[np.argsort(d2)[:k]].tolist())
        hits += len(want & set(map(int, ids[i])))
    return hits / (len(queries) * k)


def _run_mutation(scale: dict) -> dict:
    n, d = scale["n"], scale["d"]
    k = 10
    x = np.asarray(GENERATORS["gaussian_mixture"](n, d, 0)).astype(np.float32)
    config = SuCoConfig(
        n_subspaces=scale["n_subspaces"], sqrt_k=scale["sqrt_k"],
        kmeans_iters=scale["kmeans_iters"], seed=0,
    )
    policy = EnginePolicy(alpha=0.05, beta=0.01, mode="streaming")
    n_mut = max(int(n * MUTATION_FRACTION), 64)
    t0 = time.perf_counter()
    engine = SuCoEngine(
        jax.numpy.asarray(x), build_index(jax.numpy.asarray(x), config),
        policy, capacity=n + n_mut,
    )
    build_s = time.perf_counter() - t0
    server = AnnServer(engine, max_batch=scale["max_batch"])
    engine.warmup(batch_sizes=(1, scale["max_batch"]), ks=(k,))
    exe0 = server.executables

    rng = np.random.default_rng(0)
    queries = x[rng.integers(0, n, size=scale["max_batch"])]
    new_rows = (
        x[rng.integers(0, n, size=n_mut)]
        + 0.05 * rng.standard_normal((n_mut, d)).astype(np.float32)
    )

    # -- insert throughput, queries interleaved -----------------------------
    batch = max(n_mut // QUERY_BURSTS, 1)
    t0 = time.perf_counter()
    for i in range(0, n_mut, batch):
        server.insert(new_rows[i:i + batch])
        engine.query(queries, k=k)
    insert_s = time.perf_counter() - t0
    insert = dict(
        n_inserted=n_mut,
        batch=batch,
        wall_s=round(insert_s, 4),
        points_per_s=round(n_mut / insert_s, 1),
        retraces_after_warmup=server.executables - exe0,
    )

    # -- delete + visibility -----------------------------------------------
    dead = rng.choice(n, size=n_mut, replace=False)
    t0 = time.perf_counter()
    n_deleted = server.delete(dead)
    delete_s = time.perf_counter() - t0
    ids_after = np.asarray(engine.query(queries, k=k).ids)
    leaked = int(np.isin(ids_after, dead).sum())
    assert leaked == 0, f"{leaked} tombstoned ids served after delete"
    delete = dict(
        n_deleted=int(n_deleted),
        wall_s=round(delete_s, 4),
        points_per_s=round(n_deleted / delete_s, 1),
        tombstoned_ids_served=leaked,
    )

    # -- drift + warm re-index handoff -------------------------------------
    monitor = DriftMonitor().capture(engine)
    recall_before = _brute_recall(engine, queries, k)
    drift = monitor.observe(engine)
    t0 = time.perf_counter()
    tomb = np.asarray(engine.index.tombstone)
    live = np.flatnonzero(~tomb)
    x_live = jax.numpy.asarray(np.asarray(engine.x)[live])
    successor = SuCoEngine(
        x_live,
        build_index(x_live, dataclasses.replace(config, build_mode="minibatch")),
        EnginePolicy(alpha=0.05, beta=0.01, mode="streaming"),
        capacity=len(live) + n_mut,
    )
    warm_like(successor, engine)
    prepare_s = time.perf_counter() - t0
    exe_post_warm = successor.compile_count
    t0 = time.perf_counter()
    server.swap(successor)
    swap_pause_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    engine.release_retired()  # deferred predecessor-executable teardown
    release_s = time.perf_counter() - t0
    # Answers must keep flowing on the successor with zero retrace; ids
    # renumbered by the compaction, so recall is re-measured vs brute force.
    recall_after = _brute_recall(engine, queries, k)
    step_ids = np.asarray(engine.query(queries, k=k).ids)
    assert step_ids.shape == (len(queries), k)
    retraces_successor = successor.compile_count - exe_post_warm
    assert retraces_successor == 0, "handoff retraced on the successor"
    swap = dict(
        n_live=int(len(live)),
        drift_tv=round(drift.tv_distance, 4),
        drift_dead_fraction=round(drift.dead_fraction, 4),
        prepare_s=round(prepare_s, 4),
        swap_pause_s=round(swap_pause_s, 6),
        release_s=round(release_s, 6),
        recall_before=round(recall_before, 4),
        recall_after=round(recall_after, 4),
        retraces_after_warmup=retraces_successor,
    )
    return dict(
        build_s=round(build_s, 3),
        capacity=n + n_mut,
        insert=insert,
        delete=delete,
        swap=swap,
    )


def collect(*, toy: bool = False, out_path: Path | None = None) -> dict:
    scale = TOY if toy else FULL
    if out_path is None:
        out_path = TOY_OUT_PATH if toy else OUT_PATH
    section = _run_mutation(scale)
    # Merge into the serve artifact: one file carries the whole serving
    # trajectory.  Standalone runs create a minimal artifact.
    if out_path.exists():
        payload = json.loads(out_path.read_text())
    else:
        payload = dict(
            meta=dict(
                schema="suco-serve-v1",
                backend=jax.default_backend(),
                toy=toy,
                n=scale["n"],
                d=scale["d"],
            )
        )
    payload["mutation"] = section
    out_path.write_text(json.dumps(payload, indent=2) + "\n")
    return payload


def run(*, toy: bool = False) -> list[Row]:
    payload = collect(toy=toy)
    m = payload["mutation"]
    ins, dele, swap = m["insert"], m["delete"], m["swap"]
    return [
        (
            "serve_mutation/insert",
            ins["wall_s"] / max(ins["n_inserted"], 1) * 1e6,
            f"points_per_s={ins['points_per_s']};"
            f"retraces={ins['retraces_after_warmup']}",
        ),
        (
            "serve_mutation/delete",
            dele["wall_s"] / max(dele["n_deleted"], 1) * 1e6,
            f"points_per_s={dele['points_per_s']};"
            f"tombstoned_served={dele['tombstoned_ids_served']}",
        ),
        (
            "serve_mutation/swap",
            swap["swap_pause_s"] * 1e6,
            f"prepare_s={swap['prepare_s']};"
            f"recall={swap['recall_before']}->{swap['recall_after']};"
            f"retraces={swap['retraces_after_warmup']}",
        ),
    ]


if __name__ == "__main__":
    for r in run(toy=True):
        print(",".join(map(str, r)))
