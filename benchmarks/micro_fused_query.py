"""Micro-benchmark for the fused single-pass query engine.

Splits the fused design's two claims apart so a regression in either is
visible on its own row (CI fast lane: ``python -m benchmarks.micro_fused_query
--toy``):

* **prefilter hit-rate** — per streamed chunk, the fraction of rows beating
  the carried pool minimum (the Pareto observation: after the pool warms
  this is a thin tail) and the number of chunks that overflow the
  ``survivor_cap`` compaction budget into the exact full-width fallback;
* **merge-time split** — the per-chunk pool merge at the legacy full width
  ``pool + block_n`` vs the fused pruned width ``pool + survivor_cap``,
  plus the fused chunk stage (score + prefilter) vs the plain scorer;
* **end to end** — ``suco_query_fused`` vs ``suco_query_streaming`` on the
  same index (bit-identical answers, asserted here too).

Rows print as ``name,us_per_call,derived`` like every suite in
``benchmarks.run``.
"""

from __future__ import annotations

import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import Row
from repro.core import (
    SuCoConfig,
    autotune_tiles,
    build_index,
    merge_topk_pool,
    merge_topk_pool_with_dists,
    suco_query_fused,
    suco_query_streaming,
)
from repro.core import subspace as sub
from repro.core.suco import _pool_size, suco_cell_ranks, suco_scores
from repro.data import GENERATORS
from repro.kernels.sc_score.ops import sc_scores_cells, sc_scores_cells_prefilter

FULL = dict(n=48_000, d=32, sqrt_k=16, n_subspaces=8, kmeans_iters=3, m=8,
            k=10, alpha=0.05, beta=0.01, reps=20)
TOY = dict(n=6_000, d=16, sqrt_k=8, n_subspaces=4, kmeans_iters=2, m=4,
           k=5, alpha=0.05, beta=0.02, reps=5)


def _time(fn, reps: int) -> float:
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run(*, toy: bool = False) -> list[Row]:
    scale = TOY if toy else FULL
    n, d, m, k = scale["n"], scale["d"], scale["m"], scale["k"]
    alpha, beta, reps = scale["alpha"], scale["beta"], scale["reps"]
    x = jnp.asarray(
        np.asarray(GENERATORS["gaussian_mixture"](n, d, 0)).astype(np.float32)
    )
    cfg = SuCoConfig(n_subspaces=scale["n_subspaces"], sqrt_k=scale["sqrt_k"],
                     kmeans_iters=scale["kmeans_iters"], seed=0)
    index = build_index(x, cfg)
    q = x[:m] + 0.01
    pool = _pool_size(n, k, beta)
    tiles = autotune_tiles(
        n, d, m, pool, n_subspaces=cfg.n_subspaces, n_cells=cfg.n_cells
    )
    bn, cap = min(tiles.block_n, n), min(tiles.survivor_cap, n)
    n_blocks = -(-n // bn)
    rows: list[Row] = []

    # ---- prefilter hit-rate: replay the scan's thresholds in numpy ------
    count = sub.collision_count(n, alpha)
    scores = np.asarray(suco_scores(index, q, count))  # (m, n)
    hit, slow_chunks = [], 0
    pool_s = np.full((m, pool), -1, np.int64)
    for b in range(n_blocks):
        blk = scores[:, b * bn:(b + 1) * bn]
        thr = pool_s.min(axis=1, keepdims=True)
        survivors = (blk > thr).sum(axis=1)
        hit.append(survivors.mean() / blk.shape[1])
        if (survivors > cap).any():
            slow_chunks += 1
        both = np.concatenate([pool_s, blk.astype(np.int64)], axis=1)
        pool_s = -np.sort(-both, axis=1)[:, :pool]
    rows.append((
        "micro_fused/prefilter",
        0.0,
        f"hit_rate={float(np.mean(hit)):.4f};warm_hit_rate="
        f"{float(np.mean(hit[2:]) if len(hit) > 2 else hit[-1]):.4f};"
        f"slow_chunks={slow_chunks}/{n_blocks};cap={cap}",
    ))

    # ---- chunk-stage + merge-time split ---------------------------------
    ranks, cuts = jax.block_until_ready(suco_cell_ranks(index, q, count))
    cells = jnp.pad(index.cell_ids, ((0, 0), (0, n_blocks * bn - n)))
    cells_b = cells.reshape(cells.shape[0], n_blocks, bn)[:, n_blocks // 2]
    thr_j = jnp.asarray(pool_s.min(axis=1), jnp.int32)
    t_score = _time(lambda: sc_scores_cells(ranks, cuts, cells_b), reps)
    t_pref = _time(
        lambda: sc_scores_cells_prefilter(ranks, cuts, cells_b, thr_j)[0], reps
    )
    rows.append((
        "micro_fused/chunk_stage", t_pref,
        f"score_only_us={t_score:.1f};fused_overhead="
        f"{(t_pref - t_score) / max(t_score, 1e-9):+.2%}",
    ))

    rng = np.random.default_rng(0)
    ps = jnp.asarray(rng.integers(0, 8, (m, pool)), jnp.int32)
    pi = jnp.asarray(np.arange(pool, dtype=np.int32)[None].repeat(m, 0))
    pd = jnp.asarray(rng.random((m, pool), np.float32))
    full_s = jnp.asarray(rng.integers(0, 8, (m, bn)), jnp.int32)
    full_i = jnp.asarray(
        pool + np.arange(bn, dtype=np.int32)[None].repeat(m, 0)
    )
    surv_s = full_s[:, :cap]
    surv_i = full_i[:, :cap]
    surv_d = jnp.asarray(rng.random((m, cap), np.float32))
    t_full = _time(lambda: merge_topk_pool(ps, pi, full_s, full_i)[0], reps)
    t_pruned = _time(
        lambda: merge_topk_pool_with_dists(ps, pd, pi, surv_s, surv_d, surv_i)[0],
        reps,
    )
    rows.append((
        "micro_fused/merge_full", t_full,
        f"width={pool + bn};pool={pool};block_n={bn}",
    ))
    rows.append((
        "micro_fused/merge_pruned", t_pruned,
        f"width={pool + cap};speedup_vs_full={t_full / max(t_pruned, 1e-9):.2f}",
    ))

    # ---- end to end ------------------------------------------------------
    stream = lambda: suco_query_streaming(x, index, q, k=k, alpha=alpha, beta=beta)
    fused = lambda: suco_query_fused(
        x, index, q, k=k, alpha=alpha, beta=beta, tiles=tiles
    )
    r_s, r_f = stream(), fused()
    np.testing.assert_array_equal(np.asarray(r_s.ids), np.asarray(r_f.ids))
    np.testing.assert_array_equal(np.asarray(r_s.dists), np.asarray(r_f.dists))
    t_stream = _time(lambda: stream().ids, reps)
    t_fused = _time(lambda: fused().ids, reps)
    rows.append((
        "micro_fused/query_streaming", t_stream, f"n={n};m={m};k={k}",
    ))
    rows.append((
        "micro_fused/query_fused", t_fused,
        f"speedup={t_stream / max(t_fused, 1e-9):.2f};"
        f"block_n={tiles.block_n};cap={tiles.survivor_cap}",
    ))
    return rows


if __name__ == "__main__":
    for r in run(toy="--toy" in sys.argv[1:]):
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
