"""Table 5: SuCo under L1 vs L2 — recall/MRE parity across metrics."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import Row, dataset, timeit
from repro.core import SuCoConfig, build_index, suco_query
from repro.data import exact_knn, mean_relative_error, recall


def run() -> list[Row]:
    rows: list[Row] = []
    ds = dataset("gaussian_mixture", n=20_000)
    x, q = jnp.asarray(ds.x), jnp.asarray(ds.queries)
    cfg = SuCoConfig(n_subspaces=8, sqrt_k=24, kmeans_iters=5)
    idx = build_index(x, cfg)
    for metric in ("l2", "l1"):
        gt_ids, gt_d = (ds.gt_ids, ds.gt_dists) if metric == "l2" else exact_knn(
            ds.x, ds.queries, 10, metric="l1"
        )
        us = timeit(
            lambda: suco_query(x, idx, q, k=10, alpha=0.05, beta=0.02, metric=metric)
            .ids.block_until_ready(), repeats=1,
        )
        res = suco_query(x, idx, q, k=10, alpha=0.05, beta=0.02, metric=metric)
        r = recall(np.asarray(res.ids), gt_ids)
        if metric == "l2":
            mre = mean_relative_error(np.asarray(res.dists), gt_d)
        else:
            mre = float(
                np.mean((np.asarray(res.dists) - gt_d) / np.maximum(gt_d, 1e-9))
            )
        rows.append((f"table5/suco-{metric}", us, f"recall={r:.4f};mre={mre:.5f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
