"""Micro-benchmark: per-block cost of the streaming top-pool merge.

Compares ``merge_topk_pool(impl="sort")`` (two-key sort of the (m, p+b)
concat) against the default ``impl="topk"`` (single ``lax.top_k``
selection) at streaming-engine shapes, and asserts they stay
bit-identical under the streaming (ascending block id) invariant.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import Row, timeit
from repro.core import merge_topk_pool


import functools


@functools.partial(jax.jit, static_argnames=("p", "bn", "impl"))
def _run_stream(scores: jnp.ndarray, *, p: int, bn: int, impl: str):
    m, n = scores.shape
    int_max = np.iinfo(np.int32).max
    pool_s = jnp.full((m, p), -1, jnp.int32)
    pool_i = jnp.full((m, p), int_max, jnp.int32)

    def step(carry, blk):
        ps, pi = carry
        blk_s, blk_i = blk
        return merge_topk_pool(ps, pi, blk_s, blk_i, impl=impl), None

    blocks_s = scores.reshape(m, n // bn, bn).transpose(1, 0, 2)
    ids = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (m, n))
    blocks_i = ids.reshape(m, n // bn, bn).transpose(1, 0, 2)
    (ps, pi), _ = jax.lax.scan(step, (pool_s, pool_i), (blocks_s, blocks_i))
    return ps, pi


def run() -> list[Row]:
    rows: list[Row] = []
    rng = np.random.default_rng(0)
    m, n = 32, 131_072
    scores = jnp.asarray(rng.integers(0, 9, size=(m, n)), jnp.int32)  # many ties
    for p, bn in ((512, 4096), (1024, 8192)):
        res = {}
        for impl in ("sort", "topk"):
            fn = lambda impl=impl: jax.block_until_ready(
                _run_stream(scores, p=p, bn=bn, impl=impl)
            )
            fn()  # compile outside the timed region
            res[impl] = (timeit(fn, repeats=5), fn())
        (us_s, (ss, si)), (us_t, (ts, ti)) = res["sort"], res["topk"]
        bit_equal = bool(
            np.array_equal(np.asarray(ss), np.asarray(ts))
            and np.array_equal(np.asarray(si), np.asarray(ti))
        )
        n_blocks = n // bn
        rows.append(
            (
                f"micro/merge_pool-p{p}-bn{bn}",
                us_t / n_blocks,
                f"sort_us_per_block={us_s / n_blocks:.1f};"
                f"speedup={us_s / us_t:.2f}x;bit_equal={bit_equal}",
            )
        )
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
