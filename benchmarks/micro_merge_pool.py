"""Micro-benchmark: per-block cost of the streaming top-pool merge.

Races the three ``merge_topk_pool`` impls at streaming-engine shapes —
``"sort"`` (two-key sort of the (m, p+b) concat), ``"topk"`` (single
``lax.top_k`` selection), and ``"counting"`` (counting-select over the
integer score range 0..smax: bucket-count the block, invert the merge of
two sorted runs without a scatter) — and asserts all three stay
bit-identical under the streaming (ascending block id) invariant.  The
fused engine's joint (score, dist, id) pool is covered by a second set of
rows through ``merge_topk_pool_with_dists``.

CI fast lane: ``python -m benchmarks.micro_merge_pool --toy``.
"""

from __future__ import annotations

import functools
import sys

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import Row, timeit
from repro.core import merge_topk_pool
from repro.core.sc_linear import merge_topk_pool_with_dists

SMAX = 8  # SC-score range: collision counts in 0..n_subspaces
FULL = dict(m=32, n=131_072, shapes=((512, 4096), (1024, 8192)), repeats=5)
TOY = dict(m=8, n=16_384, shapes=((128, 2048),), repeats=3)


@functools.partial(jax.jit, static_argnames=("p", "bn", "impl"))
def _run_stream(scores: jnp.ndarray, *, p: int, bn: int, impl: str):
    m, n = scores.shape
    int_max = np.iinfo(np.int32).max
    pool_s = jnp.full((m, p), -1, jnp.int32)
    pool_i = jnp.full((m, p), int_max, jnp.int32)

    def step(carry, blk):
        ps, pi = carry
        blk_s, blk_i = blk
        smax = SMAX if impl == "counting" else None
        return merge_topk_pool(ps, pi, blk_s, blk_i, impl=impl, smax=smax), None

    blocks_s = scores.reshape(m, n // bn, bn).transpose(1, 0, 2)
    ids = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (m, n))
    blocks_i = ids.reshape(m, n // bn, bn).transpose(1, 0, 2)
    (ps, pi), _ = jax.lax.scan(step, (pool_s, pool_i), (blocks_s, blocks_i))
    return ps, pi


@functools.partial(jax.jit, static_argnames=("p", "bn", "impl"))
def _run_stream_dists(scores, dists, *, p: int, bn: int, impl: str):
    m, n = scores.shape
    int_max = np.iinfo(np.int32).max
    pool_s = jnp.full((m, p), -1, jnp.int32)
    pool_d = jnp.full((m, p), jnp.inf, jnp.float32)
    pool_i = jnp.full((m, p), int_max, jnp.int32)

    def step(carry, blk):
        ps, pd, pi = carry
        blk_s, blk_d, blk_i = blk
        smax = SMAX if impl == "counting" else None
        return (
            merge_topk_pool_with_dists(
                ps, pd, pi, blk_s, blk_d, blk_i, impl=impl, smax=smax
            ),
            None,
        )

    blocks_s = scores.reshape(m, n // bn, bn).transpose(1, 0, 2)
    blocks_d = dists.reshape(m, n // bn, bn).transpose(1, 0, 2)
    ids = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (m, n))
    blocks_i = ids.reshape(m, n // bn, bn).transpose(1, 0, 2)
    carry, _ = jax.lax.scan(
        step, (pool_s, pool_d, pool_i), (blocks_s, blocks_d, blocks_i)
    )
    return carry


def run(*, toy: bool = False) -> list[Row]:
    scale = TOY if toy else FULL
    m, n, repeats = scale["m"], scale["n"], scale["repeats"]
    rows: list[Row] = []
    rng = np.random.default_rng(0)
    scores = jnp.asarray(rng.integers(0, SMAX + 1, size=(m, n)), jnp.int32)
    dists = jnp.asarray(rng.random((m, n), np.float32))
    for p, bn in scale["shapes"]:
        n_blocks = n // bn
        res = {}
        for impl in ("sort", "topk", "counting"):
            fn = lambda impl=impl: jax.block_until_ready(
                _run_stream(scores, p=p, bn=bn, impl=impl)
            )
            fn()  # compile outside the timed region
            res[impl] = (timeit(fn, repeats=repeats), fn())
        (us_s, out_s), (us_t, out_t), (us_c, out_c) = (
            res["sort"], res["topk"], res["counting"],
        )
        bit_equal = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for got in (out_s, out_c)
            for a, b in zip(got, out_t)
        )
        rows.append(
            (
                f"micro/merge_pool-p{p}-bn{bn}",
                us_c / n_blocks,
                f"topk_us_per_block={us_t / n_blocks:.1f};"
                f"sort_us_per_block={us_s / n_blocks:.1f};"
                f"counting_speedup_vs_topk={us_t / us_c:.2f}x;"
                f"counting_speedup_vs_sort={us_s / us_c:.2f}x;"
                f"bit_equal={bit_equal}",
            )
        )

        # fused-engine joint pool: block width = survivor_cap-ish (pruned)
        cap = max(p // 4, 64)
        res_d = {}
        for impl in ("topk", "counting"):
            fn = lambda impl=impl: jax.block_until_ready(
                _run_stream_dists(
                    scores[:, : (n // bn) * cap],
                    dists[:, : (n // bn) * cap],
                    p=p, bn=cap, impl=impl,
                )
            )
            fn()
            res_d[impl] = (timeit(fn, repeats=repeats), fn())
        (us_td, out_td), (us_cd, out_cd) = res_d["topk"], res_d["counting"]
        bit_equal_d = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(out_cd, out_td)
        )
        rows.append(
            (
                f"micro/merge_pool_dists-p{p}-cap{cap}",
                us_cd / n_blocks,
                f"topk_us_per_block={us_td / n_blocks:.1f};"
                f"counting_speedup_vs_topk={us_td / us_cd:.2f}x;"
                f"bit_equal={bit_equal_d}",
            )
        )
    return rows


if __name__ == "__main__":
    for r in run(toy="--toy" in sys.argv[1:]):
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
