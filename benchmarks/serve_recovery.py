"""Durability + crash-recovery suite: recovery wall time, WAL replay
rate vs log length, and the crash-drill assertion pass.

Run via ``python -m benchmarks.run --suite serve_recovery --toy`` — the
CI lane for the ISSUE-10 durability subsystem.  Emits a ``recovery``
section *into* ``BENCH_serve.json`` (``.toy.json`` under ``--toy``),
merging with whatever the ``serve``/``serve_mutation`` suites wrote
earlier so one artifact carries the whole serving trajectory.

Three tracked claims:

* ``snapshot`` — wall time and artifact size of one checkpointed
  artifact-v3 write (engine + serving-state sidecar + WAL truncation).
* ``replay`` — cold :func:`~repro.serve.durability.recover` wall time as
  a function of WAL tail length (snapshot load + record replay through
  the real mutation surface + cache re-warm), with the recovered state
  asserted fingerprint-identical to the pre-crash stack.  The marginal
  records/s between the two log lengths isolates pure replay throughput
  from the fixed snapshot-load + warmup cost.
* ``drills`` — the full crash-point sweep at a small fixed scale: every
  instrumented boundary fired once under group commit, recovery
  bit-identical to a crash-free replay of the acknowledged prefix with
  zero acknowledged records lost and zero retraces.  This is an
  assertion pass, not a perf number — the drill wall time is reported
  only so CI notices pathological regressions.
"""

from __future__ import annotations

import json
import shutil
import tempfile
import time
from pathlib import Path

import numpy as np
import jax

from benchmarks.common import Row
from benchmarks.serve import OUT_PATH, TOY_OUT_PATH
from repro.core import EnginePolicy, SuCoConfig, SuCoEngine
from repro.core.suco import build_index
from repro.data import GENERATORS
from repro.serve.ann import AnnServer, DegradationLadder
from repro.serve.chaos import CRASH_POINTS, drill_steps, recovery_drill
from repro.serve.durability import (
    Durability,
    DurabilityConfig,
    fingerprint_diff,
    recover,
    state_fingerprint,
)
from repro.serve.mutation import MutationManager

K = 10

FULL = dict(n=20_000, d=32, sqrt_k=16, n_subspaces=8, kmeans_iters=3,
            wal_lengths=(100, 1000))
TOY = dict(n=2_000, d=16, sqrt_k=8, n_subspaces=4, kmeans_iters=2,
           wal_lengths=(50, 200))

# Drills always run at one small fixed scale: they assert correctness at
# every crash boundary, they do not measure anything scale-dependent.
DRILL_SCALE = dict(n=500, d=16, sqrt_k=8, n_subspaces=4, kmeans_iters=2)


def _config(scale: dict) -> SuCoConfig:
    return SuCoConfig(
        n_subspaces=scale["n_subspaces"], sqrt_k=scale["sqrt_k"],
        kmeans_iters=scale["kmeans_iters"], seed=0,
    )


def _build_stack(x: np.ndarray, scale: dict, root: Path, *,
                 capacity: int, injector=None):
    config = _config(scale)
    xj = jax.numpy.asarray(x)
    engine = SuCoEngine(
        xj, build_index(xj, config),
        EnginePolicy(alpha=0.05, beta=0.01, mode="streaming"),
        capacity=capacity,
    )
    ladder = DegradationLadder(engine, levels=1, stats_seed=0)
    server = AnnServer(engine, ladder=ladder)
    ladder.warmup([1], [K])
    manager = MutationManager(server, config, stats_seed=0)
    dur = Durability(
        root, DurabilityConfig(fsync="group"), crash=injector,
        start_worker=False,
    ).attach(server, manager)
    return server, manager, dur


def _run_recovery(scale: dict) -> dict:
    n, d = scale["n"], scale["d"]
    x = np.asarray(GENERATORS["gaussian_mixture"](n, d, 0)).astype(np.float32)
    rng = np.random.default_rng(0)
    max_len = max(scale["wal_lengths"])
    capacity = n + 4 * max_len + 64

    replay_rows = []
    snapshot_row = None
    for wal_len in scale["wal_lengths"]:
        tmp = Path(tempfile.mkdtemp(prefix="suco-recovery-"))
        try:
            root = tmp / "root"
            server, manager, dur = _build_stack(
                x, scale, root, capacity=capacity
            )
            t0 = time.perf_counter()
            dur.snapshot()
            snap_s = time.perf_counter() - t0
            if snapshot_row is None:
                snap_path = sorted(root.glob("snapshot-*.npz"))[-1]
                snapshot_row = dict(
                    wall_s=round(snap_s, 4),
                    bytes=snap_path.stat().st_size,
                )
            # one WAL record per op: 3 inserts for every delete
            for i in range(wal_len):
                if i % 4 == 3:
                    manager.delete(manager.live_keys()[
                        rng.integers(0, manager.server.engine.n_live, size=2)
                    ])
                else:
                    rows = (
                        x[rng.integers(0, n, size=4)]
                        + 0.05 * rng.standard_normal((4, d)).astype(np.float32)
                    )
                    manager.insert(rows)
            dur.flush()
            wal_bytes = (root / "wal.log").stat().st_size
            dur.abandon()  # process death: no orderly close

            t0 = time.perf_counter()
            res = recover(root, start_worker=False)
            wall_s = time.perf_counter() - t0
            diff = fingerprint_diff(
                state_fingerprint(server, manager),
                state_fingerprint(res.server, res.manager),
            )
            assert not diff, f"recovery diverged on {diff}"
            assert res.report.replayed == wal_len
            replay_rows.append(dict(
                wal_records=wal_len,
                wal_bytes=wal_bytes,
                wall_s=round(wall_s, 4),
                replayed=res.report.replayed,
                warmed=res.report.warmed,
                records_per_s=round(wal_len / wall_s, 1),
            ))
            res.durability.close()
        finally:
            shutil.rmtree(tmp)

    # marginal replay throughput: strips the fixed snapshot-load + warmup
    # cost shared by both runs
    lo, hi = replay_rows[0], replay_rows[-1]
    d_records = hi["wal_records"] - lo["wal_records"]
    d_wall = hi["wall_s"] - lo["wall_s"]
    marginal = round(d_records / d_wall, 1) if d_wall > 1e-9 else None

    # -- crash-drill assertion pass -----------------------------------------
    ds_x = np.asarray(
        GENERATORS["gaussian_mixture"](DRILL_SCALE["n"], DRILL_SCALE["d"], 0)
    ).astype(np.float32)
    t0 = time.perf_counter()
    passed = 0
    for point in CRASH_POINTS:
        tmp = Path(tempfile.mkdtemp(prefix="suco-drill-"))
        try:
            rep = recovery_drill(
                tmp,
                lambda r, inj: _build_stack(
                    ds_x, DRILL_SCALE, r,
                    capacity=DRILL_SCALE["n"] + 64, injector=inj,
                ),
                drill_steps(DRILL_SCALE["d"], seed=3),
                point,
                queries=ds_x[:4],
                k=K,
            )
            assert rep.fired, f"{point}: never reached"
            assert rep.lost_acked == 0, f"{point}: lost acknowledged records"
            assert rep.bit_identical, f"{point}: {rep.fingerprint_diff}"
            assert rep.retraces_after_warmup == 0, f"{point}: retraced"
            assert rep.answers_match, f"{point}: answers diverged"
            passed += 1
        finally:
            shutil.rmtree(tmp)
    drills = dict(
        points=len(CRASH_POINTS),
        passed=passed,
        fsync="group",
        wall_s=round(time.perf_counter() - t0, 2),
    )

    return dict(
        snapshot=snapshot_row,
        replay=replay_rows,
        marginal_replay_records_per_s=marginal,
        drills=drills,
    )


def collect(*, toy: bool = False, out_path: Path | None = None) -> dict:
    scale = TOY if toy else FULL
    if out_path is None:
        out_path = TOY_OUT_PATH if toy else OUT_PATH
    section = _run_recovery(scale)
    # Merge into the serve artifact: one file carries the whole serving
    # trajectory.  Standalone runs create a minimal artifact.
    if out_path.exists():
        payload = json.loads(out_path.read_text())
    else:
        payload = dict(
            meta=dict(
                schema="suco-serve-v1",
                backend=jax.default_backend(),
                toy=toy,
                n=scale["n"],
                d=scale["d"],
            )
        )
    payload["recovery"] = section
    out_path.write_text(json.dumps(payload, indent=2) + "\n")
    return payload


def run(*, toy: bool = False) -> list[Row]:
    payload = collect(toy=toy)
    rec = payload["recovery"]
    rows: list[Row] = [
        (
            "serve_recovery/snapshot",
            rec["snapshot"]["wall_s"] * 1e6,
            f"bytes={rec['snapshot']['bytes']}",
        ),
    ]
    for r in rec["replay"]:
        rows.append((
            f"serve_recovery/replay_{r['wal_records']}",
            r["wall_s"] * 1e6,
            f"records_per_s={r['records_per_s']};warmed={r['warmed']};"
            f"wal_bytes={r['wal_bytes']}",
        ))
    rows.append((
        "serve_recovery/drills",
        rec["drills"]["wall_s"] * 1e6,
        f"passed={rec['drills']['passed']}/{rec['drills']['points']};"
        f"fsync={rec['drills']['fsync']};"
        f"marginal_replay_per_s={rec['marginal_replay_records_per_s']}",
    ))
    return rows


if __name__ == "__main__":
    for r in run(toy=True):
        print(",".join(map(str, r)))
