"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus a roofline summary from
the dry-run artifacts when present).

``--suite index_build`` runs the index-construction perf suite instead and
writes ``BENCH_index_build.json`` (build wall time + peak-intermediate
estimate per mode for n in {1e4, 1e5, 1e6}) — the artifact CI tracks for
the perf trajectory of ``build_index``.

``--suite serve`` runs the query-serving suite (warmed SuCoEngine behind
the continuous micro-batching AnnServer) and writes ``BENCH_serve.json``
(QPS + p50/p99 latency per traffic mix for the legacy streaming engine
*and* the fused single-pass engine — the ``fused`` section tracks the
per-mix speedup — zero-retrace-after-warmup asserted for both).  ``--suite serve_async`` is the pipelined-serving slice of the
same collection: sync-vs-async replay per mix, the traffic-driven bucket
autoscale consumption path, and the heterogeneous-k sharded pool — the
zero-retrace invariant asserted on all three.  ``--suite serve_chaos``
runs the resilience smoke (``BENCH_serve_chaos.json``): a forced
degrade/recover walk down the degradation ladder with
``retraces_after_warmup == 0`` asserted, plus the flood-overload replay
comparing admission control + degradation against an uncontrolled
server.  ``--suite serve_mutation`` runs the live-mutation lane (insert
throughput, tombstone-delete visibility, warm re-index handoff with a
~0 swap pause and recall before/after the re-cluster) and merges a
``mutation`` section into ``BENCH_serve.json`` — run it after ``serve``
so one artifact carries the whole serving trajectory.
``--suite serve_recovery`` runs the durability lane (snapshot wall time,
cold-recovery wall time and WAL replay rate vs log length, and the
crash-drill assertion pass over every instrumented boundary) and merges
a ``recovery`` section into the same artifact.  ``--toy`` is the CI
smoke form for any of these: shrunk sizes, writes the ``*.toy.json``
artifact.
"""

from __future__ import annotations

import sys
import traceback

MODULES = (
    "benchmarks.fig2_pareto",
    "benchmarks.table2_sc_linear",
    "benchmarks.table4_suco_vs_linear",
    "benchmarks.table5_l1_l2",
    "benchmarks.fig6_da_vs_ms",
    "benchmarks.fig7_k_ns",
    "benchmarks.fig8_alpha_beta",
    "benchmarks.fig9_12_competitors",
    "benchmarks.fig14_preprocessing",
    "benchmarks.micro_merge_pool",
    "benchmarks.micro_fused_query",
)

# suite name -> "module" (entry point `run`) or "module:function"
SUITES = {
    "index_build": "benchmarks.index_build",
    "serve": "benchmarks.serve",
    "serve_async": "benchmarks.serve:run_async",
    "serve_chaos": "benchmarks.serve_chaos",
    "serve_mutation": "benchmarks.serve_mutation",
    "serve_recovery": "benchmarks.serve_recovery",
}


def _run_suite(name: str, extra: list[str]) -> None:
    import importlib
    import inspect

    if name not in SUITES:
        raise SystemExit(f"unknown suite {name!r}; available: {sorted(SUITES)}")
    modname, _, fn_name = SUITES[name].partition(":")
    fn = getattr(importlib.import_module(modname), fn_name or "run")
    kwargs = {}
    if "--toy" in extra:
        if "toy" not in inspect.signature(fn).parameters:
            raise SystemExit(f"suite {name!r} does not support --toy")
        kwargs["toy"] = True
    print("name,us_per_call,derived")
    for row_name, us, derived in fn(**kwargs):
        print(f"{row_name},{us:.1f},{derived}", flush=True)


def main() -> None:
    import importlib

    argv = sys.argv[1:]
    if "--suite" in argv:
        idx = argv.index("--suite")
        if idx + 1 >= len(argv):
            raise SystemExit("--suite requires a name (e.g. index_build)")
        _run_suite(argv[idx + 1], argv[idx + 2:])
        return
    only = argv[0] if argv else None
    print("name,us_per_call,derived")
    failed = 0
    for modname in MODULES:
        if only and only not in modname:
            continue
        try:
            mod = importlib.import_module(modname)
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception:
            failed += 1
            print(f"{modname},ERROR,", flush=True)
            traceback.print_exc(file=sys.stderr)

    # roofline summary (reads benchmarks/results/*.json if the dry-run ran)
    try:
        from benchmarks.roofline import load_cells, roofline_row

        for rec in load_cells():
            row = roofline_row(rec)
            if row is None:
                continue
            name = f"roofline/{row['arch']}/{row['shape']}/{row['mesh']}"
            derived = (
                f"dominant={row['dominant']};compute_s={row['compute_s']:.4e};"
                f"memory_s={row['memory_s']:.4e};collective_s={row['collective_s']:.4e};"
                f"useful={row['useful_ratio']:.2f}"
            )
            step = max(row['compute_s'], row['memory_s'], row['collective_s'])
            print(f"{name},{step*1e6:.1f},{derived}")
    except Exception:
        traceback.print_exc(file=sys.stderr)

    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
