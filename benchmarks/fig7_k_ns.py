"""Figure 7: parameter study on K (cluster count) and Ns (subspaces):
indexing time, index memory, query time, recall."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import Row, dataset, timeit
from repro.core import SuCoConfig, build_index, suco_query
from repro.data import recall


def run() -> list[Row]:
    rows: list[Row] = []
    ds = dataset("gaussian_mixture", n=20_000)
    x, q = jnp.asarray(ds.x), jnp.asarray(ds.queries)

    for sqrt_k in (16, 32, 64):
        cfg = SuCoConfig(n_subspaces=8, sqrt_k=sqrt_k, kmeans_iters=5)
        us_build = timeit(
            lambda: jax.block_until_ready(build_index(x, cfg).cell_ids), repeats=1
        )
        idx = build_index(x, cfg)
        us_q = timeit(
            lambda: suco_query(x, idx, q, k=10, alpha=0.05, beta=0.02)
            .ids.block_until_ready(), repeats=2,
        )
        res = suco_query(x, idx, q, k=10, alpha=0.05, beta=0.02)
        r = recall(np.asarray(res.ids), ds.gt_ids)
        rows.append(
            (f"fig7/K={sqrt_k**2}", us_q,
             f"recall={r:.4f};index_us={us_build:.0f};mem={idx.memory_bytes()}")
        )

    for ns in (4, 8, 16):
        cfg = SuCoConfig(n_subspaces=ns, sqrt_k=32, kmeans_iters=5)
        us_build = timeit(
            lambda: jax.block_until_ready(build_index(x, cfg).cell_ids), repeats=1
        )
        idx = build_index(x, cfg)
        us_q = timeit(
            lambda: suco_query(x, idx, q, k=10, alpha=0.05, beta=0.02)
            .ids.block_until_ready(), repeats=2,
        )
        res = suco_query(x, idx, q, k=10, alpha=0.05, beta=0.02)
        r = recall(np.asarray(res.ids), ds.gt_ids)
        rows.append(
            (f"fig7/Ns={ns}", us_q,
             f"recall={r:.4f};index_us={us_build:.0f};mem={idx.memory_bytes()}")
        )
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
