"""Table 4: SuCo vs SC-Linear — query time speedup at matched parameters.

Paper: 600-1000x at n=1e7-1e8 with recall drop <4 points.  The speedup is
O(n / (centroid work + collision gather)), so the CPU replica at n=5e4
shows a smaller but strictly >1 factor with the same recall behaviour."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import Row, dataset, timeit
from repro.core import SuCoConfig, build_index, contiguous_spec, sc_linear_query, suco_query
from repro.data import recall


def run() -> list[Row]:
    ds = dataset("gaussian_mixture")
    n, d = ds.x.shape
    x, q = jnp.asarray(ds.x), jnp.asarray(ds.queries)
    alpha, beta = 0.03, 0.01
    spec = contiguous_spec(d, 8)

    us_lin = timeit(
        lambda: sc_linear_query(x, q, spec=spec, k=10, alpha=alpha, beta=beta)
        .ids.block_until_ready(), repeats=1,
    )
    res_lin = sc_linear_query(x, q, spec=spec, k=10, alpha=alpha, beta=beta)
    r_lin = recall(np.asarray(res_lin.ids), ds.gt_ids)

    cfg = SuCoConfig(n_subspaces=8, sqrt_k=32, kmeans_iters=5)
    idx = build_index(x, cfg)
    # Production path: the tiled streaming engine (mode="auto" also picks it
    # at this n).  The dense (m, n) score-matrix path stays as the reference.
    us_suco = timeit(
        lambda: suco_query(x, idx, q, k=10, alpha=alpha, beta=beta, mode="streaming")
        .ids.block_until_ready(), repeats=2,
    )
    res_suco = suco_query(x, idx, q, k=10, alpha=alpha, beta=beta, mode="streaming")
    r_suco = recall(np.asarray(res_suco.ids), ds.gt_ids)

    us_dense = timeit(
        lambda: suco_query(x, idx, q, k=10, alpha=alpha, beta=beta, mode="dense")
        .ids.block_until_ready(), repeats=2,
    )
    res_dense = suco_query(x, idx, q, k=10, alpha=alpha, beta=beta, mode="dense")
    r_dense = recall(np.asarray(res_dense.ids), ds.gt_ids)
    assert r_suco >= r_dense, f"streaming recall regressed: {r_suco} < {r_dense}"

    return [
        ("table4/sc_linear", us_lin, f"recall={r_lin:.4f}"),
        ("table4/suco", us_suco, f"recall={r_suco:.4f}"),
        ("table4/suco_dense", us_dense, f"recall={r_dense:.4f}"),
        ("table4/speedup", 0.0, f"{us_lin/us_suco:.1f}x"),
    ]


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
