"""Figure 6: Dynamic Activation vs Multi-sequence IMI traversal.

Paper: DA is up to 40% faster; the gap grows with K and alpha (heavier
workload).  Replicated with the numpy reference implementations; the
sort-prefix TPU form is benchmarked alongside for context."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import Row, timeit
from repro.core import activate_cells_sorted
from repro.core.da_numpy import dynamic_activation, multi_sequence


def run() -> list[Row]:
    rows: list[Row] = []
    rng = np.random.default_rng(0)
    n = 1_000_000  # virtual points distributed over cells
    for sqrt_k in (32, 50, 64):
        d1 = rng.random(sqrt_k)
        d2 = rng.random(sqrt_k)
        counts = rng.multinomial(n, np.ones(sqrt_k * sqrt_k) / sqrt_k**2)
        counts2d = counts.reshape(sqrt_k, sqrt_k)
        for alpha in (0.01, 0.05, 0.1):
            target = int(alpha * n)
            us_ms = timeit(lambda: multi_sequence(d1, d2, counts2d, target), repeats=3)
            us_da = timeit(lambda: dynamic_activation(d1, d2, counts2d, target), repeats=3)
            j1, j2, jc = jnp.asarray(d1), jnp.asarray(d2), jnp.asarray(counts)
            sorted_fn = jax.jit(
                lambda a, b, c: activate_cells_sorted(a, b, c, target)
            )
            sorted_fn(j1, j2, jc).block_until_ready()
            us_sp = timeit(lambda: sorted_fn(j1, j2, jc).block_until_ready(), repeats=3)
            gain = (us_ms - us_da) / us_ms * 100
            rows.append(
                (f"fig6/K={sqrt_k**2}/alpha={alpha}/multi_sequence", us_ms, ""),
            )
            rows.append(
                (f"fig6/K={sqrt_k**2}/alpha={alpha}/dynamic_activation", us_da,
                 f"gain={gain:.1f}%"),
            )
            rows.append(
                (f"fig6/K={sqrt_k**2}/alpha={alpha}/sort_prefix(jax)", us_sp, ""),
            )
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
