"""Serving perf suite: the warmed SuCoEngine behind the continuous
micro-batching AnnServer.

Run via ``python -m benchmarks.run --suite serve`` — emits
``BENCH_serve.json`` so the query-serving trajectory (QPS, p50/p99 latency
per traffic mix) is tracked from PR 3 on, next to the index-build artifact.

Per traffic mix the driver submits bursts of heterogeneous ``(query, k)``
requests, steps the server until drained, and records:

* ``qps``, ``p50_ms`` / ``p99_ms`` / ``mean_ms`` — per-request latency from
  admission to host-side materialisation;
* ``retraces_after_warmup`` — the serving invariant: the engine pre-compiles
  one executable per (bucket, k) in the mix, so the jit cache size must be
  flat across every step (the JSON records it per step; any growth is a
  retrace on the hot path and fails the suite's own assertion).

``--toy`` (CI smoke) shrinks the dataset/mixes and writes
``BENCH_serve.toy.json`` so the tracked artifact is never clobbered by a
smoke run.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np
import jax

from benchmarks.common import Row
from repro.core import EnginePolicy, SuCoConfig, SuCoEngine, batch_bucket
from repro.data import GENERATORS
from repro.serve.ann import AnnRequest, AnnServer, latency_summary

OUT_PATH = Path("BENCH_serve.json")
TOY_OUT_PATH = Path("BENCH_serve.toy.json")

# Traffic mixes: bursts of single-query requests; sizes are the burst
# lengths the admission queue sees between steps, ks the per-request k mix.
MIXES = (
    dict(name="steady_b8", sizes=(8,), ks=(10,), bursts=24),
    dict(name="mixed_batch", sizes=(1, 2, 5, 8, 16), ks=(10,), bursts=20),
    dict(name="mixed_batch_k", sizes=(1, 4, 16), ks=(5, 10), bursts=20),
)

FULL = dict(n=48_000, d=32, sqrt_k=16, n_subspaces=8, kmeans_iters=3,
            max_batch=16, mixes=MIXES)
TOY = dict(n=4_000, d=16, sqrt_k=8, n_subspaces=4, kmeans_iters=2,
           max_batch=8,
           mixes=tuple(dict(m, bursts=4) for m in MIXES))


def _run_mix(engine: SuCoEngine, mix: dict, max_batch: int, rng) -> dict:
    server = AnnServer(engine, max_batch=max_batch)
    compile_start = engine.compile_count
    x = np.asarray(engine.x)
    rid = 0
    for b in range(mix["bursts"]):
        size = int(mix["sizes"][b % len(mix["sizes"])])
        for _ in range(size):
            q = x[rng.integers(0, x.shape[0])] + rng.normal(
                scale=0.01, size=x.shape[1]
            ).astype(np.float32)
            server.submit(AnnRequest(rid, q, k=int(rng.choice(mix["ks"]))))
            rid += 1
        server.run_until_drained()
    done = server.completed
    rec = dict(
        name=mix["name"],
        sizes=list(mix["sizes"]),
        ks=list(mix["ks"]),
        steps=len(server.steps),
        compile_count_per_step=[s.compile_count for s in server.steps],
        compile_count_start=compile_start,
        compile_count_end=engine.compile_count,
        retraces_after_warmup=engine.compile_count - compile_start,
        **latency_summary(done),
    )
    return rec


def collect(*, toy: bool = False, out_path: Path | None = None) -> dict:
    scale = TOY if toy else FULL
    if out_path is None:
        out_path = TOY_OUT_PATH if toy else OUT_PATH
    x = np.asarray(
        GENERATORS["gaussian_mixture"](scale["n"], scale["d"], 0)
    ).astype(np.float32)
    policy = EnginePolicy(alpha=0.05, beta=0.01)
    config = SuCoConfig(
        n_subspaces=scale["n_subspaces"], sqrt_k=scale["sqrt_k"],
        kmeans_iters=scale["kmeans_iters"], seed=0,
    )
    t0 = time.perf_counter()
    engine = SuCoEngine.build(x, config, policy=policy)
    build_s = time.perf_counter() - t0

    # Warm every (bucket, k) the mixes can produce: micro-batches are capped
    # at max_batch, so the bucket set is bucket(1..max_batch) x union(ks).
    all_ks = sorted({k for m in scale["mixes"] for k in m["ks"]})
    t0 = time.perf_counter()
    warm_compiles = engine.warmup(
        batch_sizes=range(1, scale["max_batch"] + 1), ks=all_ks
    )
    warmup_s = time.perf_counter() - t0

    rng = np.random.default_rng(0)
    mixes = [_run_mix(engine, m, scale["max_batch"], rng) for m in scale["mixes"]]
    for m in mixes:
        assert m["retraces_after_warmup"] == 0, (
            f"mix {m['name']} retraced {m['retraces_after_warmup']} times "
            "after warmup — the engine bucketing failed to cover the traffic"
        )
    payload = dict(
        meta=dict(
            schema="suco-serve-v1",
            backend=jax.default_backend(),
            toy=toy,
            n=scale["n"],
            d=scale["d"],
            engine=dict(
                mode=engine.mode,
                alpha=policy.alpha,
                beta=policy.beta,
                block_n=policy.block_n,
                batch_buckets=list(policy.batch_buckets),
                max_batch=scale["max_batch"],
            ),
            build_s=round(build_s, 3),
            warmup_s=round(warmup_s, 3),
            warm_compiles=warm_compiles,
            executables=engine.compile_count,
        ),
        mixes=mixes,
    )
    out_path.write_text(json.dumps(payload, indent=2) + "\n")
    return payload


def run(*, toy: bool = False) -> list[Row]:
    payload = collect(toy=toy)
    rows: list[Row] = []
    for m in payload["mixes"]:
        us = 1e6 / m["qps"] if m["qps"] else float("nan")
        derived = (
            f"qps={m['qps']:.1f};p50_ms={m['p50_ms']:.2f};"
            f"p99_ms={m['p99_ms']:.2f};steps={m['steps']};"
            f"retraces={m['retraces_after_warmup']}"
        )
        rows.append((f"serve/{m['name']}", us, derived))
    meta = payload["meta"]
    rows.append((
        "serve/warmup",
        meta["warmup_s"] * 1e6,
        f"executables={meta['executables']};mode={meta['engine']['mode']}",
    ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
