"""Serving perf suite: the warmed SuCoEngine behind the continuous
micro-batching AnnServer.

Run via ``python -m benchmarks.run --suite serve`` — emits
``BENCH_serve.json`` so the query-serving trajectory (QPS, p50/p99 latency
per traffic mix) is tracked from PR 3 on, next to the index-build artifact.

Per traffic mix the driver submits bursts of heterogeneous ``(query, k)``
requests, steps the server until drained, and records:

* ``qps``, ``p50_ms`` / ``p99_ms`` / ``mean_ms`` — per-request latency from
  admission to host-side materialisation;
* ``retraces_after_warmup`` — the serving invariant: the engine pre-compiles
  one executable per (bucket, k) in the mix, so the jit cache size must be
  flat across every step (the JSON records it per step; any growth is a
  retrace on the hot path and fails the suite's own assertion).

The ``fused`` section runs the same traffic mixes on a second engine over
the same ``(x, index)`` whose policy selects the **single-pass fused
query engine** (``mode="fused"``, autotuned tiling — see
:func:`repro.core.suco.suco_query_fused`): score -> Pareto-prune ->
merge -> in-pass rerank in one scan.  The ``mixes`` section keeps the
legacy chunked streaming path so the fused speedup
(``fused[i]["fused_speedup"]`` = fused QPS / streaming QPS per mix) is
tracked against the same baseline the artifact has carried since PR 3.
Zero-retrace-after-warmup is asserted for the fused executables too.

The ``serve_async`` sections (``--suite serve_async`` runs just these;
``--suite serve`` includes them) replay identical traces through the
synchronous and pipelined servers and compare QPS / latency splits —
recall-vs-QPS honesty demands both servers answer identically, which the
test suite enforces, so the artifact only tracks speed:

* ``serve_async`` — per mix: sync vs async replay, ``async_speedup``;
* ``autoscale``  — the traffic histogram the engine observed, the
  waste-minimising bucket proposal, and a zero-retrace replay on the
  autoscaled engine (``SuCoEngine.autoscaled`` + ``warmup(None)``);
* ``sharded_pool`` — a heterogeneous-k replay through a
  :class:`~repro.distributed.engine.ShardedEnginePool` on a 1-device mesh.

``retraces_after_warmup == 0`` is asserted for the sync, async and
sharded-pool paths alike.

The ``overload`` section (PR 7) floods the server on a virtual clock
(:mod:`repro.serve.chaos`) at arrivals far above the service rate and
compares the resilient configuration — bounded admission queue +
:class:`~repro.serve.ann.OverloadController` stepping a
:class:`~repro.serve.ann.DegradationLadder` — against the same server
with no admission control.  Tracked per arm: shed rate, degraded-answer
fraction, the minimum Theorem-2 ``quality_bound`` attached to any
degraded answer, p99 latency and the deadline hit rate (over admitted
deadlined requests).  The suite asserts the controlled arm keeps a
strictly higher deadline hit rate and zero retraces across the forced
degrade/recover excursion; the replay is wall-clock-free, so the section
is deterministic in (trace seed, chaos seed).

``--toy`` (CI smoke) shrinks the dataset/mixes and writes
``BENCH_serve.toy.json`` so the tracked artifact is never clobbered by a
smoke run.

Regenerating the tracked artifact: run ``python -m benchmarks.run --suite
serve`` (no ``--toy``) on an otherwise-idle host and commit the rewritten
``BENCH_serve.json`` — always regenerate the streaming ``mixes`` and the
``fused`` section in the same run so the speedup compares like with like
on one host.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import Row
from repro.core import (
    EnginePolicy,
    SuCoConfig,
    SuCoEngine,
    padding_waste,
)
from repro.data import GENERATORS
from repro.serve.ann import (
    AnnRequest,
    AnnServer,
    AsyncAnnServer,
    DegradationLadder,
    OverloadController,
    latency_summary,
)
from repro.serve.chaos import (
    ChaosConfig,
    ChaosEngine,
    VirtualClock,
    flood_trace,
    replay,
    wrap_ladder,
)

OUT_PATH = Path("BENCH_serve.json")
TOY_OUT_PATH = Path("BENCH_serve.toy.json")

# Traffic mixes: bursts of single-query requests; sizes are the burst
# lengths the admission queue sees between steps, ks the per-request k mix.
MIXES = (
    dict(name="steady_b8", sizes=(8,), ks=(10,), bursts=24),
    dict(name="mixed_batch", sizes=(1, 2, 5, 8, 16), ks=(10,), bursts=20),
    dict(name="mixed_batch_k", sizes=(1, 4, 16), ks=(5, 10), bursts=20),
)

FULL = dict(n=48_000, d=32, sqrt_k=16, n_subspaces=8, kmeans_iters=3,
            max_batch=16, mixes=MIXES, overload_requests=192)
TOY = dict(n=4_000, d=16, sqrt_k=8, n_subspaces=4, kmeans_iters=2,
           max_batch=8,
           mixes=tuple(dict(m, bursts=4) for m in MIXES),
           overload_requests=64)

# Overload replay: virtual service time per dispatch vs the arrival spacing
# fixes the flood intensity (arrivals ~100x faster than a max_batch=4 step
# drains them); the deadline budget is 5 service times.
OVERLOAD = dict(seed=5, trace_seed=6, service_s=0.02, interarrival_s=0.0002,
                deadline_s=0.1, max_batch=4, max_queue=8)


def _run_mix(engine: SuCoEngine, mix: dict, max_batch: int, rng) -> dict:
    server = AnnServer(engine, max_batch=max_batch)
    compile_start = engine.compile_count
    x = np.asarray(engine.x)
    rid = 0
    for b in range(mix["bursts"]):
        size = int(mix["sizes"][b % len(mix["sizes"])])
        for _ in range(size):
            q = x[rng.integers(0, x.shape[0])] + rng.normal(
                scale=0.01, size=x.shape[1]
            ).astype(np.float32)
            server.submit(AnnRequest(rid, q, k=int(rng.choice(mix["ks"]))))
            rid += 1
        server.run_until_drained()
    done = server.completed
    rec = dict(
        name=mix["name"],
        sizes=list(mix["sizes"]),
        ks=list(mix["ks"]),
        steps=len(server.steps),
        compile_count_per_step=[s.compile_count for s in server.steps],
        compile_count_start=compile_start,
        compile_count_end=engine.compile_count,
        retraces_after_warmup=engine.compile_count - compile_start,
        **latency_summary(done),
    )
    return rec


def _make_trace(x: np.ndarray, mix: dict, rng) -> list[tuple[np.ndarray, int]]:
    """The full ``(query, k)`` request trace a mix produces (deterministic in
    ``rng``): the same trace replays through every server variant so the
    comparison isolates the step discipline."""
    trace: list[tuple[np.ndarray, int]] = []
    for b in range(mix["bursts"]):
        size = int(mix["sizes"][b % len(mix["sizes"])])
        for _ in range(size):
            q = x[rng.integers(0, x.shape[0])] + rng.normal(
                scale=0.01, size=x.shape[1]
            ).astype(np.float32)
            trace.append((q.astype(np.float32), int(rng.choice(mix["ks"]))))
    return trace


def _replay(engine: SuCoEngine, server: AnnServer, trace) -> dict:
    """Submit the whole trace, drain, and summarise (queue-heavy replay:
    the regime where pipelined dispatch can overlap host and device)."""
    compile_start = engine.compile_count
    server.submit_many([AnnRequest(i, q, k=k) for i, (q, k) in enumerate(trace)])
    done = server.run_until_drained()
    return dict(
        steps=len(server.steps),
        retraces_after_warmup=engine.compile_count - compile_start,
        **latency_summary(done),
    )


def _run_serve_async(engine: SuCoEngine, scale: dict, *, toy: bool) -> list[dict]:
    """Sync vs pipelined replay of each traffic mix on the warmed engine."""
    recs = []
    for mix in scale["mixes"]:
        trace = _make_trace(np.asarray(engine.x), mix, np.random.default_rng(1))
        rec = dict(name=mix["name"], requests=len(trace))
        rec["sync"] = _replay(
            engine, AnnServer(engine, max_batch=scale["max_batch"]), trace
        )
        rec["async"] = _replay(
            engine,
            AsyncAnnServer(engine, max_batch=scale["max_batch"], depth=2),
            trace,
        )
        rec["async_speedup"] = (
            rec["async"]["qps"] / rec["sync"]["qps"] if rec["sync"]["qps"] else 1.0
        )
        for path in ("sync", "async"):
            assert rec[path]["retraces_after_warmup"] == 0, (
                f"{mix['name']}/{path} retraced after warmup"
            )
        recs.append(rec)
    if max(r["async_speedup"] for r in recs) < 1.0:
        # A correctness gate only for the tracked full-scale artifact: on a
        # noisy shared CI runner the toy smoke's host/device overlap is a
        # wall-clock coin flip, so there it warns instead of failing.
        msg = "pipelined replay slower than sync on every mix: " + str(
            {r["name"]: round(r["async_speedup"], 3) for r in recs}
        )
        if toy:
            print(f"[serve_async] WARNING (toy run, not enforced): {msg}")
        else:
            raise AssertionError(msg)
    return recs


def _run_autoscale(engine: SuCoEngine, scale: dict, all_ks) -> dict:
    """Autoscale consumption path: propose buckets from the traffic the
    engine observed across every run so far, rebucket, warm exactly the
    observed sizes, and replay the mixed-k trace with zero retraces."""
    observed = {int(m): int(c) for m, c in sorted(engine.policy.traffic.items())}
    proposed = engine.policy.autoscale_buckets()
    auto = engine.autoscaled()
    t0 = time.perf_counter()
    warm_compiles = auto.warmup(None, ks=all_ks)  # exactly the observed sizes
    warmup_s = time.perf_counter() - t0
    mix = scale["mixes"][-1]  # the mixed-k mix
    trace = _make_trace(np.asarray(engine.x), mix, np.random.default_rng(1))
    replay = _replay(
        auto, AsyncAnnServer(auto, max_batch=scale["max_batch"], depth=2), trace
    )
    assert replay["retraces_after_warmup"] == 0, "autoscaled engine retraced"
    return dict(
        observed=observed,
        default_buckets=list(engine.policy.batch_buckets),
        proposed_buckets=list(proposed),
        padding_waste_default=padding_waste(observed, engine.policy.batch_buckets),
        padding_waste_autoscaled=padding_waste(observed, proposed),
        warm_compiles=warm_compiles,
        warmup_s=round(warmup_s, 3),
        replay=dict(name=mix["name"], **replay),
    )


def _run_sharded_pool(engine: SuCoEngine, scale: dict, all_ks) -> dict:
    """Heterogeneous-k replay through a ShardedEnginePool (1-device mesh in
    this process; the multi-device form is covered by the distributed test
    suite's subprocess script)."""
    from repro.distributed.engine import DistSuCoConfig, ShardedEnginePool
    from repro.launch.mesh import compat_make_mesh

    mesh = compat_make_mesh((1, 1), ("data", "model"))
    cfg = DistSuCoConfig(
        n_subspaces=scale["n_subspaces"], sqrt_k=scale["sqrt_k"],
        alpha=0.05, beta=0.01, k=int(all_ks[0]), q_chunk=8,
        point_axes=("data",),
    )
    # share the already-built local index: pools consume the same artifact
    # format/layout, no second build
    pool = ShardedEnginePool(mesh, cfg, engine.x, engine.index, ks=all_ks)
    mix = scale["mixes"][-1]
    sizes = tuple(int(s) for s in mix["sizes"])
    t0 = time.perf_counter()
    warm_compiles = pool.warmup(batch_sizes=sizes, ks=all_ks)
    warmup_s = time.perf_counter() - t0
    qs = np.asarray(engine.x)[: max(sizes)]
    n_queries = 0
    t0 = time.perf_counter()
    for i in range(mix["bursts"]):
        m = sizes[i % len(sizes)]
        k = int(all_ks[i % len(all_ks)])
        ids, _ = pool.query(jnp.asarray(qs[:m]), k)
        jax.block_until_ready(ids)
        n_queries += m
    wall = time.perf_counter() - t0
    retraces = pool.compile_count - warm_compiles
    assert retraces == 0, f"sharded pool retraced {retraces}x after warmup"
    return dict(
        mesh=dict(mesh.shape),
        ks=[int(k) for k in all_ks],
        sizes=list(sizes),
        warm_compiles=warm_compiles,
        warmup_s=round(warmup_s, 3),
        executables=pool.compile_count,
        retraces_after_warmup=retraces,
        n_queries=n_queries,
        qps=n_queries / wall if wall > 0 else float("inf"),
    )


def _run_fused(engine: SuCoEngine, scale: dict, mixes: list[dict], all_ks) -> list[dict]:
    """The fused single-pass engine over the same (x, index): identical
    traffic (same rng seed as the streaming ``mixes`` run), QPS compared
    mix-for-mix, zero retraces asserted for the fused executables."""
    fused = SuCoEngine(
        engine.x, engine.index,
        EnginePolicy(alpha=engine.policy.alpha, beta=engine.policy.beta,
                     mode="fused"),
    )
    t0 = time.perf_counter()
    warm_compiles = fused.warmup(
        batch_sizes=range(1, scale["max_batch"] + 1), ks=all_ks
    )
    warmup_s = time.perf_counter() - t0
    rng = np.random.default_rng(0)  # same traffic as the streaming run
    recs = []
    for mix, base in zip(scale["mixes"], mixes):
        rec = _run_mix(fused, mix, scale["max_batch"], rng)
        assert rec["retraces_after_warmup"] == 0, (
            f"fused mix {rec['name']} retraced after warmup"
        )
        rec["fused_speedup"] = rec["qps"] / base["qps"] if base["qps"] else 1.0
        recs.append(rec)
    tiles = fused.tiles_for(scale["max_batch"], int(all_ks[0]))
    recs.insert(0, dict(
        name="_meta",
        mode=fused.mode,
        merge_impl=fused.policy.merge_impl,
        tiles=dict(block_n=tiles.block_n, bm=tiles.bm, bn=tiles.bn,
                   survivor_cap=tiles.survivor_cap),
        warm_compiles=warm_compiles,
        warmup_s=round(warmup_s, 3),
        executables=fused.compile_count,
    ))
    return recs


def _run_overload(engine: SuCoEngine, scale: dict) -> dict:
    """Flood the server on a virtual clock, with and without admission
    control + the degradation ladder, and record what each arm paid.

    Both arms replay the SAME seeded arrival trace through the SAME chaos
    service-time schedule, so the comparison isolates the control policy.
    """
    ov = OVERLOAD
    n_req = int(scale["overload_requests"])
    queries = np.asarray(engine.x)[:512]

    def _arm(controlled: bool) -> dict:
        clock = VirtualClock()
        cfg = ChaosConfig(seed=ov["seed"], service_s=ov["service_s"])
        if controlled:
            ladder = DegradationLadder(engine, levels=2)
            ladder.warmup(batch_sizes=range(1, ov["max_batch"] + 1), ks=(10,))
            wrap_ladder(ladder, cfg, clock)
            server = AnnServer(
                ladder.engines[0], max_batch=ov["max_batch"], clock=clock,
                sleep=clock.advance, max_queue=ov["max_queue"], ladder=ladder,
                controller=OverloadController(high_depth=4, low_depth=1),
            )
        else:
            server = AnnServer(
                ChaosEngine(engine, cfg, clock), max_batch=ov["max_batch"],
                clock=clock, sleep=clock.advance,
            )
        trace = flood_trace(
            n_req, queries.shape[1], interarrival_s=ov["interarrival_s"],
            deadline_s=ov["deadline_s"], seed=ov["trace_seed"], queries=queries,
        )
        rep = replay(server, trace, clock)
        s = rep.summary
        return dict(
            n_requests=n_req,
            n_shed=s["n_shed"],
            shed_rate=s["n_shed"] / n_req,
            n_expired=s["n_expired"],
            degraded_fraction=s["degraded_fraction"],
            max_level=rep.max_level,
            quality_bound_min=s["quality_bound_min"],
            deadline_hit_rate=s["deadline_hit_rate"],
            p50_ms=s["p50_ms"],
            p99_ms=s["p99_ms"],
            retraces_after_warmup=rep.retraces,
        )

    with_ctrl, without = _arm(True), _arm(False)
    assert with_ctrl["retraces_after_warmup"] == 0, (
        "overload replay retraced: degradation must reuse pre-warmed "
        "executables"
    )
    assert with_ctrl["deadline_hit_rate"] > without["deadline_hit_rate"], (
        "admission control lost the flood comparison: "
        f"{with_ctrl['deadline_hit_rate']} <= {without['deadline_hit_rate']}"
    )
    return dict(
        chaos=dict(ov),
        with_admission_control=with_ctrl,
        without_admission_control=without,
    )


def collect(*, toy: bool = False, out_path: Path | None = None) -> dict:
    scale = TOY if toy else FULL
    if out_path is None:
        out_path = TOY_OUT_PATH if toy else OUT_PATH
    x = np.asarray(
        GENERATORS["gaussian_mixture"](scale["n"], scale["d"], 0)
    ).astype(np.float32)
    # mode="streaming" pins the legacy chunked path: the `mixes` section
    # stays comparable with the artifact's history, and the new `fused`
    # section measures its speedup against it on the same host/run.
    policy = EnginePolicy(alpha=0.05, beta=0.01, mode="streaming")
    config = SuCoConfig(
        n_subspaces=scale["n_subspaces"], sqrt_k=scale["sqrt_k"],
        kmeans_iters=scale["kmeans_iters"], seed=0,
    )
    t0 = time.perf_counter()
    engine = SuCoEngine.build(x, config, policy=policy)
    build_s = time.perf_counter() - t0

    # Warm every (bucket, k) the mixes can produce: micro-batches are capped
    # at max_batch, so the bucket set is bucket(1..max_batch) x union(ks).
    all_ks = sorted({k for m in scale["mixes"] for k in m["ks"]})
    t0 = time.perf_counter()
    warm_compiles = engine.warmup(
        batch_sizes=range(1, scale["max_batch"] + 1), ks=all_ks
    )
    warmup_s = time.perf_counter() - t0

    rng = np.random.default_rng(0)
    mixes = [_run_mix(engine, m, scale["max_batch"], rng) for m in scale["mixes"]]
    for m in mixes:
        assert m["retraces_after_warmup"] == 0, (
            f"mix {m['name']} retraced {m['retraces_after_warmup']} times "
            "after warmup — the engine bucketing failed to cover the traffic"
        )
    fused = _run_fused(engine, scale, mixes, all_ks)
    serve_async = _run_serve_async(engine, scale, toy=toy)
    autoscale = _run_autoscale(engine, scale, all_ks)
    sharded_pool = _run_sharded_pool(engine, scale, all_ks)
    overload = _run_overload(engine, scale)
    payload = dict(
        meta=dict(
            schema="suco-serve-v1",
            backend=jax.default_backend(),
            toy=toy,
            n=scale["n"],
            d=scale["d"],
            engine=dict(
                mode=engine.mode,
                alpha=policy.alpha,
                beta=policy.beta,
                block_n=policy.block_n,
                merge_impl=policy.merge_impl,
                batch_buckets=list(policy.batch_buckets),
                max_batch=scale["max_batch"],
            ),
            build_s=round(build_s, 3),
            warmup_s=round(warmup_s, 3),
            warm_compiles=warm_compiles,
            executables=engine.compile_count,
        ),
        mixes=mixes,
        fused=fused,
        serve_async=serve_async,
        autoscale=autoscale,
        sharded_pool=sharded_pool,
        overload=overload,
    )
    out_path.write_text(json.dumps(payload, indent=2) + "\n")
    return payload


def _async_rows(payload: dict) -> list[Row]:
    rows: list[Row] = []
    for m in payload["serve_async"]:
        us = 1e6 / m["async"]["qps"] if m["async"]["qps"] else float("nan")
        derived = (
            f"qps={m['async']['qps']:.1f};sync_qps={m['sync']['qps']:.1f};"
            f"speedup={m['async_speedup']:.3f};"
            f"queue_p50_ms={m['async']['queue_p50_ms']:.2f};"
            f"exec_p50_ms={m['async']['exec_p50_ms']:.2f};"
            f"retraces={m['async']['retraces_after_warmup']}"
        )
        rows.append((f"serve_async/{m['name']}", us, derived))
    a = payload["autoscale"]
    rows.append((
        "serve_async/autoscale",
        a["warmup_s"] * 1e6,
        f"buckets={'/'.join(map(str, a['proposed_buckets']))};"
        f"waste={a['padding_waste_autoscaled']}(was {a['padding_waste_default']});"
        f"replay_qps={a['replay']['qps']:.1f};"
        f"retraces={a['replay']['retraces_after_warmup']}",
    ))
    p = payload["sharded_pool"]
    rows.append((
        "serve_async/sharded_pool",
        1e6 / p["qps"] if p["qps"] else float("nan"),
        f"qps={p['qps']:.1f};ks={'/'.join(map(str, p['ks']))};"
        f"executables={p['executables']};retraces={p['retraces_after_warmup']}",
    ))
    return rows


def _overload_rows(payload: dict) -> list[Row]:
    rows: list[Row] = []
    for arm in ("with_admission_control", "without_admission_control"):
        o = payload["overload"][arm]
        rows.append((
            f"serve_overload/{arm}",
            o["p99_ms"] * 1e3,  # virtual-clock p99, reported in us like the rest
            f"hit_rate={o['deadline_hit_rate']:.3f};shed_rate={o['shed_rate']:.3f};"
            f"degraded={o['degraded_fraction']:.3f};qbound_min={o['quality_bound_min']:.3f};"
            f"retraces={o['retraces_after_warmup']}",
        ))
    return rows


def run(*, toy: bool = False) -> list[Row]:
    payload = collect(toy=toy)
    rows: list[Row] = []
    for m in payload["mixes"]:
        us = 1e6 / m["qps"] if m["qps"] else float("nan")
        derived = (
            f"qps={m['qps']:.1f};p50_ms={m['p50_ms']:.2f};"
            f"p99_ms={m['p99_ms']:.2f};steps={m['steps']};"
            f"retraces={m['retraces_after_warmup']}"
        )
        rows.append((f"serve/{m['name']}", us, derived))
    fused_meta, fused_mixes = payload["fused"][0], payload["fused"][1:]
    for m in fused_mixes:
        us = 1e6 / m["qps"] if m["qps"] else float("nan")
        derived = (
            f"qps={m['qps']:.1f};speedup={m['fused_speedup']:.2f};"
            f"p50_ms={m['p50_ms']:.2f};p99_ms={m['p99_ms']:.2f};"
            f"block_n={fused_meta['tiles']['block_n']};"
            f"cap={fused_meta['tiles']['survivor_cap']};"
            f"retraces={m['retraces_after_warmup']}"
        )
        rows.append((f"serve_fused/{m['name']}", us, derived))
    meta = payload["meta"]
    rows.append((
        "serve/warmup",
        meta["warmup_s"] * 1e6,
        f"executables={meta['executables']};mode={meta['engine']['mode']}",
    ))
    return rows + _async_rows(payload) + _overload_rows(payload)


def run_async(*, toy: bool = False) -> list[Row]:
    """The ``serve_async`` suite entry: same collection (one build, one
    artifact — the async sections are measured on the same warmed engine),
    async/autoscale/pool rows only."""
    return _async_rows(collect(toy=toy))


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
