"""Figure 8: query-time/recall trade-off across alpha and beta."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import Row, dataset, timeit
from repro.core import SuCoConfig, build_index, suco_query
from repro.data import recall


def run() -> list[Row]:
    rows: list[Row] = []
    ds = dataset("gaussian_mixture", n=20_000)
    x, q = jnp.asarray(ds.x), jnp.asarray(ds.queries)
    idx = build_index(x, SuCoConfig(n_subspaces=8, sqrt_k=32, kmeans_iters=5))

    for alpha in (0.01, 0.05, 0.1, 0.2):
        us = timeit(
            lambda: suco_query(x, idx, q, k=10, alpha=alpha, beta=0.01)
            .ids.block_until_ready(), repeats=2,
        )
        res = suco_query(x, idx, q, k=10, alpha=alpha, beta=0.01)
        rows.append((f"fig8/alpha={alpha}", us,
                     f"recall={recall(np.asarray(res.ids), ds.gt_ids):.4f}"))

    for beta in (0.001, 0.003, 0.005, 0.009):
        us = timeit(
            lambda: suco_query(x, idx, q, k=10, alpha=0.05, beta=beta)
            .ids.block_until_ready(), repeats=2,
        )
        res = suco_query(x, idx, q, k=10, alpha=0.05, beta=beta)
        rows.append((f"fig8/beta={beta}", us,
                     f"recall={recall(np.asarray(res.ids), ds.gt_ids):.4f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
