"""Shared benchmark utilities: dataset cache, timers, CSV row type.

All benchmarks run CPU-scale replicas of the paper's experiments (n ~ 5e4
vs the paper's 1e7-1e8; k=10 vs 50) — the *relative* orderings they test
are scale-stable, and the absolute numbers are reported as derived columns.
"""

from __future__ import annotations

import functools
import time
from typing import Callable


from repro.data import Dataset, exact_knn, make_queries, GENERATORS

Row = tuple[str, float, str]  # (name, us_per_call, derived)

N_DEFAULT = 50_000
D_DEFAULT = 64
M_QUERIES = 30
K_DEFAULT = 10


@functools.lru_cache(maxsize=8)
def dataset(kind: str = "gaussian_mixture", n: int = N_DEFAULT, d: int = D_DEFAULT,
            m: int = M_QUERIES, k: int = K_DEFAULT, seed: int = 0) -> Dataset:
    x = GENERATORS[kind](n, d, seed)
    q = make_queries(x, m, seed + 1)
    ids, dists = exact_knn(x, q, k)
    return Dataset(f"{kind}-{n}x{d}", x, q, ids, dists)


def timeit(fn: Callable, *, repeats: int = 3, number: int = 1) -> float:
    """Best-of wall time in microseconds per call."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(number):
            fn()
        best = min(best, (time.perf_counter() - t0) / number)
    return best * 1e6


def block_until_ready(x):
    import jax

    return jax.block_until_ready(x)
