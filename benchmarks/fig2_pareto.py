"""Figure 2: the Pareto principle of SC-score.

Computes the mean SC-score of the i-th NN over queries and locates the
turning point (where score drops below half of the near-neighbour plateau).
The paper's claim: ~20% of points carry a distinguishable score."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import Row, dataset, timeit
from repro.core import contiguous_spec, collision_count, sc_scores_from_subspaces
from repro.core import subspace as sub


def run() -> list[Row]:
    ds = dataset("gaussian_mixture", n=20_000)
    n, d = ds.x.shape
    spec = contiguous_spec(d, 8)
    alpha = 0.1
    c = collision_count(n, alpha)
    xs = sub.split_padded(spec, sub.permute(spec, jnp.asarray(ds.x)))
    qs = sub.split_padded(spec, sub.permute(spec, jnp.asarray(ds.queries)))

    us = timeit(lambda: sc_scores_from_subspaces(xs, qs, c).block_until_ready(),
                repeats=1)
    scores = np.asarray(sc_scores_from_subspaces(xs, qs, c))  # (m, n)

    # order scores by true distance rank per query
    d2 = (
        (ds.queries**2).sum(1)[:, None]
        + (ds.x**2).sum(1)[None, :]
        - 2 * ds.queries @ ds.x.T
    )
    order = np.argsort(d2, axis=1, kind="stable")
    by_rank = np.take_along_axis(scores, order, axis=1).mean(0)  # (n,)

    plateau = by_rank[: max(10, n // 1000)].mean()
    below = np.nonzero(by_rank < plateau / 2)[0]
    turning = float(below[0] / n) if below.size else 1.0
    rows = [
        ("fig2_pareto/scoring", us, f"turning_point_frac={turning:.3f}"),
        ("fig2_pareto/plateau_score", 0.0, f"{plateau:.2f}_of_{spec.n_subspaces}"),
        ("fig2_pareto/tail_score", 0.0, f"{by_rank[int(n*0.5)]:.2f}"),
    ]
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
