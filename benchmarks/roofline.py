"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Terms per (arch x shape x mesh) cell, all PER DEVICE per step:

  compute    = FLOPs / peak_FLOPs           (197 TFLOP/s bf16, TPU v5e)
  memory     = HBM bytes / HBM bw           (819 GB/s)
  collective = collective bytes / link bw   (50 GB/s/link, 1 link assumed)

FLOPs / bytes come from the *loop-corrected* HLO analysis
(repro.launch.hlo_analysis): XLA:CPU's cost_analysis counts while bodies
once, so the raw numbers are also recorded but not used for the terms.

MODEL_FLOPS = 6 * N(_active) * tokens for train (fwd+bwd), 2 * N * tokens
for inference — the useful-FLOPs yardstick for the compute term.
"""

from __future__ import annotations

import functools
import json
import math
from pathlib import Path

from repro.configs import get_config
from repro.models.model import SHAPES


@functools.lru_cache(maxsize=32)
def exact_param_count(arch: str) -> int:
    import jax
    from repro.models import Model

    shapes = Model(get_config(arch)).param_shapes()
    return int(sum(math.prod(x.shape) for x in jax.tree.leaves(shapes)))


def effective_chips(arch: str, shape_name: str, n_chips: int) -> int:
    """Chips that actually hold work.  Decode with global_batch < the number
    of data shards leaves data ranks replicated: only (tp x batch) chips are
    busy (long_500k: 16 of 256)."""
    shape = SHAPES[shape_name]
    if shape.kind != "decode":
        return n_chips
    tp = 16
    dp = n_chips // tp
    return tp * min(shape.global_batch, dp)

RESULTS = Path(__file__).resolve().parent / "results"

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
LINK_BW = 50e9  # B/s per ICI link

__all__ = ["roofline_row", "load_cells", "summary_table", "main"]


def model_flops_per_chip(arch: str, shape_name: str, n_chips: int,
                         param_count: int | None = None) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = param_count if param_count and param_count > 0 else exact_param_count(arch)
    n_active = n
    if cfg.family == "moe":
        # scale exact count by the active/total ratio of the analytic count
        n_active = n * cfg.active_param_count() / cfg.param_count()
    chips = effective_chips(arch, shape_name, n_chips)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / chips
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch / chips


def param_traffic_per_chip(arch: str, shape_name: str, n_chips: int) -> float:
    """Analytic HBM parameter traffic per step per chip (bytes).

    The HLO memory model counts loop-body *outputs* only, so weight reads
    (operands inside the layer loop) are added back here:
      serve: params cast-read once           -> 4 B/param (fp32 master)
      train: fwd read + bwd read + param write + AdamW mu/nu read+write
             -> 4 * (1+1+1+4) = 28 B/param   (fp32 everywhere)
    Sharded over all chips (ZeRO-3 + TP shard every big tensor)."""
    shape = SHAPES[shape_name]
    n = exact_param_count(arch)
    per = 28.0 if shape.kind == "train" else 4.0
    return n * per / n_chips


def roofline_row(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    lc = rec.get("loop_corrected", {})
    if "flops" not in lc:
        return None
    t_comp = lc["flops"] / PEAK_FLOPS
    try:  # non-registry cells (the SuCo engine) have no params / 6ND model
        p_traffic = param_traffic_per_chip(rec["arch"], rec["shape"], rec["n_chips"])
        mf = model_flops_per_chip(
            rec["arch"], rec["shape"], rec["n_chips"], rec.get("param_count")
        )
    except KeyError:
        p_traffic = 0.0
        mf = float("nan")
    mem_bytes = lc["memory_bytes"] + p_traffic
    t_mem = mem_bytes / HBM_BW
    t_coll = lc["collective_bytes"] / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    step_time = max(terms.values())  # perfectly-overlapped lower bound
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": "2x16x16" if rec["multi_pod"] else "16x16",
        "n_chips": rec["n_chips"],
        "compute_s": t_comp,
        "memory_s": t_mem,
        "collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops": lc["flops"],
        "useful_ratio": mf / lc["flops"] if lc["flops"] else float("nan"),
        "mfu_bound": mf / PEAK_FLOPS / step_time if step_time else float("nan"),
        "collective_per_kind": lc.get("per_kind_bytes", {}),
    }


def load_cells(tag: str = "") -> list[dict]:
    rows = []
    for f in sorted(RESULTS.glob(f"*{tag}.json")):
        rec = json.loads(f.read_text())
        rows.append(rec)
    return rows


def summary_table(multi_pod: bool | None = False, tag: str = "") -> str:
    lines = [
        f"{'arch':24s} {'shape':12s} {'mesh':8s} {'compute':>10s} {'memory':>10s} "
        f"{'collect':>10s} {'dominant':>10s} {'useful':>7s} {'MFU<=':>6s}"
    ]
    for rec in load_cells(tag):
        if multi_pod is not None and rec.get("multi_pod") != multi_pod:
            continue
        if rec.get("status") == "skipped":
            lines.append(
                f"{rec['arch']:24s} {rec['shape']:12s} {'-':8s} {'skipped':>10s}"
            )
            continue
        row = roofline_row(rec)
        if row is None:
            lines.append(
                f"{rec['arch']:24s} {rec['shape']:12s} {'-':8s} {rec.get('status'):>10s}"
            )
            continue
        lines.append(
            f"{row['arch']:24s} {row['shape']:12s} {row['mesh']:8s} "
            f"{row['compute_s']*1e3:9.2f}m {row['memory_s']*1e3:9.2f}m "
            f"{row['collective_s']*1e3:9.2f}m {row['dominant']:>10s} "
            f"{row['useful_ratio']:7.2f} {row['mfu_bound']:6.2f}"
        )
    return "\n".join(lines)


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all-meshes", action="store_true")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    if args.json:
        rows = [r for r in (roofline_row(rec) for rec in load_cells()) if r]
        print(json.dumps(rows, indent=2))
        return
    mp = None if args.all_meshes else args.multi_pod
    print(summary_table(multi_pod=mp))


if __name__ == "__main__":
    main()
