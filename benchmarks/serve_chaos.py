"""Chaos smoke suite: the resilient serving stack under deterministic
fault injection.

Run via ``python -m benchmarks.run --suite serve_chaos --toy`` — the CI
lane that keeps the PR-7 resilience surface honest without the full
``serve`` collection.  Two sections, written to
``BENCH_serve_chaos.json`` (``.toy.json`` under ``--toy``):

* ``degrade_recover`` — a forced walk down and back up the
  :class:`~repro.serve.ann.DegradationLadder` (levels 0 -> 1 -> 2 -> 1
  -> 0), a burst of real queries served at every stop.  Asserts
  ``retraces_after_warmup == 0`` across the whole excursion — degraded
  levels must hit their pre-warmed executables, never compile on the
  hot path — and records the monotone per-level Theorem-2
  ``quality_bound`` each answer carried.
* ``overload`` — the same flood replay the ``serve`` suite tracks
  (:func:`benchmarks.serve._run_overload`): bounded admission + overload
  controller vs an uncontrolled server on one seeded arrival trace and
  chaos schedule.  Asserts the controlled arm wins on deadline hit rate
  with zero retraces.

Everything time-like in the ``overload`` section runs on a
:class:`~repro.serve.chaos.VirtualClock`, so its numbers are
deterministic in the seeds; the ``degrade_recover`` burst latencies are
real wall time on the host.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np
import jax

from benchmarks.common import Row
from benchmarks.serve import FULL, TOY, _run_overload
from repro.core import EnginePolicy, SuCoConfig, SuCoEngine
from repro.data import GENERATORS
from repro.serve.ann import AnnRequest, AnnServer, DegradationLadder

OUT_PATH = Path("BENCH_serve_chaos.json")
TOY_OUT_PATH = Path("BENCH_serve_chaos.toy.json")

# The forced excursion: serve a burst at each level, then recover.
LEVEL_WALK = (0, 1, 2, 1, 0)


def _run_degrade_recover(engine: SuCoEngine, scale: dict) -> dict:
    ladder = DegradationLadder(engine, levels=2)
    t0 = time.perf_counter()
    warm = ladder.warmup(
        batch_sizes=range(1, scale["max_batch"] + 1), ks=(10,)
    )
    warmup_s = time.perf_counter() - t0
    server = AnnServer(engine, max_batch=scale["max_batch"], ladder=ladder)
    exe0 = server.executables
    x = np.asarray(engine.x)
    rng = np.random.default_rng(0)
    rid = 0
    phases = []
    for level in LEVEL_WALK:
        server.level = level  # no controller installed: the level is pinned
        n_before = len(server.completed)
        for _ in range(scale["max_batch"]):
            q = x[rng.integers(0, x.shape[0])] + rng.normal(
                scale=0.01, size=x.shape[1]
            ).astype(np.float32)
            server.submit(AnnRequest(rid, q, k=10))
            rid += 1
        t0 = time.perf_counter()
        done = server.run_until_drained()[n_before:]
        burst_s = time.perf_counter() - t0
        assert done and all(r.error is None for r in done), "burst failed"
        phases.append(dict(
            level=level,
            n_requests=len(done),
            n_degraded=sum(1 for r in done if r.degrade_level > 0),
            quality_bound=min(r.quality_bound for r in done),
            burst_s=round(burst_s, 4),
        ))
    retraces = server.executables - exe0
    assert retraces == 0, (
        f"degrade/recover cycle retraced {retraces}x after warmup — a "
        "ladder level compiled on the hot path"
    )
    # Symmetric walk => symmetric bounds, non-increasing toward the deepest
    # level (the ladder monotonises them; recovery restores the base bound).
    bounds = [p["quality_bound"] for p in phases]
    assert bounds == [bounds[0], bounds[1], bounds[2], bounds[1], bounds[0]], (
        f"recovery did not restore per-level bounds: {bounds}"
    )
    assert bounds[0] >= bounds[1] >= bounds[2], (
        f"bounds not monotone down the ladder: {bounds}"
    )
    return dict(
        level_walk=list(LEVEL_WALK),
        warm_compiles=warm,
        warmup_s=round(warmup_s, 3),
        executables=server.executables,
        retraces_after_warmup=retraces,
        phases=phases,
    )


def collect(*, toy: bool = False, out_path: Path | None = None) -> dict:
    scale = TOY if toy else FULL
    if out_path is None:
        out_path = TOY_OUT_PATH if toy else OUT_PATH
    x = np.asarray(
        GENERATORS["gaussian_mixture"](scale["n"], scale["d"], 0)
    ).astype(np.float32)
    config = SuCoConfig(
        n_subspaces=scale["n_subspaces"], sqrt_k=scale["sqrt_k"],
        kmeans_iters=scale["kmeans_iters"], seed=0,
    )
    t0 = time.perf_counter()
    engine = SuCoEngine.build(
        x, config, policy=EnginePolicy(alpha=0.05, beta=0.01, mode="streaming")
    )
    build_s = time.perf_counter() - t0
    engine.warmup(batch_sizes=range(1, scale["max_batch"] + 1), ks=(10,))
    payload = dict(
        meta=dict(
            schema="suco-serve-chaos-v1",
            backend=jax.default_backend(),
            toy=toy,
            n=scale["n"],
            d=scale["d"],
            build_s=round(build_s, 3),
        ),
        degrade_recover=_run_degrade_recover(engine, scale),
        overload=_run_overload(engine, scale),
    )
    out_path.write_text(json.dumps(payload, indent=2) + "\n")
    return payload


def run(*, toy: bool = False) -> list[Row]:
    from benchmarks.serve import _overload_rows

    payload = collect(toy=toy)
    dr = payload["degrade_recover"]
    rows: list[Row] = [(
        "serve_chaos/degrade_recover",
        dr["warmup_s"] * 1e6,
        "levels=" + "/".join(map(str, dr["level_walk"])) + ";"
        + "qbounds=" + "/".join(
            f"{p['quality_bound']:.3f}" for p in dr["phases"]
        ) + f";retraces={dr['retraces_after_warmup']}",
    )]
    return rows + _overload_rows(payload)


if __name__ == "__main__":
    for r in run(toy=True):
        print(",".join(map(str, r)))
