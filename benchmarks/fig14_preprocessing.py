"""Figure 14: data preprocessing x subspace collision — the paper's simple
division vs PCA rotation vs LSH (random projection) preprocessing feeding
the same SC pipeline."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import Row, dataset, timeit
from repro.core import contiguous_spec, sc_linear_query
from repro.data import recall


def _pca(x: np.ndarray, q: np.ndarray):
    mu = x.mean(0)
    xc = x - mu
    cov = xc.T @ xc / x.shape[0]
    w, v = np.linalg.eigh(cov)
    rot = v[:, ::-1]  # descending variance
    return (xc @ rot).astype(np.float32), ((q - mu) @ rot).astype(np.float32)


def _lsh_proj(x: np.ndarray, q: np.ndarray, seed=0):
    rng = np.random.default_rng(seed)
    d = x.shape[1]
    p = rng.normal(size=(d, d)).astype(np.float32) / np.sqrt(d)
    return x @ p, q @ p


def run() -> list[Row]:
    rows: list[Row] = []
    ds = dataset("correlated", n=20_000)
    d = ds.x.shape[1]
    spec = contiguous_spec(d, 8)
    variants = {
        "division": (ds.x, ds.queries),
        "pca": _pca(ds.x, ds.queries),
        "lsh": _lsh_proj(ds.x, ds.queries),
    }
    for name, (xv, qv) in variants.items():
        x, q = jnp.asarray(xv), jnp.asarray(qv)
        us = timeit(
            lambda: sc_linear_query(x, q, spec=spec, k=10, alpha=0.05, beta=0.01)
            .ids.block_until_ready(), repeats=1,
        )
        res = sc_linear_query(x, q, spec=spec, k=10, alpha=0.05, beta=0.01)
        rows.append((f"fig14/sc-{name}", us,
                     f"recall={recall(np.asarray(res.ids), ds.gt_ids):.4f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
