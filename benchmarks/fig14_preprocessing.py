"""Figure 14: data preprocessing x subspace collision — the paper's simple
division vs PCA rotation vs LSH (random projection) preprocessing feeding
the same SC pipeline.

Since PR 2 the dominant preprocessing cost is index construction itself,
so this figure also times ``build_index`` under each build mode (dense /
chunked / minibatch) on the same dataset — the paper's "indexing is 1-2
orders of magnitude faster" claim lives or dies here."""

from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import Row, dataset, timeit
from repro.core import SuCoConfig, build_index, contiguous_spec, sc_linear_query, suco_query
from repro.data import recall


def _pca(x: np.ndarray, q: np.ndarray):
    mu = x.mean(0)
    xc = x - mu
    cov = xc.T @ xc / x.shape[0]
    w, v = np.linalg.eigh(cov)
    rot = v[:, ::-1]  # descending variance
    return (xc @ rot).astype(np.float32), ((q - mu) @ rot).astype(np.float32)


def _lsh_proj(x: np.ndarray, q: np.ndarray, seed=0):
    rng = np.random.default_rng(seed)
    d = x.shape[1]
    p = rng.normal(size=(d, d)).astype(np.float32) / np.sqrt(d)
    return x @ p, q @ p


def run() -> list[Row]:
    rows: list[Row] = []
    ds = dataset("correlated", n=20_000)
    d = ds.x.shape[1]
    spec = contiguous_spec(d, 8)
    variants = {
        "division": (ds.x, ds.queries),
        "pca": _pca(ds.x, ds.queries),
        "lsh": _lsh_proj(ds.x, ds.queries),
    }
    for name, (xv, qv) in variants.items():
        x, q = jnp.asarray(xv), jnp.asarray(qv)
        us = timeit(
            lambda: sc_linear_query(x, q, spec=spec, k=10, alpha=0.05, beta=0.01)
            .ids.block_until_ready(), repeats=1,
        )
        res = sc_linear_query(x, q, spec=spec, k=10, alpha=0.05, beta=0.01)
        rows.append((f"fig14/sc-{name}", us,
                     f"recall={recall(np.asarray(res.ids), ds.gt_ids):.4f}"))

    # index construction under each build memory model (division variant)
    x, q = jnp.asarray(ds.x), jnp.asarray(ds.queries)
    base = SuCoConfig(n_subspaces=8, sqrt_k=24, kmeans_iters=8, block_n=2048)
    for mode in ("dense", "chunked", "minibatch"):
        cfg = dataclasses.replace(base, build_mode=mode)
        idx = build_index(x, cfg)  # warm-up compile; reused for the query below
        jax.block_until_ready(idx.cell_ids)
        us = timeit(
            lambda: jax.block_until_ready(build_index(x, cfg).cell_ids), repeats=1
        )
        res = suco_query(x, idx, q, k=10, alpha=0.05, beta=0.02)
        rows.append((f"fig14/build-{mode}", us,
                     f"recall={recall(np.asarray(res.ids), ds.gt_ids):.4f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
