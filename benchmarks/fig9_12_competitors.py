"""Figures 9-12: SuCo vs competitor families — indexing time, index memory,
recall/QPS.  Guarantee family: SuCo, SC-Linear, E2LSH.  No-guarantee
family: IVF-Flat, IMI+Multi-sequence (OPQ-lite), HNSW-lite, RP-forest."""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import Row, dataset, timeit
from repro.baselines import E2LSH, HNSWLite, IMIPQ, IVFFlat, RPForest
from repro.core import SuCoConfig, build_index, suco_query
from repro.data import recall


def run() -> list[Row]:
    rows: list[Row] = []
    ds = dataset("gaussian_mixture", n=20_000)
    x, q = jnp.asarray(ds.x), jnp.asarray(ds.queries)
    m = ds.queries.shape[0]

    # --- SuCo
    t0 = time.perf_counter()
    idx = build_index(x, SuCoConfig(n_subspaces=8, sqrt_k=32, kmeans_iters=5))
    jax.block_until_ready(idx.cell_ids)
    t_build = (time.perf_counter() - t0) * 1e6
    # streaming engine (n=20k is below the mode="auto" cutover, so ask for it)
    us = timeit(lambda: suco_query(x, idx, q, k=10, alpha=0.05, beta=0.02,
                                   mode="streaming")
                .ids.block_until_ready(), repeats=2)
    res = suco_query(x, idx, q, k=10, alpha=0.05, beta=0.02, mode="streaming")
    rows.append(("fig9_12/suco", us / m,
                 f"recall={recall(np.asarray(res.ids), ds.gt_ids):.4f};"
                 f"index_us={t_build:.0f};mem={idx.memory_bytes()};qps={1e6*m/us:.0f}"))

    # --- competitors (numpy)
    def bench(name, builder, query_kwargs):
        t0 = time.perf_counter()
        b = builder().build(ds.x)
        t_build = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        ids = b.query(ds.queries, 10, **query_kwargs)
        t_q = (time.perf_counter() - t0) * 1e6
        rows.append((f"fig9_12/{name}", t_q / m,
                     f"recall={recall(ids, ds.gt_ids):.4f};index_us={t_build:.0f};"
                     f"mem={b.memory_bytes()};qps={1e6*m/t_q:.0f}"))

    bench("lsh", lambda: E2LSH(n_tables=8, n_bits=10), dict(threshold=1))
    bench("ivf", lambda: IVFFlat(n_cells=128, iters=5), dict(nprobe=8))
    bench("imi_pq", lambda: IMIPQ(sqrt_k=32, iters=5), dict(n_candidates=400))
    bench("hnsw", lambda: HNSWLite(m=12, ef_construction=48), dict(ef_search=64))
    bench("rpforest", lambda: RPForest(n_trees=10, leaf_size=64), dict())
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
