"""Table 2: SC-Linear recall at alpha=0.05 across re-rank ratios beta.

Paper (n=1e7, k=50): recall 0.95-1.0 rising with beta.  CPU replica:
n=5e4, k=10 — the rising-with-beta shape and the >0.9 plateau are the
claims under test."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import Row, dataset, timeit
from repro.core import contiguous_spec, sc_linear_query
from repro.data import recall


def run() -> list[Row]:
    rows: list[Row] = []
    for kind in ("gaussian_mixture", "correlated"):
        ds = dataset(kind)
        n, d = ds.x.shape
        spec = contiguous_spec(d, 8)
        x, q = jnp.asarray(ds.x), jnp.asarray(ds.queries)
        for beta in (0.001, 0.005, 0.01, 0.05):
            fn = lambda: sc_linear_query(
                x, q, spec=spec, k=10, alpha=0.05, beta=beta
            ).ids.block_until_ready()
            us = timeit(fn, repeats=1)
            res = sc_linear_query(x, q, spec=spec, k=10, alpha=0.05, beta=beta)
            r = recall(np.asarray(res.ids), ds.gt_ids)
            rows.append(
                (f"table2/{kind}/beta={beta}", us, f"recall={r:.4f}")
            )
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
