"""Index-build perf suite: wall time + peak-intermediate size per build mode.

Run via ``python -m benchmarks.run --suite index_build`` — emits
``BENCH_index_build.json`` so the index-construction perf trajectory
(dense vs chunked vs minibatch, n from 1e4 to 1e6) is tracked from PR 2 on.

Two measurements per (n, mode):

* ``build_s``       — wall-clock of ``build_index`` (compile excluded by a
  warm-up at the smallest n; at the largest sizes the dense mode is
  *estimated only* — actually materialising its ``(2Ns, n, sqrtK)``
  one-hot would defeat the point of the suite).
* ``peak_intermediate_elems`` — the largest intermediate array (in
  elements) anywhere in the build's jaxpr: a deterministic, device-free
  stand-in for peak build memory that does not require running anything.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from benchmarks.common import Row
from repro.core import SuCoConfig, build_index
from repro.data import GENERATORS
from repro.launch.hlo_analysis import jaxpr_peak_intermediate

SIZES = (10_000, 100_000, 1_000_000)
MODES = ("dense", "chunked", "minibatch")
# dense above this n is jaxpr-estimated, not executed (its (2Ns, n, sqrtK)
# one-hot would need tens of GB at 1e6 points).
DENSE_RUN_MAX_N = 100_000
OUT_PATH = Path("BENCH_index_build.json")

D = 32
_CFG = dict(n_subspaces=8, sqrt_k=32, kmeans_iters=3, seed=0, block_n=8192)


def _config(mode: str) -> SuCoConfig:
    return SuCoConfig(build_mode=mode, **_CFG)


def _measure(x: jnp.ndarray, mode: str, *, run: bool) -> dict:
    n = x.shape[0]
    cfg = _config(mode)
    peak = jaxpr_peak_intermediate(
        jax.make_jaxpr(lambda xx: build_index(xx, cfg).cell_ids)(x)
    )
    rec = dict(n=n, mode=mode, peak_intermediate_elems=peak, built=bool(run))
    if run:
        jax.block_until_ready(build_index(x, cfg).cell_ids)  # compile
        t0 = time.perf_counter()
        jax.block_until_ready(build_index(x, cfg).cell_ids)
        rec["build_s"] = time.perf_counter() - t0
    return rec


def collect(sizes=SIZES, out_path: Path = OUT_PATH) -> dict:
    if tuple(sizes) != SIZES and out_path == OUT_PATH:
        # partial/dev runs must not clobber the CI-tracked trajectory artifact
        out_path = OUT_PATH.with_suffix(".partial.json")
    results = []
    for n in sizes:
        x = jnp.asarray(GENERATORS["gaussian_mixture"](n, D, 0))
        for mode in MODES:
            run = mode != "dense" or n <= DENSE_RUN_MAX_N
            results.append(_measure(x, mode, run=run))
    from repro.core import EnginePolicy

    payload = dict(
        meta=dict(
            d=D,
            config={k: v for k, v in _CFG.items()},
            backend=jax.default_backend(),
            dense_run_max_n=DENSE_RUN_MAX_N,
            # serving-side pool-merge impl in effect when this trajectory
            # point was recorded (the serve artifact carries the same field)
            merge_impl=EnginePolicy().merge_impl,
            schema="suco-index-build-v1",
        ),
        results=results,
    )
    out_path.write_text(json.dumps(payload, indent=2) + "\n")
    return payload


def run(sizes=SIZES) -> list[Row]:
    payload = collect(sizes)
    rows: list[Row] = []
    by_key = {(r["n"], r["mode"]): r for r in payload["results"]}
    for rec in payload["results"]:
        dense = by_key[(rec["n"], "dense")]
        mem_ratio = dense["peak_intermediate_elems"] / max(
            rec["peak_intermediate_elems"], 1
        )
        us = rec.get("build_s", float("nan")) * 1e6
        derived = (
            f"peak_elems={rec['peak_intermediate_elems']};"
            f"mem_vs_dense={mem_ratio:.1f}x;built={rec['built']}"
        )
        if rec["built"] and dense.get("build_s"):
            derived += f";speed_vs_dense={dense['build_s'] / rec['build_s']:.2f}x"
        rows.append((f"index_build/n{rec['n']}/{rec['mode']}", us, derived))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
