"""Unit + property tests for the SC framework core (subspace, collision,
SC-Linear, SuCo)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # network-less env: vendored deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (
    SuCoConfig,
    build_index,
    collision_count,
    contiguous_spec,
    sampled_spec,
    sc_linear_query,
    sc_scores_from_subspaces,
    suco_query,
)
from repro.core import subspace as sub
from repro.core.collision import kth_smallest, sc_scores
from repro.data import make_dataset, recall, mean_relative_error


# ------------------------------ subspace -----------------------------------


@settings(max_examples=40, deadline=None)
@given(
    d=st.integers(4, 100),
    ns=st.integers(1, 12),
    seed=st.integers(0, 1000),
)
def test_subspace_spec_partitions_all_dims(d, ns, seed):
    if d // ns < 1:
        ns = max(1, d // 2)
    spec = sampled_spec(d, ns, seed)
    assert sum(spec.sizes) == d
    assert sorted(spec.perm) == list(range(d))
    # Definition 3: first Ns-1 subspaces have floor(d/Ns) dims
    s = d // ns
    assert all(sz == s for sz in spec.sizes[:-1])
    assert spec.sizes[-1] == d - s * (ns - 1)


def test_split_padded_preserves_distances():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(7, 13)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(13,)), jnp.float32)
    spec = sampled_spec(13, 4, 3)
    xp, qp = sub.permute(spec, x), sub.permute(spec, q)
    xs = sub.split_padded(spec, xp)
    qs = sub.split_padded(spec, qp)
    # padded per-subspace distances sum to the full distance (zero pad)
    per = jnp.sum((xs - qs[:, None, :]) ** 2, axis=-1)  # (Ns, n)
    full = jnp.sum((x - q[None]) ** 2, axis=-1)
    np.testing.assert_allclose(np.asarray(per.sum(0)), np.asarray(full), rtol=1e-5)


# ------------------------------ collision ----------------------------------


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 200), st.integers(0, 1000))
def test_kth_smallest_matches_numpy(k, seed):
    rng = np.random.default_rng(seed)
    v = rng.normal(size=300).astype(np.float32)
    got = float(kth_smallest(jnp.asarray(v), min(k, 300)))
    want = float(np.sort(v)[min(k, 300) - 1])
    assert got == pytest.approx(want)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 1000), st.floats(0.01, 0.5))
def test_collision_mask_counts_at_least_alpha_n(seed, alpha):
    """Threshold semantics: the collision set contains the alpha*n nearest
    (ties may add more — never fewer)."""
    rng = np.random.default_rng(seed)
    d = rng.normal(size=(4, 500)).astype(np.float32) ** 2
    c = collision_count(500, alpha)
    sc_scores(jnp.asarray(d), c)  # exercised for shape/trace sanity
    # per subspace: at least c collide
    from repro.core.collision import collision_mask

    m = np.asarray(collision_mask(jnp.asarray(d), c))
    assert (m.sum(axis=1) >= c).all()
    # and the c nearest definitely collide
    for i in range(4):
        near = np.argsort(d[i], kind="stable")[:c]
        assert m[i, near].all()


# ------------------------------ SC-Linear ----------------------------------


@pytest.fixture(scope="module")
def clustered():
    return make_dataset("gaussian_mixture", 4000, 48, m=16, k=10, seed=0)


def test_sc_linear_beta_one_is_exact(clustered):
    ds = clustered
    spec = contiguous_spec(48, 8)
    res = sc_linear_query(
        jnp.asarray(ds.x), jnp.asarray(ds.queries), spec=spec, k=10,
        alpha=0.05, beta=1.0,
    )
    assert recall(np.asarray(res.ids), ds.gt_ids) == 1.0
    # distances use the fp32 matmul identity; gt is float64 exact -> ~1e-3
    np.testing.assert_allclose(
        np.asarray(res.dists[:, 0]), ds.gt_dists[:, 0], rtol=2e-2, atol=1e-2
    )


def test_sc_linear_high_recall_on_clustered(clustered):
    ds = clustered
    spec = contiguous_spec(48, 8)
    res = sc_linear_query(
        jnp.asarray(ds.x), jnp.asarray(ds.queries), spec=spec, k=10,
        alpha=0.05, beta=0.05,
    )
    assert recall(np.asarray(res.ids), ds.gt_ids) >= 0.9


def test_sc_linear_l1_metric(clustered):
    ds = clustered
    spec = contiguous_spec(48, 8)
    res = sc_linear_query(
        jnp.asarray(ds.x), jnp.asarray(ds.queries), spec=spec, k=10,
        alpha=0.05, beta=0.05, metric="l1",
    )
    from repro.data import exact_knn

    gt_ids, _ = exact_knn(ds.x, ds.queries, 10, metric="l1")
    assert recall(np.asarray(res.ids), gt_ids) >= 0.85


def test_scores_scanned_matches_direct(clustered):
    ds = clustered
    spec = contiguous_spec(48, 6)
    x = jnp.asarray(ds.x[:500])
    q = jnp.asarray(ds.queries[:4])
    xs = sub.split_padded(spec, sub.permute(spec, x))
    qs = sub.split_padded(spec, sub.permute(spec, q))
    c = collision_count(500, 0.05)
    scanned = sc_scores_from_subspaces(xs, qs, c)
    # direct: per-subspace distances + thresholds
    per = jnp.sum((xs[:, None] - qs[:, :, None]) ** 2, axis=-1)  # (Ns,m,n)
    direct = jax.vmap(lambda dm: sc_scores(dm, c), in_axes=1)(per)
    np.testing.assert_array_equal(np.asarray(scanned), np.asarray(direct))


# -------------------------------- SuCo --------------------------------------


def test_suco_end_to_end_recall(clustered):
    ds = clustered
    cfg = SuCoConfig(n_subspaces=8, sqrt_k=24, kmeans_iters=8, seed=0)
    idx = build_index(jnp.asarray(ds.x), cfg)
    res = suco_query(
        jnp.asarray(ds.x), idx, jnp.asarray(ds.queries), k=10, alpha=0.05, beta=0.02
    )
    r = recall(np.asarray(res.ids), ds.gt_ids)
    assert r >= 0.9, f"SuCo recall {r} too low"
    mre = mean_relative_error(np.asarray(res.dists), ds.gt_dists)
    assert mre < 0.05


def test_suco_deterministic(clustered):
    ds = clustered
    cfg = SuCoConfig(n_subspaces=4, sqrt_k=16, kmeans_iters=4, seed=7)
    i1 = build_index(jnp.asarray(ds.x), cfg)
    i2 = build_index(jnp.asarray(ds.x), cfg)
    np.testing.assert_array_equal(np.asarray(i1.cell_ids), np.asarray(i2.cell_ids))


def test_suco_index_memory_matches_claim(clustered):
    """Paper: index space O(sqrt(K) d + n Ns) — check the dominant n*Ns term."""
    ds = clustered
    cfg = SuCoConfig(n_subspaces=8, sqrt_k=16, kmeans_iters=2)
    idx = build_index(jnp.asarray(ds.x), cfg)
    n, d = ds.x.shape
    expected = 4 * n * cfg.n_subspaces  # int32 cell ids
    assert idx.memory_bytes() < expected * 1.5
    assert idx.memory_bytes() < ds.x.nbytes  # index is lighter than the data
