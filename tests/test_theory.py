"""Theorem 1/2 calculators: the paper's probability guarantees hold in the
admissible parameter ranges, and fail gracefully outside them."""

import math

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # network-less env: vendored deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.theory import (
    subspace_statistics,
    suggest_parameters,
    theorem1_bound,
    theorem2_bound,
    _ndtri,
)


def test_ndtri_matches_known_values():
    assert _ndtri(0.5) == pytest.approx(0.0, abs=1e-9)
    assert _ndtri(0.975) == pytest.approx(1.959964, abs=1e-5)
    assert _ndtri(0.025) == pytest.approx(-1.959964, abs=1e-5)


def test_theorem1_reaches_claimed_bound():
    """For concentrated data (m >> sigma) and admissible alpha the success
    probability must reach the paper's 1/2 - 1/e^2 ~ 0.3647."""
    target = 0.5 - 1.0 / math.e**2
    rep = theorem1_bound(m=10.0, sigma=1.0, n_subspaces=8, alpha=0.95)
    assert rep.success_prob >= target - 1e-9, rep
    assert rep.c1 > 0 and rep.c2 > 0


def test_theorem1_inadmissible_alpha_returns_zero():
    rep = theorem1_bound(m=10.0, sigma=1.0, n_subspaces=8, alpha=1e-4)
    assert rep.success_prob == 0.0
    assert rep.alpha_min > 1e-4


@settings(max_examples=30, deadline=None)
@given(st.floats(2.0, 50.0), st.integers(4, 16))
def test_theorem1_monotone_region(ratio, ns):
    """Higher alpha (within range) never decreases the bound."""
    a_lo = theorem1_bound(ratio, 1.0, ns, 0.7).success_prob
    a_hi = theorem1_bound(ratio, 1.0, ns, 0.95).success_prob
    assert a_hi >= a_lo - 1e-9


def test_theorem2_reaches_half():
    p = theorem2_bound(n=100_000, k=50, n_subspaces=8, m=10.0, sigma=1.0, alpha=0.05)
    assert p >= 0.5


def test_theorem2_vacuous_when_radius_too_small():
    # alpha -> 1 shrinks the collision radius below the k-th order statistic
    p = theorem2_bound(n=1000, k=50, n_subspaces=8, m=10.0, sigma=1.0, alpha=0.999999)
    assert p == 0.0


def test_subspace_statistics_and_suggestion():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(2000, 64)).astype(np.float32)
    q = rng.normal(size=64).astype(np.float32)
    m, s = subspace_statistics(x, q, 8)
    assert m > 0 and s > 0
    sugg = suggest_parameters(n=100_000, d=64, k=50, m=m, sigma=s)
    assert set(sugg) >= {"n_subspaces", "alpha", "beta", "prob"}


# ------------------------- Theorem 2 edge cases ------------------------------


def test_theorem2_k_equals_one():
    """k=1 is the smallest admissible order statistic: the Blom plotting
    position must stay inside (0, 1) (no _ndtri domain error) and the bound
    must remain a strong, valid probability in this generous regime. (It is
    NOT monotone in k — the Chebyshev slack depends on the order-statistic
    variance, so we only pin the regime, not an ordering against k=50.)"""
    p1 = theorem2_bound(n=100_000, k=1, n_subspaces=8, m=10.0, sigma=1.0, alpha=0.05)
    assert 0.0 <= p1 <= 1.0
    assert p1 >= 0.5  # same generous regime as test_theorem2_reaches_half


def test_theorem2_single_subspace():
    """Degenerate partition (n_subspaces=1, i.e. m_sub = d): the collision
    radius collapses but the calculator must not divide by zero or leave
    [0, 1]."""
    p = theorem2_bound(n=10_000, k=10, n_subspaces=1, m=4.0, sigma=1.0, alpha=0.05)
    assert 0.0 <= p <= 1.0
    # fully degenerate: one subspace AND unit mean distance (sigma dominates)
    p = theorem2_bound(n=10_000, k=10, n_subspaces=1, m=1.0, sigma=1.0, alpha=0.05)
    assert 0.0 <= p <= 1.0


def test_theorem2_alpha_monotone_and_alpha_equals_beta_regime():
    """Shrinking alpha widens the collision radius, so the success bound is
    monotone non-increasing in alpha — including the alpha == beta corner
    used by the suggest_parameters defaults."""
    common = dict(n=100_000, k=50, n_subspaces=8, m=10.0, sigma=1.0)
    p_wide = theorem2_bound(alpha=0.02, **common)
    p_eq = theorem2_bound(alpha=0.05, **common)  # alpha == beta default pair
    p_narrow = theorem2_bound(alpha=0.2, **common)
    assert p_wide >= p_eq >= p_narrow
    assert 0.0 <= p_narrow and p_wide <= 1.0


def test_theorem2_always_a_probability():
    """Sweep the admissible corners: whatever the regime (vacuous radius,
    huge k, tiny n), the output is clamped to [0, 1] and never NaN."""
    for n in (100, 10_000, 1_000_000):
        for k in (1, 10, n - 1):
            for alpha in (0.001, 0.05, 0.5, 0.99):
                p = theorem2_bound(
                    n=n, k=k, n_subspaces=4, m=8.0, sigma=2.0, alpha=alpha
                )
                assert 0.0 <= p <= 1.0 and not math.isnan(p), (n, k, alpha)


def test_theorem2_k_near_n_is_vacuous():
    """Asking for essentially all of the dataset pushes the k-th order
    statistic past any collision radius: the bound degrades to 0, it does
    not go negative or raise."""
    p = theorem2_bound(n=1000, k=999, n_subspaces=8, m=10.0, sigma=1.0, alpha=0.05)
    assert p == 0.0


def test_degraded_budget_bound_contract():
    """The degraded-mode floor: a probability, monotone non-increasing as
    beta shrinks at fixed alpha (the pool-spill term grows), vacuous (0.0)
    once the candidate pool cannot hold a top-k answer, and never above
    the plain Theorem-2 bound for the same alpha."""
    from repro.core.theory import degraded_budget_bound

    common = dict(n=48_000, k=10, n_subspaces=8, m=8.0, sigma=2.0)
    alpha = 0.05
    betas = (0.02, 0.01, 0.005, 0.001)
    bounds = [degraded_budget_bound(alpha=alpha, beta=b, **common) for b in betas]
    assert all(0.0 <= b <= 1.0 for b in bounds)
    for hi, lo in zip(bounds, bounds[1:]):
        assert hi >= lo, (bounds, "beta-monotonicity broken")
    base = theorem2_bound(alpha=alpha, **common)
    assert all(b <= base for b in bounds)
    # infeasible pool: int(beta * n) < k  ->  vacuous
    assert degraded_budget_bound(alpha=alpha, beta=10 / (2 * 48_000), **common) == 0.0
    assert degraded_budget_bound(alpha=alpha, beta=0.0, **common) == 0.0
    assert degraded_budget_bound(alpha=alpha, beta=-0.1, **common) == 0.0


def test_estimate_subspace_statistics_deterministic_and_plausible():
    """The sampled (m, sigma) estimator is deterministic in its seed and
    lands near the per-query statistic it averages."""
    from repro.core.theory import estimate_subspace_statistics

    rng = np.random.default_rng(0)
    x = rng.standard_normal((4096, 32)).astype(np.float32)
    a = estimate_subspace_statistics(x, 8, seed=3)
    b = estimate_subspace_statistics(x, 8, seed=3)
    assert a == b
    c = estimate_subspace_statistics(x, 8, seed=4)
    assert a != c  # the seed really drives the sample
    m_ref, s_ref = subspace_statistics(x[:2048], x[7], 8)
    assert 0.5 * m_ref < a[0] < 2.0 * m_ref
    assert 0.25 * s_ref < a[1] < 4.0 * s_ref
