"""Theorem 1/2 calculators: the paper's probability guarantees hold in the
admissible parameter ranges, and fail gracefully outside them."""

import math

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # network-less env: vendored deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.theory import (
    subspace_statistics,
    suggest_parameters,
    theorem1_bound,
    theorem2_bound,
    _ndtri,
)


def test_ndtri_matches_known_values():
    assert _ndtri(0.5) == pytest.approx(0.0, abs=1e-9)
    assert _ndtri(0.975) == pytest.approx(1.959964, abs=1e-5)
    assert _ndtri(0.025) == pytest.approx(-1.959964, abs=1e-5)


def test_theorem1_reaches_claimed_bound():
    """For concentrated data (m >> sigma) and admissible alpha the success
    probability must reach the paper's 1/2 - 1/e^2 ~ 0.3647."""
    target = 0.5 - 1.0 / math.e**2
    rep = theorem1_bound(m=10.0, sigma=1.0, n_subspaces=8, alpha=0.95)
    assert rep.success_prob >= target - 1e-9, rep
    assert rep.c1 > 0 and rep.c2 > 0


def test_theorem1_inadmissible_alpha_returns_zero():
    rep = theorem1_bound(m=10.0, sigma=1.0, n_subspaces=8, alpha=1e-4)
    assert rep.success_prob == 0.0
    assert rep.alpha_min > 1e-4


@settings(max_examples=30, deadline=None)
@given(st.floats(2.0, 50.0), st.integers(4, 16))
def test_theorem1_monotone_region(ratio, ns):
    """Higher alpha (within range) never decreases the bound."""
    a_lo = theorem1_bound(ratio, 1.0, ns, 0.7).success_prob
    a_hi = theorem1_bound(ratio, 1.0, ns, 0.95).success_prob
    assert a_hi >= a_lo - 1e-9


def test_theorem2_reaches_half():
    p = theorem2_bound(n=100_000, k=50, n_subspaces=8, m=10.0, sigma=1.0, alpha=0.05)
    assert p >= 0.5


def test_theorem2_vacuous_when_radius_too_small():
    # alpha -> 1 shrinks the collision radius below the k-th order statistic
    p = theorem2_bound(n=1000, k=50, n_subspaces=8, m=10.0, sigma=1.0, alpha=0.999999)
    assert p == 0.0


def test_subspace_statistics_and_suggestion():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(2000, 64)).astype(np.float32)
    q = rng.normal(size=64).astype(np.float32)
    m, s = subspace_statistics(x, q, 8)
    assert m > 0 and s > 0
    sugg = suggest_parameters(n=100_000, d=64, k=50, m=m, sigma=s)
    assert set(sugg) >= {"n_subspaces", "alpha", "beta", "prob"}
