"""Mutate-while-serving: the ISSUE-9 acceptance scenario on the virtual
clock.  A scripted insert / delete / re-cluster+swap sequence is
interleaved with a seeded query flood through the chaos replay's callable
events; the replay must complete every request (none dropped, none
failed), never serve a tombstoned id from a batch dispatched after its
delete, keep recall above the Theorem-2 floor for the live corpus, and
hold ``retraces_after_warmup == 0`` on both engines across the handoff.
Plus the satellite: ladder quality bounds recompute from the live count."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import theory
from repro.core.suco import EnginePolicy, SuCoConfig, SuCoEngine, build_index
from repro.data import make_dataset
from repro.serve.ann import AnnRequest, AnnServer, AsyncAnnServer, DegradationLadder
from repro.serve.chaos import VirtualClock, flood_trace, replay
from repro.serve.mutation import (
    DriftMonitor,
    MutationManager,
    ReindexInProgressError,
    warm_like,
)

N, D, K = 2000, 16, 10
CFG = SuCoConfig(n_subspaces=4, sqrt_k=8, kmeans_iters=3, seed=0)
POLICY = dict(alpha=0.1, beta=0.05, mode="dense", batch_buckets=(4, 16))


@pytest.fixture(scope="module")
def ds():
    return make_dataset("gaussian_mixture", N, D, m=20, k=K, seed=0)


@pytest.fixture(scope="module")
def index(ds):
    return build_index(jnp.asarray(ds.x), CFG)


def _serving_stack(ds, index, server_cls=AsyncAnnServer, *, levels=1,
                   capacity=N + 300, max_batch=8):
    clock = VirtualClock()
    engine = SuCoEngine(
        jnp.asarray(ds.x), index, EnginePolicy(**POLICY), capacity=capacity
    )
    ladder = DegradationLadder(engine, levels=levels)
    server = server_cls(
        engine, max_batch=max_batch, clock=clock, sleep=clock.advance,
        ladder=ladder,
    )
    ladder.warmup(batch_sizes=range(1, max_batch + 1), ks=(K,))
    return clock, engine, ladder, server


def test_mutate_while_serving_chaos(ds, index):
    clock, engine, ladder, server = _serving_stack(ds, index)
    mgr = MutationManager(server, CFG, capacity_factor=1.2)
    exe_warm = server.executables

    rng = np.random.default_rng(11)
    new_rows = (ds.x[:80] + 0.1 * rng.standard_normal((80, D))).astype(np.float32)
    dead_keys = np.arange(100, 250)
    snap: dict = {}

    def ev_insert(_server):
        snap["inserted_keys"] = mgr.insert(new_rows)

    def ev_delete(_server):
        snap["t_delete"] = clock()
        snap["n_deleted"] = mgr.delete(dead_keys)

    def ev_reindex(_server):
        snap["exe_pre_reindex"] = server.executables
        mgr.reindex()
        snap["t_reindex"] = clock()
        snap["exe_post_swap"] = server.executables

    trace = flood_trace(
        60, D, interarrival_s=0.001, deadline_s=None, ks=(K,),
        seed=3, queries=ds.x,
    )
    trace += [(0.0155, ev_insert), (0.0305, ev_delete), (0.0455, ev_reindex)]
    trace.sort(key=lambda tr: tr[0])
    report = replay(server, trace, clock)

    # -- no request dropped, failed, shed, or expired -----------------------
    assert report.completed == frozenset(range(60))
    assert report.shed == report.expired == report.failed == frozenset()
    assert snap["n_deleted"] == len(dead_keys)
    assert mgr.reindexes == 1

    # -- zero retrace on both engines across the handoff --------------------
    # old surface: flat from warmup until the re-index
    assert snap["exe_pre_reindex"] == exe_warm
    # successor: warmed inside reindex() BEFORE the swap; flat afterwards
    assert server.executables == snap["exe_post_swap"]

    # -- no tombstoned id in any answer dispatched after its delete ---------
    reqs = {r.rid: r for _, r in trace if not callable(r)}
    t_delete, t_reindex = snap["t_delete"], snap["t_reindex"]
    dead = set(dead_keys.tolist())
    gen0_after_delete = [
        r for r in reqs.values() if t_delete <= r.t_start < t_reindex
    ]
    gen1 = [r for r in reqs.values() if r.t_start >= t_reindex]
    assert gen0_after_delete and gen1  # the schedule actually covers both
    for r in gen0_after_delete:
        # generation 0: slot ids ARE external keys (keys start as arange)
        assert not dead & set(map(int, r.ids)), f"rid {r.rid} leaked a tombstone"
    for r in gen1:
        keys = mgr.keys_of(np.asarray(r.ids))
        assert not dead & set(map(int, keys)), f"rid {r.rid} leaked post-swap"

    # -- recall above the Theorem-2 floor for the live corpus ---------------
    # brute force over the final live corpus, in external-key space
    live_keys = mgr.live_keys()
    key_to_slot = {int(k): s for s, k in enumerate(mgr._keys)}
    x_all = np.asarray(server.engine.x)
    live_slots = np.asarray([key_to_slot[int(k)] for k in live_keys])
    x_live = x_all[live_slots]
    rows = []
    for r in gen1:
        q = np.asarray(r.query)
        d2 = ((x_live - q[None]) ** 2).sum(axis=1)
        order = np.argsort(d2)
        want = set(live_keys[order[:K]].tolist())
        got = set(map(int, mgr.keys_of(np.asarray(r.ids))))
        answered = int(live_keys[order[0]]) in got  # the Theorem-2 event
        rows.append((len(got & want) / K, answered, r.quality_bound))
    assert all(qb is not None for _, _, qb in rows)
    # Theorem 2 lower-bounds the 1-NN success probability; every answer's
    # carried bound (recomputed for the live count) must hold empirically.
    success = float(np.mean([a for _, a, _ in rows]))
    floor = min(qb for _, _, qb in rows)
    assert success >= floor, f"success {success} below reported floor {floor}"
    # recall@k regression guard on top (the clustered-regime expectation)
    recall = float(np.mean([rc for rc, _, _ in rows]))
    assert recall >= 0.9, f"recall@{K} {recall} collapsed post-handoff"


def test_async_reindex_while_serving_chaos(ds, index):
    """ISSUE-10 satellite: the re-cluster prepare runs OFF the serving
    thread.  The replay keeps answering between ``reindex_async()`` and
    ``finish_reindex()``; a scripted insert in that window is rejected by
    the single-flight guard (the gathered corpus must not go stale); the
    commit swaps with zero retraces and post-swap answers come from the
    successor."""
    clock, engine, ladder, server = _serving_stack(ds, index)
    mgr = MutationManager(server, CFG, capacity_factor=1.2)
    exe_warm = server.executables
    snap: dict = {}

    def ev_start(_server):
        snap["exe_pre"] = server.executables
        mgr.reindex_async()

    def ev_insert_rejected(_server):
        with pytest.raises(ReindexInProgressError, match="pending"):
            mgr.insert(ds.x[:2])
        snap["rejected"] = True

    def ev_finish(_server):
        # blocks (real time) until the off-thread prepare lands, then
        # commits the swap on THIS thread — the only thread that mutates
        mgr.finish_reindex(timeout=300)
        snap["t_swap"] = clock()
        snap["exe_post"] = server.executables

    trace = flood_trace(
        60, D, interarrival_s=0.001, deadline_s=None, ks=(K,),
        seed=7, queries=ds.x,
    )
    trace += [
        (0.0155, ev_start),
        (0.0255, ev_insert_rejected),
        (0.0405, ev_finish),
    ]
    trace.sort(key=lambda tr: tr[0])
    report = replay(server, trace, clock)

    # every request completed — serving never paused for the prepare
    assert report.completed == frozenset(range(60))
    assert report.shed == report.expired == report.failed == frozenset()
    assert snap["rejected"]
    assert mgr.reindexes == 1

    # zero retraces: flat until the commit, successor pre-warmed
    assert snap["exe_pre"] == exe_warm
    assert server.executables == snap["exe_post"]

    # post-swap requests answer against the successor corpus
    reqs = {r.rid: r for _, r in trace if not callable(r)}
    gen1 = [r for r in reqs.values() if r.t_start >= snap["t_swap"]]
    assert gen1  # the schedule actually exercises the post-swap window
    for r in gen1:
        assert r.done and r.error is None
        assert len(np.asarray(r.ids)) == K


def test_ladder_quality_bound_tracks_live_count(ds, index):
    clock, engine, ladder, server = _serving_stack(ds, index, levels=1)
    b0 = ladder.quality_bound(0, K)
    rng = np.random.default_rng(5)
    server.insert((ds.x[:120] + 0.05 * rng.standard_normal((120, D))).astype(np.float32))
    server.delete(np.arange(0, 40))
    b1 = ladder.quality_bound(0, K)
    n_live = engine.n_live
    assert n_live == N + 120 - 40
    fresh = theory.degraded_budget_bound(
        n_live, K, index.spec.n_subspaces, ladder.m_stat, ladder.sigma_stat,
        engine.policy.alpha, engine.policy.beta,
    )
    assert b1 == pytest.approx(fresh)
    assert b1 != b0  # the build-time bound would be stale


def test_server_validate_uses_live_count(ds, index):
    clock, engine, ladder, server = _serving_stack(ds, index, capacity=N + 4)
    server.delete(np.arange(N - 8, N))
    req = AnnRequest(0, ds.x[0], k=engine.n_live + 1)
    assert not server.submit(req)
    assert "k=" in req.error and f"n={engine.n_live}" in req.error


def test_server_mutation_rebinds_ladder_siblings(ds, index):
    clock, engine, ladder, server = _serving_stack(ds, index)
    server.delete(np.arange(0, 50))
    for sib in ladder.engines[1:]:
        assert sib.index is engine.index
        assert sib.n_live == engine.n_live
        # degraded answers must exclude tombstones too
        ids = np.asarray(sib.query(ds.x[300], k=K).ids)
        assert (ids >= 50).all()


def test_server_swap_contract(ds, index):
    clock, engine, ladder, server = _serving_stack(ds, index)
    x2 = jnp.asarray(ds.x[:1200])
    idx2 = build_index(x2, CFG)
    succ = SuCoEngine(x2, idx2, EnginePolicy(**POLICY), capacity=1400)
    succ_ladder = DegradationLadder(succ, levels=1)
    # ladder installed but none supplied
    with pytest.raises(ValueError, match="ladder"):
        server.swap(succ)
    # supplied but cold
    engine.query(ds.x[0], k=K)  # ensure the old surface has seen traffic
    with pytest.raises(ValueError, match="not warmed"):
        server.swap(succ, ladder=succ_ladder)
    # warm level-for-level, then the handoff succeeds in place
    for old_e, new_e in zip(ladder.engines, succ_ladder.engines):
        warm_like(new_e, old_e)
    server.swap(succ, ladder=succ_ladder)
    assert server.engine is engine  # object identity preserved
    assert engine.n_live == 1200
    ids = np.asarray(engine.query(ds.x[0], k=K).ids)
    assert ids.max() < 1400


def test_sync_server_mutation(ds, index):
    # the synchronous server shares the mutation surface
    clock = VirtualClock()
    engine = SuCoEngine(
        jnp.asarray(ds.x), index, EnginePolicy(**POLICY), capacity=N + 50
    )
    server = AnnServer(engine, max_batch=4, clock=clock, sleep=clock.advance)
    engine.warmup(batch_sizes=(1, 4), ks=(K,))
    c0 = server.executables
    server.insert(ds.x[:20])
    server.delete(np.arange(0, 30))
    server.submit_many(
        [AnnRequest(i, ds.x[500 + i], k=K) for i in range(6)]
    )
    done = server.run_until_drained()
    assert all(r.done for r in done)
    assert all((np.asarray(r.ids) >= 30).all() for r in done)
    assert server.executables == c0


def test_drift_monitor_triggers_on_hollowed_occupancy(ds, index):
    clock, engine, ladder, server = _serving_stack(ds, index, capacity=N + 600)
    mgr = MutationManager(
        server, CFG,
        monitor=DriftMonitor(tv_threshold=0.05, max_fill_fraction=0.99),
        capacity_factor=1.5,
    )
    assert not mgr.check().triggered
    # delete a contiguous third of the corpus: whole cells hollow out
    mgr.delete(np.arange(0, 700))
    report = mgr.check()
    assert report.triggered
    assert any("tv" in r or "dead" in r for r in report.reasons)
    mgr.maybe_reindex()
    assert mgr.reindexes == 1
    # post-reindex the baseline re-captured: calm again
    assert not mgr.check().triggered
