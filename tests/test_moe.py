"""MoE dispatch tests: the sort+capacity path must equal a dense
per-expert loop when capacity is unconstrained, and drop tokens
deterministically when it is."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import reduced_config
from repro.models import layers as L


def _dense_reference(p, x, cfg):
    """Slow oracle: every token through its top-k experts, no capacity."""
    dtype = x.dtype
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = (xf @ p["router"]["w"].astype(dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k_experts)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    out = jnp.zeros_like(xf)
    for e in range(cfg.n_experts):
        gate = jax.nn.silu(xf @ p["w_gate"][e].astype(dtype))
        up = xf @ p["w_up"][e].astype(dtype)
        y = (gate * up) @ p["w_down"][e].astype(dtype)
        wsum = jnp.sum(jnp.where(top_e == e, top_p, 0.0), axis=-1)
        out = out + y * wsum[:, None].astype(dtype)
    return out.reshape(b, s, d)


def test_moe_matches_dense_reference_at_high_capacity():
    cfg = dataclasses.replace(
        reduced_config("olmoe-1b-7b"), dtype="float32", capacity_factor=float("inf")
    )
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    key = jax.random.key(0)
    p = L.init_moe(key, cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model), jnp.float32)
    got = L.moe_forward(p, x, cfg)
    want = _dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-3)


def test_moe_capacity_drops_bounded():
    """At capacity factor 1.0 the dispatched token count per expert is
    capped; output stays finite and close-ish to the reference."""
    cfg = dataclasses.replace(
        reduced_config("mixtral-8x7b"), dtype="float32", capacity_factor=1.0
    )
    p = L.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model), jnp.float32)
    got = L.moe_forward(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(got)))
    # dropped tokens produce zero contribution, not NaN
    norms = jnp.linalg.norm(got.reshape(-1, cfg.d_model), axis=-1)
    assert float(norms.min()) >= 0.0


def test_moe_flops_are_capacity_bounded():
    """The dispatch einsums process E*C rows, not E*T rows — no
    dense-all-experts fake FLOPs (checked structurally via capacity)."""
    import math
    cfg = dataclasses.replace(reduced_config("olmoe-1b-7b"), capacity_factor=1.25)
    t = 2 * 64
    cap = int(math.ceil(cfg.top_k_experts * t / cfg.n_experts * cfg.capacity_factor))
    assert cfg.n_experts * cap < 2 * cfg.top_k_experts * t  # ~1.25x active rows
