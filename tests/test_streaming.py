"""Tiled streaming SuCo engine: exact parity with the dense reference path,
tie-break determinism, and the O(m*(block_n + n_candidates)) memory claim."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    STREAMING_MIN_N,
    SuCoConfig,
    build_index,
    merge_topk_pool,
    rerank,
    rerank_candidates,
    suco_query,
    suco_query_streaming,
)
from repro.data import make_dataset


@pytest.fixture(scope="module")
def small():
    ds = make_dataset("gaussian_mixture", 4000, 48, m=16, k=10, seed=0)
    x = jnp.asarray(ds.x)
    idx = build_index(x, SuCoConfig(n_subspaces=8, sqrt_k=24, kmeans_iters=8, seed=0))
    return ds, x, idx


def _assert_bitwise_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))


# ------------------------- dense/streaming parity ---------------------------


@pytest.mark.parametrize("block_n", [512, 4096, 333, 1000])
def test_streaming_matches_dense_bitwise(small, block_n):
    """block_n=333/1000 do not divide n=4000 — the padded tail must not leak."""
    ds, x, idx = small
    q = jnp.asarray(ds.queries)
    dense = suco_query(x, idx, q, k=10, alpha=0.05, beta=0.02, mode="dense")
    stream = suco_query_streaming(
        x, idx, q, k=10, alpha=0.05, beta=0.02, block_n=block_n
    )
    _assert_bitwise_equal(dense, stream)


def test_streaming_pool_larger_than_n(small):
    """n < n_candidates (beta > 1): the pool clamps to n, parity still exact."""
    ds, x, idx = small
    q = jnp.asarray(ds.queries)
    dense = suco_query(x, idx, q, k=10, alpha=0.05, beta=1.5, mode="dense")
    stream = suco_query_streaming(x, idx, q, k=10, alpha=0.05, beta=1.5, block_n=777)
    _assert_bitwise_equal(dense, stream)


def test_streaming_l1_metric_parity(small):
    ds, x, idx = small
    q = jnp.asarray(ds.queries)
    dense = suco_query(x, idx, q, k=10, alpha=0.05, beta=0.05, metric="l1", mode="dense")
    stream = suco_query_streaming(
        x, idx, q, k=10, alpha=0.05, beta=0.05, metric="l1", block_n=700
    )
    _assert_bitwise_equal(dense, stream)


def test_streaming_rejects_bad_block_n(small):
    ds, x, idx = small
    q = jnp.asarray(ds.queries)
    for bad in (0, -5):
        with pytest.raises(ValueError, match="block_n"):
            suco_query_streaming(x, idx, q, k=10, alpha=0.05, beta=0.02, block_n=bad)


def test_streaming_rejects_k_larger_than_n(small):
    """The dense path raises (top_k) for k > n; the streaming path must too,
    not leak (score -1, id INT32_MAX) pool sentinels into the results."""
    ds, x, idx = small
    q = jnp.asarray(ds.queries)
    with pytest.raises(ValueError, match="k="):
        suco_query_streaming(
            x, idx, q, k=x.shape[0] + 1, alpha=0.05, beta=0.02, block_n=512
        )


def test_streaming_single_block_and_block_larger_than_n(small):
    ds, x, idx = small
    q = jnp.asarray(ds.queries)
    dense = suco_query(x, idx, q, k=10, alpha=0.05, beta=0.02, mode="dense")
    stream = suco_query_streaming(
        x, idx, q, k=10, alpha=0.05, beta=0.02, block_n=1_000_000
    )
    _assert_bitwise_equal(dense, stream)


def test_mode_dispatch(small):
    ds, x, idx = small
    q = jnp.asarray(ds.queries)
    # below the cutover, auto == dense
    auto = suco_query(x, idx, q, k=10, alpha=0.05, beta=0.02)
    dense = suco_query(x, idx, q, k=10, alpha=0.05, beta=0.02, mode="dense")
    _assert_bitwise_equal(auto, dense)
    assert ds.x.shape[0] < STREAMING_MIN_N
    with pytest.raises(ValueError, match="unknown mode"):
        suco_query(x, idx, q, k=10, alpha=0.05, beta=0.02, mode="bogus")


# ----------------------------- pool merge -----------------------------------


def test_merge_topk_pool_equals_dense_topk():
    """Scanning merge_topk_pool over blocks == one top_k over the full row,
    including the (score desc, id asc) tie-break."""
    rng = np.random.default_rng(0)
    m, n, p, bn = 7, 1000, 64, 96
    scores = jnp.asarray(rng.integers(0, 6, size=(m, n)), jnp.int32)  # many ties
    want_s, want_i = jax.lax.top_k(scores, p)

    int_max = np.iinfo(np.int32).max
    pool_s = jnp.full((m, p), -1, jnp.int32)
    pool_i = jnp.full((m, p), int_max, jnp.int32)
    for start in range(0, n, bn):
        blk = scores[:, start:start + bn]
        ids = jnp.broadcast_to(
            jnp.arange(start, start + blk.shape[1], dtype=jnp.int32), blk.shape
        )
        pool_s, pool_i = merge_topk_pool(pool_s, pool_i, blk, ids)
    np.testing.assert_array_equal(np.asarray(pool_s), np.asarray(want_s))
    np.testing.assert_array_equal(np.asarray(pool_i), np.asarray(want_i))


# -------------------------- rerank determinism ------------------------------


def test_rerank_tie_breaking_deterministic():
    """Duplicate points produce exact distance ties; rerank must resolve them
    to the earlier pool position (higher score, then lower id), identically
    on every invocation."""
    rng = np.random.default_rng(3)
    n, d, k = 24, 8, 6
    x = rng.normal(size=(n, d)).astype(np.float32)
    # rows 4, 9, 17 identical; rows 2 and 11 identical
    x[9] = x[4]
    x[17] = x[4]
    x[11] = x[2]
    q = rng.normal(size=(2, d)).astype(np.float32)
    scores = jnp.asarray(rng.integers(0, 4, size=(2, n)), jnp.int32)

    r1 = rerank(jnp.asarray(x), jnp.asarray(q), scores, k, n_candidates=16)
    r2 = rerank(jnp.asarray(x), jnp.asarray(q), scores, k, n_candidates=16)
    np.testing.assert_array_equal(np.asarray(r1.ids), np.asarray(r2.ids))
    np.testing.assert_array_equal(np.asarray(r1.dists), np.asarray(r2.dists))

    # numpy oracle: pool ordered by (score desc, id asc); final ids by
    # (distance asc, pool position asc)
    s_np = np.asarray(scores)
    for qi in range(2):
        pool = sorted(range(n), key=lambda j: (-s_np[qi, j], j))[:16]
        dd = ((x[pool] - q[qi]) ** 2).sum(axis=1)
        order = sorted(range(len(pool)), key=lambda t: (dd[t], t))[:k]
        want = [pool[t] for t in order]
        got = np.asarray(r1.ids[qi]).tolist()
        assert got == want, f"query {qi}: {got} != {want}"


def test_rerank_candidates_matches_rerank(small):
    ds, x, idx = small
    q = jnp.asarray(ds.queries)
    rng = np.random.default_rng(1)
    scores = jnp.asarray(rng.integers(0, 9, size=(q.shape[0], x.shape[0])), jnp.int32)
    full = rerank(x, q, scores, 10, n_candidates=80)
    vals, cand = jax.lax.top_k(scores, 80)
    via_pool = rerank_candidates(x, q, cand, vals, 10)
    _assert_bitwise_equal(full, via_pool)


# ------------------------------ memory model --------------------------------
#
# The ad-hoc jaxpr peak-intermediate assertions that used to live here are
# now the jaxlint `bounded-intermediate` rule: the streaming/fused entries in
# core/suco.py declare their O(m*(block_n + pool)) byte budgets, and this
# test exercises the rule itself (the full registry gate is
# tests/test_analysis.py / `python -m repro.analysis.lint`).


def test_streaming_never_materialises_m_by_n():
    """Migrated acceptance bound: the registered streaming/fused query
    entries stay inside their declared bounded-intermediate budgets — in
    particular below the (m, n) separation line — while the dense reference
    provably crosses it."""
    from repro.analysis.jaxpr_rules import (
        peak_intermediate_bytes,
        rule_bounded_intermediate,
    )
    from repro.analysis.registry import collect_entries
    from repro.core.suco import lint_dense_peak_bytes

    entries = {e.name: e for e in collect_entries(modules=("repro.core.suco",))}
    dense_line = lint_dense_peak_bytes()  # 4 * m * n at the lint shapes
    dense_peak, _ = peak_intermediate_bytes(entries["suco.query_dense"].make())
    assert dense_peak >= dense_line  # the dense path really materialises (m, n)

    for name in ("suco.query_streaming", "suco.query_fused"):
        entry = entries[name]
        jaxpr = entry.make()
        assert rule_bounded_intermediate(entry, jaxpr) == [], name
        peak, where = peak_intermediate_bytes(jaxpr)
        assert entry.budget_bytes < dense_line, name  # the budget is meaningful
        assert peak < dense_line, f"{name} materialised (m, n): {where}"


def test_streaming_parity_at_100k():
    """Acceptance: bit-identical ids to the dense path on n=100k synthetic
    data for at least two chunk sizes."""
    n, d, m = 100_000, 16, 8
    ds = make_dataset("gaussian_mixture", n, d, m=m, k=10, seed=2)
    x, q = jnp.asarray(ds.x), jnp.asarray(ds.queries)
    idx = build_index(x, SuCoConfig(n_subspaces=4, sqrt_k=16, kmeans_iters=2, seed=0))
    dense = suco_query(x, idx, q, k=10, alpha=0.03, beta=0.005, mode="dense")
    for bn in (8192, 30_000):
        stream = suco_query_streaming(
            x, idx, q, k=10, alpha=0.03, beta=0.005, block_n=bn
        )
        np.testing.assert_array_equal(np.asarray(dense.ids), np.asarray(stream.ids))
        np.testing.assert_array_equal(
            np.asarray(dense.dists), np.asarray(stream.dists)
        )
    # mode="auto" routes this n to the streaming engine
    auto = suco_query(x, idx, q, k=10, alpha=0.03, beta=0.005)
    np.testing.assert_array_equal(np.asarray(dense.ids), np.asarray(auto.ids))
