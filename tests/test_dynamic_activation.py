"""Property tests: the three Dynamic Activation implementations agree.

multi_sequence (heap, IMI'14) == dynamic_activation (paper Alg. 3) ==
activate_cells_sorted (TPU sort-prefix) == dynamic_activation_lax
(lax.while_loop port), on the retrieved cell *set* and its point total.
"""

import numpy as np
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # network-less env: vendored deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import activate_cells_sorted, dynamic_activation_lax
from repro.core.da_numpy import dynamic_activation, multi_sequence


@st.composite
def imi_case(draw):
    k = draw(st.integers(2, 12))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    d1 = rng.random(k).astype(np.float64)
    d2 = rng.random(k).astype(np.float64)
    counts = rng.integers(0, 10, size=(k, k)).astype(np.int32)
    total = int(counts.sum())
    target = draw(st.integers(1, max(total, 1)))
    return d1, d2, counts, target


@settings(max_examples=60, deadline=None)
@given(imi_case())
def test_all_four_implementations_agree(case):
    d1, d2, counts, target = case
    ms = multi_sequence(d1, d2, counts, target)
    da = dynamic_activation(d1, d2, counts, target)
    assert ms == da, "Alg.3 must retrieve the same cells in the same order"

    flat = jnp.asarray(counts.reshape(-1))
    mask_sorted = np.asarray(
        activate_cells_sorted(jnp.asarray(d1), jnp.asarray(d2), flat, target)
    )
    mask_lax = np.asarray(
        dynamic_activation_lax(jnp.asarray(d1), jnp.asarray(d2), flat, target)
    )
    k = counts.shape[1]
    set_ms = {c1 * k + c2 for c1, c2 in ms}
    assert set(np.nonzero(mask_sorted)[0].tolist()) == set_ms
    assert set(np.nonzero(mask_lax)[0].tolist()) == set_ms


@settings(max_examples=40, deadline=None)
@given(imi_case())
def test_prefix_minimality(case):
    """The retrieved set is the minimal ascending-distance prefix covering
    the target count (ties excepted — ties are broken by cell id)."""
    d1, d2, counts, target = case
    ms = multi_sequence(d1, d2, counts, target)
    got = sum(int(counts[c1, c2]) for c1, c2 in ms)
    if got < target:
        # only possible if every cell was retrieved
        assert len(ms) == counts.size
        return
    # removing the last (farthest) cell must drop below target
    drop = int(counts[ms[-1][0], ms[-1][1]])
    assert got - drop < target


def test_order_is_ascending_distance():
    rng = np.random.default_rng(0)
    d1, d2 = rng.random(8), rng.random(8)
    counts = np.ones((8, 8), np.int32)
    cells = multi_sequence(d1, d2, counts, 64)
    dists = [d1[a] + d2[b] for a, b in cells]
    assert all(x <= y + 1e-12 for x, y in zip(dists, dists[1:]))
