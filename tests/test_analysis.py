"""jaxlint gate: every rule passes on every registered entry point, and every
rule has at least one fixture that fails it — so a rule that silently stops
firing breaks the suite, not just the invariant it guards."""

import dataclasses
import json

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis import lint as lint_cli
from repro.analysis.ast_rules import AST_RULES, lint_source
from repro.analysis.findings import Finding, Report
from repro.analysis.jaxpr_rules import (
    JAXPR_RULES,
    rule_bounded_intermediate,
    rule_no_scatter_in_scan,
    rule_pinned_accumulator,
    rule_tile_shape,
    run_jaxpr_rules,
)
from repro.analysis.registry import (
    HOOK_MODULES,
    JaxprEntry,
    TileEntry,
    ast_targets,
    collect_entries,
)
from repro.core.tuning import TileConfig

S = jax.ShapeDtypeStruct


def _fatal(findings):
    return [f for f in findings if not f.suppressed]


# ----------------------- every rule x every entry ---------------------------


def test_registry_covers_the_serving_surface():
    names = {e.name for e in collect_entries()}
    expected = {
        "suco.query_streaming",
        "suco.query_fused",
        "suco.query_dense",
        "suco.engine_fused_bucket",
        "suco.engine_degraded_bucket",
        "suco.build_chunked",
        "sc_linear.query",
        "sc_linear.merge_pool_scan",
        "sc_linear.merge_pool_counting_scan",
        "sc_linear.merge_pool_with_dists_scan",
        "tuning.autotune_tiles",
        "kernels.sc_score.cells",
        "kernels.sc_score.cells_prefilter",
        "kernels.sc_score.cells_prefilter_compact",
        "kernels.sc_score.prefilter_compact_scan",
        "kernels.kmeans_assign.pair_hist",
        "kernels.sc_score.fused_distance",
        "kernels.sc_score.oracle",
        "kernels.gather_rerank.kernel",
        "kernels.gather_rerank.oracle",
        "kernels.kmeans_assign.batched",
        "kernels.kmeans_assign.stats",
        "kernels.kmeans_assign.oracle",
        "kernels.pairwise_l2.kernel",
        "kernels.pairwise_l2.oracle",
    }
    assert expected <= names, expected - names
    # targets under the AST engine
    tnames = {t.name for t in ast_targets()}
    assert "repro/serve/ann.py" in tnames
    assert any(t.startswith("repro/distributed/") for t in tnames)


def test_every_entry_passes_its_rules():
    """The acceptance gate: the whole registry lints clean (the in-process
    equivalent of `python -m repro.analysis.lint` exiting 0)."""
    for entry in collect_entries():
        findings, checked = run_jaxpr_rules(entry)
        assert checked, f"{entry.name}: no rules ran"
        assert _fatal(findings) == [], f"{entry.name}: {_fatal(findings)}"


def test_ast_engine_passes_on_serving_layer():
    for target in ast_targets():
        findings = lint_source(target.path.read_text(), target.name)
        assert _fatal(findings) == [], f"{target.name}: {_fatal(findings)}"


def test_sync_ok_annotations_are_audited():
    """The AsyncAnnServer retire point must stay an *annotated* sync — the
    suppression shows up in the report rather than vanishing."""
    target = next(t for t in ast_targets() if t.name == "repro/serve/ann.py")
    findings = lint_source(target.path.read_text(), target.name)
    suppressed = [f for f in findings if f.rule == "host-sync" and f.suppressed]
    assert suppressed, "expected annotated sync points in serve/ann.py"


# ------------------- failing fixtures: jaxpr rules --------------------------


def _entry(make, rules, **kw):
    return JaxprEntry(name="fixture", make=make, rules=rules, **kw)


def test_no_scatter_in_scan_fails_on_scatter_fixture():
    def bad(xs):
        def step(carry, row):
            return carry.at[0].set(row.sum()), None

        return jax.lax.scan(step, jnp.zeros(4), xs)[0]

    e = _entry(lambda: jax.make_jaxpr(bad)(jnp.ones((8, 16))), ("no-scatter-in-scan",))
    findings = rule_no_scatter_in_scan(e, e.make())
    assert findings and "scatter" in findings[0].message


def test_no_scatter_in_scan_fails_on_sort_fixture():
    def bad(xs):
        def step(carry, row):
            return carry + jnp.sort(row)[0], None

        return jax.lax.scan(step, jnp.float32(0), xs)[0]

    e = _entry(lambda: jax.make_jaxpr(bad)(jnp.ones((8, 16))), ("no-scatter-in-scan",))
    findings = rule_no_scatter_in_scan(e, e.make())
    assert findings and "sort" in findings[0].message


def test_no_scatter_in_scan_respects_scatter_budget():
    def small(xs):
        def step(carry, row):
            return carry.at[0].set(row.sum()), None

        return jax.lax.scan(step, jnp.zeros(4), xs)[0]

    e = _entry(
        lambda: jax.make_jaxpr(small)(jnp.ones((8, 16))),
        ("no-scatter-in-scan",),
        scatter_budget_elems=4,
    )
    assert rule_no_scatter_in_scan(e, e.make()) == []


def test_no_scatter_outside_scan_is_allowed():
    e = _entry(
        lambda: jax.make_jaxpr(lambda x: x.at[0].set(1.0))(jnp.ones(512)),
        ("no-scatter-in-scan",),
    )
    assert rule_no_scatter_in_scan(e, e.make()) == []


def test_bounded_intermediate_fails_on_tight_budget():
    e = _entry(
        lambda: jax.make_jaxpr(lambda a, b: a @ b)(
            jnp.ones((64, 64)), jnp.ones((64, 64))
        ),
        ("bounded-intermediate",),
        budget_bytes=128,
    )
    findings = rule_bounded_intermediate(e, e.make())
    assert findings and "exceeds" in findings[0].message


def test_pinned_accumulator_fails_on_bf16_matmul():
    # jnp.sum upcasts bf16 inputs to an f32 accumulator on its own (and the
    # rule accepts that); the genuinely unsafe pattern is a contraction whose
    # preferred_element_type pins the accumulator to bf16.
    def bad(a, b):
        return jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.bfloat16,
        )

    x = jnp.ones((8, 8), jnp.bfloat16)
    e = _entry(lambda: jax.make_jaxpr(bad)(x, x), ("pinned-accumulator",))
    findings = rule_pinned_accumulator(e, e.make())
    assert findings and "bfloat16" in findings[0].message


def test_pinned_accumulator_passes_on_upcast_bf16_sum_and_f32_matmul():
    for fn, arg in (
        # bf16 jnp.sum traces to convert->f32 reduce_sum: safe
        (lambda x: jnp.sum(x), jnp.ones((8, 8), jnp.bfloat16)),
        (lambda x: jnp.sum(x), jnp.ones((8, 8))),
        (lambda x: x @ x, jnp.ones((8, 8))),
    ):
        e = _entry(lambda: jax.make_jaxpr(fn)(arg), ("pinned-accumulator",))
        assert rule_pinned_accumulator(e, e.make()) == []


def test_dense_query_is_the_real_world_scatter_fixture():
    """The dense reference path (which deliberately does NOT declare
    no-scatter-in-scan) fails the rule — proof the rule bites on the real
    query stack, not only on synthetic jaxprs."""
    entries = {e.name: e for e in collect_entries(modules=("repro.core.suco",))}
    dense = entries["suco.query_dense"]
    assert "no-scatter-in-scan" not in dense.rules
    hypothetical = dataclasses.replace(dense, rules=("no-scatter-in-scan",))
    findings = rule_no_scatter_in_scan(hypothetical, hypothetical.make())
    assert findings, "dense path should scatter/sort inside its subspace scan"


# ------------------- failing fixtures: tile-shape ---------------------------


def _identity_pallas_jaxpr(block_cols: int):
    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    def run(x):
        return pl.pallas_call(
            kernel,
            grid=(2,),
            in_specs=[pl.BlockSpec((1, block_cols), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((1, block_cols), lambda i: (i, 0)),
            out_shape=S((2, block_cols), jnp.float32),
            interpret=True,
        )(x)

    return jax.make_jaxpr(run)(jnp.ones((2, block_cols), jnp.float32))


def test_tile_shape_fails_on_bad_tile_config():
    e = TileEntry(
        name="fixture.tiles",
        contract={
            "sublane": 8,
            "lane": 128,
            "block_quantum": 512,
            "cap_quantum": 64,
        },
        tile_configs=(TileConfig(block_n=1000, bm=7, bn=100, survivor_cap=50),),
    )
    messages = [f.message for f in rule_tile_shape(e)]
    assert any("bm=7" in m for m in messages)
    assert any("bn=100" in m for m in messages)
    assert any("block_n=1000" in m for m in messages)
    assert any("survivor_cap=50" in m for m in messages)


def test_tile_shape_fails_on_misaligned_block():
    e = TileEntry(
        name="fixture.lane",
        contract={"lane": 128, "block_align": {0: ((1, 128),)}},
        make=lambda: _identity_pallas_jaxpr(64),
    )
    findings = rule_tile_shape(e)
    assert findings and "not a multiple of 128" in findings[0].message


def test_tile_shape_fails_on_vmem_overflow():
    e = TileEntry(
        name="fixture.vmem",
        contract={"vmem_bytes": 64, "double_buffer": 2},
        make=lambda: _identity_pallas_jaxpr(128),
    )
    findings = rule_tile_shape(e)
    assert findings and "VMEM budget" in findings[0].message


def test_tile_shape_fails_when_no_pallas_call_traced():
    e = TileEntry(
        name="fixture.nopallas",
        contract={},
        make=lambda: jax.make_jaxpr(lambda x: x + 1)(jnp.ones(8)),
    )
    findings = rule_tile_shape(e)
    assert findings and "no pallas_call" in findings[0].message


# ------------------- failing fixtures: AST rules ----------------------------


def test_host_sync_fails_on_unannotated_asarray():
    src = "import numpy as np\n\ndef f(x):\n    return np.asarray(x)\n"
    findings = _fatal(lint_source(src, "fixture.py"))
    assert [f.rule for f in findings] == ["host-sync"]


def test_host_sync_annotation_suppresses():
    src = "import numpy as np\n\ndef f(x):\n    return np.asarray(x)  # jaxlint: sync-ok\n"
    findings = lint_source(src, "fixture.py")
    assert findings and all(f.suppressed for f in findings)


def test_host_sync_ignores_host_literals():
    src = "import numpy as np\n\ndef f(a, b):\n    return np.asarray([a, b]), np.asarray([x * 2 for x in (a, b)])\n"
    assert lint_source(src, "fixture.py") == []


def test_host_sync_flags_block_until_ready_and_item():
    src = (
        "import jax\n\ndef f(x):\n"
        "    jax.block_until_ready(x)\n"
        "    return x.item()\n"
    )
    rules = [f.rule for f in _fatal(lint_source(src, "fixture.py"))]
    assert rules == ["host-sync", "host-sync"]


def test_host_sync_flags_unannotated_fsync():
    """ISSUE-10: a durability layer full of ``os.fsync`` must declare every
    one as deliberately off the serving path — an unannotated fsync is a
    lint error, same as a device sync."""
    src = "import os\n\ndef commit(f):\n    os.fsync(f.fileno())\n"
    findings = _fatal(lint_source(src, "fixture.py"))
    assert [f.rule for f in findings] == ["host-sync"]
    assert "fsync" in findings[0].message


def test_host_sync_fsync_annotation_suppresses():
    src = (
        "import os\n\ndef commit(f):\n"
        "    os.fsync(f.fileno())  # jaxlint: sync-ok — group commit\n"
    )
    findings = lint_source(src, "fixture.py")
    assert findings and all(f.suppressed for f in findings)


def test_host_sync_flags_bare_name_fsync():
    src = "from os import fsync\n\ndef commit(fd):\n    fsync(fd)\n"
    findings = _fatal(lint_source(src, "fixture.py"))
    assert [f.rule for f in findings] == ["host-sync"]


def test_tracer_branch_fails_on_if_over_traced_arg():
    src = (
        "import jax\n\n@jax.jit\ndef f(x, flag):\n"
        "    if flag:\n        return x + 1\n    return x\n"
    )
    findings = _fatal(lint_source(src, "fixture.py"))
    assert [f.rule for f in findings] == ["tracer-branch"]
    assert "flag" in findings[0].message


def test_tracer_branch_respects_static_argnames():
    src = (
        "import functools\nimport jax\n\n"
        "@functools.partial(jax.jit, static_argnames=('flag',))\n"
        "def f(x, flag):\n"
        "    if flag:\n        return x + 1\n    return x\n"
    )
    assert _fatal(lint_source(src, "fixture.py")) == []


def test_tracer_branch_disable_comment():
    src = (
        "import jax\n\n@jax.jit\ndef f(x, flag):\n"
        "    if flag:  # jaxlint: disable=tracer-branch\n"
        "        return x + 1\n    return x\n"
    )
    findings = lint_source(src, "fixture.py")
    assert findings and all(f.suppressed for f in findings)


def test_jit_in_hot_path_fails_inside_loop():
    src = (
        "import jax\n\ndef serve(batches):\n"
        "    out = []\n"
        "    for b in batches:\n"
        "        out.append(jax.jit(lambda x: x + 1)(b))\n"
        "    return out\n"
    )
    findings = _fatal(lint_source(src, "fixture.py"))
    assert [f.rule for f in findings] == ["jit-in-hot-path"]


def test_jit_outside_loop_is_fine():
    src = (
        "import jax\n\nf = jax.jit(lambda x: x + 1)\n\n"
        "def serve(batches):\n    return [f(b) for b in batches]\n"
    )
    assert _fatal(lint_source(src, "fixture.py")) == []


# -------------------------- suppressions & report ---------------------------


def test_entry_level_suppression_is_reported_not_fatal():
    def bad(a, b):
        return jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.bfloat16,
        )

    x = jnp.ones((8, 8), jnp.bfloat16)
    e = _entry(
        lambda: jax.make_jaxpr(bad)(x, x),
        ("pinned-accumulator",),
        suppress={"pinned-accumulator": "fixture: bf16 on purpose"},
    )
    findings, checked = run_jaxpr_rules(e)
    assert checked == ["pinned-accumulator"]
    assert findings and all(f.suppressed for f in findings)
    assert findings[0].suppress_reason == "fixture: bf16 on purpose"


def test_report_json_shape():
    r = Report()
    r.mark_checked("host-sync", "a.py")
    r.extend(
        [
            Finding(rule="host-sync", target="a.py:3", message="boom"),
            Finding(
                rule="host-sync",
                target="a.py:9",
                message="ok",
                suppressed=True,
                suppress_reason="annotated",
            ),
        ]
    )
    payload = json.loads(r.to_json())
    assert payload["ok"] is False
    assert payload["n_findings"] == 1
    assert payload["n_suppressed"] == 1
    assert payload["checked"] == {"host-sync": ["a.py"]}
    assert not r.ok and len(r.fatal) == 1


def test_unknown_rule_name_is_a_finding():
    e = _entry(lambda: jax.make_jaxpr(lambda x: x + 1)(jnp.ones(4)), ("bogus-rule",))
    findings, checked = run_jaxpr_rules(e)
    assert checked == []
    assert findings and "unknown jaxpr rule" in findings[0].message


# -------------------------------- CLI ---------------------------------------


def test_cli_json_ast_only(capsys, tmp_path):
    out_path = tmp_path / "jaxlint.json"
    rc = lint_cli.main(
        ["--format", "json", "--rules", ",".join(AST_RULES), "--output", str(out_path)]
    )
    assert rc == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["ok"] is True
    assert set(AST_RULES) <= set(payload["checked"])
    assert json.loads(out_path.read_text()) == payload


def test_cli_list_and_unknown_rule(capsys):
    assert lint_cli.main(["--list"]) == 0
    out = capsys.readouterr().out
    for rule in list(JAXPR_RULES) + ["tile-shape", *AST_RULES]:
        assert rule in out
    assert "suco.query_fused" in out
    assert lint_cli.main(["--rules", "nonexistent"]) == 2


def test_cli_disable_suppresses(capsys):
    rc = lint_cli.main(
        ["--format", "json", "--rules", "host-sync", "--disable", "host-sync"]
    )
    assert rc == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["ok"] is True


def test_hook_modules_all_export_entries():
    import importlib

    for mod in HOOK_MODULES:
        assert hasattr(importlib.import_module(mod), "jaxlint_entries"), mod
