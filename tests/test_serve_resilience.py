"""Resilient-serving contracts: submit-time validation, deadlines +
oldest-deadline-first scheduling, bounded admission, the overload
controller, the degradation ladder (quantified quality bounds, zero
retraces across degrade/recover), fault isolation with retry, and the
autoscaler's histogram edge cases."""

import math

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import EnginePolicy, SuCoConfig, SuCoEngine, build_index
from repro.core.suco import autoscale_buckets, batch_bucket
from repro.core.theory import degraded_budget_bound
from repro.data import make_dataset
from repro.serve.ann import (
    AnnRequest,
    AnnServer,
    AsyncAnnServer,
    DegradationLadder,
    OverloadController,
    latency_summary,
)
from repro.serve.chaos import VirtualClock

CFG = SuCoConfig(n_subspaces=8, sqrt_k=16, kmeans_iters=4, seed=0)
POLICY_BUCKETS = (4, 16)


@pytest.fixture(scope="module")
def ds():
    return make_dataset("gaussian_mixture", 4000, 32, m=40, k=10, seed=0)


@pytest.fixture(scope="module")
def index(ds):
    return build_index(jnp.asarray(ds.x), CFG)


def _engine(ds, index):
    return SuCoEngine(
        jnp.asarray(ds.x), index,
        EnginePolicy(alpha=0.05, beta=0.02, batch_buckets=POLICY_BUCKETS),
    )


# ---- satellite: submit-time validation ----------------------------------


@pytest.mark.parametrize("server_cls", [AnnServer, AsyncAnnServer])
def test_poison_query_rejected_at_submit_healthy_batch_unharmed(
    ds, index, server_cls
):
    """A NaN query, a wrong-dim query and a k<1 request are all rejected
    per-request at submit; the healthy requests around them complete with
    correct answers."""
    engine = _engine(ds, index)
    engine.warmup(batch_sizes=(1, 4), ks=(10,))
    server = server_cls(engine, max_batch=4)
    nan_q = np.array(ds.queries[0], dtype=np.float32).copy()
    nan_q[3] = np.nan
    assert server.submit(AnnRequest(0, ds.queries[1], k=10)) is True
    assert server.submit(AnnRequest(1, nan_q, k=10)) is False
    assert server.submit(AnnRequest(2, ds.queries[2][:7], k=10)) is False
    assert server.submit(AnnRequest(3, ds.queries[3], k=0)) is False
    assert server.submit(AnnRequest(4, ds.queries[4], k=10)) is True
    done = server.run_until_drained()
    by = {r.rid: r for r in done}
    assert len(done) == 5
    assert "NaN" in by[1].error and not by[1].done
    assert "query must be" in by[2].error
    assert "k=0" in by[3].error
    for rid in (0, 4):
        r = by[rid]
        assert r.done and r.error is None
        want = engine.query(ds.queries[[1, 4][rid == 4]], k=10)
        np.testing.assert_array_equal(r.ids, np.asarray(want.ids))


# ---- deadlines ----------------------------------------------------------


def test_deadline_scheduling_oldest_deadline_first(ds, index):
    """With mixed deadlines, the tightest-deadline request leads the batch
    (and fixes its k) regardless of queue rank; deadline-free traffic
    stays FIFO."""
    engine = _engine(ds, index)
    engine.warmup(batch_sizes=(1, 4), ks=(5, 10))
    clock = VirtualClock()
    server = AnnServer(engine, max_batch=4, clock=clock, sleep=clock.advance)
    server.submit(AnnRequest(0, ds.queries[0], k=10))
    server.submit(AnnRequest(1, ds.queries[1], k=5, deadline_s=0.010))
    server.submit(AnnRequest(2, ds.queries[2], k=5, deadline_s=0.500))
    batch = server.step()
    # rid 1 has the oldest deadline -> its k=5 class is served first,
    # pulling rid 2 along and deferring the FIFO-first k=10 request.
    assert [r.rid for r in batch] == [1, 2]
    assert [r.rid for r in server.step()] == [0]


def test_expired_requests_reported_distinctly(ds, index):
    """A request whose deadline passes while queued expires at dispatch
    time (completes-with-error, expired=True) and shows up under
    n_expired, not n_failed."""
    engine = _engine(ds, index)
    engine.warmup(batch_sizes=(1, 4), ks=(10,))
    clock = VirtualClock()
    server = AnnServer(engine, max_batch=4, clock=clock, sleep=clock.advance)
    server.submit(AnnRequest(0, ds.queries[0], k=10, deadline_s=0.005))
    server.submit(AnnRequest(1, ds.queries[1], k=10))
    clock.advance(0.02)  # the deadline passes while queued
    done = server.run_until_drained()
    by = {r.rid: r for r in done}
    assert by[0].expired and not by[0].done and "expired" in by[0].error
    assert by[1].done
    s = latency_summary(done)
    assert s["n_expired"] == 1 and s["n_failed"] == 0
    assert s["deadline_hit_rate"] == 0.0  # the only deadlined request missed


def test_deadline_hit_rate_counts_only_deadlined_requests(ds, index):
    engine = _engine(ds, index)
    engine.warmup(batch_sizes=(1, 4), ks=(10,))
    server = AnnServer(engine, max_batch=4)
    server.submit(AnnRequest(0, ds.queries[0], k=10, deadline_s=60.0))
    server.submit(AnnRequest(1, ds.queries[1], k=10))  # no deadline
    s = latency_summary(server.run_until_drained())
    assert s["deadline_hit_rate"] == 1.0


# ---- admission control --------------------------------------------------


def test_bounded_admission_sheds_on_full(ds, index):
    engine = _engine(ds, index)
    engine.warmup(batch_sizes=(1, 4), ks=(10,))
    server = AnnServer(engine, max_batch=4, max_queue=2)
    accepted = server.submit_many(
        [AnnRequest(i, ds.queries[i], k=10) for i in range(5)]
    )
    assert accepted == 2 and len(server.queue) == 2
    shed = [r for r in server.completed if r.shed]
    assert len(shed) == 3
    assert all("queue full" in r.error and not r.done for r in shed)
    done = server.run_until_drained()
    s = latency_summary(done)
    assert s["n_shed"] == 3 and s["n_requests"] == 2
    with pytest.raises(ValueError, match="max_queue"):
        AnnServer(engine, max_queue=0)


# ---- overload controller ------------------------------------------------


def test_overload_controller_hysteresis():
    c = OverloadController(
        max_level=2, high_depth=8, low_depth=2, high_wait_s=0.1,
        patience=2, cooldown=2,
    )
    assert c.update(0, 0.0) == 0
    # two consecutive hot observations -> step up (not one: patience=2)
    assert c.update(10, 0.0) == 0
    assert c.update(10, 0.0) == 1
    # wait-driven overload counts too
    assert c.update(3, 0.5) == 1
    assert c.update(3, 0.5) == 2
    # clamped at max_level
    assert c.update(100, 1.0) == 2
    assert c.update(100, 1.0) == 2
    # middle ground (neither hot nor calm) holds the level
    assert c.update(5, 0.01) == 2
    # two calm observations -> step down, twice
    assert c.update(0, 0.0) == 2
    assert c.update(0, 0.0) == 1
    assert c.update(0, 0.0) == 1
    assert c.update(0, 0.0) == 0


# ---- degradation ladder -------------------------------------------------


def test_ladder_bounds_monotone_and_theorem2_derived(ds, index):
    engine = _engine(ds, index)
    ladder = DegradationLadder(engine, levels=2)
    n = int(engine.x.shape[0])
    ns = engine.index.spec.n_subspaces
    raw = [
        degraded_budget_bound(
            n, 10, ns, ladder.m_stat, ladder.sigma_stat,
            e.policy.alpha, e.policy.beta,
        )
        for e in ladder.engines
    ]
    bounds = [ladder.quality_bound(lv, 10) for lv in range(3)]
    # monotonised min over the prefix, never above the raw per-level bound
    for lv in range(3):
        assert bounds[lv] == min(raw[: lv + 1])
    assert bounds[0] >= bounds[1] >= bounds[2] >= 0.0
    assert all(0.0 <= b <= 1.0 for b in bounds)


def test_degrade_recover_cycle_zero_retraces_and_quantified_answers(ds, index):
    """The acceptance invariant: a warmed ladder serves a forced
    degrade -> recover cycle with zero retraces, every degraded answer
    carrying its level's quality bound."""
    engine = _engine(ds, index)
    ladder = DegradationLadder(engine, levels=2)
    ladder.warmup(batch_sizes=(1, 4), ks=(10,))
    server = AnnServer(engine, max_batch=4, ladder=ladder)
    before = server.executables
    for level in (0, 1, 2, 1, 0):  # forced cycle (no controller)
        server.level = level
        server.submit_many(
            [AnnRequest(100 * level + i, ds.queries[i], k=10) for i in range(4)]
        )
        batch = server.step()
        assert [r.degrade_level for r in batch] == [level] * 4
        for r in batch:
            assert r.done
            assert r.quality_bound == ladder.quality_bound(level, 10)
    assert server.executables == before, "degrade/recover retraced"
    assert all(s.compile_count == before for s in server.steps)
    s = latency_summary(server.completed)
    assert s["n_degraded"] == 12 and 0 < s["degraded_fraction"] < 1
    assert s["quality_bound_min"] == ladder.quality_bound(2, 10)


def test_controller_driven_degrade_on_backlog(ds, index):
    """A deep backlog trips the controller and the batches after the trip
    are served degraded, with bounds attached."""
    engine = _engine(ds, index)
    ladder = DegradationLadder(engine, levels=1)
    ladder.warmup(batch_sizes=(1, 4), ks=(10,))
    server = AnnServer(
        engine, max_batch=4, ladder=ladder,
        controller=OverloadController(
            max_level=1, high_depth=8, low_depth=0, patience=1, cooldown=10,
        ),
    )
    server.submit_many(
        [AnnRequest(i, ds.queries[i % 40], k=10) for i in range(16)]
    )
    done = server.run_until_drained()
    assert any(r.degrade_level == 1 for r in done)
    for r in done:
        if r.degrade_level == 1:
            assert r.quality_bound == ladder.quality_bound(1, 10)


# ---- fault isolation / retry -------------------------------------------


class _FlakyEngine:
    """Raises on the first ``fail_n`` dispatches, then delegates."""

    def __init__(self, engine, fail_n):
        self._engine = engine
        self.fail_n = fail_n
        self.calls = 0

    def query(self, q, k):
        self.calls += 1
        if self.calls <= self.fail_n:
            raise RuntimeError(f"transient dispatch error #{self.calls}")
        return self._engine.query(q, k=k)

    def __getattr__(self, name):
        return getattr(self._engine, name)


@pytest.mark.parametrize("server_cls", [AnnServer, AsyncAnnServer])
def test_transient_dispatch_error_retried_once(ds, index, server_cls):
    engine = _engine(ds, index)
    engine.warmup(batch_sizes=(1, 4), ks=(10,))
    clock = VirtualClock()
    flaky = _FlakyEngine(engine, fail_n=1)
    server = server_cls(flaky, max_batch=4, clock=clock, sleep=clock.advance)
    server.submit_many([AnnRequest(i, ds.queries[i], k=10) for i in range(3)])
    done = server.run_until_drained()
    assert all(r.done and r.error is None for r in done)
    assert all(r.retries == 1 for r in done)
    want = engine.query(np.stack([np.asarray(ds.queries[i]) for i in range(3)]), k=10)
    np.testing.assert_array_equal(
        np.stack([r.ids for r in sorted(done, key=lambda r: r.rid)]),
        np.asarray(want.ids),
    )


@pytest.mark.parametrize("server_cls", [AnnServer, AsyncAnnServer])
def test_persistent_failure_isolates_per_request(ds, index, server_cls):
    """When the batch fails its retry, requests are served one by one —
    here the fallback singles succeed, so every request still completes."""
    engine = _engine(ds, index)
    engine.warmup(batch_sizes=(1, 4), ks=(10,))
    clock = VirtualClock()
    flaky = _FlakyEngine(engine, fail_n=2)  # batch + its retry both fail
    server = server_cls(flaky, max_batch=4, clock=clock, sleep=clock.advance)
    server.submit_many([AnnRequest(i, ds.queries[i], k=10) for i in range(3)])
    done = server.run_until_drained()
    assert all(r.done and r.error is None for r in done)
    assert flaky.calls == 2 + 3  # batch, retry, then one call per request


def test_always_failing_engine_fails_requests_not_server(ds, index):
    engine = _engine(ds, index)
    clock = VirtualClock()
    flaky = _FlakyEngine(engine, fail_n=10**9)
    server = AnnServer(flaky, max_batch=4, clock=clock, sleep=clock.advance)
    server.submit_many([AnnRequest(i, ds.queries[i], k=10) for i in range(3)])
    done = server.run_until_drained()
    assert all(not r.done and "transient dispatch error" in r.error for r in done)
    assert latency_summary(done)["n_failed"] == 3


# ---- satellite: autoscaler histogram edge cases -------------------------


def test_autoscale_all_zero_histogram_falls_back():
    assert autoscale_buckets({4: 0, 8: 0}, 4, fallback=(1, 2)) == (1, 2)


def test_autoscale_single_bin_histogram():
    assert autoscale_buckets({7: 13}, 8) == (7,)
    assert autoscale_buckets({7: 13}, 1) == (7,)


def test_autoscale_empty_histogram_empty_fallback_is_clear_error():
    with pytest.raises(ValueError, match="empty"):
        autoscale_buckets({}, 4, fallback=())


def test_batch_bucket_empty_buckets_is_clear_error():
    with pytest.raises(ValueError, match="non-empty"):
        batch_bucket(3, ())


def test_policy_observe_then_autoscale_edge_histograms():
    p = EnginePolicy()
    p.observe([5] * 9)  # single-bin traffic
    assert p.autoscale_buckets() == (5,)
    assert p.autoscaled().batch_buckets == (5,)
    p2 = EnginePolicy()
    assert p2.autoscale_buckets() == tuple(sorted(set(p2.batch_buckets)))


# ---- summary accounting -------------------------------------------------


def test_summary_vacuous_fields_without_resilience_features(ds, index):
    """A plain healthy run reports neutral resilience fields: nothing
    shed/expired/degraded, hit rate and bound floor vacuously 1.0."""
    engine = _engine(ds, index)
    engine.warmup(batch_sizes=(1, 4), ks=(10,))
    server = AnnServer(engine, max_batch=4)
    server.submit_many([AnnRequest(i, ds.queries[i], k=10) for i in range(4)])
    s = latency_summary(server.run_until_drained())
    assert s["n_shed"] == s["n_expired"] == s["n_failed"] == s["n_degraded"] == 0
    assert s["deadline_hit_rate"] == 1.0 and s["quality_bound_min"] == 1.0
    assert math.isfinite(s["p99_ms"])
