"""Minimal deterministic stand-in for `hypothesis`.

This environment is network-less and `hypothesis` is not always
installable, but four test modules property-test the SC framework with
it.  This shim provides the tiny subset they use — ``given``,
``settings`` and ``strategies`` (``integers``, ``floats``,
``composite``) — running each property over a fixed number of *seeded,
deterministic* examples instead of hypothesis' adaptive search.

No shrinking, no database, no adaptive generation: every run draws the
same examples, so failures are reproducible by example index.  Test
modules import it via

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, strategies as st

so they behave identically with or without the real library installed.
"""

from __future__ import annotations

import random
from typing import Any, Callable

__all__ = ["given", "settings", "strategies", "SearchStrategy"]

_DEFAULT_MAX_EXAMPLES = 20
_SEED_BASE = 0x5C0  # "SC" — any fixed constant works; determinism is the point


class SearchStrategy:
    """A strategy is just a deterministic draw function over a PRNG."""

    def __init__(self, draw_fn: Callable[[random.Random], Any]):
        self._draw = draw_fn

    def example(self, rng: random.Random) -> Any:
        return self._draw(rng)

    def map(self, fn: Callable[[Any], Any]) -> "SearchStrategy":
        return SearchStrategy(lambda rng: fn(self._draw(rng)))


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` (used subset only)."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> SearchStrategy:
        # Bias the first draws toward the boundaries: hypothesis finds most
        # bugs at the edges, and the fallback should keep that property.
        def draw(rng: random.Random) -> int:
            r = rng.random()
            if r < 0.08:
                return min_value
            if r < 0.16:
                return max_value
            return rng.randint(min_value, max_value)

        return SearchStrategy(draw)

    @staticmethod
    def floats(min_value: float, max_value: float, **_kw) -> SearchStrategy:
        def draw(rng: random.Random) -> float:
            r = rng.random()
            if r < 0.08:
                return min_value
            if r < 0.16:
                return max_value
            return rng.uniform(min_value, max_value)

        return SearchStrategy(draw)

    @staticmethod
    def composite(fn: Callable) -> Callable[..., SearchStrategy]:
        def make(*args, **kwargs) -> SearchStrategy:
            def drawer(rng: random.Random):
                return fn(lambda strat: strat.example(rng), *args, **kwargs)

            return SearchStrategy(drawer)

        return make


st = strategies


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    """Records ``max_examples`` on the (already ``given``-wrapped) test."""

    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(*arg_strategies: SearchStrategy, **kw_strategies: SearchStrategy):
    """Run the property over seeded deterministic examples.

    The wrapper deliberately takes no parameters (and does not set
    ``__wrapped__``) so pytest's fixture resolution sees a zero-arg test
    instead of trying to inject the strategy names as fixtures.
    """

    def deco(fn):
        def wrapper():
            n = getattr(wrapper, "_fallback_max_examples", _DEFAULT_MAX_EXAMPLES)
            for i in range(n):
                rng = random.Random(_SEED_BASE * 1_000_003 + i)
                args = [s.example(rng) for s in arg_strategies]
                kwargs = {k: s.example(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*args, **kwargs)
                except Exception as e:  # re-raise with the failing example
                    raise AssertionError(
                        f"falsifying example #{i}: args={args!r} kwargs={kwargs!r}"
                    ) from e

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco
