"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracle, with
shape/dtype sweeps (hypothesis + parametrize)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # network-less env: vendored deterministic shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels.pairwise_l2.ops import pairwise_sqdist
from repro.kernels.pairwise_l2.ref import pairwise_sqdist_ref
from repro.kernels.kmeans_assign.ops import (
    kmeans_assign,
    kmeans_assign_batched,
    kmeans_assign_stats,
)
from repro.kernels.kmeans_assign.ref import (
    kmeans_assign_batched_ref,
    kmeans_assign_ref,
    kmeans_stats_ref,
)
from repro.kernels.gather_rerank.ops import gather_rerank
from repro.kernels.gather_rerank.ref import gather_rerank_ref
from repro.kernels.linear_attn.kernel import linear_attn_kernel
from repro.kernels.linear_attn.ref import linear_attn_ref
from repro.kernels.linear_attn.ops import linear_attention


# --------------------------- pairwise_l2 ------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    m=st.integers(1, 70),
    n=st.integers(1, 200),
    d=st.integers(1, 150),
    seed=st.integers(0, 99),
)
def test_pairwise_l2_shapes(m, n, d, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    got = pairwise_sqdist(q, x, interpret=True)
    want = pairwise_sqdist_ref(q, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_l2_dtypes(dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(33, 64)), dtype)
    x = jnp.asarray(rng.normal(size=(129, 64)), dtype)
    got = pairwise_sqdist(q, x, interpret=True)
    want = pairwise_sqdist_ref(q, x)
    tol = 1e-4 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol, rtol=tol)
    assert got.dtype == jnp.float32  # fp32 accumulate regardless of input


# --------------------------- kmeans_assign ----------------------------------


@settings(max_examples=12, deadline=None)
@given(
    n=st.integers(1, 300),
    k=st.integers(1, 80),
    s=st.integers(1, 40),
    seed=st.integers(0, 99),
)
def test_kmeans_assign_sweep(n, k, s, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, s)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(k, s)), jnp.float32)
    got = kmeans_assign(x, c, interpret=True)
    want = kmeans_assign_ref(x, c)
    assert (np.asarray(got) == np.asarray(want)).all()


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 6),
    n=st.integers(1, 200),
    k=st.integers(1, 60),
    s=st.integers(1, 30),
    seed=st.integers(0, 99),
)
def test_kmeans_assign_batched_sweep(b, n, k, s, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, n, s)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(b, k, s)), jnp.float32)
    got = kmeans_assign_batched(x, c, bn=64, impl="pallas", interpret=True)
    want = kmeans_assign_batched_ref(x, c)
    assert (np.asarray(got) == np.asarray(want)).all()


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 5),
    n=st.integers(1, 200),
    k=st.integers(1, 40),
    s=st.integers(1, 30),
    seed=st.integers(0, 99),
)
def test_kmeans_stats_sweep(b, n, k, s, seed):
    """The fused stats kernel (distance + argmin + partial-sum accumulation)
    must reproduce the dense oracle including n % bn != 0 padding."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, n, s)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(b, k, s)), jnp.float32)
    a, sums, counts, inertia = kmeans_assign_stats(
        x, c, bn=64, impl="pallas", interpret=True
    )
    aw, sw, cw, iw = kmeans_stats_ref(x, c)
    assert (np.asarray(a) == np.asarray(aw)).all()
    np.testing.assert_allclose(np.asarray(sums), np.asarray(sw), rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(counts), np.asarray(cw), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(inertia), np.asarray(iw), rtol=1e-4, atol=1e-3
    )


def test_kmeans_stats_without_assign():
    """The stats-only variant (used by Lloyd iterations) must drop the
    assignment output and keep the statistics bit-identical."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(3, 150, 10)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(3, 12, 10)), jnp.float32)
    a1, s1, c1, i1 = kmeans_assign_stats(x, c, bn=64, impl="pallas", interpret=True)
    a0, s0, c0, i0 = kmeans_assign_stats(
        x, c, bn=64, impl="pallas", with_assign=False, interpret=True
    )
    assert a1 is not None and a0 is None
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s0))
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c0))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i0))


@settings(max_examples=10, deadline=None)
@given(
    ns=st.integers(1, 4),
    n=st.integers(1, 200),
    k=st.integers(2, 30),
    s=st.integers(1, 20),
    seed=st.integers(0, 99),
)
def test_kmeans_pair_assign_hist_sweep(ns, n, k, s, seed):
    """Fused pair assignment + IMI histogram: Pallas (interpret) vs oracle.
    Assignments must be bit-identical to the batched kernel and the
    histogram exact (one-hot matmul accumulates small integers in f32)."""
    from repro.kernels.kmeans_assign.ops import kmeans_pair_assign_hist
    from repro.kernels.kmeans_assign.ref import kmeans_pair_assign_hist_ref

    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2 * ns, n, s)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(2 * ns, k, s)), jnp.float32)
    a, counts = kmeans_pair_assign_hist(x, c, bn=64, impl="pallas", interpret=True)
    aw, cw = kmeans_pair_assign_hist_ref(x, c)
    assert a.dtype == jnp.int32 and counts.dtype == jnp.int32
    assert (np.asarray(a) == np.asarray(aw)).all()
    assert (np.asarray(counts) == np.asarray(cw)).all()
    assert int(np.asarray(counts).sum()) == ns * n


def test_kmeans_pair_assign_hist_rejects_odd_batch():
    from repro.kernels.kmeans_assign.ops import kmeans_pair_assign_hist

    x = jnp.zeros((3, 16, 4), jnp.float32)
    c = jnp.zeros((3, 5, 4), jnp.float32)
    with pytest.raises(ValueError):
        kmeans_pair_assign_hist(x, c, impl="jnp")


# --------------------------- gather_rerank ----------------------------------


@settings(max_examples=10, deadline=None)
@given(
    mq=st.integers(1, 6),
    mc=st.integers(1, 50),
    n=st.integers(4, 300),
    d=st.integers(1, 100),
    seed=st.integers(0, 99),
)
def test_gather_rerank_sweep(mq, mc, n, d, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(mq, d)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, n, size=(mq, mc)), jnp.int32)
    got = gather_rerank(ids, x, q, interpret=True)
    want = gather_rerank_ref(ids, x, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


# ---------------------------- linear_attn -----------------------------------


@pytest.mark.parametrize("shift", [0, 1])
@pytest.mark.parametrize("t,chunk", [(64, 16), (100, 32), (32, 32)])
def test_linear_attn_kernel_vs_scan(shift, t, chunk):
    rng = np.random.default_rng(0)
    bh, dk, dv = 4, 16, 24
    q = rng.normal(size=(bh, t, dk)).astype(np.float32) * 0.3
    k = rng.normal(size=(bh, t, dk)).astype(np.float32) * 0.3
    v = rng.normal(size=(bh, t, dv)).astype(np.float32)
    w = rng.uniform(0.2, 0.9995, size=(bh, t, dk)).astype(np.float32)
    u = rng.normal(size=(bh, 1, dk)).astype(np.float32) * 0.2
    tp = -(-t // chunk) * chunk
    pad = lambda a, cv=0.0: np.pad(a, ((0, 0), (0, tp - t), (0, 0)), constant_values=cv)
    o_k, s_k = linear_attn_kernel(
        jnp.asarray(pad(q)), jnp.asarray(pad(k)), jnp.asarray(pad(v)),
        jnp.asarray(pad(w, 1.0)), jnp.asarray(u),
        chunk=chunk, shift=shift, interpret=True,
    )
    o_r, s_r = linear_attn_ref(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(w),
        jnp.asarray(u), shift=shift,
    )
    np.testing.assert_allclose(np.asarray(o_k)[:, :t], np.asarray(o_r), atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), atol=2e-4, rtol=1e-3)


def test_linear_attn_small_decay_stability():
    """The log-space chunk form must survive decays that overflow the naive
    cumprod-ratio formulation (0.2^64 ~ 1e-45 underflow)."""
    rng = np.random.default_rng(1)
    bh, t, dk, dv = 2, 128, 8, 8
    q = rng.normal(size=(bh, t, dk)).astype(np.float32)
    k = rng.normal(size=(bh, t, dk)).astype(np.float32)
    v = rng.normal(size=(bh, t, dv)).astype(np.float32)
    w = np.full((bh, t, dk), 0.2, np.float32)
    u = np.zeros((bh, 1, dk), np.float32)
    o_k, _ = linear_attn_kernel(
        *(jnp.asarray(a) for a in (q, k, v, w, u)), chunk=64, shift=0, interpret=True
    )
    o_r, _ = linear_attn_ref(*(jnp.asarray(a) for a in (q, k, v, w, u)), shift=0)
    assert np.isfinite(np.asarray(o_k)).all()
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=1e-4, rtol=1e-3)


def test_linear_attention_wrapper_routes_to_ref_on_cpu():
    rng = np.random.default_rng(2)
    b, h, t, d = 2, 3, 20, 8
    mk = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)
    q, k, v = mk(b, h, t, d), mk(b, h, t, d), mk(b, h, t, d)
    w = jnp.asarray(rng.uniform(0.5, 0.99, size=(b, h, t, d)), jnp.float32)
    out = linear_attention(q, k, v, w, mode="gla")
    assert out.shape == (b, h, t, d)
    assert np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("shift", [0, 1])
def test_linear_attn_chunked_jnp_vs_scan(shift):
    from repro.kernels.linear_attn.ref import linear_attn_chunked_jnp

    rng = np.random.default_rng(3)
    bh, t, dk, dv = 3, 128, 12, 20
    q = jnp.asarray(rng.normal(size=(bh, t, dk)), jnp.float32) * 0.3
    k = jnp.asarray(rng.normal(size=(bh, t, dk)), jnp.float32) * 0.3
    v = jnp.asarray(rng.normal(size=(bh, t, dv)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.2, 0.9995, size=(bh, t, dk)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(bh, 1, dk)), jnp.float32) * 0.2
    o_c, s_c = linear_attn_chunked_jnp(q, k, v, w, u, chunk=32, shift=shift)
    o_r, s_r = linear_attn_ref(q, k, v, w, u, shift=shift)
    np.testing.assert_allclose(np.asarray(o_c), np.asarray(o_r), atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_r), atol=2e-4, rtol=1e-3)


# ---------------------------- sc_score (fused) ------------------------------


@settings(max_examples=10, deadline=None)
@given(
    ns=st.integers(1, 8),
    m=st.integers(1, 20),
    n=st.integers(1, 300),
    s=st.integers(1, 40),
    seed=st.integers(0, 99),
)
def test_sc_score_fused_sweep(ns, m, n, s, seed):
    from repro.kernels.sc_score.ops import sc_scores_fused
    from repro.kernels.sc_score.ref import sc_score_ref

    rng = np.random.default_rng(seed)
    qs = jnp.asarray(rng.normal(size=(ns, m, s)), jnp.float32)
    xs = jnp.asarray(rng.normal(size=(ns, n, s)), jnp.float32)
    # thresholds from actual distance quantiles so masks are non-trivial
    d2 = np.maximum(
        (np.asarray(qs)[:, :, None] - np.asarray(xs)[:, None]) ** 2, 0
    ).sum(-1)
    # nudge thresholds off exact distance values so fp32 reduction-order
    # differences between kernel and oracle cannot flip boundary elements
    tau = jnp.asarray(np.quantile(d2, 0.3, axis=2) + 1e-3, jnp.float32)
    got = sc_scores_fused(qs, xs, tau, interpret=True)
    want = sc_score_ref(qs, xs, tau)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_sc_score_fused_equals_core_pipeline():
    """The fused kernel reproduces sc_scores_from_subspaces exactly."""
    from repro.core import contiguous_spec, collision_count
    from repro.core import subspace as sub
    from repro.core.collision import kth_smallest
    from repro.core.sc_linear import sc_scores_from_subspaces
    from repro.kernels.sc_score.ops import sc_scores_fused

    rng = np.random.default_rng(0)
    n, d, mq = 500, 32, 6
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(mq, d)), jnp.float32)
    spec = contiguous_spec(d, 4)
    xs = sub.split_padded(spec, sub.permute(spec, x))
    qs = sub.split_padded(spec, sub.permute(spec, q))
    c = collision_count(n, 0.05)
    want = sc_scores_from_subspaces(xs, qs, c)
    # thresholds exactly as the core path computes them (same matmul-identity
    # rounding; a direct (x-q)^2 formula flips boundary elements)
    from repro.core.distances import pairwise_dist

    d_sub = jax.vmap(lambda xx, qq: pairwise_dist(qq, xx))(xs, qs)  # (Ns,m,n)
    tau = kth_smallest(d_sub, c)  # (Ns, m)
    got = sc_scores_fused(qs, xs, tau, interpret=True)
    assert (np.asarray(got) == np.asarray(want)).all()


@settings(max_examples=10, deadline=None)
@given(
    ns=st.integers(1, 8),
    m=st.integers(1, 20),
    k_cells=st.integers(4, 400),
    bc=st.integers(1, 700),
    seed=st.integers(0, 99),
)
def test_sc_score_cells_sweep(ns, m, k_cells, bc, seed):
    """Chunked IMI entry point: Pallas (interpret) vs jnp oracle, exact."""
    from repro.kernels.sc_score.ops import sc_scores_cells
    from repro.kernels.sc_score.ref import sc_score_cells_ref

    rng = np.random.default_rng(seed)
    ranks = jnp.asarray(
        np.stack([
            np.stack([rng.permutation(k_cells) for _ in range(m)])
            for _ in range(ns)
        ]),
        jnp.int32,
    )
    cuts = jnp.asarray(rng.integers(-1, k_cells, size=(ns, m)), jnp.int32)
    cells = jnp.asarray(rng.integers(0, k_cells, size=(ns, bc)), jnp.int32)
    got = sc_scores_cells(ranks, cuts, cells, impl="pallas", interpret=True)
    want = sc_score_cells_ref(ranks, cuts, cells)
    assert got.dtype == jnp.int32
    assert (np.asarray(got) == np.asarray(want)).all()


@settings(max_examples=10, deadline=None)
@given(
    ns=st.integers(1, 8),
    m=st.integers(1, 20),
    k_cells=st.integers(4, 400),
    bc=st.integers(1, 700),
    seed=st.integers(0, 99),
)
def test_sc_score_cells_prefilter_sweep(ns, m, k_cells, bc, seed):
    """Fused score+prefilter chunk stage: Pallas (interpret) vs jnp oracle,
    exact — scores identical to the plain entry point, keep mask == the
    score-vs-threshold compare."""
    from repro.kernels.sc_score.ops import sc_scores_cells_prefilter
    from repro.kernels.sc_score.ref import (
        sc_score_cells_prefilter_ref,
        sc_score_cells_ref,
    )

    rng = np.random.default_rng(seed)
    ranks = jnp.asarray(
        np.stack([
            np.stack([rng.permutation(k_cells) for _ in range(m)])
            for _ in range(ns)
        ]),
        jnp.int32,
    )
    cuts = jnp.asarray(rng.integers(-1, k_cells, size=(ns, m)), jnp.int32)
    cells = jnp.asarray(rng.integers(0, k_cells, size=(ns, bc)), jnp.int32)
    thr = jnp.asarray(rng.integers(-1, ns + 1, size=(m,)), jnp.int32)
    got_s, got_k = sc_scores_cells_prefilter(
        ranks, cuts, cells, thr, impl="pallas", interpret=True
    )
    want_s, want_k = sc_score_cells_prefilter_ref(ranks, cuts, cells, thr)
    assert got_s.dtype == jnp.int32 and got_k.dtype == jnp.bool_
    assert (np.asarray(got_s) == np.asarray(want_s)).all()
    assert (np.asarray(got_k) == np.asarray(want_k)).all()
    # the fused stage never perturbs the plain scores
    plain = sc_score_cells_ref(ranks, cuts, cells)
    assert (np.asarray(got_s) == np.asarray(plain)).all()


@settings(max_examples=10, deadline=None)
@given(
    ns=st.integers(1, 8),
    m=st.integers(1, 16),
    k_cells=st.integers(4, 200),
    bc=st.integers(1, 600),
    cap=st.integers(1, 300),
    seed=st.integers(0, 99),
)
def test_sc_score_cells_prefilter_compact_sweep(ns, m, k_cells, bc, cap, seed):
    """Fused score + prune + in-kernel survivor compaction: Pallas
    (interpret) vs jnp oracle, exact — including ragged tails (limit < bc),
    overflow (total > cap, first ``cap`` survivors in ascending column
    order), and the sentinel fill of dead slots."""
    from repro.kernels.sc_score.ops import sc_scores_cells_prefilter_compact
    from repro.kernels.sc_score.ref import sc_score_cells_prefilter_compact_ref

    rng = np.random.default_rng(seed)
    ranks = jnp.asarray(
        np.stack([
            np.stack([rng.permutation(k_cells) for _ in range(m)])
            for _ in range(ns)
        ]),
        jnp.int32,
    )
    cuts = jnp.asarray(rng.integers(-1, k_cells, size=(ns, m)), jnp.int32)
    cells = jnp.asarray(rng.integers(0, k_cells, size=(ns, bc)), jnp.int32)
    thr = jnp.asarray(rng.integers(-1, ns + 1, size=(m,)), jnp.int32)
    limit = jnp.int32(int(rng.integers(0, bc + 1)))
    got = sc_scores_cells_prefilter_compact(
        ranks, cuts, cells, thr, limit, cap=cap, impl="pallas", interpret=True
    )
    want = sc_score_cells_prefilter_compact_ref(
        ranks, cuts, cells, thr, limit, cap=cap
    )
    for g, w in zip(got, want):
        assert g.dtype == jnp.int32
        assert (np.asarray(g) == np.asarray(w)).all()


def test_sc_score_cells_equals_dense_suco_scores():
    """Chunked scoring over blocks reassembles the dense suco_scores matrix."""
    from repro.core import SuCoConfig, build_index, collision_count
    from repro.core.suco import suco_cell_ranks, suco_scores
    from repro.kernels.sc_score.ops import sc_scores_cells
    from repro.data import make_dataset

    ds = make_dataset("gaussian_mixture", 1500, 32, m=5, k=10, seed=4)
    x = jnp.asarray(ds.x)
    q = jnp.asarray(ds.queries)
    idx = build_index(x, SuCoConfig(n_subspaces=4, sqrt_k=12, kmeans_iters=3))
    c = collision_count(1500, 0.05)
    want = suco_scores(idx, q, c)  # (m, n) dense
    ranks, cuts = suco_cell_ranks(idx, q, c)
    bn = 400
    blocks = []
    for start in range(0, 1500, bn):
        cells_b = idx.cell_ids[:, start:start + bn]
        blocks.append(np.asarray(sc_scores_cells(ranks, cuts, cells_b, impl="jnp")))
    got = np.concatenate(blocks, axis=1)
    assert (got == np.asarray(want)).all()
