"""Property-based invariants of the serving stack.

Uses the vendored deterministic hypothesis shim
(:mod:`_hypothesis_fallback`) — seeded examples, reproducible failures —
to pin the three algebraic facts the serving subsystem is built on:

* :func:`repro.core.sc_linear.merge_topk_pool` is **chunking-invariant**
  (any ascending-id block partition reproduces the dense lexicographic
  top-p selection bit-for-bit, under all three impls), **order-invariant**
  under ``impl="sort"`` (arbitrary block arrival order — the contract the
  docstring offers callers outside the streaming invariant), and its
  merged pool is a **fixed point** under sentinel merges (idempotence:
  draining an exhausted stream any number of times changes nothing).
  The **counting-select** impl is additionally pinned **bitwise equal**
  to the ``lax.top_k`` baseline on single merges of lawful pools — ties
  at every score level, all-equal scores, duplicate ids across pool and
  block, non-divisible widths, pools down to ``p=1`` — with and without
  carried distances, and ``impl="auto"`` resolves to it exactly when the
  scores are integer-ranged.
* ``batch_bucket`` **padding never changes results**: the rowwise
  distance path is bitwise invariant to zero-padded batch rows, which is
  the exact property that makes a padded engine bucket return the
  unpadded computation's top-k.
* :func:`repro.core.suco.autoscale_buckets` always **covers the observed
  max** batch, respects ``max_buckets``, and never proposes a worse
  bucket set (by expected padding waste) than the trivial single-bucket
  cover.
"""

import numpy as np
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.distances import pairwise_dist
from repro.core.sc_linear import merge_topk_pool, merge_topk_pool_with_dists
from repro.core.suco import (
    DEFAULT_BATCH_BUCKETS,
    autoscale_buckets,
    batch_bucket,
    padding_waste,
)

INT_MAX = np.iinfo(np.int32).max


def _lex_topk(scores: np.ndarray, ids: np.ndarray, p: int):
    """Reference (score desc, id asc) top-p selection, row by row."""
    out_s, out_i = [], []
    for s_row, i_row in zip(scores, ids):
        order = np.lexsort((i_row, -s_row))[:p]
        out_s.append(s_row[order])
        out_i.append(i_row[order])
    return np.asarray(out_s), np.asarray(out_i)


def _merge_blocks(blocks, p: int, impl: str, smax=None):
    """Fold (scores, ids) blocks into a sentinel-initialised top-p pool."""
    m = blocks[0][0].shape[0]
    pool_s = jnp.full((m, p), -1, jnp.int32)
    pool_i = jnp.full((m, p), INT_MAX, jnp.int32)
    for s, i in blocks:
        pool_s, pool_i = merge_topk_pool(
            pool_s, pool_i, jnp.asarray(s), jnp.asarray(i), impl=impl,
            smax=smax,
        )
    return np.asarray(pool_s), np.asarray(pool_i)


@st.composite
def _score_matrix(draw):
    """(scores (m, n) int32 >= 0, pool size p, a random chunk partition)."""
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    m = draw(st.integers(1, 4))
    n = draw(st.integers(1, 40))
    p = draw(st.integers(1, 12))
    # few distinct score values -> dense ties, the case that breaks naive merges
    scores = rng.integers(0, 4, size=(m, n)).astype(np.int32)
    cuts, at = [], 0
    while at < n:
        step = int(rng.integers(1, n - at + 1))
        cuts.append((at, at + step))
        at += step
    return scores, p, cuts


@given(_score_matrix())
@settings(max_examples=25)
def test_merge_topk_pool_chunking_invariant(case):
    """Any ascending-id chunking == the dense lexicographic selection, for
    both impls, including pools larger than the data (sentinel tail)."""
    scores, p, cuts = case
    m, n = scores.shape
    ids = np.broadcast_to(np.arange(n, dtype=np.int32), (m, n))
    want_s, want_i = _lex_topk(
        np.pad(scores, ((0, 0), (0, p)), constant_values=-1),
        np.pad(ids, ((0, 0), (0, p)), constant_values=INT_MAX),
        p,
    )
    for impl in ("topk", "sort", "counting"):
        got_s, got_i = _merge_blocks(
            [(scores[:, a:b], ids[:, a:b]) for a, b in cuts], p, impl,
            smax=3 if impl == "counting" else None,
        )
        np.testing.assert_array_equal(got_s, want_s, err_msg=f"{impl} scores")
        np.testing.assert_array_equal(got_i, want_i, err_msg=f"{impl} ids")


@given(_score_matrix())
@settings(max_examples=15)
def test_merge_topk_pool_order_invariant_with_sort_impl(case):
    """impl="sort" owes callers arbitrary block order: reversing the block
    arrival order must produce the identical pool."""
    scores, p, cuts = case
    m, n = scores.shape
    ids = np.broadcast_to(np.arange(n, dtype=np.int32), (m, n))
    blocks = [(scores[:, a:b], ids[:, a:b]) for a, b in cuts]
    fwd = _merge_blocks(blocks, p, "sort")
    rev = _merge_blocks(blocks[::-1], p, "sort")
    np.testing.assert_array_equal(fwd[0], rev[0])
    np.testing.assert_array_equal(fwd[1], rev[1])


@given(_score_matrix())
@settings(max_examples=15)
def test_merge_topk_pool_idempotent_on_exhausted_stream(case):
    """A merged pool is a fixed point: merging all-sentinel blocks (an
    exhausted stream) any number of times returns the pool bit-for-bit."""
    scores, p, cuts = case
    m, n = scores.shape
    ids = np.broadcast_to(np.arange(n, dtype=np.int32), (m, n))
    blocks = [(scores[:, a:b], ids[:, a:b]) for a, b in cuts]
    for impl in ("topk", "sort", "counting"):
        smax = 3 if impl == "counting" else None
        pool_s, pool_i = _merge_blocks(blocks, p, impl, smax=smax)
        sent_s = np.full((m, 7), -1, np.int32)
        sent_i = np.full((m, 7), INT_MAX, np.int32)
        again_s, again_i = pool_s, pool_i
        for _ in range(2):
            again_s, again_i = merge_topk_pool(
                jnp.asarray(again_s), jnp.asarray(again_i),
                jnp.asarray(sent_s), jnp.asarray(sent_i), impl=impl,
                smax=smax,
            )
        np.testing.assert_array_equal(np.asarray(again_s), pool_s)
        np.testing.assert_array_equal(np.asarray(again_i), pool_i)


@st.composite
def _sorted_pool_and_block(draw):
    """A lawful carried pool (sorted desc, sentinel tail) plus one incoming
    block — the single-merge shape the counting impl must reproduce
    bit-for-bit against the ``lax.top_k`` baseline.  Three score styles
    stress the tie structure: random over the full 0..smax range (ties at
    every level once smax is small), dense binary ties, and all-equal."""
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    m = draw(st.integers(1, 4))
    p = draw(st.integers(1, 13))  # deliberately non-divisible, down to 1
    bw = draw(st.integers(1, 37))
    smax = draw(st.integers(0, 8))
    style = draw(st.integers(0, 2))  # 0 random, 1 dense ties, 2 all-equal

    def scores(shape):
        if style == 2:
            return np.full(shape, smax, np.int32)
        hi = min(1, smax) if style == 1 else smax
        return rng.integers(0, hi + 1, size=shape).astype(np.int32)

    ps = -np.sort(-scores((m, p)), axis=1)  # pool rows sorted desc
    live = rng.integers(0, p + 1, size=m)  # sentinel tail per row
    dead = np.arange(p)[None, :] >= live[:, None]
    ps = np.where(dead, -1, ps).astype(np.int32)
    # ids are free to duplicate across pool and block: both impls select
    # positionally, so parity must not depend on id uniqueness
    pi = np.where(dead, INT_MAX, rng.integers(0, 50, size=(m, p)))
    pd = np.where(dead, np.inf, rng.normal(size=(m, p))).astype(np.float32)
    bs = scores((m, bw))
    bi = rng.integers(0, 50, size=(m, bw)).astype(np.int32)
    bd = rng.normal(size=(m, bw)).astype(np.float32)
    return ps, pd, pi.astype(np.int32), bs, bd, bi, smax


@given(_sorted_pool_and_block())
@settings(max_examples=30)
def test_counting_merge_bitwise_equals_topk(case):
    """The counting-select merge is a drop-in for the lax.top_k baseline:
    bit-identical pools for every tie structure (ties at every score
    level, all-equal scores, duplicate ids across pool and block), pool
    widths down to p=1, and non-divisible block widths — and
    ``impl="auto"`` resolves to it exactly when the scores are declared
    integer-ranged."""
    ps, _pd, pi, bs, _bd, bi, smax = case
    args = tuple(map(jnp.asarray, (ps, pi, bs, bi)))
    want = merge_topk_pool(*args, impl="topk")
    got = merge_topk_pool(*args, impl="counting", smax=smax)
    auto = merge_topk_pool(*args, impl="auto", smax=smax)
    for g, a, w in zip(got, auto, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(g))


@given(_sorted_pool_and_block())
@settings(max_examples=20)
def test_counting_merge_with_dists_bitwise_equals_topk(case):
    """Same contract for the fused engine's joint (score, dist, id) pool:
    the carried exact distances ride the identical selection."""
    ps, pd, pi, bs, bd, bi, smax = case
    args = tuple(map(jnp.asarray, (ps, pd, pi, bs, bd, bi)))
    want = merge_topk_pool_with_dists(*args, impl="topk")
    got = merge_topk_pool_with_dists(*args, impl="counting", smax=smax)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@st.composite
def _padded_batch(draw):
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    m = draw(st.integers(1, 9))
    n = draw(st.integers(2, 24))
    d = draw(st.integers(2, 16))
    q = rng.normal(size=(m, d)).astype(np.float32)
    x = rng.normal(size=(n, d)).astype(np.float32)
    return q, x, m


@given(_padded_batch())
@settings(max_examples=15)
def test_bucket_padding_never_changes_rowwise_distances(case):
    """The serving-path distance impl is bitwise invariant to the zero rows
    :func:`batch_bucket` padding appends — the property that makes padded
    engine buckets answer exactly like the unpadded batch (and therefore
    padding can never change a top-k result)."""
    q, x, m = case
    b = batch_bucket(m)
    assert b >= m
    q_pad = np.zeros((b, q.shape[1]), np.float32)
    q_pad[:m] = q
    for metric in ("l2", "l1"):
        want = np.asarray(pairwise_dist(jnp.asarray(q), jnp.asarray(x), metric, impl="rowwise"))
        got = np.asarray(pairwise_dist(jnp.asarray(q_pad), jnp.asarray(x), metric, impl="rowwise"))
        np.testing.assert_array_equal(got[:m], want, err_msg=metric)


@st.composite
def _histogram(draw):
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    n_sizes = draw(st.integers(1, 12))
    max_buckets = draw(st.integers(1, 8))
    sizes = rng.integers(1, 200, size=n_sizes)
    return {int(s): int(rng.integers(1, 50)) for s in sizes}, max_buckets


@given(_histogram())
@settings(max_examples=40)
def test_autoscale_buckets_covers_observed_max(case):
    hist, max_buckets = case
    buckets = autoscale_buckets(hist, max_buckets)
    assert len(buckets) <= max_buckets
    assert max(buckets) >= max(hist), (buckets, hist)
    assert buckets == tuple(sorted(buckets))
    # every observed size lands in a configured bucket, never the
    # power-of-two overflow rule
    for msize in hist:
        assert batch_bucket(msize, buckets) in buckets
    # never worse than the trivial single-bucket cover, and exact when the
    # budget covers every distinct size
    waste = padding_waste(hist, buckets)
    assert waste <= padding_waste(hist, (max(hist),))
    if max_buckets >= len(hist):
        assert waste == 0, (buckets, hist)


def test_autoscale_buckets_empty_histogram_is_fallback():
    assert autoscale_buckets({}, 4) == tuple(sorted(DEFAULT_BATCH_BUCKETS))
    assert autoscale_buckets({}, 4, fallback=(4, 16)) == (4, 16)
