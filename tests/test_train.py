"""Training substrate tests: optimizer, checkpoint/restart, compression,
data pipeline determinism, straggler monitor."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import reduced_config
from repro.data.lm_data import LMDataConfig, SyntheticLM
from repro.models import Model
from repro.train import checkpoint as CKPT
from repro.train.compression import (
    compressed_grad_allreduce,
    dequantize_int8,
    quantize_int8,
)
from repro.train.optimizer import OptConfig, apply_gradients, init_opt_state, lr_at
from repro.train.resilience import FailureInjector, StepTimer, run_with_restarts
from repro.train.train_step import make_train_step


def test_adamw_minimises_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0, 1.5])}
    state = init_opt_state(params)
    cfg = OptConfig(lr=0.1, warmup_steps=0, total_steps=200, weight_decay=0.0)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = apply_gradients(params, grads, state, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(lr_at(cfg, jnp.asarray(s))) for s in range(100)]
    assert lrs[0] == 0.0
    assert lrs[10] == pytest.approx(1.0, rel=0.05)
    assert lrs[-1] == pytest.approx(0.1, abs=0.02)
    assert max(lrs) <= 1.0 + 1e-6


def test_grad_clipping_caps_update_norm():
    params = {"w": jnp.zeros(4)}
    state = init_opt_state(params)
    cfg = OptConfig(lr=1e-3, warmup_steps=0, clip_norm=1.0)
    _, _, metrics = apply_gradients(params, {"w": jnp.full(4, 100.0)}, state, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_train_loss_decreases():
    # The schedule itself has no off-by-one (warmup ramps 1..w-1, hits full
    # lr exactly at step w, cosine reaches min_lr at total_steps); the old
    # version of this test stopped at step 40 of a total_steps=60 schedule,
    # mid-decay, and missed the 1.0-loss-drop bar by 3e-4.  Run the budget
    # the OptConfig declares so the decay completes.
    cfg = dataclasses.replace(reduced_config("granite-3-2b"), n_layers=2)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    opt_state = init_opt_state(params)
    step = jax.jit(make_train_step(model, OptConfig(lr=3e-3, warmup_steps=5,
                                                    total_steps=60)))
    data = SyntheticLM(LMDataConfig(cfg.vocab_size, 64, 8, seed=0))
    losses = []
    for s in range(60):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 1.0, f"no learning: {losses[0]} -> {losses[-1]}"


def test_micro_batching_matches_full_batch():
    cfg = dataclasses.replace(reduced_config("granite-3-2b"), n_layers=2)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    data = SyntheticLM(LMDataConfig(cfg.vocab_size, 32, 8, seed=1))
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    s1 = make_train_step(model, OptConfig(), micro_steps=1)
    s2 = make_train_step(model, OptConfig(), micro_steps=4)
    _, _, m1 = jax.jit(s1)(params, init_opt_state(params), batch)
    _, _, m2 = jax.jit(s2)(params, init_opt_state(params), batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=2e-2)


# ------------------------------ checkpoint ----------------------------------


def test_checkpoint_roundtrip(tmp_path):
    params = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    opt = init_opt_state(params)
    CKPT.save(tmp_path, 7, params=params, opt_state=opt, extra={"loss": 1.5})
    assert CKPT.latest_step(tmp_path) == 7
    step, p2, o2, extra = CKPT.restore(tmp_path, params_like=params, opt_state_like=opt)
    assert step == 7 and extra["loss"] == 1.5
    np.testing.assert_array_equal(np.asarray(p2["a"]), np.asarray(params["a"]))
    np.testing.assert_array_equal(
        np.asarray(o2["mu"]["b"]["c"]), np.asarray(opt["mu"]["b"]["c"])
    )


def test_checkpoint_keep_prunes(tmp_path):
    params = {"a": jnp.ones(2)}
    for s in (1, 2, 3, 4):
        CKPT.save(tmp_path, s, params=params, keep=2)
    assert CKPT.all_steps(tmp_path) == [3, 4]


def test_checkpoint_async_then_restore(tmp_path):
    params = {"a": jnp.full(8, 3.0)}
    CKPT.save(tmp_path, 5, params=params, blocking=False)
    CKPT.wait_for_pending()
    step, p2, _, _ = CKPT.restore(tmp_path, params_like=params)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(p2["a"]), np.asarray(params["a"]))


def test_restart_resumes_and_matches_uninterrupted(tmp_path):
    """Failure mid-run + restart-from-checkpoint reproduces the uninterrupted
    run exactly (deterministic data + optimizer)."""
    import argparse
    from repro.launch.train import train_once

    def args(ckpt):
        return argparse.Namespace(
            arch="granite-3-2b", reduced=True, steps=12, global_batch=4,
            seq_len=32, d_model=0, micro_steps=1, lr=1e-3, seed=0,
            no_remat=False, ckpt_dir=str(ckpt), ckpt_every=5, log_every=100,
            mesh="none",
        )

    # uninterrupted
    a1 = args(tmp_path / "run1")
    train_once(a1)
    s1, p1, _, _ = CKPT.restore(
        tmp_path / "run1",
        params_like=jax.eval_shape(
            lambda k: Model(reduced_config("granite-3-2b")).init(k), jax.random.key(0)
        ),
    )

    # failing run: dies at step 8 (after the step-5 checkpoint), restarts
    inj = FailureInjector(fail_at=(8,))
    a2 = args(tmp_path / "run2")
    restarts = run_with_restarts(lambda: train_once(a2, injector=inj), max_restarts=2)
    assert restarts == 1
    s2, p2, _, _ = CKPT.restore(
        tmp_path / "run2",
        params_like=jax.eval_shape(
            lambda k: Model(reduced_config("granite-3-2b")).init(k), jax.random.key(0)
        ),
    )
    assert s1 == s2 == 12
    for l1, l2 in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-6)


# ------------------------------ compression ---------------------------------


def test_int8_quant_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=1000), jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.51 + 1e-6


def test_compressed_allreduce_with_error_feedback():
    """Mean over a fake axis via vmap(spmd_axis_name); EF residual shrinks
    the bias across steps."""
    rng = np.random.default_rng(1)
    n_dev = 4
    g = jnp.asarray(rng.normal(size=(n_dev, 64)), jnp.float32)

    def f(gi, ri):
        out, new_r = compressed_grad_allreduce({"g": gi}, "dp", {"g": ri})
        return out["g"], new_r["g"]

    mapped = jax.vmap(f, axis_name="dp")
    r0 = jnp.zeros((n_dev, 64), jnp.float32)
    out, r1 = mapped(g, r0)
    true_mean = np.asarray(g).mean(0)
    got = np.asarray(out[0])
    assert np.abs(got - true_mean).max() < 0.05  # int8 precision
    # residual captures exactly the local quantisation error
    assert np.abs(np.asarray(r1)).max() > 0


# ------------------------------ data pipeline --------------------------------


def test_lm_data_deterministic_and_shardable():
    cfg = LMDataConfig(1000, 16, 8, seed=3)
    d = SyntheticLM(cfg)
    b1, b2 = d.batch_at(5), d.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    sh0 = d.shard_rows(b1, 0, 4)
    sh3 = d.shard_rows(b1, 3, 4)
    np.testing.assert_array_equal(sh0["tokens"], b1["tokens"][:2])
    np.testing.assert_array_equal(sh3["tokens"], b1["tokens"][6:])
    # labels are next tokens
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_step_timer_flags_stragglers():
    import time

    t = StepTimer(alpha=0.5, threshold=1.5)
    for _ in range(3):
        t.start()
        time.sleep(0.005)
        t.stop()
    t.start()
    time.sleep(0.05)
    dt = t.stop()
    assert t.flagged == 1 and t.is_straggler(dt)
