"""Async pipelined AnnServer: answers identical to sync, honest accounting,
fault isolation across the in-flight window, zero retraces under mixed-k
replay — the serving contracts the benchmark suite's numbers stand on."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import EnginePolicy, SuCoConfig, SuCoEngine, build_index
from repro.data import make_dataset
from repro.serve.ann import AnnRequest, AnnServer, AsyncAnnServer, latency_summary

CFG = SuCoConfig(n_subspaces=8, sqrt_k=16, kmeans_iters=4, seed=0)
POLICY_BUCKETS = (4, 16)


@pytest.fixture(scope="module")
def ds():
    return make_dataset("gaussian_mixture", 4000, 32, m=40, k=10, seed=0)


@pytest.fixture(scope="module")
def index(ds):
    return build_index(jnp.asarray(ds.x), CFG)


def _engine(ds, index):
    return SuCoEngine(
        jnp.asarray(ds.x), index,
        EnginePolicy(alpha=0.05, beta=0.02, batch_buckets=POLICY_BUCKETS),
    )


def _mixed_requests(ds, ks=(10, 10, 5, 10, 5, 5, 10, 5, 10, 10, 5, 10)):
    return [AnnRequest(i, ds.queries[i], k=k) for i, k in enumerate(ks)]


def test_async_results_equal_sync_modulo_permutation(ds, index):
    """Same trace through both step disciplines: the completed sets hold the
    same rids, and every request's answer is bit-identical — completion
    order is the only thing pipelining may permute."""
    engine = _engine(ds, index)
    engine.warmup(batch_sizes=(1, 4, 16), ks=(5, 10))
    sync = AnnServer(engine, max_batch=4)
    sync.submit_many(_mixed_requests(ds))
    sync.run_until_drained()
    pipelined = AsyncAnnServer(engine, max_batch=4, depth=2)
    pipelined.submit_many(_mixed_requests(ds))
    pipelined.run_until_drained()

    by_rid_sync = {r.rid: r for r in sync.completed}
    by_rid_async = {r.rid: r for r in pipelined.completed}
    assert set(by_rid_sync) == set(by_rid_async)
    for rid, rs in by_rid_sync.items():
        ra = by_rid_async[rid]
        assert ra.k == rs.k and ra.done and rs.done
        np.testing.assert_array_equal(ra.ids, rs.ids, err_msg=f"rid {rid}")
        np.testing.assert_array_equal(ra.dists, rs.dists, err_msg=f"rid {rid}")
    # the micro-batch schedule itself is identical (same queue dynamics);
    # only the retire points differ
    assert [(s.k, s.n_requests) for s in pipelined.steps] == [
        (s.k, s.n_requests) for s in sync.steps
    ]


def test_async_latency_accounting_is_monotone(ds, index):
    """Per request: admission <= dispatch <= materialisation, the
    queue/exec split tiles the total exactly, and the summary surfaces
    the split."""
    engine = _engine(ds, index)
    engine.warmup(batch_sizes=(1, 4), ks=(5, 10))
    server = AsyncAnnServer(engine, max_batch=4, depth=2)
    server.submit_many(_mixed_requests(ds))
    done = server.run_until_drained()
    assert len(done) == 12
    for r in done:
        assert r.t_submit <= r.t_start <= r.t_done, r.rid
        assert r.queue_s >= 0 and r.exec_s >= 0
        np.testing.assert_allclose(r.queue_s + r.exec_s, r.latency_s, rtol=1e-9)
    s = latency_summary(done)
    assert s["queue_p99_ms"] >= s["queue_p50_ms"] >= 0.0
    assert s["exec_p99_ms"] >= s["exec_p50_ms"] >= 0.0
    # steps record the dispatch/step split and stay within the window
    for rec in server.steps:
        assert 0.0 <= rec.dispatch_s <= rec.step_s


def test_async_malformed_request_does_not_sink_pipelined_batches(ds, index):
    """A malformed micro-batch fails at dispatch, while a healthy batch
    already in flight — and healthy batches dispatched after it — still
    deliver results."""
    engine = _engine(ds, index)
    n = ds.x.shape[0]
    server = AsyncAnnServer(engine, max_batch=4, depth=2)
    server.submit(AnnRequest(0, ds.queries[0], k=10))  # in flight first
    server.submit(AnnRequest(1, ds.queries[1], k=n + 1))  # malformed k
    server.submit(AnnRequest(2, ds.queries[2], k=10))  # dispatched after
    done = server.run_until_drained()
    assert len(done) == 3 and not server.queue and server.inflight == 0
    by_rid = {r.rid: r for r in done}
    assert not by_rid[1].done and "k=" in by_rid[1].error
    assert by_rid[1].t_done >= by_rid[1].t_start
    for rid in (0, 2):
        assert by_rid[rid].done and by_rid[rid].error is None, rid
        want = engine.query(ds.queries[rid], k=10)
        np.testing.assert_array_equal(by_rid[rid].ids, np.asarray(want.ids))
    assert latency_summary(done)["n_requests"] == 2  # only the healthy ones


def test_async_zero_retraces_under_mixed_k_replay(ds, index):
    """The serving invariant across the pipeline: a warmup covering the
    (bucket, k) mix means no step of a mixed-k replay can compile."""
    engine = _engine(ds, index)
    engine.warmup(batch_sizes=(1, 2, 3, 4), ks=(5, 10))
    warm = engine.compile_count
    server = AsyncAnnServer(engine, max_batch=4, depth=2)
    rng = np.random.default_rng(0)
    server.submit_many(
        [AnnRequest(i, ds.queries[i], k=int(rng.choice([5, 10]))) for i in range(40)]
    )
    server.run_until_drained()
    assert engine.compile_count == warm, "async server retraced after warmup"
    assert [s.compile_count for s in server.steps] == [warm] * len(server.steps)
    assert len(server.completed) == 40


def test_async_inflight_window_is_bounded(ds, index):
    """The pipeline never holds more than ``depth`` unmaterialised
    micro-batches — dispatch past the window forces a retire."""
    engine = _engine(ds, index)
    engine.warmup(batch_sizes=(1,), ks=(10,))
    for depth in (1, 2, 3):
        server = AsyncAnnServer(engine, max_batch=1, depth=depth)
        server.submit_many([AnnRequest(i, ds.queries[i], k=10) for i in range(8)])
        seen = 0
        while server.queue:
            server.step()
            seen = max(seen, server.inflight)
            assert server.inflight <= depth
        assert seen == depth  # the window actually fills
        server.flush()
        assert server.inflight == 0 and len(server.completed) == 8
    with pytest.raises(ValueError, match="depth"):
        AsyncAnnServer(engine, depth=0)


def test_latency_summary_empty_is_zeroed(ds):
    """Regression: an empty (or all-failed) request set used to crash
    np.percentile; it must return the full zeroed key set instead so report
    consumers can index unconditionally."""
    keys = {
        "n_requests", "qps", "p50_ms", "p99_ms", "mean_ms", "max_ms",
        "queue_p50_ms", "queue_p99_ms", "exec_p50_ms", "exec_p99_ms",
        "n_shed", "n_expired", "n_failed", "n_degraded",
        "degraded_fraction", "deadline_hit_rate", "quality_bound_min",
    }
    # deadline_hit_rate / quality_bound_min are vacuously 1.0 on an empty
    # set (no deadline missed, no bound violated), not 0.0.
    vacuous = {"deadline_hit_rate", "quality_bound_min"}
    for requests in ([], [AnnRequest(0, ds.queries[0], k=10)]):  # none done
        s = latency_summary(requests)
        assert set(s) == keys
        assert s["n_requests"] == 0
        assert all(s[k] == 0.0 for k in keys - {"n_requests"} - vacuous)
        assert all(s[k] == 1.0 for k in vacuous)
