"""Per-architecture smoke tests (reduced same-family configs, CPU):
one forward/train step — output shapes + finite values; prefill/decode
consistency for a representative subset (full sweep in scripts)."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.models import Model, backbone

_RNG = np.random.default_rng(0)


def _batch(cfg, b=2, s=32):
    batch = {
        "tokens": jnp.asarray(_RNG.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.asarray(_RNG.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
    }
    if cfg.family == "audio":
        batch["extras"] = jnp.asarray(
            _RNG.normal(size=(b, cfg.encoder_seq, cfg.d_model)), jnp.bfloat16
        )
    if cfg.family == "vlm":
        batch["extras"] = jnp.asarray(
            _RNG.normal(size=(b, cfg.vision_tokens, cfg.d_model)), jnp.bfloat16
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    hidden = backbone.forward_hidden(
        cfg, params, batch["tokens"], extras=batch.get("extras"), remat=False
    )
    assert hidden.shape == (2, 32, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden.astype(jnp.float32))))
    loss, grads = jax.jit(jax.value_and_grad(lambda p: model.loss(p, batch)))(params)
    assert bool(jnp.isfinite(loss))
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch", ["qwen1.5-4b", "gemma2-9b", "rwkv6-1.6b",
                                  "zamba2-1.2b", "mixtral-8x7b", "whisper-large-v3"])
def test_prefill_decode_matches_forward(arch):
    cfg = dataclasses.replace(
        reduced_config(arch), dtype="float32", capacity_factor=8.0
    )
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    b, s = 2, 17
    batch = _batch(cfg, b, s + 1)
    toks = batch["tokens"]
    extras = batch.get("extras")
    if extras is not None:
        extras = extras.astype(jnp.float32)
    hidden = backbone.forward_hidden(cfg, params, toks, extras=extras, remat=False)
    want = backbone.logits_for_position(cfg, params, hidden[:, -1])
    from repro.models import prefill as P

    lp, cache = P.prefill(cfg, params, toks[:, :s], extras=extras, max_seq=s + 4,
                          cache_dtype=jnp.float32)
    got, _ = model.decode_step(params, cache, toks[:, s], jnp.asarray(s, jnp.int32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=1e-3)


def test_remat_matches_no_remat():
    cfg = dataclasses.replace(reduced_config("granite-3-2b"), dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.key(2))
    batch = _batch(cfg)
    l1 = model.loss(params, batch, remat=False)
    l2 = model.loss(params, batch, remat=True)
    assert float(l1) == pytest.approx(float(l2), rel=1e-5)


def test_param_shapes_abstract_no_alloc():
    cfg = get_config("mixtral-8x7b")  # 47B params -- must NOT allocate
    shapes = Model(cfg).param_shapes()
    total = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    assert total > 4e10
    assert all(isinstance(s, jax.ShapeDtypeStruct) for s in jax.tree.leaves(shapes))


def test_gemma2_local_global_masking_differs():
    """A token beyond the local window must attend differently in local vs
    global layers: perturbing a distant token changes global-layer output
    but not a pure local stack's."""
    cfg = dataclasses.replace(
        reduced_config("gemma2-9b"), n_layers=2, dtype="float32", local_window=4
    )
    model = Model(cfg)
    params = model.init(jax.random.key(3))
    toks = jnp.asarray(_RNG.integers(0, cfg.vocab_size, (1, 16)), jnp.int32)
    h = backbone.forward_hidden(cfg, params, toks, remat=False)
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
    h2 = backbone.forward_hidden(cfg, params, toks2, remat=False)
    # layer 1 is global -> distant perturbation must propagate to last token
    assert float(jnp.abs(h[0, -1] - h2[0, -1]).max()) > 0


def test_vocab_padding_masked_in_loss():
    cfg = reduced_config("granite-3-2b")
    assert cfg.padded_vocab % 256 == 0 and cfg.padded_vocab >= cfg.vocab_size
    model = Model(cfg)
    params = model.init(jax.random.key(4))
    batch = _batch(cfg)
    logits, _ = model.prefill(params, batch["tokens"], max_seq=40)
    # padded tail must never win argmax
    assert int(jnp.argmax(logits, -1).max()) < cfg.vocab_size
