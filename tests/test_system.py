"""End-to-end behaviour tests for the whole system:
index -> query -> recall; serve (prefill + continuous batching decode);
sharding rules; dry-run machinery on a debug scale."""


import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.core import SuCoConfig, build_index, suco_query
from repro.core.theory import suggest_parameters, subspace_statistics
from repro.data import make_dataset, recall
from repro.models import Model, SHAPES, input_specs


def test_ann_pipeline_end_to_end():
    """The paper's full pipeline: stats -> suggested params -> index ->
    query -> high recall."""
    ds = make_dataset("gaussian_mixture", 8000, 64, m=24, k=10)
    m, s = subspace_statistics(ds.x, ds.queries[0], 8)
    sugg = suggest_parameters(n=8000, d=64, k=10, m=m, sigma=s)
    cfg = SuCoConfig(n_subspaces=sugg["n_subspaces"], sqrt_k=24, kmeans_iters=8)
    idx = build_index(jnp.asarray(ds.x), cfg)
    res = suco_query(
        jnp.asarray(ds.x), idx, jnp.asarray(ds.queries),
        k=10, alpha=max(sugg["alpha"], 0.05), beta=0.02,
    )
    assert recall(np.asarray(res.ids), ds.gt_ids) >= 0.9


def test_serve_continuous_batching():
    from repro.launch.serve import Request, Server

    cfg = reduced_config("granite-3-2b")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    reqs = [
        Request(i, rng.integers(0, cfg.vocab_size, 8).astype(np.int32))
        for i in range(5)
    ]
    server = Server(model, params, n_slots=2, max_seq=24)
    done = server.run(reqs, gen_len=4)
    assert len(done) == 5
    assert all(len(r.generated) == 4 for r in done)
    assert all(0 <= t < cfg.vocab_size for r in done for t in r.generated)


def test_input_specs_cover_all_cells():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            specs = input_specs(cfg, shape)
            leaves = jax.tree.leaves(specs)
            assert leaves, (arch, shape.name)
            assert all(isinstance(leaf, jax.ShapeDtypeStruct) for leaf in leaves)
            if shape.kind == "decode":
                assert "cache" in specs


def test_sharding_rules_fit_every_arch():
    """param_specs must produce divisibility-safe specs for the production
    mesh shapes on every architecture (checked against a tiny stand-in mesh
    object — no devices needed)."""
    import math
    from repro.launch import shardings as SH

    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        shapes = Model(cfg).param_shapes()
        specs = SH.param_specs(cfg, FakeMesh(), shapes)

        def check(spec, leaf):
            for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 10):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                size = math.prod(FakeMesh.shape[a] for a in axes)
                assert dim % size == 0, (arch, spec, leaf.shape)

        jax.tree.map(
            check, specs, shapes,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
        )


def test_dryrun_skip_rule_matches_design():
    from repro.launch.dryrun import should_skip

    expect_runs = {"rwkv6-1.6b", "zamba2-1.2b", "gemma2-9b", "mixtral-8x7b"}
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        skipped = should_skip(cfg, SHAPES["long_500k"]) is not None
        assert skipped == (arch not in expect_runs), arch
        assert should_skip(cfg, SHAPES["train_4k"]) is None


def test_hlo_analysis_on_synthetic_module():
    from repro.launch.hlo_analysis import analyze_hlo

    hlo = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %lhs = f32[8,8] get-tuple-element(%p), index=1
  %d = f32[8,8] dot(%lhs, %lhs), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8] all-reduce(%d), replica_groups={}
  ROOT %t = (s32[], f32[8,8]) tuple(%p, %ar)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  ROOT %c = pred[] constant(true)
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %w = (s32[], f32[8,8]) while(%a), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""
    res = analyze_hlo(hlo)
    # 5 iterations x (2*8*8*8 flops, 256-byte all-reduce)
    assert res["flops"] == 5 * 2 * 8 * 8 * 8
    assert res["collective_bytes"] == 5 * 8 * 8 * 4
    assert res["per_kind_bytes"]["all-reduce"] == 5 * 256
