"""Durability of the mutable serving index (ISSUE 10): WAL record codec
round-trips, torn-tail truncation, group-commit semantics, checksummed
artifact-v3 snapshots with sidecar state (external keys survive a plain
save/load), snapshot+replay recovery equivalence, the off-thread re-index
prepare with failure containment, and the full crash-point drill sweep —
every instrumented boundary, both fsync policies, bit-identical recovery
of the acknowledged prefix with zero retraces."""

import threading
import time

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.suco import (
    ArtifactError,
    EnginePolicy,
    SuCoConfig,
    SuCoEngine,
    build_index,
)
from repro.data import make_dataset
from repro.serve.ann import AnnServer, DegradationLadder
from repro.serve.chaos import (
    CRASH_POINTS,
    CrashInjector,
    drill_steps,
    recovery_drill,
)
from repro.serve.durability import (
    Durability,
    DurabilityConfig,
    RecoveryError,
    WalRecord,
    WriteAheadLog,
    decode_records,
    encode_record,
    fingerprint_diff,
    load_serving_stack,
    recover,
    state_fingerprint,
)
from repro.serve.mutation import MutationManager, ReindexInProgressError

N, D, K = 500, 16, 5
CFG = SuCoConfig(n_subspaces=4, sqrt_k=8, kmeans_iters=2, seed=0)


@pytest.fixture(scope="module")
def ds():
    return make_dataset("gaussian_mixture", N, D, m=10, k=5, seed=0)


def _build_stack(ds, root, injector=None, *, fsync="group", levels=1,
                 capacity=N + 200, start_worker=False, config=None):
    idx = build_index(jnp.asarray(ds.x), CFG)
    engine = SuCoEngine(
        jnp.asarray(ds.x), idx, EnginePolicy(alpha=0.1, beta=0.05),
        capacity=capacity,
    )
    ladder = DegradationLadder(engine, levels=levels, stats_seed=0)
    server = AnnServer(engine, ladder=ladder)
    ladder.warmup([1], [K])
    manager = MutationManager(server, CFG, stats_seed=0)
    dur = Durability(
        root,
        config if config is not None else DurabilityConfig(fsync=fsync),
        crash=injector,
        start_worker=start_worker,
    ).attach(server, manager)
    return server, manager, dur


def _rows(rng, b):
    return rng.standard_normal((b, D)).astype(np.float32)


# ---------------------------------------------------------------------------
# WAL record codec (hypothesis property: encode/decode identity)
# ---------------------------------------------------------------------------


def _random_record(rng: np.random.Generator, kind_i: int, seq: int) -> WalRecord:
    kind = ("insert", "delete", "reindex")[kind_i]
    if kind == "insert":
        b, d = int(rng.integers(0, 6)), int(rng.integers(1, 9))
        return WalRecord(
            kind=kind,
            seq=seq,
            keys=rng.integers(0, 1 << 40, size=b).astype(np.int64),
            slots=rng.integers(0, 1 << 20, size=b).astype(np.int64),
            rows=rng.standard_normal((b, d)).astype(np.float32),
        )
    if kind == "delete":
        b = int(rng.integers(0, 8))
        return WalRecord(
            kind=kind, seq=seq,
            slots=rng.integers(0, 1 << 20, size=b).astype(np.int64),
        )
    return WalRecord(
        kind=kind, seq=seq,
        capacity=int(rng.integers(1, 1 << 30)),
        min_free=int(rng.integers(0, 1 << 10)),
    )


@settings(max_examples=40)
@given(
    seed=st.integers(min_value=0, max_value=100_000),
    kind_i=st.integers(min_value=0, max_value=2),
    seq=st.integers(min_value=0, max_value=1 << 50),
)
def test_wal_record_roundtrip(seed, kind_i, seq):
    rng = np.random.default_rng(seed)
    rec = _random_record(rng, kind_i, seq)
    buf = encode_record(rec)
    out, end = decode_records(buf)
    assert end == len(buf)
    assert out == [rec]


@settings(max_examples=40)
@given(
    seed=st.integers(min_value=0, max_value=100_000),
    n_records=st.integers(min_value=0, max_value=6),
    cut_frac=st.floats(min_value=0.0, max_value=1.0),
)
def test_wal_torn_tail_any_prefix_decodes_to_record_prefix(
    seed, n_records, cut_frac
):
    """Torn-tail tolerance as a property: cutting a valid log at ANY byte
    boundary recovers exactly a prefix of the records — never a corrupt
    record, never a record out of order."""
    rng = np.random.default_rng(seed)
    records = [
        _random_record(rng, int(rng.integers(0, 3)), i)
        for i in range(n_records)
    ]
    buf = b"".join(encode_record(r) for r in records)
    cut = int(round(cut_frac * len(buf)))
    out, end = decode_records(buf[:cut])
    assert end <= cut
    assert out == records[: len(out)]
    # and the boundary is exact: decoding from `end` onward in the FULL
    # log yields precisely the remaining records
    rest, _ = decode_records(buf, end)
    assert rest == records[len(out):]


def test_wal_rejects_bad_crc_and_unknown_kind():
    rec = WalRecord(kind="delete", seq=0, slots=np.asarray([1], np.int64))
    buf = bytearray(encode_record(rec))
    buf[-1] ^= 0xFF  # flip a payload byte: CRC must catch it
    out, end = decode_records(bytes(buf))
    assert out == [] and end == 0
    with pytest.raises(ValueError, match="unknown WAL record kind"):
        encode_record(WalRecord(kind="upsert"))


# ---------------------------------------------------------------------------
# WriteAheadLog file behavior
# ---------------------------------------------------------------------------


def test_wal_reopen_restores_counters_and_truncates_torn_tail(tmp_path):
    path = tmp_path / "wal.log"
    wal = WriteAheadLog(path, fsync="off")
    for i in range(3):
        seq = wal.append(WalRecord(kind="delete", slots=np.asarray([i], np.int64)))
        assert seq == i
    wal.close()
    # simulate a torn append: half a frame beyond the valid tail
    frame = encode_record(WalRecord(kind="delete", seq=3, slots=np.asarray([9], np.int64)))
    with open(path, "ab") as f:
        f.write(frame[: len(frame) // 2])
    wal2 = WriteAheadLog(path, fsync="off")
    assert wal2.next_seq == 3
    assert wal2.appended_seq == 2
    assert wal2.torn_bytes_dropped == len(frame) // 2
    # the torn bytes are gone from disk, and appends continue the sequence
    records, _, dropped = WriteAheadLog.read(path)
    assert dropped == 0 and [r.seq for r in records] == [0, 1, 2]
    assert wal2.append(WalRecord(kind="delete", slots=np.asarray([4], np.int64))) == 3
    wal2.close()


def test_wal_truncate_drops_covered_keeps_tail(tmp_path):
    wal = WriteAheadLog(tmp_path / "wal.log", fsync="off")
    for i in range(5):
        wal.append(WalRecord(kind="delete", slots=np.asarray([i], np.int64)))
    wal.truncate(2)
    records, _, _ = WriteAheadLog.read(tmp_path / "wal.log")
    assert [r.seq for r in records] == [3, 4]
    # appends after a truncation keep the global sequence
    assert wal.append(WalRecord(kind="delete", slots=np.asarray([9], np.int64))) == 5
    wal.close()


def test_wal_missing_file_and_bad_header():
    records, valid, dropped = WriteAheadLog.read("/nonexistent/wal.log")
    assert (records, valid, dropped) == ([], 0, 0)


def test_wal_bad_header_starts_fresh(tmp_path):
    p = tmp_path / "wal.log"
    p.write_bytes(b"garbage-not-a-wal-header")
    wal = WriteAheadLog(p, fsync="off")
    assert wal.torn_bytes_dropped == 24
    assert wal.append(WalRecord(kind="delete", slots=np.asarray([0], np.int64))) == 0
    wal.close()


def test_fsync_policy_validated(tmp_path):
    with pytest.raises(ValueError, match="fsync policy"):
        WriteAheadLog(tmp_path / "w.log", fsync="sometimes")
    with pytest.raises(ValueError, match="fsync policy"):
        DurabilityConfig(fsync="sometimes")
    with pytest.raises(ValueError, match="flush_interval_s"):
        DurabilityConfig(flush_interval_s=0.0)
    with pytest.raises(ValueError, match="snapshot_keep"):
        DurabilityConfig(snapshot_keep=0)


def test_group_commit_flush_semantics(tmp_path):
    wal = WriteAheadLog(tmp_path / "wal.log", fsync="group")
    wal.append(WalRecord(kind="delete", slots=np.asarray([0], np.int64)))
    assert wal.appended_seq == 0 and wal.synced_seq == -1  # framed, not synced
    assert wal.flush() is True
    assert wal.synced_seq == 0
    assert wal.flush() is False  # nothing dirty: no redundant fsync
    wal.close()
    # per-record policy: durable at the ack
    wal = WriteAheadLog(tmp_path / "wal2.log", fsync="always")
    wal.append(WalRecord(kind="delete", slots=np.asarray([0], np.int64)))
    assert wal.synced_seq == 0
    wal.close()


def test_maintenance_worker_flushes_in_background(tmp_path):
    wal = WriteAheadLog(tmp_path / "wal.log", fsync="group")
    dur_like_flush = wal.flush
    from repro.serve.durability import MaintenanceWorker

    worker = MaintenanceWorker(dur_like_flush, interval_s=0.005)
    try:
        wal.append(WalRecord(kind="delete", slots=np.asarray([0], np.int64)))
        deadline = time.monotonic() + 5.0
        while wal.synced_seq < 0 and time.monotonic() < deadline:
            time.sleep(0.005)
        assert wal.synced_seq == 0, "group-commit flush never ran"
    finally:
        worker.stop()
        wal.close()


def test_maintenance_worker_survives_failing_job_and_flush(tmp_path):
    from repro.serve.durability import MaintenanceWorker

    calls = []

    def flaky_flush():
        calls.append("flush")
        if len(calls) == 1:
            raise OSError("disk went away")
        return True

    worker = MaintenanceWorker(flaky_flush, interval_s=0.002)
    try:
        done = threading.Event()
        worker.submit(lambda: (_ for _ in ()).throw(RuntimeError("job boom")))
        worker.submit(done.set)
        assert done.wait(timeout=5.0), "worker died on a failing job"
    finally:
        worker.stop()


# ---------------------------------------------------------------------------
# artifact v3: content checksums + serving-state sidecar
# ---------------------------------------------------------------------------


def test_artifact_checksum_names_corrupted_key(ds, tmp_path):
    """The ISSUE-10 bugfix regression: a bit-flipped centroid block must
    fail loudly, naming the offending key — not silently serve wrong
    answers.  The rewrite keeps the zip layer consistent, so only the
    content checksum can catch it."""
    idx = build_index(jnp.asarray(ds.x), CFG)
    p = tmp_path / "index.npz"
    idx.save(p, CFG)
    blob = dict(np.load(p, allow_pickle=False))
    tampered = blob["centroids1"].copy()
    tampered.view(np.uint8)[3] ^= 0x01  # one flipped bit
    blob["centroids1"] = tampered
    np.savez(p, **blob)  # stale crc_centroids1 rides along
    from repro.core.suco import load_index_artifact

    with pytest.raises(ArtifactError, match="checksum mismatch.*'centroids1'"):
        load_index_artifact(p)


def test_artifact_v2_without_checksums_still_loads(ds, tmp_path):
    idx = build_index(jnp.asarray(ds.x), CFG)
    p = tmp_path / "index.npz"
    idx.save(p, CFG)
    blob = dict(np.load(p, allow_pickle=False))
    blob = {k: v for k, v in blob.items() if not k.startswith("crc_")}
    blob["version"] = np.asarray(2)
    np.savez(p, **blob)
    from repro.core.suco import load_index_artifact

    idx2, cfg2 = load_index_artifact(p)
    assert np.array_equal(np.asarray(idx.centroids1), np.asarray(idx2.centroids1))
    assert cfg2 == CFG


def test_save_stack_keys_survive_plain_save_load(ds, tmp_path):
    """Satellite: external ids survive a plain save/load with NO WAL —
    the artifact-v3 sidecar carries the MutationManager key table."""
    server, manager, dur = _build_stack(ds, tmp_path / "root")
    rng = np.random.default_rng(0)
    new_keys = manager.insert(_rows(rng, 4))
    manager.delete(np.asarray([0, 1, 2], np.int64))
    p = tmp_path / "stack.npz"
    manager.save(p)
    server2, manager2 = load_serving_stack(p)
    assert manager2 is not None
    assert np.array_equal(manager._keys, manager2._keys)
    assert manager2._next_key == manager._next_key
    diff = fingerprint_diff(
        state_fingerprint(server, manager), state_fingerprint(server2, manager2)
    )
    assert not diff, diff
    # the restored stack serves identical answers with zero retraces
    exe0 = server2.executables
    got = np.asarray(server2.engine.query(ds.x[7], k=K).ids)
    want = np.asarray(server.engine.query(ds.x[7], k=K).ids)
    assert np.array_equal(got, want)
    assert server2.executables == exe0
    # and keys keep translating: fresh inserts continue the key space
    k2 = manager2.insert(_rows(rng, 2))
    assert int(k2.min()) > int(new_keys.max())
    dur.close()


def test_load_serving_stack_rejects_bare_artifact(ds, tmp_path):
    idx = build_index(jnp.asarray(ds.x), CFG)
    p = tmp_path / "bare.npz"
    idx.save(p, CFG)
    with pytest.raises(ArtifactError, match="sidecar"):
        load_serving_stack(p)


# ---------------------------------------------------------------------------
# snapshot + WAL replay recovery (hypothesis property: equivalence)
# ---------------------------------------------------------------------------

_DS_CACHE: dict = {}


def _module_ds():
    if "ds" not in _DS_CACHE:
        _DS_CACHE["ds"] = make_dataset("gaussian_mixture", N, D, m=10, k=5, seed=0)
    return _DS_CACHE["ds"]


@settings(max_examples=4)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    snap_at=st.integers(min_value=0, max_value=4),
)
def test_snapshot_replay_equivalence(seed, snap_at):
    """Property: for a random acknowledged mutation sequence with a
    snapshot at a random position, recovery (snapshot + WAL tail replay)
    reconstructs the exact in-memory state — fingerprints bit-identical,
    external keys included."""
    import shutil
    import tempfile

    ds = _module_ds()
    root = tempfile.mkdtemp()
    try:
        server, manager, dur = _build_stack(ds, root, fsync="group")
        rng = np.random.default_rng(seed)
        ops = []
        for _ in range(5):
            o = rng.random()
            if o < 0.55:
                ops.append(lambda: manager.insert(_rows(rng, int(rng.integers(1, 4)))))
            elif o < 0.9:
                ops.append(lambda: manager.delete(
                    rng.choice(manager.live_keys(), size=2, replace=False)
                ))
            else:
                ops.append(lambda: manager.reindex())
        for i, op in enumerate(ops):
            if i == snap_at:
                dur.snapshot()
            op()
        dur.abandon()  # no orderly close: replay does the work
        res = recover(root, start_worker=False)
        diff = fingerprint_diff(
            state_fingerprint(server, manager),
            state_fingerprint(res.server, res.manager),
        )
        assert not diff, f"recovery diverged on {diff}"
        res.durability.close()
    finally:
        shutil.rmtree(root)


def test_recovered_stack_keeps_logging_and_recovers_again(ds, tmp_path):
    root = tmp_path / "root"
    server, manager, dur = _build_stack(ds, root)
    rng = np.random.default_rng(3)
    manager.insert(_rows(rng, 3))
    dur.snapshot()
    dur.abandon()
    res = recover(root, start_worker=False)
    # the recovered stack continues the same WAL generation
    res.manager.insert(_rows(rng, 2))
    res.manager.delete(np.asarray([5], np.int64))
    res.durability.abandon()
    res2 = recover(root, start_worker=False)
    diff = fingerprint_diff(
        state_fingerprint(res.server, res.manager),
        state_fingerprint(res2.server, res2.manager),
    )
    assert not diff, diff
    assert res2.report.replayed == 2
    res2.durability.close()


def test_recover_requires_a_snapshot(tmp_path):
    (tmp_path / "root").mkdir()
    with pytest.raises(RecoveryError, match="no valid snapshot"):
        recover(tmp_path / "root", start_worker=False)
    with pytest.raises(RecoveryError, match="not a durability root"):
        recover(tmp_path / "nope", start_worker=False)


def test_recover_falls_back_past_corrupt_newest_snapshot(ds, tmp_path):
    """Bit-rot on the newest snapshot: recovery falls back to the previous
    one and replays the longer WAL tail — zero acknowledged records lost,
    because the WAL is only truncated to the OLDEST retained snapshot."""
    root = tmp_path / "root"
    server, manager, dur = _build_stack(ds, root)
    rng = np.random.default_rng(4)
    manager.insert(_rows(rng, 3))
    dur.snapshot()
    manager.delete(np.asarray([1, 2], np.int64))
    dur.snapshot()
    dur.abandon()
    snaps = sorted(root.glob("snapshot-*.npz"))
    assert len(snaps) == 2
    # corrupt the newest (truncate it mid-file: zip layer catches it)
    newest = snaps[-1]
    newest.write_bytes(newest.read_bytes()[:200])
    res = recover(root, start_worker=False)
    assert res.report.snapshots_skipped == 1
    assert res.report.snapshot_path == str(snaps[0])
    assert res.report.replayed >= 1  # the delete came back from the WAL
    diff = fingerprint_diff(
        state_fingerprint(server, manager),
        state_fingerprint(res.server, res.manager),
    )
    assert not diff, diff
    res.durability.close()


def test_bare_swap_checkpoints_via_note_swap(ds, tmp_path):
    """A swap outside the manager's replayable reindex path is out-of-band
    state: the durability layer must checkpoint it immediately."""
    from repro.serve.mutation import warm_like

    server, manager, dur = _build_stack(ds, tmp_path / "root")
    n_before = len(list((tmp_path / "root").glob("snapshot-*.npz")))
    x2 = jnp.asarray(ds.x[:400])
    idx2 = build_index(x2, CFG)
    succ = SuCoEngine(
        x2, idx2, EnginePolicy(alpha=0.1, beta=0.05), capacity=600
    )
    ladder2 = DegradationLadder(succ, levels=1, stats_seed=0)
    for old_e, new_e in zip(server.ladder.engines, ladder2.engines):
        warm_like(new_e, old_e)
    server.swap(succ, ladder=ladder2)
    snaps = sorted((tmp_path / "root").glob("snapshot-*.npz"))
    assert len(snaps) == n_before + 1
    dur.abandon()
    res = recover(tmp_path / "root", start_worker=False)
    diff = fingerprint_diff(
        state_fingerprint(server, manager),
        state_fingerprint(res.server, res.manager),
    )
    assert not diff, diff
    res.durability.close()


# ---------------------------------------------------------------------------
# off-thread re-index prepare: containment + single flight
# ---------------------------------------------------------------------------


def test_reindex_async_happy_path_and_single_flight(ds, tmp_path):
    server, manager, dur = _build_stack(ds, tmp_path / "root")
    rng = np.random.default_rng(0)
    job = manager.reindex_async()
    with pytest.raises(ReindexInProgressError, match="pending"):
        manager.insert(_rows(rng, 1))
    with pytest.raises(ReindexInProgressError, match="pending"):
        manager.reindex()
    with pytest.raises(ReindexInProgressError, match="pending"):
        manager.reindex_async()
    assert manager.finish_reindex(timeout=120) is server.engine
    assert manager.reindexes == 1
    manager.insert(_rows(rng, 1))  # guard released
    dur.close()


def test_reindex_async_failure_leaves_incumbent_untouched(ds, tmp_path, monkeypatch):
    import repro.serve.mutation as mut

    server, manager, dur = _build_stack(ds, tmp_path / "root")
    before = state_fingerprint(server, manager)
    wal_before = dur.wal.appended_seq
    monkeypatch.setattr(
        mut, "build_index",
        lambda *a, **k: (_ for _ in ()).throw(RuntimeError("cluster blew up")),
    )
    manager.reindex_async()
    with pytest.raises(RuntimeError, match="cluster blew up"):
        manager.finish_reindex(timeout=120)
    # nothing mutated, nothing logged, guard released
    assert not fingerprint_diff(before, state_fingerprint(server, manager))
    assert dur.wal.appended_seq == wal_before
    assert manager.reindexes == 0
    monkeypatch.undo()
    manager.reindex()  # the next re-index proceeds normally
    assert manager.reindexes == 1
    dur.close()


def test_finish_without_pending_raises(ds, tmp_path):
    server, manager, dur = _build_stack(ds, tmp_path / "root")
    with pytest.raises(ValueError, match="no asynchronous re-index"):
        manager.finish_reindex()
    dur.close()


# ---------------------------------------------------------------------------
# the crash-drill sweep: every instrumented boundary, both fsync policies
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fsync", ["always", "group"])
@pytest.mark.parametrize("point", CRASH_POINTS)
def test_crash_drill_sweep(ds, tmp_path, point, fsync):
    """The ISSUE-10 acceptance criterion: kill at every instrumented
    write/rename/fsync boundary, recover, and the state is bit-identical
    to a crash-free replay of the acknowledged prefix — zero acknowledged
    mutations lost, zero retraces while serving the recovered surface,
    Theorem-2 floors agreeing with the reference."""
    rep = recovery_drill(
        tmp_path,
        lambda root, inj: _build_stack(ds, root, inj, fsync=fsync),
        drill_steps(D, seed=3),
        point,
        queries=ds.x[:4],
        k=K,
    )
    assert rep.fired, f"{point} was never reached by the drill script"
    assert rep.lost_acked == 0, rep
    assert rep.bit_identical, rep.fingerprint_diff
    assert rep.retraces_after_warmup == 0, rep
    assert rep.answers_match, rep
    assert rep.quality_bounds_match, rep


def test_drill_coverage_ledger(ds, tmp_path):
    """Un-armed, a full drill script crosses every instrumented boundary
    except the torn-append simulation (which only exists when armed) —
    the sweep above is therefore exhaustive, not vacuous."""
    from repro.serve.chaos import _apply_drill_step

    injector = CrashInjector()
    server, manager, dur = _build_stack(ds, tmp_path / "root", injector)
    for step in drill_steps(D, seed=3):
        _apply_drill_step(server, manager, dur, step)
    dur.close()
    reached = set(injector.reached)
    expected = set(CRASH_POINTS) - {"wal.append.torn", "wal.fsync.post"}
    # fsync.post fires on flush only when dirty (group) or per record
    # (always); the group-policy script reaches it via the explicit flush
    assert "wal.fsync.post" in reached
    assert expected <= reached, expected - reached
