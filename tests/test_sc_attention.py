"""Beyond-paper SC sparse attention: selection quality + exactness."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.sc_attention import (
    attention_mass_recall,
    sc_select_keys,
    sc_sparse_attention,
)


def _data(h=4, s=2048, hd=32, seed=0):
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.normal(size=(h, s, hd)), jnp.float32)
    values = jnp.asarray(rng.normal(size=(h, s, hd)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(h, hd)), jnp.float32) + keys[:, -1]
    return q, keys, values


def test_sc_selection_beats_random():
    q, keys, values = _data()
    n_keep = 128
    ids = sc_select_keys(q, keys, n_subspaces=4, alpha=0.05, n_keep=n_keep)
    mass = float(attention_mass_recall(q, keys, ids).mean())
    rng = np.random.default_rng(1)
    rnd = jnp.asarray(rng.choice(keys.shape[1], size=(keys.shape[0], n_keep),
                                 replace=False))
    mass_rnd = float(attention_mass_recall(q, keys, rnd).mean())
    assert mass > 3 * mass_rnd, (mass, mass_rnd)


def test_sc_sparse_attention_converges_to_exact():
    q, keys, values = _data()
    out_full_keep, ids = sc_sparse_attention(
        q, keys, values, n_subspaces=4, alpha=0.2, n_keep=keys.shape[1]
    )
    logits = jnp.einsum("hd,hsd->hs", q, keys) / np.sqrt(q.shape[-1])
    w = jax.nn.softmax(logits, axis=-1)
    exact = jnp.einsum("hs,hsd->hd", w, values)
    np.testing.assert_allclose(np.asarray(out_full_keep), np.asarray(exact),
                               atol=1e-4, rtol=1e-4)


def test_sc_mass_recall_monotone_in_budget():
    q, keys, values = _data(seed=2)
    masses = []
    for n_keep in (64, 256, 1024):
        _, ids = sc_sparse_attention(q, keys, values, n_subspaces=4,
                                     alpha=0.05, n_keep=n_keep)
        masses.append(float(attention_mass_recall(q, keys, ids).mean()))
    assert masses[0] <= masses[1] <= masses[2]
    # iid gaussian keys are the framework's worst case (LID == d); the
    # structured-cache demo reaches 0.98 — here 0.6+ at half the keys
    assert masses[2] > 0.6
