"""Fused single-pass query engine: exact parity with the dense reference
and the legacy streaming path, Pareto-prefilter soundness, tiling
autotuner invariants, and the serving-policy knobs that ride along
(traffic-histogram cap, sentinel-id clipping at the gather_rerank op
boundary)."""


import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (
    EnginePolicy,
    MemoryLimits,
    SuCoConfig,
    SuCoEngine,
    TileConfig,
    autotune_build_block_n,
    autotune_tiles,
    build_index,
    merge_topk_pool,
    suco_query,
    suco_query_fused,
    suco_query_streaming,
)
from repro.data import make_dataset

INT_MAX = np.iinfo(np.int32).max


@pytest.fixture(scope="module")
def small():
    ds = make_dataset("gaussian_mixture", 4000, 48, m=16, k=10, seed=0)
    x = jnp.asarray(ds.x)
    idx = build_index(x, SuCoConfig(n_subspaces=8, sqrt_k=24, kmeans_iters=8, seed=0))
    return ds, x, idx


def _assert_bitwise_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))


# --------------------------- parity suite -----------------------------------


@pytest.mark.parametrize(
    "tiles",
    [
        None,  # autotuned
        TileConfig(block_n=512, survivor_cap=64),
        TileConfig(block_n=333, survivor_cap=64),  # does not divide n=4000
        TileConfig(block_n=1000, survivor_cap=1),  # every chunk overflows
        TileConfig(block_n=4096, survivor_cap=4096),  # never overflows
        TileConfig(block_n=1_000_000, survivor_cap=128),  # single block > n
    ],
)
def test_fused_matches_dense_and_streaming_bitwise(small, tiles):
    """The acceptance contract: ids, distances and scores all bit-identical
    to both the dense reference and the legacy streaming engine, for
    autotuned and adversarial tilings (non-divisible chunks, a survivor
    cap that forces the full-width fallback on every chunk, one that never
    falls back, one block covering the whole dataset)."""
    ds, x, idx = small
    q = jnp.asarray(ds.queries)
    dense = suco_query(x, idx, q, k=10, alpha=0.05, beta=0.02, mode="dense")
    stream = suco_query_streaming(x, idx, q, k=10, alpha=0.05, beta=0.02)
    fused = suco_query_fused(x, idx, q, k=10, alpha=0.05, beta=0.02, tiles=tiles)
    _assert_bitwise_equal(dense, fused)
    _assert_bitwise_equal(stream, fused)


def test_fused_tie_break_determinism():
    """Duplicate points produce exact distance ties; the fused path must
    resolve them exactly like the dense pool order (higher score, then
    lower id), on every invocation."""
    rng = np.random.default_rng(3)
    n, d, k = 400, 16, 8
    x = rng.normal(size=(n, d)).astype(np.float32)
    for dup in (9, 17, 33, 101):  # exact duplicates of row 4
        x[dup] = x[4]
    x[11] = x[2]
    ds_x = jnp.asarray(x)
    idx = build_index(ds_x, SuCoConfig(n_subspaces=4, sqrt_k=8, kmeans_iters=4, seed=0))
    q = jnp.asarray(rng.normal(size=(3, d)).astype(np.float32))
    dense = suco_query(ds_x, idx, q, k=k, alpha=0.2, beta=0.2, mode="dense")
    for tiles in (TileConfig(block_n=64, survivor_cap=16),
                  TileConfig(block_n=100, survivor_cap=400)):
        fused = suco_query_fused(ds_x, idx, q, k=k, alpha=0.2, beta=0.2, tiles=tiles)
        _assert_bitwise_equal(dense, fused)


def test_fused_pool_larger_than_n(small):
    """beta > 1: the pool clamps to n, parity still exact."""
    ds, x, idx = small
    q = jnp.asarray(ds.queries)
    dense = suco_query(x, idx, q, k=10, alpha=0.05, beta=1.5, mode="dense")
    fused = suco_query_fused(
        x, idx, q, k=10, alpha=0.05, beta=1.5,
        tiles=TileConfig(block_n=777, survivor_cap=96),
    )
    _assert_bitwise_equal(dense, fused)


def test_fused_l1_metric_parity(small):
    ds, x, idx = small
    q = jnp.asarray(ds.queries)
    dense = suco_query(
        x, idx, q, k=10, alpha=0.05, beta=0.05, metric="l1", mode="dense"
    )
    fused = suco_query_fused(
        x, idx, q, k=10, alpha=0.05, beta=0.05, metric="l1",
        tiles=TileConfig(block_n=700, survivor_cap=128),
    )
    _assert_bitwise_equal(dense, fused)


def test_fused_rejects_k_larger_than_n(small):
    ds, x, idx = small
    q = jnp.asarray(ds.queries)
    with pytest.raises(ValueError, match="k="):
        suco_query_fused(x, idx, q, k=x.shape[0] + 1, alpha=0.05, beta=0.02)


def test_mode_fused_dispatch(small):
    """suco_query(mode="fused") routes to the fused engine; "auto" at small
    n stays dense; bogus modes still raise."""
    ds, x, idx = small
    q = jnp.asarray(ds.queries)
    dense = suco_query(x, idx, q, k=10, alpha=0.05, beta=0.02, mode="dense")
    via_mode = suco_query(x, idx, q, k=10, alpha=0.05, beta=0.02, mode="fused")
    _assert_bitwise_equal(dense, via_mode)
    with pytest.raises(ValueError, match="unknown mode"):
        suco_query(x, idx, q, k=10, alpha=0.05, beta=0.02, mode="bogus")


def test_fused_never_copies_or_streams_x():
    """The fused scan touches x only through O(cap)-row gathers: no live
    intermediate is O(n*d)-sized (in particular no padded copy of x, which
    would double dataset residency), and nothing of size m*n exists."""
    from repro.launch.hlo_analysis import jaxpr_peak_intermediate

    n, d, m, k, beta = 20_000, 32, 32, 10, 0.02
    ds = make_dataset("gaussian_mixture", n, d, m=m, k=k, seed=1)
    x, q = jnp.asarray(ds.x), jnp.asarray(ds.queries)
    cfg = SuCoConfig(n_subspaces=8, sqrt_k=16, kmeans_iters=2, seed=0)
    idx = build_index(x, cfg)
    tiles = TileConfig(block_n=2048, survivor_cap=128)

    jaxpr = jax.make_jaxpr(
        lambda xx, qq: suco_query_fused(
            xx, idx, qq, k=k, alpha=0.05, beta=beta, tiles=tiles
        )
    )(x, q)
    p = max(k, int(beta * n))
    bn = tiles.block_n
    n_pad = -(-n // bn) * bn
    allowed = max(
        2 * m * (bn + p),  # score block + carried pool triple
        cfg.n_subspaces * m * bn,  # per-chunk per-subspace collision gather
        m * p * d,  # overflow-fallback distance gather (pool rows)
        cfg.n_subspaces * n_pad,  # the index's cell ids, reshaped to blocks
        cfg.n_subspaces * m * cfg.n_cells,  # Dynamic-Activation ranks
    )
    got = jaxpr_peak_intermediate(jaxpr)
    assert got <= allowed, f"fused intermediate {got} > allowed {allowed}"
    assert got < n * d, f"fused path materialised an O(n*d) array: {got}"
    assert got < m * n, f"fused path materialised an (m, n)-sized array: {got}"


def test_fused_score_prune_is_one_kernel_launch(small):
    """The score -> prune stage is a single pallas_call per chunk: the
    in-kernel survivor compaction leaves exactly one kernel launch in the
    whole fused-query jaxpr (the chunk scan body runs once per chunk), with
    no host-graph searchsorted/gather stage between the scorer and the
    merge on the pruned path."""
    from repro.analysis.jaxpr_rules import iter_eqns

    ds, x, idx = small
    q = jnp.asarray(ds.queries)
    tiles = TileConfig(block_n=512, survivor_cap=128)
    jaxpr = jax.make_jaxpr(
        lambda xx, qq: suco_query_fused(
            xx, idx, qq, k=10, alpha=0.05, beta=0.02, tiles=tiles,
            score_impl="pallas",
        )
    )(x, q)
    launches = [
        eqn for eqn, _ in iter_eqns(jaxpr) if eqn.primitive.name == "pallas_call"
    ]
    assert len(launches) == 1, (
        f"fused query traced {len(launches)} pallas_call eqns; the "
        "score+prefilter+compaction stage must be exactly one launch"
    )


@pytest.mark.slow
def test_fused_parity_at_100k():
    """Acceptance: bit-identical to dense on n=100k synthetic data for two
    tile configs, and mode="auto" routes this n to the fused engine."""
    n, d, m = 100_000, 16, 8
    ds = make_dataset("gaussian_mixture", n, d, m=m, k=10, seed=2)
    x, q = jnp.asarray(ds.x), jnp.asarray(ds.queries)
    idx = build_index(x, SuCoConfig(n_subspaces=4, sqrt_k=16, kmeans_iters=2, seed=0))
    dense = suco_query(x, idx, q, k=10, alpha=0.03, beta=0.005, mode="dense")
    for tiles in (None, TileConfig(block_n=30_000, survivor_cap=192)):
        fused = suco_query_fused(
            x, idx, q, k=10, alpha=0.03, beta=0.005, tiles=tiles
        )
        _assert_bitwise_equal(dense, fused)
    auto = suco_query(x, idx, q, k=10, alpha=0.03, beta=0.005)
    _assert_bitwise_equal(dense, auto)


# ------------------- Pareto prefilter soundness (property) ------------------


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 4),
    p=st.integers(1, 24),
    b=st.integers(1, 48),
    hi=st.integers(1, 6),
    seed=st.integers(0, 10_000),
)
def test_prefilter_never_drops_a_kept_row(m, p, b, hi, seed):
    """The fused fast path prunes block rows with score <= the carried pool
    minimum before merging.  Property: the pruned merge (losers replaced
    by sentinels) is bit-identical to the exact full merge — the prefilter
    can never drop a row merge_topk_pool would keep, nor keep one it
    would drop."""
    rng = np.random.default_rng(seed)
    pool_s_raw = rng.integers(-1, hi + 1, size=(m, p)).astype(np.int32)
    pool_i_raw = np.sort(rng.integers(0, 1000, size=(m, p)), axis=1).astype(np.int32)
    # sort pool rows by (score desc, id asc) and sentinel-ify score<0 rows,
    # mirroring a mid-scan carried pool
    for i in range(m):
        order = np.lexsort((pool_i_raw[i], -pool_s_raw[i]))
        pool_s_raw[i] = pool_s_raw[i][order]
        pool_i_raw[i] = pool_i_raw[i][order]
        pool_i_raw[i][pool_s_raw[i] < 0] = INT_MAX
    blk_s = rng.integers(0, hi + 1, size=(m, b)).astype(np.int32)
    blk_i = 1000 + np.arange(b, dtype=np.int32)[None].repeat(m, 0)  # ids ascend

    pool_s, pool_i = jnp.asarray(pool_s_raw), jnp.asarray(pool_i_raw)
    want = merge_topk_pool(pool_s, pool_i, jnp.asarray(blk_s), jnp.asarray(blk_i))

    thr = pool_s_raw[:, -1:]  # pool sorted desc -> min in the last column
    keep = blk_s > thr
    pruned_s = np.where(keep, blk_s, -1).astype(np.int32)
    pruned_i = np.where(keep, blk_i, INT_MAX).astype(np.int32)
    got = merge_topk_pool(pool_s, pool_i, jnp.asarray(pruned_s), jnp.asarray(pruned_i))
    np.testing.assert_array_equal(np.asarray(want[0]), np.asarray(got[0]))
    np.testing.assert_array_equal(np.asarray(want[1]), np.asarray(got[1]))


# ------------------------------ engine wiring -------------------------------


def test_engine_fused_mode_parity_and_zero_retrace(small):
    """mode="fused" behind EnginePolicy: padded buckets return exactly the
    wrapper's answers, and warmed (bucket, k) executables never retrace."""
    ds, x, idx = small
    policy = EnginePolicy(alpha=0.05, beta=0.02, mode="fused",
                          batch_buckets=(4, 16))
    engine = SuCoEngine(x, idx, policy)
    assert engine.mode == "fused"
    engine.warmup(batch_sizes=(1, 4, 16), ks=(10,))
    warm = engine.compile_count
    q = jnp.asarray(ds.queries)
    for m in (1, 3, 4, 16):
        got = engine.query(q[:m], k=10)
        want = suco_query(
            x, idx, q[:m], k=10, alpha=0.05, beta=0.02, mode="fused"
        )
        _assert_bitwise_equal(got, want)
    assert engine.compile_count == warm, "fused engine retraced after warmup"


def test_engine_auto_resolves_fused_at_streaming_scale():
    """The fused path is the streaming-scale default behind EnginePolicy."""
    n, d = 32_768, 8
    ds = make_dataset("gaussian_mixture", n, d, m=2, k=5, seed=0)
    x = jnp.asarray(ds.x)
    idx = build_index(x, SuCoConfig(n_subspaces=4, sqrt_k=8, kmeans_iters=1, seed=0))
    engine = SuCoEngine(x, idx)
    assert engine.mode == "fused"
    got = engine.query(jnp.asarray(ds.queries), k=5)
    want = suco_query(x, idx, jnp.asarray(ds.queries), k=5,
                      alpha=engine.policy.alpha, beta=engine.policy.beta)
    _assert_bitwise_equal(got, want)


def test_engine_tiles_for_is_pure(small):
    ds, x, idx = small
    engine = SuCoEngine(x, idx, EnginePolicy(mode="fused"))
    before = engine.compile_count
    t1 = engine.tiles_for(3, 10)
    t2 = engine.tiles_for(3, 10)
    assert t1 == t2 and isinstance(t1, TileConfig)
    assert engine.compile_count == before  # introspection never compiles
    pinned = TileConfig(block_n=512, survivor_cap=64)
    assert SuCoEngine(
        x, idx, EnginePolicy(mode="fused", tiles=pinned)
    ).tiles_for(3, 10) == pinned
    # dense engines have no fused tiling
    assert SuCoEngine(x, idx, EnginePolicy(mode="dense")).tiles_for(3, 10) is None


def test_engine_pinned_tiles_parity(small):
    ds, x, idx = small
    q = jnp.asarray(ds.queries)
    tiles = TileConfig(block_n=600, survivor_cap=32)
    engine = SuCoEngine(x, idx, EnginePolicy(alpha=0.05, beta=0.02,
                                             mode="fused", tiles=tiles))
    got = engine.query(q, k=10)
    want = suco_query(x, idx, q, k=10, alpha=0.05, beta=0.02,
                      mode="fused", tiles=tiles)
    _assert_bitwise_equal(got, want)


# --------------------------- policy satellites ------------------------------


def test_observe_histogram_is_bounded_and_resettable():
    policy = EnginePolicy()
    cap = EnginePolicy.TRAFFIC_MAX_BINS
    policy.observe(range(1, cap + 1))
    assert len(policy.traffic) == cap
    policy.observe([cap + 7])  # new size at capacity -> evict, not grow
    assert len(policy.traffic) == cap
    assert policy.traffic[cap + 7] == 1
    # the evicted bin is the least-frequent (smallest size on ties): size 1
    assert 1 not in policy.traffic
    # re-observing an existing size never evicts
    policy.observe([cap + 7] * 5)
    assert policy.traffic[cap + 7] == 6 and len(policy.traffic) == cap
    policy.reset_traffic()
    assert not policy.traffic
    with pytest.raises(ValueError, match="batch size"):
        policy.observe([0])


def test_observe_eviction_keeps_hot_bins():
    policy = EnginePolicy()
    cap = EnginePolicy.TRAFFIC_MAX_BINS
    policy.observe([8] * 100)  # hot bin
    policy.observe(range(10, 10 + cap - 1))  # fill to capacity
    assert len(policy.traffic) == cap
    policy.observe([9999])
    assert policy.traffic[8] == 100  # the hot bin survives eviction


# ------------------------------- autotuner ----------------------------------


def test_autotune_tiles_deterministic_and_bounded():
    t1 = autotune_tiles(48_000, 32, 8, 480, n_subspaces=8, n_cells=256)
    t2 = autotune_tiles(48_000, 32, 8, 480, n_subspaces=8, n_cells=256)
    assert t1 == t2  # same shape -> same tiles -> no retrace
    assert t1.block_n % 512 == 0 and 512 <= t1.block_n <= 1 << 16
    assert t1.bm % 8 == 0 and t1.bn % 128 == 0
    assert 1 <= t1.survivor_cap <= max(64, min(480, t1.block_n))


def test_autotune_tiles_scales_with_memory():
    small_mem = autotune_tiles(
        1_000_000, 32, 8, 2000, n_subspaces=8, n_cells=2500,
        limits=MemoryLimits(fast_bytes=1 << 20, hbm_bytes=1 << 34),
    )
    big_mem = autotune_tiles(
        1_000_000, 32, 8, 2000, n_subspaces=8, n_cells=2500,
        limits=MemoryLimits(fast_bytes=1 << 24, hbm_bytes=1 << 34),
    )
    assert big_mem.block_n >= small_mem.block_n
    # block never exceeds the (rounded-up) dataset
    tiny = autotune_tiles(1000, 8, 1, 10, n_subspaces=4, n_cells=64)
    assert tiny.block_n <= 1024
    with pytest.raises(ValueError, match=">= 1"):
        autotune_tiles(0, 8, 1, 10)


def test_autotune_build_block_n_bounds():
    bn = autotune_build_block_n(100_000, 32, sqrt_k=50, n_subspaces=8)
    assert bn % 512 == 0 and 512 <= bn <= 1 << 16
    small = autotune_build_block_n(700, 32, sqrt_k=50, n_subspaces=8)
    assert small <= 1024
    with pytest.raises(ValueError, match=">= 1"):
        autotune_build_block_n(100, 0, sqrt_k=8)


def test_tileconfig_validation():
    with pytest.raises(ValueError, match="block_n"):
        TileConfig(block_n=0)
    with pytest.raises(ValueError, match="survivor_cap"):
        TileConfig(block_n=512, survivor_cap=0)


# ------------------ gather_rerank op-boundary validation --------------------


def test_gather_rerank_clips_sentinel_ids():
    """Satellite: pools are padded with -1 / INT32_MAX sentinels; the op
    boundary clips them into range once, so the kernel's scalar-prefetch
    index map can never read out of bounds and the jnp path matches."""
    from repro.kernels.gather_rerank.ops import gather_rerank, gather_rerank_block

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(32, 8)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(3, 8)).astype(np.float32))
    ids = jnp.asarray(
        np.array([[0, 5, -1, INT_MAX], [31, -1, -1, 2], [7, 7, 40, -5]], np.int32)
    )
    clipped = jnp.clip(ids, 0, 31)
    got = gather_rerank(ids, x, q, interpret=True)
    want = gather_rerank(clipped, x, q, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert np.isfinite(np.asarray(got)).all()

    got_b = gather_rerank_block(ids, x, q)
    want_b = gather_rerank_block(clipped, x, q)
    np.testing.assert_array_equal(np.asarray(got_b), np.asarray(want_b))


def test_gather_rerank_block_matches_rerank_candidates_distances(small):
    """The in-pass distance op reproduces rerank_candidates' fp reduction
    bit-for-bit (the whole basis of carrying distances through the pool)."""
    from repro.core.sc_linear import rerank_candidates
    from repro.kernels.gather_rerank.ops import gather_rerank_block

    ds, x, idx = small
    q = jnp.asarray(ds.queries)
    rng = np.random.default_rng(1)
    cand = jnp.asarray(
        rng.integers(0, x.shape[0], size=(q.shape[0], 64)), jnp.int32
    )
    for metric in ("l2", "l1"):
        via_op = gather_rerank_block(cand, x, q, metric=metric)
        via_rerank = rerank_candidates(
            x, q, cand, jnp.zeros_like(cand), 64, metric
        ).dists  # k=64 = pool size -> dists of every candidate, reordered
        # compare as sorted rows (rerank_candidates reorders by distance)
        np.testing.assert_array_equal(
            np.sort(np.asarray(via_op), axis=1),
            np.sort(np.asarray(via_rerank), axis=1),
        )


def test_backend_limits_unknown_backend_warns_and_falls_back():
    """An unrecognised backend name degrades to the conservative 'cpu'
    memory model with a warning instead of raising — serving keeps running
    on exotic platforms, just with smaller tiles."""
    from repro.core.tuning import backend_limits

    with pytest.warns(UserWarning, match="unknown backend"):
        limits = backend_limits("quantum_annealer")
    assert limits == backend_limits("cpu")
    # known backends stay silent
    import warnings as _warnings

    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        for backend in ("cpu", "gpu", "tpu"):
            backend_limits(backend)


def test_measured_backend_limits_probe_caches_and_quantises(
    tmp_path, monkeypatch
):
    """Tentpole: the active backend's limits are measured once, persisted
    as JSON keyed by device kind, quantised (1 GiB hbm / power-of-two-ish
    fast), and bit-stable across cache hits — the jit-static contract."""
    import json

    from repro.core import tuning

    monkeypatch.setenv(tuning._CACHE_DIR_ENV, str(tmp_path))
    tuning._measured_limits.cache_clear()
    try:
        lim = tuning.measured_backend_limits()
        assert lim.fast_bytes >= tuning._FAST_MIN
        assert lim.fast_bytes <= tuning._FAST_MAX
        assert lim.hbm_bytes >= tuning._HBM_QUANTUM
        assert lim.hbm_bytes % tuning._HBM_QUANTUM == 0
        backend = jax.default_backend()
        path = tmp_path / f"limits_{backend}.json"
        assert path.exists()
        rec = json.loads(path.read_text())
        assert rec["fast_bytes"] == lim.fast_bytes
        assert rec["hbm_bytes"] == lim.hbm_bytes
        assert rec["backend"] == backend
        # disk-cache hit after dropping the in-process cache: no re-probe,
        # identical values (the file is trusted, not re-measured)
        rec["fast_bytes"] = tuning._FAST_MIN
        path.write_text(json.dumps(rec))
        tuning._measured_limits.cache_clear()
        assert tuning.measured_backend_limits().fast_bytes == tuning._FAST_MIN
        # corrupt cache: silently re-probed and rewritten.  A re-probe under
        # load may land on a neighbouring knee, so assert the rewritten file
        # matches the re-measured value, not the first probe.
        path.write_text("{not json")
        tuning._measured_limits.cache_clear()
        lim2 = tuning.measured_backend_limits()
        assert lim2.hbm_bytes == lim.hbm_bytes  # allocator ceiling is exact
        assert json.loads(path.read_text())["fast_bytes"] == lim2.fast_bytes
        # refresh=True drops both caches and re-measures
        lim3 = tuning.measured_backend_limits(refresh=True)
        assert tuning._FAST_MIN <= lim3.fast_bytes <= tuning._FAST_MAX
        # the env kill-switch pins the static table
        monkeypatch.setenv(tuning._MEASURE_ENV, "0")
        assert tuning.backend_limits() == tuning._BACKEND_LIMITS[backend]
        # inactive backends always get the static prior, no probe
        other = "tpu" if backend != "tpu" else "gpu"
        assert tuning.measured_backend_limits(other) == tuning._BACKEND_LIMITS[
            other
        ]
        with pytest.raises(ValueError, match="unknown backend"):
            tuning.measured_backend_limits("quantum_annealer")
    finally:
        tuning._measured_limits.cache_clear()  # drop tmp_path-backed entries


def test_backend_limits_measured_feeds_autotune(tmp_path, monkeypatch):
    """autotune_tiles plans against the measured limits (not the static
    prior) and stays deterministic across calls on one host."""
    from repro.core import tuning

    monkeypatch.setenv(tuning._CACHE_DIR_ENV, str(tmp_path))
    tuning._measured_limits.cache_clear()
    try:
        lim = tuning.backend_limits()
        assert lim == tuning.measured_backend_limits()
        t1 = tuning.autotune_tiles(48_000, 32, 8, 480, n_subspaces=8, n_cells=256)
        t2 = tuning.autotune_tiles(48_000, 32, 8, 480, n_subspaces=8, n_cells=256)
        assert t1 == t2
        explicit = tuning.autotune_tiles(
            48_000, 32, 8, 480, n_subspaces=8, n_cells=256, limits=lim
        )
        assert t1 == explicit
    finally:
        tuning._measured_limits.cache_clear()


def test_autotune_survivor_cap_stays_quantised():
    """Regression (found by the jaxlint tile-shape rule): when the cap
    clamps to min(pool, block_n) it must still land on a 64 multiple, or
    the Pallas prefilter kernel loses its lane alignment."""
    for n, d, m, pool in [
        (50_000, 128, 8, 1_000),  # the case that used to yield cap=1000
        (1_000_000, 96, 64, 20_000),
        (32_768, 16, 1, 33),
        (4_096, 8, 2, 100),
    ]:
        t = autotune_tiles(n, d, m, pool, n_subspaces=8, n_cells=256)
        assert t.survivor_cap % 64 == 0, (n, d, m, pool, t)
        assert t.survivor_cap <= max(64, t.block_n)
