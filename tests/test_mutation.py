"""Live index mutation at the core layer: slot inserts against frozen
centroids, tombstoned deletes threaded through every query path, capacity
engines that never retrace, the warm-swap contract, the atomic artifact
save, and the unified ``candidate_pool_size`` clamp."""

import dataclasses
import os

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.sc_linear import candidate_pool_size
from repro.core.suco import (
    CapacityError,
    EnginePolicy,
    SuCoConfig,
    SuCoEngine,
    assign_points,
    build_index,
    load_index_artifact,
    suco_query,
)
from repro.data import make_dataset

CFG = SuCoConfig(n_subspaces=4, sqrt_k=8, kmeans_iters=3, seed=0)
N, D = 2000, 16


@pytest.fixture(scope="module")
def ds():
    return make_dataset("gaussian_mixture", N, D, m=20, k=10, seed=0)


@pytest.fixture(scope="module")
def index(ds):
    return build_index(jnp.asarray(ds.x), CFG)


def _fresh(x_new, rng, b=64):
    return rng.standard_normal((b, D)).astype(np.float32) * 0.1 + x_new


def _mutable_engine(ds, index, capacity=N + 400, **policy_kw):
    policy = EnginePolicy(alpha=0.1, beta=0.05, **policy_kw)
    return SuCoEngine(jnp.asarray(ds.x), index, policy, capacity=capacity)


# ---------------------------------------------------------------------------
# inserts
# ---------------------------------------------------------------------------


def test_insert_matches_assignment_oracle(ds, index):
    eng = _mutable_engine(ds, index)
    rng = np.random.default_rng(1)
    x_new = _fresh(ds.x[:64], rng)
    slots = eng.insert(x_new)
    assert np.array_equal(slots, np.arange(N, N + 64))
    cells, counts_delta, _ = assign_points(
        jnp.asarray(x_new),
        index.centroids1,
        index.centroids2,
        spec=index.spec,
        sqrt_k=index.sqrt_k,
        block_n=eng.policy.block_n,
    )
    got = np.asarray(eng.index.cell_ids[:, N:N + 64])
    assert np.array_equal(got, np.asarray(cells))
    # counts moved by exactly the oracle delta
    assert np.array_equal(
        np.asarray(eng.index.cell_counts),
        np.asarray(index.cell_counts) + np.asarray(counts_delta),
    )
    assert eng.n_live == N + 64
    assert not np.asarray(eng.index.tombstone[N:N + 64]).any()


def test_cell_counts_equal_live_histogram_after_mutation(ds, index):
    eng = _mutable_engine(ds, index)
    rng = np.random.default_rng(2)
    eng.insert(_fresh(ds.x[:100], rng, b=100))
    eng.delete(np.arange(0, 150))
    cells = np.asarray(eng.index.cell_ids)
    tomb = np.asarray(eng.index.tombstone)
    counts = np.asarray(eng.index.cell_counts)
    for s in range(cells.shape[0]):
        hist = np.bincount(
            cells[s][~tomb], minlength=counts.shape[1]
        )
        assert np.array_equal(counts[s], hist), f"subspace {s}"


def test_insert_beyond_capacity_raises(ds, index):
    eng = _mutable_engine(ds, index, capacity=N + 10)
    rng = np.random.default_rng(3)
    with pytest.raises(CapacityError, match="exceeds capacity"):
        eng.insert(_fresh(ds.x[:11], rng, b=11))
    # nothing was mutated by the failed insert
    assert eng.n_live == N
    assert eng.free_slots == 10


def test_immutable_engine_rejects_mutation(ds, index):
    eng = SuCoEngine(jnp.asarray(ds.x), index, EnginePolicy(mode="dense"))
    with pytest.raises(ValueError, match="mutable engine"):
        eng.insert(np.zeros((1, D), np.float32))
    with pytest.raises(ValueError, match="mutable engine"):
        eng.delete([0])


# ---------------------------------------------------------------------------
# deletes
# ---------------------------------------------------------------------------


def test_delete_idempotent_and_counts_consistent(ds, index):
    eng = _mutable_engine(ds, index)
    ids = np.array([5, 5, 17, 999])
    assert eng.delete(ids) == 3
    counts_after = np.asarray(eng.index.cell_counts)
    # re-deleting (with duplicates) is a no-op
    assert eng.delete(ids) == 0
    assert np.array_equal(np.asarray(eng.index.cell_counts), counts_after)
    assert eng.n_live == N - 3
    assert int(np.asarray(eng.index.cell_counts).sum()) == (
        index.spec.n_subspaces * (N - 3)
    )


def test_delete_out_of_range_raises(ds, index):
    eng = _mutable_engine(ds, index, capacity=N + 8)
    # slots past n_points (even tombstoned free slots) are not valid ids
    with pytest.raises(ValueError, match="ids must be in"):
        eng.delete([N + 8])
    with pytest.raises(ValueError, match="ids must be in"):
        eng.delete([-1])


def test_deleted_ids_never_in_answers_and_brute_force_exact(ds, index):
    # beta=1.0 makes the candidate pool cover the whole corpus, so the
    # engine answer must EQUAL brute force over the live points.
    policy = EnginePolicy(alpha=0.2, beta=1.0, mode="dense")
    eng = SuCoEngine(jnp.asarray(ds.x), index, policy, capacity=N + 100)
    rng = np.random.default_rng(4)
    eng.insert(_fresh(ds.x[:50], rng, b=50))
    dead = rng.choice(N + 50, size=300, replace=False)
    eng.delete(dead)
    q = ds.x[200:208]
    res = eng.query(q, k=10)
    ids = np.asarray(res.ids)
    assert not np.isin(ids, dead).any()
    # brute force over the live corpus
    x_all = np.asarray(eng.x)
    tomb = np.asarray(eng.index.tombstone)
    live = np.flatnonzero(~tomb)
    d2 = ((q[:, None, :] - x_all[None, live, :]) ** 2).sum(-1)
    want = live[np.argsort(d2, axis=1)[:, :10]]
    assert np.array_equal(np.sort(ids, axis=1), np.sort(want, axis=1))


def test_query_paths_bit_identical_under_tombstones(ds, index):
    tomb = jnp.asarray(np.random.default_rng(5).random(N) < 0.25)
    idx_t = dataclasses.replace(index, tombstone=tomb)
    q = jnp.asarray(ds.x[:6])
    outs = {}
    for mode in ("dense", "streaming", "fused"):
        r = suco_query(
            jnp.asarray(ds.x), idx_t, q, k=9,
            alpha=0.1, beta=0.05, mode=mode, block_n=512,
        )
        outs[mode] = (np.asarray(r.ids), np.asarray(r.dists))
    for mode in ("streaming", "fused"):
        assert np.array_equal(outs["dense"][0], outs[mode][0]), mode
        assert np.allclose(outs["dense"][1], outs[mode][1]), mode
    assert not np.asarray(tomb)[outs["dense"][0]].any()


def test_k_bounded_by_live_count(ds, index):
    eng = _mutable_engine(ds, index, capacity=N + 4)
    eng.delete(np.arange(N - 5, N))
    assert eng.n_live == N - 5
    eng.query(ds.x[0], k=N - 5)  # boundary: fine
    with pytest.raises(ValueError, match="must be in"):
        eng.query(ds.x[0], k=N - 4)


# ---------------------------------------------------------------------------
# zero-retrace serving invariant under mutation
# ---------------------------------------------------------------------------


def test_mutation_never_retraces(ds, index):
    eng = _mutable_engine(ds, index, mode="dense")
    eng.warmup(batch_sizes=(1, 4), ks=(5,))
    c0 = eng.compile_count
    rng = np.random.default_rng(6)
    for step in range(3):
        eng.insert(_fresh(ds.x[:16], rng, b=16))
        eng.delete(rng.integers(0, N, size=8))
        eng.query(ds.x[:4], k=5)
        eng.query(ds.x[0], k=5)
    assert eng.compile_count == c0


# ---------------------------------------------------------------------------
# warm swap
# ---------------------------------------------------------------------------


def test_swap_requires_warm_successor_and_adopts_state(ds, index):
    eng = _mutable_engine(ds, index, mode="dense")
    eng.warmup(batch_sizes=(1, 4), ks=(5,))
    x2 = ds.x[:1500]
    idx2 = build_index(jnp.asarray(x2), CFG)
    succ = SuCoEngine(
        jnp.asarray(x2), idx2, EnginePolicy(alpha=0.1, beta=0.05, mode="dense"),
        capacity=1600,
    )
    with pytest.raises(ValueError, match="not warmed"):
        eng.swap(succ)
    for b, k in sorted(eng._buckets_seen):
        succ.warmup([b], [k])
    c_succ = succ.compile_count
    eng.swap(succ)
    assert eng.n_live == 1500
    assert eng.capacity == 1600
    r = eng.query(ds.x[:4], k=5)
    assert np.asarray(r.ids).max() < 1600
    # post-swap serving runs on the successor's warmed executables
    assert succ.compile_count == c_succ


# ---------------------------------------------------------------------------
# atomic artifact save (satellite bugfix)
# ---------------------------------------------------------------------------


def test_save_is_atomic_under_simulated_crash(ds, index, tmp_path, monkeypatch):
    path = tmp_path / "index.npz"
    index.save(path, CFG)
    good = path.read_bytes()

    def crashing_savez(f, **payload):
        f.write(b"partial garbage")
        raise OSError("simulated crash mid-write")

    monkeypatch.setattr(np, "savez", crashing_savez)
    with pytest.raises(OSError, match="simulated crash"):
        index.save(path, CFG)
    monkeypatch.undo()
    # the live artifact is untouched and still loads; no temp litter
    assert path.read_bytes() == good
    loaded, _ = load_index_artifact(path)
    assert loaded.n_points == N
    assert os.listdir(tmp_path) == ["index.npz"]


def test_tombstone_roundtrips_through_artifact(ds, index, tmp_path):
    tomb = jnp.asarray(np.random.default_rng(7).random(N) < 0.1)
    idx_t = dataclasses.replace(index, tombstone=tomb)
    path = tmp_path / "tomb.npz"
    idx_t.save(path, CFG)
    loaded, cfg = load_index_artifact(path)
    assert loaded.tombstone is not None
    assert np.array_equal(np.asarray(loaded.tombstone), np.asarray(tomb))
    assert loaded.n_live == N - int(np.asarray(tomb).sum())


def test_v1_artifact_still_loads(ds, index, tmp_path):
    # A pre-mutation artifact has version 1 and no tombstone key; the
    # reader must keep accepting it (tombstone comes back None).
    path = tmp_path / "v2.npz"
    index.save(path, CFG)
    with np.load(path, allow_pickle=False) as z:
        payload = {k: z[k] for k in z.files}
    assert "tombstone" not in payload
    payload["version"] = np.asarray(1, np.int64)
    v1 = tmp_path / "v1.npz"
    with open(v1, "wb") as f:
        np.savez(f, **payload)
    loaded, cfg = load_index_artifact(v1)
    assert loaded.tombstone is None
    assert loaded.n_points == N
    assert cfg.n_subspaces == CFG.n_subspaces


# ---------------------------------------------------------------------------
# unified candidate_pool_size clamp (satellite bugfix)
# ---------------------------------------------------------------------------


@settings(max_examples=60)
@given(
    n=st.integers(min_value=0, max_value=200_000),
    k=st.integers(min_value=1, max_value=500),
    beta=st.floats(min_value=0.0, max_value=2.0),
)
def test_candidate_pool_size_properties(n, k, beta):
    pool = candidate_pool_size(n, k, beta)
    assert pool >= k  # enough candidates to fill an answer
    assert pool >= min(int(beta * n), n) or pool == k
    # the n-clamp: beta*n past the corpus never over-allocates
    assert pool <= max(k, n)
    # monotone in beta
    assert candidate_pool_size(n, k, min(beta * 2, 2.0)) >= pool


def test_candidate_pool_size_edge_cases():
    assert candidate_pool_size(100, 10, 0.0) == 10  # beta*n < k
    assert candidate_pool_size(100, 10, 5.0) == 100  # beta*n > n: clamped
    assert candidate_pool_size(7, 10, 0.5) == 10  # k > n: k wins
    # post-delete live count shrinking below beta*n_build stays clamped
    assert candidate_pool_size(50, 10, 1.0) == 50
    with pytest.raises(ValueError):
        candidate_pool_size(-1, 10, 0.5)
    with pytest.raises(ValueError):
        candidate_pool_size(100, 0, 0.5)
