"""Distributed SuCo engine tests.

These need >1 device, so they run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the main pytest
process keeps the default single device per the dry-run contract)."""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")

_SCRIPT = textwrap.dedent(
    """
    import numpy as np, jax, jax.numpy as jnp
    from repro.distributed.engine import (
        DistSuCoConfig, build_sharded, query_sharded, index_shardings, shard_index,
    )
    from repro.core import SuCoConfig, build_index, suco_query
    from repro.data import make_dataset, recall
    from repro.launch.mesh import compat_make_mesh

    mesh = compat_make_mesh((2, 2, 2), ("pod", "data", "model"))
    ds = make_dataset("gaussian_mixture", 4096, 64, m=16, k=10)
    cfg = DistSuCoConfig(n_subspaces=8, sqrt_k=16, kmeans_iters=6, alpha=0.05,
                         beta=0.02, k=10, q_chunk=16, point_axes=("pod", "data"))
    sh = index_shardings(mesh, cfg)
    x = jax.device_put(jnp.asarray(ds.x), sh["x"])
    q = jax.device_put(jnp.asarray(ds.queries), sh["queries"])

    # distributed build + query
    idx = build_sharded(mesh, x, cfg)
    ids, dists = query_sharded(mesh, cfg, x, idx, q)
    r = recall(np.asarray(ids), ds.gt_ids)
    assert r >= 0.85, f"distributed recall too low: {r}"

    # same-index equivalence: local query on the distributed index
    local_idx = jax.device_put(idx, jax.devices()[0])
    res = suco_query(jnp.asarray(ds.x), local_idx, jnp.asarray(ds.queries),
                     k=10, alpha=0.05, beta=0.02)
    overlap = np.mean([
        len(set(map(int, ids[i])) & set(map(int, res.ids[i]))) / 10
        for i in range(16)
    ])
    assert overlap >= 0.95, f"distributed/local disagree: {overlap}"

    # streaming (blocked) vs dense per-shard scoring: bit-identical results
    import dataclasses
    ids_d, dists_d = query_sharded(mesh, dataclasses.replace(cfg, block_n=0), x, idx, q)
    ids_b, dists_b = query_sharded(mesh, dataclasses.replace(cfg, block_n=300), x, idx, q)
    assert np.array_equal(np.asarray(ids_d), np.asarray(ids_b)), "engine streaming ids"
    assert np.array_equal(np.asarray(dists_d), np.asarray(dists_b)), "engine streaming dists"

    # chunked vs dense sharded *build*, mechanism check at 1 Lloyd iteration:
    # exact cell_ids/counts, centroids to fp tolerance (build_block_n=300 does
    # not divide n_loc=512 — the padded tail must not leak).  A single
    # iteration isolates the accumulator correctness; more iterations let
    # Lloyd chaotically amplify benign summation-order noise at Voronoi
    # boundaries, which the full-run check below bounds statistically.
    cfg1 = dataclasses.replace(cfg, kmeans_iters=1)
    idx_bd = build_sharded(mesh, x, dataclasses.replace(cfg1, build_block_n=0))
    idx_bc = build_sharded(mesh, x, dataclasses.replace(cfg1, build_block_n=300))
    assert np.array_equal(np.asarray(idx_bd.cell_ids), np.asarray(idx_bc.cell_ids)), \
        "chunked build cell_ids"
    assert np.array_equal(np.asarray(idx_bd.cell_counts), np.asarray(idx_bc.cell_counts)), \
        "chunked build cell_counts"
    np.testing.assert_allclose(np.asarray(idx_bd.centroids1), np.asarray(idx_bc.centroids1),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(idx_bd.centroids2), np.asarray(idx_bc.centroids2),
                               rtol=1e-5, atol=1e-5)

    # full-depth chunked build: near-total agreement with dense (only
    # boundary points may flip) and equal recall quality
    fd = build_sharded(mesh, x, dataclasses.replace(cfg, build_block_n=0))
    fc = build_sharded(mesh, x, dataclasses.replace(cfg, build_block_n=300))
    agree = np.mean(np.asarray(fd.cell_ids) == np.asarray(fc.cell_ids))
    assert agree >= 0.995, f"chunked build diverged from dense: {agree}"
    ids_fc, _ = query_sharded(mesh, cfg, x, fc, q)
    r_fc = recall(np.asarray(ids_fc), ds.gt_ids)
    assert r_fc >= 0.85, f"chunked-build recall too low: {r_fc}"

    # shard_index round-trip of a locally built index
    lcfg = SuCoConfig(n_subspaces=8, sqrt_k=16, kmeans_iters=6)
    li = build_index(jnp.asarray(ds.x), lcfg)
    si = shard_index(mesh, cfg, li)
    ids2, _ = query_sharded(mesh, cfg, x, si, q)
    r2 = recall(np.asarray(ids2), ds.gt_ids)
    assert r2 >= 0.85, f"sharded local-index recall too low: {r2}"

    # elastic re-scaling: move the index to a DIFFERENT mesh shape and
    # re-query — results must be identical (sharding-agnostic layout)
    from repro.distributed.elastic import reshard_index
    mesh2 = compat_make_mesh((4, 2), ("data", "model"))
    cfg2 = dataclasses.replace(cfg, point_axes=("data",))
    from repro.distributed.engine import index_shardings as ish
    idx2 = reshard_index(mesh2, cfg2, idx)
    x2 = jax.device_put(jnp.asarray(ds.x), ish(mesh2, cfg2)["x"])
    q2 = jax.device_put(jnp.asarray(ds.queries), ish(mesh2, cfg2)["queries"])
    ids3, _ = query_sharded(mesh2, cfg2, x2, idx2, q2)
    overlap2 = np.mean([
        len(set(map(int, ids[i])) & set(map(int, ids3[i]))) / 10
        for i in range(16)
    ])
    assert overlap2 >= 0.95, f"elastic reshard changed results: {overlap2}"

    # ShardedSuCoEngine: bucketed serving over the same artifact format —
    # warmed buckets never retrace, partial batches pad-and-slice, and a
    # persisted single-host artifact serves the mesh bit-identically.
    import tempfile, os as _os
    from repro.distributed.engine import ShardedSuCoEngine
    eng = ShardedSuCoEngine(mesh, cfg, jnp.asarray(ds.x), idx)
    n_warm = eng.warmup(batch_sizes=(1, 16))
    ids_e, _ = eng.query(q)  # m=16: warmed bucket
    assert eng.compile_count == n_warm, "sharded engine retraced after warmup"
    assert np.array_equal(np.asarray(ids_e), np.asarray(ids)), "engine != query_sharded"
    ids_p, _ = eng.query(jnp.asarray(ds.queries[:3]))  # padded partial batch
    assert np.array_equal(np.asarray(ids_p), np.asarray(ids[:3])), "padded batch"
    with tempfile.TemporaryDirectory() as td:
        pth = _os.path.join(td, "idx.npz")
        eng.save(pth)
        eng2 = ShardedSuCoEngine.from_artifact(pth, mesh, cfg, jnp.asarray(ds.x))
        ids_a, _ = eng2.query(q)
        assert np.array_equal(np.asarray(ids_a), np.asarray(ids)), "artifact round trip"

    # ShardedEnginePool: per-k engines for heterogeneous-k traffic over one
    # placed (x, index).  A mixed-k replay binds each request to its k's
    # pre-warmed (bucket, k) executable: pool-wide compile count stays flat,
    # the k=cfg.k path is bit-identical to query_sharded, and every k agrees
    # with the local engine on the same index.
    from repro.distributed.engine import ShardedEnginePool
    from repro.core import EnginePolicy, SuCoEngine
    pool = ShardedEnginePool(mesh, cfg, jnp.asarray(ds.x), idx, ks=(5, 10))
    p_warm = pool.warmup(batch_sizes=(1, 16))
    assert pool.ks == (5, 10)
    for mq_r, k_r in ((16, 10), (1, 5), (16, 5), (1, 10), (16, 10)):
        ids_k, dists_k = pool.query(q[:mq_r], k_r)
        assert ids_k.shape == (mq_r, k_r), (ids_k.shape, mq_r, k_r)
    assert pool.compile_count == p_warm, "pool retraced under mixed-k replay"
    ids_p, _ = pool.query(q, 10)
    assert np.array_equal(np.asarray(ids_p), np.asarray(ids)), "pool != query_sharded"
    leng = SuCoEngine(jnp.asarray(ds.x), local_idx,
                      EnginePolicy(alpha=0.05, beta=0.02))
    for k_r in (5, 10):
        ids_k, _ = pool.query(q, k_r)
        ids_l = np.asarray(leng.query(jnp.asarray(ds.queries), k=k_r).ids)
        ov_k = np.mean([
            len(set(map(int, ids_k[i])) & set(map(int, ids_l[i]))) / k_r
            for i in range(16)
        ])
        assert ov_k >= 0.9, f"pool k={k_r} disagrees with local engine: {ov_k}"

    # Fault tolerance: a dead per-k engine rebinds its k-class to a healthy
    # engine with a degraded-answer marker (truncating a larger-k answer is
    # the exact top-k); ValueError passes through without killing anything;
    # revive() restores primary service.
    from repro.serve.chaos import kill_pool_engine
    ids10 = np.asarray(pool.query(q, 10)[0])
    _, _, info = pool.query_resilient(q, 5)
    assert info == {"degraded": False, "served_by": 5, "reason": ""}
    kill_pool_engine(pool, 5)
    ids_r, dists_r, info = pool.query_resilient(q, 5)
    assert info["degraded"] and info["served_by"] == 10, info
    assert "k=5" in info["reason"] and "rebound" in info["reason"]
    assert np.array_equal(np.asarray(ids_r), ids10[:, :5]), "rebind not exact"
    assert pool.dead_ks == (5,)
    try:
        pool.query_resilient(q, ds.x.shape[0] + 1)
        raise AssertionError("ValueError expected for malformed k")
    except ValueError:
        pass
    assert pool.dead_ks == (5,), "malformed input must not kill an engine"
    assert pool.compile_count == p_warm, "rebound serving retraced"
    pool.revive(5)
    assert pool.dead_ks == ()
    _, _, info = pool.query_resilient(q, 5)
    assert not info["degraded"], "revived k must serve primary again"

    print("DISTRIBUTED_OK", r, overlap, r2, overlap2)
    """
)


def test_distributed_engine_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=env, capture_output=True, text=True,
        timeout=900,
    )
    assert out.returncode == 0, f"stdout={out.stdout}\nstderr={out.stderr[-3000:]}"
    assert "DISTRIBUTED_OK" in out.stdout
