"""Streaming index-construction engine: chunked Lloyd parity with the dense
reference, minibatch K-means, build-mode routing, the O(block_n) build
memory claim, and the top_k-based pool merge."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    STREAMING_MIN_N,
    SuCoConfig,
    autotune_build_block_n,
    build_index,
    merge_topk_pool,
    suco_query,
)
from repro.core.kmeans import kmeans, kmeans_batched
from repro.data import make_dataset


def _mixture(n, s, k_true, seed=0, spread=8.0):
    """Well-separated gaussian mixture: argmin flips from fp summation-order
    noise are vanishingly unlikely, so dense/chunked parity is exact."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k_true, s)) * spread
    who = rng.integers(0, k_true, n)
    return jnp.asarray(centers[who] + rng.normal(size=(n, s)), jnp.float32)


# ------------------------- chunked Lloyd parity -----------------------------


@pytest.mark.parametrize("block_n", [512, 333, 4096, 1])
def test_chunked_lloyd_matches_dense(block_n):
    """block_n=333 does not divide n=3777 — the padded tail must not leak;
    block_n=1 is the degenerate one-point-chunk case."""
    n = 3777 if block_n != 1 else 97
    x = _mixture(n, 12, 9)
    key = jax.random.key(0)
    dense = kmeans(key, x, 16, 8)
    chunk = kmeans(key, x, 16, 8, block_n=block_n)
    np.testing.assert_array_equal(
        np.asarray(dense.assignments), np.asarray(chunk.assignments)
    )
    np.testing.assert_allclose(
        np.asarray(dense.centroids), np.asarray(chunk.centroids), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(
        float(dense.inertia), float(chunk.inertia), rtol=1e-5
    )


def test_chunked_lloyd_empty_clusters():
    """Duplicate-heavy data collapses centroids: empty clusters must keep the
    previous centroid on both paths, chunks owning no member of some cluster
    must contribute zero, and nothing may go NaN."""
    rng = np.random.default_rng(1)
    base = rng.normal(size=(5, 6)).astype(np.float32) * 10
    x = jnp.asarray(base[rng.integers(0, 5, 400)])  # only 5 distinct points
    key = jax.random.key(3)
    dense = kmeans(key, x, 12, 6)  # k=12 >> 5 distinct values -> empties
    chunk = kmeans(key, x, 12, 6, block_n=64)
    assert np.isfinite(np.asarray(chunk.centroids)).all()
    np.testing.assert_array_equal(
        np.asarray(dense.assignments), np.asarray(chunk.assignments)
    )
    np.testing.assert_allclose(
        np.asarray(dense.centroids), np.asarray(chunk.centroids), rtol=1e-5, atol=1e-5
    )


def test_chunked_lloyd_batched_parity():
    xs = jnp.stack([_mixture(1000, 8, 7, seed=i) for i in range(6)])
    key = jax.random.key(1)
    dense = kmeans_batched(key, xs, 10, 6)
    chunk = kmeans_batched(key, xs, 10, 6, block_n=256)
    np.testing.assert_array_equal(
        np.asarray(dense.assignments), np.asarray(chunk.assignments)
    )
    np.testing.assert_allclose(
        np.asarray(dense.centroids), np.asarray(chunk.centroids), rtol=1e-5, atol=1e-5
    )


def test_kmeans_validates_args():
    x = _mixture(100, 4, 3)
    key = jax.random.key(0)
    with pytest.raises(ValueError, match="algo"):
        kmeans(key, x, 4, 2, algo="bogus")
    with pytest.raises(ValueError, match="block_n"):
        kmeans(key, x, 4, 2, block_n=-1)
    with pytest.raises(ValueError, match="impl"):
        kmeans(key, x, 4, 2, impl="cuda")


# ----------------------------- minibatch ------------------------------------


def test_minibatch_deterministic_and_converges():
    xs = jnp.stack([_mixture(2000, 8, 6, seed=i) for i in range(4)])
    key = jax.random.key(2)
    lloyd = kmeans_batched(key, xs, 8, 8)
    mb1 = kmeans_batched(key, xs, 8, 48, algo="minibatch", block_n=512)
    mb2 = kmeans_batched(key, xs, 8, 48, algo="minibatch", block_n=512)
    np.testing.assert_array_equal(np.asarray(mb1.centroids), np.asarray(mb2.centroids))
    np.testing.assert_array_equal(
        np.asarray(mb1.assignments), np.asarray(mb2.assignments)
    )
    assert mb1.assignments.shape == lloyd.assignments.shape
    assert mb1.centroids.shape == lloyd.centroids.shape
    # Approximate mode: within a modest factor of the Lloyd fixed point.
    assert np.all(np.asarray(mb1.inertia) <= 1.5 * np.asarray(lloyd.inertia) + 1e-3)


# --------------------------- build-mode routing ------------------------------


@pytest.fixture(scope="module")
def small_ds():
    ds = make_dataset("gaussian_mixture", 4000, 48, m=8, k=10, seed=0)
    return ds, jnp.asarray(ds.x)


def test_build_chunked_matches_dense(small_ds):
    _, x = small_ds
    base = SuCoConfig(n_subspaces=8, sqrt_k=24, kmeans_iters=8, seed=0)
    dense = build_index(x, dataclasses.replace(base, build_mode="dense"))
    for bn in (512, 333):  # 333 does not divide n=4000
        chunk = build_index(
            x, dataclasses.replace(base, build_mode="chunked", block_n=bn)
        )
        np.testing.assert_array_equal(
            np.asarray(dense.cell_ids), np.asarray(chunk.cell_ids)
        )
        np.testing.assert_array_equal(
            np.asarray(dense.cell_counts), np.asarray(chunk.cell_counts)
        )
        np.testing.assert_allclose(
            np.asarray(dense.centroids1), np.asarray(chunk.centroids1),
            rtol=1e-5, atol=1e-5,
        )
        np.testing.assert_allclose(
            np.asarray(dense.centroids2), np.asarray(chunk.centroids2),
            rtol=1e-5, atol=1e-5,
        )


def test_build_minibatch_quality(small_ds):
    ds, x = small_ds
    q = jnp.asarray(ds.queries)
    cfg = SuCoConfig(
        n_subspaces=8, sqrt_k=24, kmeans_iters=24, seed=0,
        build_mode="minibatch", block_n=512,
    )
    idx = build_index(x, cfg)
    res = suco_query(x, idx, q, k=10, alpha=0.05, beta=0.02)
    got = np.asarray(res.ids)
    rec = np.mean([len(set(got[i]) & set(ds.gt_ids[i])) / 10 for i in range(len(got))])
    assert rec >= 0.9, f"minibatch-built index recall too low: {rec}"


def test_build_mode_validation(small_ds):
    _, x = small_ds
    with pytest.raises(ValueError, match="build_mode"):
        build_index(x, SuCoConfig(build_mode="bogus"))
    with pytest.raises(ValueError, match="block_n"):
        build_index(x, SuCoConfig(build_mode="chunked", block_n=-1))


def test_build_block_n_zero_autotunes(small_ds):
    """block_n=0 resolves the chunk size from the backend memory limits
    (repro.core.tuning.autotune_build_block_n) — same assignments as an
    explicitly-chunked build of the same data."""
    _, x = small_ds
    base = SuCoConfig(n_subspaces=8, sqrt_k=24, kmeans_iters=4, seed=0)
    auto = build_index(x, dataclasses.replace(base, build_mode="chunked", block_n=0))
    explicit = build_index(
        x,
        dataclasses.replace(
            base,
            build_mode="chunked",
            block_n=autotune_build_block_n(
                x.shape[0], x.shape[1], sqrt_k=24, n_subspaces=8
            ),
        ),
    )
    np.testing.assert_array_equal(
        np.asarray(auto.cell_ids), np.asarray(explicit.cell_ids)
    )


def test_assign_ops_validate_impl():
    from repro.kernels.kmeans_assign.ops import kmeans_assign_stats

    x = jnp.zeros((1, 8, 4), jnp.float32)
    c = jnp.zeros((1, 2, 4), jnp.float32)
    with pytest.raises(ValueError, match="impl"):
        kmeans_assign_stats(x, c, impl="jnpp")


def test_build_auto_dispatch_threshold(small_ds):
    """auto == dense below STREAMING_MIN_N (and this dataset is below it)."""
    _, x = small_ds
    assert x.shape[0] < STREAMING_MIN_N
    base = SuCoConfig(n_subspaces=4, sqrt_k=16, kmeans_iters=3, seed=0)
    auto = build_index(x, base)
    dense = build_index(x, dataclasses.replace(base, build_mode="dense"))
    np.testing.assert_array_equal(np.asarray(auto.cell_ids), np.asarray(dense.cell_ids))


# --------------------------- score_impl plumbing ----------------------------


def test_suco_query_exposes_score_impl(small_ds):
    ds, x = small_ds
    q = jnp.asarray(ds.queries)
    idx = build_index(x, SuCoConfig(n_subspaces=4, sqrt_k=16, kmeans_iters=3, seed=0))
    auto = suco_query(x, idx, q, k=10, alpha=0.05, beta=0.02, mode="streaming")
    jnp_ = suco_query(
        x, idx, q, k=10, alpha=0.05, beta=0.02, mode="streaming", score_impl="jnp"
    )
    np.testing.assert_array_equal(np.asarray(auto.ids), np.asarray(jnp_.ids))
    np.testing.assert_array_equal(np.asarray(auto.dists), np.asarray(jnp_.dists))


# ------------------------------ pool merge ----------------------------------


def test_merge_topk_pool_topk_equals_sort():
    """Under the streaming invariant (ascending block ids) the top_k merge is
    bit-identical to the two-key sort merge at every step of the scan."""
    rng = np.random.default_rng(0)
    m, n, p, bn = 5, 2000, 64, 128
    scores = jnp.asarray(rng.integers(0, 5, size=(m, n)), jnp.int32)  # many ties
    int_max = np.iinfo(np.int32).max
    pools = {
        impl: (
            jnp.full((m, p), -1, jnp.int32),
            jnp.full((m, p), int_max, jnp.int32),
        )
        for impl in ("sort", "topk")
    }
    for start in range(0, n, bn):
        blk = scores[:, start:start + bn]
        ids = jnp.broadcast_to(
            jnp.arange(start, start + blk.shape[1], dtype=jnp.int32), blk.shape
        )
        for impl in pools:
            pools[impl] = merge_topk_pool(*pools[impl], blk, ids, impl=impl)
        np.testing.assert_array_equal(
            np.asarray(pools["sort"][0]), np.asarray(pools["topk"][0])
        )
        np.testing.assert_array_equal(
            np.asarray(pools["sort"][1]), np.asarray(pools["topk"][1])
        )
    want_s, want_i = jax.lax.top_k(scores, p)
    np.testing.assert_array_equal(np.asarray(pools["topk"][0]), np.asarray(want_s))
    np.testing.assert_array_equal(np.asarray(pools["topk"][1]), np.asarray(want_i))


def test_merge_topk_pool_rejects_bad_impl():
    z = jnp.zeros((1, 4), jnp.int32)
    with pytest.raises(ValueError, match="impl"):
        merge_topk_pool(z, z, z, z, impl="bogus")


# ------------------------------ memory model --------------------------------
#
# The ad-hoc jaxpr peak-intermediate assertion that used to live here is now
# the jaxlint `bounded-intermediate` rule: the chunked-build entry in
# core/suco.py declares its O(2Ns * block_n * max(sqrtK, h_max)) byte budget
# (plus the O(n*d) data views), and this test exercises the rule (the full
# registry gate is tests/test_analysis.py / `python -m repro.analysis.lint`).


def test_build_chunked_never_materialises_n_by_k():
    """Migrated acceptance bound: the registered chunked-build entry stays
    inside its declared bounded-intermediate budget — below the (n, sqrtK)
    separation line — and keeps its scan free of data-sized scatters, while
    the dense build provably allocates an (n, sqrtK)-sized array."""
    from repro.analysis.jaxpr_rules import (
        peak_intermediate_bytes,
        rule_bounded_intermediate,
        rule_no_scatter_in_scan,
    )
    from repro.analysis.registry import collect_entries
    from repro.core.suco import LINT_BUILD_SHAPES

    entries = {e.name: e for e in collect_entries(modules=("repro.core.suco",))}
    entry = entries["suco.build_chunked"]
    jaxpr = entry.make()
    assert rule_bounded_intermediate(entry, jaxpr) == []
    assert rule_no_scatter_in_scan(entry, jaxpr) == []

    s = LINT_BUILD_SHAPES
    codebooks = 2 * s["n_subspaces"]
    dense_line = 4 * codebooks * s["n"] * s["sqrt_k"]  # bytes
    assert entry.budget_bytes < dense_line  # the budget is meaningful
    peak, where = peak_intermediate_bytes(jaxpr)
    assert peak < dense_line, f"chunked build materialised (n, sqrtK): {where}"

    base = SuCoConfig(
        n_subspaces=s["n_subspaces"], sqrt_k=s["sqrt_k"], kmeans_iters=2, seed=0
    )
    x = _mixture(s["n"], s["d"], 10, seed=1)
    dense_jaxpr = jax.make_jaxpr(
        lambda xx: build_index(
            xx, dataclasses.replace(base, build_mode="dense")
        ).cell_ids
    )(x)
    dense_peak, _ = peak_intermediate_bytes(dense_jaxpr)
    assert dense_peak >= dense_line  # the bound is real


# --------------------------- kmeans++ seeding -------------------------------


def test_kmeanspp_never_starts_worse_than_random():
    """Satellite acceptance: on every seed dataset generator, the kmeans++
    D^2 seeding's starting inertia (before any Lloyd/minibatch update) is
    never worse than random init's.  The guarantee is an expectation (a
    single draw is a coin flip on structureless data), so the comparison
    averages over 8 keys — deterministic given the fixed key set."""
    from repro.core.kmeans import _init_centroids, init_centroids_pp
    from repro.data import GENERATORS

    def start_inertia(x, c):
        d2 = jnp.sum((x[:, None, :] - c[None]) ** 2, axis=-1)
        return float(jnp.sum(jnp.min(d2, axis=-1)))

    k = 12
    for gen in GENERATORS:
        x = jnp.asarray(np.asarray(GENERATORS[gen](3000, 16, 0), np.float32))
        rand, pp = [], []
        for seed in range(8):
            key = jax.random.key(seed)
            rand.append(start_inertia(x, _init_centroids(key, x, k)))
            pp.append(start_inertia(x, init_centroids_pp(key, x, k)))
        assert np.mean(pp) <= np.mean(rand) * (1 + 1e-6), (
            f"{gen}: kmeans++ mean start {np.mean(pp)} worse than "
            f"random {np.mean(rand)}"
        )


def test_kmeanspp_is_minibatch_default_and_deterministic():
    """init="auto" resolves to kmeans++ for minibatch; explicit forms agree."""
    xs = jnp.stack([_mixture(1500, 8, 6, seed=i) for i in range(3)])
    key = jax.random.key(5)
    auto = kmeans_batched(key, xs, 8, 12, algo="minibatch", block_n=256)
    pp = kmeans_batched(
        key, xs, 8, 12, algo="minibatch", block_n=256, init="kmeans++"
    )
    np.testing.assert_array_equal(np.asarray(auto.centroids), np.asarray(pp.centroids))
    rand = kmeans_batched(
        key, xs, 8, 12, algo="minibatch", block_n=256, init="random"
    )
    assert not np.array_equal(np.asarray(auto.centroids), np.asarray(rand.centroids))
    # lloyd's auto stays random init (the paper's choice), unchanged results
    ll_auto = kmeans_batched(key, xs, 8, 4, block_n=256)
    ll_rand = kmeans_batched(key, xs, 8, 4, block_n=256, init="random")
    np.testing.assert_array_equal(
        np.asarray(ll_auto.centroids), np.asarray(ll_rand.centroids)
    )
    with pytest.raises(ValueError, match="init"):
        kmeans(key, xs[0], 8, 2, init="bogus")


def test_kmeanspp_sampled_subset():
    """sample_n caps the seeding working set without breaking determinism."""
    from repro.core.kmeans import init_centroids_pp

    x = _mixture(5000, 8, 6, seed=2)
    key = jax.random.key(0)
    a = init_centroids_pp(key, x, 8, sample_n=512)
    b = init_centroids_pp(key, x, 8, sample_n=512)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (8, 8) and a.dtype == x.dtype


# ------------------------- fused cell-count histogram -----------------------


def test_assign_scan_pair_counts_match_bincount():
    """The IMI occupancy histogram fused into the final-assignment scan is
    exactly the bincount of a1 * sqrt_k + a2 — including non-divisible
    block_n, where the padded tail must not count."""
    from repro.core.kmeans import assign_scan, block_batched

    sqrt_k = 6
    xs = jnp.stack([_mixture(1111, 5, 4, seed=i) for i in range(4)])  # B=4=2*2
    key = jax.random.key(7)
    res = kmeans_batched(key, xs, sqrt_k, 3)
    for bn in (256, 123, 1111):
        blocks, valid = block_batched(xs, bn)
        a, _, counts = assign_scan(blocks, valid, res.centroids, pair_sqrt_k=sqrt_k)
        a = np.asarray(a[:, :1111])
        want = np.stack([
            np.bincount(a[i] * sqrt_k + a[i + 2], minlength=sqrt_k * sqrt_k)
            for i in range(2)
        ])
        np.testing.assert_array_equal(np.asarray(counts), want)
        assert counts.dtype == jnp.int32
    with pytest.raises(ValueError, match="even batch"):
        blocks, valid = block_batched(xs[:3], 256)
        assign_scan(blocks, valid, res.centroids[:3], pair_sqrt_k=sqrt_k)


def test_kmeans_batched_pair_counts_threaded():
    """kmeans_batched(pair_sqrt_k=...) returns the fused histogram for both
    lloyd and minibatch, matching a bincount over the assignments."""
    sqrt_k = 5
    xs = jnp.stack([_mixture(900, 6, 4, seed=i) for i in range(6)])
    key = jax.random.key(1)
    for kw in (dict(block_n=200), dict(algo="minibatch", block_n=128), dict()):
        res = kmeans_batched(key, xs, sqrt_k, 4, pair_sqrt_k=sqrt_k, **kw)
        assert res.cell_counts is not None, kw
        a = np.asarray(res.assignments)
        want = np.stack([
            np.bincount(a[i] * sqrt_k + a[i + 3], minlength=sqrt_k * sqrt_k)
            for i in range(3)
        ])
        np.testing.assert_array_equal(np.asarray(res.cell_counts), want, err_msg=str(kw))
    # default: no histogram requested, None returned
    assert kmeans_batched(key, xs, sqrt_k, 2).cell_counts is None
