"""SuCoEngine subsystem: index persistence (bit-identical round trips,
version gating), bucketed executable compilation (jit cache stats), the
suco_query back-compat contract, and the continuous micro-batching ANN
server."""

import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    INDEX_ARTIFACT_VERSION,
    EnginePolicy,
    SuCoConfig,
    SuCoEngine,
    SuCoIndex,
    batch_bucket,
    build_index,
    load_index_artifact,
    suco_query,
)
from repro.data import make_dataset
from repro.serve.ann import AnnRequest, AnnServer, latency_summary

CFG = SuCoConfig(n_subspaces=8, sqrt_k=16, kmeans_iters=4, seed=0)
POLICY = EnginePolicy(alpha=0.05, beta=0.02, batch_buckets=(4, 16))


@pytest.fixture(scope="module")
def ds():
    return make_dataset("gaussian_mixture", 4000, 32, m=20, k=10, seed=0)


@pytest.fixture(scope="module")
def index(ds):
    return build_index(jnp.asarray(ds.x), CFG)


# ------------------------------ persistence ---------------------------------


def test_save_load_round_trip_bit_identical(ds, index, tmp_path):
    path = tmp_path / "index.npz"
    index.save(path, CFG)
    loaded, config = load_index_artifact(path)
    assert config == CFG
    assert loaded.spec == index.spec
    assert loaded.sqrt_k == index.sqrt_k
    for name in ("centroids1", "centroids2", "cell_ids", "cell_counts"):
        a, b = getattr(index, name), getattr(loaded, name)
        assert a.dtype == b.dtype, name
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)
    # the loaded index answers queries bit-identically
    x, q = jnp.asarray(ds.x), jnp.asarray(ds.queries)
    r1 = suco_query(x, index, q, k=10, alpha=0.05, beta=0.02)
    r2 = suco_query(x, loaded, q, k=10, alpha=0.05, beta=0.02)
    np.testing.assert_array_equal(np.asarray(r1.ids), np.asarray(r2.ids))
    np.testing.assert_array_equal(np.asarray(r1.dists), np.asarray(r2.dists))


def test_save_without_config_loads_none(index, tmp_path):
    path = tmp_path / "bare.npz"
    index.save(path)
    loaded, config = load_index_artifact(path)
    assert config is None
    assert loaded.n_points == index.n_points
    # SuCoIndex.load is the config-less convenience form
    again = SuCoIndex.load(path)
    np.testing.assert_array_equal(
        np.asarray(again.cell_ids), np.asarray(loaded.cell_ids)
    )


def test_version_mismatch_raises(index, tmp_path):
    path = tmp_path / "stale.npz"
    index.save(path)
    blob = dict(np.load(path))
    blob["version"] = np.asarray(INDEX_ARTIFACT_VERSION + 1, np.int32)
    with open(path, "wb") as f:
        np.savez(f, **blob)
    with pytest.raises(ValueError, match="version"):
        SuCoIndex.load(path)


def test_foreign_npz_rejected(tmp_path):
    path = tmp_path / "foreign.npz"
    with open(path, "wb") as f:
        np.savez(f, weights=np.zeros(3))
    with pytest.raises(ValueError, match="artifact"):
        load_index_artifact(path)


def test_truncated_artifact_raises_clear_artifact_error(index, tmp_path):
    """A bit-truncated artifact must raise ArtifactError naming the path —
    never leak a bare KeyError/BadZipFile into a serving process.  Checked
    at several cut points: before the zip directory, mid-payload, and a
    structurally valid npz missing required keys."""
    from repro.core.suco import ArtifactError

    path = tmp_path / "trunc.npz"
    index.save(path)
    raw = path.read_bytes()
    for frac in (0.25, 0.5, 0.9, 0.99):
        path.write_bytes(raw[: int(len(raw) * frac)])
        with pytest.raises(ArtifactError, match="trunc.npz") as ei:
            load_index_artifact(path)
        assert not isinstance(ei.value, KeyError)
    # ArtifactError subclasses ValueError: existing callers keep working
    path.write_bytes(raw[: len(raw) // 2])
    with pytest.raises(ValueError):
        SuCoIndex.load(path)


def test_artifact_missing_keys_named_in_error(index, tmp_path):
    from repro.core.suco import ArtifactError

    path = tmp_path / "partial.npz"
    index.save(path)
    blob = dict(np.load(path))
    for key in ("centroids1", "spec_perm", "sqrt_k"):
        blob.pop(key)
    with open(path, "wb") as f:
        np.savez(f, **blob)
    with pytest.raises(ArtifactError, match="missing keys") as ei:
        load_index_artifact(path)
    for key in ("centroids1", "spec_perm", "sqrt_k"):
        assert key in str(ei.value)


def test_artifact_version_error_reports_found_vs_expected(index, tmp_path):
    from repro.core.suco import ArtifactError

    path = tmp_path / "stale.npz"
    index.save(path)
    blob = dict(np.load(path))
    blob["version"] = np.asarray(INDEX_ARTIFACT_VERSION + 3, np.int32)
    with open(path, "wb") as f:
        np.savez(f, **blob)
    with pytest.raises(ArtifactError) as ei:
        load_index_artifact(path)
    msg = str(ei.value)
    assert str(INDEX_ARTIFACT_VERSION + 3) in msg  # found
    assert f"version {INDEX_ARTIFACT_VERSION}" in msg  # expected


# ------------------------------- bucketing ----------------------------------


def test_batch_bucket_policy():
    buckets = (4, 16)
    assert [batch_bucket(m, buckets) for m in (1, 4, 5, 16)] == [4, 4, 16, 16]
    # above the largest bucket: next power-of-two multiple, never a failure
    assert batch_bucket(17, buckets) == 32
    assert batch_bucket(100, buckets) == 128
    with pytest.raises(ValueError, match="batch size"):
        batch_bucket(0, buckets)


def test_engine_compiles_exactly_one_executable_per_bucket_k(ds, index):
    engine = SuCoEngine(jnp.asarray(ds.x), index, POLICY)
    assert engine.compile_count == 0  # jit cache stats: nothing yet
    n = engine.warmup(batch_sizes=(1, 3, 4), ks=(10,))
    assert n == 1  # all three sizes share bucket 4
    assert engine.compile_count == 1
    # served sizes inside a warmed bucket never retrace
    for m in (1, 2, 4):
        engine.query(jnp.asarray(ds.queries[:m]), k=10)
    assert engine.compile_count == 1
    # a second batch size -> exactly one more executable
    engine.query(jnp.asarray(ds.queries[:9]), k=10)  # bucket 16
    assert engine.compile_count == 2
    engine.query(jnp.asarray(ds.queries[:16]), k=10)
    assert engine.compile_count == 2
    # a second k on a warmed bucket -> exactly one more executable
    engine.query(jnp.asarray(ds.queries[:4]), k=5)
    assert engine.compile_count == 3
    stats = engine.stats()
    assert stats.executables == 3
    assert (4, 10) in stats.buckets and (16, 10) in stats.buckets


def test_engine_default_policy_entry_points(ds, index):
    """policy=None constructs a fresh default policy per engine — the
    documented default entry points work, and no traffic histogram is
    shared between default-constructed engines."""
    eng = SuCoEngine(jnp.asarray(ds.x), index)
    assert eng.mode == "dense" and eng.policy.alpha == EnginePolicy().alpha
    res = eng.query(jnp.asarray(ds.queries[:2]), k=5)
    assert np.asarray(res.ids).shape == (2, 5)
    other = SuCoEngine(jnp.asarray(ds.x), index)
    assert eng.policy is not other.policy
    assert dict(eng.policy.traffic) == {2: 1} and not other.policy.traffic


def test_engine_mode_resolved_once(ds, index):
    engine = SuCoEngine(jnp.asarray(ds.x), index, POLICY)
    assert engine.mode == "dense"  # n=4000 < STREAMING_MIN_N
    forced = SuCoEngine(
        jnp.asarray(ds.x), index, dataclasses.replace(POLICY, mode="streaming")
    )
    assert forced.mode == "streaming"
    with pytest.raises(ValueError, match="mode"):
        SuCoEngine(jnp.asarray(ds.x), index, dataclasses.replace(POLICY, mode="bogus"))


def test_engine_rejects_bad_requests(ds, index):
    engine = SuCoEngine(jnp.asarray(ds.x), index, POLICY)
    with pytest.raises(ValueError, match="k="):
        engine.query(jnp.asarray(ds.queries[:2]), k=ds.x.shape[0] + 1)
    with pytest.raises(ValueError, match="queries"):
        engine.query(jnp.zeros((2, 7), jnp.float32), k=5)


# ---------------------------- back-compat parity ----------------------------


def test_engine_bit_identical_to_suco_query(ds, index):
    """The acceptance contract: every padded engine path returns exactly
    what the suco_query wrapper returns on the unpadded batch — dense and
    (forced) streaming modes both."""
    x, q = jnp.asarray(ds.x), jnp.asarray(ds.queries)
    for mode in ("dense", "streaming"):
        engine = SuCoEngine(x, index, dataclasses.replace(POLICY, mode=mode))
        for m in (1, 3, 4, 16, 20):  # exact-bucket, padded, and oversize
            got = engine.query(q[:m], k=10)
            want = suco_query(
                x, index, q[:m], k=10, alpha=POLICY.alpha, beta=POLICY.beta,
                mode=mode,
            )
            np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(want.ids))
            np.testing.assert_array_equal(
                np.asarray(got.dists), np.asarray(want.dists)
            )
            np.testing.assert_array_equal(
                np.asarray(got.scores), np.asarray(want.scores)
            )


def test_engine_merge_impl_switch_zero_retrace(ds, index):
    """merge_impl is jit-static and rides EnginePolicy: warming an engine
    on either impl compiles once per (bucket, k), serving after warmup
    never retraces across the switch, and the counting-select merge
    answers bit-identically to the baseline top_k merge."""
    x, q = jnp.asarray(ds.x), jnp.asarray(ds.queries)
    results = {}
    for impl in ("topk", "counting"):
        policy = dataclasses.replace(POLICY, mode="fused", merge_impl=impl)
        engine = SuCoEngine(x, index, policy)
        engine.warmup(batch_sizes=(1, 4), ks=(10,))
        warm = engine.compile_count
        assert warm == 1  # sizes 1..4 share one bucket
        for m in (1, 2, 4):
            results[impl, m] = engine.query(q[:m], k=10)
        retraces_after_warmup = engine.compile_count - warm
        assert retraces_after_warmup == 0, impl
    for m in (1, 2, 4):
        a, b = results["topk", m], results["counting", m]
        np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
        np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))


def test_engine_single_query_form(ds, index):
    x, q = jnp.asarray(ds.x), jnp.asarray(ds.queries)
    engine = SuCoEngine(x, index, POLICY)
    got = engine.query(q[0], k=7)
    assert got.ids.shape == (7,)
    want = engine.query(q[:1], k=7)
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(want.ids[0]))


def test_engine_from_artifact(ds, index, tmp_path):
    path = tmp_path / "serve.npz"
    index.save(path)
    x, q = jnp.asarray(ds.x), jnp.asarray(ds.queries)
    engine = SuCoEngine.from_artifact(path, x, POLICY)
    got = engine.query(q, k=10)
    want = suco_query(x, index, q, k=10, alpha=POLICY.alpha, beta=POLICY.beta)
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(want.ids))


# ------------------------------- ANN server ---------------------------------


def test_ann_server_heterogeneous_requests(ds, index):
    engine = SuCoEngine(jnp.asarray(ds.x), index, POLICY)
    engine.warmup(batch_sizes=(1, 4), ks=(5, 10))
    warm = engine.compile_count
    server = AnnServer(engine, max_batch=4)
    ks = [10, 10, 5, 10, 5, 10]
    server.submit_many(
        [AnnRequest(i, ds.queries[i], k=k) for i, k in enumerate(ks)]
    )
    done = server.run_until_drained()
    assert len(done) == len(ks)
    assert engine.compile_count == warm, "server retraced after warmup"
    # same-k micro-batches, FIFO within each k; every result matches the
    # direct engine path for that single query
    for r in done:
        assert r.done and r.ids.shape == (r.k,)
        assert r.t_submit <= r.t_start <= r.t_done
        want = engine.query(ds.queries[r.rid], k=r.k)
        np.testing.assert_array_equal(r.ids, np.asarray(want.ids))
    # step accounting: compile count flat, buckets within policy
    assert [s.compile_count for s in server.steps] == [warm] * len(server.steps)
    assert all(s.n_requests <= 4 for s in server.steps)
    summary = latency_summary(done)
    assert summary["n_requests"] == len(ks)
    assert summary["p99_ms"] >= summary["p50_ms"] >= 0.0


def test_ann_server_malformed_request_does_not_sink_healthy_ones(ds, index):
    """A bad request completes-with-error; requests in other micro-batches
    still drain and succeed."""
    engine = SuCoEngine(jnp.asarray(ds.x), index, POLICY)
    server = AnnServer(engine, max_batch=4)
    server.submit(AnnRequest(0, ds.queries[0], k=ds.x.shape[0] + 1))  # bad k
    server.submit(AnnRequest(1, ds.queries[1], k=10))
    done = server.run_until_drained()
    assert len(done) == 2 and not server.queue
    by_rid = {r.rid: r for r in done}
    assert not by_rid[0].done and "k=" in by_rid[0].error
    assert by_rid[1].done and by_rid[1].error is None
    assert latency_summary(done)["n_requests"] == 1  # only the healthy one
