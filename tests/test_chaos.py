"""Fault-injection harness contracts: replay determinism (same seed + same
trace => identical outcome sets, sync and async), every injector actually
firing, deadline misses under latency spikes, flood shedding + controller
degradation with quantified bounds, and pool shard-death rebinding."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import EnginePolicy, SuCoConfig, SuCoEngine, build_index
from repro.data import make_dataset
from repro.serve.ann import (
    AnnServer,
    AsyncAnnServer,
    DegradationLadder,
    OverloadController,
)
from repro.serve.chaos import (
    ChaosConfig,
    ChaosEngine,
    ChaosError,
    VirtualClock,
    flood_trace,
    replay,
    wrap_ladder,
)

CFG = SuCoConfig(n_subspaces=8, sqrt_k=16, kmeans_iters=4, seed=0)


@pytest.fixture(scope="module")
def ds():
    return make_dataset("gaussian_mixture", 4000, 32, m=40, k=10, seed=0)


@pytest.fixture(scope="module")
def index(ds):
    return build_index(jnp.asarray(ds.x), CFG)


@pytest.fixture(scope="module")
def engine(ds, index):
    eng = SuCoEngine(
        jnp.asarray(ds.x), index,
        EnginePolicy(alpha=0.05, beta=0.02, batch_buckets=(4, 16)),
    )
    eng.warmup(batch_sizes=(1, 4, 16), ks=(10,))
    return eng


CHAOS = ChaosConfig(
    seed=7, service_s=0.004, p_engine_error=0.1,
    p_latency_spike=0.15, latency_spike_s=0.05,
)


def _chaos_replay(engine, server_cls, *, chaos=CHAOS, trace_seed=3,
                  n_requests=48, interarrival_s=0.001, deadline_s=0.05,
                  p_malformed=0.05, queries=None, **server_kw):
    clock = VirtualClock()
    ladder = DegradationLadder(engine, levels=2)
    ladder.warmup(batch_sizes=(1, 4), ks=(10,))
    wrap_ladder(ladder, chaos, clock)  # chaos hits the degraded paths too
    server = server_cls(
        ladder.engines[0], max_batch=4, clock=clock, sleep=clock.advance,
        max_queue=16, ladder=ladder,
        controller=OverloadController(high_depth=8, low_depth=2),
        **server_kw,
    )
    trace = flood_trace(
        n_requests, 32, interarrival_s=interarrival_s, deadline_s=deadline_s,
        p_malformed=p_malformed, seed=trace_seed, queries=queries,
    )
    return replay(server, trace, clock)


# ---- satellite: determinism ---------------------------------------------


@pytest.mark.parametrize("server_cls", [AnnServer, AsyncAnnServer])
def test_chaos_replay_is_deterministic(engine, server_cls):
    """Same chaos seed + same trace => identical completed/shed/expired/
    failed/degraded sets and identical counters across two replays."""
    r1 = _chaos_replay(engine, server_cls)
    r2 = _chaos_replay(engine, server_cls)
    assert r1.outcome_sets == r2.outcome_sets
    assert r1.max_level == r2.max_level
    assert r1.summary["n_shed"] == r2.summary["n_shed"]
    assert r1.summary["deadline_hit_rate"] == r2.summary["deadline_hit_rate"]


def test_chaos_seed_actually_changes_the_schedule(engine):
    """Different chaos seeds produce different fault schedules (guards
    against the injectors silently not consuming the rng).  Checked at the
    injector level: a resilient server can absorb mild fault-schedule
    differences without changing its outcome sets."""
    def schedule(seed):
        clock = VirtualClock()
        proxy = ChaosEngine(
            engine,
            ChaosConfig(seed=seed, p_engine_error=0.3, p_latency_spike=0.3),
            clock,
        )
        out = []
        for _ in range(32):
            try:
                proxy.query(np.zeros((1, 32), np.float32), k=10)
                out.append(("ok", proxy.n_spikes))
            except ChaosError:
                out.append(("err", proxy.n_spikes))
        return out

    assert schedule(0) == schedule(0)
    assert schedule(0) != schedule(1)


# ---- injectors ----------------------------------------------------------


def test_engine_error_injector_fires_and_is_survived(engine):
    clock = VirtualClock()
    proxy = ChaosEngine(
        engine, ChaosConfig(seed=0, p_engine_error=1.0), clock
    )
    with pytest.raises(ChaosError):
        proxy.query(np.zeros((1, 32), np.float32), k=10)
    assert proxy.n_errors == 1
    # a server over an always-erroring engine fails requests, not itself
    server = AnnServer(proxy, max_batch=4, clock=clock, sleep=clock.advance)
    from repro.serve.ann import AnnRequest
    server.submit(AnnRequest(0, np.zeros(32, np.float32), k=10))
    done = server.run_until_drained()
    assert done[0].error is not None and "injected engine failure" in done[0].error


def test_latency_spike_injector_causes_deadline_misses(engine):
    """With spikes far beyond the deadline budget, deadlined requests
    expire; without spikes (same seed, same trace) none do."""
    spiky = _chaos_replay(
        engine, AnnServer,
        chaos=ChaosConfig(seed=1, service_s=0.004, p_latency_spike=0.5,
                          latency_spike_s=0.2),
        deadline_s=0.03, p_malformed=0.0,
    )
    calm = _chaos_replay(
        engine, AnnServer,
        chaos=ChaosConfig(seed=1, service_s=0.004),
        deadline_s=0.03, p_malformed=0.0,
    )
    assert len(spiky.expired) > 0
    assert spiky.summary["deadline_hit_rate"] < calm.summary["deadline_hit_rate"]


def test_malformed_injector_rejected_per_request(engine, ds):
    r = _chaos_replay(
        engine, AnnServer,
        chaos=ChaosConfig(seed=2, service_s=0.001),
        p_malformed=0.3, deadline_s=None, queries=np.asarray(ds.queries),
    )
    assert len(r.failed) > 0  # the poisoned requests
    assert len(r.completed) > 0  # the healthy ones around them
    assert r.completed.isdisjoint(r.failed)


def test_flood_sheds_and_degrades_with_admission_control(engine):
    """A flood (arrivals far above service rate) trips the bounded queue
    and the overload controller: requests shed, answers degrade with
    quality bounds attached, and the zero-retrace invariant holds."""
    r = _chaos_replay(
        engine, AnnServer,
        chaos=ChaosConfig(seed=4, service_s=0.02),
        n_requests=64, interarrival_s=0.0002, deadline_s=None, p_malformed=0.0,
    )
    assert len(r.shed) > 0
    assert len(r.degraded) > 0 and r.max_level >= 1
    assert r.summary["quality_bound_min"] < 1.0
    assert r.retraces == 0


def test_flood_with_control_beats_uncontrolled_on_deadlines(engine):
    """The acceptance comparison: under the same flood, admission control +
    degradation keeps the deadline hit rate strictly above the
    uncontrolled server's (which queues everything and misses en masse)."""
    def run(controlled):
        clock = VirtualClock()
        cfg = ChaosConfig(seed=5, service_s=0.02)
        proxy = ChaosEngine(engine, cfg, clock)
        kw = {}
        if controlled:
            ladder = DegradationLadder(engine, levels=2)
            ladder.warmup(batch_sizes=(1, 4), ks=(10,))
            wrap_ladder(ladder, cfg, clock)
            proxy = ladder.engines[0]
            kw = dict(max_queue=8, ladder=ladder,
                      controller=OverloadController(high_depth=4, low_depth=1))
        server = AnnServer(proxy, max_batch=4, clock=clock,
                           sleep=clock.advance, **kw)
        trace = flood_trace(64, 32, interarrival_s=0.0002, deadline_s=0.1,
                            seed=6)
        return replay(server, trace, clock)

    with_ctrl, without = run(True), run(False)
    assert (
        with_ctrl.summary["deadline_hit_rate"]
        > without.summary["deadline_hit_rate"]
    )
    assert without.summary["deadline_hit_rate"] < 0.5  # it really floods
    assert with_ctrl.retraces == 0


# ---- trace / clock primitives -------------------------------------------


def test_virtual_clock_monotone():
    c = VirtualClock()
    assert c() == 0.0
    c.advance(1.5)
    assert c() == 1.5
    with pytest.raises(ValueError, match="backwards"):
        c.advance(-1.0)


def test_flood_trace_deterministic_and_sorted():
    t1 = flood_trace(16, 8, p_malformed=0.25, seed=9)
    t2 = flood_trace(16, 8, p_malformed=0.25, seed=9)
    assert [a for a, _ in t1] == sorted(a for a, _ in t1)
    for (a1, q1), (a2, q2) in zip(t1, t2):
        assert a1 == a2 and q1.k == q2.k
        np.testing.assert_array_equal(q1.query, q2.query)
    n_bad = sum(1 for _, q in t1 if not np.isfinite(q.query).all())
    assert 0 < n_bad < 16


def test_chaos_config_validates_probabilities():
    with pytest.raises(ValueError, match="p_engine_error"):
        ChaosConfig(p_engine_error=1.5)
