"""Recall-guarantee suite: the answers, not just the speed.

The SC framework's point (paper Theorems 1-2) is that subspace collision
answers k-ANN queries with a provable success probability.  These tests
hold every serving path to that bound on synthetic Gaussian (``uniform``,
the hard high-LID regime) and clustered (``gaussian_mixture``, the
SIFT/Deep-like regime) datasets, against brute-force ground truth:

* **theory bound** — ``theorem2_bound`` lower-bounds the probability that
  a query is *answered* (the true nearest neighbour appears in the
  returned top-k).  The empirical success rate must meet it, per dataset
  and seed.  Note the bound is about answering the query, not about the
  full top-k overlap: recall@k on high-LID data is legitimately far below
  it while the 1-NN success rate stays above.
* **recall floors** — recall@k (mean |R ∩ R*| / k) must clear an explicit
  per-regime floor, so a quality regression cannot hide behind the
  weaker success-rate metric.
* **path identity** — dense, streaming and engine paths must report
  *identical* recall (they are bit-identical by contract; asserting
  through the recall metric locks the contract to the quality number),
  and the sharded path must independently clear the same bound/floor.

Everything is deterministic: fixed seeds, fixed datasets, jax CPU — a
pass today is a pass tomorrow, there is no statistical flake.

The default-sized cases run everywhere; the nightly-sized streaming case
is ``@pytest.mark.slow`` (CI deselects ``slow`` — see ci.yml).
"""


import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import EnginePolicy, SuCoConfig, SuCoEngine, build_index, suco_query
from repro.core.theory import subspace_statistics, theorem2_bound
from repro.data import make_dataset, recall

N, D, M, K = 4000, 32, 32, 10
NS, SQRT_K, ITERS = 8, 16, 6

# (alpha, beta) per data regime, with an explicit recall@k floor: clustered
# data is the paper's low-LID sweet spot; iid Gaussian is the hard regime
# where a bigger candidate pool (beta) is needed for usable overlap.
PARAMS = {
    "gaussian_mixture": dict(alpha=0.05, beta=0.02, floor=0.95),
    "uniform": dict(alpha=0.10, beta=0.05, floor=0.60),
}
CASES = [(kind, seed) for kind in PARAMS for seed in (0, 1)]

_cache: dict = {}


def _case(kind: str, seed: int):
    """(dataset, index, theory bound, params) for one (kind, seed) cell."""
    key = (kind, seed)
    if key not in _cache:
        ds = make_dataset(kind, N, D, m=M, k=K, seed=seed)
        cfg = SuCoConfig(n_subspaces=NS, sqrt_k=SQRT_K, kmeans_iters=ITERS, seed=seed)
        index = build_index(jnp.asarray(ds.x), cfg)
        p = PARAMS[kind]
        stats = [subspace_statistics(ds.x, q, NS) for q in ds.queries]
        mean = float(np.mean([s[0] for s in stats]))
        sigma = float(np.mean([s[1] for s in stats]))
        bound = theorem2_bound(N, K, NS, mean, sigma, p["alpha"])
        _cache[key] = (ds, index, bound, p)
    return _cache[key]


def _success_rate(ids: np.ndarray, gt_ids: np.ndarray) -> float:
    """Fraction of queries whose true nearest neighbour is in the returned
    top-k — the event Theorem 2 lower-bounds."""
    return float(
        np.mean([int(gt_ids[i, 0]) in set(map(int, ids[i])) for i in range(len(ids))])
    )


@pytest.mark.parametrize("kind,seed", CASES)
def test_recall_meets_theory_bound(kind, seed):
    ds, index, bound, p = _case(kind, seed)
    assert 0.5 <= bound <= 1.0, f"vacuous theory bound {bound} — bad test params"
    res = suco_query(
        jnp.asarray(ds.x), index, jnp.asarray(ds.queries),
        k=K, alpha=p["alpha"], beta=p["beta"],
    )
    ids = np.asarray(res.ids)
    succ = _success_rate(ids, ds.gt_ids)
    assert succ >= bound, (
        f"{kind}/seed{seed}: success rate {succ} below theory bound {bound}"
    )
    r = recall(ids, ds.gt_ids)
    assert r >= p["floor"], f"{kind}/seed{seed}: recall@{K} {r} below floor {p['floor']}"


@pytest.mark.parametrize("kind,seed", CASES)
def test_dense_streaming_engine_report_identical_recall(kind, seed):
    """The three local serving paths are one quality surface: identical ids,
    therefore identical recall — asserted through the metric so the
    bit-identity contract is visibly a recall contract too."""
    ds, index, _, p = _case(kind, seed)
    x, q = jnp.asarray(ds.x), jnp.asarray(ds.queries)
    results = {
        mode: suco_query(x, index, q, k=K, alpha=p["alpha"], beta=p["beta"], mode=mode)
        for mode in ("dense", "streaming", "fused")
    }
    engine = SuCoEngine(
        x, index,
        EnginePolicy(alpha=p["alpha"], beta=p["beta"], batch_buckets=(8, 32)),
    )
    results["engine"] = engine.query(q, k=K)  # padded bucket path
    recalls = {name: recall(np.asarray(r.ids), ds.gt_ids) for name, r in results.items()}
    assert (
        recalls["dense"] == recalls["streaming"] == recalls["fused"]
        == recalls["engine"]
    ), recalls
    for name in ("streaming", "fused", "engine"):
        np.testing.assert_array_equal(
            np.asarray(results["dense"].ids), np.asarray(results[name].ids)
        )


def test_sharded_path_meets_theory_bound():
    """The sharded engine clears the same bound/floor on a 1-device mesh
    (the multi-device form runs in the distributed subprocess suite)."""
    from repro.distributed.engine import DistSuCoConfig, ShardedSuCoEngine
    from repro.launch.mesh import compat_make_mesh

    kind, seed = "gaussian_mixture", 0
    ds, index, bound, p = _case(kind, seed)
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    cfg = DistSuCoConfig(
        n_subspaces=NS, sqrt_k=SQRT_K, alpha=p["alpha"], beta=p["beta"],
        k=K, q_chunk=16, point_axes=("data",),
    )
    eng = ShardedSuCoEngine(mesh, cfg, jnp.asarray(ds.x), index)
    eng.warmup(batch_sizes=(M,))
    ids, _ = eng.query(jnp.asarray(ds.queries))
    ids = np.asarray(ids)
    succ = _success_rate(ids, ds.gt_ids)
    assert succ >= bound, f"sharded success rate {succ} below theory bound {bound}"
    assert recall(ids, ds.gt_ids) >= p["floor"]
    assert eng.compile_count == 1  # and it did so without retracing


@pytest.mark.slow
def test_recall_nightly_streaming_scale():
    """Nightly-sized case: the auto regime at n >= STREAMING_MIN_N (the
    fused single-pass engine since PR 5) must clear the same guarantee —
    the pool merge path, not just the dense reference, owns the recall
    contract at scale."""
    kind, seed = "gaussian_mixture", 0
    n, m = 40_000, 16
    ds = make_dataset(kind, n, D, m=m, k=K, seed=seed)
    p = PARAMS[kind]
    engine = SuCoEngine.build(
        jnp.asarray(ds.x),
        SuCoConfig(n_subspaces=NS, sqrt_k=SQRT_K, kmeans_iters=4, seed=seed),
        policy=EnginePolicy(alpha=p["alpha"], beta=p["beta"]),
    )
    assert engine.mode == "fused"  # the streaming-scale default
    stats = [subspace_statistics(ds.x, q, NS) for q in ds.queries]
    bound = theorem2_bound(
        n, K, NS,
        float(np.mean([s[0] for s in stats])),
        float(np.mean([s[1] for s in stats])),
        p["alpha"],
    )
    ids = np.asarray(engine.query(jnp.asarray(ds.queries), k=K).ids)
    succ = _success_rate(ids, ds.gt_ids)
    assert succ >= bound, f"streaming-scale success rate {succ} below bound {bound}"
    assert recall(ids, ds.gt_ids) >= p["floor"]
