"""IMI + Multi-sequence baseline (OPQ-lite, the VQ/PQ state of the art's
retrieval structure with M=2 subquantisers; numpy).

This is exactly the index SuCo borrows — but used the *original* way: one
global IMI over the full space, fine-grained cells, Multi-sequence
traversal, candidates re-ranked exactly.  The contrast with SuCo (many
coarse per-subspace IMIs + collision counting) is the paper's §5.5 story.
"""

from __future__ import annotations

import numpy as np

from repro.core.da_numpy import multi_sequence

__all__ = ["IMIPQ"]


class IMIPQ:
    def __init__(self, sqrt_k: int = 128, iters: int = 10, seed: int = 0):
        self.sqrt_k = sqrt_k
        self.iters = iters
        self.seed = seed

    def _kmeans(self, x: np.ndarray, k: int, rng) -> tuple[np.ndarray, np.ndarray]:
        c = x[rng.choice(x.shape[0], k, replace=False)].copy()
        for _ in range(self.iters):
            d2 = (x**2).sum(1)[:, None] + (c**2).sum(1)[None, :] - 2 * x @ c.T
            a = d2.argmin(1)
            for j in range(k):
                m = a == j
                if m.any():
                    c[j] = x[m].mean(0)
        d2 = (x**2).sum(1)[:, None] + (c**2).sum(1)[None, :] - 2 * x @ c.T
        return c, d2.argmin(1)

    def build(self, x: np.ndarray) -> "IMIPQ":
        rng = np.random.default_rng(self.seed)
        d = x.shape[1]
        self.h = d // 2
        self.c1, a1 = self._kmeans(x[:, : self.h], self.sqrt_k, rng)
        self.c2, a2 = self._kmeans(x[:, self.h :], self.sqrt_k, rng)
        cell = a1 * self.sqrt_k + a2
        self.counts = np.bincount(cell, minlength=self.sqrt_k**2).reshape(
            self.sqrt_k, self.sqrt_k
        )
        order = np.argsort(cell, kind="stable")
        self.sorted_ids = order
        self.offsets = np.zeros(self.sqrt_k**2 + 1, dtype=np.int64)
        np.cumsum(self.counts.reshape(-1), out=self.offsets[1:])
        self.x = x
        return self

    def memory_bytes(self) -> int:
        return (
            self.c1.nbytes + self.c2.nbytes + self.counts.nbytes
            + self.sorted_ids.nbytes + self.offsets.nbytes
        )

    def query(self, q: np.ndarray, k: int, n_candidates: int = 1000) -> np.ndarray:
        out = np.zeros((q.shape[0], k), dtype=np.int64)
        for i, qi in enumerate(q):
            d1 = ((self.c1 - qi[: self.h]) ** 2).sum(1)
            d2 = ((self.c2 - qi[self.h :]) ** 2).sum(1)
            cells = multi_sequence(d1, d2, self.counts, n_candidates)
            cand = np.concatenate(
                [
                    self.sorted_ids[
                        self.offsets[c1 * self.sqrt_k + c2] : self.offsets[
                            c1 * self.sqrt_k + c2
                        ]
                        + self.counts[c1, c2]
                    ]
                    for c1, c2 in cells
                ]
            )
            if cand.size < k:
                cand = np.arange(self.x.shape[0])
            d = ((self.x[cand] - qi) ** 2).sum(1)
            out[i] = cand[np.argsort(d, kind="stable")[:k]]
        return out
