"""HNSW-lite baseline (the graph family's state of the art; numpy).

Single-layer NSW with an HNSW-style entry hierarchy collapsed to greedy
restarts — keeps the characteristic index/query trade-off (expensive
neighbour identification at build, converging greedy walk at query) at a
size the CPU container can build.
"""

from __future__ import annotations

import heapq

import numpy as np

__all__ = ["HNSWLite"]


class HNSWLite:
    def __init__(self, m: int = 16, ef_construction: int = 64, seed: int = 0):
        self.m = m
        self.efc = ef_construction
        self.seed = seed

    def _search(self, q: np.ndarray, ef: int, n_nodes: int) -> list[tuple[float, int]]:
        """Beam search over the current graph; returns (dist, id) ascending."""
        x = self.x
        start = self.entry
        d0 = float(((x[start] - q) ** 2).sum())
        visited = {start}
        cand = [(d0, start)]  # min-heap of frontier
        best: list[tuple[float, int]] = [(-d0, start)]  # max-heap of results
        while cand:
            d, u = heapq.heappop(cand)
            if d > -best[0][0] and len(best) >= ef:
                break
            for v in self.links[u]:
                if v in visited:
                    continue
                visited.add(v)
                dv = float(((x[v] - q) ** 2).sum())
                if len(best) < ef or dv < -best[0][0]:
                    heapq.heappush(cand, (dv, v))
                    heapq.heappush(best, (-dv, v))
                    if len(best) > ef:
                        heapq.heappop(best)
        return sorted((-nd, i) for nd, i in best)

    def build(self, x: np.ndarray) -> "HNSWLite":
        n = x.shape[0]
        self.x = x
        self.links: list[list[int]] = [[] for _ in range(n)]
        self.entry = 0
        for i in range(1, n):
            res = self._search(x[i], self.efc, i)
            nbrs = [v for _, v in res[: self.m]]
            self.links[i] = nbrs
            for v in nbrs:
                self.links[v].append(i)
                if len(self.links[v]) > 2 * self.m:
                    # prune to the closest 2M (simple heuristic)
                    dd = ((x[self.links[v]] - x[v]) ** 2).sum(1)
                    keep = np.argsort(dd, kind="stable")[: 2 * self.m]
                    self.links[v] = [self.links[v][j] for j in keep]
        return self

    def memory_bytes(self) -> int:
        return sum(8 * len(lk) + 56 for lk in self.links)

    def query(self, q: np.ndarray, k: int, ef_search: int = 64) -> np.ndarray:
        out = np.zeros((q.shape[0], k), dtype=np.int64)
        for i, qi in enumerate(q):
            res = self._search(qi, max(ef_search, k), self.x.shape[0])
            ids = [v for _, v in res[:k]]
            while len(ids) < k:
                ids.append(ids[-1] if ids else 0)
            out[i] = ids
        return out
