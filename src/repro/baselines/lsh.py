"""E2LSH-style collision-counting baseline (the LSH family; numpy).

L tables x K p-stable projections; a point is a candidate when it collides
with the query in >= ``threshold`` tables (C2LSH/QALSH-style counting),
then candidates are re-ranked exactly.  Provides-guarantees family.
"""

from __future__ import annotations

import numpy as np

__all__ = ["E2LSH"]


class E2LSH:
    def __init__(self, n_tables: int = 8, n_bits: int = 12, w: float = 4.0, seed: int = 0):
        self.L = n_tables
        self.K = n_bits
        self.w = w
        self.seed = seed

    def build(self, x: np.ndarray) -> "E2LSH":
        rng = np.random.default_rng(self.seed)
        n, d = x.shape
        self.a = rng.normal(size=(self.L, self.K, d)).astype(np.float32)
        self.b = (rng.random((self.L, self.K)) * self.w).astype(np.float32)
        # (L, n, K) bucket coordinates -> hashed to one int per table
        codes = np.floor(
            (np.einsum("lkd,nd->lnk", self.a, x) + self.b[:, None, :]) / self.w
        ).astype(np.int64)
        self.tables: list[dict[int, np.ndarray]] = []
        mult = rng.integers(1, 2**31, size=self.K)
        self.mult = mult
        for li in range(self.L):
            h = (codes[li] * mult[None, :]).sum(1)
            tab: dict[int, list[int]] = {}
            for i, hv in enumerate(h):
                tab.setdefault(int(hv), []).append(i)
            self.tables.append({k: np.asarray(v, np.int64) for k, v in tab.items()})
        self.x = x
        return self

    def memory_bytes(self) -> int:
        b = self.a.nbytes + self.b.nbytes
        for tab in self.tables:
            b += sum(v.nbytes + 8 for v in tab.values())
        return b

    def query(self, q: np.ndarray, k: int, threshold: int = 1) -> np.ndarray:
        out = np.zeros((q.shape[0], k), dtype=np.int64)
        n = self.x.shape[0]
        for i, qi in enumerate(q):
            codes = np.floor(
                ((self.a @ qi) + self.b) / self.w
            ).astype(np.int64)  # (L, K)
            counts = np.zeros(n, dtype=np.int32)
            for li in range(self.L):
                hv = int((codes[li] * self.mult).sum())
                hit = self.tables[li].get(hv)
                if hit is not None:
                    counts[hit] += 1
            cand = np.nonzero(counts >= threshold)[0]
            if cand.size < k:
                cand = np.arange(n)
            d = ((self.x[cand] - qi) ** 2).sum(1)
            out[i] = cand[np.argsort(d, kind="stable")[:k]]
        return out
