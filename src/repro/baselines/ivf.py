"""IVF-Flat baseline (the VQ family's simplest member; numpy).

K-means over the full space; query probes the ``nprobe`` nearest cells and
scans their inverted lists exactly.
"""

from __future__ import annotations

import numpy as np

__all__ = ["IVFFlat"]


class IVFFlat:
    def __init__(self, n_cells: int = 256, iters: int = 10, seed: int = 0):
        self.n_cells = n_cells
        self.iters = iters
        self.seed = seed

    def build(self, x: np.ndarray) -> "IVFFlat":
        rng = np.random.default_rng(self.seed)
        n = x.shape[0]
        c = x[rng.choice(n, self.n_cells, replace=False)].copy()
        for _ in range(self.iters):
            d2 = ((x**2).sum(1)[:, None] + (c**2).sum(1)[None, :] - 2 * x @ c.T)
            a = d2.argmin(1)
            for j in range(self.n_cells):
                m = a == j
                if m.any():
                    c[j] = x[m].mean(0)
        d2 = ((x**2).sum(1)[:, None] + (c**2).sum(1)[None, :] - 2 * x @ c.T)
        a = d2.argmin(1)
        self.centroids = c
        self.lists = [np.nonzero(a == j)[0] for j in range(self.n_cells)]
        self.x = x
        return self

    def memory_bytes(self) -> int:
        return self.centroids.nbytes + sum(arr.nbytes for arr in self.lists)

    def query(self, q: np.ndarray, k: int, nprobe: int = 8) -> np.ndarray:
        out = np.zeros((q.shape[0], k), dtype=np.int64)
        for i, qi in enumerate(q):
            dc = ((self.centroids - qi) ** 2).sum(1)
            cells = np.argpartition(dc, min(nprobe, len(dc) - 1))[:nprobe]
            cand = np.concatenate([self.lists[c] for c in cells]) if nprobe else np.array([], np.int64)
            if cand.size == 0:
                cand = np.arange(min(k, self.x.shape[0]))
            d = ((self.x[cand] - qi) ** 2).sum(1)
            sel = np.argsort(d, kind="stable")[:k]
            ids = cand[sel]
            if ids.size < k:
                ids = np.pad(ids, (0, k - ids.size), constant_values=ids[0] if ids.size else 0)
            out[i] = ids[:k]
        return out
