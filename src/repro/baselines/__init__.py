"""Competitor baselines, one per family the paper compares against (§5):

  E2LSH      — LSH / collision counting (guarantees family)
  IVFFlat    — vector quantisation, coarse inverted file
  IMIPQ      — IMI + Multi-sequence (OPQ-lite, M=2)
  HNSWLite   — proximity graph
  RPForest   — random-projection trees (Annoy-style)
  brute      — exact scan (ground truth / reference cost)
"""

from repro.baselines.ivf import IVFFlat
from repro.baselines.lsh import E2LSH
from repro.baselines.imi_pq import IMIPQ
from repro.baselines.hnsw import HNSWLite
from repro.baselines.rpforest import RPForest

__all__ = ["IVFFlat", "E2LSH", "IMIPQ", "HNSWLite", "RPForest"]
