"""Random-projection forest baseline (Annoy-style trees; numpy)."""

from __future__ import annotations

import numpy as np

__all__ = ["RPForest"]


class _Node:
    __slots__ = ("w", "b", "left", "right", "ids")

    def __init__(self, w=None, b=0.0, left=None, right=None, ids=None):
        self.w, self.b, self.left, self.right, self.ids = w, b, left, right, ids


class RPForest:
    def __init__(self, n_trees: int = 8, leaf_size: int = 64, seed: int = 0):
        self.n_trees = n_trees
        self.leaf_size = leaf_size
        self.seed = seed

    def _build(self, ids: np.ndarray, rng) -> _Node:
        if ids.size <= self.leaf_size:
            return _Node(ids=ids)
        w = rng.normal(size=self.x.shape[1]).astype(np.float32)
        proj = self.x[ids] @ w
        b = float(np.median(proj))
        left = ids[proj <= b]
        right = ids[proj > b]
        if left.size == 0 or right.size == 0:
            return _Node(ids=ids)
        return _Node(w=w, b=b, left=self._build(left, rng), right=self._build(right, rng))

    def build(self, x: np.ndarray) -> "RPForest":
        self.x = x
        rng = np.random.default_rng(self.seed)
        ids = np.arange(x.shape[0])
        self.trees = [self._build(ids, rng) for _ in range(self.n_trees)]
        return self

    def memory_bytes(self) -> int:
        total = 0
        stack = list(self.trees)
        while stack:
            nd = stack.pop()
            if nd.ids is not None:
                total += nd.ids.nbytes
            else:
                total += nd.w.nbytes + 8
                stack.extend([nd.left, nd.right])
        return total

    def query(self, q: np.ndarray, k: int, search_k: int | None = None) -> np.ndarray:
        search_k = search_k or (self.n_trees * self.leaf_size)
        out = np.zeros((q.shape[0], k), dtype=np.int64)
        for i, qi in enumerate(q):
            cand: list[np.ndarray] = []
            got = 0
            for t in self.trees:
                nd = t
                while nd.ids is None:
                    nd = nd.left if qi @ nd.w <= nd.b else nd.right
                cand.append(nd.ids)
                got += nd.ids.size
                if got >= search_k:
                    break
            cc = np.unique(np.concatenate(cand))
            if cc.size < k:
                cc = np.arange(self.x.shape[0])
            d = ((self.x[cc] - qi) ** 2).sum(1)
            out[i] = cc[np.argsort(d, kind="stable")[:k]]
        return out
