"""Training driver: synthetic-data LM training with checkpoint/restart,
straggler monitoring, optional microbatching and (shard_map DP path)
int8 gradient compression.

CPU-scale usage (the e2e example drives this):
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-4b --reduced \
      --steps 100 --global-batch 8 --seq-len 128 --ckpt-dir /tmp/ckpt

On a real cluster the same driver runs under the production mesh
(``--mesh prod`` / ``--mesh prod2``); the data pipeline, checkpointing and
restart logic are mesh-agnostic.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config, ARCH_IDS
from repro.data.lm_data import LMDataConfig, SyntheticLM
from repro.models import Model
from repro.train import checkpoint as CKPT
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.resilience import FailureInjector, StepTimer
from repro.train.train_step import make_train_step


def build(args):
    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if args.d_model:
        cfg = dataclasses.replace(
            cfg, d_model=args.d_model, d_ff=args.d_model * 4,
            head_dim=args.d_model // cfg.n_heads,
        )
    model = Model(cfg)
    opt_cfg = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                        total_steps=args.steps)
    step_fn = make_train_step(model, opt_cfg, micro_steps=args.micro_steps,
                              remat=not args.no_remat)
    data = SyntheticLM(LMDataConfig(cfg.vocab_size, args.seq_len, args.global_batch,
                                    seed=args.seed))
    return cfg, model, step_fn, data


def train_once(args, injector: FailureInjector | None = None) -> int:
    cfg, model, step_fn, data = build(args)
    jitted = jax.jit(step_fn, donate_argnums=(0, 1))

    start_step = 0
    params = opt_state = None
    if args.ckpt_dir and CKPT.latest_step(args.ckpt_dir) is not None:
        p_like = jax.eval_shape(lambda k: model.init(k), jax.random.key(args.seed))
        o_like = jax.eval_shape(init_opt_state, p_like)
        start_step, params, opt_state, extra = CKPT.restore(
            args.ckpt_dir, params_like=p_like, opt_state_like=o_like
        )
        print(f"[train] resumed from step {start_step}")
    if params is None:
        params = model.init(jax.random.key(args.seed))
        opt_state = init_opt_state(params)

    timer = StepTimer()
    losses = []
    for step in range(start_step, args.steps):
        if injector is not None:
            injector.maybe_fail(step)
        batch_np = data.batch_at(step)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        timer.start()
        params, opt_state, metrics = jitted(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = timer.stop()
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train] step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):8.3f} {dt*1e3:7.1f} ms"
                  + (" [straggler]" if timer.is_straggler(dt) else ""))
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            CKPT.save(args.ckpt_dir, step + 1, params=params, opt_state=opt_state,
                      extra={"loss": loss}, blocking=False)
    if args.ckpt_dir:
        CKPT.save(args.ckpt_dir, args.steps, params=params, opt_state=opt_state,
                  extra={"loss": losses[-1] if losses else None}, blocking=True)
    if losses:
        print(f"[train] done. first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")
    return args.steps


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--micro-steps", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--mesh", default="none", choices=("none", "debug", "prod", "prod2"))
    args = ap.parse_args()
    train_once(args)


if __name__ == "__main__":
    main()
