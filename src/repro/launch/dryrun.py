import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script:
  1. builds the production mesh ((16,16) single-pod / (2,16,16) multi-pod),
  2. lowers the right step function (train_step / prefill / decode_step)
     against ShapeDtypeStruct inputs with explicit in/out shardings,
  3. compiles it (XLA SPMD partitioning for 512 fake host devices),
  4. records memory_analysis / cost_analysis / per-collective byte counts
     (parsed from the optimized HLO) into benchmarks/results/<cell>.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""

import argparse
import math
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCH_IDS, get_config
from repro.models import Model, SHAPES, input_specs
from repro.models.model import ShapeSpec
from repro.models.shard_ctx import activation_sharding
from repro.launch.mesh import make_production_mesh
from repro.launch import shardings as SH
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_train_step

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results"

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in the optimized HLO.

    This is the per-device traffic proxy used for the roofline collective
    term (operand bytes == output bytes for all-reduce; for all-gather the
    output is the gathered buffer each device materialises).
    """
    per_kind: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)$", ls)
        if not m:
            continue
        rhs = m.group(1)
        kind = None
        for k in _COLLECTIVES:
            if re.search(rf"\b{k}(-start)?\(", rhs):
                kind = k
                break
        if kind is None:
            continue
        # output type is the leading "(tuple)" or single shape on the rhs
        shapes = _SHAPE_RE.findall(rhs.split(f"{kind}")[0])
        nbytes = 0
        for dt, dims in shapes:
            if dt not in _DTYPE_BYTES:
                continue
            size = 1
            for d in dims.split(","):
                if d:
                    size *= int(d)
            nbytes += size * _DTYPE_BYTES[dt]
        per_kind[kind] += nbytes
        counts[kind] += 1
    total = sum(per_kind.values())
    return {"total_bytes": total, "per_kind_bytes": per_kind, "counts": counts}


def _cell_name(arch: str, shape: str, multi_pod: bool) -> str:
    return f"{arch}__{shape}__{'pod2' if multi_pod else 'pod1'}"


def should_skip(cfg, shape: ShapeSpec) -> str | None:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return (
            "long_500k skipped: pure full-attention arch (sub-quadratic rule, "
            "see DESIGN.md §6)"
        )
    return None


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               act_sharding: bool = True):
    """Build + lower + compile one cell. Returns the result record."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = should_skip(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": skip}

    # nested-jit traces cache mesh-specific sharding constraints; clear
    # between cells so pod1/pod2 lowerings never share stale constraints
    jax.clear_caches()
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = Model(cfg)
    p_shapes = model.param_shapes()
    p_specs = SH.param_specs(cfg, mesh, p_shapes)
    ins = input_specs(cfg, shape)
    b_specs = SH.batch_specs(cfg, mesh, shape, ins)

    t0 = time.time()
    sh = lambda specs: SH.to_shardings(mesh, specs)
    import contextlib

    act_ctx = activation_sharding(mesh) if act_sharding else contextlib.nullcontext()
    if shape.kind == "train":
        opt_shapes = jax.eval_shape(init_opt_state, p_shapes)
        o_specs = SH.opt_state_specs(cfg, mesh, opt_shapes)
        step = make_train_step(model, OptConfig(), remat=True)
        jitted = jax.jit(
            step,
            in_shardings=(sh(p_specs), sh(o_specs), sh(b_specs)),
            out_shardings=(sh(p_specs), sh(o_specs), None),
            donate_argnums=(0, 1),
        )
        with act_ctx:
            lowered = jitted.lower(p_shapes, opt_shapes, ins)
    elif shape.kind == "prefill":
        def fn(params, tokens, extras=None):
            return model.prefill(params, tokens, extras=extras, max_seq=shape.seq_len)

        from repro.models import decode as D

        cache_shapes = jax.eval_shape(
            lambda: D.init_cache(cfg, shape.global_batch, shape.seq_len)
        )
        cache_sp = SH.fit_tree(SH.cache_specs(cfg, mesh, shape), cache_shapes, mesh)
        args = [p_shapes, ins["tokens"]]
        in_sh = [sh(p_specs), sh(b_specs["tokens"])]
        if "extras" in ins:
            args.append(ins["extras"])
            in_sh.append(sh(b_specs["extras"]))
        ba = SH.batch_axes(mesh)
        from jax.sharding import PartitionSpec as P

        logits_sp = SH.fit_spec(
            P(ba, "model"), (shape.global_batch, cfg.padded_vocab), mesh
        )
        jitted = jax.jit(
            fn,
            in_shardings=tuple(in_sh),
            out_shardings=(sh(logits_sp), sh(cache_sp)),
        )
        with act_ctx:
            lowered = jitted.lower(*args)
    else:  # decode
        def fn(params, cache, token, pos):
            return model.decode_step(params, cache, token, pos)

        from jax.sharding import PartitionSpec as P

        ba = SH.batch_axes(mesh)
        logits_spec = P(None, "model") if shape.global_batch == 1 else P(ba, "model")
        logits_spec = SH.fit_spec(
            logits_spec, (shape.global_batch, cfg.padded_vocab), mesh
        )
        jitted = jax.jit(
            fn,
            in_shardings=(
                sh(p_specs), sh(b_specs["cache"]), sh(b_specs["token"]),
                sh(b_specs["pos"]),
            ),
            out_shardings=(sh(logits_spec), sh(b_specs["cache"])),
            donate_argnums=(1,),
        )
        with act_ctx:
            lowered = jitted.lower(p_shapes, ins["cache"], ins["token"], ins["pos"])
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        }
    except Exception as e:  # pragma: no cover - backend specific
        mem_rec = {"error": str(e)}

    try:
        cost = compiled.cost_analysis() or {}
        cost_rec = {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "transcendentals": float(cost.get("transcendentals", 0.0)),
        }
    except Exception as e:  # pragma: no cover
        cost_rec = {"error": str(e)}

    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    from repro.launch.hlo_analysis import analyze_hlo

    try:
        corrected = analyze_hlo(hlo)
    except Exception as e:  # pragma: no cover
        corrected = {"error": str(e)}

    n_chips = 512 if multi_pod else 256
    return {
        "arch": arch,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "n_chips": n_chips,
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": mem_rec,
        "cost_analysis": cost_rec,
        "collectives": coll,
        "loop_corrected": corrected,
        "act_sharding": act_sharding,
        "param_count": int(
            sum(math.prod(x.shape) for x in jax.tree.leaves(model.param_shapes()))
        ),
        "active_param_count": cfg.active_param_count(),
    }


def run_and_save(arch: str, shape: str, multi_pod: bool, force: bool,
                 act_sharding: bool = True, tag: str = "") -> dict:
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    out = RESULTS_DIR / f"{_cell_name(arch, shape, multi_pod)}{tag}.json"
    if out.exists() and not force:
        rec = json.loads(out.read_text())
        print(f"[cached] {out.name}: {rec['status']}")
        return rec
    print(f"[dryrun] {arch} x {shape} ({'2 pods' if multi_pod else '1 pod'}) ...",
          flush=True)
    try:
        rec = lower_cell(arch, shape, multi_pod=multi_pod,
                         act_sharding=act_sharding)
    except Exception as e:
        rec = {
            "arch": arch, "shape": shape, "multi_pod": multi_pod,
            "status": "error", "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
    out.write_text(json.dumps(rec, indent=2))
    status = rec["status"]
    extra = ""
    if status == "ok":
        extra = (f" compile={rec['compile_s']}s flops={rec['cost_analysis'].get('flops', 0):.3e}"
                 f" coll={rec['collectives']['total_bytes']/1e9:.3f}GB")
    print(f"[done]   {out.name}: {status}{extra}", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-act-sharding", action="store_true",
                    help="baseline: drop activation sharding constraints")
    ap.add_argument("--tag", default="", help="suffix for the result file")
    args = ap.parse_args()

    if args.all:
        archs = ARCH_IDS
        shapes = tuple(SHAPES)
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        archs, shapes = (args.arch,), (args.shape,)

    meshes = (False, True) if args.both_meshes else (args.multi_pod,)
    n_bad = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_and_save(arch, shape, mp, args.force,
                                   act_sharding=not args.no_act_sharding,
                                   tag=args.tag)
                if rec["status"] == "error":
                    n_bad += 1
    sys.exit(1 if n_bad else 0)


if __name__ == "__main__":
    main()
