"""Production mesh construction.

Single pod: (data, model) = (16, 16) — 256 chips (one v5e pod).
Multi-pod:  (pod, data, model) = (2, 16, 16) — 512 chips.

Defined as functions (never module-level constants) so importing this module
never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import to fabricate the devices.

Version compat: ``jax.sharding.AxisType`` (explicit-sharding axis typing)
only exists in newer jax releases; the pinned 0.4.37 predates it.
:func:`compat_make_mesh` passes ``axis_types`` only when available, so the
same call sites work on both sides of the API change.
"""

from __future__ import annotations

import jax

__all__ = [
    "compat_make_mesh",
    "make_production_mesh",
    "make_debug_mesh",
    "fsdp_axes",
    "batch_axes",
]


def compat_make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """``jax.make_mesh`` with ``axis_types=Auto`` where the API supports it."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    kwargs = (
        {"axis_types": (axis_type.Auto,) * len(axes)} if axis_type is not None else {}
    )
    return jax.make_mesh(shape, axes, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2, 2)):
    """Small fake-device mesh for CPU tests."""
    axes = ("pod", "data", "model")[-len(shape):]
    return compat_make_mesh(shape, axes)


def fsdp_axes(mesh) -> tuple[str, ...]:
    """Axes used to shard the parameter 'data' dimension (ZeRO/FSDP)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
