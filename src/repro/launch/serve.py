"""Serving driver: batched prefill + decode loop with a simple continuous
batching queue (new requests join at step boundaries; finished ones leave).

CPU-scale usage:
  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --reduced \
      --requests 8 --prompt-len 32 --gen-len 16
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config, ARCH_IDS
from repro.models import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    """Fixed-slot continuous batching: ``n_slots`` concurrent sequences share
    one cache; slots are refilled from the queue as requests finish."""

    def __init__(self, model: Model, params, n_slots: int, max_seq: int):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))

    def run(self, requests: list[Request], gen_len: int) -> list[Request]:
        cfg = self.model.cfg
        queue = list(requests)
        # batch all prompts of equal length together (prefill)
        assert all(len(r.prompt) == len(queue[0].prompt) for r in queue)
        out: list[Request] = []
        while queue:
            active = queue[: self.n_slots]
            queue = queue[self.n_slots:]
            toks = jnp.asarray(np.stack([r.prompt for r in active]), jnp.int32)
            extras = None
            if cfg.family == "audio":
                extras = jnp.zeros((len(active), cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
            if cfg.family == "vlm":
                extras = jnp.zeros((len(active), cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
            logits, cache = self.model.prefill(
                self.params, toks, extras=extras, max_seq=self.max_seq
            )
            pos = len(active[0].prompt)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            for t in range(gen_len):
                for r, tk in zip(active, np.asarray(nxt)):
                    r.generated.append(int(tk))
                logits, cache = self._decode(
                    self.params, cache, nxt, jnp.asarray(pos + t, jnp.int32)
                )
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            for r in active:
                r.done = True
                out.append(r)
        return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(i, rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32))
        for i in range(args.requests)
    ]
    server = Server(model, params, args.slots, args.prompt_len + args.gen_len + 1)
    t0 = time.perf_counter()
    done = server.run(reqs, args.gen_len)
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.generated) for r in done)
    print(f"[serve] {len(done)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s)")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.generated[:8]}...")


if __name__ == "__main__":
    main()
