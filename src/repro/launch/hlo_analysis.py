"""Loop-corrected roofline terms from optimized (SPMD-partitioned) HLO text.

XLA:CPU's ``compiled.cost_analysis()`` counts every while-loop body ONCE,
which silently drops ~L x the FLOPs of a scan-over-layers model.  The
optimized HLO, however, annotates every loop with
``backend_config={"known_trip_count":{"n":...}}`` — so we parse the module,
attribute work to computations, and expand the call graph with trip-count
multiplication:

  flops            2*prod(out_dims)*prod(contracting_dims) per dot/conv
  memory bytes     sum(operand bytes) + output bytes per top-level op
                   (fusions hide their internals, so this approximates true
                   HBM traffic post-fusion)
  collective bytes output-shape bytes per all-gather / all-reduce /
                   reduce-scatter / all-to-all / collective-permute

Everything is PER DEVICE (the module is already partitioned).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

__all__ = ["analyze_hlo", "HloStats", "jaxpr_peak_intermediate"]


def jaxpr_peak_intermediate(jaxpr) -> int:
    """Largest intermediate array (in elements) anywhere in a jaxpr tree,
    excluding top-level inputs/constants.

    A deterministic, device-free stand-in for peak memory used by the
    streaming-engine memory-bound tests (``tests/test_streaming.py``,
    ``tests/test_kmeans_streaming.py``) and the index-build benchmark
    suite (``benchmarks/index_build.py``).
    """
    import numpy as _np

    seen = set()
    best = 0

    def walk(jx):
        nonlocal best
        if id(jx) in seen:
            return
        seen.add(id(jx))
        for eqn in jx.eqns:
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                if aval is not None and hasattr(aval, "shape"):
                    best = max(best, int(_np.prod(aval.shape, dtype=_np.int64)))
            for p in eqn.params.values():
                for sub in (p if isinstance(p, (list, tuple)) else (p,)):
                    inner = getattr(sub, "jaxpr", sub)
                    if hasattr(inner, "eqns"):
                        walk(inner)

    walk(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr)
    return best

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$|^(?:ENTRY\s+)?%?([\w.\-]+)\s+\{")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_WHILE_RE = re.compile(r"\bwhile\(.*?body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"\b(?:call|async-start)\(.*?to_apply=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    memory_full: float = 0.0  # operands + outputs (top-level semantics)
    memory_out: float = 0.0  # outputs only (inside loop bodies, where
    # operands are loop-carried state that lives in VMEM on TPU)
    collective_bytes: float = 0.0
    per_kind: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES}
    )
    collective_count: float = 0.0

    def add(self, other: "HloStats", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.memory_full += mult * other.memory_full
        self.memory_out += mult * other.memory_out
        self.collective_bytes += mult * other.collective_bytes
        self.collective_count += mult * other.collective_count
        for k in _COLLECTIVES:
            self.per_kind[k] += mult * other.per_kind[k]


def _dot_flops(out_type: str, rhs: str, shapes: dict[str, str]) -> float:
    """2 * prod(output) * prod(contracting dims of lhs)."""
    out_dims = _shape_dims(out_type)
    out_elems = 0
    for _, dims in out_dims:
        n = 1
        for d in dims:
            n *= d
        out_elems += n
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
    ops = _OPERAND_RE.findall(rhs.split("(", 1)[1]) if "(" in rhs else []
    k = 1
    if m and ops:
        lhs_name = ops[0]
        lhs_type = shapes.get(lhs_name, "")
        dims = _shape_dims(lhs_type)
        if dims:
            lhs_dims = dims[0][1]
            for ci in m.group(1).split(","):
                if ci and int(ci) < len(lhs_dims):
                    k *= lhs_dims[int(ci)]
    return 2.0 * out_elems * k


def analyze_hlo(text: str) -> dict:
    # ---- pass 1: split into computations, build name -> output type map
    computations: dict[str, list[str]] = {}
    shapes: dict[str, str] = {}
    current = None
    for line in text.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and ("->" in stripped or stripped.startswith("ENTRY")
                                       or re.match(r"^%?[\w.\-]+\s+\{", stripped)):
            name = stripped.split()[0].lstrip("%")
            if name == "ENTRY":
                name = stripped.split()[1].lstrip("%")
            current = name
            computations[current] = []
            continue
        if stripped == "}":
            continue
        if current is None:
            continue
        computations[current].append(stripped)
        m = _DEF_RE.match(stripped)
        if m:
            rhs = m.group(2)
            # output type = everything before the op name token
            shapes[m.group(1)] = rhs.split(" ", 1)[0] if rhs.startswith(("(", "f", "b", "s", "u", "p", "c")) else rhs

    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            entry = line.split()[1].lstrip("%").split("(")[0]
            break
    if entry is None:  # fall back: computation named *main* or the last one
        cand = [c for c in computations if "main" in c]
        entry = cand[0] if cand else list(computations)[-1]

    # ---- pass 2: per-computation direct stats + sub-calls
    direct: dict[str, HloStats] = {}
    calls: dict[str, list[tuple[str, float]]] = defaultdict(list)
    for comp, lines in computations.items():
        st = HloStats()
        for ls in lines:
            m = _DEF_RE.match(ls)
            if not m:
                continue
            rhs = m.group(2)
            out_type = rhs.split(" ", 1)[0]
            out_b = _shape_bytes(out_type)
            # collectives
            is_coll = False
            for k in _COLLECTIVES:
                if re.search(rf"\b{k}(-start)?\(", rhs):
                    st.per_kind[k] += out_b
                    st.collective_bytes += out_b
                    st.collective_count += 1
                    is_coll = True
                    break
            # flops (dot / convolution)
            if re.search(r"\bdot\(", rhs) or re.search(r"\bconvolution\(", rhs):
                st.flops += _dot_flops(out_type, rhs, shapes)
            # memory traffic: operands + output of top-level ops
            kind_m = re.match(r"[\w\[\],{}\(\) /*]*?\b([a-z][\w\-]*)\(", rhs)
            kind = kind_m.group(1) if kind_m else ""
            if kind in ("fusion", "dot", "convolution", "copy", "dynamic-slice",
                        "dynamic-update-slice", "gather", "scatter", "sort",
                        "reduce", "transpose", "broadcast", "concatenate",
                        "slice", "reshape", "bitcast", "iota", "pad",
                        "select-and-scatter") or is_coll:
                if kind in ("bitcast", "reshape", "iota"):
                    pass  # free
                else:
                    operand_bytes = 0
                    args = rhs.split("(", 1)[1] if "(" in rhs else ""
                    for opn in _OPERAND_RE.findall(args.split("),", 1)[0]):
                        operand_bytes += _shape_bytes(shapes.get(opn, ""))
                    st.memory_full += operand_bytes + out_b
                    st.memory_out += out_b
            # sub-computations
            wm = _WHILE_RE.search(rhs)
            if wm:
                tm = _TRIP_RE.search(rhs)
                trip = float(tm.group(1)) if tm else 1.0
                calls[comp].append((wm.group(1), trip))
            cm = _CALL_RE.search(rhs)
            if cm:
                calls[comp].append((cm.group(1), 1.0))
        direct[comp] = st

    # ---- pass 3: expand the call graph with memoisation
    memo: dict[str, HloStats] = {}

    def total(comp: str, stack=()) -> HloStats:
        if comp in memo:
            return memo[comp]
        if comp in stack or comp not in direct:
            return HloStats()
        st = HloStats()
        st.add(direct[comp])
        for child, mult in calls.get(comp, ()):  # bodies expanded x trip
            st.add(total(child, stack + (comp,)), mult)
        memo[comp] = st
        return st

    agg = total(entry)
    # memory model: entry-level ops pay operands+outputs; everything reached
    # through a loop pays outputs only (operands are VMEM-resident carries
    # or already-counted weight reads -- see roofline.py, which adds the
    # analytic parameter-read traffic back on top).
    loop_mem = agg.memory_out - direct[entry].memory_out
    memory = direct[entry].memory_full + loop_mem
    return {
        "entry": entry,
        "flops": agg.flops,
        "memory_bytes": memory,
        "memory_bytes_full": agg.memory_full,
        "collective_bytes": agg.collective_bytes,
        "collective_count": agg.collective_count,
        "per_kind_bytes": dict(agg.per_kind),
        "n_computations": len(computations),
    }
