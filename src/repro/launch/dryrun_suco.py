import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dry-run of the paper's own technique at production scale: the sharded
SuCo engine serving k-ANN queries over 1B x 128-d vectors on the
(2x)16x16 mesh.

Cells (suffix `suco_serve` / `suco_build`):
  * query step: 256 queries/batch, alpha=0.03, beta=0.003, Ns=16, K=64^2
  * build step: distributed K-means (10 Lloyd iterations via psum)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun_suco [--multi-pod] [--build]
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.distributed.engine import (
    DistSuCoConfig,
    ShardedEnginePool,
    ShardedSuCoEngine,
    index_shardings,
    resolved_query_block_n,
)
from repro.launch.dryrun import RESULTS_DIR, collective_bytes
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh

N_POINTS = 1_000_000_000
DIM = 128
N_QUERIES = 256


def suco_cell(*, multi_pod: bool, build: bool = False,
              pool_ks: tuple[int, ...] = (10,)) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    pa = ("pod", "data") if multi_pod else ("data",)
    cfg = DistSuCoConfig(
        n_subspaces=16, sqrt_k=64, kmeans_iters=10, alpha=0.03, beta=0.003,
        k=50, q_chunk=8, point_axes=pa,
        # the dry-run emulates a TPU pod on fabricated CPU devices: pin the
        # autotuner to TPU memory limits so the lowered scan structure is
        # exactly what production serving would resolve
        tuning_backend="tpu",
    )
    index_shardings(mesh, cfg)  # exercises/validates the sharding rules
    x = jax.ShapeDtypeStruct((N_POINTS, DIM), jnp.float32)
    h1 = (DIM // cfg.n_subspaces + 1) // 2
    c_shape = jax.ShapeDtypeStruct((cfg.n_subspaces, cfg.sqrt_k, h1), jnp.float32)
    ids_shape = jax.ShapeDtypeStruct((cfg.n_subspaces, N_POINTS), jnp.int32)
    cnt_shape = jax.ShapeDtypeStruct((cfg.n_subspaces, cfg.n_cells), jnp.int32)

    del build  # the build step is exercised at test scale; query is the
    # serving hot path we dry-run at 1B
    t0 = time.time()
    # the engine's AOT path: same bucketing policy production serving uses,
    # so the lowered executable is exactly the one a ShardedSuCoEngine
    # would dispatch a 256-query batch to
    qfn, mq = ShardedSuCoEngine.aot_query_fn(mesh, cfg, N_POINTS, DIM, N_QUERIES)
    q = jax.ShapeDtypeStruct((mq, DIM), jnp.float32)
    lowered = qfn.lower(x, c_shape, c_shape, ids_shape, cnt_shape, q)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes")
            if hasattr(mem, k)
        }
    except Exception as e:  # pragma: no cover
        mem_rec = {"error": str(e)}
    try:
        cost = compiled.cost_analysis() or {}
        cost_rec = {"flops": float(cost.get("flops", 0.0)),
                    "bytes_accessed": float(cost.get("bytes accessed", 0.0))}
    except Exception as e:  # pragma: no cover
        cost_rec = {"error": str(e)}
    # Heterogeneous-k serving lowers one executable per pool binding: prove
    # each (bucket, k != cfg.k) binding lowers independently through the
    # ShardedEnginePool AOT path (lower-only — the k=cfg.k compile above
    # already prices the full pipeline).
    pool_rec = []
    for k in pool_ks:
        t0 = time.time()
        pfn, pmq = ShardedEnginePool.aot_query_fn(mesh, cfg, N_POINTS, DIM,
                                                  N_QUERIES, k)
        pq = jax.ShapeDtypeStruct((pmq, DIM), jnp.float32)
        pfn.lower(x, c_shape, c_shape, ids_shape, cnt_shape, pq)
        pool_rec.append({"k": int(k), "mq": int(pmq),
                         "lower_s": round(time.time() - t0, 2)})

    hlo = compiled.as_text()
    return {
        "pool": pool_rec,
        # the tiling the lowered query step resolved to (block_n=None in
        # DistSuCoConfig -> autotuned from backend limits + shard shape)
        "tiling": {
            "query_block_n": resolved_query_block_n(mesh, cfg, N_POINTS, DIM),
            "q_chunk": cfg.q_chunk,
            "tuning_backend": cfg.tuning_backend,
        },
        "arch": "suco-engine-1b",
        "shape": "serve_q256",
        "multi_pod": multi_pod,
        "n_chips": 512 if multi_pod else 256,
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": mem_rec,
        "cost_analysis": cost_rec,
        "collectives": collective_bytes(hlo),
        "loop_corrected": analyze_hlo(hlo),
        "config": {"n": N_POINTS, "d": DIM, "Ns": cfg.n_subspaces,
                   "sqrtK": cfg.sqrt_k, "alpha": cfg.alpha, "beta": cfg.beta,
                   "queries": N_QUERIES},
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--ks", type=int, nargs="*", default=[10],
                    help="extra per-k pool bindings to lower (besides cfg.k)")
    args = ap.parse_args()
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    meshes = (False, True) if args.both_meshes else (args.multi_pod,)
    for mp in meshes:
        out = RESULTS_DIR / f"suco-engine-1b__serve_q256__{'pod2' if mp else 'pod1'}.json"
        if out.exists() and not args.force:
            print(f"[cached] {out.name}")
            continue
        print(f"[dryrun] suco engine 1B x 128d ({'2 pods' if mp else '1 pod'}) ...",
              flush=True)
        try:
            rec = suco_cell(multi_pod=mp, pool_ks=tuple(args.ks))
        except Exception as e:
            rec = {"arch": "suco-engine-1b", "shape": "serve_q256",
                   "multi_pod": mp, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
        out.write_text(json.dumps(rec, indent=2))
        print(f"[done]   {out.name}: {rec['status']}", flush=True)


if __name__ == "__main__":
    main()
