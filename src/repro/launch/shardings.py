"""Sharding rules: parameter, optimizer, batch and cache PartitionSpecs.

Strategy (MaxText-style 2D "fsdp + tensor"):
  * tensor axis   = "model": heads / d_ff / vocab / experts
  * fsdp axis(es) = ("pod","data"): the d_model side of every big matrix
    (ZeRO-3: params+optimizer sharded over the batch axes too)
  * batch axes    = ("pod","data") for activations
  * long_500k     = KV-cache *sequence* axis over the batch axes
    (sequence-parallel decode; softmax statistics turn into psums)

Rules are path-based over the param pytree, so they apply uniformly to
params, grads and AdamW moments.
"""

from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.model import ShapeSpec
from repro.launch.mesh import batch_axes, fsdp_axes

__all__ = [
    "param_specs",
    "opt_state_specs",
    "batch_specs",
    "cache_specs",
    "to_shardings",
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def _spec_for(path: str, ndim: int, cfg: ModelConfig, fsdp, tp="model") -> P:
    """PartitionSpec for one parameter leaf. Leading scan (L) axes are
    detected as (ndim - base rank) and left unsharded."""

    def lead(base: int) -> tuple:
        return (None,) * (ndim - base)

    # embeddings / heads / positions
    if path == "embed":
        return P(tp, fsdp)
    if path.endswith("lm_head/w"):
        return P(fsdp, tp)
    if path.endswith("dec_pos") or path.endswith("enc_pos"):
        return P(fsdp, None)

    # MoE expert tensors: expert-parallel when divisible, else tensor on d_ff
    if re.search(r"moe/(w_gate|w_up)$", path) or re.search(r"moe/(w_gate|w_up)/w$", path):
        pass  # not reached (moe weights are raw arrays, matched below)
    if "moe/" in path:
        if path.endswith("router/w"):
            return P(*lead(2), fsdp, None)
        ep = cfg.n_experts % 16 == 0
        if path.endswith("w_gate") or path.endswith("w_up"):
            return P(*lead(3), tp, fsdp, None) if ep else P(*lead(3), None, fsdp, tp)
        if path.endswith("w_down"):
            return P(*lead(3), tp, None, fsdp) if ep else P(*lead(3), None, tp, fsdp)

    # attention / cross-attention projections
    if re.search(r"(attn|cross)/(wq|wk|wv)/w$", path):
        return P(*lead(2), fsdp, tp)
    if re.search(r"(attn|cross)/(wq|wk|wv)/b$", path):
        return P(*lead(1), tp)
    if re.search(r"(attn|cross)/wo/w$", path):
        return P(*lead(2), tp, fsdp)

    # dense mlp
    if re.search(r"(w_gate|w_up|wk)/w$", path):
        return P(*lead(2), fsdp, tp)
    if re.search(r"(w_down|wv)/w$", path):
        return P(*lead(2), tp, fsdp)
    if re.search(r"(w_up)/b$", path):
        return P(*lead(1), tp)

    # rwkv time mix / mamba projections
    if re.search(r"(wr|wg|w_in)/w$", path):
        return P(*lead(2), fsdp, tp)
    if re.search(r"(w_out)/w$", path):
        return P(*lead(2), tp, fsdp)
    if path.endswith("w_a"):
        return P(*lead(2), fsdp, None)
    if path.endswith("w_b"):
        return P(*lead(2), None, fsdp)
    if path.endswith("conv"):
        return P(*lead(2), None, tp)

    # everything small (norm scales, gates, decay vectors, biases)
    return P()


def fit_spec(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop sharded axes that don't divide the dimension exactly.

    Explicit argument shardings (unlike internal GSPMD propagation) require
    exact divisibility; odd vocabularies (49155, 51866) and fixed memory
    lengths (1500/1601) fall back to replication on that dim."""
    import math as _math

    entries = tuple(spec) + (None,) * (len(shape) - len(spec))
    out = []
    for dim, ax in zip(shape, entries):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = _math.prod(mesh.shape[a] for a in axes)
        out.append(ax if size and dim % size == 0 else None)
    return P(*out)


def fit_tree(specs, shapes, mesh: Mesh):
    return jax.tree.map(
        lambda s, x: fit_spec(s, x.shape, mesh),
        specs,
        shapes,
        is_leaf=lambda x: isinstance(x, P),
    )


def param_specs(cfg: ModelConfig, mesh: Mesh, shapes) -> Any:
    fsdp = fsdp_axes(mesh)

    def leaf(path, x):
        return fit_spec(_spec_for(_path_str(path), len(x.shape), cfg, fsdp), x.shape, mesh)

    return jax.tree_util.tree_map_with_path(leaf, shapes)


def opt_state_specs(cfg: ModelConfig, mesh: Mesh, opt_shapes) -> Any:
    """AdamW moments mirror the param tree; `step` is replicated."""
    p_specs = param_specs(cfg, mesh, opt_shapes["mu"])
    return {"mu": p_specs, "nu": param_specs(cfg, mesh, opt_shapes["nu"]), "step": P()}


def batch_specs(cfg: ModelConfig, mesh: Mesh, shape: ShapeSpec, specs: dict) -> dict:
    """PartitionSpecs matching input_specs(cfg, shape)."""
    ba = batch_axes(mesh)
    out: dict[str, Any] = {}
    if shape.kind in ("train", "prefill"):
        out["tokens"] = P(ba, None)
        if shape.kind == "train":
            out["labels"] = P(ba, None)
        if "extras" in specs:
            out["extras"] = P(ba, None, None)
        return out
    # decode
    seq_shard = shape.global_batch == 1  # long_500k: shard the KV seq axis
    out["token"] = P(None) if seq_shard else P(ba)
    out["pos"] = P()
    cs = cache_specs(cfg, mesh, shape)
    if "cache" in specs:
        cs = fit_tree(cs, specs["cache"], mesh)
    out["cache"] = cs
    return out


def cache_specs(cfg: ModelConfig, mesh: Mesh, shape: ShapeSpec) -> dict:
    """KV/state cache PartitionSpecs.

    Explicit argument shardings must divide exactly, so the head axis only
    takes the tensor axis when ``n_kv_heads % model == 0``; otherwise the
    tensor axis is folded into the *sequence* axis (sequence-sharded KV
    within the TP group — flash-decode semantics, the softmax statistics
    become psums under GSPMD)."""
    ba = batch_axes(mesh)
    tp_size = mesh.shape["model"]
    seq_shard = shape.global_batch == 1  # long_500k
    b_ax = None if seq_shard else ba

    heads_div = cfg.n_kv_heads % tp_size == 0
    h_ax = "model" if heads_div else None
    if heads_div:
        s_ax = ba if seq_shard else None
    else:
        s_ax = (*ba, "model") if seq_shard else "model"

    # SSM/hybrid small-state tensors: heads axis if divisible, else replicate
    st_h = "model" if cfg.n_heads % tp_size == 0 else None
    inner_ax = "model"  # inner = 2*d_model, always divisible in practice

    out: dict[str, Any] = {}
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        out["k"] = P(None, b_ax, h_ax, s_ax, None)
        out["v"] = P(None, b_ax, h_ax, s_ax, None)
    if cfg.family in ("vlm", "audio"):
        # memory K/V: fixed odd lengths (1601/1500) -> never shard seq
        out["xk"] = P(None, b_ax, h_ax, None, None)
        out["xv"] = P(None, b_ax, h_ax, None, None)
    if cfg.family == "ssm":
        out["prev1"] = P(None, b_ax, inner_ax if cfg.d_model % tp_size == 0 else None)
        out["prev2"] = out["prev1"]
        out["wkv"] = P(None, b_ax, st_h, None, None)
    if cfg.family == "hybrid":
        inner_ok = (cfg.ssm_expand * cfg.d_model) % tp_size == 0
        out["conv"] = P(None, b_ax, None, inner_ax if inner_ok else None)
        out["ssm"] = P(None, b_ax, st_h, None, None)
        out["sk"] = P(None, b_ax, h_ax, s_ax, None)
        out["sv"] = P(None, b_ax, h_ax, s_ax, None)
    return out


def to_shardings(mesh: Mesh, specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )
