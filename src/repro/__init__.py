"""repro — Subspace Collision (SuCo) ANN framework on JAX/TPU.

Layers: core (the paper), kernels (Pallas TPU), distributed (multi-pod
engine), models (assigned architecture pool), train/serve substrate,
configs + launch (mesh, dry-run, drivers).
"""

__version__ = "0.1.0"
