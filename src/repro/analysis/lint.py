"""jaxlint CLI: ``python -m repro.analysis.lint``.

Runs both engines over everything the registry declares and reports either a
human summary or machine-readable JSON (``--format=json``).  Exit status is 0
iff no unsuppressed finding and no engine error.

Options::

    --format {human,json}   report format (default: human)
    --output PATH           also write the report to a file (CI artifact)
    --rules A,B             only run the named rules
    --entries GLOB          only check entry names / file paths matching GLOB
    --disable A,B           run but suppress the named rules (audited opt-out)
    --list                  list registered entries and rules, then exit
"""

from __future__ import annotations

import argparse
import sys
from typing import Sequence

from repro.analysis import ast_rules, jaxpr_rules, registry
from repro.analysis.findings import Finding, Report

ALL_RULES: tuple[str, ...] = (
    tuple(jaxpr_rules.JAXPR_RULES) + ("tile-shape",) + ast_rules.AST_RULES
)

RULE_DOCS: dict[str, str] = {**jaxpr_rules.RULE_DOCS, **ast_rules.AST_RULE_DOCS}


def _filter_rules(findings: list[Finding], rules: set[str] | None) -> list[Finding]:
    if rules is None:
        return findings
    return [f for f in findings if f.rule in rules]


def _disable(findings: list[Finding], disabled: set[str]) -> list[Finding]:
    out = []
    for f in findings:
        if not f.suppressed and f.rule in disabled:
            f = Finding(
                rule=f.rule,
                target=f.target,
                message=f.message,
                severity=f.severity,
                suppressed=True,
                suppress_reason="disabled on the command line",
            )
        out.append(f)
    return out


def lint_entry(entry, rules: set[str] | None = None) -> tuple[list[Finding], list[str]]:
    """Run one registry entry through its applicable jaxpr/tile rules."""
    findings, checked = jaxpr_rules.run_jaxpr_rules(entry)
    if rules is not None:
        checked = [r for r in checked if r in rules]
        findings = _filter_rules(findings, rules)
    return findings, checked


def run_lint(
    rules: set[str] | None = None,
    entries_glob: str = "*",
    disabled: set[str] | None = None,
) -> Report:
    """Run both engines; never raises on a rule failure, only records it."""
    report = Report()

    want_jaxpr = rules is None or bool(
        rules & (set(jaxpr_rules.JAXPR_RULES) | {"tile-shape"})
    )
    if want_jaxpr:
        try:
            entries = registry.collect_entries(pattern=entries_glob)
        except Exception as exc:  # a broken hook must fail the run
            report.errors.append(f"registry collection failed: {exc!r}")
            entries = []
        for entry in entries:
            try:
                findings, checked = lint_entry(entry, rules)
            except Exception as exc:
                report.errors.append(f"entry {entry.name!r} failed to trace: {exc!r}")
                continue
            report.extend(findings)
            for rule in checked:
                report.mark_checked(rule, entry.name)

    want_ast = rules is None or bool(rules & set(ast_rules.AST_RULES))
    if want_ast:
        for target in registry.ast_targets(pattern=entries_glob):
            try:
                findings = ast_rules.lint_target(target)
            except Exception as exc:
                report.errors.append(f"AST scan of {target.name} failed: {exc!r}")
                continue
            report.extend(_filter_rules(findings, rules))
            for rule in ast_rules.AST_RULES:
                if rules is None or rule in rules:
                    report.mark_checked(rule, target.name)

    if disabled:
        report.findings = _disable(report.findings, disabled)
    return report


def _parse_args(argv: Sequence[str] | None) -> argparse.Namespace:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="jaxlint: prove the serving invariants statically.",
    )
    parser.add_argument("--format", choices=("human", "json"), default="human")
    parser.add_argument("--output", default=None, help="also write the report here")
    parser.add_argument("--rules", default=None, help="comma-separated rule subset")
    parser.add_argument("--entries", default="*", help="glob over entry/file names")
    parser.add_argument("--disable", default=None, help="suppress these rules")
    parser.add_argument("--list", action="store_true", help="list entries and rules")
    return parser.parse_args(argv)


def _split(value: str | None) -> set[str] | None:
    if value is None:
        return None
    return {v.strip() for v in value.split(",") if v.strip()}


def main(argv: Sequence[str] | None = None) -> int:
    args = _parse_args(argv)

    if args.list:
        print("rules:")
        for rule in ALL_RULES:
            print(f"  {rule}: {RULE_DOCS[rule]}")
        print("jaxpr/tile entries:")
        for entry in registry.collect_entries(pattern=args.entries):
            kind = "tile" if isinstance(entry, registry.TileEntry) else "jaxpr"
            note = f" — {entry.note}" if entry.note else ""
            print(f"  [{kind}] {entry.name}{note}")
        print("ast targets:")
        for target in registry.ast_targets(pattern=args.entries):
            print(f"  {target.name}")
        return 0

    rules = _split(args.rules)
    if rules is not None:
        unknown = rules - set(ALL_RULES)
        if unknown:
            print(f"unknown rule(s): {sorted(unknown)}", file=sys.stderr)
            return 2

    report = run_lint(
        rules=rules, entries_glob=args.entries, disabled=_split(args.disable)
    )
    text = report.to_json() if args.format == "json" else report.render(RULE_DOCS)
    print(text)
    if args.output:
        with open(args.output, "w") as fh:
            fh.write(text + "\n")
    return 0 if report.ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
