"""Finding/Report datatypes shared by both jaxlint engines and the CLI."""

from __future__ import annotations

import dataclasses
import json
from typing import Iterable, Mapping, Sequence


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation (or suppressed would-be violation) at one site.

    ``target`` is the registered entry-point name for jaxpr rules and a
    ``path:line`` location for AST rules.  ``suppressed`` findings are kept in
    the report (so suppressions stay auditable) but do not fail the lint.
    """

    rule: str
    target: str
    message: str
    severity: str = "error"
    suppressed: bool = False
    suppress_reason: str = ""

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        tag = f"[{self.rule}]"
        if self.suppressed:
            why = f" ({self.suppress_reason})" if self.suppress_reason else ""
            return f"  suppressed {tag} {self.target}: {self.message}{why}"
        return f"  {self.severity} {tag} {self.target}: {self.message}"


@dataclasses.dataclass
class Report:
    """Aggregated lint run: every finding plus what was actually checked."""

    findings: list[Finding] = dataclasses.field(default_factory=list)
    checked: dict[str, list[str]] = dataclasses.field(default_factory=dict)
    errors: list[str] = dataclasses.field(default_factory=list)

    def extend(self, findings: Iterable[Finding]) -> None:
        self.findings.extend(findings)

    def mark_checked(self, rule: str, target: str) -> None:
        self.checked.setdefault(rule, []).append(target)

    @property
    def fatal(self) -> list[Finding]:
        return [f for f in self.findings if not f.suppressed]

    @property
    def ok(self) -> bool:
        return not self.fatal and not self.errors

    def to_json(self) -> str:
        payload = {
            "ok": self.ok,
            "n_findings": len(self.fatal),
            "n_suppressed": len(self.findings) - len(self.fatal),
            "findings": [f.to_dict() for f in self.findings],
            "checked": {rule: sorted(t) for rule, t in sorted(self.checked.items())},
            "errors": self.errors,
        }
        return json.dumps(payload, indent=2, sort_keys=False)

    def render(self, rule_docs: Mapping[str, str] | None = None) -> str:
        lines: list[str] = []
        by_rule: dict[str, list[Finding]] = {}
        for f in self.findings:
            by_rule.setdefault(f.rule, []).append(f)
        for rule in sorted(set(self.checked) | set(by_rule)):
            targets = self.checked.get(rule, [])
            hits = by_rule.get(rule, [])
            fatal = [f for f in hits if not f.suppressed]
            status = "FAIL" if fatal else "ok"
            lines.append(f"{status:>4}  {rule}  ({len(targets)} targets checked)")
            if rule_docs and rule in rule_docs:
                lines.append(f"      {rule_docs[rule]}")
            for f in hits:
                lines.append(f.render())
        for err in self.errors:
            lines.append(f"ERROR {err}")
        verdict = "clean" if self.ok else f"{len(self.fatal)} finding(s)"
        lines.append(f"jaxlint: {verdict}")
        return "\n".join(lines)


def merge_reports(reports: Sequence[Report]) -> Report:
    out = Report()
    for r in reports:
        out.findings.extend(r.findings)
        out.errors.extend(r.errors)
        for rule, targets in r.checked.items():
            for t in targets:
                out.mark_checked(rule, t)
    return out
