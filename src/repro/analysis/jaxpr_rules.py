"""Engine 1: structural rules over closed jaxprs.

These rules read the traced program XLA will compile — not the Python that
produced it — so they certify what actually runs: the chunk scan of the
streaming/fused query paths stays scatter- and sort-free, no intermediate
outgrows the declared budget, float reductions accumulate in fp32, and every
``pallas_call``'s blocks respect the TPU tile model and fit VMEM.
"""

from __future__ import annotations

import math
from typing import Any, Callable, Iterator

import numpy as np

from repro.analysis.findings import Finding
from repro.analysis.registry import JaxprEntry, TileEntry

# --------------------------- jaxpr traversal --------------------------------

#: Primitives whose sub-jaxprs execute once per carried step — the "hot loop"
#: scope for no-scatter-in-scan.  (pjit/cond bodies inherit the depth of the
#: equation that contains them; they do not open a loop themselves.)
_LOOP_PRIMS = frozenset({"scan", "while"})


def _sub_jaxprs(eqn) -> Iterator[Any]:
    for param in eqn.params.values():
        items = param if isinstance(param, (tuple, list)) else (param,)
        for item in items:
            inner = getattr(item, "jaxpr", item)
            if hasattr(inner, "eqns"):
                yield inner


def iter_eqns(jaxpr, depth: int = 0) -> Iterator[tuple[Any, int]]:
    """Yield ``(eqn, loop_depth)`` for every equation, recursing into
    scan/while/cond/pjit/pallas sub-jaxprs.  ``loop_depth`` counts how many
    scan/while bodies enclose the equation."""
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in inner.eqns:
        yield eqn, depth
        child_depth = depth + (1 if eqn.primitive.name in _LOOP_PRIMS else 0)
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub, child_depth)


def _aval_bytes(aval) -> int | None:
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return None
    try:
        elems = int(math.prod(int(d) for d in shape))
    except (TypeError, ValueError):  # dynamic/polymorphic dims
        return None
    try:
        itemsize = np.dtype(dtype).itemsize
    except TypeError:  # extended dtypes (PRNG keys) — count their base size
        itemsize = getattr(dtype, "itemsize", None)
        if itemsize is None:
            return None
    return elems * itemsize


def peak_intermediate_bytes(jaxpr) -> tuple[int, str]:
    """Largest single intermediate produced by any equation, in bytes.

    Returns ``(bytes, description)`` where the description names the
    offending primitive and shape — this is the bytes-denominated successor
    of ``repro.launch.hlo_analysis.jaxpr_peak_intermediate`` (which counts
    elements and stays in use by the benchmarks)."""
    peak, where = 0, "(empty jaxpr)"
    for eqn, _ in iter_eqns(jaxpr):
        for var in eqn.outvars:
            b = _aval_bytes(getattr(var, "aval", None))
            if b is not None and b > peak:
                peak = b
                aval = var.aval
                where = f"{eqn.primitive.name} -> {aval.dtype}{list(aval.shape)}"
    return peak, where


# ------------------------------- rules --------------------------------------

_SORT_PRIMS = frozenset({"sort"})


def _is_scatter(prim_name: str) -> bool:
    return prim_name.startswith("scatter")


def rule_no_scatter_in_scan(entry: JaxprEntry, jaxpr) -> list[Finding]:
    """Forbid scatter*/sort primitives inside scan/while bodies.

    A scatter or sort inside the chunk scan re-serialises the streaming path
    (PR 5's fused scan is score -> prune -> merge with no data-sized
    shuffles).  Entries may declare ``scatter_budget_elems`` to allow small
    carried scatters (the build scan's IMI histogram updates an (Ns, K)
    carry); anything larger — or any in-loop sort — is a violation."""
    findings: list[Finding] = []
    for eqn, depth in iter_eqns(jaxpr):
        if depth == 0:
            continue
        name = eqn.primitive.name
        if name in _SORT_PRIMS:
            shapes = [list(getattr(v.aval, "shape", ())) for v in eqn.outvars]
            findings.append(
                Finding(
                    rule="no-scatter-in-scan",
                    target=entry.name,
                    message=f"sort {shapes} inside a scan body (loop depth {depth})",
                )
            )
        elif _is_scatter(name):
            elems = max(
                (
                    int(math.prod(getattr(v.aval, "shape", ()) or (1,)))
                    for v in eqn.outvars
                ),
                default=0,
            )
            if elems > entry.scatter_budget_elems:
                findings.append(
                    Finding(
                        rule="no-scatter-in-scan",
                        target=entry.name,
                        message=(
                            f"{name} of {elems} elems inside a scan body "
                            f"(budget {entry.scatter_budget_elems}, "
                            f"loop depth {depth})"
                        ),
                    )
                )
    return findings


def rule_bounded_intermediate(entry: JaxprEntry, jaxpr) -> list[Finding]:
    """Peak single-intermediate bytes must fit the entry's declared budget.

    The budget encodes the paper-facing memory claim (streaming query:
    O(m*(block_n + n_candidates)); chunked build: O(codebooks * block)) and
    is additionally capped by the backend HBM model from
    ``core.tuning.backend_limits``."""
    from repro.core.tuning import backend_limits

    budget = entry.budget_bytes
    if budget is None:
        budget = backend_limits().hbm_bytes
    budget = min(budget, backend_limits().hbm_bytes)
    peak, where = peak_intermediate_bytes(jaxpr)
    if peak > budget:
        return [
            Finding(
                rule="bounded-intermediate",
                target=entry.name,
                message=(
                    f"peak intermediate {peak} B ({where}) exceeds the "
                    f"declared budget {budget} B"
                ),
            )
        ]
    return []


#: Reductions whose accumulator dtype matters for the paper's exactness story.
_REDUCE_PRIMS = frozenset({"reduce_sum", "cumsum", "dot_general", "add_any"})
_LOW_PRECISION = frozenset(
    {"float16", "bfloat16", "float8_e4m3fn", "float8_e5m2", "float8_e4m3b11fnuz"}
)


def rule_pinned_accumulator(entry: JaxprEntry, jaxpr) -> list[Finding]:
    """Float reductions (sums, cumsums, matmuls) must accumulate in fp32+.

    The rerank distances and k-means statistics are exactness-critical: a
    bf16 accumulator silently breaks the bit-parity contract between the
    dense/streaming/fused paths and the tie-break determinism tests."""
    findings: list[Finding] = []
    for eqn, _ in iter_eqns(jaxpr):
        if eqn.primitive.name not in _REDUCE_PRIMS:
            continue
        for var in eqn.outvars:
            dtype = getattr(getattr(var, "aval", None), "dtype", None)
            if dtype is not None and str(dtype) in _LOW_PRECISION:
                shape = list(var.aval.shape)
                findings.append(
                    Finding(
                        rule="pinned-accumulator",
                        target=entry.name,
                        message=(
                            f"{eqn.primitive.name} accumulates in {dtype} "
                            f"{shape}; reductions must be pinned to float32"
                        ),
                    )
                )
    return findings


# ----------------------------- tile-shape -----------------------------------


def _pallas_eqns(jaxpr) -> Iterator[Any]:
    for eqn, _ in iter_eqns(jaxpr):
        if eqn.primitive.name == "pallas_call":
            yield eqn


def _int_dims(block_shape) -> list[int | None]:
    return [d if isinstance(d, int) else None for d in block_shape]


def rule_tile_shape(entry: TileEntry) -> list[Finding]:
    """Validate Pallas block/grid shapes against the declared tile contract.

    Checks, per ``pallas_call`` found in the entry's jaxpr: every block
    divides its operand (no silent partial tiles beyond the op wrapper's own
    padding), declared lane/sublane alignment per block mapping, and the
    summed block footprint (x double-buffering) fits the TPU fast-memory
    budget.  ``TileConfig`` samples are checked against the autotuner's
    quantisation contract."""
    from repro.core.tuning import backend_limits

    findings: list[Finding] = []
    c = entry.contract

    def fail(message: str) -> None:
        findings.append(Finding(rule="tile-shape", target=entry.name, message=message))

    for cfg in entry.tile_configs:
        if c.get("sublane") and cfg.bm % c["sublane"]:
            fail(f"TileConfig bm={cfg.bm} not a multiple of sublane {c['sublane']}")
        if c.get("lane") and cfg.bn % c["lane"]:
            fail(f"TileConfig bn={cfg.bn} not a multiple of lane {c['lane']}")
        if c.get("block_quantum") and cfg.block_n % c["block_quantum"]:
            fail(
                f"TileConfig block_n={cfg.block_n} not a multiple of "
                f"quantum {c['block_quantum']}"
            )
        if c.get("cap_quantum") and cfg.survivor_cap % c["cap_quantum"]:
            fail(
                f"TileConfig survivor_cap={cfg.survivor_cap} not a multiple "
                f"of quantum {c['cap_quantum']}"
            )
        if cfg.survivor_cap > cfg.block_n:
            fail(
                f"TileConfig survivor_cap={cfg.survivor_cap} exceeds "
                f"block_n={cfg.block_n}"
            )

    if entry.make is None:
        return findings

    jaxpr = entry.make()
    vmem_budget = int(c.get("vmem_bytes", backend_limits("tpu").fast_bytes))
    double_buffer = int(c.get("double_buffer", 2))
    found_any = False
    for eqn in _pallas_eqns(jaxpr):
        found_any = True
        gm = eqn.params.get("grid_mapping")
        out_avals = tuple(eqn.params.get("out_avals", ()))
        if gm is None:
            fail("pallas_call without a grid_mapping param (jax API drift)")
            continue
        grid = tuple(gm.grid)
        if not all(isinstance(g, int) and g > 0 for g in grid):
            fail(f"non-static or empty grid {grid}")
        mappings = list(gm.block_mappings)
        n_out = len(out_avals)
        in_maps = mappings[: len(mappings) - n_out]
        # scalar-prefetch operands lead the invars and have no block mapping
        in_avals = [v.aval for v in eqn.invars][len(eqn.invars) - len(in_maps) :]
        operands = list(zip(in_maps, in_avals)) + list(
            zip(mappings[len(in_maps) :], out_avals)
        )

        vmem = 0
        for mi, (bm, aval) in enumerate(operands):
            block = _int_dims(bm.block_shape)
            shape = tuple(getattr(aval, "shape", ()))
            dtype = getattr(aval, "dtype", np.dtype("float32"))
            if len(block) > len(shape):
                fail(
                    f"mapping {mi}: block rank {len(block)} exceeds operand "
                    f"rank {len(shape)} ({shape})"
                )
                continue
            # blocks index the trailing dims of the operand
            for dim, bdim in enumerate(block):
                if bdim is None:
                    continue
                odim = shape[len(shape) - len(block) + dim]
                if bdim > odim or odim % bdim:
                    fail(
                        f"mapping {mi}: block {block} does not tile operand "
                        f"{list(shape)} (dim {dim}: {odim} % {bdim} != 0)"
                    )
            vmem += (
                math.prod(b if b is not None else 1 for b in block)
                * np.dtype(dtype).itemsize
            )
            for dim, mult in c.get("block_align", {}).get(mi, ()):
                bdim = block[dim]
                if bdim is not None and bdim % mult:
                    fail(
                        f"mapping {mi}: block {block} dim {dim} = {bdim} "
                        f"not a multiple of {mult} (tile contract)"
                    )
        if vmem * double_buffer > vmem_budget:
            fail(
                f"block working set {vmem} B x{double_buffer} double-buffer "
                f"exceeds the VMEM budget {vmem_budget} B"
            )
    if not found_any:
        fail("entry declared a tile contract but traced no pallas_call")
    return findings


# ------------------------------ dispatch ------------------------------------

JaxprRule = Callable[[JaxprEntry, Any], list[Finding]]

JAXPR_RULES: dict[str, JaxprRule] = {
    "no-scatter-in-scan": rule_no_scatter_in_scan,
    "bounded-intermediate": rule_bounded_intermediate,
    "pinned-accumulator": rule_pinned_accumulator,
}

RULE_DOCS: dict[str, str] = {
    "no-scatter-in-scan": (
        "no scatter/sort primitive executes inside the chunk scan body"
    ),
    "bounded-intermediate": (
        "peak single-intermediate bytes fit the declared block_n-scaled budget"
    ),
    "pinned-accumulator": "float reductions accumulate in float32, never bf16/f16",
    "tile-shape": (
        "Pallas blocks tile their operands, respect lane/sublane alignment, "
        "and fit the VMEM model"
    ),
}


def _apply_suppressions(entry, findings: list[Finding]) -> list[Finding]:
    out = []
    for f in findings:
        reason = entry.suppress.get(f.rule)
        if reason is not None:
            f = Finding(
                rule=f.rule,
                target=f.target,
                message=f.message,
                severity=f.severity,
                suppressed=True,
                suppress_reason=reason,
            )
        out.append(f)
    return out


def run_jaxpr_rules(entry) -> tuple[list[Finding], list[str]]:
    """Run every applicable rule for one registry entry.

    Returns ``(findings, rules_checked)``.  For a :class:`TileEntry` the only
    applicable rule is ``tile-shape``; for a :class:`JaxprEntry` the entry is
    traced once and each declared rule runs over the shared jaxpr."""
    if isinstance(entry, TileEntry):
        return _apply_suppressions(entry, rule_tile_shape(entry)), ["tile-shape"]
    jaxpr = entry.make()
    findings: list[Finding] = []
    checked: list[str] = []
    for rule in entry.rules:
        fn = JAXPR_RULES.get(rule)
        if fn is None:
            findings.append(
                Finding(
                    rule=rule,
                    target=entry.name,
                    message=f"unknown jaxpr rule {rule!r} declared by the entry",
                )
            )
            continue
        findings.extend(fn(entry, jaxpr))
        checked.append(rule)
    return _apply_suppressions(entry, findings), checked
