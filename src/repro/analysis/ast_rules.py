"""Engine 2: Python-AST rules over the serving layer.

The jaxpr engine sees the traced program; these rules see the Python around
it — the territory where retrace hazards and accidental host syncs live.
Three rules:

* ``host-sync`` — ``np.asarray`` / ``block_until_ready`` / ``.item()`` /
  ``jax.device_get`` on device data blocks the dispatch pipeline, and
  ``os.fsync`` / ``os.fdatasync`` blocks the caller on durable storage
  (milliseconds, not microseconds — a stray fsync on the serving path is
  the WAL's no-blocking-fsync invariant broken).  Every such point in
  ``serve/``/``distributed/`` must carry an explicit
  ``# jaxlint: sync-ok`` annotation (the AsyncAnnServer retire point is the
  only blocking point in the hot path; everything else is warmup,
  checkpoint I/O, or the durability maintenance thread).  Conversions of
  host-literal containers (lists, list comprehensions, constants) are not
  syncs and are ignored.
* ``tracer-branch`` — a Python ``if``/``while`` on a parameter of a jitted
  function branches on a tracer: either a ConcretizationTypeError at trace
  time or, via ``static_argnames``, a silent retrace per distinct value.
* ``jit-in-hot-path`` — constructing ``jax.jit(...)`` inside a ``for``/
  ``while`` body makes a fresh cache per iteration, defeating the
  zero-retrace-after-warmup contract.

Suppression is comment-based and line-scoped: ``# jaxlint: sync-ok`` (for
host-sync) or ``# jaxlint: disable=<rule>`` on the flagged line.
"""

from __future__ import annotations

import ast
import re

from repro.analysis.findings import Finding
from repro.analysis.registry import AstTarget

_SYNC_OK = re.compile(r"#\s*jaxlint:\s*sync-ok\b")
_DISABLE = re.compile(r"#\s*jaxlint:\s*disable=([\w,-]+)")

#: Call attribute names that force device->host synchronisation.
_SYNC_ATTRS = frozenset({"block_until_ready", "device_get"})
#: Blocking durable-storage calls: not a device sync, but the same SLO
#: hazard — an fsync on the serving path stalls the dispatch loop for
#: milliseconds.  The durability layer (serve/durability.py) confines
#: these to per-record opt-in, the maintenance thread, and snapshot I/O.
_BLOCKING_IO = frozenset({"fsync", "fdatasync"})
_NUMPY_NAMES = frozenset({"np", "numpy"})
_NUMPY_CONVERTERS = frozenset({"asarray", "array"})

#: AST node types whose conversion to numpy is host data, not a device sync.
_HOST_LITERALS = (
    ast.List,
    ast.ListComp,
    ast.GeneratorExp,
    ast.Tuple,
    ast.Dict,
    ast.DictComp,
    ast.SetComp,
    ast.Constant,
)


def _dotted(node: ast.AST) -> str:
    """Render a Name/Attribute chain like ``jax.jit``; '' if not a chain."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _line_suppressions(source: str) -> tuple[set[int], dict[int, set[str]]]:
    sync_ok: set[int] = set()
    disabled: dict[int, set[str]] = {}
    for lineno, line in enumerate(source.splitlines(), start=1):
        if _SYNC_OK.search(line):
            sync_ok.add(lineno)
        m = _DISABLE.search(line)
        if m:
            disabled.setdefault(lineno, set()).update(
                r.strip() for r in m.group(1).split(",") if r.strip()
            )
    return sync_ok, disabled


# ------------------------------ host-sync -----------------------------------


def _sync_call_reason(call: ast.Call) -> str | None:
    func = call.func
    if isinstance(func, ast.Attribute):
        if func.attr in _SYNC_ATTRS:
            return f"{_dotted(func) or func.attr}() blocks until device work finishes"
        if func.attr in _BLOCKING_IO:
            return (
                f"{_dotted(func) or func.attr}() blocks the caller on durable "
                "storage"
            )
        if func.attr == "item" and not call.args and not call.keywords:
            return ".item() pulls a device scalar to the host"
        if isinstance(func.value, ast.Name) and func.value.id in _NUMPY_NAMES:
            if func.attr in _NUMPY_CONVERTERS:
                if call.args and isinstance(call.args[0], _HOST_LITERALS):
                    return None  # converting host data, not a device array
                return (
                    f"np.{func.attr}() on a device value synchronises the stream"
                )
    elif isinstance(func, ast.Name) and func.id in _SYNC_ATTRS:
        return f"{func.id}() blocks until device work finishes"
    elif isinstance(func, ast.Name) and func.id in _BLOCKING_IO:
        return f"{func.id}() blocks the caller on durable storage"
    return None


def _check_host_sync(tree: ast.AST, target: str, sync_ok: set[int]) -> list[Finding]:
    findings: list[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        reason = _sync_call_reason(node)
        if reason is None:
            continue
        end_line = getattr(node, "end_lineno", node.lineno)
        if node.lineno in sync_ok or end_line in sync_ok:
            findings.append(
                Finding(
                    rule="host-sync",
                    target=f"{target}:{node.lineno}",
                    message=reason,
                    suppressed=True,
                    suppress_reason="annotated sync-ok",
                )
            )
        else:
            findings.append(
                Finding(
                    rule="host-sync",
                    target=f"{target}:{node.lineno}",
                    message=f"unannotated host sync: {reason} "
                    "(add '# jaxlint: sync-ok' if intentional)",
                )
            )
    return findings


# ---------------------------- tracer-branch ---------------------------------


def _jit_static_argnames(func: ast.FunctionDef) -> tuple[bool, set[str]]:
    """Is this function jit-decorated, and which params are static?

    Recognises ``@jax.jit``, ``@jit``, ``@partial(jax.jit, ...)`` and
    ``@functools.partial(jax.jit, static_argnames=...)``."""
    for deco in func.decorator_list:
        name = _dotted(deco)
        if name in ("jax.jit", "jit"):
            return True, set()
        if isinstance(deco, ast.Call):
            cname = _dotted(deco.func)
            if cname in ("jax.jit", "jit"):
                return True, _static_names_from_kwargs(deco)
            if cname in ("partial", "functools.partial") and deco.args:
                inner = _dotted(deco.args[0])
                if inner in ("jax.jit", "jit"):
                    return True, _static_names_from_kwargs(deco)
    return False, set()


def _static_names_from_kwargs(call: ast.Call) -> set[str]:
    static: set[str] = set()
    for kw in call.keywords:
        if kw.arg in ("static_argnames", "static_argnums") and isinstance(
            kw.value, (ast.Tuple, ast.List, ast.Constant)
        ):
            elts = (
                kw.value.elts
                if isinstance(kw.value, (ast.Tuple, ast.List))
                else [kw.value]
            )
            for e in elts:
                if isinstance(e, ast.Constant) and isinstance(e.value, str):
                    static.add(e.value)
    return static


def _check_tracer_branch(tree: ast.AST, target: str) -> list[Finding]:
    findings: list[Finding] = []
    for func in ast.walk(tree):
        if not isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        jitted, static = _jit_static_argnames(func)
        if not jitted:
            continue
        params = {
            a.arg
            for a in (
                func.args.args + func.args.posonlyargs + func.args.kwonlyargs
            )
        }
        traced = params - static - {"self", "cls"}
        for node in ast.walk(func):
            if not isinstance(node, (ast.If, ast.While)):
                continue
            names = {
                n.id for n in ast.walk(node.test) if isinstance(n, ast.Name)
            }
            hits = sorted(names & traced)
            if hits:
                findings.append(
                    Finding(
                        rule="tracer-branch",
                        target=f"{target}:{node.lineno}",
                        message=(
                            f"Python {type(node).__name__.lower()} on traced "
                            f"argument(s) {hits} of jitted '{func.name}' — "
                            "use lax.cond/select or mark the argument static"
                        ),
                    )
                )
    return findings


# --------------------------- jit-in-hot-path --------------------------------


def _check_jit_in_hot_path(tree: ast.AST, target: str) -> list[Finding]:
    findings: list[Finding] = []
    for loop in ast.walk(tree):
        if not isinstance(loop, (ast.For, ast.While, ast.AsyncFor)):
            continue
        for node in ast.walk(loop):
            if isinstance(node, ast.Call) and _dotted(node.func) in (
                "jax.jit",
                "jit",
            ):
                findings.append(
                    Finding(
                        rule="jit-in-hot-path",
                        target=f"{target}:{node.lineno}",
                        message=(
                            "jax.jit(...) constructed inside a loop body — "
                            "each call makes a fresh compilation cache; hoist "
                            "it out of the loop"
                        ),
                    )
                )
    return findings


# ------------------------------ dispatch ------------------------------------

AST_RULES: tuple[str, ...] = ("host-sync", "tracer-branch", "jit-in-hot-path")

AST_RULE_DOCS: dict[str, str] = {
    "host-sync": (
        "every device->host sync point — and every blocking fsync/fdatasync — "
        "carries an explicit '# jaxlint: sync-ok' annotation"
    ),
    "tracer-branch": (
        "no Python if/while branches on a traced argument of a jitted function"
    ),
    "jit-in-hot-path": "jax.jit is never constructed inside a loop body",
}


def lint_source(source: str, target: str) -> list[Finding]:
    """Run all AST rules over one file's source text."""
    tree = ast.parse(source, filename=target)
    sync_ok, disabled = _line_suppressions(source)
    findings = (
        _check_host_sync(tree, target, sync_ok)
        + _check_tracer_branch(tree, target)
        + _check_jit_in_hot_path(tree, target)
    )
    out: list[Finding] = []
    for f in findings:
        lineno = int(f.target.rsplit(":", 1)[1]) if ":" in f.target else -1
        rules_off = disabled.get(lineno, set())
        if not f.suppressed and f.rule in rules_off:
            f = Finding(
                rule=f.rule,
                target=f.target,
                message=f.message,
                severity=f.severity,
                suppressed=True,
                suppress_reason="line disable comment",
            )
        out.append(f)
    return out


def lint_target(target: AstTarget) -> list[Finding]:
    return lint_source(target.path.read_text(), target.name)
