"""jaxlint: static analysis that proves the serving invariants before runtime.

The serving stack's production claims — zero retraces after warmup, streaming
memory bounded by ``block_n``, the fused path's scatter/sort-free scan, the
fp32-pinned rerank reductions, lane-aligned Pallas tiles — used to be enforced
only by runtime assertions scattered across tests and benchmarks, so a
regression surfaced (if at all) after an expensive build/serve run.  This
package certifies them *statically*, in milliseconds, from two sources of
truth:

* **Engine 1** (:mod:`repro.analysis.jaxpr_rules`) walks the **closed
  jaxprs** of the registered entry points (the query paths, the engine's
  per-bucket executables, the index-build scans, each Pallas op) — the exact
  programs XLA will compile — and checks structural rules: no scatter/sort
  primitive inside a chunk scan, peak intermediate bytes within the declared
  budget, float reductions pinned to fp32, Pallas block/grid shapes aligned
  to the TPU tile and sized for VMEM.
* **Engine 2** (:mod:`repro.analysis.ast_rules`) parses the Python source of
  the serving layer (``repro/serve``, ``repro/distributed``) for retrace
  hazards the tracer cannot see — ``jax.jit`` constructed inside a hot loop,
  Python branches on traced arguments — and for host-sync points
  (``np.asarray`` / ``block_until_ready``) missing an explicit
  ``# jaxlint: sync-ok`` annotation.

Entry points self-register through ``jaxlint_entries()`` hooks in the core
modules and kernel op wrappers (:mod:`repro.analysis.registry`); the CLI is
``python -m repro.analysis.lint`` (human or ``--format=json`` report,
per-rule suppressions).  The rule catalogue, what each rule proves, and how
it maps onto the paper's guarantees live in ``docs/invariants.md``.
"""

from repro.analysis.findings import Finding, Report
from repro.analysis.registry import (
    AstTarget,
    JaxprEntry,
    TileEntry,
    ast_targets,
    collect_entries,
)
from repro.analysis.jaxpr_rules import (
    JAXPR_RULES,
    iter_eqns,
    peak_intermediate_bytes,
    run_jaxpr_rules,
)
from repro.analysis.ast_rules import AST_RULES, lint_source

# NOTE: repro.analysis.lint (the CLI) is deliberately not imported here —
# ``python -m repro.analysis.lint`` would otherwise import it twice (runpy
# RuntimeWarning).  Import it explicitly where needed.

__all__ = [
    "Finding",
    "Report",
    "JaxprEntry",
    "TileEntry",
    "AstTarget",
    "collect_entries",
    "ast_targets",
    "JAXPR_RULES",
    "AST_RULES",
    "iter_eqns",
    "peak_intermediate_bytes",
    "run_jaxpr_rules",
    "lint_source",
]
