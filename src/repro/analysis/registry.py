"""Entry-point registry: what jaxlint checks and where it finds it.

Modules that own a public jitted entry point (the query paths in
``core/suco.py``, the linear-scan fallback in ``core/sc_linear.py``, the tile
autotuner in ``core/tuning.py``, each Pallas op wrapper under ``kernels/``)
export a module-level ``jaxlint_entries()`` hook returning ``JaxprEntry`` /
``TileEntry`` records.  The hook owns the *declaration* — which rules apply,
the peak-intermediate budget, the tile contract — so the invariant lives next
to the code it constrains; this module only aggregates.

Hooks are imported lazily inside :func:`collect_entries` (and hook bodies
import this module lazily in turn) so ``repro.core`` never depends on
``repro.analysis`` at import time.
"""

from __future__ import annotations

import dataclasses
import fnmatch
import importlib
from pathlib import Path
from typing import Any, Callable, Mapping, Sequence

#: Modules probed for a ``jaxlint_entries()`` hook, in report order.
HOOK_MODULES: tuple[str, ...] = (
    "repro.core.suco",
    "repro.core.sc_linear",
    "repro.core.tuning",
    "repro.kernels.sc_score.ops",
    "repro.kernels.gather_rerank.ops",
    "repro.kernels.kmeans_assign.ops",
    "repro.kernels.pairwise_l2.ops",
)


@dataclasses.dataclass(frozen=True)
class JaxprEntry:
    """A traceable entry point checked by the jaxpr engine.

    ``make`` returns the closed jaxpr (``jax.make_jaxpr(...)`` result) of the
    entry at canonical shapes — large enough that the declared budgets
    separate the bounded paths from the dense ones, small enough to trace in
    seconds.  ``rules`` names which jaxpr rules apply; ``budget_bytes`` is the
    ``bounded-intermediate`` ceiling (peak bytes of any single intermediate);
    ``scatter_budget_elems`` lets ``no-scatter-in-scan`` tolerate declared
    small scatters (the build scan's IMI histogram) while still forbidding
    data-sized ones.  ``suppress`` maps rule name -> reason for audited
    opt-outs.
    """

    name: str
    make: Callable[[], Any]
    rules: tuple[str, ...]
    budget_bytes: int | None = None
    scatter_budget_elems: int = 0
    suppress: Mapping[str, str] = dataclasses.field(default_factory=dict)
    note: str = ""


@dataclasses.dataclass(frozen=True)
class TileEntry:
    """A Pallas kernel's tile contract, checked by the ``tile-shape`` rule.

    ``contract`` declares the alignment model: ``sublane``/``lane`` (TPU
    register tile for 4-byte dtypes), ``double_buffer`` (VMEM multiplier for
    pipelined blocks), and optional ``block_align`` mapping a block-mapping
    index (inputs then outputs, scalar-prefetch operands excluded) to
    ``((dim, multiple), ...)`` constraints.  ``make`` (optional) returns a
    jaxpr containing the ``pallas_call`` so block shapes/grid are read from
    the traced program, not from the declaration.  ``tile_configs`` (optional)
    are :class:`repro.core.tuning.TileConfig` samples to validate against the
    quantisation contract.
    """

    name: str
    contract: Mapping[str, Any]
    make: Callable[[], Any] | None = None
    tile_configs: tuple = ()
    suppress: Mapping[str, str] = dataclasses.field(default_factory=dict)
    note: str = ""


@dataclasses.dataclass(frozen=True)
class AstTarget:
    """One source file scanned by the AST engine."""

    name: str
    path: Path


Entry = Any  # JaxprEntry | TileEntry


def collect_entries(
    modules: Sequence[str] = HOOK_MODULES,
    pattern: str = "*",
) -> list[Entry]:
    """Import each hook module and gather its declared entries.

    ``pattern`` is an fnmatch glob over entry names (CLI ``--entries``).
    Import or hook failures raise — a broken hook must fail the lint loudly,
    not silently shrink coverage.
    """
    entries: list[Entry] = []
    seen: set[str] = set()
    for modname in modules:
        mod = importlib.import_module(modname)
        hook = getattr(mod, "jaxlint_entries", None)
        if hook is None:
            continue
        for entry in hook():
            if entry.name in seen:
                raise ValueError(f"duplicate jaxlint entry name: {entry.name!r}")
            seen.add(entry.name)
            if fnmatch.fnmatch(entry.name, pattern):
                entries.append(entry)
    return entries


#: Packages whose Python source the AST engine scans (serving layer: the
#: code where a stray host sync or retrace hazard breaks the SLO story).
AST_SCAN_PACKAGES: tuple[str, ...] = ("serve", "distributed")


def ast_targets(pattern: str = "*") -> list[AstTarget]:
    import repro

    root = Path(repro.__file__).resolve().parent
    targets: list[AstTarget] = []
    for pkg in AST_SCAN_PACKAGES:
        for path in sorted((root / pkg).glob("*.py")):
            name = f"repro/{pkg}/{path.name}"
            if fnmatch.fnmatch(name, pattern):
                targets.append(AstTarget(name=name, path=path))
    return targets
