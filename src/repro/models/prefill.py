"""Prefill: full-sequence forward that also materialises the decode cache.

Returns ``(last_token_logits, cache)`` with the cache laid out exactly as
:func:`repro.models.decode.init_cache` (zero-padded to ``max_seq``).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.backbone import _dtype, _layer_windows, logits_for_position
from repro.models.layers import Params
from repro.kernels.linear_attn.ops import linear_attention_with_state


def _kv(p, xn, cfg: ModelConfig, positions=None):
    dtype = xn.dtype
    k = L._split_heads(L.linear(p["wk"], xn, dtype), cfg.n_kv_heads)
    v = L._split_heads(L.linear(p["wv"], xn, dtype), cfg.n_kv_heads)
    if cfg.use_rope and positions is not None:
        k = L.rope(k, positions, cfg.rope_theta)
    return k, v


def _pad_seq(a: jax.Array, max_seq: int, axis: int = 2) -> jax.Array:
    pad = max_seq - a.shape[axis]
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def _self_attn_with_kv(p, x, cfg, window=None):
    """Self-attention block half that also returns (k, v) for the cache."""
    dtype = x.dtype
    b, s, _ = x.shape
    q = L._split_heads(L.linear(p["wq"], x, dtype), cfg.n_heads)
    pos = jnp.arange(s)
    k, v = _kv(p, x, cfg, positions=pos)
    if cfg.use_rope:
        q = L.rope(q, pos, cfg.rope_theta)
    o = L.flash_attention(q, k, v, causal=True, window=window, softcap=cfg.attn_softcap)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.q_dim)
    return L.linear(p["wo"], o, dtype), k, v


def _dense_block_prefill(p, x, cfg, window):
    xn = L.apply_norm(p["ln1"], x, cfg)
    h, k, v = _self_attn_with_kv(p["attn"], xn, cfg, window)
    if cfg.sandwich_norm:
        h = L.apply_norm(p["ln1_post"], h, cfg)
    x = x + h
    y = L.apply_norm(p["ln2"], x, cfg)
    y = L.moe_forward(p["moe"], y, cfg) if "moe" in p else L.mlp_forward(p["mlp"], y, cfg)
    if cfg.sandwich_norm:
        y = L.apply_norm(p["ln2_post"], y, cfg)
    return x + y, k, v


def prefill(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,  # (B, S)
    *,
    extras: jax.Array | None = None,
    max_seq: int | None = None,
    cache_dtype=jnp.bfloat16,
) -> tuple[jax.Array, Params]:
    dtype = _dtype(cfg)
    b, s = tokens.shape
    max_seq = max_seq or s
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    if cfg.embed_scale:
        x = x * math.sqrt(cfg.d_model)
    if cfg.learned_pos:
        x = x + params["dec_pos"][:s][None].astype(dtype)
    cache: Params = {}

    if cfg.family in ("dense", "moe"):
        windows = _layer_windows(cfg)

        if windows is None:
            def body(x, p):
                x, k, v = _dense_block_prefill(p, x, cfg, None)
                return x, (k.astype(cache_dtype), v.astype(cache_dtype))
            x, (ks, vs) = jax.lax.scan(body, x, params["blocks"])
        else:
            def body(x, inp):
                p, w = inp
                # static alternation is resolved per-layer by masking with a
                # huge window when the flag is 0
                eff = jnp.where(w > 0, w, jnp.asarray(1 << 30, jnp.int32))
                from repro.models.backbone import _flash_dynwin
                xn = L.apply_norm(p["ln1"], x, cfg)
                q = L._split_heads(L.linear(p["attn"]["wq"], xn, x.dtype), cfg.n_heads)
                pos = jnp.arange(x.shape[1])
                k, v = _kv(p["attn"], xn, cfg, positions=pos)
                if cfg.use_rope:
                    q = L.rope(q, pos, cfg.rope_theta)
                o = _flash_dynwin(q, k, v, eff, cfg)
                o = o.transpose(0, 2, 1, 3).reshape(x.shape[0], x.shape[1], cfg.q_dim)
                h = L.linear(p["attn"]["wo"], o, x.dtype)
                if cfg.sandwich_norm:
                    h = L.apply_norm(p["ln1_post"], h, cfg)
                xx = x + h
                y = L.apply_norm(p["ln2"], xx, cfg)
                y = (L.moe_forward(p["moe"], y, cfg) if "moe" in p
                     else L.mlp_forward(p["mlp"], y, cfg))
                if cfg.sandwich_norm:
                    y = L.apply_norm(p["ln2_post"], y, cfg)
                return xx + y, (k.astype(cache_dtype), v.astype(cache_dtype))
            x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], windows))

        cache["k"] = _pad_seq(ks, max_seq, axis=3)
        cache["v"] = _pad_seq(vs, max_seq, axis=3)

    elif cfg.family == "ssm":
        def body(x, p):
            xn1 = L.apply_norm(p["ln1"], x, cfg)
            h, wkv_state = _rwkv_time_mix_with_state(p["time_mix"], xn1, cfg)
            x = x + h
            xn2 = L.apply_norm(p["ln2"], x, cfg)
            x = x + S.rwkv_channel_mix(p["channel_mix"], xn2, cfg)
            return x, (xn1[:, -1].astype(cache_dtype), xn2[:, -1].astype(cache_dtype),
                       wkv_state)
        x, (p1, p2, wkv) = jax.lax.scan(body, x, params["blocks"])
        cache.update(prev1=p1, prev2=p2, wkv=wkv)

    elif cfg.family == "hybrid":
        period = cfg.hybrid_period
        n_units = cfg.n_layers // period
        n_tail = cfg.n_layers - n_units * period
        shared = params["shared"]
        unit_pp = jax.tree.map(
            lambda a: a[: n_units * period].reshape(n_units, period, *a.shape[1:]),
            params["blocks"],
        )
        tail_pp = jax.tree.map(lambda a: a[n_units * period :], params["blocks"])

        def mamba_body(x, p):
            xn = L.apply_norm(p["ln1"], x, cfg)
            h, conv_st, ssm_st = _mamba2_with_state(p["mamba"], xn, cfg)
            return x + h, (conv_st.astype(cache_dtype), ssm_st)

        def unit(x, pp):
            x, states = jax.lax.scan(mamba_body, x, pp)
            xn = L.apply_norm(shared["ln1"], x, cfg)
            h, k, v = _self_attn_with_kv(shared["attn"], xn, cfg, None)
            x = x + h
            y = L.apply_norm(shared["ln2"], x, cfg)
            x = x + L.mlp_forward(shared["mlp"], y, cfg)
            return x, (states, k.astype(cache_dtype), v.astype(cache_dtype))

        x, (unit_states, sk, sv) = jax.lax.scan(unit, x, unit_pp)
        if n_tail:
            x, tail_states = jax.lax.scan(mamba_body, x, tail_pp)
        conv_u, ssm_u = unit_states
        conv = conv_u.reshape(n_units * period, *conv_u.shape[2:])
        ssm_st = ssm_u.reshape(n_units * period, *ssm_u.shape[2:])
        if n_tail:
            conv = jnp.concatenate([conv, tail_states[0]], axis=0)
            ssm_st = jnp.concatenate([ssm_st, tail_states[1]], axis=0)
        cache.update(
            conv=conv, ssm=ssm_st,
            sk=_pad_seq(sk, max_seq, axis=3), sv=_pad_seq(sv, max_seq, axis=3),
        )

    elif cfg.family == "audio":
        enc = extras.astype(dtype) + params["enc_pos"][None].astype(dtype)

        def enc_body(h, p):
            h = h + L.attn_forward(p["attn"], L.apply_norm(p["ln1"], h, cfg), cfg, causal=False)
            h = h + L.mlp_forward(p["mlp"], L.apply_norm(p["ln2"], h, cfg), cfg)
            return h, None
        enc, _ = jax.lax.scan(enc_body, enc, params["enc_blocks"])
        enc = L.apply_norm(params["enc_final_norm"], enc, cfg)

        def dec_body(x, p):
            xn = L.apply_norm(p["ln1"], x, cfg)
            h, k, v = _self_attn_with_kv(p["attn"], xn, cfg, None)
            x = x + h
            xn2 = L.apply_norm(p["ln_x"], x, cfg)
            xk, xv = _kv(p["cross"], enc, cfg)
            x = x + L.attn_forward(p["cross"], xn2, cfg, kv_override=enc)
            x = x + L.mlp_forward(p["mlp"], L.apply_norm(p["ln2"], x, cfg), cfg)
            return x, (k.astype(cache_dtype), v.astype(cache_dtype),
                       xk.astype(cache_dtype), xv.astype(cache_dtype))

        x, (ks, vs, xks, xvs) = jax.lax.scan(dec_body, x, params["blocks"])
        cache.update(
            k=_pad_seq(ks, max_seq, axis=3), v=_pad_seq(vs, max_seq, axis=3),
            xk=xks, xv=xvs,
        )

    elif cfg.family == "vlm":
        period = cfg.cross_attn_period
        n_units = cfg.n_layers // period
        vision = extras.astype(dtype)
        self_pp = jax.tree.map(
            lambda a: a.reshape(n_units, period - 1, *a.shape[1:]), params["blocks"]
        )

        def unit(x, inp):
            selfs, crossp = inp

            def inner(x, p):
                x, k, v = _dense_block_prefill(p, x, cfg, None)
                return x, (k.astype(cache_dtype), v.astype(cache_dtype))

            x, (ks, vs) = jax.lax.scan(inner, x, selfs)
            xk, xv = _kv(crossp["cross"], vision, cfg)
            h = L.attn_forward(
                crossp["cross"], L.apply_norm(crossp["ln1"], x, cfg), cfg,
                kv_override=vision,
            )
            x = x + jnp.tanh(crossp["gate"]).astype(x.dtype) * h
            x = x + L.mlp_forward(crossp["mlp"], L.apply_norm(crossp["ln2"], x, cfg), cfg)
            return x, (ks, vs, xk.astype(cache_dtype), xv.astype(cache_dtype))

        x, (ks, vs, xks, xvs) = jax.lax.scan(
            unit, x, (self_pp, params["cross_blocks"])
        )
        cache["k"] = _pad_seq(ks.reshape(-1, *ks.shape[2:]), max_seq, axis=3)
        cache["v"] = _pad_seq(vs.reshape(-1, *vs.shape[2:]), max_seq, axis=3)
        cache["xk"], cache["xv"] = xks, xvs
    else:
        raise ValueError(cfg.family)

    x_last = L.apply_norm(params["final_norm"], x[:, -1:], cfg)[:, 0]
    return logits_for_position(cfg, params, x_last), cache


# -- state-returning variants of the ssm mixers ------------------------------


def _rwkv_time_mix_with_state(p, x, cfg: ModelConfig):
    dtype = x.dtype
    b, t, d = x.shape
    h = cfg.n_heads
    hd = d // h
    prev = S._token_shift(x)

    def mixed(i):
        mu = p["mu"][i].astype(dtype)
        return x + (prev - x) * mu

    r = L.linear(p["wr"], mixed(0), dtype)
    k = L.linear(p["wk"], mixed(1), dtype)
    v = L.linear(p["wv"], mixed(2), dtype)
    g = L.linear(p["wg"], mixed(3), dtype)
    xw = mixed(4).astype(jnp.float32)
    dd = jnp.tanh(xw @ p["w_a"]) @ p["w_b"]
    w = jnp.exp(-jnp.exp(p["w0"][None, None] + dd))

    def heads(a):
        return a.reshape(b, t, h, hd).transpose(0, 2, 1, 3).reshape(b * h, t, hd)

    u_b = jnp.broadcast_to(
        p["u"].reshape(1, h, hd).astype(dtype), (b, h, hd)
    ).reshape(b * h, 1, hd)
    o, state = linear_attention_with_state(
        heads(r), heads(k), heads(v), heads(w.astype(dtype)), u_b, shift=1
    )
    o = o.reshape(b, h, t, hd)
    state = state.reshape(b, h, hd, hd)
    of = o.astype(jnp.float32)
    of = of * jax.lax.rsqrt(jnp.mean(of * of, axis=-1, keepdims=True) + 1e-6)
    o = of.astype(dtype).transpose(0, 2, 1, 3).reshape(b, t, d)
    return L.linear(p["wo"], o * jax.nn.silu(g), dtype), state


def _mamba2_with_state(p, x, cfg: ModelConfig):
    dtype = x.dtype
    b, t, d = x.shape
    inner = cfg.ssm_expand * d
    n = cfg.ssm_state
    h = cfg.n_heads
    ph = inner // h

    zxbcdt = L.linear(p["w_in"], x, dtype)
    xin, z, bmat, cmat, dt = jnp.split(
        zxbcdt, [inner, 2 * inner, 2 * inner + n, 2 * inner + 2 * n], axis=-1
    )
    kw = p["conv"].astype(dtype)
    xpad = jnp.pad(xin, ((0, 0), (cfg.ssm_conv - 1, 0), (0, 0)))
    xconv = sum(xpad[:, i : i + t] * kw[i][None, None] for i in range(cfg.ssm_conv))
    xconv = jax.nn.silu(xconv)
    # conv state: the last K-1 raw (pre-activation) inputs
    conv_state = xin[:, t - (cfg.ssm_conv - 1) :]

    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    decay = jnp.exp(-dtf * jnp.exp(p["a_log"]))

    v = xconv.reshape(b, t, h, ph).transpose(0, 2, 1, 3)
    v = v * dtf.transpose(0, 2, 1)[..., None].astype(dtype)
    k = jnp.broadcast_to(bmat[:, None], (b, h, t, n))
    q = jnp.broadcast_to(cmat[:, None], (b, h, t, n))
    w = jnp.broadcast_to(decay.transpose(0, 2, 1)[..., None], (b, h, t, n)).astype(dtype)

    def flat(a):
        return a.reshape(b * h, t, a.shape[-1])

    u0 = jnp.zeros((b * h, 1, n), dtype)
    o, state = linear_attention_with_state(
        flat(q), flat(k), flat(v), flat(w), u0, shift=0
    )
    y = o.reshape(b, h, t, ph)
    state = state.reshape(b, h, n, ph)
    y = y + p["d_skip"].astype(dtype)[None, :, None, None] * v
    y = y.transpose(0, 2, 1, 3).reshape(b, t, inner)
    y = L.apply_norm(p["norm"], y, cfg) * jax.nn.silu(z)
    return L.linear(p["w_out"], y, dtype), conv_state, state
