"""Public model API: one object per architecture config.

    model = Model(get_config("qwen1.5-4b"))
    params = model.init(jax.random.key(0))
    loss   = model.loss(params, batch)                   # training
    logits, cache = model.prefill(params, tokens, ...)   # serving
    logits, cache = model.decode_step(params, cache, token, pos)

``input_specs(cfg, shape)`` produces ShapeDtypeStruct stand-ins for the
dry-run (weak-type-correct, shardable, no allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import backbone, decode as D, prefill as P
from repro.models.layers import Params

__all__ = ["Model", "ShapeSpec", "input_specs", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- parameters --------------------------------------------------------
    def init(self, key) -> Params:
        return backbone.init_params(self.cfg, key)

    def param_shapes(self) -> Params:
        return backbone.param_shapes(self.cfg)

    # -- training ----------------------------------------------------------
    def loss(self, params: Params, batch: dict, *, remat: bool = True) -> jax.Array:
        hidden = backbone.forward_hidden(
            self.cfg, params, batch["tokens"], extras=batch.get("extras"), remat=remat
        )
        return backbone.chunked_ce_loss(self.cfg, params, hidden, batch["labels"])

    # -- serving -----------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int, dtype=jnp.bfloat16) -> Params:
        return D.init_cache(self.cfg, batch, max_seq, dtype)

    def prefill(self, params, tokens, *, extras=None, max_seq=None):
        return P.prefill(self.cfg, params, tokens, extras=extras, max_seq=max_seq)

    def decode_step(self, params, cache, token, pos):
        return D.decode_step(self.cfg, params, cache, token, pos)


def _extras_spec(cfg: ModelConfig, batch: int, dtype) -> jax.ShapeDtypeStruct | None:
    if cfg.family == "audio":
        return jax.ShapeDtypeStruct((batch, cfg.encoder_seq, cfg.d_model), dtype)
    if cfg.family == "vlm":
        return jax.ShapeDtypeStruct((batch, cfg.vision_tokens, cfg.d_model), dtype)
    return None


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of the given cell."""
    dtype = jnp.dtype(cfg.dtype)
    b = shape.global_batch
    s = shape.seq_len
    tok = jnp.int32
    if shape.kind == "train":
        out = {
            "tokens": jax.ShapeDtypeStruct((b, s), tok),
            "labels": jax.ShapeDtypeStruct((b, s), tok),
        }
        ex = _extras_spec(cfg, b, dtype)
        if ex is not None:
            out["extras"] = ex
        return out
    if shape.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((b, s), tok)}
        ex = _extras_spec(cfg, b, dtype)
        if ex is not None:
            out["extras"] = ex
        return out
    if shape.kind == "decode":
        cache = jax.eval_shape(lambda: D.init_cache(cfg, b, s, jnp.bfloat16))
        return {
            "token": jax.ShapeDtypeStruct((b,), tok),
            "pos": jax.ShapeDtypeStruct((), tok),
            "cache": cache,
        }
    raise ValueError(shape.kind)
