"""Prefill + single-token decode with per-family caches.

Cache layouts (leading L = layer axis, scanned):

  dense/moe   {"k","v": (L, B, Hkv, Smax, hd)}  (+ per-layer window flags)
  vlm         {"k","v": (Ls, ...)} + read-only {"xk","xv": (Lc, B, Hkv, Tv, hd)}
  audio       {"k","v": (L, ...)} + read-only cross {"xk","xv": (L, B, Hkv, Te, hd)}
  ssm (rwkv6) {"prev1","prev2": (L, B, D), "wkv": (L, B, H, hd, hd)}
  hybrid      {"conv": (L, B, K-1, inner), "ssm": (L, B, H, N, P),
               "sk","sv": (n_apps, B, Hkv, Smax, hd)}   (shared-attn KV)

SSM/hybrid state is O(1) in context length — the 500k-decode shape costs the
same as 1k-decode for rwkv6, and only the shared-attention KV grows for
zamba2 (sharded over the data axis at 500k; see launch/shardings).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.backbone import _dtype, _layer_windows, logits_for_position
from repro.models.layers import Params


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16) -> Params:
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    d = cfg.d_model
    if cfg.family in ("dense", "moe"):
        shape = (cfg.n_layers, batch, hkv, max_seq, hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if cfg.family == "vlm":
        n_cross = cfg.n_layers // cfg.cross_attn_period
        n_self = cfg.n_layers - n_cross
        return {
            "k": jnp.zeros((n_self, batch, hkv, max_seq, hd), dtype),
            "v": jnp.zeros((n_self, batch, hkv, max_seq, hd), dtype),
            "xk": jnp.zeros((n_cross, batch, hkv, cfg.vision_tokens, hd), dtype),
            "xv": jnp.zeros((n_cross, batch, hkv, cfg.vision_tokens, hd), dtype),
        }
    if cfg.family == "audio":
        return {
            "k": jnp.zeros((cfg.n_layers, batch, hkv, max_seq, hd), dtype),
            "v": jnp.zeros((cfg.n_layers, batch, hkv, max_seq, hd), dtype),
            "xk": jnp.zeros((cfg.n_layers, batch, hkv, cfg.encoder_seq, hd), dtype),
            "xv": jnp.zeros((cfg.n_layers, batch, hkv, cfg.encoder_seq, hd), dtype),
        }
    if cfg.family == "ssm":
        h = cfg.n_heads
        hd_r = d // h
        return {
            "prev1": jnp.zeros((cfg.n_layers, batch, d), dtype),
            "prev2": jnp.zeros((cfg.n_layers, batch, d), dtype),
            "wkv": jnp.zeros((cfg.n_layers, batch, h, hd_r, hd_r), jnp.float32),
        }
    if cfg.family == "hybrid":
        inner = cfg.ssm_expand * d
        n_apps = cfg.n_layers // cfg.hybrid_period
        return {
            "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, inner), dtype),
            "ssm": jnp.zeros(
                (cfg.n_layers, batch, cfg.n_heads, cfg.ssm_state, inner // cfg.n_heads),
                jnp.float32,
            ),
            "sk": jnp.zeros((n_apps, batch, hkv, max_seq, hd), dtype),
            "sv": jnp.zeros((n_apps, batch, hkv, max_seq, hd), dtype),
        }
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# Decode step (one token)
# ---------------------------------------------------------------------------


def _shared_block_decode(p, x, ck, cv, pos, cfg):
    """Dense block single-token: returns (x, new_ck, new_cv)."""
    xn = L.apply_norm(p["ln1"], x[:, None], cfg)[:, 0]
    h, nk, nv = L.attn_decode(p["attn"], xn[:, None], ck, cv, pos, cfg)
    h = h[:, 0]
    if cfg.sandwich_norm:
        h = L.apply_norm(p["ln1_post"], h, cfg)
    x = x + h
    y = L.apply_norm(p["ln2"], x[:, None], cfg)
    y = (
        L.moe_forward(p["moe"], y, cfg) if "moe" in p else L.mlp_forward(p["mlp"], y, cfg)
    )[:, 0]
    if cfg.sandwich_norm:
        y = L.apply_norm(p["ln2_post"], y, cfg)
    return x + y, nk, nv


def decode_step(
    cfg: ModelConfig,
    params: Params,
    cache: Params,
    token: jax.Array,  # (B,) int32
    pos: jax.Array,  # () int32 current write position
) -> tuple[jax.Array, Params]:
    """Returns (logits (B, V), new_cache)."""
    dtype = _dtype(cfg)
    x = jnp.take(params["embed"], token, axis=0).astype(dtype)  # (B, D)
    if cfg.embed_scale:
        x = x * math.sqrt(cfg.d_model)
    if cfg.learned_pos:
        x = x + jnp.take(params["dec_pos"], pos, axis=0)[None].astype(dtype)
    new_cache = dict(cache)

    if cfg.family in ("dense", "moe"):
        windows = _layer_windows(cfg)

        def body(x, inp):
            if windows is None:
                p, ck, cv = inp
                win = None
            else:
                p, ck, cv, w = inp
                # traced per-layer window; 0 means global -> huge window
                win = jnp.where(w > 0, w, jnp.asarray(1 << 30, jnp.int32))
            xn = L.apply_norm(p["ln1"], x[:, None], cfg)
            h, nk, nv = L.attn_decode(p["attn"], xn, ck, cv, pos, cfg, window=win)
            h = h[:, 0]
            if cfg.sandwich_norm:
                h = L.apply_norm(p["ln1_post"], h, cfg)
            x = x + h
            y = L.apply_norm(p["ln2"], x[:, None], cfg)
            y = (
                L.moe_forward(p["moe"], y, cfg)
                if "moe" in p
                else L.mlp_forward(p["mlp"], y, cfg)
            )[:, 0]
            if cfg.sandwich_norm:
                y = L.apply_norm(p["ln2_post"], y, cfg)
            return x + y, (nk, nv)

        xs = (params["blocks"], cache["k"], cache["v"])
        if windows is not None:
            xs = xs + (windows,)
        x, (nk, nv) = jax.lax.scan(body, x, xs)
        new_cache["k"], new_cache["v"] = nk, nv

    elif cfg.family == "ssm":
        def body(x, inp):
            p, p1, p2, st = inp
            xn = L.apply_norm(p["ln1"], x[:, None], cfg)[:, 0]
            h, np1, nst = S.rwkv_time_mix_decode(p["time_mix"], xn, p1, st, cfg)
            x = x + h
            xn2 = L.apply_norm(p["ln2"], x[:, None], cfg)[:, 0]
            h2, np2 = S.rwkv_channel_mix_decode(p["channel_mix"], xn2, p2, cfg)
            return x + h2, (np1.astype(p1.dtype), np2.astype(p2.dtype), nst)

        x, (np1, np2, nst) = jax.lax.scan(
            body, x, (params["blocks"], cache["prev1"], cache["prev2"], cache["wkv"])
        )
        new_cache.update(prev1=np1, prev2=np2, wkv=nst)

    elif cfg.family == "hybrid":
        period = cfg.hybrid_period
        shared = params["shared"]
        flags = jnp.asarray(
            [1 if (i + 1) % period == 0 else 0 for i in range(cfg.n_layers)], jnp.int32
        )
        # application j sits at layer (j+1)*period - 1
        app_idx = jnp.asarray(
            [((i + 1) // period - 1) if (i + 1) % period == 0 else 0
             for i in range(cfg.n_layers)], jnp.int32
        )

        def body(carry, inp):
            x, sk, sv = carry
            p, conv, st, flag, aidx = inp
            xn = L.apply_norm(p["ln1"], x[:, None], cfg)[:, 0]
            h, nconv, nst = S.mamba2_decode(p["mamba"], xn, conv, st, cfg)
            x = x + h
            ck = jax.lax.dynamic_index_in_dim(sk, aidx, 0, keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(sv, aidx, 0, keepdims=False)
            y, nk, nv = _shared_block_decode(shared, x, ck, cv, pos, cfg)
            x = jnp.where(flag > 0, y, x)
            sk = jnp.where(
                flag > 0, jax.lax.dynamic_update_index_in_dim(sk, nk, aidx, 0), sk
            )
            sv = jnp.where(
                flag > 0, jax.lax.dynamic_update_index_in_dim(sv, nv, aidx, 0), sv
            )
            return (x, sk, sv), (nconv, nst)

        (x, nsk, nsv), (nconv, nst) = jax.lax.scan(
            body,
            (x, cache["sk"], cache["sv"]),
            (params["blocks"], cache["conv"], cache["ssm"], flags, app_idx),
        )
        new_cache.update(conv=nconv, ssm=nst, sk=nsk, sv=nsv)

    elif cfg.family == "vlm":
        period = cfg.cross_attn_period
        n_units = cfg.n_layers // period
        self_pp = jax.tree.map(
            lambda a: a.reshape(n_units, period - 1, *a.shape[1:]), params["blocks"]
        )
        ksplit = jax.tree.map(
            lambda a: a.reshape(n_units, period - 1, *a.shape[1:]), cache["k"]
        )
        vsplit = jax.tree.map(
            lambda a: a.reshape(n_units, period - 1, *a.shape[1:]), cache["v"]
        )

        def unit(x, inp):
            selfs, sks, svs, crossp, xk, xv = inp

            def inner(x, i2):
                p, ck, cv = i2
                y, nk, nv = _shared_block_decode(p, x, ck, cv, pos, cfg)
                return y, (nk, nv)

            x, (nk, nv) = jax.lax.scan(inner, x, (selfs, sks, svs))
            xn = L.apply_norm(crossp["ln1"], x[:, None], cfg)
            h = _cross_decode(crossp["cross"], xn, xk, xv, cfg)[:, 0]
            x = x + jnp.tanh(crossp["gate"]).astype(x.dtype) * h
            y = L.mlp_forward(
                crossp["mlp"], L.apply_norm(crossp["ln2"], x[:, None], cfg), cfg
            )[:, 0]
            return x + y, (nk, nv)

        x, (nk, nv) = jax.lax.scan(
            unit,
            x,
            (self_pp, ksplit, vsplit, params["cross_blocks"], cache["xk"], cache["xv"]),
        )
        new_cache["k"] = nk.reshape(cache["k"].shape)
        new_cache["v"] = nv.reshape(cache["v"].shape)

    elif cfg.family == "audio":
        def body(x, inp):
            p, ck, cv, xk, xv = inp
            xn = L.apply_norm(p["ln1"], x[:, None], cfg)
            h, nk, nv = L.attn_decode(p["attn"], xn, ck, cv, pos, cfg)
            x = x + h[:, 0]
            xn2 = L.apply_norm(p["ln_x"], x[:, None], cfg)
            x = x + _cross_decode(p["cross"], xn2, xk, xv, cfg)[:, 0]
            y = L.mlp_forward(p["mlp"], L.apply_norm(p["ln2"], x[:, None], cfg), cfg)[:, 0]
            return x + y, (nk, nv)

        x, (nk, nv) = jax.lax.scan(
            body, x, (params["blocks"], cache["k"], cache["v"], cache["xk"], cache["xv"])
        )
        new_cache["k"], new_cache["v"] = nk, nv
    else:
        raise ValueError(cfg.family)

    x = L.apply_norm(params["final_norm"], x[:, None], cfg)[:, 0]
    return logits_for_position(cfg, params, x), new_cache


def _cross_decode(p, x, xk, xv, cfg: ModelConfig) -> jax.Array:
    """Single-token cross attention against precomputed memory K/V."""
    dtype = x.dtype
    b = x.shape[0]
    q = L._split_heads(L.linear(p["wq"], x, dtype), cfg.n_heads)  # (B,Hq,1,h)
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    g = hq // hkv
    scale = 1.0 / math.sqrt(cfg.head_dim)
    qf = q.reshape(b, hkv, g, 1, cfg.head_dim).astype(jnp.float32) * scale
    s = jnp.einsum("bkgqh,bkch->bkgqc", qf, xk.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bkch->bkgqh", w, xv.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, hq, 1, cfg.head_dim).transpose(0, 2, 1, 3).reshape(b, 1, cfg.q_dim)
    return L.linear(p["wo"], o.astype(dtype), dtype)
