"""Sequence-mixing blocks for the attention-free / hybrid families:
RWKV6 ("Finch", data-dependent per-channel decay) and Mamba2 (SSD,
scalar-per-head decay).  Both reduce to the gated linear-attention
recurrence and share the chunked kernel
(:mod:`repro.kernels.linear_attn`).

Documented simplifications vs the reference implementations (DESIGN.md §7):
* RWKV6: static token-shift mix per projection (the low-rank data-dependent
  mix is kept only for the decay ``w``, which is the paper-defining part).
* Mamba2: B/C projections shared across heads (as in SSD), depthwise conv
  applied to the value path only; no chunked dt-bias discretisation beyond
  ``softplus``.
* Zamba2: the shared transformer block operates on the residual stream
  (the concat-with-embedding variant is noted but not reproduced).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Params, init_linear, linear, init_norm, apply_norm
from repro.kernels.linear_attn.ops import linear_attention


# ------------------------------- RWKV6 -------------------------------------


def init_rwkv_time_mix(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    lora = 64
    return {
        "mu": jnp.full((5, d), 0.5, jnp.float32),  # shift-mix for r,k,v,g,w
        "wr": init_linear(ks[0], d, d),
        "wk": init_linear(ks[1], d, d),
        "wv": init_linear(ks[2], d, d),
        "wg": init_linear(ks[3], d, d),
        "wo": init_linear(ks[4], d, d),
        "w0": jnp.full((d,), -6.0, jnp.float32),  # base decay (w ~ exp(-exp(.)))
        "w_a": jax.random.normal(ks[5], (d, lora), jnp.float32) * 0.01,
        "w_b": jax.random.normal(ks[6], (lora, d), jnp.float32) * 0.01,
        "u": jax.random.normal(ks[7], (d,), jnp.float32) * 0.1,  # bonus
    }


def _token_shift(x: jax.Array) -> jax.Array:
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def rwkv_time_mix(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dtype = x.dtype
    b, t, d = x.shape
    h = cfg.n_heads
    hd = d // h
    prev = _token_shift(x)

    def mixed(i):
        mu = p["mu"][i].astype(dtype)
        return x + (prev - x) * mu

    r = linear(p["wr"], mixed(0), dtype)
    k = linear(p["wk"], mixed(1), dtype)
    v = linear(p["wv"], mixed(2), dtype)
    g = linear(p["wg"], mixed(3), dtype)
    # data-dependent decay (the Finch contribution)
    xw = mixed(4).astype(jnp.float32)
    dd = jnp.tanh(xw @ p["w_a"]) @ p["w_b"]  # (B,T,D)
    w = jnp.exp(-jnp.exp(p["w0"][None, None] + dd))  # in (0,1)

    def heads(a):
        return a.reshape(b, t, h, hd).transpose(0, 2, 1, 3)

    o = linear_attention(
        heads(r), heads(k), heads(v), heads(w.astype(dtype)),
        u=p["u"].reshape(h, hd).astype(dtype), mode="rwkv",
    )  # (B,H,T,hd)
    # per-head groupnorm (RWKV uses GroupNorm over heads)
    of = o.astype(jnp.float32)
    of = of * jax.lax.rsqrt(jnp.mean(of * of, axis=-1, keepdims=True) + 1e-6)
    o = of.astype(dtype).transpose(0, 2, 1, 3).reshape(b, t, d)
    return linear(p["wo"], o * jax.nn.silu(g), dtype)


def init_rwkv_channel_mix(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "mu": jnp.full((2, cfg.d_model), 0.5, jnp.float32),
        "wr": init_linear(ks[0], cfg.d_model, cfg.d_model),
        "wk": init_linear(ks[1], cfg.d_model, cfg.d_ff),
        "wv": init_linear(ks[2], cfg.d_ff, cfg.d_model),
    }


def rwkv_channel_mix(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dtype = x.dtype
    prev = _token_shift(x)
    xk = x + (prev - x) * p["mu"][0].astype(dtype)
    xr = x + (prev - x) * p["mu"][1].astype(dtype)
    r = jax.nn.sigmoid(linear(p["wr"], xr, dtype))
    k = jnp.square(jax.nn.relu(linear(p["wk"], xk, dtype)))
    return r * linear(p["wv"], k, dtype)


# ------------------------------- Mamba2 ------------------------------------


def init_mamba2(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    inner = cfg.ssm_expand * d
    n = cfg.ssm_state
    h = cfg.n_heads
    ks = jax.random.split(key, 4)
    return {
        "w_in": init_linear(ks[0], d, 2 * inner + 2 * n + h),  # x,z,B,C,dt
        "conv": jax.random.normal(ks[1], (cfg.ssm_conv, inner), jnp.float32) * 0.1,
        "a_log": jnp.zeros((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": init_norm(cfg, inner),
        "w_out": init_linear(ks[2], inner, d),
    }


def mamba2_forward(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dtype = x.dtype
    b, t, d = x.shape
    inner = cfg.ssm_expand * d
    n = cfg.ssm_state
    h = cfg.n_heads
    ph = inner // h  # channels per head

    zxbcdt = linear(p["w_in"], x, dtype)
    xin, z, bmat, cmat, dt = jnp.split(
        zxbcdt, [inner, 2 * inner, 2 * inner + n, 2 * inner + 2 * n], axis=-1
    )
    # causal depthwise conv on the value path
    kw = p["conv"].astype(dtype)  # (K, inner)
    xpad = jnp.pad(xin, ((0, 0), (cfg.ssm_conv - 1, 0), (0, 0)))
    xconv = sum(
        xpad[:, i : i + t] * kw[i][None, None] for i in range(cfg.ssm_conv)
    )
    xconv = jax.nn.silu(xconv)

    # scalar-per-head decay a_t = exp(-softplus(dt + bias) * exp(A_log))
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,T,H)
    decay = jnp.exp(-dtf * jnp.exp(p["a_log"]))  # (B,T,H) in (0,1)

    def heads(a, width):
        return a.reshape(b, t, h, width).transpose(0, 2, 1, 3)

    v = heads(xconv, ph)  # (B,H,T,P)
    k = jnp.broadcast_to(bmat[:, None], (b, h, t, n))  # shared across heads
    q = jnp.broadcast_to(cmat[:, None], (b, h, t, n))
    w = jnp.broadcast_to(
        decay.transpose(0, 2, 1)[..., None], (b, h, t, n)
    ).astype(dtype)
    # dt also scales the input (discretised B): v_eff = dt * v
    v = v * dtf.transpose(0, 2, 1)[..., None].astype(dtype)

    y = linear_attention(q, k, v, w, mode="ssd")  # (B,H,T,P)
    y = y + p["d_skip"].astype(dtype)[None, :, None, None] * v
    y = y.transpose(0, 2, 1, 3).reshape(b, t, inner)
    y = apply_norm(p["norm"], y, cfg) * jax.nn.silu(z)
    return linear(p["w_out"], y, dtype)


# --------------------------- decode (stateful) ------------------------------
# SSM decode carries O(1) state per layer instead of a KV cache — this is
# what makes the 500k-context decode shape trivially cheap for this family.


def rwkv_time_mix_decode(
    p: Params, x: jax.Array, prev_x: jax.Array, state: jax.Array, cfg: ModelConfig
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token RWKV6 time mix.  x: (B, D); state: (B, H, hd, hd)."""
    dtype = x.dtype
    b, d = x.shape
    h = cfg.n_heads
    hd = d // h

    def mixed(i):
        mu = p["mu"][i].astype(dtype)
        return x + (prev_x - x) * mu

    r = linear(p["wr"], mixed(0), dtype).reshape(b, h, hd)
    k = linear(p["wk"], mixed(1), dtype).reshape(b, h, hd)
    v = linear(p["wv"], mixed(2), dtype).reshape(b, h, hd)
    g = linear(p["wg"], mixed(3), dtype)
    xw = mixed(4).astype(jnp.float32)
    dd = jnp.tanh(xw @ p["w_a"]) @ p["w_b"]
    w = jnp.exp(-jnp.exp(p["w0"][None] + dd)).reshape(b, h, hd)
    u = p["u"].reshape(h, hd)

    sf = state.astype(jnp.float32)
    rf, kf, vf = r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)
    kv = kf[..., :, None] * vf[..., None, :]  # (B,H,hd,hd)
    o = jnp.einsum("bhk,bhkv->bhv", rf, sf + u[None, :, :, None] * kv)
    new_state = w[..., :, None] * sf + kv
    of = o * jax.lax.rsqrt(jnp.mean(o * o, axis=-1, keepdims=True) + 1e-6)
    o = of.astype(dtype).reshape(b, d)
    return linear(p["wo"], o * jax.nn.silu(g), dtype), x, new_state.astype(state.dtype)


def rwkv_channel_mix_decode(
    p: Params, x: jax.Array, prev_x: jax.Array, cfg: ModelConfig
) -> tuple[jax.Array, jax.Array]:
    dtype = x.dtype
    xk = x + (prev_x - x) * p["mu"][0].astype(dtype)
    xr = x + (prev_x - x) * p["mu"][1].astype(dtype)
    r = jax.nn.sigmoid(linear(p["wr"], xr, dtype))
    k = jnp.square(jax.nn.relu(linear(p["wk"], xk, dtype)))
    return r * linear(p["wv"], k, dtype), x


def mamba2_decode(
    p: Params,
    x: jax.Array,  # (B, D)
    conv_state: jax.Array,  # (B, K-1, inner)
    ssm_state: jax.Array,  # (B, H, N, P)
    cfg: ModelConfig,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    dtype = x.dtype
    b, d = x.shape
    inner = cfg.ssm_expand * d
    n = cfg.ssm_state
    h = cfg.n_heads
    ph = inner // h

    zxbcdt = linear(p["w_in"], x, dtype)
    xin, z, bmat, cmat, dt = jnp.split(
        zxbcdt, [inner, 2 * inner, 2 * inner + n, 2 * inner + 2 * n], axis=-1
    )
    kw = p["conv"].astype(dtype)  # (K, inner)
    hist = jnp.concatenate([conv_state, xin[:, None]], axis=1)  # (B, K, inner)
    xconv = jax.nn.silu(jnp.einsum("bki,ki->bi", hist, kw))
    new_conv = hist[:, 1:]

    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    decay = jnp.exp(-dtf * jnp.exp(p["a_log"]))  # (B,H)

    v = xconv.reshape(b, h, ph).astype(jnp.float32) * dtf[..., None]
    kf = bmat.astype(jnp.float32)  # (B,N)
    qf = cmat.astype(jnp.float32)
    sf = ssm_state.astype(jnp.float32)
    new_s = decay[..., None, None] * sf + kf[:, None, :, None] * v[:, :, None, :]
    y = jnp.einsum("bn,bhnp->bhp", qf, new_s)
    y = y + p["d_skip"][None, :, None] * v
    y = y.reshape(b, inner).astype(dtype)
    y = apply_norm(p["norm"], y, cfg) * jax.nn.silu(z)
    return linear(p["w_out"], y, dtype), new_conv, new_s.astype(ssm_state.dtype)
