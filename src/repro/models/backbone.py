"""Backbone assembly: block patterns, scan-over-layers, train/prefill/decode.

Every architecture family maps to a *pattern* of blocks whose parameters are
stacked along a leading layer axis and applied with ``lax.scan`` (small HLO,
fast 512-device compiles, remat-friendly):

  dense / moe        uniform [attn + (mlp|moe)] x L        (gemma2: per-layer
                     local/global flag rides the scan xs)
  ssm (rwkv6)        uniform [time_mix + channel_mix] x L
  hybrid (zamba2)    [mamba2] x L with a *shared* transformer block applied
                     every ``hybrid_period`` layers (same params each time)
  audio (whisper)    encoder scan + decoder scan (self + cross attention);
                     frame embeddings come precomputed (conv frontend stub)
  vlm (llama-vision) units of [self x (period-1), gated cross-attn] scanned
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.layers import Params


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _stack_init(key, n: int, init_fn) -> Params:
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


# ---------------------------------------------------------------------------
# Block initialisers per family
# ---------------------------------------------------------------------------


def init_dense_block(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": L.init_norm(cfg),
        "attn": L.init_attention(k1, cfg),
        "ln2": L.init_norm(cfg),
    }
    if cfg.family == "moe":
        p["moe"] = L.init_moe(k2, cfg)
    else:
        p["mlp"] = L.init_mlp(k2, cfg)
    if cfg.sandwich_norm:
        p["ln1_post"] = L.init_norm(cfg)
        p["ln2_post"] = L.init_norm(cfg)
    return p


def init_rwkv_block(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_norm(cfg),
        "time_mix": S.init_rwkv_time_mix(k1, cfg),
        "ln2": L.init_norm(cfg),
        "channel_mix": S.init_rwkv_channel_mix(k2, cfg),
    }


def init_mamba_block(key, cfg: ModelConfig) -> Params:
    return {"ln1": L.init_norm(cfg), "mamba": S.init_mamba2(key, cfg)}


def init_encoder_block(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_norm(cfg),
        "attn": L.init_attention(k1, cfg),
        "ln2": L.init_norm(cfg),
        "mlp": L.init_mlp(k2, cfg),
    }


def init_encdec_block(key, cfg: ModelConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": L.init_norm(cfg),
        "attn": L.init_attention(k1, cfg),
        "ln_x": L.init_norm(cfg),
        "cross": L.init_attention(k2, cfg),
        "ln2": L.init_norm(cfg),
        "mlp": L.init_mlp(k3, cfg),
    }


def init_cross_block(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_norm(cfg),
        "cross": L.init_attention(k1, cfg),
        "ln2": L.init_norm(cfg),
        "mlp": L.init_mlp(k2, cfg),
        "gate": jnp.zeros((1,), jnp.float32),  # tanh-gated residual
    }


# ---------------------------------------------------------------------------
# Model parameters
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> Params:
    keys = jax.random.split(key, 8)
    d = cfg.d_model
    p: Params = {
        # padded vocab (multiple of 256) so the vocab dim always shards;
        # padded logits are masked in the loss
        "embed": jax.random.normal(keys[0], (cfg.padded_vocab, d), jnp.float32) * 0.02,
        "final_norm": L.init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.init_linear(keys[1], d, cfg.padded_vocab)

    if cfg.family in ("dense", "moe"):
        p["blocks"] = _stack_init(
            keys[2], cfg.n_layers, lambda k: init_dense_block(k, cfg)
        )
    elif cfg.family == "ssm":  # rwkv6
        p["blocks"] = _stack_init(
            keys[2], cfg.n_layers, lambda k: init_rwkv_block(k, cfg)
        )
    elif cfg.family == "hybrid":  # zamba2
        p["blocks"] = _stack_init(
            keys[2], cfg.n_layers, lambda k: init_mamba_block(k, cfg)
        )
        p["shared"] = init_dense_block(keys[3], dataclasses.replace(cfg, family="dense"))
    elif cfg.family == "audio":  # whisper enc-dec
        p["enc_blocks"] = _stack_init(
            keys[2], cfg.encoder_layers, lambda k: init_encoder_block(k, cfg)
        )
        p["blocks"] = _stack_init(
            keys[3], cfg.n_layers, lambda k: init_encdec_block(k, cfg)
        )
        p["enc_pos"] = jax.random.normal(keys[4], (cfg.encoder_seq, d), jnp.float32) * 0.02
        p["dec_pos"] = jax.random.normal(keys[5], (cfg.max_learned_pos, d), jnp.float32) * 0.02
        p["enc_final_norm"] = L.init_norm(cfg)
    elif cfg.family == "vlm":  # llama-3.2-vision
        period = cfg.cross_attn_period
        n_cross = cfg.n_layers // period
        n_self = cfg.n_layers - n_cross
        p["blocks"] = _stack_init(keys[2], n_self, lambda k: init_dense_block(k, cfg))
        p["cross_blocks"] = _stack_init(
            keys[3], n_cross, lambda k: init_cross_block(k, cfg)
        )
    else:
        raise ValueError(cfg.family)
    return p


def param_shapes(cfg: ModelConfig) -> Params:
    """Abstract params (no allocation) — dry-run input."""
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.key(0))


# ---------------------------------------------------------------------------
# Forward passes (full-sequence)
# ---------------------------------------------------------------------------


def _dense_block_fwd(p, x, cfg: ModelConfig, window):
    h = L.attn_forward(p["attn"], L.apply_norm(p["ln1"], x, cfg), cfg, window=window)
    if cfg.sandwich_norm:
        h = L.apply_norm(p["ln1_post"], h, cfg)
    x = x + h
    y = L.apply_norm(p["ln2"], x, cfg)
    y = L.moe_forward(p["moe"], y, cfg) if "moe" in p else L.mlp_forward(p["mlp"], y, cfg)
    if cfg.sandwich_norm:
        y = L.apply_norm(p["ln2_post"], y, cfg)
    return x + y


def _layer_windows(cfg: ModelConfig) -> jax.Array | None:
    """Per-layer window sizes as a scan xs (0 = global)."""
    if cfg.local_global:
        w = [(cfg.local_window if i % 2 == 0 else 0) for i in range(cfg.n_layers)]
        return jnp.asarray(w, jnp.int32)
    if cfg.sliding_window is not None:
        return jnp.full((cfg.n_layers,), cfg.sliding_window, jnp.int32)
    return None


def _scan_blocks(block_params, x, body, remat: bool, xs_extra=None):
    from repro.models.shard_ctx import constrain

    def pinned(c, *i):
        # pin the residual stream's batch sharding inside the loop — GSPMD
        # otherwise drops it through checkpointed backward bodies
        c = constrain(c, "batch", None, None)
        return body(c, *i)

    f = jax.checkpoint(pinned) if remat else pinned
    ins = (block_params,) if xs_extra is None else (block_params, xs_extra)
    x, _ = jax.lax.scan(lambda c, i: (f(c, *i), None), x, ins)
    return x


def forward_hidden(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,  # (B, S) int32
    *,
    extras: jax.Array | None = None,  # frames (audio) / patches (vlm)
    remat: bool = True,
) -> jax.Array:
    dtype = _dtype(cfg)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    if cfg.embed_scale:
        x = x * math.sqrt(cfg.d_model)
    b, s = tokens.shape

    if cfg.learned_pos:
        x = x + params["dec_pos"][:s][None].astype(dtype)

    if cfg.family in ("dense", "moe"):
        windows = _layer_windows(cfg)

        if windows is None:
            def body(x, p):
                return _dense_block_fwd(p, x, cfg, None)
            x = _scan_blocks(params["blocks"], x, body, remat)
        else:
            # window rides the scan; 0 means global. Implemented by masking
            # with an effective window of S (no-op) when the flag is 0.
            def body(x, p, w):
                eff = jnp.where(w > 0, w, jnp.asarray(1 << 30, jnp.int32))
                return _dense_block_fwd_dynwin(p, x, cfg, eff)
            x = _scan_blocks(params["blocks"], x, body, remat, xs_extra=windows)

    elif cfg.family == "ssm":
        def body(x, p):
            x = x + S.rwkv_time_mix(p["time_mix"], L.apply_norm(p["ln1"], x, cfg), cfg)
            x = x + S.rwkv_channel_mix(p["channel_mix"], L.apply_norm(p["ln2"], x, cfg), cfg)
            return x
        x = _scan_blocks(params["blocks"], x, body, remat)

    elif cfg.family == "hybrid":
        from repro.models.shard_ctx import constrain as _constrain

        period = cfg.hybrid_period
        shared = params["shared"]
        n_units = cfg.n_layers // period
        n_tail = cfg.n_layers - n_units * period
        unit_pp = jax.tree.map(
            lambda a: a[: n_units * period].reshape(n_units, period, *a.shape[1:]),
            params["blocks"],
        )
        tail_pp = jax.tree.map(lambda a: a[n_units * period :], params["blocks"])

        def mamba_body(x, p):
            x = _constrain(x, "batch", None, None)
            return x + S.mamba2_forward(p["mamba"], L.apply_norm(p["ln1"], x, cfg), cfg)

        def unit(x, pp):
            x, _ = jax.lax.scan(lambda c, p: (mamba_body(c, p), None), x, pp)
            return _dense_block_fwd(shared, x, cfg, None)

        f = jax.checkpoint(unit) if remat else unit
        x, _ = jax.lax.scan(lambda c, p: (f(c, p), None), x, unit_pp)
        if n_tail:
            ft = jax.checkpoint(mamba_body) if remat else mamba_body
            x, _ = jax.lax.scan(lambda c, p: (ft(c, p), None), x, tail_pp)

    elif cfg.family == "audio":
        enc = extras.astype(dtype) + params["enc_pos"][None].astype(dtype)

        def enc_body(h, p):
            h = h + L.attn_forward(p["attn"], L.apply_norm(p["ln1"], h, cfg), cfg, causal=False)
            h = h + L.mlp_forward(p["mlp"], L.apply_norm(p["ln2"], h, cfg), cfg)
            return h
        enc = _scan_blocks(params["enc_blocks"], enc, enc_body, remat)
        enc = L.apply_norm(params["enc_final_norm"], enc, cfg)

        def dec_body(x, p):
            x = x + L.attn_forward(p["attn"], L.apply_norm(p["ln1"], x, cfg), cfg)
            x = x + L.attn_forward(
                p["cross"], L.apply_norm(p["ln_x"], x, cfg), cfg, kv_override=enc
            )
            x = x + L.mlp_forward(p["mlp"], L.apply_norm(p["ln2"], x, cfg), cfg)
            return x
        x = _scan_blocks(params["blocks"], x, dec_body, remat)

    elif cfg.family == "vlm":
        period = cfg.cross_attn_period
        n_units = cfg.n_layers // period
        vision = extras.astype(dtype)
        self_pp = jax.tree.map(
            lambda a: a.reshape(n_units, period - 1, *a.shape[1:]), params["blocks"]
        )

        def unit_body(x, selfs, crossp):
            def inner(x, p):
                return _dense_block_fwd(p, x, cfg, None)
            x, _ = jax.lax.scan(lambda c, p: (inner(c, p), None), x, selfs)
            h = L.attn_forward(
                crossp["cross"], L.apply_norm(crossp["ln1"], x, cfg), cfg,
                kv_override=vision,
            )
            x = x + jnp.tanh(crossp["gate"]).astype(x.dtype) * h
            x = x + L.mlp_forward(crossp["mlp"], L.apply_norm(crossp["ln2"], x, cfg), cfg)
            return x

        body = jax.checkpoint(unit_body) if remat else unit_body
        x, _ = jax.lax.scan(
            lambda c, i: (body(c, *i), None), x, (self_pp, params["cross_blocks"])
        )
    else:
        raise ValueError(cfg.family)

    return L.apply_norm(params["final_norm"], x, cfg)


def _dense_block_fwd_dynwin(p, x, cfg: ModelConfig, window: jax.Array):
    """Dense block with a traced (per-layer) window size."""
    xn = L.apply_norm(p["ln1"], x, cfg)
    dtype = x.dtype
    b, s, _ = x.shape
    q = L._split_heads(L.linear(p["attn"]["wq"], xn, dtype), cfg.n_heads)
    k = L._split_heads(L.linear(p["attn"]["wk"], xn, dtype), cfg.n_kv_heads)
    v = L._split_heads(L.linear(p["attn"]["wv"], xn, dtype), cfg.n_kv_heads)
    if cfg.use_rope:
        pos = jnp.arange(s)
        q = L.rope(q, pos, cfg.rope_theta)
        k = L.rope(k, pos, cfg.rope_theta)
    o = _flash_dynwin(q, k, v, window, cfg)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.q_dim)
    h = L.linear(p["attn"]["wo"], o, dtype)
    if cfg.sandwich_norm:
        h = L.apply_norm(p["ln1_post"], h, cfg)
    x = x + h
    y = L.apply_norm(p["ln2"], x, cfg)
    y = L.moe_forward(p["moe"], y, cfg) if "moe" in p else L.mlp_forward(p["mlp"], y, cfg)
    if cfg.sandwich_norm:
        y = L.apply_norm(p["ln2_post"], y, cfg)
    return x + y


def _flash_dynwin(q, k, v, window: jax.Array, cfg: ModelConfig):
    """flash_attention variant whose window is a traced scalar."""
    b, hq, sq, hd = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(hd)
    qf = q.reshape(b, hkv, g, sq, hd).astype(jnp.float32) * scale
    kv_chunk = min(1024, skv)
    n_chunks = -(-skv // kv_chunk)
    pad = n_chunks * kv_chunk - skv
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kc = kp.reshape(b, hkv, n_chunks, kv_chunk, hd).transpose(2, 0, 1, 3, 4)
    vc = vp.reshape(b, hkv, n_chunks, kv_chunk, hd).transpose(2, 0, 1, 3, 4)
    qpos = jnp.arange(sq)

    def body(carry, inp):
        acc, m, lse = carry
        kb, vb, ci = inp
        s = jnp.einsum("bkgqh,bkch->bkgqc", qf, kb.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
        s = L._softcap(s, cfg.attn_softcap)
        kpos = ci * kv_chunk + jnp.arange(kv_chunk)
        ok = (kpos[None, :] < skv) & (kpos[None, :] <= qpos[:, None])
        ok = ok & (qpos[:, None] - kpos[None, :] < window)
        s = jnp.where(ok[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        lse_new = lse * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bkch->bkgqh", p, vb.astype(jnp.float32),
            preferred_element_type=jnp.float32)
        return (acc_new, m_new, lse_new), None

    acc0 = jnp.zeros((b, hkv, g, sq, hd), jnp.float32)
    m0 = jnp.full((b, hkv, g, sq), -jnp.inf, jnp.float32)
    lse0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    (acc, m, lse), _ = jax.lax.scan(body, (acc0, m0, lse0), (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(lse[..., None], 1e-30)
    return out.reshape(b, hq, sq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Loss (chunked cross-entropy over the vocab)
# ---------------------------------------------------------------------------


def chunked_ce_loss(
    cfg: ModelConfig, params: Params, hidden: jax.Array, labels: jax.Array
) -> jax.Array:
    """CE without materialising (B, S, V) logits: scan over seq chunks."""
    b, s, d = hidden.shape
    w = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]["w"]
    ).astype(_dtype(cfg))
    chunk = min(cfg.vocab_chunk, s)
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    hp = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hc = hp.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    lc = lp.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    vocab_mask = (jnp.arange(cfg.padded_vocab) < cfg.vocab_size)[None, None, :]

    def body(acc, inp):
        h, lab = inp  # (B, C, D), (B, C)
        logits = jnp.einsum("bcd,dv->bcv", h, w, preferred_element_type=jnp.float32)
        from repro.models.shard_ctx import constrain as _constrain

        logits = _constrain(logits, "batch", None, "vocab")
        if cfg.final_softcap:
            logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
        logits = jnp.where(vocab_mask, logits, -1e30)  # mask pad vocab
        lse = jax.nn.logsumexp(logits, axis=-1)
        safe = jnp.maximum(lab, 0)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        valid = (lab >= 0).astype(jnp.float32)
        nll = (lse - gold) * valid
        return (acc[0] + nll.sum(), acc[1] + valid.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (hc, lc))
    return tot / jnp.maximum(cnt, 1.0)


def logits_for_position(cfg: ModelConfig, params: Params, hidden_last: jax.Array) -> jax.Array:
    """(B, D) -> (B, V) final logits (decode/prefill tail)."""
    w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"]["w"]).astype(
        _dtype(cfg)
    )
    logits = jnp.einsum("bd,dv->bv", hidden_last, w, preferred_element_type=jnp.float32)
    if cfg.final_softcap:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
    return jnp.where(mask[None, :], logits, -1e30)
