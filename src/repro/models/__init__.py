from repro.models.config import ModelConfig
from repro.models.model import Model, ShapeSpec, SHAPES, input_specs
from repro.models import backbone, decode, prefill, layers, ssm

__all__ = [
    "ModelConfig", "Model", "ShapeSpec", "SHAPES", "input_specs",
    "backbone", "decode", "prefill", "layers", "ssm",
]
