"""Logical activation-sharding context.

Model code never mentions mesh axes; it marks activations with *logical*
dims:

    q = constrain(q, "batch", "heads", "qseq", None)

A launcher installs a mapping {logical dim -> mesh axis (or axes)} via
:func:`activation_sharding`; ``constrain`` resolves it per-tensor with two
safety rules:

  * an axis is applied only when it divides the dim exactly,
  * each mesh axis is used at most once per tensor (first logical dim wins),

so GQA models where ``heads % tp != 0`` automatically fall back to the next
logical dim that the tensor offers (e.g. sequence parallelism for
attention) — this is what keeps attention compute sharded instead of
replicated across the tensor axis (see EXPERIMENTS.md §Perf iteration 1).

Outside a context (unit tests, CPU runs) ``constrain`` is the identity.
"""

from __future__ import annotations

import contextlib
import contextvars
import math
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX: contextvars.ContextVar = contextvars.ContextVar("act_sharding", default=None)

# default logical rules for the production mesh
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    # merged (batch*heads) dim of the linear-attention kernels: spread over
    # the whole mesh (heads fold into the tensor axis)
    "batch_heads": ("pod", "data", "model"),
    "heads": ("model",),
    "kv_heads": ("model",),
    "qseq": ("model",),  # fallback target when heads don't divide
    "ffn": ("model",),
    "expert": ("model",),
    "embed": (),  # activations keep d_model replicated
    "vocab": ("model",),
    "kvseq": (),
}

__all__ = ["activation_sharding", "constrain", "DEFAULT_RULES"]


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules: dict[str, tuple[str, ...]] | None = None):
    token = _CTX.set((mesh, dict(DEFAULT_RULES, **(rules or {}))))
    try:
        yield
    finally:
        _CTX.reset(token)


def _axes_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    return math.prod(mesh.shape[a] for a in axes) if axes else 1


def constrain(x: jax.Array, *logical: str | Sequence[str] | None) -> jax.Array:
    """Apply with_sharding_constraint per the active logical rules.

    Each entry is a logical dim name, a tuple of *candidate* names (first
    one that divides and is free wins), or None.
    """
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    used: set[str] = set()
    spec = []
    for dim, names in zip(x.shape, logical):
        if names is None:
            spec.append(None)
            continue
        cands = (names,) if isinstance(names, str) else tuple(names)
        chosen = None
        for name in cands:
            axes = tuple(a for a in rules.get(name, ()) if a in mesh.axis_names)
            if not axes or any(a in used for a in axes):
                continue
            if dim % _axes_size(mesh, axes) == 0:
                chosen = axes
                break
        if chosen:
            used.update(chosen)
            spec.append(chosen if len(chosen) > 1 else chosen[0])
        else:
            spec.append(None)
    # pad remaining dims
    spec += [None] * (x.ndim - len(spec))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
