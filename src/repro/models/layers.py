"""Shared neural net layers: norms, RoPE, attention (flash-style chunked),
MLPs, and the MoE layer with sort-based capacity dispatch.

All parameters are plain nested dicts of ``jnp`` arrays (fp32 master);
compute runs in the config dtype (bf16 by default) with fp32 softmax/
normalisation statistics.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.shard_ctx import constrain

Params = dict


# ----------------------------- initialisers -------------------------------


def _dense_init(key, d_in: int, d_out: int, scale: float | None = None) -> jax.Array:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), jnp.float32) * scale


def linear(p: Params, x: jax.Array, dtype) -> jax.Array:
    y = jnp.einsum(
        "...d,df->...f", x, p["w"].astype(dtype), preferred_element_type=jnp.float32
    ).astype(dtype)
    if "b" in p:
        y = y + p["b"].astype(dtype)
    return y


def init_linear(key, d_in: int, d_out: int, bias: bool = False) -> Params:
    p = {"w": _dense_init(key, d_in, d_out)}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


# ------------------------------- norms ------------------------------------


def init_norm(cfg: ModelConfig, d: int | None = None) -> Params:
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6) * p["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-6) * p["scale"] + p["bias"]
    return y.astype(x.dtype)


# -------------------------------- RoPE -------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """``x: (..., S, h), positions: (S,) or broadcastable`` rotary embed."""
    h = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, h, 2, dtype=jnp.float32) / h)  # (h/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (S, h/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., : h // 2], x[..., h // 2 :]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ----------------------------- attention -----------------------------------


def init_attention(key, cfg: ModelConfig, kv_heads: int | None = None) -> Params:
    kv = kv_heads or cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], cfg.d_model, cfg.q_dim, cfg.qkv_bias),
        "wk": init_linear(ks[1], cfg.d_model, kv * cfg.head_dim, cfg.qkv_bias),
        "wv": init_linear(ks[2], cfg.d_model, kv * cfg.head_dim, cfg.qkv_bias),
        "wo": init_linear(ks[3], cfg.q_dim, cfg.d_model),
    }


def _split_heads(x: jax.Array, n_heads: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, -1).transpose(0, 2, 1, 3)  # (B, H, S, h)


def _softcap(s: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return s
    return cap * jnp.tanh(s / cap)


def flash_attention(
    q: jax.Array,  # (B, Hq, Sq, h)
    k: jax.Array,  # (B, Hkv, Skv, h)
    v: jax.Array,
    *,
    q_offset: int = 0,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Online-softmax attention, chunked over KV (memory O(S * chunk)).

    GQA handled by grouping query heads over KV heads.  fp32 statistics.
    """
    b, hq, sq, hd = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(hd)
    qf = q.reshape(b, hkv, g, sq, hd).astype(jnp.float32) * scale

    kv_chunk = min(kv_chunk, skv)
    n_chunks = skv // kv_chunk if skv % kv_chunk == 0 else -(-skv // kv_chunk)
    pad = n_chunks * kv_chunk - skv
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kc = kp.reshape(b, hkv, n_chunks, kv_chunk, hd).transpose(2, 0, 1, 3, 4)
    vc = vp.reshape(b, hkv, n_chunks, kv_chunk, hd).transpose(2, 0, 1, 3, 4)

    qpos = q_offset + jnp.arange(sq)

    def body(carry, inp):
        acc, m, lse = carry
        kb, vb, ci = inp  # (B, Hkv, C, h), (B, Hkv, C, h), ()
        s = jnp.einsum(
            "bkgqh,bkch->bkgqc", qf, kb.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        s = _softcap(s, softcap)
        kpos = ci * kv_chunk + jnp.arange(kv_chunk)
        ok = kpos[None, :] < skv  # mask tail padding
        if causal:
            ok = jnp.logical_and(ok, kpos[None, :] <= qpos[:, None])
        if window is not None:
            ok = jnp.logical_and(ok, qpos[:, None] - kpos[None, :] < window)
        s = jnp.where(ok[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        lse_new = lse * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bkch->bkgqh", p, vb.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        return (acc_new, m_new, lse_new), None

    acc0 = jnp.zeros((b, hkv, g, sq, hd), jnp.float32)
    m0 = jnp.full((b, hkv, g, sq), -jnp.inf, jnp.float32)
    lse0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    (acc, m, lse), _ = jax.lax.scan(
        body, (acc0, m0, lse0), (kc, vc, jnp.arange(n_chunks))
    )
    out = acc / jnp.maximum(lse[..., None], 1e-30)
    return out.reshape(b, hq, sq, hd).astype(q.dtype)


def attn_forward(
    p: Params,
    x: jax.Array,  # (B, S, D)
    cfg: ModelConfig,
    *,
    positions: jax.Array | None = None,
    causal: bool = True,
    window: int | None = None,
    kv_override: jax.Array | None = None,  # cross-attn memory (B, Skv, D)
) -> jax.Array:
    dtype = x.dtype
    b, s, _ = x.shape
    src = kv_override if kv_override is not None else x
    q = _split_heads(linear(p["wq"], x, dtype), cfg.n_heads)
    k = _split_heads(linear(p["wk"], src, dtype), cfg.n_kv_heads)
    v = _split_heads(linear(p["wv"], src, dtype), cfg.n_kv_heads)
    # heads over the tensor axis when divisible, else sequence parallelism
    q = constrain(q, "batch", ("heads", "qseq"), ("qseq",), None)
    k = constrain(k, "batch", ("kv_heads",), None, None)
    v = constrain(v, "batch", ("kv_heads",), None, None)
    if cfg.use_rope and kv_override is None:
        pos = positions if positions is not None else jnp.arange(s)
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    o = flash_attention(
        q, k, v, causal=causal and kv_override is None, window=window,
        softcap=cfg.attn_softcap,
    )
    o = constrain(o, "batch", ("heads", "qseq"), ("qseq",), None)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.q_dim)
    return linear(p["wo"], o, dtype)


def attn_decode(
    p: Params,
    x: jax.Array,  # (B, 1, D)
    cache_k: jax.Array,  # (B, Hkv, Smax, h) — updated functionally
    cache_v: jax.Array,
    pos: jax.Array,  # () int32 current position
    cfg: ModelConfig,
    *,
    window: int | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token decode with a KV cache.

    The einsum-over-cache formulation keeps the seq axis shardable: with the
    cache sharded over ``data`` (sequence parallelism for 500k contexts)
    GSPMD turns the softmax statistics into psum-style partial reductions —
    distributed flash-decoding for free.
    """
    dtype = x.dtype
    b = x.shape[0]
    smax = cache_k.shape[2]
    q = _split_heads(linear(p["wq"], x, dtype), cfg.n_heads)  # (B,Hq,1,h)
    k1 = _split_heads(linear(p["wk"], x, dtype), cfg.n_kv_heads)
    v1 = _split_heads(linear(p["wv"], x, dtype), cfg.n_kv_heads)
    if cfg.use_rope:
        posv = jnp.full((1,), pos)
        q = rope(q, posv, cfg.rope_theta)
        k1 = rope(k1, posv, cfg.rope_theta)
    ck = jax.lax.dynamic_update_slice_in_dim(cache_k, k1.astype(cache_k.dtype), pos, axis=2)
    cv = jax.lax.dynamic_update_slice_in_dim(cache_v, v1.astype(cache_v.dtype), pos, axis=2)

    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    g = hq // hkv
    scale = 1.0 / math.sqrt(cfg.head_dim)
    qf = q.reshape(b, hkv, g, 1, cfg.head_dim).astype(jnp.float32) * scale
    s = jnp.einsum(
        "bkgqh,bkch->bkgqc", qf, ck.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )  # (B,Hkv,G,1,Smax)
    s = _softcap(s, cfg.attn_softcap)
    kpos = jnp.arange(smax)
    ok = kpos <= pos
    if window is not None:
        ok = jnp.logical_and(ok, pos - kpos < window)
    s = jnp.where(ok[None, None, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bkgqc,bkch->bkgqh", w, cv.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    o = o.reshape(b, hq, 1, cfg.head_dim).transpose(0, 2, 1, 3).reshape(b, 1, cfg.q_dim)
    return linear(p["wo"], o.astype(dtype), dtype), ck, cv


# -------------------------------- MLPs -------------------------------------


def init_mlp(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "w_gate": init_linear(ks[0], cfg.d_model, cfg.d_ff),
            "w_up": init_linear(ks[1], cfg.d_model, cfg.d_ff),
            "w_down": init_linear(ks[2], cfg.d_ff, cfg.d_model),
        }
    return {
        "w_up": init_linear(ks[0], cfg.d_model, cfg.d_ff, bias=True),
        "w_down": init_linear(ks[1], cfg.d_ff, cfg.d_model, bias=True),
    }


def mlp_forward(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dtype = x.dtype
    if cfg.mlp in ("swiglu", "geglu"):
        gate = constrain(linear(p["w_gate"], x, dtype), "batch", None, "ffn")
        act = jax.nn.silu(gate) if cfg.mlp == "swiglu" else jax.nn.gelu(gate)
        up = constrain(linear(p["w_up"], x, dtype), "batch", None, "ffn")
        return linear(p["w_down"], act * up, dtype)
    h = constrain(linear(p["w_up"], x, dtype), "batch", None, "ffn")
    return linear(p["w_down"], jax.nn.gelu(h), dtype)


# --------------------------------- MoE --------------------------------------


def init_moe(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f)
    return {
        "router": init_linear(ks[0], d, e),
        "w_gate": jax.random.normal(ks[1], (e, d, f), jnp.float32) * s_in,
        "w_up": jax.random.normal(ks[2], (e, d, f), jnp.float32) * s_in,
        "w_down": jax.random.normal(ks[3], (e, f, d), jnp.float32) * s_out,
    }


def moe_forward(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Top-k MoE with sort-based capacity dispatch (GShard-style dropping).

    Dispatch is PER BATCH ROW (vmap over B): each row sorts its own S*k
    (token, expert) pairs, so no sort or scatter ever crosses the batch
    sharding — under pjit the only expert-parallel communication left is
    the all-to-all between row-sharded buffers and expert-sharded FFNs.
    (A global-sort variant was measured 10-60x more collective-bound; see
    EXPERIMENTS.md §Perf iteration 3.)  Capacity is per row:
    C = ceil(k * S / E * capacity_factor); overflow tokens are dropped.
    """
    dtype = x.dtype
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k_experts
    cap = int(math.ceil(k * s / e * cfg.capacity_factor))

    logits = linear(p["router"], x, dtype).astype(jnp.float32)  # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (B, S, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    def dispatch_row(xr, er, wr):
        # xr: (S, D); er/wr: (S, k)
        e_flat = er.reshape(-1)
        w_flat = wr.reshape(-1)
        tok_flat = jnp.repeat(jnp.arange(s), k)
        order = jnp.argsort(e_flat)  # per-row sort: S*k elements
        e_sorted = jnp.take(e_flat, order)
        tok_sorted = jnp.take(tok_flat, order)
        w_sorted = jnp.take(w_flat, order)
        counts = jnp.bincount(e_flat, length=e)
        starts = jnp.cumsum(counts) - counts
        pos_in_e = jnp.arange(s * k) - jnp.take(starts, e_sorted)
        keep = pos_in_e < cap
        slot = jnp.where(keep, e_sorted * cap + pos_in_e, e * cap)
        buf = jnp.zeros((e * cap + 1, d), dtype)
        buf = buf.at[slot].set(jnp.take(xr, tok_sorted, axis=0).astype(dtype))
        return buf[: e * cap].reshape(e, cap, d), (tok_sorted, w_sorted, keep, slot)

    h, aux = jax.vmap(dispatch_row)(x, top_e, top_p)  # h: (B, E, C, D)
    h = constrain(h, "batch", ("expert",), None, None)

    if e > 16:
        # expert-parallel: expert-major 3D layout; the EP all-to-all lives
        # in this transpose under GSPMD (expert dim divides the tensor axis)
        h3 = h.transpose(1, 0, 2, 3).reshape(e, b * cap, d)
        # expert over the tensor axis AND rows over the batch axes — without
        # the row sharding the (E, B*C, D) buffer replicates over data
        h3 = constrain(h3, ("expert",), ("batch",), None)
        gate = jnp.einsum("ecd,edf->ecf", h3, p["w_gate"].astype(dtype),
                          preferred_element_type=jnp.float32).astype(dtype)
        up = jnp.einsum("ecd,edf->ecf", h3, p["w_up"].astype(dtype),
                        preferred_element_type=jnp.float32).astype(dtype)
        gate = constrain(gate, ("expert",), ("batch",), "ffn")
        up = constrain(up, ("expert",), ("batch",), "ffn")
        act = jax.nn.silu(gate) * up
        out3 = jnp.einsum("ecf,efd->ecd", act, p["w_down"].astype(dtype),
                          preferred_element_type=jnp.float32).astype(dtype)
        out3 = constrain(out3, ("expert",), ("batch",), None)
        out_e = out3.reshape(e, b, cap, d).transpose(1, 0, 2, 3)
    else:
        # few experts (< tensor axis): expert dim cannot shard, so keep
        # tokens batch-sharded and unroll E tensor-parallel FFNs — weights
        # are gathered (MBs), activations never are (a fully-replicated
        # (E, B*C, D) buffer cost 43 GB/layer of all-gather; see
        # EXPERIMENTS.md §Perf iteration 3)
        outs = []
        for ei in range(e):
            he = h[:, ei]  # (B, C, D) batch-sharded
            gate = constrain(
                jnp.einsum("bcd,df->bcf", he, p["w_gate"][ei].astype(dtype),
                           preferred_element_type=jnp.float32).astype(dtype),
                "batch", None, "ffn")
            up = constrain(
                jnp.einsum("bcd,df->bcf", he, p["w_up"][ei].astype(dtype),
                           preferred_element_type=jnp.float32).astype(dtype),
                "batch", None, "ffn")
            act = jax.nn.silu(gate) * up
            outs.append(
                jnp.einsum("bcf,fd->bcd", act, p["w_down"][ei].astype(dtype),
                           preferred_element_type=jnp.float32).astype(dtype))
        out_e = jnp.stack(outs, axis=1)  # (B, E, C, D)

    def combine_row(oer, auxr):
        tok_sorted, w_sorted, keep, slot = auxr
        flat = oer.reshape(e * cap, d)
        gathered = jnp.take(flat, jnp.minimum(slot, e * cap - 1), axis=0)
        gathered = jnp.where(keep[:, None], gathered, 0.0)
        return jnp.zeros((s, d), dtype).at[tok_sorted].add(
            gathered * w_sorted[:, None].astype(dtype)
        )

    return jax.vmap(combine_row)(out_e, aux)
