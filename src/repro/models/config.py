"""Model configuration for the assigned architecture pool.

One frozen dataclass describes every family (dense / MoE / SSM / hybrid /
enc-dec / VLM); the backbone assembles the right block pattern from it.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

__all__ = ["ModelConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention details
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True
    sliding_window: int | None = None  # SWA window (Mixtral)
    local_global: bool = False  # Gemma2 alternating local/global
    local_window: int = 4096
    attn_softcap: float | None = None
    final_softcap: float | None = None

    # ffn
    mlp: Literal["swiglu", "geglu", "gelu"] = "swiglu"
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    tie_embeddings: bool = False
    embed_scale: bool = False  # Gemma: embeddings * sqrt(d_model)
    sandwich_norm: bool = False  # Gemma2: post-block norms too
    learned_pos: bool = False  # Whisper: learned absolute positions
    max_learned_pos: int = 32768

    # moe
    n_experts: int = 0
    top_k_experts: int = 0
    capacity_factor: float = 1.25

    # ssm / rwkv
    attn_free: bool = False  # rwkv6
    ssm_state: int = 0  # mamba2 state size N
    ssm_conv: int = 4  # depthwise conv width
    ssm_expand: int = 2  # mamba inner expansion
    hybrid_period: int = 0  # zamba2: shared attn every N mamba blocks

    # enc-dec (whisper) / cross-attn VLM (llama-3.2-vision)
    encoder_layers: int = 0
    encoder_seq: int = 0  # whisper: 1500 precomputed frames (conv stub)
    cross_attn_period: int = 0  # llama-vision: every 5th layer is cross-attn
    vision_tokens: int = 0  # precomputed patch embeddings (stub)

    # numerics
    dtype: str = "bfloat16"  # activation/compute dtype
    vocab_chunk: int = 2048  # chunked-CE logits block (memory bound)

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Megatron-style vocab padding to a multiple of 256 so the vocab
        dim shards over any tensor axis; padded logits are masked in the
        loss (see backbone.chunked_ce_loss)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def supports_decode(self) -> bool:
        return True  # all assigned archs are decoders or enc-dec

    @property
    def sub_quadratic(self) -> bool:
        """Whether the arch admits 500k-token decode per the brief's rule:
        SSM/hybrid/linear-attn families and windowed-attention archs."""
        return (
            self.attn_free
            or self.ssm_state > 0
            or self.sliding_window is not None
            or self.local_global
        )

    # -- parameter counting (for 6*N*D model-flops and memory estimates) ---
    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += d * v  # lm head

        def attn_params(kv_heads: int) -> int:
            p = d * self.q_dim + 2 * d * (kv_heads * self.head_dim) + self.q_dim * d
            if self.qkv_bias:
                p += self.q_dim + 2 * kv_heads * self.head_dim
            return p

        def mlp_params() -> int:
            if self.mlp in ("swiglu", "geglu"):
                return 3 * d * f
            return 2 * d * f

        def moe_params() -> int:
            return self.n_experts * 3 * d * f + d * self.n_experts

        def rwkv_params() -> int:
            # time-mix: r,k,v,g,w,o projections + decay lora + channel mix
            return 6 * d * d + 2 * d * 64 + 3 * d * f

        def mamba_params() -> int:
            inner = self.ssm_expand * d
            # in-proj (x,z), dt/B/C proj, out proj, conv, D, A
            return d * 2 * inner + inner * (2 * self.ssm_state + self.n_heads) + inner * d + self.ssm_conv * inner + 2 * inner

        per_layer_norms = 2 * d
        if self.family == "moe":
            block = attn_params(self.n_kv_heads) + moe_params() + per_layer_norms
            n += self.n_layers * block
        elif self.attn_free:
            n += self.n_layers * (rwkv_params() + per_layer_norms)
        elif self.ssm_state > 0 and self.hybrid_period:
            n += self.n_layers * (mamba_params() + per_layer_norms)
            n += attn_params(self.n_kv_heads) + mlp_params() + per_layer_norms  # shared block
        elif self.is_encdec:
            dec_block = attn_params(self.n_kv_heads) * 2 + mlp_params() + 3 * d
            enc_block = attn_params(self.n_kv_heads) + mlp_params() + per_layer_norms
            n += self.n_layers * dec_block + self.encoder_layers * enc_block
            n += (self.encoder_seq + 8192) * d  # learned positions (enc+dec)
        elif self.cross_attn_period:
            n_cross = self.n_layers // self.cross_attn_period
            n_self = self.n_layers - n_cross
            block = attn_params(self.n_kv_heads) + mlp_params() + per_layer_norms
            n += n_self * block + n_cross * (block + d)  # + gate
        else:
            n += self.n_layers * (attn_params(self.n_kv_heads) + mlp_params() + per_layer_norms)
        n += d  # final norm
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k of E experts)."""
        if self.family != "moe":
            return self.param_count()
        full = self.param_count()
        moe_total = self.n_layers * self.n_experts * 3 * self.d_model * self.d_ff
        moe_active = self.n_layers * self.top_k_experts * 3 * self.d_model * self.d_ff
        return full - moe_total + moe_active
