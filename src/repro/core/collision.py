"""Collision counting — the heart of the Subspace Collision framework.

TPU adaptation (see DESIGN.md §3): the paper counts collisions by sorting
per-subspace distances and walking an id list (`SC_scores[id]++`).  Scatter
increments are hostile to the VPU, so we use the *threshold* formulation:

    o collides with q in subspace i  <=>  dist_i(o, q) <= tau_i,

where ``tau_i`` is the (alpha*n)-th smallest distance in subspace ``i``.
This yields a dense ``(Ns, n)`` boolean mask whose column sum *is* the
SC-score — identical semantics (the same alpha*n set, modulo exact-distance
ties which the paper also breaks arbitrarily), zero scatters.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["kth_smallest", "collision_thresholds", "collision_mask", "sc_scores"]


def kth_smallest(values: jax.Array, k: int) -> jax.Array:
    """k-th smallest (1-indexed) along the last axis, O(n log k) via top_k."""
    neg_topk, _ = jax.lax.top_k(-values, k)
    return -neg_topk[..., -1]


def collision_thresholds(subspace_dists: jax.Array, count: int) -> jax.Array:
    """``(Ns, n) -> (Ns,)`` per-subspace collision thresholds tau_i."""
    return kth_smallest(subspace_dists, count)


def collision_mask(subspace_dists: jax.Array, count: int) -> jax.Array:
    """``(Ns, n) -> (Ns, n)`` bool: does point j collide with q in subspace i."""
    tau = collision_thresholds(subspace_dists, count)
    return subspace_dists <= tau[..., None]


def sc_scores(subspace_dists: jax.Array, count: int) -> jax.Array:
    """``(Ns, n) -> (n,)`` int32 SC-scores (Definition 4)."""
    return jnp.sum(collision_mask(subspace_dists, count).astype(jnp.int32), axis=0)
