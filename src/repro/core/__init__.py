"""repro.core — the Subspace Collision (SC) framework.

Public API:
  SubspaceSpec / contiguous_spec / sampled_spec   (Definition 3)
  sc_scores_from_subspaces, sc_linear_query       (Algorithm 1, SC-Linear)
  SuCoConfig, SuCoIndex, build_index, suco_query  (Algorithms 2-4, SuCo)
  activate_cells_sorted, dynamic_activation_lax   (Algorithm 3)
  SuCoEngine, EnginePolicy, load_index_artifact   (persistent batched serving)
  theory                                          (Theorems 1-2)
"""

from repro.core.subspace import (
    SubspaceSpec,
    contiguous_spec,
    sampled_spec,
    collision_count,
)
from repro.core.sc_linear import (
    QueryResult,
    merge_topk_pool,
    merge_topk_pool_with_dists,
    rerank,
    rerank_candidates,
    sc_linear_query,
    sc_scores_from_subspaces,
)
from repro.core.tuning import (
    MemoryLimits,
    TileConfig,
    autotune_build_block_n,
    autotune_tiles,
    backend_limits,
)
from repro.core.suco import (
    DEFAULT_BATCH_BUCKETS,
    INDEX_ARTIFACT_VERSION,
    STREAMING_MIN_N,
    EnginePolicy,
    EngineStats,
    SuCoConfig,
    SuCoEngine,
    SuCoIndex,
    autoscale_buckets,
    batch_bucket,
    build_index,
    load_index_artifact,
    padding_waste,
    suco_cell_ranks,
    suco_query,
    suco_query_fused,
    suco_query_streaming,
    suco_scores,
    activate_cells_sorted,
    dynamic_activation_lax,
)
from repro.core import theory, da_numpy

__all__ = [
    "SubspaceSpec",
    "contiguous_spec",
    "sampled_spec",
    "collision_count",
    "QueryResult",
    "sc_linear_query",
    "sc_scores_from_subspaces",
    "rerank",
    "rerank_candidates",
    "merge_topk_pool",
    "merge_topk_pool_with_dists",
    "MemoryLimits",
    "TileConfig",
    "autotune_build_block_n",
    "autotune_tiles",
    "backend_limits",
    "STREAMING_MIN_N",
    "DEFAULT_BATCH_BUCKETS",
    "INDEX_ARTIFACT_VERSION",
    "EnginePolicy",
    "EngineStats",
    "SuCoConfig",
    "SuCoEngine",
    "SuCoIndex",
    "autoscale_buckets",
    "batch_bucket",
    "build_index",
    "load_index_artifact",
    "padding_waste",
    "suco_cell_ranks",
    "suco_query",
    "suco_query_fused",
    "suco_query_streaming",
    "suco_scores",
    "activate_cells_sorted",
    "dynamic_activation_lax",
    "theory",
    "da_numpy",
]
