"""repro.core — the Subspace Collision (SC) framework.

Public API:
  SubspaceSpec / contiguous_spec / sampled_spec   (Definition 3)
  sc_scores_from_subspaces, sc_linear_query       (Algorithm 1, SC-Linear)
  SuCoConfig, SuCoIndex, build_index, suco_query  (Algorithms 2-4, SuCo)
  activate_cells_sorted, dynamic_activation_lax   (Algorithm 3)
  theory                                          (Theorems 1-2)
"""

from repro.core.subspace import (
    SubspaceSpec,
    contiguous_spec,
    sampled_spec,
    collision_count,
)
from repro.core.sc_linear import QueryResult, sc_linear_query, sc_scores_from_subspaces, rerank
from repro.core.suco import (
    SuCoConfig,
    SuCoIndex,
    build_index,
    suco_query,
    suco_scores,
    activate_cells_sorted,
    dynamic_activation_lax,
)
from repro.core import theory, da_numpy

__all__ = [
    "SubspaceSpec",
    "contiguous_spec",
    "sampled_spec",
    "collision_count",
    "QueryResult",
    "sc_linear_query",
    "sc_scores_from_subspaces",
    "rerank",
    "SuCoConfig",
    "SuCoIndex",
    "build_index",
    "suco_query",
    "suco_scores",
    "activate_cells_sorted",
    "dynamic_activation_lax",
    "theory",
    "da_numpy",
]
