"""SC-Linear (paper Algorithm 1): index-free subspace-collision ANN search.

Linear-scan cost, near-exact recall; the fidelity baseline for SuCo and the
reference semantics for every test in the framework.

Memory note: the naive formulation materialises an ``(Ns, m, n)`` distance
tensor.  We instead ``lax.scan`` over subspaces and keep a single ``(m, n)``
block live — same math, 1/Ns the footprint, and XLA pipelines the blocks.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import subspace
from repro.core.collision import kth_smallest
from repro.core.distances import Metric, pairwise_dist

__all__ = [
    "QueryResult",
    "sc_scores_from_subspaces",
    "sc_linear_query",
    "rerank",
    "rerank_candidates",
    "merge_topk_pool",
    "merge_topk_pool_with_dists",
]


class QueryResult(NamedTuple):
    ids: jax.Array  # (..., k) int32 — dataset row ids, ascending distance
    dists: jax.Array  # (..., k) — squared L2 (or L1) distances
    scores: jax.Array  # (..., k) int32 — SC-scores of the returned points


def sc_scores_from_subspaces(
    xs: jax.Array,
    qs: jax.Array,
    count: int,
    metric: Metric = "l2",
) -> jax.Array:
    """``xs: (Ns, n, s), qs: (Ns, m, s) -> (m, n)`` int32 SC-scores.

    Scans over subspaces: per subspace computes the (m, n) distance block,
    derives the per-query collision threshold tau (the ``count``-th smallest
    distance, Definition 1) and accumulates the collision indicator.
    """
    m, n = qs.shape[1], xs.shape[1]

    def body(acc: jax.Array, inp: tuple[jax.Array, jax.Array]):
        x_i, q_i = inp
        d = pairwise_dist(q_i, x_i, metric)  # (m, n)
        tau = kth_smallest(d, count)  # (m,)
        return acc + (d <= tau[:, None]).astype(jnp.int32), None

    init = jnp.zeros((m, n), dtype=jnp.int32)
    scores, _ = jax.lax.scan(body, init, (xs, qs))
    return scores


def rerank_candidates(
    x: jax.Array,
    q: jax.Array,
    cand: jax.Array,
    cand_scores: jax.Array,
    k: int,
    metric: Metric = "l2",
) -> QueryResult:
    """Exact re-rank of an explicit candidate pool (Alg. 1 lines 11-15).

    ``x: (n, d)``, ``q: (m, d)``, ``cand/cand_scores: (m, p)`` — per-query
    candidate row ids and their SC-scores.  Deterministic: distance ties
    resolve to the earlier pool position (``top_k`` tie-break), so two
    callers that present the same pool in the same order get bit-identical
    results.
    """

    def one(qi: jax.Array, cand_i: jax.Array, cs_i: jax.Array) -> QueryResult:
        xc = jnp.take(x, cand_i, axis=0)  # (p, d)
        # impl="rowwise": per-element reduction order is independent of the
        # batch size, so zero-padded serving batches (SuCoEngine buckets)
        # rerank bit-identically to the unpadded computation.
        d = pairwise_dist(qi[None], xc, metric, impl="rowwise")[0]  # (p,)
        neg, pos = jax.lax.top_k(-d, k)
        ids = jnp.take(cand_i, pos)
        return QueryResult(ids.astype(jnp.int32), -neg, jnp.take(cs_i, pos))

    return jax.vmap(one)(q, cand, cand_scores)


def rerank(
    x: jax.Array,
    q: jax.Array,
    scores: jax.Array,
    k: int,
    n_candidates: int,
    metric: Metric = "l2",
) -> QueryResult:
    """Paper Alg. 1 lines 11-15: exact re-rank of the top-SC-score pool.

    ``x: (n, d)``, ``q: (m, d)``, ``scores: (m, n)``.
    """
    n = x.shape[0]
    m = max(k, min(n_candidates, n))
    # top_k on int scores breaks ties by lower index — deterministic, and
    # identical to the streaming pool's (score desc, id asc) ordering.
    vals, cand = jax.lax.top_k(scores, m)  # (mq, m)
    return rerank_candidates(x, q, cand, vals, k, metric)


def merge_topk_pool(
    pool_scores: jax.Array,
    pool_ids: jax.Array,
    blk_scores: jax.Array,
    blk_ids: jax.Array,
    *,
    impl: str = "topk",
) -> tuple[jax.Array, jax.Array]:
    """Merge a score block into a carried top-pool, keeping the pool size.

    ``pool_*: (m, p)``, ``blk_*: (m, b)`` -> ``(m, p)``.  Ordering is
    lexicographic (score desc, id asc) — exactly ``lax.top_k``'s tie-break
    on a dense score row — so a scan of ``merge_topk_pool`` over blocks
    reproduces the dense ``top_k(scores, p)`` selection bit-for-bit.
    Sentinel entries (score -1, id INT32_MAX) sort after every real entry
    (real scores are >= 0) and are expelled as real candidates arrive.

    ``impl="topk"`` (the default) replaces the two-key sort of the
    ``(m, p+b)`` concat with a single ``lax.top_k`` over the scores —
    O((p+b) log k) selection instead of a full O((p+b) log (p+b)) sort
    (see ``benchmarks/micro_merge_pool.py`` for the per-block win).  It is
    bit-compatible with ``impl="sort"`` under the *streaming invariant*
    that every in-repo caller satisfies: blocks arrive in ascending-id
    order (so every real pool id is smaller than every real block id, and
    both segments are id-ascending within equal scores), which makes
    ``top_k``'s position tie-break coincide with the (score desc, id asc)
    order.  Callers merging arbitrarily-ordered blocks must pass
    ``impl="sort"``.
    """
    p = pool_scores.shape[-1]
    s = jnp.concatenate([pool_scores, blk_scores], axis=-1)
    i = jnp.concatenate([pool_ids, blk_ids], axis=-1)
    if impl == "topk":
        vals, pos = jax.lax.top_k(s, p)
        return vals, jnp.take_along_axis(i, pos, axis=-1)
    if impl != "sort":
        raise ValueError(f"impl must be 'topk'|'sort', got {impl!r}")
    neg_sorted, ids_sorted = jax.lax.sort((-s, i), num_keys=2)
    return -neg_sorted[..., :p], ids_sorted[..., :p]


def merge_topk_pool_with_dists(
    pool_scores: jax.Array,
    pool_dists: jax.Array,
    pool_ids: jax.Array,
    blk_scores: jax.Array,
    blk_dists: jax.Array,
    blk_ids: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """:func:`merge_topk_pool` for the fused engine's joint
    ``(sc_score, exact_dist, id)`` pool.

    Selection is identical: ``lax.top_k`` on the scores, whose position
    tie-break equals the (score desc, id asc) order whenever every
    equal-score run of the concatenated row is already id-ascending —
    true for ascending-id blocks (all block ids exceed all pool ids) and
    equally for a block pre-sorted by (score desc, id asc), the fused
    overflow fallback's shape.  The pre-computed exact distances simply
    ride along through the same gather, so the post-scan rerank gather
    over ``x`` is never needed.  Sentinel entries carry ``dist = +inf``.
    ``pool_*: (m, p)``, ``blk_*: (m, b)`` -> three ``(m, p)`` arrays.
    """
    p = pool_scores.shape[-1]
    s = jnp.concatenate([pool_scores, blk_scores], axis=-1)
    dd = jnp.concatenate([pool_dists, blk_dists], axis=-1)
    i = jnp.concatenate([pool_ids, blk_ids], axis=-1)
    vals, pos = jax.lax.top_k(s, p)
    return (
        vals,
        jnp.take_along_axis(dd, pos, axis=-1),
        jnp.take_along_axis(i, pos, axis=-1),
    )


@functools.partial(
    jax.jit, static_argnames=("spec", "k", "alpha", "beta", "metric")
)
def sc_linear_query(
    x: jax.Array,
    q: jax.Array,
    *,
    spec: subspace.SubspaceSpec,
    k: int,
    alpha: float,
    beta: float,
    metric: Metric = "l2",
) -> QueryResult:
    """Algorithm 1 for a batch of queries ``q: (m, d)`` over ``x: (n, d)``."""
    n = x.shape[0]
    xp = subspace.permute(spec, x)
    qp = subspace.permute(spec, q)
    xs = subspace.split_padded(spec, xp)  # (Ns, n, s)
    qs = subspace.split_padded(spec, qp)  # (Ns, m, s)
    c = subspace.collision_count(n, alpha)
    scores = sc_scores_from_subspaces(xs, qs, c, metric)  # (m, n)
    n_candidates = max(k, int(beta * n))
    return rerank(x, q, scores, k, n_candidates, metric)


# --------------------------------------------------------------------------
# jaxlint registry hook (see repro.analysis)
# --------------------------------------------------------------------------


def jaxlint_entries():
    """Registry hook: the index-free baseline and the pool-merge scan."""
    from repro.analysis.registry import JaxprEntry

    n, d, m, k = 4_096, 32, 8, 10
    alpha, beta = 0.05, 0.05
    spec = subspace.contiguous_spec(d, 8)
    pool = max(k, int(beta * n))

    def make_query():
        S = jax.ShapeDtypeStruct
        return jax.make_jaxpr(
            lambda xx, qq: sc_linear_query(
                xx, qq, spec=spec, k=k, alpha=alpha, beta=beta
            )
        )(S((n, d), jnp.float32), S((m, d), jnp.float32))

    def make_merge_scan():
        mq, p, bn, blocks = 8, 64, 128, 4
        int_max = jnp.iinfo(jnp.int32).max

        def scan_merge(scores, ids):
            init = (
                jnp.full((mq, p), -1, jnp.int32),
                jnp.full((mq, p), int_max, jnp.int32),
            )

            def step(carry, inp):
                return merge_topk_pool(carry[0], carry[1], *inp), None

            return jax.lax.scan(step, init, (scores, ids))[0]

        S = jax.ShapeDtypeStruct
        return jax.make_jaxpr(scan_merge)(
            S((blocks, mq, bn), jnp.int32), S((blocks, mq, bn), jnp.int32)
        )

    return [
        JaxprEntry(
            name="sc_linear.query",
            make=make_query,
            rules=("bounded-intermediate", "pinned-accumulator"),
            # the subspace scan keeps one (m, n) distance block live plus
            # the (Ns, n, s) split views (O(n*d)) and the rerank gather
            budget_bytes=4 * max(2 * m * n, 2 * n * d, m * pool * d),
            note=(
                "Algorithm 1 baseline; its subspace scan sorts (kth_smallest) "
                "by design, so no-scatter-in-scan is intentionally not declared"
            ),
        ),
        JaxprEntry(
            name="sc_linear.merge_pool_scan",
            make=make_merge_scan,
            rules=("no-scatter-in-scan", "pinned-accumulator"),
            note="the carried top-pool merge the streaming engines scan with",
        ),
    ]
