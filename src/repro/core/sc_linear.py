"""SC-Linear (paper Algorithm 1): index-free subspace-collision ANN search.

Linear-scan cost, near-exact recall; the fidelity baseline for SuCo and the
reference semantics for every test in the framework.

Memory note: the naive formulation materialises an ``(Ns, m, n)`` distance
tensor.  We instead ``lax.scan`` over subspaces and keep a single ``(m, n)``
block live — same math, 1/Ns the footprint, and XLA pipelines the blocks.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import subspace
from repro.core.collision import kth_smallest
from repro.core.distances import Metric, pairwise_dist

__all__ = [
    "QueryResult",
    "candidate_pool_size",
    "sc_scores_from_subspaces",
    "sc_linear_query",
    "rerank",
    "rerank_candidates",
    "merge_topk_pool",
    "merge_topk_pool_with_dists",
]


def candidate_pool_size(n: int, k: int, beta: float) -> int:
    """Candidate-pool width for an Alg. 1 re-rank: ``beta * n`` clamped to
    ``[k, n]``.

    The single source of truth for every ``beta * n`` call site (local
    dense/streaming/fused queries, SC-Linear, the sharded engine).  The
    upper clamp matters once ``n`` is a *live* count — after deletions
    ``int(beta * n_total)`` can exceed the survivors, and the lower clamp
    keeps the pool at least ``k`` wide however small ``beta * n`` gets.
    The result is never larger than ``max(k, n)``; callers validate
    ``k <= n`` separately.
    """
    if n < 0:
        raise ValueError(f"n must be >= 0, got {n}")
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    return max(k, min(int(beta * n), n))


class QueryResult(NamedTuple):
    ids: jax.Array  # (..., k) int32 — dataset row ids, ascending distance
    dists: jax.Array  # (..., k) — squared L2 (or L1) distances
    scores: jax.Array  # (..., k) int32 — SC-scores of the returned points


def sc_scores_from_subspaces(
    xs: jax.Array,
    qs: jax.Array,
    count: int,
    metric: Metric = "l2",
) -> jax.Array:
    """``xs: (Ns, n, s), qs: (Ns, m, s) -> (m, n)`` int32 SC-scores.

    Scans over subspaces: per subspace computes the (m, n) distance block,
    derives the per-query collision threshold tau (the ``count``-th smallest
    distance, Definition 1) and accumulates the collision indicator.
    """
    m, n = qs.shape[1], xs.shape[1]

    def body(acc: jax.Array, inp: tuple[jax.Array, jax.Array]):
        x_i, q_i = inp
        d = pairwise_dist(q_i, x_i, metric)  # (m, n)
        tau = kth_smallest(d, count)  # (m,)
        return acc + (d <= tau[:, None]).astype(jnp.int32), None

    init = jnp.zeros((m, n), dtype=jnp.int32)
    scores, _ = jax.lax.scan(body, init, (xs, qs))
    return scores


def rerank_candidates(
    x: jax.Array,
    q: jax.Array,
    cand: jax.Array,
    cand_scores: jax.Array,
    k: int,
    metric: Metric = "l2",
) -> QueryResult:
    """Exact re-rank of an explicit candidate pool (Alg. 1 lines 11-15).

    ``x: (n, d)``, ``q: (m, d)``, ``cand/cand_scores: (m, p)`` — per-query
    candidate row ids and their SC-scores.  Deterministic: distance ties
    resolve to the earlier pool position (``top_k`` tie-break), so two
    callers that present the same pool in the same order get bit-identical
    results.
    """

    def one(qi: jax.Array, cand_i: jax.Array, cs_i: jax.Array) -> QueryResult:
        xc = jnp.take(x, cand_i, axis=0)  # (p, d)
        # impl="rowwise": per-element reduction order is independent of the
        # batch size, so zero-padded serving batches (SuCoEngine buckets)
        # rerank bit-identically to the unpadded computation.
        d = pairwise_dist(qi[None], xc, metric, impl="rowwise")[0]  # (p,)
        # Score < 0 marks a non-candidate slot: pool sentinels and (under
        # live mutation) tombstoned rows.  Real SC-scores are >= 0, so this
        # is a no-op on full immutable pools, and it guarantees a masked
        # slot can never win the distance top_k however close its row is.
        bad = (
            jnp.inf
            if jnp.issubdtype(d.dtype, jnp.floating)
            else jnp.iinfo(d.dtype).max
        )
        d = jnp.where(cs_i < 0, bad, d)
        neg, pos = jax.lax.top_k(-d, k)
        ids = jnp.take(cand_i, pos)
        return QueryResult(ids.astype(jnp.int32), -neg, jnp.take(cs_i, pos))

    return jax.vmap(one)(q, cand, cand_scores)


def rerank(
    x: jax.Array,
    q: jax.Array,
    scores: jax.Array,
    k: int,
    n_candidates: int,
    metric: Metric = "l2",
) -> QueryResult:
    """Paper Alg. 1 lines 11-15: exact re-rank of the top-SC-score pool.

    ``x: (n, d)``, ``q: (m, d)``, ``scores: (m, n)``.
    """
    n = x.shape[0]
    m = max(k, min(n_candidates, n))
    # top_k on int scores breaks ties by lower index — deterministic, and
    # identical to the streaming pool's (score desc, id asc) ordering.
    vals, cand = jax.lax.top_k(scores, m)  # (mq, m)
    return rerank_candidates(x, q, cand, vals, k, metric)


def _counting_sort_block(
    blk_scores: jax.Array, smax: int, p_out: int
) -> jax.Array:
    """Column indices of the top-``p_out`` block entries, (score desc, pos asc).

    The counting select the integer score range admits: scores live in
    ``[-1, smax]`` (sentinel -1, real SC-scores ``0..smax = Ns``), so a
    per-bucket histogram (one ``cumsum`` pass per score level), the
    suffix-cumsum of bucket sizes (the running ``start`` — each bucket's
    first output slot), and a stable compaction (the r-th occurrence of a
    bucket is the first column whose running count reaches r+1 — a binary
    search on the monotone per-bucket cumsum) reproduce a stable
    (score desc, position asc) sort without ``lax.sort`` or any scatter.
    O((smax+2) * bw) histogram work + O((smax+2) * p_out * log bw)
    inversion, versus the O(bw log bw) comparison sort it replaces.
    """
    m, bw = blk_scores.shape
    sv = blk_scores.astype(jnp.int32) + 1  # shift: sentinel -1 -> bucket 0
    u = jnp.arange(p_out, dtype=jnp.int32)
    src = jnp.zeros((m, p_out), jnp.int32)
    start = jnp.zeros((m, 1), jnp.int32)
    for b in range(smax + 1, -1, -1):  # highest bucket fills slots first
        pref = jnp.cumsum((sv == b).astype(jnp.int32), axis=-1)  # (m, bw)
        hist = pref[:, -1:]
        r = u[None, :] - start  # rank within bucket b, if slot u is b's
        in_b = (r >= 0) & (r < hist)
        pos = jax.vmap(lambda c, q: jnp.searchsorted(c, q, side="left"))(
            pref, jnp.clip(r + 1, 1, bw)
        )
        src = jnp.where(in_b, pos.astype(jnp.int32), src)
        start = start + hist
    return src


def _merge_sorted_desc(
    a_s: jax.Array, b_s: jax.Array, p: int
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Invert the stable merge of two score-descending rows, scatter-free.

    ``a_s: (m, pa)`` and ``b_s: (m, pb)`` are each sorted descending; the
    merged order is (score desc, A before B on ties, original order within
    each).  Every A element's merged position is ``i + #{B > A_i}`` and
    every B element's is ``j + #{A >= B_j}`` — both strictly increasing
    sequences, so the *inverse* map (output slot -> source element) is a
    plain ``searchsorted`` into them.  Returns ``(is_a, i_a, i_b)`` for the
    first ``p`` merged slots: take ``A[i_a]`` where ``is_a`` else ``B[i_b]``.
    """
    t = jnp.arange(p, dtype=jnp.int32)
    na, nb = -a_s, -b_s  # negate: ascending, as searchsorted requires
    right = lambda a, v: jnp.searchsorted(a, v, side="right")
    left = lambda a, v: jnp.searchsorted(a, v, side="left")
    cnt_a = jax.vmap(right)(na, nb)  # per B_j: #A >= B_j (ties -> A first)
    cnt_b = jax.vmap(left)(nb, na)  # per A_i: #B > A_i (strict)
    pos_a = (
        jnp.arange(a_s.shape[1], dtype=jnp.int32)[None, :]
        + cnt_b.astype(jnp.int32)
    )
    pos_b = (
        jnp.arange(b_s.shape[1], dtype=jnp.int32)[None, :]
        + cnt_a.astype(jnp.int32)
    )
    i_a = jax.vmap(left, in_axes=(0, None))(pos_a, t)
    i_b = jax.vmap(left, in_axes=(0, None))(pos_b, t)
    i_a = jnp.minimum(i_a, a_s.shape[1] - 1).astype(jnp.int32)
    i_b = jnp.minimum(i_b, b_s.shape[1] - 1).astype(jnp.int32)
    is_a = jnp.take_along_axis(pos_a, i_a, axis=1) == t[None, :]
    return is_a, i_a, i_b


def _counting_merge(
    pool: tuple[jax.Array, ...], blk: tuple[jax.Array, ...], smax: int
) -> tuple[jax.Array, ...]:
    """Counting-select pool merge: sort the block by counting, then invert
    the sorted-merge.  ``pool[0]``/``blk[0]`` are the scores; the remaining
    arrays (ids, optionally dists) ride through the same gathers."""
    p, bw = pool[0].shape[-1], blk[0].shape[-1]
    # Only the block's top min(p, bw) can survive a p-wide merge.
    src = _counting_sort_block(blk[0], smax, min(p, bw))
    blk_sorted = tuple(jnp.take_along_axis(a, src, axis=1) for a in blk)
    is_a, i_a, i_b = _merge_sorted_desc(pool[0], blk_sorted[0], p)
    return tuple(
        jnp.where(
            is_a,
            jnp.take_along_axis(pa, i_a, axis=1),
            jnp.take_along_axis(ba, i_b, axis=1),
        )
        for pa, ba in zip(pool, blk_sorted)
    )


_MERGE_IMPLS = ("topk", "sort", "counting", "auto")


def _resolve_merge_impl(impl: str, score_dtype, smax: int | None) -> str:
    """``impl="auto"`` picks counting exactly when the scores are declared
    integer-ranged (integer dtype + a ``smax`` bound), else ``top_k``."""
    if impl not in _MERGE_IMPLS:
        raise ValueError(
            f"impl must be one of {_MERGE_IMPLS}, got {impl!r}"
        )
    integer = jnp.issubdtype(score_dtype, jnp.integer)
    if impl == "auto":
        return "counting" if (smax is not None and integer) else "topk"
    if impl == "counting":
        if smax is None:
            raise ValueError(
                "impl='counting' needs smax (the maximum score, e.g. "
                "n_subspaces for SC-scores)"
            )
        if not integer:
            raise ValueError(
                f"impl='counting' requires integer scores, got {score_dtype}"
            )
    return impl


def merge_topk_pool(
    pool_scores: jax.Array,
    pool_ids: jax.Array,
    blk_scores: jax.Array,
    blk_ids: jax.Array,
    *,
    impl: str = "topk",
    smax: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Merge a score block into a carried top-pool, keeping the pool size.

    ``pool_*: (m, p)``, ``blk_*: (m, b)`` -> ``(m, p)``.  Ordering is
    lexicographic (score desc, id asc) — exactly ``lax.top_k``'s tie-break
    on a dense score row — so a scan of ``merge_topk_pool`` over blocks
    reproduces the dense ``top_k(scores, p)`` selection bit-for-bit.
    Sentinel entries (score -1, id INT32_MAX) sort after every real entry
    (real scores are >= 0) and are expelled as real candidates arrive.

    ``impl="topk"`` (the default) replaces the two-key sort of the
    ``(m, p+b)`` concat with a single ``lax.top_k`` over the scores —
    O((p+b) log k) selection instead of a full O((p+b) log (p+b)) sort
    (see ``benchmarks/micro_merge_pool.py`` for the per-block win).  It is
    bit-compatible with ``impl="sort"`` under the *streaming invariant*
    that every in-repo caller satisfies: blocks arrive in ascending-id
    order (so every real pool id is smaller than every real block id, and
    both segments are id-ascending within equal scores), which makes
    ``top_k``'s position tie-break coincide with the (score desc, id asc)
    order.  Callers merging arbitrarily-ordered blocks must pass
    ``impl="sort"``.

    ``impl="counting"`` exploits the *integer score range*: SC-scores are
    collision counts in ``0..Ns`` (``smax = Ns``; sentinel -1), so the
    block is stably ordered by a per-score-level counting pass
    (:func:`_counting_sort_block`) and merged against the carried pool —
    which every caller holds sorted descending, being this function's own
    output — by inverting the sorted-merge positions with binary searches
    (:func:`_merge_sorted_desc`).  No comparison sort, no ``top_k``, no
    scatter; ~1.4x faster than ``top_k`` at the fused pruned width and
    ~3x at full streaming widths on CPU.  Bit-compatible with
    ``impl="topk"`` on *any* input whose pool segment is score-descending
    (ties break to the earlier position, exactly ``top_k``'s rule), and
    therefore with ``"sort"`` under the streaming invariant above.
    Requires ``smax`` (scores must lie in ``[-1, smax]`` — out-of-range
    scores are silently dropped) and an integer score dtype.

    ``impl="auto"`` resolves to ``"counting"`` exactly when the scores
    are declared integer-ranged (integer dtype and ``smax`` given), else
    to ``"topk"``.
    """
    impl = _resolve_merge_impl(impl, pool_scores.dtype, smax)
    p = pool_scores.shape[-1]
    if impl == "counting":
        return _counting_merge(
            (pool_scores, pool_ids), (blk_scores, blk_ids), smax
        )
    s = jnp.concatenate([pool_scores, blk_scores], axis=-1)
    i = jnp.concatenate([pool_ids, blk_ids], axis=-1)
    if impl == "topk":
        vals, pos = jax.lax.top_k(s, p)
        return vals, jnp.take_along_axis(i, pos, axis=-1)
    neg_sorted, ids_sorted = jax.lax.sort((-s, i), num_keys=2)
    return -neg_sorted[..., :p], ids_sorted[..., :p]


def merge_topk_pool_with_dists(
    pool_scores: jax.Array,
    pool_dists: jax.Array,
    pool_ids: jax.Array,
    blk_scores: jax.Array,
    blk_dists: jax.Array,
    blk_ids: jax.Array,
    *,
    impl: str = "topk",
    smax: int | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """:func:`merge_topk_pool` for the fused engine's joint
    ``(sc_score, exact_dist, id)`` pool.

    Selection is identical, per ``impl`` (same knob and semantics as
    :func:`merge_topk_pool`): ``"topk"`` selects with ``lax.top_k`` on the
    scores, whose position tie-break equals the (score desc, id asc) order
    whenever every equal-score run of the concatenated row is already
    id-ascending — true for ascending-id blocks (all block ids exceed all
    pool ids) and equally for a block pre-sorted by (score desc, id asc),
    the fused overflow fallback's shape.  ``"counting"`` is the integer
    counting-select (requires ``smax``); ``"sort"`` the two-key reference
    sort; ``"auto"`` picks counting iff the scores are integer-ranged.
    The pre-computed exact distances simply ride along through the same
    gather, so the post-scan rerank gather over ``x`` is never needed.
    Sentinel entries carry ``dist = +inf``.
    ``pool_*: (m, p)``, ``blk_*: (m, b)`` -> three ``(m, p)`` arrays.
    """
    impl = _resolve_merge_impl(impl, pool_scores.dtype, smax)
    p = pool_scores.shape[-1]
    if impl == "counting":
        s, i, dd = _counting_merge(
            (pool_scores, pool_ids, pool_dists),
            (blk_scores, blk_ids, blk_dists),
            smax,
        )
        return s, dd, i
    s = jnp.concatenate([pool_scores, blk_scores], axis=-1)
    dd = jnp.concatenate([pool_dists, blk_dists], axis=-1)
    i = jnp.concatenate([pool_ids, blk_ids], axis=-1)
    if impl == "sort":
        neg_sorted, ids_sorted, dd_sorted = jax.lax.sort(
            (-s, i, dd), num_keys=2
        )
        return (
            -neg_sorted[..., :p],
            dd_sorted[..., :p],
            ids_sorted[..., :p],
        )
    vals, pos = jax.lax.top_k(s, p)
    return (
        vals,
        jnp.take_along_axis(dd, pos, axis=-1),
        jnp.take_along_axis(i, pos, axis=-1),
    )


@functools.partial(
    jax.jit, static_argnames=("spec", "k", "alpha", "beta", "metric")
)
def sc_linear_query(
    x: jax.Array,
    q: jax.Array,
    *,
    spec: subspace.SubspaceSpec,
    k: int,
    alpha: float,
    beta: float,
    metric: Metric = "l2",
) -> QueryResult:
    """Algorithm 1 for a batch of queries ``q: (m, d)`` over ``x: (n, d)``."""
    n = x.shape[0]
    xp = subspace.permute(spec, x)
    qp = subspace.permute(spec, q)
    xs = subspace.split_padded(spec, xp)  # (Ns, n, s)
    qs = subspace.split_padded(spec, qp)  # (Ns, m, s)
    c = subspace.collision_count(n, alpha)
    scores = sc_scores_from_subspaces(xs, qs, c, metric)  # (m, n)
    n_candidates = candidate_pool_size(n, k, beta)
    return rerank(x, q, scores, k, n_candidates, metric)


# --------------------------------------------------------------------------
# jaxlint registry hook (see repro.analysis)
# --------------------------------------------------------------------------


def jaxlint_entries():
    """Registry hook: the index-free baseline and the pool-merge scan."""
    from repro.analysis.registry import JaxprEntry

    n, d, m, k = 4_096, 32, 8, 10
    alpha, beta = 0.05, 0.05
    spec = subspace.contiguous_spec(d, 8)
    pool = candidate_pool_size(n, k, beta)

    def make_query():
        S = jax.ShapeDtypeStruct
        return jax.make_jaxpr(
            lambda xx, qq: sc_linear_query(
                xx, qq, spec=spec, k=k, alpha=alpha, beta=beta
            )
        )(S((n, d), jnp.float32), S((m, d), jnp.float32))

    def make_merge_scan(impl: str = "topk", smax: int | None = None):
        mq, p, bn, blocks = 8, 64, 128, 4
        int_max = jnp.iinfo(jnp.int32).max

        def scan_merge(scores, ids):
            init = (
                jnp.full((mq, p), -1, jnp.int32),
                jnp.full((mq, p), int_max, jnp.int32),
            )

            def step(carry, inp):
                return (
                    merge_topk_pool(
                        carry[0], carry[1], *inp, impl=impl, smax=smax
                    ),
                    None,
                )

            return jax.lax.scan(step, init, (scores, ids))[0]

        S = jax.ShapeDtypeStruct
        return jax.make_jaxpr(scan_merge)(
            S((blocks, mq, bn), jnp.int32), S((blocks, mq, bn), jnp.int32)
        )

    def make_merge_with_dists_scan(impl: str = "auto", smax: int | None = 8):
        mq, p, bn, blocks = 8, 64, 128, 4
        int_max = jnp.iinfo(jnp.int32).max

        def scan_merge(scores, dists, ids):
            init = (
                jnp.full((mq, p), -1, jnp.int32),
                jnp.full((mq, p), jnp.inf, jnp.float32),
                jnp.full((mq, p), int_max, jnp.int32),
            )

            def step(carry, inp):
                return (
                    merge_topk_pool_with_dists(
                        *carry, *inp, impl=impl, smax=smax
                    ),
                    None,
                )

            return jax.lax.scan(step, init, (scores, dists, ids))[0]

        S = jax.ShapeDtypeStruct
        return jax.make_jaxpr(scan_merge)(
            S((blocks, mq, bn), jnp.int32),
            S((blocks, mq, bn), jnp.float32),
            S((blocks, mq, bn), jnp.int32),
        )

    return [
        JaxprEntry(
            name="sc_linear.query",
            make=make_query,
            rules=("bounded-intermediate", "pinned-accumulator"),
            # the subspace scan keeps one (m, n) distance block live plus
            # the (Ns, n, s) split views (O(n*d)) and the rerank gather
            budget_bytes=4 * max(2 * m * n, 2 * n * d, m * pool * d),
            note=(
                "Algorithm 1 baseline; its subspace scan sorts (kth_smallest) "
                "by design, so no-scatter-in-scan is intentionally not declared"
            ),
        ),
        JaxprEntry(
            name="sc_linear.merge_pool_scan",
            make=make_merge_scan,
            rules=("no-scatter-in-scan", "pinned-accumulator"),
            note="the carried top-pool merge the streaming engines scan with",
        ),
        JaxprEntry(
            name="sc_linear.merge_pool_counting_scan",
            make=functools.partial(make_merge_scan, impl="counting", smax=8),
            rules=("no-scatter-in-scan", "pinned-accumulator"),
            note=(
                "the counting-select merge (integer score range): per-level "
                "histogram + suffix-cumsum + searchsorted compaction — must "
                "stay sort- and scatter-free inside the scan"
            ),
        ),
        JaxprEntry(
            name="sc_linear.merge_pool_with_dists_scan",
            make=make_merge_with_dists_scan,
            rules=("no-scatter-in-scan", "pinned-accumulator"),
            note=(
                "the fused engine's joint (score, dist, id) pool merge with "
                "impl='auto' resolving to counting — the serving default"
            ),
        ),
    ]
