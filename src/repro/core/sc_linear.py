"""SC-Linear (paper Algorithm 1): index-free subspace-collision ANN search.

Linear-scan cost, near-exact recall; the fidelity baseline for SuCo and the
reference semantics for every test in the framework.

Memory note: the naive formulation materialises an ``(Ns, m, n)`` distance
tensor.  We instead ``lax.scan`` over subspaces and keep a single ``(m, n)``
block live — same math, 1/Ns the footprint, and XLA pipelines the blocks.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import subspace
from repro.core.collision import kth_smallest
from repro.core.distances import Metric, pairwise_dist

__all__ = ["QueryResult", "sc_scores_from_subspaces", "sc_linear_query", "rerank"]


class QueryResult(NamedTuple):
    ids: jax.Array  # (..., k) int32 — dataset row ids, ascending distance
    dists: jax.Array  # (..., k) — squared L2 (or L1) distances
    scores: jax.Array  # (..., k) int32 — SC-scores of the returned points


def sc_scores_from_subspaces(
    xs: jax.Array,
    qs: jax.Array,
    count: int,
    metric: Metric = "l2",
) -> jax.Array:
    """``xs: (Ns, n, s), qs: (Ns, m, s) -> (m, n)`` int32 SC-scores.

    Scans over subspaces: per subspace computes the (m, n) distance block,
    derives the per-query collision threshold tau (the ``count``-th smallest
    distance, Definition 1) and accumulates the collision indicator.
    """
    m, n = qs.shape[1], xs.shape[1]

    def body(acc: jax.Array, inp: tuple[jax.Array, jax.Array]):
        x_i, q_i = inp
        d = pairwise_dist(q_i, x_i, metric)  # (m, n)
        tau = kth_smallest(d, count)  # (m,)
        return acc + (d <= tau[:, None]).astype(jnp.int32), None

    init = jnp.zeros((m, n), dtype=jnp.int32)
    scores, _ = jax.lax.scan(body, init, (xs, qs))
    return scores


def rerank(
    x: jax.Array,
    q: jax.Array,
    scores: jax.Array,
    k: int,
    n_candidates: int,
    metric: Metric = "l2",
) -> QueryResult:
    """Paper Alg. 1 lines 11-15: exact re-rank of the top-SC-score pool.

    ``x: (n, d)``, ``q: (m, d)``, ``scores: (m, n)``.
    """
    n = x.shape[0]
    m = max(k, min(n_candidates, n))
    # top_k on int scores breaks ties by lower index — deterministic.
    _, cand = jax.lax.top_k(scores, m)  # (mq, m)

    def one(qi: jax.Array, cand_i: jax.Array, scores_i: jax.Array) -> QueryResult:
        xc = jnp.take(x, cand_i, axis=0)  # (m, d)
        d = pairwise_dist(qi[None], xc, metric)[0]  # (m,)
        neg, pos = jax.lax.top_k(-d, k)
        ids = jnp.take(cand_i, pos)
        return QueryResult(
            ids.astype(jnp.int32), -neg, jnp.take(scores_i, ids, axis=0)
        )

    return jax.vmap(one)(q, cand, scores)


@functools.partial(
    jax.jit, static_argnames=("spec", "k", "alpha", "beta", "metric")
)
def sc_linear_query(
    x: jax.Array,
    q: jax.Array,
    *,
    spec: subspace.SubspaceSpec,
    k: int,
    alpha: float,
    beta: float,
    metric: Metric = "l2",
) -> QueryResult:
    """Algorithm 1 for a batch of queries ``q: (m, d)`` over ``x: (n, d)``."""
    n = x.shape[0]
    xp = subspace.permute(spec, x)
    qp = subspace.permute(spec, q)
    xs = subspace.split_padded(spec, xp)  # (Ns, n, s)
    qs = subspace.split_padded(spec, qp)  # (Ns, m, s)
    c = subspace.collision_count(n, alpha)
    scores = sc_scores_from_subspaces(xs, qs, c, metric)  # (m, n)
    n_candidates = max(k, int(beta * n))
    return rerank(x, q, scores, k, n_candidates, metric)
