"""Batched K-means in JAX (the paper's Algorithm 2 building block).

SuCo runs ``2 * Ns`` small K-means problems (two half-subspaces per
subspace), each with only ``sqrt(K)`` centroids (~50).  All codebooks are
trained in one batched XLA program — the TPU analogue of the paper's "one
OpenMP task per subspace" parallelism.

Index-build memory model (three execution paths, one reference semantics):

* **dense** (``block_n=0``, the reference) — full-batch Lloyd; every
  iteration materialises the ``(B, n, k)`` distance matrix and a
  ``(B, n, k)`` one-hot update.  Fastest for small n (one fused einsum),
  but the one-hot alone is ``k`` times the dataset and caps dataset size.
* **chunked** (``block_n>0``, ``algo="lloyd"``) — the same Lloyd update
  as a blocked ``lax.scan`` over data chunks of ``block_n`` points that
  carries per-centroid ``(sums, counts, inertia)`` accumulators: nothing
  of size ``(n, k)`` is ever live, peak per-iteration memory is
  O(B * block_n * max(k, s)).  Centroids agree with dense up to fp
  summation order; over multiple iterations that noise can flip the
  assignment of points sitting exactly on Voronoi boundaries (exact
  parity on separated data, <0.1% flips otherwise).  On TPU the whole
  per-iteration pass runs
  through the fused Pallas :func:`~repro.kernels.kmeans_assign.ops.
  kmeans_assign_stats` kernel (distance + argmin + partial-sum
  accumulation in VMEM); on CPU the jnp ``lax.scan`` is the oracle path.
* **minibatch** (``algo="minibatch"``) — opt-in web-scale mode: each step
  assigns one *sampled* chunk of ``block_n`` points and moves centroids
  with per-centroid learning rates ``counts_step / counts_total``
  (Sculley-style mini-batch K-means, aggregated form).  O(iters * block_n)
  assignment work instead of O(iters * n) — the right trade for
  million-point builds where full Lloyd epochs are wasteful.  Approximate:
  centroids converge near, not to, the Lloyd fixed point.

The final assignment pass respects ``impl`` ("auto" routes to the fused
Pallas ``kmeans_assign`` kernels on TPU, pure jnp elsewhere) and is
chunked whenever ``block_n>0``.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.distances import pairwise_sqdist

__all__ = [
    "KMeansResult",
    "kmeans",
    "kmeans_batched",
    "assign",
    "block_batched",
    "lloyd_stats_scan",
    "assign_scan",
    "init_centroids_pp",
]

_ALGOS = ("lloyd", "minibatch")
_INITS = ("auto", "random", "kmeans++")
_MINIBATCH_DEFAULT_BLOCK = 4096
# kmeans++ seeds from a uniform sample of this many points (capped at n):
# enough for D^2 sampling to separate the modes, independent of dataset size.
_PP_SAMPLE_PER_K = 32
_PP_SAMPLE_MIN = 2048


class KMeansResult(NamedTuple):
    centroids: jax.Array  # (k, s) — or (B, k, s) batched
    assignments: jax.Array  # (n,) int32 — or (B, n) batched
    inertia: jax.Array  # () — or (B,); sum of squared distances to the
    # owning centroid.  Lloyd paths report the last update step's inertia
    # (dense-reference semantics); minibatch reports the final full-data
    # inertia from the assignment pass.
    cell_counts: jax.Array | None = None  # (B//2, pair_sqrt_k**2) int32 when
    # requested via ``pair_sqrt_k`` (SuCo IMI occupancy fused into the final
    # assignment scan); None otherwise.


def assign(x: jax.Array, centroids: jax.Array, *, impl: str = "auto") -> jax.Array:
    """``argmin_c ||x - centroid_c||^2`` for every row of ``x``."""
    if _use_pallas(impl):
        from repro.kernels.kmeans_assign import ops as _ops

        return _ops.kmeans_assign(x, centroids)
    d2 = pairwise_sqdist(x, centroids, impl="jnp")  # (n, k)
    return jnp.argmin(d2, axis=1).astype(jnp.int32)


def _use_pallas(impl: str) -> bool:
    if impl == "pallas":
        return True
    if impl == "jnp":
        return False
    if impl != "auto":
        raise ValueError(f"impl must be 'auto'|'jnp'|'pallas', got {impl!r}")
    return jax.default_backend() == "tpu"


def _init_centroids(key: jax.Array, x: jax.Array, k: int) -> jax.Array:
    """Random distinct-row init (the paper uses plain Lloyd; kmeans++ is
    unnecessary at sqrt(K)=50 granularity and costs an extra O(nk) pass)."""
    n = x.shape[0]
    idx = jax.random.permutation(key, n)[:k]
    return jnp.take(x, idx, axis=0)


def init_centroids_pp(
    key: jax.Array, x: jax.Array, k: int, *, sample_n: int = 0
) -> jax.Array:
    """kmeans++-style D^2 seeding (Arthur & Vassilvitskii) over a sample.

    ``sample_n > 0`` seeds from that many uniformly sampled rows instead of
    all of ``x`` — the streaming-friendly form: minibatch never touches the
    full dataset before its final assignment pass, and the seeding keeps
    that property.  O(sample_n * k) work; deterministic given ``key``.
    """
    n = x.shape[0]
    k_sub, k_first, k_pick = jax.random.split(key, 3)
    if 0 < sample_n < n:
        idx = jax.random.permutation(k_sub, n)[:sample_n]
        xs = jnp.take(x, idx, axis=0)
    else:
        xs = x
    xf = xs.astype(jnp.float32)
    c0 = xf[jax.random.randint(k_first, (), 0, xs.shape[0])]
    cents = jnp.zeros((k, x.shape[1]), jnp.float32).at[0].set(c0)
    d2 = jnp.sum((xf - c0) ** 2, axis=-1)

    def body(carry, inp):
        cents, d2 = carry
        i, kt = inp
        # Sample the next seed with prob ∝ D^2; all-zero D^2 (every sampled
        # row already a centroid, duplicate-heavy data) falls back to uniform.
        logits = jnp.log(jnp.maximum(d2, jnp.finfo(jnp.float32).tiny))
        logits = jnp.where(jnp.sum(d2) > 0, logits, jnp.zeros_like(d2))
        c = xf[jax.random.categorical(kt, logits)]
        cents = cents.at[i].set(c)
        d2 = jnp.minimum(d2, jnp.sum((xf - c) ** 2, axis=-1))
        return (cents, d2), None

    (cents, _), _ = jax.lax.scan(
        body,
        (cents, d2),
        (jnp.arange(1, k), jax.random.split(k_pick, k - 1)),
    )
    return cents.astype(x.dtype)


def _init_batched(
    key: jax.Array, xs: jax.Array, k: int, init: str, algo: str
) -> jax.Array:
    """``(B, n, s) -> (B, k, s)`` initial centroids for every problem.

    ``init="auto"`` resolves to kmeans++ for minibatch (whose few sampled
    steps cannot recover from a bad random seed the way full Lloyd epochs
    can) and random for lloyd (the paper's choice)."""
    mode = init
    if mode == "auto":
        mode = "kmeans++" if algo == "minibatch" else "random"
    keys = jax.random.split(key, xs.shape[0])
    if mode == "random":
        return jax.vmap(lambda kk, x: _init_centroids(kk, x, k))(keys, xs)
    sample_n = min(xs.shape[1], max(_PP_SAMPLE_PER_K * k, _PP_SAMPLE_MIN))
    return jax.vmap(
        lambda kk, x: init_centroids_pp(kk, x, k, sample_n=sample_n)
    )(keys, xs)


# --------------------------------------------------------------------------
# Dense reference step (the semantics every streaming path must match)
# --------------------------------------------------------------------------


def _lloyd_step(x: jax.Array, centroids: jax.Array) -> tuple[jax.Array, jax.Array]:
    k = centroids.shape[0]
    d2 = pairwise_sqdist(x, centroids, impl="jnp")  # (n, k)
    a = jnp.argmin(d2, axis=1)
    one_hot = jax.nn.one_hot(a, k, dtype=x.dtype)  # (n, k)
    sums = jnp.einsum("nk,ns->ks", one_hot, x)
    counts = jnp.sum(one_hot, axis=0)  # (k,)
    new = sums / jnp.maximum(counts, 1.0)[:, None]
    # Empty cluster: keep the previous centroid (matches common practice and
    # keeps the update a fixed-shape op).
    new = jnp.where(counts[:, None] > 0, new, centroids)
    inertia = jnp.sum(jnp.take_along_axis(d2, a[:, None], axis=1))
    return new, inertia


# --------------------------------------------------------------------------
# Chunked streaming statistics (shared with the distributed engine)
# --------------------------------------------------------------------------


def block_batched(
    xs: jax.Array, block_n: int
) -> tuple[jax.Array, jax.Array]:
    """``(B, n, s) -> (blocks (nb, B, bn, s), valid (nb, bn) bool)``.

    Zero-pads n up to a multiple of ``bn = min(block_n, n)`` and exposes
    the data as scan-ready chunks; ``valid`` masks the padded tail.
    """
    b, n, s = xs.shape
    bn = max(1, min(block_n, n))
    nb = -(-n // bn)
    xp = jnp.pad(xs, ((0, 0), (0, nb * bn - n), (0, 0)))
    blocks = xp.reshape(b, nb, bn, s).transpose(1, 0, 2, 3)
    valid = (jnp.arange(nb * bn) < n).reshape(nb, bn)
    return blocks, valid


def lloyd_stats_scan(
    blocks: jax.Array,
    valid: jax.Array,
    centroids: jax.Array,
    *,
    cast_init: Callable[[tuple], tuple] = lambda t: t,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One Lloyd assignment pass as a blocked scan with carried accumulators.

    ``blocks: (nb, B, bn, s)``, ``valid: (nb, bn)``, ``centroids: (B, k, s)``
    -> ``(sums (B, k, s) f32, counts (B, k) f32, inertia (B,) f32)``.

    Per chunk only a ``(B, bn, k)`` distance tile and a ``(B, bn, k)``
    weighted one-hot are live — the O(n * k) full-batch intermediates never
    exist.  ``cast_init`` lets shard_map callers mark the zero carries as
    device-varying (VMA) before the scan.
    """
    _, b, _, s = blocks.shape
    k = centroids.shape[1]
    cf = centroids.astype(jnp.float32)

    def body(carry, inp):
        sums, counts, inertia = carry
        xb, vb = inp  # (B, bn, s), (bn,)
        xf = xb.astype(jnp.float32)
        d2 = jax.vmap(lambda xx, cc: pairwise_sqdist(xx, cc, impl="jnp"))(xf, cf)
        a = jnp.argmin(d2, axis=-1)  # (B, bn)
        w = vb.astype(jnp.float32)  # (bn,)
        oh = jax.nn.one_hot(a, k, dtype=jnp.float32) * w[None, :, None]
        sums = sums + jnp.einsum("bnk,bns->bks", oh, xf)
        counts = counts + jnp.sum(oh, axis=1)
        inertia = inertia + jnp.sum(jnp.min(d2, axis=-1) * w[None, :], axis=1)
        return (sums, counts, inertia), None

    init = cast_init(
        (
            jnp.zeros((b, k, s), jnp.float32),
            jnp.zeros((b, k), jnp.float32),
            jnp.zeros((b,), jnp.float32),
        )
    )
    (sums, counts, inertia), _ = jax.lax.scan(body, init, (blocks, valid))
    return sums, counts, inertia


def assign_scan(
    blocks: jax.Array,
    valid: jax.Array,
    centroids: jax.Array,
    *,
    cast_init: Callable = lambda t: t,
    pair_sqrt_k: int = 0,
) -> tuple[jax.Array, jax.Array, jax.Array | None]:
    """Chunked final assignment:
    ``-> (assign (B, nb*bn) int32, inertia (B,), cell_counts | None)``.

    Assignments for padded rows are junk — the caller slices ``[:, :n]``;
    the inertia accumulator masks them out.

    ``pair_sqrt_k > 0`` treats the batch as SuCo's paired half-subspace
    layout — rows ``[:B//2]`` are first halves, ``[B//2:]`` second halves
    of the same subspaces — and additionally accumulates the IMI cell
    occupancy ``bincount(a1 * pair_sqrt_k + a2)`` per chunk into a carried
    ``(B//2, pair_sqrt_k**2) int32`` accumulator: the histogram that used
    to be a second full pass over ``cell_ids`` rides the assignment scan
    for free.
    """
    _, b, _, _ = blocks.shape
    cf = centroids.astype(jnp.float32)
    if pair_sqrt_k and b % 2:
        raise ValueError(f"pair_sqrt_k needs an even batch, got B={b}")
    ns = b // 2

    def body(carry, inp):
        inertia, counts = carry
        xb, vb = inp
        d2 = jax.vmap(lambda xx, cc: pairwise_sqdist(xx, cc, impl="jnp"))(
            xb.astype(jnp.float32), cf
        )
        a = jnp.argmin(d2, axis=-1).astype(jnp.int32)  # (B, bn)
        w = vb.astype(jnp.float32)
        inertia = inertia + jnp.sum(jnp.min(d2, axis=-1) * w[None, :], axis=1)
        if pair_sqrt_k:
            cells = a[:ns] * pair_sqrt_k + a[ns:]  # (ns, bn)
            rows = jnp.arange(ns, dtype=jnp.int32)[:, None]
            wb = jnp.broadcast_to(vb.astype(jnp.int32), cells.shape)
            counts = counts.at[rows, cells].add(wb)
        return (inertia, counts), a

    counts0 = (
        jnp.zeros((ns, pair_sqrt_k * pair_sqrt_k), jnp.int32)
        if pair_sqrt_k
        else jnp.zeros((), jnp.int32)
    )
    init = cast_init((jnp.zeros((b,), jnp.float32), counts0))
    (inertia, counts), a_blocks = jax.lax.scan(body, init, (blocks, valid))
    a = a_blocks.transpose(1, 0, 2).reshape(b, -1)  # (B, nb*bn)
    return a, inertia, counts if pair_sqrt_k else None


def _stats_batched(
    xs: jax.Array, centroids: jax.Array, *, block_n: int, impl: str
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Dispatch one batched Lloyd statistics pass: fused Pallas kernel on
    TPU, blocked jnp scan elsewhere."""
    if _use_pallas(impl):
        from repro.kernels.kmeans_assign import ops as _ops

        # impl="pallas": the dispatch decision is already made — forward it
        # so an explicit off-TPU request runs the kernel (or fails loudly)
        # instead of silently falling back to the dense jnp oracle.
        # with_assign=False: Lloyd iterations consume only the statistics,
        # and an unused pallas output cannot be DCE'd.
        _, sums, counts, inertia = _ops.kmeans_assign_stats(
            xs, centroids, bn=block_n, impl="pallas", with_assign=False
        )
        return sums, counts, inertia
    blocks, valid = block_batched(xs, block_n)
    return lloyd_stats_scan(blocks, valid, centroids)


# --------------------------------------------------------------------------
# Training loops
# --------------------------------------------------------------------------


def _kmeans_core(
    key: jax.Array,
    xs: jax.Array,  # (B, n, s)
    c0: jax.Array,  # (B, k, s)
    iters: int,
    algo: str,
    block_n: int,
    impl: str,
    pair_sqrt_k: int = 0,
) -> KMeansResult:
    b, n, s = xs.shape
    k = c0.shape[1]
    pallas = _use_pallas(impl)

    if algo == "minibatch":
        bn = max(1, min(block_n or _MINIBATCH_DEFAULT_BLOCK, n))

        def mb_body(carry, t):
            c, cnts = carry
            kt = jax.random.fold_in(key, t)
            idx = jax.random.randint(kt, (bn,), 0, n)
            xb = jnp.take(xs, idx, axis=1)  # (B, bn, s) — shared sample
            sums, counts, _ = _stats_batched(xb, c, block_n=bn, impl=impl)
            cnts = cnts + counts
            # Aggregated Sculley update: per-centroid learning rate
            # counts / cnts, i.e. c <- c + (batch_sum - batch_count*c)/cnts.
            delta = (sums - counts[..., None] * c.astype(jnp.float32)) / jnp.maximum(
                cnts, 1.0
            )[..., None]
            return (
                (c.astype(jnp.float32) + delta).astype(c.dtype),
                cnts,
            ), None

        (c_fin, _), _ = jax.lax.scan(
            mb_body,
            (c0, jnp.zeros((b, k), jnp.float32)),
            jnp.arange(iters, dtype=jnp.int32),
        )
        a, inertia, counts = _final_assign(xs, c_fin, block_n=bn, pallas=pallas,
                                           need_inertia=True,
                                           pair_sqrt_k=pair_sqrt_k)
        return KMeansResult(c_fin, a, inertia, counts)

    # algo == "lloyd"
    chunked = block_n > 0
    if chunked and not pallas:
        blocks, valid = block_batched(xs, block_n)

    def lloyd_body(c, _):
        if not chunked:
            new, inertia = jax.vmap(_lloyd_step)(xs, c)
            return new, inertia
        if pallas:
            sums, counts, inertia = _stats_batched(xs, c, block_n=block_n, impl=impl)
        else:
            sums, counts, inertia = lloyd_stats_scan(blocks, valid, c)
        new = sums / jnp.maximum(counts, 1.0)[..., None]
        new = jnp.where(counts[..., None] > 0, new, c.astype(jnp.float32))
        return new.astype(c.dtype), inertia

    centroids, inertias = jax.lax.scan(lloyd_body, c0, None, length=iters)
    a, _, counts = _final_assign(xs, centroids, block_n=block_n, pallas=pallas,
                                 need_inertia=False, pair_sqrt_k=pair_sqrt_k)
    return KMeansResult(centroids, a, inertias[-1], counts)


def _final_assign(
    xs: jax.Array,
    centroids: jax.Array,
    *,
    block_n: int,
    pallas: bool,
    need_inertia: bool,
    pair_sqrt_k: int = 0,
) -> tuple[jax.Array, jax.Array | None, jax.Array | None]:
    """Final assignment pass
    -> (assign (B, n) int32, inertia (B,) f32|None, cell_counts|None).

    Routed through the batched Pallas kernels on TPU (regardless of
    block_n: they stream internally), the chunked jnp scan when
    ``block_n>0``, and the dense jnp argmin otherwise.  Lloyd callers pass
    ``need_inertia=False`` (they report the last update step's inertia) so
    the TPU path can use the assign-only kernel and skip the dead
    one-hot/stats accumulation work entirely; minibatch needs the final
    full-data inertia and takes the fused stats kernel.

    ``pair_sqrt_k > 0`` fuses the SuCo IMI occupancy histogram into the
    scan (see :func:`assign_scan`).  The Lloyd-path TPU route fuses it
    too (:func:`repro.kernels.kmeans_assign.ops.kmeans_pair_assign_hist`:
    the histogram accumulates on the MXU inside the assignment kernel);
    only the minibatch TPU path — which additionally needs the full-data
    inertia from the stats kernel — still returns None and leaves the
    caller a bincount over the assignments.
    """
    b, n, _ = xs.shape
    if pallas:
        from repro.kernels.kmeans_assign import ops as _ops

        bn = block_n or 1024
        if not need_inertia:
            if pair_sqrt_k:
                a, counts = _ops.kmeans_pair_assign_hist(
                    xs, centroids, bn=bn, impl="pallas"
                )
                return a, None, counts
            a = _ops.kmeans_assign_batched(xs, centroids, bn=bn, impl="pallas")
            return a, None, None
        a, _, _, inertia = _ops.kmeans_assign_stats(
            xs, centroids, bn=bn, impl="pallas"
        )
        return a, inertia, None
    blocks, valid = block_batched(xs, block_n or n)
    a, inertia, counts = assign_scan(blocks, valid, centroids,
                                     pair_sqrt_k=pair_sqrt_k)
    return a[:, :n], inertia, counts


def _check_args(algo: str, block_n: int, init: str = "auto") -> None:
    if algo not in _ALGOS:
        raise ValueError(f"algo must be one of {_ALGOS}, got {algo!r}")
    if block_n < 0:
        raise ValueError(f"block_n must be >= 0 (0 = dense), got {block_n}")
    if init not in _INITS:
        raise ValueError(f"init must be one of {_INITS}, got {init!r}")


def kmeans(
    key: jax.Array,
    x: jax.Array,
    k: int,
    iters: int,
    *,
    algo: str = "lloyd",
    block_n: int = 0,
    impl: str = "auto",
    init: str = "auto",
) -> KMeansResult:
    """K-means with ``iters`` update steps; deterministic given ``key``.

    ``algo``: "lloyd" (exact full-batch updates) | "minibatch" (sampled
    chunks + learning-rate updates).  ``block_n``: 0 = dense reference
    Lloyd; >0 = chunked streaming updates over ``block_n``-point chunks
    (same update rule; centroids and assignments agree with dense up to
    fp summation-order noise at Voronoi boundaries).  ``impl`` selects
    the assignment backend ("auto" = fused Pallas kernels on TPU, jnp
    elsewhere).  ``init``: "random" | "kmeans++" (sampled D^2 seeding) |
    "auto" (kmeans++ for minibatch, random for lloyd).
    """
    _check_args(algo, block_n, init)
    mode = init
    if mode == "auto":
        mode = "kmeans++" if algo == "minibatch" else "random"
    if mode == "random":
        c0 = _init_centroids(key, x, k)
    else:
        sample_n = min(x.shape[0], max(_PP_SAMPLE_PER_K * k, _PP_SAMPLE_MIN))
        c0 = init_centroids_pp(key, x, k, sample_n=sample_n)
    res = _kmeans_core(key, x[None], c0[None], iters, algo, block_n, impl)
    return KMeansResult(res.centroids[0], res.assignments[0], res.inertia[0])


def kmeans_batched(
    key: jax.Array,
    xs: jax.Array,
    k: int,
    iters: int,
    *,
    algo: str = "lloyd",
    block_n: int = 0,
    impl: str = "auto",
    init: str = "auto",
    pair_sqrt_k: int = 0,
) -> KMeansResult:
    """``xs: (B, n, s)`` -> centroids ``(B, k, s)``, assignments ``(B, n)``.

    One fused program for all ``B`` codebooks (B = 2*Ns for SuCo); same
    ``algo``/``block_n``/``impl``/``init`` contract as :func:`kmeans`.
    ``pair_sqrt_k > 0`` additionally returns the fused IMI cell occupancy
    ``KMeansResult.cell_counts`` from the final-assignment scan (jnp paths
    only; the Pallas final assignment leaves it None — see
    :func:`assign_scan`).
    """
    _check_args(algo, block_n, init)
    c0 = _init_batched(key, xs, k, init, algo)
    return _kmeans_core(key, xs, c0, iters, algo, block_n, impl, pair_sqrt_k)
