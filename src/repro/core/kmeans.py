"""Batched Lloyd K-means in JAX (the paper's Algorithm 2 building block).

SuCo runs ``2 * Ns`` small K-means problems (two half-subspaces per
subspace), each with only ``sqrt(K)`` centroids (~50).  We therefore batch
all codebooks into one ``vmap`` so a single XLA program trains the whole
index — this is the TPU analogue of the paper's "one OpenMP task per
subspace" parallelism.

The assignment step can optionally run through the fused Pallas
``kmeans_assign`` kernel (distance + argmin without materialising the
``(n, K)`` distance matrix).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.distances import pairwise_sqdist

__all__ = ["KMeansResult", "kmeans", "kmeans_batched", "assign"]


class KMeansResult(NamedTuple):
    centroids: jax.Array  # (k, s)
    assignments: jax.Array  # (n,) int32
    inertia: jax.Array  # () sum of squared distances to the owning centroid


def assign(x: jax.Array, centroids: jax.Array, *, impl: str = "auto") -> jax.Array:
    """``argmin_c ||x - centroid_c||^2`` for every row of ``x``."""
    if impl == "pallas" or (impl == "auto" and jax.default_backend() == "tpu"):
        from repro.kernels.kmeans_assign import ops as _ops

        return _ops.kmeans_assign(x, centroids)
    d2 = pairwise_sqdist(x, centroids, impl="jnp")  # (n, k)
    return jnp.argmin(d2, axis=1).astype(jnp.int32)


def _init_centroids(key: jax.Array, x: jax.Array, k: int) -> jax.Array:
    """Random distinct-row init (the paper uses plain Lloyd; kmeans++ is
    unnecessary at sqrt(K)=50 granularity and costs an extra O(nk) pass)."""
    n = x.shape[0]
    idx = jax.random.permutation(key, n)[:k]
    return jnp.take(x, idx, axis=0)


def _lloyd_step(x: jax.Array, centroids: jax.Array) -> tuple[jax.Array, jax.Array]:
    k = centroids.shape[0]
    d2 = pairwise_sqdist(x, centroids, impl="jnp")  # (n, k)
    a = jnp.argmin(d2, axis=1)
    one_hot = jax.nn.one_hot(a, k, dtype=x.dtype)  # (n, k)
    sums = jnp.einsum("nk,ns->ks", one_hot, x)
    counts = jnp.sum(one_hot, axis=0)  # (k,)
    new = sums / jnp.maximum(counts, 1.0)[:, None]
    # Empty cluster: keep the previous centroid (matches common practice and
    # keeps the update a fixed-shape op).
    new = jnp.where(counts[:, None] > 0, new, centroids)
    inertia = jnp.sum(jnp.take_along_axis(d2, a[:, None], axis=1))
    return new, inertia


def kmeans(key: jax.Array, x: jax.Array, k: int, iters: int) -> KMeansResult:
    """Plain Lloyd with ``iters`` update steps; deterministic given ``key``."""
    centroids0 = _init_centroids(key, x, k)

    def body(c, _):
        new, inertia = _lloyd_step(x, c)
        return new, inertia

    centroids, inertias = jax.lax.scan(body, centroids0, None, length=iters)
    a = assign(x, centroids, impl="jnp")
    return KMeansResult(centroids, a, inertias[-1])


def kmeans_batched(key: jax.Array, xs: jax.Array, k: int, iters: int) -> KMeansResult:
    """``xs: (B, n, s)`` -> centroids ``(B, k, s)``, assignments ``(B, n)``.

    One fused program for all ``B`` codebooks (B = 2*Ns for SuCo).
    """
    keys = jax.random.split(key, xs.shape[0])
    return jax.vmap(lambda kk, x: kmeans(kk, x, k, iters))(keys, xs)
