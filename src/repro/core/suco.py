"""SuCo (paper Algorithms 2-4): clustering-based lightweight index + query.

Index (Alg. 2): per subspace, split dims in two halves; K-means with sqrt(K)
centroids per half; IMI = the sqrt(K) x sqrt(K) Cartesian grid.  TPU-adapted
layout (DESIGN.md §3): instead of ragged inverted lists we store

* ``cell_ids   (Ns, n) int32`` — which IMI cell each point falls in,
* ``cell_counts (Ns, K) int32`` — points per cell,

which makes collision counting a dense gather+compare instead of pointer
chasing.

Query (Algs. 3-4): the Dynamic Activation traversal is replaced by its exact
sort-prefix equivalent :func:`activate_cells_sorted` (K <= 4096 cells: one
sort + one cumsum), property-tested against the sequential forms in
:mod:`repro.core.da_numpy`.  A faithful ``lax.while_loop`` port of Algorithm
3 is kept in :func:`dynamic_activation_lax`.

Query-memory model (two execution paths, identical results):

* **dense** (:func:`suco_query` with ``mode="dense"``) — materialises the
  full ``(m, n)`` int32 SC-score matrix and runs one ``top_k`` over all n
  points.  Peak query memory O(m*n); fastest for small n (one fused XLA
  loop, no pool bookkeeping).  The reference semantics.
* **streaming** (:func:`suco_query_streaming`) — a blocked ``lax.scan``
  over data chunks of ``block_n`` points: each chunk's collision counts
  come from the chunked SC-score kernel path
  (:func:`repro.kernels.sc_score.ops.sc_scores_cells`), and a running
  per-query top-``n_candidates`` pool is maintained by
  :func:`repro.core.sc_linear.merge_topk_pool` under the (score desc,
  id asc) order — exactly ``top_k``'s tie-break on the dense matrix, so
  the surviving pool, and therefore the reranked result, is bit-identical
  to the dense path.  Peak query memory O(m*(block_n + n_candidates)).
* **fused** (:func:`suco_query_fused`) — the single-pass engine: while a
  chunk is resident, one fused stage scores it, applies the **Pareto
  prefilter** (only rows beating the carried pool minimum can enter the
  merge — the paper's Pareto observation makes that a thin tail, so the
  merge runs at a compacted ``survivor_cap`` width instead of the full
  chunk width), and computes **exact rerank distances in-pass** for the
  survivors — O(cap) rows of ``x`` per chunk, gathered by global id while
  the chunk's scores are fresh — carrying a joint ``(sc_score,
  exact_dist, id)`` pool; the post-scan rerank gather over ``x``
  disappears and ``x`` is never copied or streamed through the scan.  A
  chunk whose survivors overflow the cap falls back (``lax.cond``) to an
  exact chunk-``top_k`` merge, so results are bit-identical to dense /
  streaming either way.  Tile sizes come from
  :func:`repro.core.tuning.autotune_tiles` unless pinned.

``suco_query(mode="auto")`` (the default) selects dense below
``STREAMING_MIN_N`` points and the fused engine at or above it —
million-point datasets never allocate an (m, n) intermediate; the legacy
streaming path stays available as ``mode="streaming"``.

Index-build memory model (mirrors the query design; see
:mod:`repro.core.kmeans` for the K-means internals):

* **dense** (``SuCoConfig(build_mode="dense")``) — full-batch Lloyd; each
  iteration materialises ``(2Ns, n, sqrtK)`` distance and one-hot
  intermediates.  The reference semantics; fastest for small n.
* **chunked** (``build_mode="chunked"``) — streaming Lloyd: a blocked
  ``lax.scan`` over ``block_n``-point chunks carrying per-centroid
  ``(sums, counts, inertia)`` accumulators, and a chunked final
  assignment.  Peak per-iteration memory O(2Ns * block_n * max(sqrtK,
  h_max)).  Same update rule as dense; the chunked accumulators sum in a
  different fp order, so over multiple Lloyd iterations points sitting
  exactly on Voronoi boundaries can flip cells (in practice <0.1%; exact
  parity on separated data).  On TPU the pass is the fused Pallas
  ``kmeans_assign_stats`` kernel.
* **minibatch** (``build_mode="minibatch"``) — opt-in approximate mode
  for million-point builds: each K-means step assigns one sampled
  ``block_n`` chunk and applies learning-rate centroid updates; the
  only full-data pass left is the final chunked assignment.

``build_mode="auto"`` (the default) picks dense below ``STREAMING_MIN_N``
points and chunked at or above it, so large builds never materialise an
``(n, sqrtK)`` intermediate.  ``minibatch`` is never auto-selected — it
trades accuracy and must be requested.

Serving (the persistent subsystem on top of the algorithms):

* :meth:`SuCoIndex.save` / :meth:`SuCoIndex.load` persist the index as a
  version-stamped npz artifact (bit-identical round trips; unknown
  versions raise) — :func:`load_index_artifact` also recovers the build
  config.
* :class:`SuCoEngine` owns ``(data, index, EnginePolicy)`` for its
  lifetime and serves ``query(q, k)`` through jitted executables keyed by
  ``(padded batch bucket, k)`` (:func:`batch_bucket`): after
  :meth:`SuCoEngine.warmup` covers the traffic mix, no request can
  retrace.  The dense/streaming/fused dispatch — and the fused path's
  tiling (:class:`repro.core.tuning.TileConfig`) — lives in the policy,
  not on the call; :func:`suco_query` stays as the bit-identical
  back-compat wrapper for one-shot use.  The continuous micro-batching
  server over the engine is :mod:`repro.serve.ann`; the sharded
  counterpart is :class:`repro.distributed.engine.ShardedSuCoEngine`.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import os
import tempfile
import zipfile
import zlib
from typing import Iterable, Mapping, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import subspace as sub
from repro.core.distances import Metric, pairwise_dist
from repro.core.kmeans import assign_scan, block_batched, kmeans_batched
from repro.core.sc_linear import (
    QueryResult,
    candidate_pool_size,
    merge_topk_pool,
    merge_topk_pool_with_dists,
    rerank,
    rerank_candidates,
)
from repro.core.tuning import TileConfig, autotune_build_block_n, autotune_tiles
from repro.kernels.gather_rerank.ops import gather_rerank_block
from repro.kernels.sc_score.ops import (
    sc_scores_cells,
    sc_scores_cells_prefilter_compact,
)

__all__ = [
    "SuCoConfig",
    "SuCoIndex",
    "build_index",
    "activate_cells_sorted",
    "dynamic_activation_lax",
    "suco_scores",
    "suco_cell_ranks",
    "suco_query",
    "suco_query_streaming",
    "suco_query_fused",
    "STREAMING_MIN_N",
    "INDEX_ARTIFACT_VERSION",
    "ArtifactError",
    "CapacityError",
    "load_index_artifact",
    "assign_points",
    "EnginePolicy",
    "EngineStats",
    "SuCoEngine",
    "batch_bucket",
    "autoscale_buckets",
    "padding_waste",
    "DEFAULT_BATCH_BUCKETS",
]

# mode="auto" switches from the dense (m, n) score matrix to the tiled
# streaming engine at this dataset size (see module docstring); the index
# build's "auto" switches dense -> chunked Lloyd at the same point.
STREAMING_MIN_N = 32_768

_BUILD_MODES = ("auto", "dense", "chunked", "minibatch")

# SuCoIndex.save/load artifact contract: a plain .npz, tagged and
# version-stamped so a serving process refuses artifacts it cannot trust.
# Version 2 added the optional "tombstone" key (live-mutation deletes);
# version 3 adds per-array content checksums ("crc_<key>") and an optional
# "extra_<name>" block (serving-state sidecar: corpus rows, external key
# table, WAL high-water mark — see repro.serve.durability).  Version-1/-2
# artifacts load unchanged (no checksums to verify, no extras).
_ARTIFACT_MAGIC = "suco-index"
INDEX_ARTIFACT_VERSION = 3
_ARTIFACT_READABLE_VERSIONS = (1, 2, 3)

# Payload keys excluded from content checksumming: both are validated
# semantically before any checksum is looked at (magic match, version
# gate), and tests rewrite them in place to probe those gates.
_ARTIFACT_UNCHECKSUMMED = ("artifact", "version")

#: Prefix for caller-supplied serving-state arrays riding in the artifact.
_ARTIFACT_EXTRA_PREFIX = "extra_"

# Keys every readable artifact must carry (the optional config_* block is
# allowed to be absent; these are not).
_ARTIFACT_REQUIRED_KEYS = (
    "artifact",
    "version",
    "centroids1",
    "centroids2",
    "cell_ids",
    "cell_counts",
    "sqrt_k",
    "spec_d",
    "spec_n_subspaces",
    "spec_perm",
    "spec_bounds",
)


def _array_crc(a: np.ndarray) -> np.uint32:
    """CRC32 over an array's dtype, shape, and raw bytes.

    The content checksum stored per payload array (``crc_<key>``): a
    bit-flip inside the npz member that slips past the zip-level CRC (or a
    rewrite that kept the zip consistent) still fails the load loudly with
    the offending key named, instead of silently serving wrong answers.
    """
    a = np.ascontiguousarray(a)
    h = zlib.crc32(str(a.dtype).encode())
    h = zlib.crc32(repr(a.shape).encode(), h)
    h = zlib.crc32(a.tobytes(), h)
    return np.uint32(h & 0xFFFFFFFF)


class ArtifactError(ValueError):
    """A ``SuCoIndex.save`` artifact could not be loaded.

    Raised with the offending path and what exactly failed — a foreign
    file, a version mismatch (found vs expected), missing keys, or a
    truncated/corrupt payload — instead of leaking a bare ``KeyError`` or
    ``zipfile.BadZipFile`` into a serving process.  Subclasses
    ``ValueError`` so existing ``pytest.raises(ValueError)`` gates and
    caller-side handling keep working.
    """


class CapacityError(ValueError):
    """A mutable :class:`SuCoEngine` ran out of pre-allocated insert slots.

    Raised by :meth:`SuCoEngine.insert` when the batch does not fit in the
    remaining ``capacity`` — the signal for the serving layer to trigger a
    re-index + swap (:mod:`repro.serve.mutation`) onto a larger successor.
    Subclasses ``ValueError`` for uniform caller-side handling.
    """


@dataclasses.dataclass(frozen=True)
class SuCoConfig:
    """Static SuCo hyper-parameters (paper defaults: K=50^2, Ns=8, t=20).

    ``build_mode``/``block_n`` select the index-construction memory model
    (see module docstring): "auto" | "dense" | "chunked" | "minibatch",
    with ``block_n`` the streaming chunk size (and the minibatch sample
    size).  ``block_n=0`` autotunes the chunk from the backend's memory
    limits and the dataset shape
    (:func:`repro.core.tuning.autotune_build_block_n`); any positive value
    pins it by hand.
    """

    n_subspaces: int = 8
    sqrt_k: int = 50
    kmeans_iters: int = 20
    seed: int = 0
    build_mode: str = "auto"
    block_n: int = 4096

    @property
    def n_cells(self) -> int:
        return self.sqrt_k * self.sqrt_k


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SuCoIndex:
    """The SuCo index: centroid codebooks + dense IMI occupancy arrays.

    ``tombstone`` (live mutation, optional): a ``(n,) bool`` mask, True for
    deleted (or not-yet-inserted) slots.  ``None`` — the build/load default
    — means every point is live; the immutable query graphs are unchanged.
    A present mask is threaded through every query path's keep-mask so a
    tombstoned id can never enter a candidate pool.  ``cell_counts`` always
    reflects the *live* points only (deletes decrement it), keeping the
    Dynamic-Activation prefix honest after mutation.
    """

    centroids1: jax.Array  # (Ns, sqrtK, h_max)
    centroids2: jax.Array  # (Ns, sqrtK, h_max)
    cell_ids: jax.Array  # (Ns, n) int32
    cell_counts: jax.Array  # (Ns, K) int32
    spec: sub.SubspaceSpec = dataclasses.field(metadata=dict(static=True))
    sqrt_k: int = dataclasses.field(metadata=dict(static=True))
    tombstone: jax.Array | None = None  # (n,) bool, True = deleted slot

    @property
    def n_cells(self) -> int:
        return self.sqrt_k * self.sqrt_k

    @property
    def n_points(self) -> int:
        return self.cell_ids.shape[1]

    @property
    def n_live(self) -> int:
        """Live (non-tombstoned) point count; ``n_points`` when immutable."""
        if self.tombstone is None:
            return self.n_points
        return self.n_points - int(jnp.sum(self.tombstone))

    def memory_bytes(self) -> int:
        """Index footprint (the paper's `O(sqrt(K) d + n Ns)` claim)."""
        arrays = [self.centroids1, self.centroids2, self.cell_ids, self.cell_counts]
        if self.tombstone is not None:
            arrays.append(self.tombstone)
        return sum(a.size * a.dtype.itemsize for a in arrays)

    # ---- live mutation ---------------------------------------------------

    def insert(self, x_new: jax.Array, *, block_n: int = 4096) -> "SuCoIndex":
        """Append ``x_new: (b, d)`` points, assigned to the existing
        centroids — paper Alg. 2's assignment step only, no re-cluster.

        Returns a new index with ``b`` extra live columns: ``cell_ids``
        grows by the chunked :func:`~repro.core.kmeans.assign_scan`
        assignment (the same pass the streaming build runs per chunk),
        ``cell_counts`` absorbs the new occupancy, and the tombstone mask
        (when present) extends with ``False``.  Ids of existing points are
        stable; the new points get ids ``n_points .. n_points + b - 1``.
        Shapes change, so engines serving a fixed-capacity layout use
        :meth:`SuCoEngine.insert` (slot writes, zero retrace) instead.
        """
        x_new = jnp.asarray(x_new)
        if x_new.ndim == 1:
            x_new = x_new[None]
        if x_new.ndim != 2 or x_new.shape[-1] != self.spec.d:
            raise ValueError(
                f"points must be (b, {self.spec.d}), got {x_new.shape}"
            )
        cells, counts_delta, _ = assign_points(
            x_new, self.centroids1, self.centroids2,
            spec=self.spec, sqrt_k=self.sqrt_k, block_n=block_n,
        )
        tomb = self.tombstone
        if tomb is not None:
            tomb = jnp.concatenate([tomb, jnp.zeros(x_new.shape[0], bool)])
        return dataclasses.replace(
            self,
            cell_ids=jnp.concatenate([self.cell_ids, cells], axis=1),
            cell_counts=self.cell_counts + counts_delta,
            tombstone=tomb,
        )

    def delete(self, ids) -> "SuCoIndex":
        """Tombstone the given point ids (idempotent; duplicate ids fine).

        Returns a new index whose tombstone mask marks the ids deleted and
        whose ``cell_counts`` drops the *newly* deleted points' occupancy —
        re-deleting an already-dead id changes nothing.  Shapes are
        preserved, so a :class:`SuCoEngine` can rebind the result without
        retracing.
        """
        ids = np.unique(np.asarray(ids, dtype=np.int64))
        if ids.size == 0:
            return self
        if ids[0] < 0 or ids[-1] >= self.n_points:
            raise ValueError(
                f"ids must be in [0, {self.n_points}), got range "
                f"[{ids[0]}, {ids[-1]}]"
            )
        ids = jnp.asarray(ids, jnp.int32)
        tomb = (
            jnp.zeros(self.n_points, bool)
            if self.tombstone is None
            else self.tombstone
        )
        newly = jnp.logical_not(tomb[ids])  # idempotence: only live ids count
        tomb = tomb.at[ids].set(True)
        # Drop the newly dead points from the IMI occupancy so the
        # Dynamic-Activation prefix keeps targeting live mass.
        dead_cells = self.cell_ids[:, ids]  # (Ns, b)
        rows = jnp.arange(self.cell_ids.shape[0], dtype=jnp.int32)[:, None]
        w = jnp.broadcast_to(newly.astype(jnp.int32), dead_cells.shape)
        counts = self.cell_counts.at[rows, dead_cells].add(-w)
        return dataclasses.replace(self, cell_counts=counts, tombstone=tomb)

    def save(
        self,
        path,
        config: SuCoConfig | None = None,
        *,
        extras: Mapping[str, np.ndarray] | None = None,
    ) -> None:
        """Persist the index as a version-stamped ``.npz`` artifact.

        The artifact holds the four index arrays byte-exactly, the
        :class:`~repro.core.subspace.SubspaceSpec`, and (when given) the
        build :class:`SuCoConfig` — everything a serving process needs to
        reconstruct the index without the original build.  Round trips are
        bit-identical.  Written via an open file handle so the exact
        ``path`` is honoured (``np.savez`` alone appends ``.npz``).

        Version 3 additions: every payload array gets a ``crc_<key>``
        content checksum (verified on load — a bit-flipped block fails
        loudly naming the key), and ``extras`` rides along as
        ``extra_<name>`` arrays — the serving-state sidecar
        (:mod:`repro.serve.durability` stores the corpus rows, the
        external key table, and the WAL high-water mark there).

        The write is **atomic**: the payload lands in a same-directory
        temp file, is fsynced, and is ``os.replace``d onto ``path`` — a
        crash mid-write can never truncate or corrupt an artifact a
        serving process is about to (re)load.  This is what lets the
        re-index handoff (:mod:`repro.serve.mutation`) publish successor
        artifacts under a live server.
        """
        payload: dict[str, np.ndarray] = {
            "artifact": np.asarray(_ARTIFACT_MAGIC),
            "version": np.asarray(INDEX_ARTIFACT_VERSION, np.int32),
            "centroids1": np.asarray(self.centroids1),
            "centroids2": np.asarray(self.centroids2),
            "cell_ids": np.asarray(self.cell_ids),
            "cell_counts": np.asarray(self.cell_counts),
            "sqrt_k": np.asarray(self.sqrt_k, np.int32),
            "spec_d": np.asarray(self.spec.d, np.int32),
            "spec_n_subspaces": np.asarray(self.spec.n_subspaces, np.int32),
            "spec_perm": np.asarray(self.spec.perm, np.int32),
            "spec_bounds": np.asarray(self.spec.bounds, np.int32),
        }
        if self.tombstone is not None:
            payload["tombstone"] = np.asarray(self.tombstone, np.uint8)
        if config is not None:
            payload.update(
                config_n_subspaces=np.asarray(config.n_subspaces, np.int32),
                config_sqrt_k=np.asarray(config.sqrt_k, np.int32),
                config_kmeans_iters=np.asarray(config.kmeans_iters, np.int32),
                config_seed=np.asarray(config.seed, np.int32),
                config_build_mode=np.asarray(config.build_mode),
                config_block_n=np.asarray(config.block_n, np.int32),
            )
        if extras:
            for name, value in extras.items():
                key = _ARTIFACT_EXTRA_PREFIX + name
                if key in payload:
                    raise ValueError(f"duplicate extras key {name!r}")
                payload[key] = np.asarray(value)
        payload.update(
            {
                f"crc_{k}": _array_crc(v)
                for k, v in list(payload.items())
                if k not in _ARTIFACT_UNCHECKSUMMED
            }
        )
        path = os.fspath(path)
        parent = os.path.dirname(path) or "."
        # Same directory: os.replace is atomic only within a filesystem.
        fd, tmp = tempfile.mkstemp(
            dir=parent, prefix=os.path.basename(path) + ".", suffix=".tmp"
        )
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(f, **payload)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except BaseException:
            # A failed write must not leave temp litter next to the live
            # artifact; the artifact itself was never touched.
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    @classmethod
    def load(cls, path) -> "SuCoIndex":
        """Load an index artifact written by :meth:`save` (bit-identical)."""
        index, _ = load_index_artifact(path)
        return index


@functools.partial(
    jax.jit, static_argnames=("spec", "sqrt_k", "iters", "algo", "block_n")
)
def _build(
    x: jax.Array,
    key: jax.Array,
    *,
    spec,
    sqrt_k: int,
    iters: int,
    algo: str = "lloyd",
    block_n: int = 0,
):
    ns = spec.n_subspaces
    xp = sub.permute(spec, x)
    h1, h2 = sub.split_halves_padded(spec, xp)  # 2 x (Ns, n, h_max)
    both = jnp.concatenate([h1, h2], axis=0)  # (2Ns, n, h_max)
    # block_n=0 is the dense reference; >0 streams every K-means pass —
    # including the final assignment feeding cell_ids — in block_n chunks.
    # pair_sqrt_k fuses the IMI occupancy histogram into that final
    # assignment scan, so cell_counts costs no extra pass over the data.
    res = kmeans_batched(
        key, both, sqrt_k, iters, algo=algo, block_n=block_n, pair_sqrt_k=sqrt_k
    )
    a1, a2 = res.assignments[:ns], res.assignments[ns:]
    cell_ids = (a1 * sqrt_k + a2).astype(jnp.int32)  # (Ns, n)
    if res.cell_counts is not None:
        counts = res.cell_counts
    else:  # minibatch TPU final assignment (stats kernel) does not fuse it
        counts = jax.vmap(
            lambda c: jnp.bincount(c, length=sqrt_k * sqrt_k).astype(jnp.int32)
        )(cell_ids)
    return res.centroids[:ns], res.centroids[ns:], cell_ids, counts


def build_index(x: jax.Array, config: SuCoConfig, *, spec: sub.SubspaceSpec | None = None) -> SuCoIndex:
    """Algorithm 2.  ``x: (n, d)``; deterministic given ``config.seed``.

    ``config.build_mode`` picks the construction memory model ("auto"
    selects chunked at or above ``STREAMING_MIN_N`` points — see module
    docstring); dense and chunked run the same update rule and agree up
    to fp summation order (boundary points can differ after many
    iterations).
    """
    if spec is None:
        spec = sub.contiguous_spec(x.shape[-1], config.n_subspaces)
    mode = config.build_mode
    if mode not in _BUILD_MODES:
        raise ValueError(f"unknown build_mode {mode!r}, expected one of {_BUILD_MODES}")
    if mode == "auto":
        mode = "chunked" if x.shape[0] >= STREAMING_MIN_N else "dense"
    if mode != "dense" and config.block_n < 0:
        raise ValueError(
            f"build_mode={mode!r} requires block_n >= 0 (0 = autotune), "
            f"got {config.block_n}"
        )
    algo = "minibatch" if mode == "minibatch" else "lloyd"
    if mode == "dense":
        block_n = 0
    elif config.block_n == 0:  # autotune from backend limits + data shape
        block_n = autotune_build_block_n(
            x.shape[0],
            x.shape[-1],
            sqrt_k=config.sqrt_k,
            n_subspaces=config.n_subspaces,
        )
    else:
        block_n = config.block_n
    key = jax.random.key(config.seed)
    c1, c2, cell_ids, counts = _build(
        x,
        key,
        spec=spec,
        sqrt_k=config.sqrt_k,
        iters=config.kmeans_iters,
        algo=algo,
        block_n=block_n,
    )
    return SuCoIndex(c1, c2, cell_ids, counts, spec=spec, sqrt_k=config.sqrt_k)


@functools.partial(jax.jit, static_argnames=("spec", "sqrt_k", "block_n"))
def assign_points(
    x_new: jax.Array,
    centroids1: jax.Array,
    centroids2: jax.Array,
    *,
    spec: sub.SubspaceSpec,
    sqrt_k: int,
    block_n: int = 4096,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Assign ``x_new: (b, d)`` to existing centroids, chunked.

    The incremental-insert core: exactly the build's final-assignment pass
    (:func:`repro.core.kmeans.assign_scan` with the fused IMI histogram)
    over just the new points.  Returns ``(cell_ids (Ns, b) int32,
    counts_delta (Ns, K) int32, inertia () f32)`` — the occupancy delta to
    add to ``cell_counts`` and the new points' assignment inertia (the
    drift monitor's statistic: rising per-point insert inertia vs. the
    build baseline means the centroids no longer describe the data).
    """
    ns = spec.n_subspaces
    b = x_new.shape[0]
    xp = sub.permute(spec, x_new)
    h1, h2 = sub.split_halves_padded(spec, xp)  # 2 x (Ns, b, h_max)
    both = jnp.concatenate([h1, h2], axis=0)  # (2Ns, b, h_max)
    cents = jnp.concatenate([centroids1, centroids2], axis=0)
    blocks, valid = block_batched(both, block_n)
    a, inertia, counts = assign_scan(blocks, valid, cents, pair_sqrt_k=sqrt_k)
    a = a[:, :b]
    cells = (a[:ns] * sqrt_k + a[ns:]).astype(jnp.int32)  # (Ns, b)
    return cells, counts, jnp.sum(inertia)


def load_index_artifact(
    path, *, return_extras: bool = False
) -> tuple[SuCoIndex, SuCoConfig | None] | tuple[
    SuCoIndex, SuCoConfig | None, dict[str, np.ndarray]
]:
    """Load a ``SuCoIndex.save`` artifact -> ``(index, build config | None)``.

    Validates the artifact tag, version, and key inventory before touching
    any payload; an unknown version, a foreign npz, missing keys, or a
    truncated/corrupt file raises :class:`ArtifactError` (a ``ValueError``)
    naming the path and the found-vs-expected state instead of leaking a
    bare ``KeyError``/``BadZipFile`` into a serving process.

    Version-3 artifacts additionally carry per-array content checksums
    (``crc_<key>``): every checksummed array is verified before anything is
    returned, and a mismatch — a bit-flip the zip layer did not catch, or a
    tampered rewrite — raises :class:`ArtifactError` naming the offending
    key.  Pre-checksum artifacts (v1/v2) load with no verification, as
    before.  With ``return_extras=True`` the result is a 3-tuple whose last
    element maps each ``extra_<name>`` sidecar array (serving state written
    by :mod:`repro.serve.durability`) back to ``name``.
    """
    try:
        z = np.load(path, allow_pickle=False)
    except (ValueError, OSError, EOFError, zipfile.BadZipFile) as e:
        # A file truncated before the zip central directory fails here
        # (BadZipFile) rather than at member read time.
        raise ArtifactError(
            f"{path!s}: not a readable npz ({type(e).__name__}: {e})"
        ) from e
    with z:
        names = set(z.files)
        if "artifact" not in names or str(z["artifact"][()]) != _ARTIFACT_MAGIC:
            raise ArtifactError(f"{path!s} is not a {_ARTIFACT_MAGIC} artifact")
        missing = [k for k in _ARTIFACT_REQUIRED_KEYS if k not in names]
        if missing:
            raise ArtifactError(
                f"{path!s}: {_ARTIFACT_MAGIC} artifact is missing keys "
                f"{missing} (found {sorted(names)}) — truncated or "
                "incompletely written file"
            )
        try:
            version = int(z["version"][()])
            if version not in _ARTIFACT_READABLE_VERSIONS:
                raise ArtifactError(
                    f"{path!s}: unsupported {_ARTIFACT_MAGIC} artifact version "
                    f"{version} (this build reads version "
                    f"{INDEX_ARTIFACT_VERSION})"
                )
            # Content checksums (v3): verify BEFORE constructing anything —
            # a serving process must never adopt a bit-flipped centroid
            # block.  Pre-checksum artifacts simply carry no crc_* keys.
            for key in sorted(names):
                if key.startswith("crc_") or f"crc_{key}" not in names:
                    continue
                stored = int(z[f"crc_{key}"][()])
                computed = int(_array_crc(z[key]))
                if computed != stored:
                    raise ArtifactError(
                        f"{path!s}: content checksum mismatch on key "
                        f"{key!r} (stored 0x{stored:08x}, computed "
                        f"0x{computed:08x}) — bit-flipped or tampered "
                        "artifact"
                    )
            spec = sub.SubspaceSpec(
                d=int(z["spec_d"][()]),
                n_subspaces=int(z["spec_n_subspaces"][()]),
                perm=tuple(int(p) for p in z["spec_perm"]),
                bounds=tuple(int(b) for b in z["spec_bounds"]),
            )
            # "tombstone" is the one version-2 key; absent (every v1
            # artifact, and v2 saves of never-mutated indexes) means all
            # points are live.
            tombstone = (
                jnp.asarray(z["tombstone"].astype(bool))
                if "tombstone" in names
                else None
            )
            index = SuCoIndex(
                centroids1=jnp.asarray(z["centroids1"]),
                centroids2=jnp.asarray(z["centroids2"]),
                cell_ids=jnp.asarray(z["cell_ids"]),
                cell_counts=jnp.asarray(z["cell_counts"]),
                spec=spec,
                sqrt_k=int(z["sqrt_k"][()]),
                tombstone=tombstone,
            )
            config = None
            if "config_n_subspaces" in names:
                config = SuCoConfig(
                    n_subspaces=int(z["config_n_subspaces"][()]),
                    sqrt_k=int(z["config_sqrt_k"][()]),
                    kmeans_iters=int(z["config_kmeans_iters"][()]),
                    seed=int(z["config_seed"][()]),
                    build_mode=str(z["config_build_mode"][()]),
                    block_n=int(z["config_block_n"][()]),
                )
            extras: dict[str, np.ndarray] = {}
            if return_extras:
                extras = {
                    k[len(_ARTIFACT_EXTRA_PREFIX):]: z[k]
                    for k in names
                    if k.startswith(_ARTIFACT_EXTRA_PREFIX)
                }
        except ArtifactError:
            raise
        except Exception as e:
            # A member listed in the directory but truncated mid-payload
            # (zlib error, zipfile CRC failure, short read) surfaces here.
            raise ArtifactError(
                f"{path!s}: {_ARTIFACT_MAGIC} artifact payload is corrupt "
                f"({type(e).__name__}: {e}) — truncated file?"
            ) from e
    if return_extras:
        return index, config, extras
    return index, config


# --------------------------------------------------------------------------
# Dynamic Activation
# --------------------------------------------------------------------------


def _cell_ranks_and_cut(
    dists1: jax.Array, dists2: jax.Array, cell_counts: jax.Array, target: int
) -> tuple[jax.Array, jax.Array]:
    """Dynamic Activation as (per-cell rank, cutoff rank).

    ``rank[c]`` is cell c's position in ascending ``dists1+dists2`` order
    (ties by cell id — stable argsort) and ``cut`` the last rank inside
    the minimal prefix whose cumulative count reaches ``target``; the
    activation mask is ``rank <= cut``.  This split form feeds the chunked
    score kernel, which gathers ranks by cell id and compares to the cut.
    """
    cell_dist = (dists1[:, None] + dists2[None, :]).reshape(-1)  # (K,)
    order = jnp.argsort(cell_dist)  # stable -> ties by cell id
    csum = jnp.cumsum(jnp.take(cell_counts, order))
    # First prefix position reaching the target (or everything if impossible).
    reached = csum >= target
    cut = jnp.where(jnp.any(reached), jnp.argmax(reached), csum.shape[0] - 1)
    rank = jnp.zeros_like(order).at[order].set(jnp.arange(order.shape[0]))
    return rank.astype(jnp.int32), cut.astype(jnp.int32)


def activate_cells_sorted(
    dists1: jax.Array, dists2: jax.Array, cell_counts: jax.Array, target: int
) -> jax.Array:
    """TPU-native Dynamic Activation: exact sort-prefix equivalent of Alg. 3.

    ``dists1/dists2: (sqrtK,)``, ``cell_counts: (K,)`` (row-major over
    ``(c1, c2)``).  Returns a ``(K,)`` bool mask of activated cells: the
    minimal ascending-distance prefix whose cumulative count reaches
    ``target`` — exactly the Multi-sequence / Dynamic-Activation set.
    """
    rank, cut = _cell_ranks_and_cut(dists1, dists2, cell_counts, target)
    return rank <= cut


def dynamic_activation_lax(
    dists1: jax.Array, dists2: jax.Array, cell_counts: jax.Array, target: int
) -> jax.Array:
    """Faithful ``lax.while_loop`` port of paper Algorithm 3.

    Kept for fidelity/testing; the production path is
    :func:`activate_cells_sorted`.  Returns the same ``(K,)`` bool mask.
    """
    k1 = dists1.shape[0]
    k2 = dists2.shape[0]
    idx1 = jnp.argsort(dists1)
    idx2 = jnp.argsort(dists2)
    s1 = jnp.take(dists1, idx1)
    s2 = jnp.take(dists2, idx2)
    counts2d = cell_counts.reshape(k1, k2)

    inf = jnp.asarray(jnp.inf, dists1.dtype)
    state = (
        jnp.zeros(k1, jnp.int32),  # active_idx (column per row)
        jnp.full((k1,), inf).at[0].set(s1[0] + s2[0]),  # active_dists
        jnp.zeros(k1 * k2, bool),  # retrieved mask (over original cell ids)
        jnp.asarray(0, jnp.int32),  # retrieved_num
    )

    def cond(st):
        _, ad, _, got = st
        return jnp.logical_and(got < target, jnp.any(jnp.isfinite(ad)))

    def body(st):
        ai, ad, mask, got = st
        pos = jnp.argmin(ad)
        col = ai[pos]
        c1 = idx1[pos]
        c2 = idx2[col]
        mask = mask.at[c1 * k2 + c2].set(True)
        got = got + counts2d[c1, c2]
        # Activate next row iff this row was popped at column 0 (Alg.3 l.12).
        do_spawn = jnp.logical_and(col == 0, pos < k1 - 1)
        nxt = jnp.minimum(pos + 1, k1 - 1)
        ad = jnp.where(do_spawn, ad.at[nxt].set(s1[nxt] + s2[0]), ad)
        ai = jnp.where(do_spawn, ai.at[nxt].set(0), ai)
        # Advance this row (Alg.3 l.15-17) or retire it.
        can_adv = col < k2 - 1
        newcol = jnp.minimum(col + 1, k2 - 1)
        ad = ad.at[pos].set(jnp.where(can_adv, s1[pos] + s2[newcol], inf))
        ai = ai.at[pos].set(jnp.where(can_adv, newcol, col))
        return ai, ad, mask, got

    _, _, mask, _ = jax.lax.while_loop(cond, body, state)
    return mask


# --------------------------------------------------------------------------
# Query (Algorithm 4)
# --------------------------------------------------------------------------


def _centroid_dists(
    index: SuCoIndex, q: jax.Array, metric: Metric
) -> tuple[jax.Array, jax.Array]:
    """``q: (m, d)`` -> per-subspace query-to-centroid distances
    ``(Ns, m, sqrtK)`` for each half."""
    qp = sub.permute(index.spec, q)
    qh1, qh2 = sub.split_halves_padded(index.spec, qp)  # (Ns, m, h_max)
    # impl="rowwise": centroid distances must be invariant to batch padding
    # (they order the Dynamic-Activation prefix) so a SuCoEngine bucket
    # activates exactly the cells the unpadded batch would.
    d1 = jax.vmap(
        lambda qq, cc: pairwise_dist(qq, cc, metric, impl="rowwise")
    )(qh1, index.centroids1)
    d2 = jax.vmap(
        lambda qq, cc: pairwise_dist(qq, cc, metric, impl="rowwise")
    )(qh2, index.centroids2)
    return d1, d2


def suco_scores(
    index: SuCoIndex,
    q: jax.Array,
    count: int,
    metric: Metric = "l2",
) -> jax.Array:
    """``q: (m, d) -> (m, n)`` int32 SC-scores via the IMI (Alg. 4 l.3-12).

    Scans over subspaces; per subspace the per-point collision test is a
    rank-gather: point j collides iff its cell is inside the activated
    prefix.
    """
    d1, d2 = _centroid_dists(index, q, metric)  # (Ns, m, sqrtK)
    m = q.shape[0]
    n = index.n_points

    def per_subspace(acc, inp):
        d1_i, d2_i, cells_i, counts_i = inp  # (m,sK),(m,sK),(n,),(K,)

        def per_query(d1_q, d2_q):
            mask = activate_cells_sorted(d1_q, d2_q, counts_i, count)  # (K,)
            return jnp.take(mask, cells_i)  # (n,) bool

        collide = jax.vmap(per_query)(d1_i, d2_i)  # (m, n)
        return acc + collide.astype(jnp.int32), None

    init = jnp.zeros((m, n), jnp.int32)
    scores, _ = jax.lax.scan(
        init=init,
        xs=(d1, d2, index.cell_ids, index.cell_counts),
        f=per_subspace,
    )
    return scores


def suco_cell_ranks(
    index: SuCoIndex, q: jax.Array, count: int, metric: Metric = "l2"
) -> tuple[jax.Array, jax.Array]:
    """Per-(subspace, query) Dynamic-Activation state for chunked scoring.

    ``q: (m, d) -> (ranks (Ns, m, K) int32, cuts (Ns, m) int32)`` — the
    split form of :func:`activate_cells_sorted` (mask == rank <= cut).
    O(Ns * m * K) memory, independent of n.
    """
    d1, d2 = _centroid_dists(index, q, metric)  # (Ns, m, sqrtK)

    def per_sub(d1_i, d2_i, counts_i):
        return jax.vmap(
            lambda a, b: _cell_ranks_and_cut(a, b, counts_i, count)
        )(d1_i, d2_i)

    return jax.vmap(per_sub)(d1, d2, index.cell_counts)


def _pool_size(n: int, k: int, beta: float) -> int:
    """Candidate-pool size — the shared clamped form
    (:func:`repro.core.sc_linear.candidate_pool_size`)."""
    return candidate_pool_size(n, k, beta)


@functools.partial(
    jax.jit,
    static_argnames=(
        "k", "alpha", "beta", "metric", "block_n", "score_impl", "merge_impl"
    ),
)
def suco_query_streaming(
    x: jax.Array,
    index: SuCoIndex,
    q: jax.Array,
    *,
    k: int,
    alpha: float,
    beta: float,
    metric: Metric = "l2",
    block_n: int = 4096,
    score_impl: str = "auto",
    merge_impl: str = "auto",
) -> QueryResult:
    """Algorithm 4 as a tiled streaming engine — bit-identical to the dense
    path, peak query memory O(m*(block_n + n_candidates)).

    A ``lax.scan`` over ceil(n / block_n) data chunks: per chunk the
    collision counts come from the chunked SC-score kernel path
    (:func:`sc_scores_cells`), and a carried per-query top pool is merged
    under the (score desc, id asc) order — ``merge_impl`` picks the merge
    algorithm (:func:`repro.core.sc_linear.merge_topk_pool`; "auto"
    resolves to the counting-select over the integer ``0..Ns`` score
    range, bit-identical to ``top_k``).  After the scan the pool equals
    the dense ``top_k(scores, n_candidates)`` selection exactly (sentinels
    at score -1 / id INT32_MAX lose to every real point), so the exact
    re-rank returns the same ids/distances as :func:`suco_query`.
    """
    if block_n < 1:
        raise ValueError(f"block_n must be >= 1, got {block_n}")
    n = x.shape[0]
    if k > n:
        # the dense path raises from top_k here; without this the pool would
        # keep (score -1, id INT32_MAX) sentinels and leak them into ids.
        raise ValueError(f"k={k} must be <= n={n}")
    m = q.shape[0]
    c = sub.collision_count(n, alpha)
    ranks, cuts = suco_cell_ranks(index, q, c, metric)  # (Ns,m,K), (Ns,m)
    pool = _pool_size(n, k, beta)

    bn = min(block_n, n)
    n_blocks = -(-n // bn)
    int_max = jnp.iinfo(jnp.int32).max
    cells = jnp.pad(index.cell_ids, ((0, 0), (0, n_blocks * bn - n)))
    cells = cells.reshape(cells.shape[0], n_blocks, bn).transpose(1, 0, 2)
    # Tombstones ride the scan as a per-chunk keep mask; an index without
    # them (tombstone=None — a zero-leaf pytree entry) scans the identical
    # immutable graph.
    keep_blocks = None
    if index.tombstone is not None:
        keepp = jnp.pad(
            jnp.logical_not(index.tombstone), (0, n_blocks * bn - n)
        )
        keep_blocks = keepp.reshape(n_blocks, bn)

    def step(carry, inp):
        pool_s, pool_i = carry
        blk, cells_b, keep_b = inp  # (), (Ns, bn), (bn,) | None
        s = sc_scores_cells(ranks, cuts, cells_b, impl=score_impl)  # (m, bn)
        gids = blk * bn + jnp.arange(bn, dtype=jnp.int32)
        valid = gids < n  # mask chunk padding past the end of the data
        if keep_b is not None:
            valid = jnp.logical_and(valid, keep_b)  # and tombstoned slots
        s = jnp.where(valid[None, :], s, -1)
        ids_b = jnp.broadcast_to(jnp.where(valid, gids, int_max), (m, bn))
        merged = merge_topk_pool(
            pool_s, pool_i, s, ids_b,
            impl=merge_impl, smax=index.spec.n_subspaces,
        )
        return merged, None

    init = (
        jnp.full((m, pool), -1, jnp.int32),
        jnp.full((m, pool), int_max, jnp.int32),
    )
    (pool_s, pool_i), _ = jax.lax.scan(
        step, init, (jnp.arange(n_blocks, dtype=jnp.int32), cells, keep_blocks)
    )
    return rerank_candidates(x, q, pool_i, pool_s, k, metric)


@functools.partial(
    jax.jit,
    static_argnames=(
        "k", "alpha", "beta", "metric", "tiles", "score_impl", "merge_impl"
    ),
)
def suco_query_fused(
    x: jax.Array,
    index: SuCoIndex,
    q: jax.Array,
    *,
    k: int,
    alpha: float,
    beta: float,
    metric: Metric = "l2",
    tiles: TileConfig | None = None,
    score_impl: str = "auto",
    merge_impl: str = "auto",
) -> QueryResult:
    """Algorithm 4 as a **single-pass fused engine**: score -> prune ->
    merge -> rerank in one scan over the data, bit-identical to the dense
    path.

    Per ``block_n``-point chunk, while the chunk is resident:

    1. **score + prune, one launch** — the fused chunk stage
       (:func:`repro.kernels.sc_score.ops.sc_scores_cells_prefilter_compact`)
       computes SC-scores, the Pareto prefilter, *and* the survivor
       compaction in a single kernel: only rows whose score beats the
       carried pool minimum can possibly enter the pool (pool entries
       with equal score always win the (score desc, id asc) tie-break,
       having strictly smaller ids under the streaming invariant), and
       the survivors come back already compacted into a
       ``survivor_cap``-wide buffer in ascending-id order — in-kernel
       cumsum + one-hot slot write while the score tile is resident, so
       no sort, scatter, or second pass ever touches the
       ``(m, block_n)`` block and the merge's lexicographic tie-break is
       preserved bit-for-bit.  (The jnp oracle — the production CPU
       path — runs the identical compaction as a binary search on the
       keep-mask's cumsum.)
    2. **rerank in-pass** — exact distances for the survivors — O(cap)
       rows of ``x`` per chunk, the rows just scored — are gathered by
       global id (:func:`repro.kernels.gather_rerank.ops.gather_rerank_block`,
       same fp reduction as :func:`repro.core.sc_linear.rerank_candidates`);
       ``x`` itself is never padded, copied, or streamed through the scan.
    3. **merge** — the joint ``(sc_score, exact_dist, id)`` pool merges at
       width ``pool + survivor_cap`` instead of ``pool + block_n``
       (:func:`repro.core.sc_linear.merge_topk_pool_with_dists`;
       ``merge_impl`` selects the algorithm, "auto" resolving to the
       counting-select over the integer ``0..Ns`` score range).

    A chunk whose survivor count exceeds ``survivor_cap`` for any query
    (cold pool on the first chunks, adversarial score ties) falls back via
    ``lax.cond`` to an exact ``top_k`` selection of the chunk's own best
    ``min(pool, block_n)`` rows (the merged pool can absorb at most
    ``pool`` of them, so this is bit-identical to merging the whole
    chunk) — slower, identical results, so the fast path's pruning can
    never change an answer.  After the scan the answer is one ``top_k``
    over the carried distances; the post-scan rerank gather over ``x`` of
    the legacy streaming path does not exist.

    ``tiles=None`` autotunes ``(block_n, bm, bn, survivor_cap)`` from the
    backend memory limits and ``(n, d, m, pool)``
    (:func:`repro.core.tuning.autotune_tiles`); pass an explicit
    :class:`~repro.core.tuning.TileConfig` to pin them.
    """
    n, d = x.shape
    if k > n:
        raise ValueError(f"k={k} must be <= n={n}")
    m = q.shape[0]
    pool = _pool_size(n, k, beta)
    if tiles is None:
        tiles = autotune_tiles(
            n, d, m, pool,
            n_subspaces=index.spec.n_subspaces,
            n_cells=index.n_cells,
            itemsize=x.dtype.itemsize,
        )
    c = sub.collision_count(n, alpha)
    ranks, cuts = suco_cell_ranks(index, q, c, metric)  # (Ns,m,K), (Ns,m)

    bn = min(tiles.block_n, n)
    cap = min(tiles.survivor_cap, bn)
    n_blocks = -(-n // bn)
    int_max = jnp.iinfo(jnp.int32).max
    cells = jnp.pad(index.cell_ids, ((0, 0), (0, n_blocks * bn - n)))
    cells = cells.reshape(cells.shape[0], n_blocks, bn).transpose(1, 0, 2)
    # Tombstones fold into the fused stage's existing keep-mask (the
    # Pareto prefilter) — no new kernel; tombstone=None traces the
    # identical immutable graph (None contributes no scan leaves).
    keep_blocks = None
    if index.tombstone is not None:
        keepp = jnp.pad(
            jnp.logical_not(index.tombstone), (0, n_blocks * bn - n)
        )
        keep_blocks = keepp.reshape(n_blocks, bn)
    dist_dtype = (
        jnp.float32 if metric == "l2" else jnp.result_type(x.dtype, q.dtype)
    )
    inf = jnp.asarray(jnp.inf, dist_dtype)
    cols = jnp.arange(bn, dtype=jnp.int32)
    slot = jnp.arange(cap, dtype=jnp.int32)

    def step(carry, inp):
        pool_s, pool_d, pool_i = carry
        blk, cells_b, keep_b = inp  # (), (Ns, bn), (bn,) | None
        thr = pool_s[:, -1]  # pool sorted desc -> last col is the minimum
        limit = jnp.minimum(n - blk * bn, bn)  # valid columns this chunk
        s, surv_c, surv_s, total = sc_scores_cells_prefilter_compact(
            ranks, cuts, cells_b, thr, limit, keep_b,
            cap=cap, bm=tiles.bm, bn=tiles.bn, impl=score_impl,
        )  # (m, bn), (m, cap), (m, cap), (m) — all int32, s pre-masked
        gids = blk * bn + cols
        col_ok = cols < limit
        if keep_b is not None:
            # Tombstoned columns must not enter the overflow fallback's
            # top_k either: sentinel ids make their distances +inf below.
            col_ok = jnp.logical_and(col_ok, keep_b)
        ids_b = jnp.broadcast_to(jnp.where(col_ok, gids, int_max), (m, bn))

        def pruned_merge(_):
            # The kernel already compacted the survivors into cap slots in
            # ascending-id order while the score tile was resident — the
            # host graph only rebuilds global ids from the chunk-local
            # columns and masks empty slots to the sentinels.  A slot is
            # live iff it is below the survivor count AND carries a real
            # (>= 0) score — the second clause is vacuous for immutable
            # indexes (survivors beat thr >= -1) and masks the Pallas
            # path's post-hoc tombstoned survivors under mutation.
            live = jnp.logical_and(
                slot[None, :] < total[:, None], surv_s >= 0
            )
            surv_i = jnp.where(live, blk * bn + surv_c, int_max)
            surv_sm = jnp.where(live, surv_s, -1)
            # survivors only ever touch O(cap) rows of x per chunk — the
            # rows just scored, fetched by global id (the op clips the
            # int_max sentinels; their distances are masked to +inf).
            # impl="jnp" pins the fp reduction to rerank_candidates'
            # rowwise contract on every backend; the Pallas gather kernel
            # stays opt-in until a real-TPU run proves it ulp-identical.
            dists = gather_rerank_block(surv_i, x, q, metric=metric, impl="jnp")
            dists = jnp.where(live, dists, inf)
            return merge_topk_pool_with_dists(
                pool_s, pool_d, pool_i, surv_sm, dists, surv_i,
                impl=merge_impl, smax=index.spec.n_subspaces,
            )

        def full_merge(_):
            # Exact overflow fallback: the merged top-pool can absorb at
            # most `pool` chunk rows, so selecting the chunk's own top
            # min(pool, bn) by (score desc, id asc) — lax.top_k's position
            # tie-break on ascending-id columns — before merging is
            # bit-identical to merging the whole chunk, at an O(bn)
            # selection instead of an O(pool + bn) one, with distances for
            # `pool` rows instead of `bn`.
            c = min(pool, bn)
            top_s, top_pos = jax.lax.top_k(s, c)
            top_i = jnp.take_along_axis(ids_b, top_pos, axis=-1)
            dists = gather_rerank_block(top_i, x, q, metric=metric, impl="jnp")
            dists = jnp.where(top_i == int_max, inf, dists)
            return merge_topk_pool_with_dists(
                pool_s, pool_d, pool_i, top_s, dists, top_i,
                impl=merge_impl, smax=index.spec.n_subspaces,
            )

        overflow = jnp.any(total > cap)
        return jax.lax.cond(overflow, full_merge, pruned_merge, None), None

    init = (
        jnp.full((m, pool), -1, jnp.int32),
        jnp.full((m, pool), inf, dist_dtype),
        jnp.full((m, pool), int_max, jnp.int32),
    )
    (pool_s, pool_d, pool_i), _ = jax.lax.scan(
        step, init, (jnp.arange(n_blocks, dtype=jnp.int32), cells, keep_blocks)
    )
    # Final selection == rerank_candidates' top_k on the carried pool:
    # ascending distance, ties to the earlier (score desc, id asc) slot.
    neg, pos = jax.lax.top_k(-pool_d, k)
    return QueryResult(
        jnp.take_along_axis(pool_i, pos, axis=-1).astype(jnp.int32),
        -neg,
        jnp.take_along_axis(pool_s, pos, axis=-1),
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "k", "alpha", "beta", "metric", "mode", "block_n", "score_impl",
        "tiles", "merge_impl",
    ),
)
def suco_query(
    x: jax.Array,
    index: SuCoIndex,
    q: jax.Array,
    *,
    k: int,
    alpha: float,
    beta: float,
    metric: Metric = "l2",
    mode: str = "auto",
    block_n: int = 4096,
    score_impl: str = "auto",
    tiles: TileConfig | None = None,
    merge_impl: str = "auto",
) -> QueryResult:
    """Algorithm 4: k-ANN for a batch ``q: (m, d)`` using the SuCo index.

    ``mode``: "dense" | "streaming" | "fused" | "auto" (fused iff
    n >= ``STREAMING_MIN_N``); all paths return bit-identical results —
    see the module docstring for the memory models.  ``score_impl``
    ("auto" | "jnp" | "pallas") overrides the chunked scorer's kernel
    dispatch (:func:`sc_scores_cells` / the fused prefilter stage); the
    dense path is jnp-only and ignores it.  ``block_n`` sizes the legacy
    streaming path's chunks; the fused path tiles itself from ``tiles``
    (``None`` = autotune, see :func:`repro.core.tuning.autotune_tiles`).
    ``merge_impl`` ("auto" | "topk" | "sort" | "counting") selects the
    pool-merge algorithm for the streaming/fused paths
    (:func:`repro.core.sc_linear.merge_topk_pool`); every impl is
    bit-identical, and "auto" resolves to the counting-select over the
    integer ``0..Ns`` score range.  The dense path ignores it.
    """
    n = x.shape[0]
    if mode not in ("auto", "dense", "streaming", "fused"):
        raise ValueError(f"unknown mode {mode!r}")
    if mode == "fused" or (mode == "auto" and n >= STREAMING_MIN_N):
        return suco_query_fused(
            x,
            index,
            q,
            k=k,
            alpha=alpha,
            beta=beta,
            metric=metric,
            tiles=tiles,
            score_impl=score_impl,
            merge_impl=merge_impl,
        )
    if mode == "streaming":
        return suco_query_streaming(
            x,
            index,
            q,
            k=k,
            alpha=alpha,
            beta=beta,
            metric=metric,
            block_n=block_n,
            score_impl=score_impl,
            merge_impl=merge_impl,
        )
    c = sub.collision_count(n, alpha)
    scores = suco_scores(index, q, c, metric)  # (m, n)
    if index.tombstone is not None:
        # Tombstoned points score -1 — below every live point, and
        # rerank_candidates masks negative-score slots to +inf distance,
        # so a deleted id can neither crowd out pool slots nor be returned.
        scores = jnp.where(index.tombstone[None, :], -1, scores)
    n_candidates = candidate_pool_size(n, k, beta)
    return rerank(x, q, scores, k, n_candidates, metric)


# --------------------------------------------------------------------------
# SuCoEngine: the persistent, batched serving subsystem
# --------------------------------------------------------------------------

# Padded batch-size buckets: every request batch is zero-padded up to the
# smallest bucket that fits, so the engine compiles one executable per
# (bucket, k) instead of one per observed batch size.
DEFAULT_BATCH_BUCKETS = (1, 2, 4, 8, 16, 32, 64)


def batch_bucket(m: int, buckets: Sequence[int] = DEFAULT_BATCH_BUCKETS) -> int:
    """The padded batch size serving ``m`` queries: the smallest configured
    bucket >= m, growing by powers of two above the largest bucket (so an
    oversized burst costs one extra executable, not a failure).  Shared by
    the local and sharded engines — one bucketing policy across the stack."""
    if m < 1:
        raise ValueError(f"batch size must be >= 1, got {m}")
    if not buckets:
        raise ValueError("buckets must be non-empty")
    for b in sorted(buckets):
        if m <= b:
            return int(b)
    b = int(max(buckets))
    while b < m:
        b *= 2
    return b


def padding_waste(
    histogram: Mapping[int, int], buckets: Sequence[int] = DEFAULT_BATCH_BUCKETS
) -> int:
    """Expected padded-row waste of serving ``histogram`` with ``buckets``.

    ``histogram`` maps observed micro-batch size -> occurrence count; every
    batch of size m is padded to :func:`batch_bucket`\\ ``(m, buckets)``, so
    the waste is ``sum(count * (bucket(m) - m))`` — the number of all-zero
    query rows the engine computes and throws away.
    """
    return sum(
        int(c) * (batch_bucket(int(m), buckets) - int(m))
        for m, c in histogram.items()
        if c
    )


def autoscale_buckets(
    histogram: Mapping[int, int],
    max_buckets: int = 8,
    *,
    fallback: Sequence[int] = DEFAULT_BATCH_BUCKETS,
) -> tuple[int, ...]:
    """Propose a batch-bucket set for an observed traffic histogram.

    Picks at most ``max_buckets`` bucket sizes minimising the expected
    padding waste (:func:`padding_waste`) of replaying the histogram, by
    exact dynamic programming over the distinct observed sizes: an optimal
    bucket boundary always coincides with some observed size (lowering a
    bucket to the largest size it serves never increases waste), so the
    search space is subsets of the observed sizes that contain the maximum
    — the proposal therefore always covers the observed max batch, and
    oversize bursts still fall through to ``batch_bucket``'s power-of-two
    overflow rule.  An empty histogram returns ``fallback`` unchanged.
    """
    if max_buckets < 1:
        raise ValueError(f"max_buckets must be >= 1, got {max_buckets}")
    hist = {int(m): int(c) for m, c in histogram.items() if int(c) > 0}
    if not hist:
        # An all-zero histogram (every count 0) degenerates to the empty
        # one: nothing observed, so the fallback buckets stand.  A server
        # configured with no fallback would otherwise propose an empty
        # bucket set and crash batch_bucket much later — fail here instead.
        fb = tuple(sorted(set(int(b) for b in fallback)))
        if not fb:
            raise ValueError(
                "autoscale_buckets: empty traffic histogram and empty "
                "fallback bucket set — configure at least one bucket"
            )
        return fb
    if min(hist) < 1:
        raise ValueError(f"batch sizes must be >= 1, got {sorted(hist)[0]}")
    sizes = sorted(hist)
    u = len(sizes)
    n_b = min(max_buckets, u)
    # prefix sums -> O(1) segment waste: serving sizes[i..j] with bucket
    # sizes[j] wastes sizes[j]*sum(cnt) - sum(cnt*size) over the segment.
    pc = [0] * (u + 1)
    pm = [0] * (u + 1)
    for i, s in enumerate(sizes):
        pc[i + 1] = pc[i] + hist[s]
        pm[i + 1] = pm[i] + hist[s] * s

    def seg(i: int, j: int) -> int:  # waste of sizes[i..j] under bucket sizes[j]
        return sizes[j] * (pc[j + 1] - pc[i]) - (pm[j + 1] - pm[i])

    inf = float("inf")
    dp = [[inf] * u for _ in range(n_b + 1)]
    parent: list[list[int]] = [[-1] * u for _ in range(n_b + 1)]
    for j in range(u):
        dp[1][j] = seg(0, j)
    for t in range(2, n_b + 1):
        for j in range(t - 1, u):
            for i in range(t - 2, j):
                c = dp[t - 1][i] + seg(i + 1, j)
                if c < dp[t][j]:
                    dp[t][j] = c
                    parent[t][j] = i
    best_t = min(range(1, n_b + 1), key=lambda t: (dp[t][u - 1], t))
    chosen = []
    t, j = best_t, u - 1
    while j >= 0 and t >= 1:
        chosen.append(sizes[j])
        j = parent[t][j]
        t -= 1
    return tuple(sorted(chosen))


@dataclasses.dataclass(frozen=True)
class EnginePolicy:
    """Query-serving policy owned by :class:`SuCoEngine`.

    What used to travel on every ``suco_query`` call (alpha/beta/metric,
    the execution mode, the scorer kernel impl, the chunk/tile sizes) is
    fixed once per engine; per-request inputs shrink to ``(queries, k)``.
    ``mode="auto"`` resolves against the dataset size a single time at
    engine construction — requests never re-decide it; the large-``n``
    resolution is the **fused** single-pass engine (the legacy chunked
    path stays reachable as ``mode="streaming"``).

    Tiling knobs:

    * ``block_n`` — the legacy streaming path's chunk size (ignored by
      dense and fused modes).
    * ``tiles`` — the fused path's :class:`~repro.core.tuning.TileConfig`
      (chunk size, kernel ``bm``/``bn`` grid tile, survivor-compaction
      width).  ``None`` (the default) autotunes per ``(bucket, k)``
      executable from the backend memory limits and the padded batch
      shape (:func:`repro.core.tuning.autotune_tiles`) — deterministic
      per shape, so warmed executables never retrace.

    The policy also accumulates a traffic histogram (``observe``, fed by
    every engine query) from which :meth:`autoscale_buckets` proposes a
    waste-minimising bucket set; the histogram is observational state, not
    configuration — it never participates in equality or hashing, is
    bounded at ``TRAFFIC_MAX_BINS`` distinct sizes (long-running servers
    must not grow an unbounded dict), and can be dropped wholesale with
    :meth:`reset_traffic`.
    """

    # Bound on distinct batch sizes the traffic histogram tracks; beyond
    # it the least-frequent (smallest on ties) bin is evicted, so the
    # histogram is approximate under adversarial traffic but its memory is
    # O(1) over a server's lifetime.
    TRAFFIC_MAX_BINS = 512

    alpha: float = 0.05
    beta: float = 0.02
    metric: Metric = "l2"
    mode: str = "auto"  # "auto" | "dense" | "streaming" | "fused"
    score_impl: str = "auto"  # chunked scorer kernel dispatch
    merge_impl: str = "auto"  # pool-merge algorithm (sc_linear.merge_topk_pool)
    block_n: int = 4096  # legacy streaming chunk size
    tiles: TileConfig | None = None  # fused-path tiling (None = autotune)
    batch_buckets: tuple[int, ...] = DEFAULT_BATCH_BUCKETS
    traffic: collections.Counter = dataclasses.field(
        default_factory=collections.Counter, init=False, repr=False, compare=False
    )

    def observe(self, batch_sizes: Iterable[int]) -> None:
        """Record observed micro-batch sizes into the traffic histogram.

        Bounded: once ``TRAFFIC_MAX_BINS`` distinct sizes are tracked, a
        new size evicts the least-frequent existing bin (smallest size on
        ties) instead of growing the dict — :meth:`autoscale_buckets`
        keeps seeing the traffic that matters while a long-running server
        with pathological size churn stays O(1)."""
        for m in batch_sizes:
            m = int(m)
            if m < 1:
                raise ValueError(f"batch size must be >= 1, got {m}")
            if (
                m not in self.traffic
                and len(self.traffic) >= self.TRAFFIC_MAX_BINS
            ):
                victim = min(self.traffic.items(), key=lambda kv: (kv[1], kv[0]))
                del self.traffic[victim[0]]
            self.traffic[m] += 1

    def reset_traffic(self) -> None:
        """Drop the accumulated traffic histogram (e.g. after consuming it
        through :meth:`autoscaled`, or on a traffic-shape change)."""
        self.traffic.clear()

    def autoscale_buckets(self, max_buckets: int | None = None) -> tuple[int, ...]:
        """Bucket-set proposal from the observed traffic
        (:func:`autoscale_buckets`); the configured buckets when nothing
        has been observed yet."""
        if max_buckets is None:
            max_buckets = max(len(self.batch_buckets), 1)
        return autoscale_buckets(
            self.traffic, max_buckets, fallback=self.batch_buckets
        )

    def autoscaled(self, max_buckets: int | None = None) -> "EnginePolicy":
        """A new policy serving the observed traffic with minimal padding
        waste (same alpha/beta/metric/mode).  The histogram is carried
        forward so a consumer can still warm exactly the observed sizes
        (``SuCoEngine.warmup(batch_sizes=None)``)."""
        new = dataclasses.replace(
            self, batch_buckets=self.autoscale_buckets(max_buckets)
        )
        new.traffic.update(self.traffic)
        return new

    def degraded(self, level: int) -> "EnginePolicy":
        """The reduced-budget policy at degradation-ladder step ``level``.

        Level 0 is this policy unchanged.  Each further level sheds work
        along the knobs the paper exposes (Section 5.3.3 tuning ranges):

        * ``beta`` halves per level — the candidate pool is the dominant
          rerank cost, and shrinking it is what honestly lowers the
          Theorem-2 floor (:func:`repro.core.theory.degraded_budget_bound`
          charges the pool-spill term ``alpha**Ns / beta``).
        * ``alpha`` shrinks mildly (x0.8 per level) — fewer activated
          cells per subspace, cheaper SC-scoring.
        * pinned ``tiles`` shrink ``survivor_cap`` with the pool (halved
          per level, floored at 64 and kept a 64-multiple per the
          tile-shape lint rule); autotuned tiles (``tiles=None``) need no
          edit — the autotuner re-derives the cap from the reduced pool.

        Deterministic in ``level`` and structural only (fresh traffic
        Counter via ``dataclasses.replace``), so a ladder of pre-warmed
        engines can be built once at server start and swapping levels
        never retraces.
        """
        if level < 0:
            raise ValueError(f"degradation level must be >= 0, got {level}")
        if level == 0:
            return self
        tiles = self.tiles
        if tiles is not None:
            cap = max(64, (tiles.survivor_cap >> level) // 64 * 64)
            tiles = dataclasses.replace(tiles, survivor_cap=cap)
        return dataclasses.replace(
            self,
            alpha=max(self.alpha * 0.8**level, 1e-6),
            beta=self.beta * 0.5**level,
            tiles=tiles,
        )


class EngineStats(NamedTuple):
    executables: int  # compiled (bucket, k) query executables (jit cache)
    batches: int  # query() calls served
    queries: int  # individual queries served (pre-padding)
    padded_queries: int  # wasted padding rows across all batches
    buckets: tuple[tuple[int, int], ...]  # (bucket, k) pairs seen


class SuCoEngine:
    """Owns the SuCo index lifecycle end to end: build-or-load, pre-compiled
    bucketed query executables, and batched serving.

    The engine pins ``(x, index, policy)`` for its lifetime and exposes
    ``query(q, k)``: the batch is zero-padded to a policy bucket
    (:func:`batch_bucket`) and dispatched to a jitted executable keyed by
    ``(bucket, k)`` — after :meth:`warmup` covers the live traffic mix, a
    request can never trigger a retrace (``compile_count`` stays flat).
    Padding is sound because every query path is per-row independent
    (vmapped scoring, per-row top-k/merge), so the first ``m`` rows of a
    padded batch are bit-identical to the unpadded computation — and to
    ``suco_query``, the back-compat wrapper over the same kernels.
    """

    def __init__(
        self,
        x: jax.Array,
        index: SuCoIndex,
        policy: EnginePolicy | None = None,
        *,
        capacity: int | None = None,
    ):
        self.x = jnp.asarray(x)
        self.index = index
        # None -> a fresh default policy per engine (policies carry a mutable
        # traffic histogram, so a shared module-level default would bleed
        # observations across engines).
        policy = EnginePolicy() if policy is None else policy
        self.policy = policy
        if self.x.shape[-1] != index.spec.d:
            raise ValueError(
                f"data dim {self.x.shape[-1]} != index spec d={index.spec.d}"
            )
        if self.x.shape[0] != index.n_points:
            raise ValueError(
                f"data rows {self.x.shape[0]} != index points {index.n_points}"
            )
        n0 = self.x.shape[0]
        if capacity is not None:
            # Mutable layout: pre-pad (x, index) to `capacity` slots so
            # inserts are in-place slot writes — shapes (and therefore the
            # warmed executables) never change.  Empty slots are tombstoned
            # (never scored, never returned) and uncounted in cell_counts.
            if capacity < n0:
                raise ValueError(
                    f"capacity={capacity} must be >= current n={n0}"
                )
            tomb = (
                jnp.zeros(n0, bool) if index.tombstone is None
                else index.tombstone
            )
            self.x = jnp.pad(self.x, ((0, capacity - n0), (0, 0)))
            self.index = dataclasses.replace(
                index,
                cell_ids=jnp.pad(
                    index.cell_ids, ((0, 0), (0, capacity - n0))
                ),
                tombstone=jnp.concatenate(
                    [tomb, jnp.ones(capacity - n0, bool)]
                ),
            )
        self._capacity = capacity
        self._next_slot = n0
        self._n_live = self.index.n_live  # host int, maintained on mutation
        self._insert_inertia = 0.0  # drift statistic: sum over inserts
        self._inserted = 0
        mode = policy.mode
        if mode == "auto":
            # fused is the streaming-scale default: same answers as the
            # legacy chunked path, one pass over the data.
            mode = "fused" if self.x.shape[0] >= STREAMING_MIN_N else "dense"
        if mode not in ("dense", "streaming", "fused"):
            raise ValueError(f"unknown engine mode {policy.mode!r}")
        self._mode = mode
        self._batches = 0
        self._queries = 0
        self._padded = 0
        self._buckets_seen: set[tuple[int, int]] = set()
        self._jit = jax.jit(self._raw_query, static_argnames=("k",))
        self._retired_jit = None  # predecessor executables parked by swap

    # ---- lifecycle -------------------------------------------------------

    @classmethod
    def build(
        cls,
        x: jax.Array,
        config: SuCoConfig = SuCoConfig(),
        *,
        spec: sub.SubspaceSpec | None = None,
        policy: EnginePolicy | None = None,
    ) -> "SuCoEngine":
        """Build the index (Algorithm 2) and wrap it in an engine."""
        x = jnp.asarray(x)
        return cls(x, build_index(x, config, spec=spec), policy)

    @classmethod
    def from_artifact(
        cls, path, x: jax.Array, policy: EnginePolicy | None = None
    ) -> "SuCoEngine":
        """Serve a persisted index (:meth:`SuCoIndex.save`) over ``x``."""
        index, _ = load_index_artifact(path)
        return cls(x, index, policy)

    def autoscaled(self, max_buckets: int | None = None) -> "SuCoEngine":
        """A new engine over the same ``(x, index)`` whose bucket set is the
        autoscale proposal for this engine's observed traffic
        (:meth:`EnginePolicy.autoscale_buckets`).  The new engine starts
        with an empty jit cache — re-run :meth:`warmup` (its no-argument
        form warms exactly the observed traffic) before serving."""
        return SuCoEngine(self.x, self.index, self.policy.autoscaled(max_buckets))

    def save(
        self,
        path,
        config: SuCoConfig | None = None,
        *,
        extras: Mapping[str, np.ndarray] | None = None,
    ) -> None:
        """Persist this engine's index artifact (see :meth:`SuCoIndex.save`)."""
        self.index.save(path, config, extras=extras)

    # ---- live mutation ---------------------------------------------------

    def _require_mutable(self, op: str) -> None:
        if self._capacity is None:
            raise ValueError(
                f"{op} needs a mutable engine — construct with "
                "capacity=<max points> (pre-padded slots keep the warmed "
                "executables' shapes fixed); this engine is immutable"
            )

    def insert(self, x_new: jax.Array) -> np.ndarray:
        """Insert ``x_new: (b, d)`` (or one ``(d,)`` point) into free slots.

        Assignment to the existing centroids reuses the chunked build pass
        (:func:`assign_points`); ``cell_ids``/``cell_counts`` and the
        tombstone mask update in place (functional ``.at[]`` writes on the
        same shapes — the warmed query executables never retrace).  Returns
        the assigned slot ids (stable: slots are never reused until a
        re-index).  Raises :class:`CapacityError` when the batch does not
        fit in the remaining capacity — the re-index trigger.
        """
        self._require_mutable("insert")
        x_new = jnp.asarray(x_new, self.x.dtype)
        if x_new.ndim == 1:
            x_new = x_new[None]
        if x_new.ndim != 2 or x_new.shape[-1] != self.index.spec.d:
            raise ValueError(
                f"points must be (b, {self.index.spec.d}), got {x_new.shape}"
            )
        b = x_new.shape[0]
        if self._next_slot + b > self._capacity:
            raise CapacityError(
                f"insert of {b} points exceeds capacity "
                f"{self._capacity} (next free slot {self._next_slot}) — "
                "re-index onto a larger successor engine"
            )
        cells, counts_delta, inertia = assign_points(
            x_new, self.index.centroids1, self.index.centroids2,
            spec=self.index.spec, sqrt_k=self.index.sqrt_k,
            block_n=self.policy.block_n,
        )
        slots = np.arange(self._next_slot, self._next_slot + b)
        sl = jnp.asarray(slots, jnp.int32)
        self.index = dataclasses.replace(
            self.index,
            cell_ids=self.index.cell_ids.at[:, sl].set(cells),
            cell_counts=self.index.cell_counts + counts_delta,
            tombstone=self.index.tombstone.at[sl].set(False),
        )
        self.x = self.x.at[sl].set(x_new)
        self._next_slot += b
        self._n_live += b
        self._insert_inertia += float(inertia)
        self._inserted += b
        return slots

    def delete(self, ids) -> int:
        """Tombstone the given slot ids; returns how many were newly dead.

        Delegates to :meth:`SuCoIndex.delete` (idempotent, occupancy-
        correcting) and rebinds the same-shape result — zero retrace.
        """
        self._require_mutable("delete")
        before = self.index
        self.index = before.delete(ids)
        newly = int(jnp.sum(before.tombstone != self.index.tombstone))
        self._n_live -= newly
        return newly

    def swap(self, successor: "SuCoEngine") -> None:
        """Atomically become ``successor`` — the warm re-index handoff.

        The successor must already be warmed over at least this engine's
        seen ``(bucket, k)`` set (build it, :meth:`warmup` it, then swap):
        the whole point is that no request ever waits on a compile or is
        dropped across the handoff.  Adoption rebinds every serving field
        in place, so callers holding this engine object — servers, ladders
        — cut over atomically; in-flight results computed on the old
        executables stay valid (their device buffers are unaffected).
        """
        if successor is self:
            return
        missing = self._buckets_seen - successor._buckets_seen
        if missing:
            raise ValueError(
                "swap target is not warmed over the live traffic mix — "
                f"missing (bucket, k) executables {sorted(missing)}; "
                "run successor.warmup(...) over the seen mix first"
            )
        self.x = successor.x
        self.index = successor.index
        self.policy = successor.policy
        self._mode = successor._mode
        # Dropping the last reference to the old jitted dispatcher tears
        # down its compiled executables synchronously (tens of ms) — done
        # inline that teardown WOULD be the swap pause.  Park it instead;
        # release_retired() frees it off the serving path.
        self._retired_jit = self._jit
        self._jit = successor._jit
        self._capacity = successor._capacity
        self._next_slot = successor._next_slot
        self._n_live = successor._n_live
        self._insert_inertia = successor._insert_inertia
        self._inserted = successor._inserted
        self._buckets_seen = set(
            self._buckets_seen | successor._buckets_seen
        )

    def release_retired(self) -> None:
        """Free the predecessor executables a :meth:`swap` parked.

        Compiled-executable teardown is synchronous and slow relative to a
        query step, so ``swap`` defers it; call this from a maintenance
        point (between steps, after the handoff settles) to reclaim the
        memory without the teardown ever appearing inside the cutover."""
        self._retired_jit = None

    def _rebind(
        self, x: jax.Array, index: SuCoIndex, *, n_live: int, next_slot: int
    ) -> None:
        """Adopt mutated ``(x, index)`` in place — same shapes and treedef
        as the current ones, so the warmed executables keep hitting.  The
        propagation hook for sibling engines (degradation-ladder levels)
        that share this engine's data."""
        self.x = x
        self.index = index
        self._n_live = n_live
        self._next_slot = next_slot

    # ---- query -----------------------------------------------------------

    def _raw_query(self, x: jax.Array, index: SuCoIndex, q: jax.Array, *, k: int):
        # one implementation, two entry points: routing through suco_query
        # keeps the wrapper's bit-identical contract true by construction
        p = self.policy
        return suco_query(
            x, index, q, k=k, alpha=p.alpha, beta=p.beta, metric=p.metric,
            mode=self._mode, block_n=p.block_n, score_impl=p.score_impl,
            tiles=p.tiles, merge_impl=p.merge_impl,
        )

    def tiles_for(self, m: int, k: int) -> TileConfig | None:
        """The fused-path tiling an ``(m, k)`` request resolves to: the
        policy's pinned :class:`~repro.core.tuning.TileConfig`, or the
        autotune result for the request's padded bucket (exactly what the
        dispatched executable uses — deterministic per ``(bucket, k)``, so
        inspecting it never perturbs the jit cache).  ``None`` for
        non-fused engines with no pinned tiles."""
        if self.policy.tiles is not None or self._mode != "fused":
            return self.policy.tiles
        b = batch_bucket(m, self.policy.batch_buckets)
        n, d = self.x.shape
        return autotune_tiles(
            n, d, b, _pool_size(n, k, self.policy.beta),
            n_subspaces=self.index.spec.n_subspaces,
            n_cells=self.index.n_cells,
            itemsize=self.x.dtype.itemsize,
        )

    def query(self, q: jax.Array, k: int) -> QueryResult:
        """Serve a batch ``q: (m, d)`` (or a single ``(d,)`` query) -> top-k.

        Pads to the policy bucket, dispatches the ``(bucket, k)``
        executable, slices the padding back off.  Results are bit-identical
        to ``suco_query`` on the unpadded batch.
        """
        q = jnp.asarray(q)
        single = q.ndim == 1
        if single:
            q = q[None]
        if q.ndim != 2 or q.shape[-1] != self.index.spec.d:
            raise ValueError(
                f"queries must be (m, {self.index.spec.d}) or "
                f"({self.index.spec.d},), got {q.shape}"
            )
        if not 1 <= k <= self.n_live:
            # k is bounded by the LIVE count: with tombstones, asking for
            # more neighbours than live points would leak sentinel ids.
            raise ValueError(f"k={k} must be in [1, n={self.n_live}]")
        m = q.shape[0]
        b = batch_bucket(m, self.policy.batch_buckets)
        if b != m:
            q = jnp.pad(q, ((0, b - m), (0, 0)))
        res = self._jit(self.x, self.index, q, k=k)
        self._batches += 1
        self._queries += m
        self._padded += b - m
        self._buckets_seen.add((b, k))
        self.policy.observe((m,))  # feed the autoscaler's traffic histogram
        if single:
            return QueryResult(res.ids[0], res.dists[0], res.scores[0])
        if b != m:
            res = QueryResult(res.ids[:m], res.dists[:m], res.scores[:m])
        return res

    def warmup(
        self,
        batch_sizes: Sequence[int] | None = (1,),
        ks: Sequence[int] = (10,),
    ) -> int:
        """Pre-compile one executable per (bucket, k) covering the given
        traffic mix; returns the number of fresh compiles.  After a warmup
        that covers the live mix, ``compile_count`` stays flat forever.

        ``batch_sizes=None`` warms the *observed* traffic: the sizes in the
        policy's accumulated histogram (falling back to ``(1,)`` when no
        traffic has been recorded) — the consumption path for
        :meth:`autoscaled` engines, whose bucket set was proposed from the
        same histogram."""
        if batch_sizes is None:
            batch_sizes = tuple(sorted(self.policy.traffic)) or (1,)
        before = self.compile_count
        d = self.index.spec.d
        for b in sorted({batch_bucket(m, self.policy.batch_buckets)
                         for m in batch_sizes}):
            for k in sorted(set(ks)):
                probe = jnp.zeros((b, d), self.x.dtype)
                jax.block_until_ready(self._jit(self.x, self.index, probe, k=k).ids)
                self._buckets_seen.add((b, k))
        return self.compile_count - before

    # ---- introspection ---------------------------------------------------

    @property
    def mode(self) -> str:
        """The resolved execution mode ("dense" | "streaming" | "fused" —
        the last is what ``mode="auto"`` resolves to at streaming scale)."""
        return self._mode

    @property
    def n_points(self) -> int:
        return self.x.shape[0]

    @property
    def n_live(self) -> int:
        """Live (non-tombstoned, non-empty-slot) point count — the honest
        ``n`` for k-validation and quality bounds under mutation."""
        return self._n_live

    @property
    def capacity(self) -> int | None:
        """Total slots of a mutable engine (``None`` = immutable)."""
        return self._capacity

    @property
    def free_slots(self) -> int:
        """Remaining insert slots (0 for immutable engines)."""
        if self._capacity is None:
            return 0
        return self._capacity - self._next_slot

    @property
    def insert_inertia_per_point(self) -> float:
        """Mean assignment inertia over all points inserted so far — the
        drift monitor's statistic (rising vs. the build-time baseline
        means the centroids no longer describe the incoming data)."""
        if not self._inserted:
            return 0.0
        return self._insert_inertia / self._inserted

    @property
    def compile_count(self) -> int:
        """Number of compiled query executables (the jit cache size) — the
        serving invariant is that this is flat after warmup."""
        return self._jit._cache_size()

    def stats(self) -> EngineStats:
        return EngineStats(
            executables=self.compile_count,
            batches=self._batches,
            queries=self._queries,
            padded_queries=self._padded,
            buckets=tuple(sorted(self._buckets_seen)),
        )


# --------------------------------------------------------------------------
# jaxlint registry hook (see repro.analysis)
# --------------------------------------------------------------------------

# Canonical lint shapes: large enough that the bounded-intermediate budgets
# separate the streaming/fused paths (peak independent of n) from the dense
# reference (peak >= m*n elements) — the same separation the jaxpr memory
# tests assert — and small enough that the one-time index build behind the
# query entries traces in seconds on CPU.
#: Shapes for the jaxlint traces.  ``n`` must be comfortably larger than
#: ``n_subspaces * block_n`` so the streamed peaks (O(m * ns * block_n),
#: constant in n) separate cleanly from the dense (m, n) line.
LINT_QUERY_SHAPES: Mapping[str, int | float] = {
    "n": 60_000,
    "d": 32,
    "m": 32,
    "k": 10,
    "block_n": 2_048,
    "alpha": 0.05,
    "beta": 0.02,
    "n_subspaces": 8,
    "sqrt_k": 16,
}
LINT_BUILD_SHAPES: Mapping[str, int] = {
    "n": 20_000,
    "d": 16,
    "n_subspaces": 4,
    "sqrt_k": 32,
    "block_n": 512,
}


@functools.lru_cache(maxsize=1)
def _lint_problem():
    s = LINT_QUERY_SHAPES
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((s["n"], s["d"])).astype(np.float32))
    q = jnp.asarray(rng.standard_normal((s["m"], s["d"])).astype(np.float32))
    cfg = SuCoConfig(
        n_subspaces=s["n_subspaces"], sqrt_k=s["sqrt_k"], kmeans_iters=2, seed=0
    )
    return x, q, build_index(x, cfg), cfg


def lint_query_budget_bytes(block_n: int, m: int | None = None) -> int:
    """bounded-intermediate budget for a streamed query at the lint shapes:
    the streaming memory claim O(m*(block_n + pool)) plus the index-scale
    terms every path carries (ranks, blocked cell ids, rerank gather)."""
    s = LINT_QUERY_SHAPES
    n, d, k = s["n"], s["d"], s["k"]
    m = s["m"] if m is None else m
    ns = s["n_subspaces"]
    cells = s["sqrt_k"] ** 2
    pool = max(k, int(s["beta"] * n))
    n_pad = -(-n // block_n) * block_n
    elems = max(
        2 * m * (block_n + pool),  # score block + carried pool (merge concat)
        ns * m * block_n,  # per-chunk per-subspace collision gather
        m * pool * d,  # rerank candidate gather
        ns * n_pad,  # the index's cell-id array, reshaped into blocks
        ns * m * cells,  # Dynamic-Activation ranks
    )
    return 4 * elems  # every array in the query stack is 4-byte


def lint_dense_peak_bytes() -> int:
    """The dense reference provably materialises an (m, n) score array; the
    migrated memory tests use this as the separation line."""
    return 4 * LINT_QUERY_SHAPES["m"] * LINT_QUERY_SHAPES["n"]


def _lint_build_budget_bytes() -> int:
    s = LINT_BUILD_SHAPES
    n, d, ns, sqrt_k, bn = (
        s["n"], s["d"], s["n_subspaces"], s["sqrt_k"], s["block_n"],
    )
    h_max = (d // ns + 1) // 2
    n_pad = -(-n // bn) * bn
    codebooks = 2 * ns
    elems = max(
        codebooks * n_pad * h_max,  # the blocked data views (O(n*d))
        n * d,  # the permuted input itself
        2 * codebooks * bn * max(sqrt_k, h_max),  # per-chunk dist + one-hot
        ns * sqrt_k * sqrt_k,  # cell_counts
    )
    return 4 * elems


def jaxlint_entries():
    """Registry hook: the serving entry points and their invariants."""
    from repro.analysis.registry import JaxprEntry

    s = LINT_QUERY_SHAPES
    k, alpha, beta = s["k"], s["alpha"], s["beta"]
    scan_rules = ("no-scatter-in-scan", "bounded-intermediate", "pinned-accumulator")

    def make_streaming():
        x, q, index, _ = _lint_problem()
        return jax.make_jaxpr(
            lambda xx, qq: suco_query_streaming(
                xx, index, qq, k=k, alpha=alpha, beta=beta, block_n=s["block_n"]
            )
        )(x, q)

    # Lint tiles are pinned to the *static* memory model: the measured
    # limits vary per host, and the lint gate must prove the identical
    # canonical shapes (and bounded-intermediate budgets) everywhere.
    from repro.core.tuning import static_backend_limits

    lint_limits = static_backend_limits()

    def _fused_tiles(m: int) -> TileConfig:
        pool = max(k, int(beta * s["n"]))
        return autotune_tiles(
            s["n"], s["d"], m, pool,
            n_subspaces=s["n_subspaces"], n_cells=s["sqrt_k"] ** 2,
            limits=lint_limits,
        )

    def make_fused():
        x, q, index, _ = _lint_problem()
        return jax.make_jaxpr(
            lambda xx, qq: suco_query_fused(
                xx, index, qq, k=k, alpha=alpha, beta=beta,
                tiles=_fused_tiles(s["m"]),
            )
        )(x, q)

    def make_fused_tombstoned():
        # The live-mutation variant of the fused entry: a ~10% tombstone
        # mask threads through the prefilter keep-mask (docs/index_mutation.md).
        # Same scan rules and budget — the extra arrays (one bool per point,
        # one per chunk column) are smaller than every budgeted term.
        x, q, index, _ = _lint_problem()
        rng = np.random.default_rng(7)
        tomb = jnp.asarray(rng.random(s["n"]) < 0.1)
        tindex = dataclasses.replace(index, tombstone=tomb)
        return jax.make_jaxpr(
            lambda xx, qq: suco_query_fused(
                xx, tindex, qq, k=k, alpha=alpha, beta=beta,
                tiles=_fused_tiles(s["m"]),
            )
        )(x, q)

    def make_dense():
        x, q, index, _ = _lint_problem()
        return jax.make_jaxpr(
            lambda xx, qq: suco_query(
                xx, index, qq, k=k, alpha=alpha, beta=beta, mode="dense"
            )
        )(x, q)

    def make_engine_bucket():
        x, q, index, _ = _lint_problem()
        engine = SuCoEngine(
            x, index,
            EnginePolicy(mode="fused", tiles=_fused_tiles(batch_bucket(5))),
        )
        qb = q[: batch_bucket(5)]  # one warmed (bucket=8, k) executable
        return jax.make_jaxpr(functools.partial(engine._raw_query, k=k))(
            engine.x, engine.index, qb
        )

    def _degraded_tiles(m: int) -> TileConfig:
        p = EnginePolicy(mode="fused").degraded(1)
        pool = max(k, int(p.beta * s["n"]))
        return autotune_tiles(
            s["n"], s["d"], m, pool,
            n_subspaces=s["n_subspaces"], n_cells=s["sqrt_k"] ** 2,
            limits=lint_limits,
        )

    def make_engine_degraded_bucket():
        # The degradation ladder's level-1 engine: same entry point, reduced
        # (alpha, beta) budget.  Proving the same scan/memory invariants
        # here keeps the ladder inside docs/invariants.md — degrading under
        # load must never regress the streaming guarantees.
        x, q, index, _ = _lint_problem()
        engine = SuCoEngine(x, index, EnginePolicy(mode="fused").degraded(1))
        qb = q[: batch_bucket(5)]
        return jax.make_jaxpr(functools.partial(engine._raw_query, k=k))(
            engine.x, engine.index, qb
        )

    def make_build_chunked():
        b = LINT_BUILD_SHAPES
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.standard_normal((b["n"], b["d"])).astype(np.float32))
        cfg = SuCoConfig(
            n_subspaces=b["n_subspaces"], sqrt_k=b["sqrt_k"], kmeans_iters=2,
            seed=0, build_mode="chunked", block_n=b["block_n"],
        )
        return jax.make_jaxpr(lambda xx: build_index(xx, cfg).cell_ids)(x)

    b = LINT_BUILD_SHAPES
    return [
        JaxprEntry(
            name="suco.query_streaming",
            make=make_streaming,
            rules=scan_rules,
            budget_bytes=lint_query_budget_bytes(s["block_n"]),
            note="legacy chunked query: scan over block_n-point chunks",
        ),
        JaxprEntry(
            name="suco.query_fused",
            make=make_fused,
            rules=scan_rules,
            budget_bytes=lint_query_budget_bytes(_fused_tiles(s["m"]).block_n),
            note="single-pass fused query: score/prune/merge/rerank per chunk",
        ),
        JaxprEntry(
            name="suco.query_fused_tombstoned",
            make=make_fused_tombstoned,
            rules=scan_rules,
            budget_bytes=lint_query_budget_bytes(_fused_tiles(s["m"]).block_n),
            note=(
                "fused query over a tombstoned (live-mutation) index: the "
                "delete mask folds into the prefilter keep-mask — same "
                "scan, same memory budget, no new kernel"
            ),
        ),
        JaxprEntry(
            name="suco.query_dense",
            make=make_dense,
            rules=("bounded-intermediate", "pinned-accumulator"),
            budget_bytes=4 * 2 * s["m"] * s["n"] * s["n_subspaces"],
            note=(
                "dense reference path; materialises (m, n) and sorts inside "
                "its subspace scan by design, so no-scatter-in-scan is "
                "intentionally not declared"
            ),
        ),
        JaxprEntry(
            name="suco.engine_fused_bucket",
            make=make_engine_bucket,
            rules=scan_rules,
            budget_bytes=lint_query_budget_bytes(
                _fused_tiles(batch_bucket(5)).block_n
            ),
            note="one SuCoEngine per-(bucket, k) executable, fused mode",
        ),
        JaxprEntry(
            name="suco.engine_degraded_bucket",
            make=make_engine_degraded_bucket,
            rules=scan_rules,
            # The full-budget bound also covers the reduced pool: shrinking
            # beta only shrinks the carried pool and rerank gather.
            budget_bytes=lint_query_budget_bytes(
                _degraded_tiles(batch_bucket(5)).block_n
            ),
            note=(
                "degradation-ladder level-1 executable "
                "(EnginePolicy.degraded): reduced (alpha, beta) budget, "
                "same fused path and invariants"
            ),
        ),
        JaxprEntry(
            name="suco.build_chunked",
            make=make_build_chunked,
            rules=scan_rules,
            budget_bytes=_lint_build_budget_bytes(),
            # The chunked build's scan legitimately scatters into small
            # codebook-sized carries (the fused IMI histogram, the k-means++
            # seed updates); data-sized scatters stay forbidden.
            scatter_budget_elems=2 * b["n_subspaces"] * b["sqrt_k"] ** 2,
            note="chunked index build: every k-means pass streams the data",
        ),
    ]
