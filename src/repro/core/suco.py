"""SuCo (paper Algorithms 2-4): clustering-based lightweight index + query.

Index (Alg. 2): per subspace, split dims in two halves; K-means with sqrt(K)
centroids per half; IMI = the sqrt(K) x sqrt(K) Cartesian grid.  TPU-adapted
layout (DESIGN.md §3): instead of ragged inverted lists we store

* ``cell_ids   (Ns, n) int32`` — which IMI cell each point falls in,
* ``cell_counts (Ns, K) int32`` — points per cell,

which makes collision counting a dense gather+compare instead of pointer
chasing.

Query (Algs. 3-4): the Dynamic Activation traversal is replaced by its exact
sort-prefix equivalent :func:`activate_cells_sorted` (K <= 4096 cells: one
sort + one cumsum), property-tested against the sequential forms in
:mod:`repro.core.da_numpy`.  A faithful ``lax.while_loop`` port of Algorithm
3 is kept in :func:`dynamic_activation_lax`.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import subspace as sub
from repro.core.distances import Metric, pairwise_dist
from repro.core.kmeans import kmeans_batched
from repro.core.sc_linear import QueryResult, rerank

__all__ = [
    "SuCoConfig",
    "SuCoIndex",
    "build_index",
    "activate_cells_sorted",
    "dynamic_activation_lax",
    "suco_scores",
    "suco_query",
]


@dataclasses.dataclass(frozen=True)
class SuCoConfig:
    """Static SuCo hyper-parameters (paper defaults: K=50^2, Ns=8, t=20)."""

    n_subspaces: int = 8
    sqrt_k: int = 50
    kmeans_iters: int = 20
    seed: int = 0

    @property
    def n_cells(self) -> int:
        return self.sqrt_k * self.sqrt_k


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SuCoIndex:
    """The SuCo index: centroid codebooks + dense IMI occupancy arrays."""

    centroids1: jax.Array  # (Ns, sqrtK, h_max)
    centroids2: jax.Array  # (Ns, sqrtK, h_max)
    cell_ids: jax.Array  # (Ns, n) int32
    cell_counts: jax.Array  # (Ns, K) int32
    spec: sub.SubspaceSpec = dataclasses.field(metadata=dict(static=True))
    sqrt_k: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_cells(self) -> int:
        return self.sqrt_k * self.sqrt_k

    @property
    def n_points(self) -> int:
        return self.cell_ids.shape[1]

    def memory_bytes(self) -> int:
        """Index footprint (the paper's `O(sqrt(K) d + n Ns)` claim)."""
        return sum(
            a.size * a.dtype.itemsize
            for a in (self.centroids1, self.centroids2, self.cell_ids, self.cell_counts)
        )


@functools.partial(jax.jit, static_argnames=("spec", "sqrt_k", "iters"))
def _build(x: jax.Array, key: jax.Array, *, spec, sqrt_k: int, iters: int):
    ns = spec.n_subspaces
    xp = sub.permute(spec, x)
    h1, h2 = sub.split_halves_padded(spec, xp)  # 2 x (Ns, n, h_max)
    both = jnp.concatenate([h1, h2], axis=0)  # (2Ns, n, h_max)
    res = kmeans_batched(key, both, sqrt_k, iters)
    a1, a2 = res.assignments[:ns], res.assignments[ns:]
    cell_ids = (a1 * sqrt_k + a2).astype(jnp.int32)  # (Ns, n)
    counts = jax.vmap(
        lambda c: jnp.bincount(c, length=sqrt_k * sqrt_k).astype(jnp.int32)
    )(cell_ids)
    return res.centroids[:ns], res.centroids[ns:], cell_ids, counts


def build_index(x: jax.Array, config: SuCoConfig, *, spec: sub.SubspaceSpec | None = None) -> SuCoIndex:
    """Algorithm 2.  ``x: (n, d)``; deterministic given ``config.seed``."""
    if spec is None:
        spec = sub.contiguous_spec(x.shape[-1], config.n_subspaces)
    key = jax.random.key(config.seed)
    c1, c2, cell_ids, counts = _build(
        x, key, spec=spec, sqrt_k=config.sqrt_k, iters=config.kmeans_iters
    )
    return SuCoIndex(c1, c2, cell_ids, counts, spec=spec, sqrt_k=config.sqrt_k)


# --------------------------------------------------------------------------
# Dynamic Activation
# --------------------------------------------------------------------------


def activate_cells_sorted(
    dists1: jax.Array, dists2: jax.Array, cell_counts: jax.Array, target: int
) -> jax.Array:
    """TPU-native Dynamic Activation: exact sort-prefix equivalent of Alg. 3.

    ``dists1/dists2: (sqrtK,)``, ``cell_counts: (K,)`` (row-major over
    ``(c1, c2)``).  Returns a ``(K,)`` bool mask of activated cells: the
    minimal ascending-distance prefix whose cumulative count reaches
    ``target`` — exactly the Multi-sequence / Dynamic-Activation set.
    """
    k1 = dists1.shape[0]
    cell_dist = (dists1[:, None] + dists2[None, :]).reshape(-1)  # (K,)
    order = jnp.argsort(cell_dist)  # stable -> ties by cell id
    csum = jnp.cumsum(jnp.take(cell_counts, order))
    # First prefix position reaching the target (or everything if impossible).
    reached = csum >= target
    cut = jnp.where(jnp.any(reached), jnp.argmax(reached), csum.shape[0] - 1)
    rank = jnp.zeros_like(order).at[order].set(jnp.arange(order.shape[0]))
    return rank <= cut


def dynamic_activation_lax(
    dists1: jax.Array, dists2: jax.Array, cell_counts: jax.Array, target: int
) -> jax.Array:
    """Faithful ``lax.while_loop`` port of paper Algorithm 3.

    Kept for fidelity/testing; the production path is
    :func:`activate_cells_sorted`.  Returns the same ``(K,)`` bool mask.
    """
    k1 = dists1.shape[0]
    k2 = dists2.shape[0]
    idx1 = jnp.argsort(dists1)
    idx2 = jnp.argsort(dists2)
    s1 = jnp.take(dists1, idx1)
    s2 = jnp.take(dists2, idx2)
    counts2d = cell_counts.reshape(k1, k2)

    inf = jnp.asarray(jnp.inf, dists1.dtype)
    state = (
        jnp.zeros(k1, jnp.int32),  # active_idx (column per row)
        jnp.full((k1,), inf).at[0].set(s1[0] + s2[0]),  # active_dists
        jnp.zeros(k1 * k2, bool),  # retrieved mask (over original cell ids)
        jnp.asarray(0, jnp.int32),  # retrieved_num
    )

    def cond(st):
        _, ad, _, got = st
        return jnp.logical_and(got < target, jnp.any(jnp.isfinite(ad)))

    def body(st):
        ai, ad, mask, got = st
        pos = jnp.argmin(ad)
        col = ai[pos]
        c1 = idx1[pos]
        c2 = idx2[col]
        mask = mask.at[c1 * k2 + c2].set(True)
        got = got + counts2d[c1, c2]
        # Activate next row iff this row was popped at column 0 (Alg.3 l.12).
        do_spawn = jnp.logical_and(col == 0, pos < k1 - 1)
        nxt = jnp.minimum(pos + 1, k1 - 1)
        ad = jnp.where(do_spawn, ad.at[nxt].set(s1[nxt] + s2[0]), ad)
        ai = jnp.where(do_spawn, ai.at[nxt].set(0), ai)
        # Advance this row (Alg.3 l.15-17) or retire it.
        can_adv = col < k2 - 1
        newcol = jnp.minimum(col + 1, k2 - 1)
        ad = ad.at[pos].set(jnp.where(can_adv, s1[pos] + s2[newcol], inf))
        ai = ai.at[pos].set(jnp.where(can_adv, newcol, col))
        return ai, ad, mask, got

    _, _, mask, _ = jax.lax.while_loop(cond, body, state)
    return mask


# --------------------------------------------------------------------------
# Query (Algorithm 4)
# --------------------------------------------------------------------------


def _centroid_dists(
    index: SuCoIndex, q: jax.Array, metric: Metric
) -> tuple[jax.Array, jax.Array]:
    """``q: (m, d)`` -> per-subspace query-to-centroid distances
    ``(Ns, m, sqrtK)`` for each half."""
    qp = sub.permute(index.spec, q)
    qh1, qh2 = sub.split_halves_padded(index.spec, qp)  # (Ns, m, h_max)
    d1 = jax.vmap(lambda qq, cc: pairwise_dist(qq, cc, metric))(qh1, index.centroids1)
    d2 = jax.vmap(lambda qq, cc: pairwise_dist(qq, cc, metric))(qh2, index.centroids2)
    return d1, d2


def suco_scores(
    index: SuCoIndex,
    q: jax.Array,
    count: int,
    metric: Metric = "l2",
) -> jax.Array:
    """``q: (m, d) -> (m, n)`` int32 SC-scores via the IMI (Alg. 4 l.3-12).

    Scans over subspaces; per subspace the per-point collision test is a
    rank-gather: point j collides iff its cell is inside the activated
    prefix.
    """
    d1, d2 = _centroid_dists(index, q, metric)  # (Ns, m, sqrtK)
    m = q.shape[0]
    n = index.n_points

    def per_subspace(acc, inp):
        d1_i, d2_i, cells_i, counts_i = inp  # (m,sK),(m,sK),(n,),(K,)

        def per_query(d1_q, d2_q):
            mask = activate_cells_sorted(d1_q, d2_q, counts_i, count)  # (K,)
            return jnp.take(mask, cells_i)  # (n,) bool

        collide = jax.vmap(per_query)(d1_i, d2_i)  # (m, n)
        return acc + collide.astype(jnp.int32), None

    init = jnp.zeros((m, n), jnp.int32)
    scores, _ = jax.lax.scan(
        init=init,
        xs=(d1, d2, index.cell_ids, index.cell_counts),
        f=per_subspace,
    )
    return scores


@functools.partial(jax.jit, static_argnames=("k", "alpha", "beta", "metric"))
def suco_query(
    x: jax.Array,
    index: SuCoIndex,
    q: jax.Array,
    *,
    k: int,
    alpha: float,
    beta: float,
    metric: Metric = "l2",
) -> QueryResult:
    """Algorithm 4: k-ANN for a batch ``q: (m, d)`` using the SuCo index."""
    n = x.shape[0]
    c = sub.collision_count(n, alpha)
    scores = suco_scores(index, q, c, metric)  # (m, n)
    n_candidates = max(k, int(beta * n))
    return rerank(x, q, scores, k, n_candidates, metric)
