"""Reference IMI traversals in numpy, kept for fidelity + Figure 6.

* :func:`multi_sequence` — the original priority-queue Multi-sequence
  algorithm from the Inverted Multi-Index paper [Babenko & Lempitsky '14].
* :func:`dynamic_activation` — the paper's Algorithm 3, verbatim: a
  heap-free frontier over activated rows.

Both return the retrieved cell list in the same (distance-ascending) order,
which `tests/test_dynamic_activation.py` asserts, along with equality with
the TPU-native sort-prefix form in :mod:`repro.core.suco`.
"""

from __future__ import annotations

import heapq

import numpy as np

__all__ = ["multi_sequence", "dynamic_activation"]


def multi_sequence(
    dists1: np.ndarray,
    dists2: np.ndarray,
    cell_counts: np.ndarray,
    target: int,
) -> list[tuple[int, int]]:
    """Priority-queue traversal of the IMI grid.

    ``dists1/dists2``: (sqrtK,) query-to-centroid distances per half-space.
    ``cell_counts``: (sqrtK, sqrtK) points per cell (row = half-1 cluster).
    Returns cells ``(c1, c2)`` in ascending ``dists1[c1] + dists2[c2]`` order
    until the cumulative count reaches ``target``.
    """
    k1, k2 = len(dists1), len(dists2)
    idx1 = np.argsort(dists1, kind="stable")
    idx2 = np.argsort(dists2, kind="stable")
    heap: list[tuple[float, int, int]] = [(float(dists1[idx1[0]] + dists2[idx2[0]]), 0, 0)]
    seen = {(0, 0)}
    out: list[tuple[int, int]] = []
    got = 0
    while heap and got < target:
        _, i, j = heapq.heappop(heap)
        c1, c2 = int(idx1[i]), int(idx2[j])
        out.append((c1, c2))
        got += int(cell_counts[c1, c2])
        if i + 1 < k1 and (i + 1, j) not in seen:
            seen.add((i + 1, j))
            heapq.heappush(heap, (float(dists1[idx1[i + 1]] + dists2[idx2[j]]), i + 1, j))
        if j + 1 < k2 and (i, j + 1) not in seen:
            seen.add((i, j + 1))
            heapq.heappush(heap, (float(dists1[idx1[i]] + dists2[idx2[j + 1]]), i, j + 1))
    return out


def dynamic_activation(
    dists1: np.ndarray,
    dists2: np.ndarray,
    cell_counts: np.ndarray,
    target: int,
) -> list[tuple[int, int]]:
    """Paper Algorithm 3, verbatim (array-based frontier, no heap).

    ``active_idx[p]`` is how far row ``p`` (p-th closest half-1 cluster) has
    advanced along the sorted half-2 clusters; ``active_dists[p]`` caches the
    next candidate distance of that row.  Each round pops the global minimum,
    optionally activates row ``p+1`` (only when the popped row was at column
    0), and advances row ``p``.
    """
    k1 = len(dists1)
    idx1 = np.argsort(dists1, kind="stable")
    idx2 = np.argsort(dists2, kind="stable")
    active_idx = np.zeros(k1, dtype=np.int64)
    active_dists = np.full(k1, np.inf, dtype=np.float64)
    n_active = 1
    active_dists[0] = dists1[idx1[0]] + dists2[idx2[0]]
    out: list[tuple[int, int]] = []
    got = 0
    while got < target:
        pos = int(np.argmin(active_dists[:n_active]))
        col = int(active_idx[pos])
        c1, c2 = int(idx1[pos]), int(idx2[col])
        out.append((c1, c2))
        got += int(cell_counts[c1, c2])
        if got >= target:
            break
        if col == 0 and pos < k1 - 1:
            # Activate the next row at column 0.
            n_active = max(n_active, pos + 2)
            active_idx[pos + 1] = 0
            active_dists[pos + 1] = dists1[idx1[pos + 1]] + dists2[idx2[0]]
        if col < len(idx2) - 1:
            active_idx[pos] = col + 1
            active_dists[pos] = dists1[idx1[pos]] + dists2[idx2[col + 1]]
        else:
            active_dists[pos] = np.inf
    return out
