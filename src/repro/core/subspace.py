"""Subspace sampling and division (paper Definition 3).

A :class:`SubspaceSpec` is a static (hashable) description of how the ``d``
original dimensions are distributed over ``Ns`` subspaces:

* ``perm``   -- a permutation of ``range(d)``; applying it first makes every
  division a *contiguous* slicing problem (the paper's "practical" contiguous
  division is ``perm == identity``; Definition 3's uniform sampling without
  replacement is a random permutation).
* ``bounds`` -- ``Ns+1`` prefix boundaries.  Subspace ``i`` owns permuted dims
  ``bounds[i]:bounds[i+1]``.  Per Definition 3 the first ``Ns-1`` subspaces
  get ``floor(d/Ns)`` dims and the last one picks up the remainder.

For TPU friendliness every ragged view is materialised as a dense, zero-padded
array: zero padding never changes L1/L2 distances, K-means centroids of padded
columns stay at zero, so all downstream math is padding-oblivious.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "SubspaceSpec",
    "contiguous_spec",
    "sampled_spec",
    "permute",
    "split_padded",
    "split_query_padded",
    "collision_count",
]


@dataclasses.dataclass(frozen=True)
class SubspaceSpec:
    """Static description of a subspace division."""

    d: int
    n_subspaces: int
    perm: tuple[int, ...]
    bounds: tuple[int, ...]

    def __post_init__(self) -> None:
        if len(self.perm) != self.d:
            raise ValueError(f"perm has {len(self.perm)} entries, expected d={self.d}")
        if sorted(self.perm) != list(range(self.d)):
            raise ValueError("perm is not a permutation of range(d)")
        if len(self.bounds) != self.n_subspaces + 1:
            raise ValueError("bounds must have Ns+1 entries")
        if self.bounds[0] != 0 or self.bounds[-1] != self.d:
            raise ValueError("bounds must span [0, d]")
        for a, b in zip(self.bounds, self.bounds[1:]):
            if b <= a:
                raise ValueError("every subspace must own at least one dim")

    @property
    def sizes(self) -> tuple[int, ...]:
        return tuple(b - a for a, b in zip(self.bounds, self.bounds[1:]))

    @property
    def max_size(self) -> int:
        return max(self.sizes)

    # -- halves (used by the IMI: each subspace is product-quantised in two) --
    @property
    def half_sizes(self) -> tuple[tuple[int, int], ...]:
        out = []
        for s in self.sizes:
            h1 = math.ceil(s / 2)
            out.append((h1, s - h1))
        return tuple(out)

    @property
    def max_half_size(self) -> int:
        return max(max(h1, h2) for h1, h2 in self.half_sizes)


def _even_bounds(d: int, n_subspaces: int) -> tuple[int, ...]:
    s = d // n_subspaces
    if s == 0:
        raise ValueError(f"d={d} too small for Ns={n_subspaces}")
    bounds = [i * s for i in range(n_subspaces)] + [d]
    return tuple(bounds)


def contiguous_spec(d: int, n_subspaces: int) -> SubspaceSpec:
    """The paper's practical division: contiguous equal slices (§3.2)."""
    return SubspaceSpec(d, n_subspaces, tuple(range(d)), _even_bounds(d, n_subspaces))


def sampled_spec(d: int, n_subspaces: int, seed: int) -> SubspaceSpec:
    """Definition 3: multi-round uniform sampling without replacement."""
    rng = np.random.default_rng(seed)
    perm = tuple(int(x) for x in rng.permutation(d))
    return SubspaceSpec(d, n_subspaces, perm, _even_bounds(d, n_subspaces))


def permute(spec: SubspaceSpec, x: jax.Array) -> jax.Array:
    """Apply the dim permutation to the trailing axis of ``x``."""
    perm = jnp.asarray(spec.perm, dtype=jnp.int32)
    return jnp.take(x, perm, axis=-1)


def split_padded(spec: SubspaceSpec, x: jax.Array) -> jax.Array:
    """``(..., d) -> (Ns, ..., s_max)`` zero-padded dense subspace view.

    ``x`` must already be permuted (see :func:`permute`).
    """
    s_max = spec.max_size
    parts = []
    for i, (a, b) in enumerate(zip(spec.bounds, spec.bounds[1:])):
        piece = x[..., a:b]
        pad = s_max - (b - a)
        if pad:
            widths = [(0, 0)] * (piece.ndim - 1) + [(0, pad)]
            piece = jnp.pad(piece, widths)
        parts.append(piece)
    return jnp.stack(parts, axis=0)


def split_query_padded(spec: SubspaceSpec, q: jax.Array) -> jax.Array:
    """Convenience alias, kept for call-site readability."""
    return split_padded(spec, q)


def split_halves_padded(spec: SubspaceSpec, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """``(..., d) -> 2 x (Ns, ..., h_max)`` zero-padded half-subspace views."""
    h_max = spec.max_half_size
    first, second = [], []
    for (a, b), (h1, _h2) in zip(zip(spec.bounds, spec.bounds[1:]), spec.half_sizes):
        p1 = x[..., a : a + h1]
        p2 = x[..., a + h1 : b]
        for piece, acc in ((p1, first), (p2, second)):
            pad = h_max - piece.shape[-1]
            if pad:
                widths = [(0, 0)] * (piece.ndim - 1) + [(0, pad)]
                piece = jnp.pad(piece, widths)
            acc.append(piece)
    return jnp.stack(first, axis=0), jnp.stack(second, axis=0)


def collision_count(n: int, alpha: float) -> int:
    """Number of per-subspace collisions: the ``alpha * n`` of Definition 1."""
    if not 0.0 < alpha <= 1.0:
        raise ValueError(f"alpha must be in (0, 1], got {alpha}")
    return max(1, int(alpha * n))
