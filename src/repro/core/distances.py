"""Distance primitives shared by the whole framework.

Two execution paths exist for the hot pairwise-L2 computation:

* pure ``jnp`` (this module) — the reference semantics, used on CPU and as
  the oracle for the Pallas kernel;
* ``repro.kernels.pairwise_l2.ops.pairwise_sqdist`` — the blocked MXU Pallas
  kernel targeted at TPU.  ``repro.core`` routes through
  :func:`pairwise_sqdist` with ``impl="auto"`` which picks the kernel only on
  TPU backends, so CPU tests/benches stay on the oracle path.
"""

from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

Metric = Literal["l2", "l1"]

__all__ = [
    "pairwise_sqdist",
    "pairwise_dist",
    "rowwise_candidate_dist",
    "sq_l2",
    "Metric",
]


def sq_l2(a: jax.Array, b: jax.Array) -> jax.Array:
    """Squared L2 between matching rows of ``a`` and ``b``."""
    diff = a - b
    return jnp.sum(diff * diff, axis=-1)


def _sqdist_jnp(q: jax.Array, x: jax.Array) -> jax.Array:
    """``(m, d), (n, d) -> (m, n)`` squared L2 via the matmul identity."""
    qn = jnp.sum(q * q, axis=-1)
    xn = jnp.sum(x * x, axis=-1)
    # fp32 accumulation even when inputs are bf16.
    cross = jnp.einsum("md,nd->mn", q, x, preferred_element_type=jnp.float32)
    d2 = qn[:, None].astype(jnp.float32) + xn[None, :].astype(jnp.float32) - 2.0 * cross
    return jnp.maximum(d2, 0.0)


def _sqdist_rowwise(q: jax.Array, x: jax.Array) -> jax.Array:
    """``(m, d), (n, d) -> (m, n)`` squared L2 via the broadcast difference.

    The reduction runs over ``d`` only, so each output element's fp
    summation order is independent of the batch sizes ``m``/``n`` — unlike
    the matmul identity, whose tiling (and therefore last-ulp results)
    varies with shape.  The serving stack relies on this: a query batch
    zero-padded to a SuCoEngine bucket must return bit-identical distances
    to the unpadded computation.  O(m*n*d) intermediate — only for small
    ``n`` (centroid tables, candidate pools), never the full dataset.
    """
    diff = q[:, None, :].astype(jnp.float32) - x[None, :, :].astype(jnp.float32)
    return jnp.sum(diff * diff, axis=-1)


def pairwise_sqdist(q: jax.Array, x: jax.Array, *, impl: str = "auto") -> jax.Array:
    """Pairwise squared L2 distances ``(m, d), (n, d) -> (m, n)``.

    ``impl``: "jnp" | "pallas" | "auto" (pallas iff running on TPU) |
    "rowwise" (batch-padding-invariant broadcast form, see
    :func:`_sqdist_rowwise`).
    """
    if impl == "pallas" or (impl == "auto" and jax.default_backend() == "tpu"):
        from repro.kernels.pairwise_l2 import ops as _ops

        return _ops.pairwise_sqdist(q, x)
    if impl == "rowwise":
        return _sqdist_rowwise(q, x)
    return _sqdist_jnp(q, x)


def rowwise_candidate_dist(
    q: jax.Array, xc: jax.Array, metric: Metric = "l2"
) -> jax.Array:
    """Exact per-candidate distances ``q: (m, d), xc: (m, c, d) -> (m, c)``.

    The fused streaming engine computes rerank distances in-pass for each
    chunk's surviving rows; this helper pins the fp semantics to exactly
    what :func:`repro.core.sc_linear.rerank_candidates` produces through
    ``pairwise_dist(..., impl="rowwise")``: the reduction runs over ``d``
    only (batch-padding-invariant), L2 accumulates in fp32, L1 reduces in
    the inputs' promoted dtype — so a distance computed mid-scan is
    bit-identical to the post-scan gather path it replaces.
    """
    if metric == "l2":
        diff = q[:, None, :].astype(jnp.float32) - xc.astype(jnp.float32)
        return jnp.sum(diff * diff, axis=-1)
    if metric != "l1":
        raise ValueError(f"unknown metric {metric!r}")
    return jnp.sum(jnp.abs(q[:, None, :] - xc), axis=-1)


def _l1_block(q: jax.Array, xb: jax.Array) -> jax.Array:
    # (m, d), (nb, d) -> (m, nb); broadcast is fine for a block.
    return jnp.sum(jnp.abs(q[:, None, :] - xb[None, :, :]), axis=-1)


def pairwise_dist(
    q: jax.Array,
    x: jax.Array,
    metric: Metric = "l2",
    *,
    block: int = 16384,
    impl: str = "auto",
) -> jax.Array:
    """Pairwise distances under ``metric``; L2 returns *squared* distances.

    Squared L2 preserves the NN ordering, which is all the framework needs;
    callers that report metric values take ``sqrt`` at the edge.
    L1 is computed blocked over ``x`` to bound the broadcast intermediate.
    """
    if metric == "l2":
        return pairwise_sqdist(q, x, impl=impl)
    if metric != "l1":
        raise ValueError(f"unknown metric {metric!r}")
    n = x.shape[0]
    if n <= block:
        return _l1_block(q, x)
    nblocks = -(-n // block)
    pad = nblocks * block - n
    # Pad with +inf-ish rows so padded columns never win any NN selection.
    xp = jnp.pad(x, ((0, pad), (0, 0)), constant_values=1e30)
    xb = xp.reshape(nblocks, block, x.shape[1])
    out = jax.lax.map(lambda blk: _l1_block(q, blk), xb)  # (nb, m, block)
    out = jnp.moveaxis(out, 0, 1).reshape(q.shape[0], nblocks * block)
    return out[:, :n]
