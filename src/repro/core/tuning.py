"""Tiling autotuner: pick streaming-query / kernel tile sizes from backend
memory limits and the problem shape instead of hard-coded constants.

The streaming and fused query engines (:mod:`repro.core.suco`) process the
dataset in chunks of ``block_n`` points; the SC-score Pallas kernel tiles
each chunk into ``(bm, bn)`` blocks; and the fused engine additionally
carries a ``survivor_cap``-wide compaction buffer for chunk rows that beat
the carried pool minimum (the Pareto prefilter).  Until this module, those
knobs were frozen at ``4096 / 8 / 512`` — tuned by hand for one CPU host
and one dataset size.  :func:`autotune_tiles` instead sizes them so the
per-chunk working set (resident data chunk + cell ids + score block +
carried pool) fits the backend's fast memory (VMEM on TPU, a per-core L2
budget on CPU), which is what "as fast as the hardware allows" means for a
bandwidth-bound scan: the chunk a step touches should be served from the
closest memory level, and the merge should run as rarely as that allows.

The memory limits the tiler plans against are *measured*, not guessed:
:func:`backend_limits` probes the active backend once per host (cache-knee
timing sweep on CPU, the runtime's reported allocator ceiling for device
memory) and caches the quantised result on disk and in-process — see the
"Measured limits" section below.  The static ``_BACKEND_LIMITS`` table
survives as the prior for absent hardware and as the
``REPRO_MEASURED_LIMITS=0`` escape hatch.

The autotuner is *deterministic* and *shape-only*: given the same
``(n, d, m, pool)`` and backend it always returns the same
:class:`TileConfig` within a host, so jitted executables keyed on tile
sizes never retrace between identical requests (the serving stack's
zero-retrace invariant).  Every knob can still be pinned by hand through
:class:`~repro.core.suco.EnginePolicy` / :class:`~repro.core.suco.SuCoConfig`.
"""

from __future__ import annotations

import dataclasses
import functools
import json
import os
import time
import warnings
from pathlib import Path

import jax

__all__ = [
    "MemoryLimits",
    "TileConfig",
    "backend_limits",
    "measured_backend_limits",
    "static_backend_limits",
    "autotune_tiles",
    "autotune_build_block_n",
]


@dataclasses.dataclass(frozen=True)
class MemoryLimits:
    """Per-device memory budget the tiler plans against.

    ``fast_bytes`` is the working-set budget for one streamed chunk — VMEM
    on TPU, a per-core L2-ish slice on CPU, shared-memory-adjacent L2 on
    GPU.  ``hbm_bytes`` bounds whole-array residency (index + dataset) and
    is only used for sanity clamps.
    """

    fast_bytes: int
    hbm_bytes: int


# Static priors per backend: the fallback when the measured probe is
# disabled, fails, or is asked about a backend this host does not run.
# Unknown backends fall back to "cpu".
_BACKEND_LIMITS: dict[str, MemoryLimits] = {
    # ~16 MB VMEM per TensorCore; leave half for Pallas double-buffering.
    "tpu": MemoryLimits(fast_bytes=8 * 2**20, hbm_bytes=16 * 2**30),
    # L2 slice per SM-cluster; HBM on a modern part.
    "gpu": MemoryLimits(fast_bytes=4 * 2**20, hbm_bytes=40 * 2**30),
    # Per-core L2 on a server CPU; "hbm" is host RAM.
    "cpu": MemoryLimits(fast_bytes=2 * 2**20, hbm_bytes=32 * 2**30),
}


# --------------------------------------------------------------------------
# Measured limits: probe the host once, cache per backend
# --------------------------------------------------------------------------
#
# The static table above is a *prior*, not a measurement: the serving host's
# actual cache topology and device memory decide whether a streamed chunk is
# bandwidth-cheap.  ``backend_limits`` therefore runs a tiny calibration for
# the backend this process is actually executing on — a timed reduction
# sweep to find the cache knee (CPU) and the runtime's reported allocator
# ceiling for device memory — and quantises the result so timing noise
# cannot leak into tile shapes.  The probe runs at most once per host per
# backend: results persist as JSON under ``$REPRO_TUNE_CACHE_DIR`` (default
# ``~/.cache/repro/tuning``), keyed by device kind, and an in-process
# ``lru_cache`` keeps the value bit-stable for jit static arguments — the
# zero-retrace invariant.  ``REPRO_MEASURED_LIMITS=0`` disables the probe
# entirely (static table only); backends other than the active one always
# use the static prior (there is no hardware to measure).

_MEASURE_ENV = "REPRO_MEASURED_LIMITS"  # "0" -> static table only
_CACHE_DIR_ENV = "REPRO_TUNE_CACHE_DIR"  # override the on-disk cache dir
_PROBE_VERSION = 1
_HBM_QUANTUM = 1 << 30  # device memory quantised down to 1 GiB
_FAST_MIN = 1 << 20  # measured fast memory clamps to [1 MiB, 64 MiB]
_FAST_MAX = 1 << 26
# A working set counts as cache-resident while its best per-byte reduction
# time stays within this factor of the small-set baseline; the first size
# past it is the knee.
_KNEE_FACTOR = 1.6


def _probe_cache_dir() -> Path:
    env = os.environ.get(_CACHE_DIR_ENV)
    if env:
        return Path(env)
    xdg = os.environ.get("XDG_CACHE_HOME")
    base = Path(xdg) if xdg else Path(os.path.expanduser("~")) / ".cache"
    return base / "repro" / "tuning"


def _device_kind(backend: str) -> str:
    try:
        devs = jax.devices(backend)
    except RuntimeError:
        return ""
    return devs[0].device_kind if devs else ""


def _measure_hbm_bytes(backend: str) -> int | None:
    """Device-memory ceiling: the runtime's own allocator limit where the
    platform reports one (TPU/GPU ``memory_stats``), physical RAM on CPU."""
    try:
        dev = jax.devices(backend)[0]
    except (RuntimeError, IndexError):
        return None
    try:
        stats = dev.memory_stats() or {}
    except Exception:
        stats = {}
    if stats.get("bytes_limit"):
        return int(stats["bytes_limit"])
    try:  # CPU backends rarely report allocator stats: physical RAM
        return int(os.sysconf("SC_PHYS_PAGES")) * int(os.sysconf("SC_PAGE_SIZE"))
    except (AttributeError, OSError, ValueError):
        return None


def _measure_cpu_fast_bytes() -> tuple[int | None, dict]:
    """Find the cache knee with a timed reduction sweep.

    Reduces float32 working sets of power-of-two sizes (256 KiB..64 MiB,
    best-of-3 per size, ~16 MiB of traffic per timing) and returns half the
    largest size whose per-byte time stays within ``_KNEE_FACTOR`` of the
    small-set baseline — half, because the streamed chunk shares the level
    with the kernel's double buffers.  Power-of-two candidates make the
    result self-quantising: run-to-run timing noise must move the knee a
    full octave to change the answer.
    """
    import numpy as np

    sizes = [1 << p for p in range(18, 27)]
    per_byte = []
    for size in sizes:
        arr = np.ones(size // 4, np.float32)
        reps = max(1, (1 << 24) // size)
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            for _ in range(reps):
                arr.sum()
            best = min(best, (time.perf_counter() - t0) / (reps * size))
        per_byte.append(best)
    trace = {"sizes": sizes, "per_byte_ns": [t * 1e9 for t in per_byte]}
    base = min(per_byte[:2])
    fast = None
    for size, t in zip(sizes, per_byte):
        if t <= base * _KNEE_FACTOR:
            fast = size
        else:
            break
    if fast is None:
        return None, trace
    return _clamp(fast // 2, _FAST_MIN, _FAST_MAX), trace


def _probe_limits(backend: str) -> tuple[MemoryLimits, dict]:
    static = _BACKEND_LIMITS[backend]
    hbm = _measure_hbm_bytes(backend)
    hbm = (
        max(_HBM_QUANTUM, _round_down(hbm, _HBM_QUANTUM))
        if hbm
        else static.hbm_bytes
    )
    trace: dict = {}
    if backend == "cpu":
        fast, trace = _measure_cpu_fast_bytes()
        fast = fast if fast is not None else static.fast_bytes
    else:
        # VMEM / L2-slice budgets are not queryable through memory_stats;
        # keep the per-backend prior and measure only the memory ceiling.
        fast = static.fast_bytes
    return MemoryLimits(fast_bytes=fast, hbm_bytes=hbm), trace


@functools.lru_cache(maxsize=None)
def _measured_limits(backend: str) -> MemoryLimits:
    kind = _device_kind(backend)
    path = _probe_cache_dir() / f"limits_{backend}.json"
    try:
        rec = json.loads(path.read_text())
        if (
            rec.get("version") == _PROBE_VERSION
            and rec.get("backend") == backend
            and rec.get("device_kind") == kind
        ):
            return MemoryLimits(int(rec["fast_bytes"]), int(rec["hbm_bytes"]))
    except (OSError, ValueError, KeyError, TypeError):
        pass  # missing / stale / corrupt cache: re-probe and rewrite
    limits, trace = _probe_limits(backend)
    rec = {
        "version": _PROBE_VERSION,
        "backend": backend,
        "device_kind": kind,
        "fast_bytes": limits.fast_bytes,
        "hbm_bytes": limits.hbm_bytes,
        "probe": trace,
    }
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_name(f"{path.name}.{os.getpid()}.tmp")
        tmp.write_text(json.dumps(rec, indent=2, sort_keys=True) + "\n")
        os.replace(tmp, path)
    except OSError:
        pass  # unwritable cache dir: the in-process lru_cache still holds
    return limits


def static_backend_limits(backend: str | None = None) -> MemoryLimits:
    """The static prior for ``backend`` (default: active), never measured.

    For callers that need *host-independent* limits — the jaxlint entry
    hooks pin their canonical tile shapes and bounded-intermediate budgets
    to this model so the lint gate proves the same thing on every machine,
    while serving plans against the measured truth."""
    if backend is None:
        backend = jax.default_backend()
    if backend not in _BACKEND_LIMITS:
        raise ValueError(
            f"static_backend_limits: unknown backend {backend!r} "
            f"(known: {sorted(_BACKEND_LIMITS)})"
        )
    return _BACKEND_LIMITS[backend]


def measured_backend_limits(
    backend: str | None = None, *, refresh: bool = False
) -> MemoryLimits:
    """Measured :class:`MemoryLimits` for ``backend`` (default: active).

    Probes at most once per host per backend (JSON cache keyed by device
    kind, plus an in-process ``lru_cache``); ``refresh=True`` drops both
    caches and re-measures.  Only meaningful for the active backend —
    others return the static prior via the same code path."""
    if backend is None:
        backend = jax.default_backend()
    if backend not in _BACKEND_LIMITS:
        raise ValueError(
            f"measured_backend_limits: unknown backend {backend!r} "
            f"(known: {sorted(_BACKEND_LIMITS)})"
        )
    if backend != jax.default_backend():
        return _BACKEND_LIMITS[backend]
    if refresh:
        _measured_limits.cache_clear()
        try:
            (_probe_cache_dir() / f"limits_{backend}.json").unlink()
        except OSError:
            pass
    return _measured_limits(backend)


def backend_limits(backend: str | None = None) -> MemoryLimits:
    """Memory limits for ``backend`` (default: the active jax backend).

    For the backend this process is running on, the limits are *measured*
    (see :func:`measured_backend_limits`) unless ``REPRO_MEASURED_LIMITS=0``
    pins the static table; other backends use the static prior.  An unknown
    backend string falls back to the CPU model — with an explicit warning,
    since silently tiling a new accelerator with CPU-sized chunks is a
    performance bug that should surface in logs."""
    active = jax.default_backend()
    if backend is None:
        backend = active
    if backend not in _BACKEND_LIMITS:
        warnings.warn(
            f"backend_limits: unknown backend {backend!r}; falling back to "
            f"the conservative 'cpu' memory model "
            f"(known: {sorted(_BACKEND_LIMITS)})",
            stacklevel=2,
        )
        backend = "cpu"
    if backend == active and os.environ.get(_MEASURE_ENV, "1") != "0":
        try:
            return _measured_limits(backend)
        except Exception:  # probe failure is never fatal: static prior
            pass
    return _BACKEND_LIMITS[backend]


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """Resolved tiling for the streaming/fused query engines.

    * ``block_n`` — data points per streamed chunk (the ``lax.scan`` step).
    * ``bm`` / ``bn`` — SC-score kernel grid tile (queries x chunk columns);
      multiples of the f32 TPU tile (8 sublanes x 128 lanes).
    * ``survivor_cap`` — fused-path compaction width: the per-chunk budget
      of rows beating the carried pool minimum that merge at the pruned
      (cheap) width; a chunk exceeding it falls back to the exact
      full-width merge (same results, slower — see
      :func:`repro.core.suco.suco_query_fused`).

    Hashable/frozen so it can ride in jit static arguments and in
    :class:`~repro.core.suco.EnginePolicy` equality.
    """

    block_n: int
    bm: int = 8
    bn: int = 512
    survivor_cap: int = 256

    def __post_init__(self):
        if self.block_n < 1:
            raise ValueError(f"block_n must be >= 1, got {self.block_n}")
        if self.bm < 1 or self.bn < 1:
            raise ValueError(f"bm/bn must be >= 1, got {self.bm}/{self.bn}")
        if self.survivor_cap < 1:
            raise ValueError(
                f"survivor_cap must be >= 1, got {self.survivor_cap}"
            )


def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def _round_down(v: int, mult: int) -> int:
    return (v // mult) * mult


def _clamp(v: int, lo: int, hi: int) -> int:
    return max(lo, min(v, hi))


# Streamed chunks are sized in multiples of this (one f32 lane tile wide
# per subspace row); also the floor so tiny datasets still vectorise.
_BLOCK_QUANTUM = 512
_BLOCK_MAX = 1 << 16  # beyond this the scan stops gaining and pads hurt
_CAP_QUANTUM = 64
# Safety factor on the expected per-chunk survivor count: under the
# paper's Pareto observation the rows beating the carried pool minimum
# are a thin tail, but early chunks (cold pool, low threshold) and
# clustered queries overshoot the uniform estimate — the second chunk
# typically sees ~2x the steady-state tail, so budget well past it.
_CAP_SAFETY = 8


def autotune_tiles(
    n: int,
    d: int,
    m: int,
    pool: int,
    *,
    n_subspaces: int = 8,
    n_cells: int = 2500,
    backend: str | None = None,
    limits: MemoryLimits | None = None,
    itemsize: int = 4,
) -> TileConfig:
    """Pick ``(block_n, bm, bn, survivor_cap)`` for a streamed query.

    ``n/d`` are the dataset shape, ``m`` the (padded) query-batch size,
    ``pool`` the carried candidate-pool width (``max(k, beta*n)``),
    ``n_subspaces``/``n_cells`` the index shape (they size the kernel's
    rank table), ``itemsize`` the dataset dtype width.

    Sizing model (all per query batch, bytes):

    * chunk-resident: ``block_n * (Ns*4 + d*itemsize + m*4)`` — cell ids,
      the data chunk itself, and the int32 score block;
    * carried: ``3 * m * pool * 4`` for the (score, dist, id) pool, twice
      (the merge concatenates pool + survivors).

    The largest ``block_n`` (multiple of 512, clamped to [512, 65536] and
    to roughly an eighth of the dataset) whose total fits
    ``limits.fast_bytes`` wins: bigger chunks mean fewer pool merges — the
    dominant per-chunk cost — while staying inside the memory level that
    makes the scan bandwidth-cheap; the ~n/8 ceiling guarantees the scan
    actually streams (the Pareto prefilter only pays once the carried pool
    has warmed past the first chunks).  ``bm``/``bn`` then tile that chunk
    for the Pallas kernel under a quarter of the same budget (ranks tile +
    cells tile + out tile), and ``survivor_cap`` budgets ``_CAP_SAFETY``
    times the uniform-order expectation ``pool * block_n / n`` of new pool
    entrants per chunk.
    """
    if min(n, d, m, pool) < 1:
        raise ValueError(
            f"n/d/m/pool must all be >= 1, got {n}/{d}/{m}/{pool}"
        )
    if limits is None:
        limits = backend_limits(backend)
    fast = limits.fast_bytes

    per_point = n_subspaces * 4 + d * itemsize + m * 4
    carried = 2 * 3 * m * pool * 4
    budget = max(fast - carried, _BLOCK_QUANTUM * per_point)
    block_n = _clamp(
        _round_down(budget // per_point, _BLOCK_QUANTUM),
        _BLOCK_QUANTUM,
        _BLOCK_MAX,
    )
    block_n = min(
        block_n, max(_round_up(n // 8, _BLOCK_QUANTUM), _BLOCK_QUANTUM)
    )
    # When the carried pool alone overflows fast memory (huge beta*n), the
    # cache-residency model bottoms out — but tiny chunks would multiply
    # the per-chunk merges, each already O(pool) wide.  Chunks at least
    # pool-sized keep total merge work O(n), the scan's own order.
    block_n = max(
        block_n, _clamp(_round_up(pool, _BLOCK_QUANTUM), _BLOCK_QUANTUM, _BLOCK_MAX)
    )

    # Kernel grid tile: bm covers the (padded) batch in f32 sublane
    # multiples; bn splits the chunk into lane-multiple column blocks small
    # enough that (ranks tile + cells tile + score tile) sits in a quarter
    # of fast memory, leaving room for Pallas pipelining.
    bm = _clamp(_round_up(m, 8), 8, 128)
    tile_budget = fast // 4 - bm * n_cells * 4
    bn = _clamp(
        _round_down(tile_budget // max(4 * (bm + 1), 1), 128), 128, 2048
    )
    bn = min(bn, max(_round_up(block_n, 128), 128))

    expected = pool * block_n / max(n, 1)
    # The pool/block ceiling is rounded *down* to the quantum so the cap
    # stays a _CAP_QUANTUM multiple even when it clamps (a slightly smaller
    # cap only means earlier exact-fallback merges, never wrong results).
    cap = _clamp(
        _round_up(int(_CAP_SAFETY * expected) + 1, _CAP_QUANTUM),
        _CAP_QUANTUM,
        max(_CAP_QUANTUM, _round_down(min(pool, block_n), _CAP_QUANTUM)),
    )
    return TileConfig(block_n=block_n, bm=bm, bn=bn, survivor_cap=cap)


def autotune_build_block_n(
    n: int,
    d: int,
    *,
    sqrt_k: int,
    n_subspaces: int = 8,
    backend: str | None = None,
    limits: MemoryLimits | None = None,
    itemsize: int = 4,
) -> int:
    """Chunk size for the streaming index build (chunked/minibatch Lloyd).

    Each K-means step materialises per chunk a ``(2Ns, block_n, sqrtK)``
    distance block and the ``(2Ns, block_n, h_max)`` half-space view; the
    largest 512-multiple whose sum fits the backend's fast memory keeps
    the assign/stats scan cache-resident without shrinking chunks (and
    therefore multiplying scan steps) more than the hardware requires.
    """
    if min(n, d, sqrt_k, n_subspaces) < 1:
        raise ValueError(
            f"n/d/sqrt_k/n_subspaces must be >= 1, got "
            f"{n}/{d}/{sqrt_k}/{n_subspaces}"
        )
    if limits is None:
        limits = backend_limits(backend)
    h_max = -(-(-(-d // n_subspaces)) // 2)  # ceil(ceil(d/Ns) / 2)
    per_point = 2 * n_subspaces * (sqrt_k + h_max) * itemsize
    block_n = _clamp(
        _round_down(limits.fast_bytes // per_point, _BLOCK_QUANTUM),
        _BLOCK_QUANTUM,
        _BLOCK_MAX,
    )
    return min(block_n, max(_round_up(n, _BLOCK_QUANTUM), _BLOCK_QUANTUM))


# --------------------------------------------------------------------------
# jaxlint registry hook (see repro.analysis)
# --------------------------------------------------------------------------


def jaxlint_entries():
    """Registry hook: autotuner outputs must respect the TPU tile quanta on
    every backend — a drifted quantum here would produce Pallas blocks that
    fail to lower on real hardware."""
    from repro.analysis.registry import TileEntry

    sweep = (
        # (n, d, m, pool, n_subspaces, n_cells): serving-scale, huge-pool,
        # and minimum-viable shapes
        (50_000, 128, 8, 1_000, 8, 2_500),
        (1_000_000, 96, 64, 20_000, 8, 2_500),
        (32_768, 16, 1, 33, 4, 256),
    )
    configs = tuple(
        autotune_tiles(n, d, m, pool, n_subspaces=ns, n_cells=nc, backend=b)
        for b in ("cpu", "gpu", "tpu")
        for (n, d, m, pool, ns, nc) in sweep
    )
    contract = {
        "sublane": 8,
        "lane": 128,
        "block_quantum": _BLOCK_QUANTUM,
        "cap_quantum": _CAP_QUANTUM,
    }
    return [
        TileEntry(
            name="tuning.autotune_tiles",
            contract=contract,
            tile_configs=configs,
            note="TileConfig quantisation contract across backends",
        )
    ]
