"""Theoretical guarantees (paper Theorems 1 & 2) as executable calculators.

These functions turn the proofs' parameter recipes into code so that the
framework can (a) validate the guarantees numerically (tests) and (b) suggest
``(Ns, alpha, beta)`` for a dataset from its subspace statistics ``(m, sigma)``
— the mean/stddev of per-subspace squared distances.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import numpy as np

__all__ = [
    "GuaranteeReport",
    "subspace_statistics",
    "estimate_subspace_statistics",
    "theorem1_bound",
    "theorem2_bound",
    "degraded_budget_bound",
    "suggest_parameters",
]

_GAMMA = 0.375  # Blom's constant for normal order statistics


def _ndtri(p: float) -> float:
    """Inverse standard normal CDF (Acklam's rational approximation).

    Avoids a scipy dependency; |error| < 1.2e-8 over (0, 1).
    """
    if not 0.0 < p < 1.0:
        raise ValueError("p must be in (0,1)")
    a = [-3.969683028665376e01, 2.209460984245205e02, -2.759285104469687e02,
         1.383577518672690e02, -3.066479806614716e01, 2.506628277459239e00]
    b = [-5.447609879822406e01, 1.615858368580409e02, -1.556989798598866e02,
         6.680131188771972e01, -1.328068155288572e01]
    c = [-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e00,
         -2.549732539343734e00, 4.374664141464968e00, 2.938163982698783e00]
    d = [7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e00,
         3.754408661907416e00]
    plow, phigh = 0.02425, 1 - 0.02425
    if p < plow:
        ql = math.sqrt(-2 * math.log(p))
        return (((((c[0] * ql + c[1]) * ql + c[2]) * ql + c[3]) * ql + c[4]) * ql + c[5]) / (
            (((d[0] * ql + d[1]) * ql + d[2]) * ql + d[3]) * ql + 1
        )
    if p > phigh:
        ql = math.sqrt(-2 * math.log(1 - p))
        return -(((((c[0] * ql + c[1]) * ql + c[2]) * ql + c[3]) * ql + c[4]) * ql + c[5]) / (
            (((d[0] * ql + d[1]) * ql + d[2]) * ql + d[3]) * ql + 1
        )
    qm = p - 0.5
    r = qm * qm
    return (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r + a[5]) * qm / (
        ((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1
    )


def _phi(x: float) -> float:
    return math.exp(-0.5 * x * x) / math.sqrt(2 * math.pi)


class GuaranteeReport(NamedTuple):
    success_prob: float  # lower bound on the success probability
    alpha_min: float  # smallest admissible collision ratio
    c1: float
    c2: float


def subspace_statistics(x: np.ndarray, q: np.ndarray, n_subspaces: int) -> tuple[float, float]:
    """Empirical (m, sigma) of per-subspace squared distances ``Z_i^j``."""
    n, d = x.shape
    s = d // n_subspaces
    z = np.abs(x - q[None, :]) ** 2
    zs = np.add.reduceat(z, np.arange(0, s * n_subspaces, s), axis=1)  # (n, Ns)
    return float(zs.mean()), float(zs.std())


def estimate_subspace_statistics(
    x: np.ndarray,
    n_subspaces: int,
    *,
    n_queries: int = 8,
    n_points: int = 2048,
    seed: int = 0,
) -> tuple[float, float]:
    """Deterministic sampled ``(m, sigma)`` estimate for a serving dataset.

    :func:`subspace_statistics` needs a concrete query; a serving process
    has none at policy time, so this draws ``n_queries`` probe queries from
    the data itself, measures each against an ``n_points`` sample, and
    averages — the same estimator the recall test harness applies per
    query, collapsed to one number pair.  Deterministic in ``seed`` so a
    degradation ladder's recall floors are stable across restarts.
    """
    n = x.shape[0]
    rng = np.random.default_rng(seed)
    xs = x[rng.choice(n, size=min(n_points, n), replace=False)]
    qs = x[rng.choice(n, size=min(n_queries, n), replace=False)]
    stats = [subspace_statistics(xs, q, n_subspaces) for q in qs]
    return float(np.mean([s[0] for s in stats])), float(np.mean([s[1] for s in stats]))


def theorem1_bound(m: float, sigma: float, n_subspaces: int, alpha: float) -> GuaranteeReport:
    """Theorem 1: SC-score ordering implies distance ordering w.p. >= 1/2-1/e^2.

    Implements the proof's explicit ``c1, c2`` recipe.  The bound holds for
    ``alpha > max(1/(1+m^2/s^2), 1 - e^2/(1+m^2/s^2))``.
    """
    r2 = (m / sigma) ** 2  # m^2/sigma^2
    alpha_min = max(1.0 / (1.0 + r2), 1.0 - math.e**2 / (1.0 + r2))
    root = math.sqrt(max((1.0 - alpha) * (1.0 + r2), 0.0))
    denom = m / sigma - root
    if alpha <= alpha_min or denom <= 0:
        return GuaranteeReport(0.0, alpha_min, float("nan"), float("nan"))
    c1 = math.sqrt(8.0 * max(n_subspaces - 1, 1)) / denom
    c2 = (math.e - root) / denom
    p = (
        1.0
        - (2.0 * (n_subspaces - 1) / c1**2) * denom**-2
        - (c2 * (m / sigma) + root * (1.0 - c2)) ** -2
    )
    return GuaranteeReport(p, alpha_min, c1, c2)


def theorem2_bound(
    n: int, k: int, n_subspaces: int, m: float, sigma: float, alpha: float
) -> float:
    """Theorem 2: probability lower bound that Alg. 1 answers a k-ANN query.

    Uses Blom's normal order-statistic approximations (paper Eq. 11-12) for
    ``E_{k,n}`` / ``V_{k,n}`` and the Chebyshev step of the proof.  Returns a
    probability in [0, 1] (>= 1/2 for admissible parameters).
    """
    ns = n_subspaces
    e_kn = ns * m + math.sqrt(ns) * sigma * _ndtri((k - _GAMMA) / (n - 2 * _GAMMA + 1))
    v_kn = (
        ns
        * sigma**2
        * (k * (n - k + 1) / ((n + 1) ** 2 * (n + 2)))
        * _phi(_ndtri(k / (n + 1))) ** -2
    )
    # Collision bound on ||z||^2 when C = Ns (all subspaces collide).
    bound = ns * m * math.sqrt((1.0 - alpha) * (1.0 + (sigma / m) ** 2))
    t = bound - e_kn
    if t <= 0:
        # Candidate radius below the k-th order statistic: the Chebyshev step
        # is vacuous; the proof's recipe asks for a larger alpha/beta.
        return 0.0
    return max(0.0, 1.0 - v_kn / t**2)


def degraded_budget_bound(
    n: int,
    k: int,
    n_subspaces: int,
    m: float,
    sigma: float,
    alpha: float,
    beta: float,
) -> float:
    """Theorem-2 success bound recomputed for a reduced ``(alpha, beta)``
    serving budget — the quantified floor a degraded-mode answer carries.

    :func:`theorem2_bound` assumes the candidate set retains every
    full-collision point; a load-shedding policy truncates the candidate
    pool at ``beta * n`` entries, which breaks that premise in two ways:

    * **infeasible pool** — ``int(beta * n) < k``: the pool cannot even
      hold a top-k answer, so the guarantee is vacuous (0.0).  A
      degradation ladder must not step past this point if it wants to
      keep returning quantified answers.
    * **pool spill** — the true neighbour can be evicted by spurious
      full-collision points.  Per Definition 1 each subspace's activated
      prefix covers ``alpha * n`` points, so under the proof's
      independence step a random point fully collides w.p.
      ``alpha ** Ns`` and the expected impostor count is
      ``n * alpha**Ns``; Markov bounds the spill probability by
      ``alpha**Ns / beta``.  The term is monotone in the budget: shrinking
      ``beta`` at fixed ``alpha`` strictly lowers the floor.

    Returns ``max(0, theorem2 - spill)`` clamped to [0, 1].
    """
    if beta <= 0.0:
        return 0.0
    if int(beta * n) < k:
        return 0.0
    base = theorem2_bound(n, k, n_subspaces, m, sigma, alpha)
    spill = min(1.0, alpha**n_subspaces / beta)
    return max(0.0, min(1.0, base - spill))


def suggest_parameters(
    n: int, d: int, k: int, m: float, sigma: float, *, target_prob: float = 0.5
) -> dict:
    """Search a small grid for (Ns, alpha) meeting the Theorem 2 bound.

    beta is set by the paper's practical recipe (Section 5.3.3):
    beta in [0.003, 0.005], larger for harder (higher-LID) data.
    """
    best = None
    for ns in (6, 8, 10, 12, 16):
        if d // ns < 2:
            continue
        for alpha in (0.01, 0.03, 0.05, 0.1, 0.2):
            p = theorem2_bound(n, k, ns, m, sigma, alpha)
            if p >= target_prob and (best is None or alpha < best["alpha"]):
                best = dict(n_subspaces=ns, alpha=alpha, beta=0.005, prob=p)
    return best or dict(n_subspaces=8, alpha=0.1, beta=0.005, prob=0.0)
