"""Beyond-paper application: Subspace-Collision sparse attention.

Long-context decode spends its time scoring one query against an S=500k KV
cache.  The paper's insight — SC-score is a cheap, theoretically-grounded
proxy for nearest-neighbour rank — applies directly: treat the cached keys
as the dataset, the (RoPE'd) query as the query point, pick the top-(beta*S)
keys by SC-score, and run exact softmax attention on that candidate set
only.

Attention ranks keys by inner product, so the per-subspace "distance" here
is the negated partial dot product (max-inner-product collisions); under L2
on RMS-normalised keys the two coincide and the framework's guarantees
carry over.  Cost per
head drops from O(S*hd) to O(S*hd/Ns ... ) distances in subspaces of width
hd/Ns plus an O(beta*S*hd) exact pass — the same alpha/beta trade the paper
makes for ANN.

This module is exploratory (EXPERIMENTS.md §Beyond-paper): the quality
metric is *attention-mass recall* — the fraction of the true softmax mass
captured by the selected keys.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.core.collision import kth_smallest

__all__ = ["sc_select_keys", "sc_sparse_attention", "attention_mass_recall"]


def _subspace_scores(q: jax.Array, keys: jax.Array, n_subspaces: int, count: int) -> jax.Array:
    """``q: (hd,), keys: (S, hd) -> (S,)`` SC-scores with L2 collisions."""
    s, hd = keys.shape
    w = hd // n_subspaces
    kq = q[: w * n_subspaces].reshape(n_subspaces, w)
    kk = keys[:, : w * n_subspaces].reshape(s, n_subspaces, w).transpose(1, 0, 2)

    def per_sub(acc, inp):
        ks, qs = inp  # (S, w), (w,)
        # negated partial inner product: "closest" == largest q.k
        d = -(ks @ qs)
        tau = kth_smallest(d, count)
        return acc + (d <= tau).astype(jnp.int32), None

    scores, _ = jax.lax.scan(per_sub, jnp.zeros(s, jnp.int32), (kk, kq))
    return scores


def sc_select_keys(
    q: jax.Array,  # (H, hd)
    keys: jax.Array,  # (H, S, hd)
    *,
    n_subspaces: int = 4,
    alpha: float = 0.05,
    n_keep: int = 1024,
) -> jax.Array:
    """Per head: ids (H, n_keep) of the highest-SC-score keys."""
    s = keys.shape[1]
    count = max(1, int(alpha * s))

    def per_head(qh, kh):
        sc = _subspace_scores(qh, kh, n_subspaces, count)
        _, ids = jax.lax.top_k(sc, n_keep)
        return ids

    return jax.vmap(per_head)(q, keys)


@functools.partial(jax.jit, static_argnames=("n_subspaces", "alpha", "n_keep"))
def sc_sparse_attention(
    q: jax.Array,  # (H, hd)
    keys: jax.Array,  # (H, S, hd)
    values: jax.Array,  # (H, S, hd)
    *,
    n_subspaces: int = 4,
    alpha: float = 0.05,
    n_keep: int = 1024,
) -> tuple[jax.Array, jax.Array]:
    """Returns (output (H, hd), selected ids (H, n_keep))."""
    ids = sc_select_keys(q, keys, n_subspaces=n_subspaces, alpha=alpha, n_keep=n_keep)
    scale = 1.0 / math.sqrt(q.shape[-1])

    def per_head(qh, kh, vh, idh):
        ks = jnp.take(kh, idh, axis=0)  # (n_keep, hd)
        vs = jnp.take(vh, idh, axis=0)
        logits = (ks @ qh) * scale
        w = jax.nn.softmax(logits)
        return w @ vs

    out = jax.vmap(per_head)(q, keys, values, ids)
    return out, ids


def attention_mass_recall(q: jax.Array, keys: jax.Array, ids: jax.Array) -> jax.Array:
    """Fraction of the full softmax mass captured by the selected keys."""
    scale = 1.0 / math.sqrt(q.shape[-1])

    def per_head(qh, kh, idh):
        logits = (kh @ qh) * scale
        w = jax.nn.softmax(logits)
        return jnp.sum(jnp.take(w, idh))

    return jax.vmap(per_head)(q, keys, ids)
