"""Architecture registry: --arch <id> resolution + reduced smoke configs."""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

_MODULES = {
    "rwkv6-1.6b": "rwkv6_1p6b",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "whisper-large-v3": "whisper_large_v3",
    "qwen1.5-4b": "qwen15_4b",
    "phi4-mini-3.8b": "phi4_mini_3p8b",
    "granite-3-2b": "granite3_2b",
    "gemma2-9b": "gemma2_9b",
    "zamba2-1.2b": "zamba2_1p2b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "mixtral-8x7b": "mixtral_8x7b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def reduced_config(name: str) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests (shapes shrink, structure
    — GQA ratios, expert counts, patterns — is preserved)."""
    cfg = get_config(name)
    kv_ratio = max(1, cfg.n_heads // cfg.n_kv_heads)
    n_heads = 4
    n_kv = max(1, n_heads // kv_ratio)
    upd: dict = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=128,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        vocab_chunk=64,
        max_learned_pos=4096,
    )
    if cfg.family == "moe":
        upd.update(n_experts=8 if cfg.n_experts >= 64 else 4,
                   top_k_experts=min(cfg.top_k_experts, 2))
    if cfg.family == "hybrid":
        upd.update(n_layers=8, hybrid_period=3, ssm_state=16)
    if cfg.family == "ssm":
        upd.update(n_layers=4)
    if cfg.family == "audio":
        upd.update(encoder_layers=2, encoder_seq=64)
    if cfg.family == "vlm":
        upd.update(n_layers=5, cross_attn_period=5, vision_tokens=48)
    if cfg.local_global:
        upd.update(local_window=32)
    if cfg.sliding_window is not None:
        upd.update(sliding_window=32)
    return dataclasses.replace(cfg, **upd)
