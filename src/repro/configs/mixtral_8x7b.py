"""mixtral-8x7b — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    n_experts=8, top_k_experts=2, sliding_window=4096,
)
