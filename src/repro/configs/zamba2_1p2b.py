"""zamba2-1.2b — Mamba2 backbone + shared attention block every 6 layers
[arXiv:2411.15242]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_conv=4, hybrid_period=6,
)
