"""llama-3.2-vision-11b — cross-attn image layers (backbone only; the vision
encoder is a stub: input_specs supplies precomputed patch embeddings)
[hf:meta-llama/Llama-3.2-11B-Vision]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=128256,
    rope_theta=500000.0, cross_attn_period=5, vision_tokens=1601,
)
