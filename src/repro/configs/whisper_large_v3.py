"""whisper-large-v3 — enc-dec; conv/audio frontend is a stub (input_specs
supplies precomputed 1500-frame embeddings) [arXiv:2212.04356]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, head_dim=64,
    d_ff=5120, vocab_size=51866,
    encoder_layers=32, encoder_seq=1500,
    mlp="gelu", norm="layernorm", use_rope=False, learned_pos=True,
)
