"""Large-scale resilience utilities: straggler detection, failure-driven
restart, elastic re-sharding.

On thousands of nodes the dominant failure modes are (a) whole-job restart
after a hardware fault (handled by checkpoint+resume in launch/train.py),
(b) slow hosts dragging the synchronous step (detected here), (c) planned
re-scaling (handled by sharding-agnostic checkpoints, see train.checkpoint).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

__all__ = ["StepTimer", "FailureInjector", "run_with_restarts"]


@dataclasses.dataclass
class StepTimer:
    """EWMA step timer; flags stragglers at ``threshold`` x the running mean.

    On a real cluster the flagged step would page the straggler-mitigation
    policy (evict host / shrink mesh); here it feeds metrics + tests.
    """

    alpha: float = 0.1
    threshold: float = 2.0
    ewma: float | None = None
    flagged: int = 0
    _t0: float | None = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self) -> float:
        dt = time.perf_counter() - self._t0
        is_straggler = self.ewma is not None and dt > self.threshold * self.ewma
        if is_straggler:
            self.flagged += 1
        # stragglers don't poison the mean
        if self.ewma is None:
            self.ewma = dt
        elif not is_straggler:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return dt

    def is_straggler(self, dt: float) -> bool:
        return self.ewma is not None and dt > self.threshold * self.ewma


class FailureInjector:
    """Deterministic fault injection for restart tests: raises on the
    configured steps (once each)."""

    def __init__(self, fail_at: tuple[int, ...] = ()):  # global step numbers
        self.fail_at = set(fail_at)

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at:
            self.fail_at.remove(step)
            raise RuntimeError(f"injected node failure at step {step}")


def run_with_restarts(
    train_once: Callable[[], int],
    *,
    max_restarts: int = 3,
) -> int:
    """Run ``train_once`` (which resumes from the latest checkpoint) until it
    completes, restarting on failure up to ``max_restarts`` times.  Returns
    the number of restarts that occurred."""
    restarts = 0
    while True:
        try:
            train_once()
            return restarts
        except RuntimeError:
            restarts += 1
            if restarts > max_restarts:
                raise
