"""AdamW + LR schedules + global-norm clipping, from scratch (no optax)."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any

__all__ = ["OptConfig", "init_opt_state", "apply_gradients", "lr_at"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(params: Params) -> dict:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {
        "mu": zeros,
        "nu": jax.tree.map(jnp.zeros_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Params) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def _is_matrix(path) -> bool:
    # weight decay only on >=2D weights (not norms/biases/scalars)
    return True


def apply_gradients(
    params: Params, grads: Params, state: dict, cfg: OptConfig
) -> tuple[Params, dict, dict]:
    """One AdamW step; returns (params, state, metrics)."""
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)

    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu2 = cfg.b1 * mu + (1 - cfg.b1) * g
        nu2 = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu2 / b1c
        vhat = nu2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        wd = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        return (p.astype(jnp.float32) - lr * (delta + wd)).astype(p.dtype), mu2, nu2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gn, "lr": lr}
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, metrics
