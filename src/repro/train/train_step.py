"""Train step factory: loss -> grads -> AdamW, with optional gradient
accumulation (scan over microbatches) and int8-compressed data-parallel
all-reduce (shard_map path)."""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.train.optimizer import OptConfig, apply_gradients

__all__ = ["make_train_step", "make_eval_step"]


def make_train_step(
    model: Model,
    opt_cfg: OptConfig,
    *,
    micro_steps: int = 1,
    remat: bool = True,
) -> Callable:
    """Returns ``step(params, opt_state, batch) -> (params, opt_state, metrics)``.

    With ``micro_steps > 1`` the global batch is split along axis 0 and
    gradients are accumulated with a ``lax.scan`` — memory scales with the
    microbatch, FLOPs are unchanged.
    """

    def loss_fn(params, batch):
        return model.loss(params, batch, remat=remat)

    def step(params, opt_state, batch):
        if micro_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(x):
                return x.reshape(micro_steps, x.shape[0] // micro_steps, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def body(acc, mb):
                loss_mb, g = jax.value_and_grad(loss_fn)(params, mb)
                return (
                    acc[0] + loss_mb / micro_steps,
                    jax.tree.map(lambda a, b: a + b / micro_steps, acc[1], g),
                ), None

            zero = (
                jnp.zeros((), jnp.float32),
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            )
            (loss, grads), _ = jax.lax.scan(body, zero, micro)
        new_params, new_state, metrics = apply_gradients(params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss)
        return new_params, new_state, metrics

    return step


def make_eval_step(model: Model, *, remat: bool = False) -> Callable:
    def step(params, batch):
        return model.loss(params, batch, remat=remat)

    return step
