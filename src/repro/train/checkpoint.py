"""Fault-tolerant checkpointing: atomic, async, sharding-agnostic.

Layout:  <dir>/step_00001230/arrays.npz + manifest.json
         <dir>/step_00001230.tmp...    (atomic rename on completion)

* Arrays are saved logically-unsharded (device_get), so a checkpoint written
  on one mesh restores onto ANY mesh — this is the elastic-scaling path:
  pass new ``shardings`` to :func:`restore` and every leaf is device_put with
  the new layout.
* ``save(..., blocking=False)`` hands the write to a background thread; the
  next save joins it first (at most one outstanding write, never torn:
  the rename happens last).
* ``keep`` bounds disk usage; pruning never removes the newest checkpoint.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "all_steps"]

_PENDING: threading.Thread | None = None


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten(tree_like: Any, flat: dict[str, np.ndarray]) -> Any:
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    new_leaves = []
    for path, leaf in leaves_with_path:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        new_leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def _step_dir(root: Path, step: int) -> Path:
    return root / f"step_{step:08d}"


def all_steps(root: str | os.PathLike) -> list[int]:
    root = Path(root)
    if not root.exists():
        return []
    out = []
    for p in root.iterdir():
        if p.is_dir() and p.name.startswith("step_") and (p / "manifest.json").exists():
            out.append(int(p.name.split("_")[1]))
    return sorted(out)


def latest_step(root: str | os.PathLike) -> int | None:
    steps = all_steps(root)
    return steps[-1] if steps else None


def _write(root: Path, step: int, flat_groups: dict[str, dict[str, np.ndarray]],
           extra: dict, keep: int) -> None:
    final = _step_dir(root, step)
    tmp = Path(str(final) + f".tmp{os.getpid()}")
    tmp.mkdir(parents=True, exist_ok=True)
    manifest = {"step": step, "time": time.time(), "groups": {}, "extra": extra}
    for group, flat in flat_groups.items():
        np.savez(tmp / f"{group}.npz", **flat)
        manifest["groups"][group] = sorted(flat)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic publish
    # prune
    steps = all_steps(root)
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(_step_dir(root, s), ignore_errors=True)


def save(
    root: str | os.PathLike,
    step: int,
    *,
    params: Any,
    opt_state: Any | None = None,
    extra: dict | None = None,
    keep: int = 3,
    blocking: bool = True,
) -> None:
    global _PENDING
    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)
    if _PENDING is not None:
        _PENDING.join()
        _PENDING = None
    groups = {"params": _flatten(params)}
    if opt_state is not None:
        groups["opt_state"] = _flatten(opt_state)
    if blocking:
        _write(root, step, groups, extra or {}, keep)
    else:
        t = threading.Thread(
            target=_write, args=(root, step, groups, extra or {}, keep), daemon=True
        )
        t.start()
        _PENDING = t


def wait_for_pending() -> None:
    global _PENDING
    if _PENDING is not None:
        _PENDING.join()
        _PENDING = None


def restore(
    root: str | os.PathLike,
    *,
    params_like: Any,
    opt_state_like: Any | None = None,
    step: int | None = None,
    shardings: Any | None = None,
    opt_shardings: Any | None = None,
) -> tuple[int, Any, Any | None, dict]:
    """Load a checkpoint; optionally re-shard onto a (new) mesh layout."""
    root = Path(root)
    step = step if step is not None else latest_step(root)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {root}")
    d = _step_dir(root, step)
    manifest = json.loads((d / "manifest.json").read_text())

    def load_group(name, like, shard):
        flat = dict(np.load(d / f"{name}.npz"))
        tree = _unflatten(like, flat)
        if shard is not None:
            tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shard)
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return tree

    params = load_group("params", params_like, shardings)
    opt_state = None
    if opt_state_like is not None and "opt_state" in manifest["groups"]:
        opt_state = load_group("opt_state", opt_state_like, opt_shardings)
    return step, params, opt_state, manifest.get("extra", {})
