"""Gradient compression for the data-parallel all-reduce.

``int8_allreduce``: per-shard symmetric int8 quantisation + all_gather of
(payload, scale) + local dequant-sum.  Bytes on the wire: n/4 per hop vs
fp32 ring all-reduce's ~2n — a win for the gradient-sized messages the DP
axis moves every step.  Combine with :class:`ErrorFeedback` so quantisation
error is re-injected next step (standard EF-SGD; keeps convergence).

Used by the shard_map data-parallel train wrapper (``--grad-compression``
in launch/train.py); the pjit path leaves reduction to XLA.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "int8_allreduce", "ErrorFeedback",
           "compressed_grad_allreduce"]


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def int8_allreduce(x: jax.Array, axis_name: str | tuple[str, ...]) -> jax.Array:
    """Mean over `axis_name` with int8 payloads (inside shard_map)."""
    q, s = quantize_int8(x)
    qg = jax.lax.all_gather(q, axis_name)  # (P, ...) int8
    sg = jax.lax.all_gather(s, axis_name)  # (P,)
    n = qg.shape[0]
    deq = qg.astype(jnp.float32) * sg.reshape((n,) + (1,) * x.ndim)
    return jnp.sum(deq, axis=0) / n


def compressed_grad_allreduce(grads: Any, axis_name, residuals: Any) -> tuple[Any, Any]:
    """Error-feedback int8 all-reduce over a gradient pytree."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = quantize_int8(gf)
        local_deq = dequantize_int8(q, s)
        new_r = gf - local_deq  # error feedback
        qg = jax.lax.all_gather(q, axis_name)
        sg = jax.lax.all_gather(s, axis_name)
        n = qg.shape[0]
        mean = jnp.sum(
            qg.astype(jnp.float32) * sg.reshape((n,) + (1,) * g.ndim), axis=0
        ) / n
        return mean.astype(g.dtype), new_r

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return tdef.unflatten([o[0] for o in out]), tdef.unflatten([o[1] for o in out])


class ErrorFeedback:
    """Residual initialiser for :func:`compressed_grad_allreduce`."""

    @staticmethod
    def init(grads_like: Any) -> Any:
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
