from repro.train.optimizer import OptConfig, apply_gradients, init_opt_state, lr_at
from repro.train.train_step import make_train_step, make_eval_step
from repro.train import checkpoint, compression, resilience

__all__ = [
    "OptConfig", "apply_gradients", "init_opt_state", "lr_at",
    "make_train_step", "make_eval_step", "checkpoint", "compression",
    "resilience",
]
