"""Scalar-prefetch gather + exact-distance Pallas kernel (the re-rank hot
loop).

Re-ranking gathers ``m_c = beta*n`` candidate rows (per query) from the
dataset and computes exact squared distances to the query.  On TPU the
candidate ids are *scalar-prefetched* into SMEM so they can drive the
``BlockSpec`` index map: grid step ``i`` DMAs exactly row ``ids[i]`` from HBM
into VMEM.  Pallas pipelines these block fetches across grid steps, so the
gather gets double-buffered DMA/compute overlap for free — this is the
canonical TPU embedding-gather pattern.

Layout: queries and ids are flattened to one grid, ``ids: (mq * mc,)``;
``q`` is indexed by ``i // mc``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, x_ref, q_ref, out_ref):
    del ids_ref  # only used by the index maps
    xr = x_ref[...].astype(jnp.float32)  # (1, d)
    qr = q_ref[...].astype(jnp.float32)  # (1, d)
    diff = xr - qr
    out_ref[...] = jnp.sum(diff * diff, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("mc", "interpret"))
def gather_rerank_kernel(
    ids: jax.Array,  # (mq*mc,) int32 candidate row ids
    x: jax.Array,  # (n, d)
    q: jax.Array,  # (mq, d)
    *,
    mc: int,
    interpret: bool = False,
) -> jax.Array:
    total = ids.shape[0]
    d = x.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(total,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, ids_ref: (ids_ref[i], 0)),
            pl.BlockSpec((1, d), lambda i, ids_ref: (i // mc, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, ids_ref: (i, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((total, 1), jnp.float32),
        interpret=interpret,
    )(ids, x, q)
