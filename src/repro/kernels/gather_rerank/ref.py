"""Pure-jnp oracles for the gather_rerank kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_rerank_ref(ids: jax.Array, x: jax.Array, q: jax.Array) -> jax.Array:
    """``ids: (mq, mc), x: (n, d), q: (mq, d) -> (mq, mc)`` exact sq-L2."""
    xc = jnp.take(x, ids, axis=0).astype(jnp.float32)  # (mq, mc, d)
    diff = xc - q[:, None, :].astype(jnp.float32)
    return jnp.sum(diff * diff, axis=-1)


def gather_rerank_block_ref(
    cols: jax.Array, x_blk: jax.Array, q: jax.Array, *, metric: str = "l2"
) -> jax.Array:
    """``cols: (m, c), x_blk: (bn, d), q: (m, d) -> (m, c)`` exact distances.

    The per-query candidate form the fused streaming engine reranks with:
    ``cols`` are row ids into ``x_blk`` — one chunk or the whole dataset
    (already validated by the op boundary).  The fp semantics are pinned to
    :func:`repro.core.distances.rowwise_candidate_dist` — the exact
    reduction :func:`repro.core.sc_linear.rerank_candidates` uses — so an
    in-pass distance is bit-identical to the post-scan gather it replaces.
    """
    # Imported lazily: the kernels package must stay importable before
    # repro.core finishes initialising (core pulls these ops in).
    from repro.core.distances import rowwise_candidate_dist

    xc = jnp.take(x_blk, cols, axis=0)  # (m, c, d)
    return rowwise_candidate_dist(q, xc, metric)
