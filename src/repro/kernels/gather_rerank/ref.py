"""Pure-jnp oracle for the gather_rerank kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_rerank_ref(ids: jax.Array, x: jax.Array, q: jax.Array) -> jax.Array:
    """``ids: (mq, mc), x: (n, d), q: (mq, d) -> (mq, mc)`` exact sq-L2."""
    xc = jnp.take(x, ids, axis=0).astype(jnp.float32)  # (mq, mc, d)
    diff = xc - q[:, None, :].astype(jnp.float32)
    return jnp.sum(diff * diff, axis=-1)
