"""jit'd wrappers for gather_rerank.

Candidate-id validation happens here, once, at the op boundary: candidate
pools are padded with sentinels (``-1`` or ``INT32_MAX``) whose distances
the caller's selection discards, but whose raw values must not fault the
scalar-prefetch index map or poison the gather.  Both entry points clip
ids into ``[0, n-1]`` before dispatch, so no caller has to pre-sanitise.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gather_rerank.kernel import gather_rerank_kernel
from repro.kernels.gather_rerank.ref import gather_rerank_block_ref, gather_rerank_ref


def _clip_ids(ids: jax.Array, n: int) -> jax.Array:
    """Clip sentinel / out-of-range candidate ids into ``[0, n-1]``."""
    return jnp.clip(ids.astype(jnp.int32), 0, n - 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_rerank(
    ids: jax.Array, x: jax.Array, q: jax.Array, *, interpret: bool = False
) -> jax.Array:
    """``ids: (mq, mc), x: (n, d), q: (mq, d) -> (mq, mc)`` exact sq-L2."""
    mq, mc = ids.shape
    flat = _clip_ids(ids, x.shape[0]).reshape(-1)
    out = gather_rerank_kernel(flat, x, q, mc=mc, interpret=interpret)
    return out.reshape(mq, mc)


@functools.partial(jax.jit, static_argnames=("metric", "impl", "interpret"))
def gather_rerank_block(
    cols: jax.Array,
    x_blk: jax.Array,
    q: jax.Array,
    *,
    metric: str = "l2",
    impl: str = "auto",
    interpret: bool = False,
) -> jax.Array:
    """Per-query candidate rerank: ``cols: (m, c)`` row ids into
    ``x_blk: (bn, d)``, ``q: (m, d) -> (m, c)`` exact distances.

    The fused streaming engine's in-pass rerank stage: each chunk's
    Pareto-prefilter survivors (O(cap) rows, not the whole chunk) are
    gathered and reranked mid-scan, instead of re-fetched from the full
    dataset after it — ``x_blk`` may be one resident chunk or the whole
    dataset with global ids; the op only ever touches the ``c`` addressed
    rows.  ``impl``: "jnp" | "pallas" | "auto" (pallas iff on TPU and
    ``metric="l2"`` — the scalar-prefetch kernel computes sq-L2; L1
    always takes the jnp oracle).  Sentinel ids are clipped at this
    boundary; their distances are real but the caller's selection never
    consumes them.
    """
    cols = _clip_ids(cols, x_blk.shape[0])
    use_kernel = metric == "l2" and (
        impl == "pallas" or (impl == "auto" and jax.default_backend() == "tpu")
    )
    if not use_kernel:
        return gather_rerank_block_ref(cols, x_blk, q, metric=metric)
    m, c = cols.shape
    out = gather_rerank_kernel(
        cols.reshape(-1), x_blk, q, mc=c, interpret=interpret
    )
    return out.reshape(m, c)


__all__ = ["gather_rerank", "gather_rerank_block", "gather_rerank_block_ref", "gather_rerank_ref"]


# --------------------------------------------------------------------------
# jaxlint registry hook (see repro.analysis)
# --------------------------------------------------------------------------

#: Tile contract: the scalar-prefetch gather addresses one (1, d) row per
#: grid step, so only the lane (minor-dim) alignment binds; the (1, 1)
#: distance output has no lane constraint.
TILE_CONTRACT = {
    "sublane": 8,
    "lane": 128,
    "double_buffer": 2,
    "block_align": {
        0: ((1, 128),),  # x row (1, d)
        1: ((1, 128),),  # q row (1, d)
    },
}


def jaxlint_entries():
    from repro.analysis.registry import JaxprEntry, TileEntry

    S = jax.ShapeDtypeStruct
    n, d, mq, mc = 4_096, 128, 8, 64

    def make_kernel():
        return jax.make_jaxpr(
            lambda i, x, q: gather_rerank_kernel(i, x, q, mc=mc, interpret=True)
        )(
            S((mq * mc,), jnp.int32),
            S((n, d), jnp.float32),
            S((mq, d), jnp.float32),
        )

    def make_oracle():
        return jax.make_jaxpr(
            lambda c, x, q: gather_rerank_block(c, x, q, impl="jnp")
        )(
            S((mq, mc), jnp.int32),
            S((n, d), jnp.float32),
            S((mq, d), jnp.float32),
        )

    return [
        TileEntry(
            name="kernels.gather_rerank.kernel",
            make=make_kernel,
            contract=TILE_CONTRACT,
            note="scalar-prefetch candidate gather + exact sq-L2",
        ),
        JaxprEntry(
            name="kernels.gather_rerank.oracle",
            make=make_oracle,
            rules=("bounded-intermediate", "pinned-accumulator"),
            budget_bytes=4 * 2 * mq * mc * d,
            note="jnp oracle of the candidate rerank (the production CPU path)",
        ),
    ]
