"""jit'd wrapper for gather_rerank."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gather_rerank.kernel import gather_rerank_kernel
from repro.kernels.gather_rerank.ref import gather_rerank_ref


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_rerank(
    ids: jax.Array, x: jax.Array, q: jax.Array, *, interpret: bool = False
) -> jax.Array:
    """``ids: (mq, mc), x: (n, d), q: (mq, d) -> (mq, mc)`` exact sq-L2."""
    mq, mc = ids.shape
    flat = ids.reshape(-1).astype(jnp.int32)
    out = gather_rerank_kernel(flat, x, q, mc=mc, interpret=interpret)
    return out.reshape(mq, mc)


__all__ = ["gather_rerank", "gather_rerank_ref"]
