"""jit'd wrapper for the fused SC-score kernel: pads blocks, dispatches."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.sc_score.kernel import (
    sc_score_cells_kernel,
    sc_score_cells_prefilter_compact_kernel,
    sc_score_cells_prefilter_kernel,
    sc_score_kernel,
)
from repro.kernels.sc_score.ref import (
    sc_score_cells_prefilter_compact_ref,
    sc_score_cells_prefilter_ref,
    sc_score_cells_ref,
    sc_score_ref,
)


def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def sc_scores_fused(
    qs: jax.Array,  # (Ns, m, s)
    xs: jax.Array,  # (Ns, n, s)
    tau: jax.Array,  # (Ns, m)
    *,
    bm: int = 8,
    bn: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Fused threshold-compare + accumulate; padding contract: padded data
    rows sit at +inf distance (never collide), padded query rows are junk
    and sliced off, padded dims are zeros (distance-neutral)."""
    n_sub, m, s = qs.shape
    n = xs.shape[1]
    bm_ = min(bm, _round_up(m, 8))
    bn_ = min(bn, _round_up(n, 128))
    sp = _round_up(s, 128)
    mp, np_ = _round_up(m, bm_), _round_up(n, bn_)
    qp = jnp.pad(qs, ((0, 0), (0, mp - m), (0, sp - s)))
    xp = jnp.pad(xs, ((0, 0), (0, 0), (0, sp - s)))
    xp = jnp.pad(xp, ((0, 0), (0, np_ - n), (0, 0)), constant_values=1e6)
    taup = jnp.pad(tau, ((0, 0), (0, mp - m)))
    out = sc_score_kernel(qp, xp, taup, bm=bm_, bn=bn_, interpret=interpret)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "impl", "interpret"))
def sc_scores_cells(
    ranks: jax.Array,  # (Ns, m, K) per-(subspace, query) cell ranks
    cuts: jax.Array,  # (Ns, m) activation cutoff ranks
    cells: jax.Array,  # (Ns, bc) chunk cell ids
    *,
    bm: int = 8,
    bn: int = 512,
    impl: str = "auto",
    interpret: bool = False,
) -> jax.Array:
    """Chunked SuCo collision scores ``-> (m, bc)`` int32.

    ``impl``: "jnp" | "pallas" | "auto" (pallas iff running on TPU; the
    jnp oracle is the production CPU path — interpret-mode Pallas is for
    tests only).  Padding contract for the kernel: padded queries get cut
    -1 (nothing activates), padded K entries get rank INT32_MAX (never
    inside a prefix), padded chunk columns gather cell 0 and are sliced
    off.
    """
    if impl == "jnp" or (impl == "auto" and jax.default_backend() != "tpu"):
        return sc_score_cells_ref(ranks, cuts, cells)
    n_sub, m, k_cells = ranks.shape
    bc = cells.shape[1]
    bm_ = min(bm, _round_up(m, 8))
    bn_ = min(bn, _round_up(bc, 128))
    mp, bcp = _round_up(m, bm_), _round_up(bc, bn_)
    kp = _round_up(k_cells, 128)
    rp = jnp.pad(
        ranks, ((0, 0), (0, mp - m), (0, kp - k_cells)),
        constant_values=jnp.iinfo(jnp.int32).max,
    )
    cutp = jnp.pad(cuts, ((0, 0), (0, mp - m)), constant_values=-1)
    cellp = jnp.pad(cells, ((0, 0), (0, bcp - bc)))
    out = sc_score_cells_kernel(rp, cutp, cellp, bm=bm_, bn=bn_, interpret=interpret)
    return out[:m, :bc]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "impl", "interpret"))
def sc_scores_cells_prefilter(
    ranks: jax.Array,  # (Ns, m, K) per-(subspace, query) cell ranks
    cuts: jax.Array,  # (Ns, m) activation cutoff ranks
    cells: jax.Array,  # (Ns, bc) chunk cell ids
    thr: jax.Array,  # (m,) carried pool minimum score per query
    *,
    bm: int = 8,
    bn: int = 512,
    impl: str = "auto",
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused chunk stage for the single-pass engine ``-> (scores, keep)``.

    :func:`sc_scores_cells` plus the Pareto prefilter computed while the
    score tile is still resident: ``keep[q, j] = scores[q, j] > thr[q]``
    (``(m, bc)`` bool).  Same ``impl`` dispatch and padding contract as
    :func:`sc_scores_cells`; padded query rows additionally get
    ``thr = INT32_MAX`` so they never survive, and padded chunk columns
    are sliced off before the caller sees them (the caller still masks
    columns past the end of the *data*, which this op cannot know about).
    """
    if impl == "jnp" or (impl == "auto" and jax.default_backend() != "tpu"):
        return sc_score_cells_prefilter_ref(ranks, cuts, cells, thr)
    n_sub, m, k_cells = ranks.shape
    bc = cells.shape[1]
    int_max = jnp.iinfo(jnp.int32).max
    bm_ = min(bm, _round_up(m, 8))
    bn_ = min(bn, _round_up(bc, 128))
    mp, bcp = _round_up(m, bm_), _round_up(bc, bn_)
    kp = _round_up(k_cells, 128)
    rp = jnp.pad(
        ranks, ((0, 0), (0, mp - m), (0, kp - k_cells)),
        constant_values=int_max,
    )
    cutp = jnp.pad(cuts, ((0, 0), (0, mp - m)), constant_values=-1)
    thrp = jnp.pad(
        thr[None, :].astype(jnp.int32), ((0, 0), (0, mp - m)),
        constant_values=int_max,
    )
    cellp = jnp.pad(cells, ((0, 0), (0, bcp - bc)))
    out_s, out_k = sc_score_cells_prefilter_kernel(
        rp, cutp, thrp, cellp, bm=bm_, bn=bn_, interpret=interpret
    )
    return out_s[:m, :bc], out_k[:m, :bc].astype(bool)


@functools.partial(
    jax.jit, static_argnames=("cap", "bm", "bn", "impl", "interpret")
)
def sc_scores_cells_prefilter_compact(
    ranks: jax.Array,  # (Ns, m, K) per-(subspace, query) cell ranks
    cuts: jax.Array,  # (Ns, m) activation cutoff ranks
    cells: jax.Array,  # (Ns, bc) chunk cell ids
    thr: jax.Array,  # (m,) carried pool minimum score per query
    limit: jax.Array,  # () count of valid chunk columns (traced ok)
    keep_cols: jax.Array | None = None,  # (bc,) bool live-column mask
    *,
    cap: int,
    bm: int = 8,
    bn: int = 512,
    impl: str = "auto",
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One-launch chunk stage for the single-pass engine:
    ``-> (scores, surv_cols, surv_scores, count)``.

    :func:`sc_scores_cells_prefilter` plus the survivor compaction the
    fused query used to run as a host-graph cumsum/searchsorted/gather —
    here it happens while the score tile is still resident, so the whole
    score -> prune stage is a single ``pallas_call`` per chunk.  Outputs
    (all int32): the chunk scores with columns ``>= limit`` masked to the
    -1 sentinel, the compacted chunk-local survivor columns and their
    scores (``(m, cap)``, ascending-column order, 0 / -1 in empty slots),
    and the *true* per-query survivor count (``(m,)``, may exceed ``cap``
    — the caller's exact-fallback signal; overflowed slots are dropped).

    ``keep_cols`` (optional ``(bc,) bool``, default all-live) is the
    live-mutation tombstone mask: False columns are deleted points.  The
    jnp oracle folds it into the validity mask exactly like ``limit`` (a
    dead column scores -1, never survives, never consumes a compaction
    slot).  The Pallas path keeps the existing kernel — no new kernel for
    mutation — and post-masks instead: dead columns' scores and any dead
    survivors' slot scores drop to -1; ``count`` then *overcounts* dead
    survivors, which is conservative (the caller's exact overflow fallback
    fires at worst more often, and its top_k sees the masked -1 scores, so
    answers are unchanged).

    Same ``impl`` dispatch and padding contract as
    :func:`sc_scores_cells`; padded query rows get ``thr = INT32_MAX`` so
    they never survive, and ``cap`` is rounded up to a lane multiple for
    the kernel then sliced back.
    """
    if impl == "jnp" or (impl == "auto" and jax.default_backend() != "tpu"):
        return sc_score_cells_prefilter_compact_ref(
            ranks, cuts, cells, thr, limit, keep_cols, cap=cap
        )
    n_sub, m, k_cells = ranks.shape
    bc = cells.shape[1]
    int_max = jnp.iinfo(jnp.int32).max
    bm_ = min(bm, _round_up(m, 8))
    bn_ = min(bn, _round_up(bc, 128))
    mp, bcp = _round_up(m, bm_), _round_up(bc, bn_)
    kp = _round_up(k_cells, 128)
    capp = _round_up(cap, 128)
    rp = jnp.pad(
        ranks, ((0, 0), (0, mp - m), (0, kp - k_cells)),
        constant_values=int_max,
    )
    cutp = jnp.pad(cuts, ((0, 0), (0, mp - m)), constant_values=-1)
    thrp = jnp.pad(
        thr[None, :].astype(jnp.int32), ((0, 0), (0, mp - m)),
        constant_values=int_max,
    )
    limp = jnp.reshape(limit, (1, 1)).astype(jnp.int32)
    cellp = jnp.pad(cells, ((0, 0), (0, bcp - bc)))
    out_s, out_c, out_ss, out_n = sc_score_cells_prefilter_compact_kernel(
        rp, cutp, thrp, limp, cellp, bm=bm_, bn=bn_, cap=capp,
        interpret=interpret,
    )
    out_s = out_s[:m, :bc]
    out_c, out_ss, out_n = out_c[:m, :cap], out_ss[:m, :cap], out_n[:m, 0]
    if keep_cols is not None:
        out_s = jnp.where(keep_cols[None, :], out_s, -1)
        dead_slot = jnp.logical_not(jnp.take(keep_cols, out_c))
        out_ss = jnp.where(dead_slot, -1, out_ss)
    return out_s, out_c, out_ss, out_n


__all__ = [
    "sc_scores_fused",
    "sc_scores_cells",
    "sc_scores_cells_prefilter",
    "sc_scores_cells_prefilter_compact",
    "sc_score_ref",
    "sc_score_cells_ref",
    "sc_score_cells_prefilter_ref",
    "sc_score_cells_prefilter_compact_ref",
]


# --------------------------------------------------------------------------
# jaxlint registry hook (see repro.analysis)
# --------------------------------------------------------------------------

# Canonical pre-padded kernel shapes for tile validation (Ns, m, K, chunk,
# subspace width) — already lane/sublane aligned, as the op wrappers
# guarantee before dispatching.
_LINT_NS, _LINT_M, _LINT_K, _LINT_BC, _LINT_S = 4, 8, 2_560, 512, 128

#: TPU tile contract shared by the SC-score kernels: f32/int32 blocks keep
#: a lane-multiple minor dim and a sublane-multiple second-minor dim; the
#: (1, bm)-shaped per-query rows ride the sublane quantum only.
TILE_CONTRACT = {
    "sublane": 8,
    "lane": 128,
    "double_buffer": 2,
}


def jaxlint_entries():
    from repro.analysis.registry import JaxprEntry, TileEntry

    S = jax.ShapeDtypeStruct
    ns, m, K, bc, s = _LINT_NS, _LINT_M, _LINT_K, _LINT_BC, _LINT_S

    def make_cells():
        return jax.make_jaxpr(
            lambda r, c, ce: sc_score_cells_kernel(
                r, c, ce, bm=8, bn=512, interpret=True
            )
        )(S((ns, m, K), jnp.int32), S((ns, m), jnp.int32), S((ns, bc), jnp.int32))

    def make_prefilter():
        return jax.make_jaxpr(
            lambda r, c, t, ce: sc_score_cells_prefilter_kernel(
                r, c, t, ce, bm=8, bn=512, interpret=True
            )
        )(
            S((ns, m, K), jnp.int32),
            S((ns, m), jnp.int32),
            S((1, m), jnp.int32),
            S((ns, bc), jnp.int32),
        )

    def make_prefilter_compact():
        return jax.make_jaxpr(
            lambda r, c, t, lim, ce: sc_score_cells_prefilter_compact_kernel(
                r, c, t, lim, ce, bm=8, bn=512, cap=128, interpret=True
            )
        )(
            S((ns, m, K), jnp.int32),
            S((ns, m), jnp.int32),
            S((1, m), jnp.int32),
            S((1, 1), jnp.int32),
            S((ns, bc), jnp.int32),
        )

    def make_prefilter_compact_scan():
        # The compact kernel as the fused query runs it: inside the chunk
        # scan.  Gates the in-kernel compaction (cumsum + one-hot matmul)
        # against the no-scatter/no-sort and accumulator-dtype rules.
        def scan_compact(r, c, t, lim, cells_blocks):
            def step(carry, ce):
                outs = sc_score_cells_prefilter_compact_kernel(
                    r, c, t, lim, ce, bm=8, bn=512, cap=128, interpret=True
                )
                return carry, outs[3]
            return jax.lax.scan(step, jnp.zeros((), jnp.int32), cells_blocks)

        return jax.make_jaxpr(scan_compact)(
            S((ns, m, K), jnp.int32),
            S((ns, m), jnp.int32),
            S((1, m), jnp.int32),
            S((1, 1), jnp.int32),
            S((4, ns, bc), jnp.int32),
        )

    def make_fused():
        return jax.make_jaxpr(
            lambda q, x, tau: sc_score_kernel(q, x, tau, bm=8, bn=512, interpret=True)
        )(
            S((ns, m, s), jnp.float32),
            S((ns, 1_024, s), jnp.float32),
            S((ns, m), jnp.float32),
        )

    def make_oracle():
        return jax.make_jaxpr(
            lambda r, c, ce: sc_scores_cells(r, c, ce, impl="jnp")
        )(S((ns, m, K), jnp.int32), S((ns, m), jnp.int32), S((ns, bc), jnp.int32))

    return [
        TileEntry(
            name="kernels.sc_score.cells",
            make=make_cells,
            contract={
                **TILE_CONTRACT,
                # mapping index (inputs then outputs) -> ((dim, multiple), ...)
                "block_align": {
                    0: ((1, 8), (2, 128)),  # ranks (1, bm, K)
                    1: ((1, 8),),  # cuts (1, bm)
                    2: ((1, 128),),  # cells (1, bn)
                    3: ((0, 8), (1, 128)),  # out (bm, bn)
                },
            },
            note="chunked IMI scorer: gather-compare-accumulate",
        ),
        TileEntry(
            name="kernels.sc_score.cells_prefilter",
            make=make_prefilter,
            contract={
                **TILE_CONTRACT,
                "block_align": {
                    0: ((1, 8), (2, 128)),  # ranks (1, bm, K)
                    1: ((1, 8),),  # cuts (1, bm)
                    2: ((1, 8),),  # thr (1, bm)
                    3: ((1, 128),),  # cells (1, bn)
                    4: ((0, 8), (1, 128)),  # scores (bm, bn)
                    5: ((0, 8), (1, 128)),  # keep (bm, bn)
                },
            },
            note="fused chunk stage: scores + Pareto-prefilter mask",
        ),
        TileEntry(
            name="kernels.sc_score.cells_prefilter_compact",
            make=make_prefilter_compact,
            contract={
                **TILE_CONTRACT,
                "block_align": {
                    0: ((1, 8), (2, 128)),  # ranks (1, bm, K)
                    1: ((1, 8),),  # cuts (1, bm)
                    2: ((1, 8),),  # thr (1, bm)
                    # 3: limit (1, 1) scalar — no alignment demand
                    4: ((1, 128),),  # cells (1, bn)
                    5: ((0, 8), (1, 128)),  # scores (bm, bn)
                    6: ((0, 8), (1, 128)),  # surv_cols (bm, cap)
                    7: ((0, 8), (1, 128)),  # surv_scores (bm, cap)
                    8: ((0, 8),),  # count (bm, 1)
                },
            },
            note="one-launch chunk stage: scores + in-kernel survivor compaction",
        ),
        JaxprEntry(
            name="kernels.sc_score.prefilter_compact_scan",
            make=make_prefilter_compact_scan,
            rules=("no-scatter-in-scan", "pinned-accumulator"),
            note=(
                "compact kernel inside the chunk scan: the in-kernel "
                "compaction stays scatter/sort-free"
            ),
        ),
        TileEntry(
            name="kernels.sc_score.fused_distance",
            make=make_fused,
            contract={
                **TILE_CONTRACT,
                "block_align": {
                    0: ((1, 8), (2, 128)),  # qs (1, bm, s)
                    1: ((1, 128), (2, 128)),  # xs (1, bn, s)
                    2: ((1, 8),),  # tau (1, bm)
                    3: ((0, 8), (1, 128)),  # out (bm, bn)
                },
            },
            note="MXU distance + threshold-accumulate scorer",
        ),
        JaxprEntry(
            name="kernels.sc_score.oracle",
            make=make_oracle,
            rules=("bounded-intermediate", "pinned-accumulator"),
            budget_bytes=4 * 2 * ns * m * max(K, bc),
            note="jnp oracle of the chunked scorer (the production CPU path)",
        ),
    ]
