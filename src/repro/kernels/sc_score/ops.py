"""jit'd wrapper for the fused SC-score kernel: pads blocks, dispatches."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.sc_score.kernel import sc_score_kernel
from repro.kernels.sc_score.ref import sc_score_ref


def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def sc_scores_fused(
    qs: jax.Array,  # (Ns, m, s)
    xs: jax.Array,  # (Ns, n, s)
    tau: jax.Array,  # (Ns, m)
    *,
    bm: int = 8,
    bn: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Fused threshold-compare + accumulate; padding contract: padded data
    rows sit at +inf distance (never collide), padded query rows are junk
    and sliced off, padded dims are zeros (distance-neutral)."""
    n_sub, m, s = qs.shape
    n = xs.shape[1]
    bm_ = min(bm, _round_up(m, 8))
    bn_ = min(bn, _round_up(n, 128))
    sp = _round_up(s, 128)
    mp, np_ = _round_up(m, bm_), _round_up(n, bn_)
    qp = jnp.pad(qs, ((0, 0), (0, mp - m), (0, sp - s)))
    xp = jnp.pad(xs, ((0, 0), (0, 0), (0, sp - s)))
    xp = jnp.pad(xp, ((0, 0), (0, np_ - n), (0, 0)), constant_values=1e6)
    taup = jnp.pad(tau, ((0, 0), (0, mp - m)))
    out = sc_score_kernel(qp, xp, taup, bm=bm_, bn=bn_, interpret=interpret)
    return out[:m, :n]


__all__ = ["sc_scores_fused", "sc_score_ref"]
