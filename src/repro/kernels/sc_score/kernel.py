"""Fused SC-score accumulation Pallas kernel — the paper's inner loop.

Given per-subspace query/data blocks and per-(subspace, query) collision
thresholds tau, computes

    scores[q, j] = sum_i [ ||q_i - x_ij||^2 <= tau[i, q] ]

in one pass: the distance block is formed on the MXU (norm + matmul
identity), compared against tau in VREGs, and accumulated into an int32
score tile that lives in the output across the subspace grid dimension —
the (Ns, m, n) distance tensor never touches HBM.

Grid = (m/bm, n/bn, Ns); subspace innermost so the output tile revisits.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, x_ref, tau_ref, out_ref, *, n_sub: int):
    i = pl.program_id(2)  # subspace index (innermost)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    qb = q_ref[0].astype(jnp.float32)  # (bm, s)
    xb = x_ref[0].astype(jnp.float32)  # (bn, s)
    tau = tau_ref[...].astype(jnp.float32)  # (1, bm)
    qn = jnp.sum(qb * qb, axis=1, keepdims=True)  # (bm, 1)
    xn = jnp.sum(xb * xb, axis=1, keepdims=True).T  # (1, bn)
    cross = jax.lax.dot_general(
        qb, xb, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    d2 = jnp.maximum(qn + xn - 2.0 * cross, 0.0)  # (bm, bn)
    out_ref[...] += (d2 <= tau.T).astype(jnp.int32)


def _cells_kernel(rank_ref, cut_ref, cell_ref, out_ref):
    i = pl.program_id(2)  # subspace index (innermost)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    r = rank_ref[0]  # (bm, K) per-query cell ranks
    cut = cut_ref[...].astype(jnp.int32)  # (1, bm) activation cutoffs
    cells = cell_ref[0]  # (bn,) chunk cell ids
    g = jnp.take(r, cells, axis=1)  # (bm, bn) rank of each point's cell
    out_ref[...] += (g <= cut.T).astype(jnp.int32)


def _cells_prefilter_kernel(
    rank_ref, cut_ref, thr_ref, cell_ref, score_ref, keep_ref, *, n_sub: int
):
    i = pl.program_id(2)  # subspace index (innermost)

    @pl.when(i == 0)
    def _init():
        score_ref[...] = jnp.zeros_like(score_ref)
        keep_ref[...] = jnp.zeros_like(keep_ref)

    r = rank_ref[0]  # (bm, K) per-query cell ranks
    cut = cut_ref[...].astype(jnp.int32)  # (1, bm) activation cutoffs
    cells = cell_ref[0]  # (bn,) chunk cell ids
    g = jnp.take(r, cells, axis=1)  # (bm, bn) rank of each point's cell
    score_ref[...] += (g <= cut.T).astype(jnp.int32)

    # Pareto prefilter, fused into the last subspace visit: once the score
    # tile is complete, compare it against the carried pool minimum while
    # it is still in VMEM — the survivors mask costs one VPU compare
    # instead of a second pass over the (m, bc) score block.
    @pl.when(i == n_sub - 1)
    def _prefilter():
        thr = thr_ref[...].astype(jnp.int32)  # (1, bm) pool minima
        keep_ref[...] = (score_ref[...] > thr.T).astype(jnp.int32)


def _cells_prefilter_compact_kernel(
    rank_ref, cut_ref, thr_ref, limit_ref, cell_ref,
    score_ref, svcol_ref, svscore_ref, cnt_ref,
    *, n_sub: int, bn: int, cap: int,
):
    j = pl.program_id(1)  # column-block index (sequential -> cnt accumulates)
    i = pl.program_id(2)  # subspace index (innermost)

    @pl.when((j == 0) & (i == 0))
    def _init_survivors():
        svcol_ref[...] = jnp.zeros_like(svcol_ref)
        svscore_ref[...] = jnp.full_like(svscore_ref, -1)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    @pl.when(i == 0)
    def _init_scores():
        score_ref[...] = jnp.zeros_like(score_ref)

    r = rank_ref[0]  # (bm, K) per-query cell ranks
    cut = cut_ref[...].astype(jnp.int32)  # (1, bm) activation cutoffs
    cells = cell_ref[0]  # (bn,) chunk cell ids
    g = jnp.take(r, cells, axis=1)  # (bm, bn) rank of each point's cell
    score_ref[...] += (g <= cut.T).astype(jnp.int32)

    # Survivor compaction, fused into the last subspace visit: while the
    # completed score tile is resident, columns past ``limit`` are masked
    # to the -1 sentinel, the Pareto prefilter picks the survivors, and a
    # running in-block cumsum assigns each survivor its destination slot.
    # The slot write is a one-hot matmul on the MXU (scatter-free; each
    # slot is written exactly once across the whole column sweep, so the
    # += against the -1/0 initialisation recovers the exact value: the
    # one-hot contraction sums integers < 2^24, exact in f32).  The
    # (bm, bn, cap) one-hot is the kernel's VMEM high-water mark —
    # ~bm*bn*cap*4 bytes, 4 MB at the (8, 512, 256) defaults — which the
    # autotuner's survivor_cap model keeps inside the fast-memory budget.
    @pl.when(i == n_sub - 1)
    def _compact():
        bm = score_ref.shape[0]
        col = j * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
        s = jnp.where(col < limit_ref[0, 0], score_ref[...], -1)
        score_ref[...] = s
        thr = thr_ref[...].astype(jnp.int32)  # (1, bm) pool minima
        keep = s > thr.T  # (bm, bn)
        incl = jnp.cumsum(keep.astype(jnp.int32), axis=1)  # (bm, bn)
        base = cnt_ref[...][:, 0]  # (bm,) survivors before this block
        dest = base[:, None] + incl - 1  # slot of each kept column
        write = keep & (dest < cap)
        onehot = (
            (dest[:, :, None] == jax.lax.broadcasted_iota(jnp.int32, (bm, bn, cap), 2))
            & write[:, :, None]
        ).astype(jnp.float32)
        batch_contract = (((1,), (1,)), ((0,), (0,)))
        svscore_ref[...] += jax.lax.dot_general(
            (s + 1).astype(jnp.float32), onehot,
            dimension_numbers=batch_contract,
            preferred_element_type=jnp.float32,
        ).astype(jnp.int32)
        svcol_ref[...] += jax.lax.dot_general(
            col.astype(jnp.float32), onehot,
            dimension_numbers=batch_contract,
            preferred_element_type=jnp.float32,
        ).astype(jnp.int32)
        cnt_ref[...] += incl[:, -1:]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "cap", "interpret"))
def sc_score_cells_prefilter_compact_kernel(
    ranks: jax.Array,  # (Ns, m, K) per-(subspace, query) cell ranks
    cuts: jax.Array,  # (Ns, m) activation cutoff ranks
    thr: jax.Array,  # (1, m) carried pool minimum score per query
    limit: jax.Array,  # (1, 1) number of valid (non-padding) columns
    cells: jax.Array,  # (Ns, bc) cell ids of one data chunk
    *,
    bm: int = 8,
    bn: int = 512,
    cap: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused chunk stage with in-kernel survivor compaction.

    :func:`sc_score_cells_prefilter_kernel` taken one step further: instead
    of a keep-mask that the host graph still has to cumsum/searchsorted/
    gather, the kernel emits the compacted survivors directly — the fused
    query's score->prune stage becomes *one* kernel launch per chunk.

    Outputs (all int32):

    * ``scores (m, bc)`` — the chunk scores, columns ``>= limit`` masked
      to the -1 sentinel (the caller no longer masks padding itself);
    * ``surv_cols (m, cap)`` — chunk-local column of the j-th survivor in
      ascending-column order (0 for empty slots);
    * ``surv_scores (m, cap)`` — its score (-1 for empty slots);
    * ``count (m, 1)`` — the *true* survivor count, which may exceed
      ``cap`` (overflow slots are dropped; the caller detects
      ``count > cap`` and falls back to an exact full merge).

    The survivor tiles revisit across the whole (column-block, subspace)
    grid sweep, so the running count threads destination slots across
    column blocks without any host round trip.  Caller pre-pads
    ``m % bm == bc % bn == 0`` and ``cap % 128 == 0``.
    """
    n_sub, m, k_cells = ranks.shape
    bc = cells.shape[1]
    grid = (m // bm, bc // bn, n_sub)
    return pl.pallas_call(
        functools.partial(
            _cells_prefilter_compact_kernel, n_sub=n_sub, bn=bn, cap=cap
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, k_cells), lambda i, j, k: (k, i, 0)),
            pl.BlockSpec((1, bm), lambda i, j, k: (k, i)),
            pl.BlockSpec((1, bm), lambda i, j, k: (0, i)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bm, cap), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bm, cap), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, bc), jnp.int32),
            jax.ShapeDtypeStruct((m, cap), jnp.int32),
            jax.ShapeDtypeStruct((m, cap), jnp.int32),
            jax.ShapeDtypeStruct((m, 1), jnp.int32),
        ],
        interpret=interpret,
    )(ranks, cuts, thr, limit, cells)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def sc_score_cells_prefilter_kernel(
    ranks: jax.Array,  # (Ns, m, K) per-(subspace, query) cell ranks
    cuts: jax.Array,  # (Ns, m) activation cutoff ranks
    thr: jax.Array,  # (1, m) carried pool minimum score per query
    cells: jax.Array,  # (Ns, bc) cell ids of one data chunk
    *,
    bm: int = 8,
    bn: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused chunk stage: SC-scores + Pareto-prefilter survivors mask.

    :func:`sc_score_cells_kernel` with one extra input (the per-query
    carried pool minimum ``thr``) and one extra output: ``keep[q, j] =
    scores[q, j] > thr[q]`` (int32 0/1), emitted on the final subspace
    grid step while the completed score tile is still resident — the
    fused streaming engine's prune decision never re-reads the scores
    from HBM.  Caller pre-pads ``m % bm == bc % bn == 0``; returns
    ``(scores (m, bc) int32, keep (m, bc) int32)``.
    """
    n_sub, m, k_cells = ranks.shape
    bc = cells.shape[1]
    grid = (m // bm, bc // bn, n_sub)
    return pl.pallas_call(
        functools.partial(_cells_prefilter_kernel, n_sub=n_sub),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, k_cells), lambda i, j, k: (k, i, 0)),
            pl.BlockSpec((1, bm), lambda i, j, k: (k, i)),
            pl.BlockSpec((1, bm), lambda i, j, k: (0, i)),
            pl.BlockSpec((1, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, bc), jnp.int32),
            jax.ShapeDtypeStruct((m, bc), jnp.int32),
        ],
        interpret=interpret,
    )(ranks, cuts, thr, cells)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def sc_score_cells_kernel(
    ranks: jax.Array,  # (Ns, m, K) per-(subspace, query) cell ranks
    cuts: jax.Array,  # (Ns, m) activation cutoff ranks
    cells: jax.Array,  # (Ns, bc) cell ids of one data chunk
    *,
    bm: int = 8,
    bn: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Chunked IMI entry point: fused gather-compare-accumulate.

    The SuCo collision test (point j collides with query q in subspace i
    iff its IMI cell sits inside the activated ascending-distance prefix)
    is ``rank[i, q, cells[i, j]] <= cut[i, q]`` — the same
    threshold-compare + int32-accumulate structure as :func:`sc_score_kernel`
    with the MXU distance block replaced by a VMEM rank gather.  Grid =
    (m/bm, bc/bn, Ns), subspace innermost so the output tile revisits; the
    (m, n) score matrix never exists — callers stream chunks of ``bc``
    points and merge into a running top pool.

    Caller pre-pads m % bm == bc % bn == 0.  Returns (m, bc) int32.
    """
    n_sub, m, k_cells = ranks.shape
    bc = cells.shape[1]
    grid = (m // bm, bc // bn, n_sub)
    return pl.pallas_call(
        _cells_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, k_cells), lambda i, j, k: (k, i, 0)),
            pl.BlockSpec((1, bm), lambda i, j, k: (k, i)),
            pl.BlockSpec((1, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, bc), jnp.int32),
        interpret=interpret,
    )(ranks, cuts, cells)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def sc_score_kernel(
    qs: jax.Array,  # (Ns, m, s) per-subspace queries (zero-padded s)
    xs: jax.Array,  # (Ns, n, s) per-subspace data
    tau: jax.Array,  # (Ns, m) collision thresholds
    *,
    bm: int = 8,
    bn: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Caller pre-pads m % bm == n % bn == 0. Returns (m, n) int32 scores."""
    n_sub, m, s = qs.shape
    n = xs.shape[1]
    grid = (m // bm, n // bn, n_sub)
    return pl.pallas_call(
        functools.partial(_kernel, n_sub=n_sub),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, s), lambda i, j, k: (k, i, 0)),
            pl.BlockSpec((1, bn, s), lambda i, j, k: (k, j, 0)),
            pl.BlockSpec((1, bm), lambda i, j, k: (k, i)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(qs, xs, tau)
