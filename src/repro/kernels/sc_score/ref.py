"""Pure-jnp oracle for the fused SC-score kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sc_score_cells_ref(
    ranks: jax.Array, cuts: jax.Array, cells: jax.Array
) -> jax.Array:
    """``ranks: (Ns,m,K), cuts: (Ns,m), cells: (Ns,bc) -> (m,bc)`` int32.

    Oracle for the chunked IMI kernel: point j collides with query q in
    subspace i iff the rank of its cell is within the activation cutoff.
    """
    g = jax.vmap(lambda r, c: jnp.take(r, c, axis=-1))(ranks, cells)  # (Ns,m,bc)
    mask = g <= cuts[:, :, None]
    return jnp.sum(mask.astype(jnp.int32), axis=0)


def sc_score_cells_prefilter_ref(
    ranks: jax.Array, cuts: jax.Array, cells: jax.Array, thr: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Oracle for the fused score + Pareto-prefilter chunk stage.

    ``thr: (m,)`` is the per-query carried pool minimum; returns the
    chunk scores plus ``keep = scores > thr[:, None]`` — the rows that
    could possibly enter a top pool whose minimum is ``thr`` (everything
    else is pruned before the merge, exactly).
    """
    s = sc_score_cells_ref(ranks, cuts, cells)
    return s, s > thr[:, None]


def sc_score_cells_prefilter_compact_ref(
    ranks: jax.Array,
    cuts: jax.Array,
    cells: jax.Array,
    thr: jax.Array,
    limit: jax.Array,
    keep_cols: jax.Array | None = None,
    *,
    cap: int,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Oracle for the fused score + prefilter + survivor-compaction stage.

    ``thr: (m,)`` is the per-query carried pool minimum and ``limit`` the
    (possibly traced) count of valid chunk columns; columns at or past it
    are masked to the -1 score sentinel and can never survive.
    ``keep_cols`` (optional, ``(bc,) bool``) further restricts the valid
    columns — the live-mutation tombstone mask: a False column is masked
    to -1 exactly like one past ``limit``, so deleted points neither
    survive nor occupy compaction slots nor count toward ``count``.
    Returns ``(scores (m, bc), surv_cols (m, cap), surv_scores (m, cap),
    count (m,))``: the j-th survivor (ascending column order, exactly the
    keep-mask compaction the fused query used to run on the host) sits at
    slot j; empty slots hold column 0 / score -1; ``count`` is the true
    survivor count and may exceed ``cap`` (the caller's overflow signal).
    The compaction is a binary search on the keep-mask's monotone cumsum —
    no sort or scatter touches the ``(m, bc)`` block.
    """
    bc = cells.shape[1]
    s = sc_score_cells_ref(ranks, cuts, cells)
    col = jnp.arange(bc, dtype=jnp.int32)
    ok = col[None, :] < limit
    if keep_cols is not None:
        ok = jnp.logical_and(ok, keep_cols[None, :])
    s = jnp.where(ok, s, -1)
    keep = s > thr[:, None]
    cnt = jnp.cumsum(keep.astype(jnp.int32), axis=1)
    slot = jnp.arange(cap, dtype=jnp.int32)
    surv = jax.vmap(lambda row: jnp.searchsorted(row, slot + 1, side="left"))(cnt)
    surv = jnp.minimum(surv, bc - 1).astype(jnp.int32)
    total = cnt[:, -1]
    live = slot[None, :] < total[:, None]
    surv_cols = jnp.where(live, surv, 0)
    surv_scores = jnp.where(live, jnp.take_along_axis(s, surv, axis=1), -1)
    return s, surv_cols, surv_scores, total


def sc_score_ref(qs: jax.Array, xs: jax.Array, tau: jax.Array) -> jax.Array:
    """``qs: (Ns,m,s), xs: (Ns,n,s), tau: (Ns,m) -> (m,n)`` int32 scores."""
    qf, xf = qs.astype(jnp.float32), xs.astype(jnp.float32)
    d2 = (
        jnp.sum(qf * qf, axis=-1)[:, :, None]
        + jnp.sum(xf * xf, axis=-1)[:, None, :]
        - 2.0 * jnp.einsum("ims,ins->imn", qf, xf, preferred_element_type=jnp.float32)
    )
    d2 = jnp.maximum(d2, 0.0)
    mask = d2 <= tau[:, :, None]
    return jnp.sum(mask.astype(jnp.int32), axis=0)
