"""Pallas TPU kernels for the perf-critical hot spots.

Each kernel directory follows the kernel.py (pallas_call + BlockSpec) /
ops.py (jit'd public wrapper) / ref.py (pure-jnp oracle) convention and is
validated under ``interpret=True`` in tests/test_kernels.py.
"""

from repro.kernels.pairwise_l2.ops import pairwise_sqdist
from repro.kernels.kmeans_assign.ops import kmeans_assign
from repro.kernels.gather_rerank.ops import gather_rerank
from repro.kernels.linear_attn.ops import linear_attention
from repro.kernels.sc_score.ops import sc_scores_cells, sc_scores_fused

__all__ = ["pairwise_sqdist", "kmeans_assign", "gather_rerank",
           "linear_attention", "sc_scores_fused", "sc_scores_cells"]
