"""Pure-jnp oracles for the kmeans_assign kernel family."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def kmeans_assign_ref(x: jax.Array, centroids: jax.Array) -> jax.Array:
    """``(n, s), (k, s) -> (n,)`` int32 nearest-centroid ids."""
    xf = x.astype(jnp.float32)
    cf = centroids.astype(jnp.float32)
    d2 = (
        jnp.sum(xf * xf, axis=1)[:, None]
        + jnp.sum(cf * cf, axis=1)[None, :]
        - 2.0 * jnp.einsum("ns,ks->nk", xf, cf, preferred_element_type=jnp.float32)
    )
    return jnp.argmin(d2, axis=1).astype(jnp.int32)


def kmeans_assign_batched_ref(x: jax.Array, centroids: jax.Array) -> jax.Array:
    """``(B, n, s), (B, k, s) -> (B, n)`` int32 nearest-centroid ids."""
    return jax.vmap(kmeans_assign_ref)(x, centroids)


def kmeans_pair_assign_hist_ref(
    x: jax.Array, centroids: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Oracle for the fused pair assignment + IMI histogram kernel.

    ``x: (2*Ns, n, s)``, ``centroids: (2*Ns, k, s)`` in SuCo's paired
    half-subspace layout -> ``(assign (2*Ns, n) int32, cell_counts
    (Ns, k*k) int32)`` with ``cell_counts[i, a1*k + a2]`` the occupancy of
    each IMI cell.
    """
    b = x.shape[0]
    ns = b // 2
    k = centroids.shape[1]
    a = kmeans_assign_batched_ref(x, centroids)  # (2*Ns, n)
    cells = a[:ns] * k + a[ns:]  # (Ns, n)
    counts = jax.vmap(
        lambda c: jnp.bincount(c, length=k * k).astype(jnp.int32)
    )(cells)
    return a, counts


def kmeans_stats_ref(
    x: jax.Array, centroids: jax.Array, weights: jax.Array | None = None
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Dense oracle for the fused Lloyd-statistics kernel.

    ``x: (B, n, s)``, ``centroids: (B, k, s)``, ``weights: (n,)`` (or None
    for all-ones) -> ``(assign (B, n) int32, sums (B, k, s) f32,
    counts (B, k) f32, inertia (B,) f32)``.  Deliberately materialises the
    ``(B, n, k)`` one-hot — it is the *reference semantics* the streaming
    paths must reproduce, not a production path.
    """
    b, n, s = x.shape
    k = centroids.shape[1]
    xf = x.astype(jnp.float32)
    cf = centroids.astype(jnp.float32)
    d2 = (
        jnp.sum(xf * xf, axis=2)[:, :, None]
        + jnp.sum(cf * cf, axis=2)[:, None, :]
        - 2.0 * jnp.einsum("bns,bks->bnk", xf, cf, preferred_element_type=jnp.float32)
    )
    a = jnp.argmin(d2, axis=2)  # (B, n)
    w = jnp.ones((n,), jnp.float32) if weights is None else weights.astype(jnp.float32)
    oh = jax.nn.one_hot(a, k, dtype=jnp.float32) * w[None, :, None]  # (B, n, k)
    sums = jnp.einsum("bnk,bns->bks", oh, xf, preferred_element_type=jnp.float32)
    counts = jnp.sum(oh, axis=1)  # (B, k)
    best = jnp.min(d2, axis=2)  # (B, n)
    inertia = jnp.sum(best * w[None, :], axis=1)  # (B,)
    return a.astype(jnp.int32), sums, counts, inertia
