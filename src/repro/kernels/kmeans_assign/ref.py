"""Pure-jnp oracle for the kmeans_assign kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def kmeans_assign_ref(x: jax.Array, centroids: jax.Array) -> jax.Array:
    """``(n, s), (k, s) -> (n,)`` int32 nearest-centroid ids."""
    xf = x.astype(jnp.float32)
    cf = centroids.astype(jnp.float32)
    d2 = (
        jnp.sum(xf * xf, axis=1)[:, None]
        + jnp.sum(cf * cf, axis=1)[None, :]
        - 2.0 * jnp.einsum("ns,ks->nk", xf, cf, preferred_element_type=jnp.float32)
    )
    return jnp.argmin(d2, axis=1).astype(jnp.int32)
