"""jit'd wrappers for the kmeans_assign kernel family: padding + dispatch.

Three entry points, all with the same padding contract (dims zero-padded,
centroid rows padded far away, point rows padded then masked/sliced):

* :func:`kmeans_assign`         — ``(n, s)`` single-problem assignments.
* :func:`kmeans_assign_batched` — ``(B, n, s)`` batched assignments (the
  SuCo ``2*Ns``-codebook layout) without vmap-of-pallas.
* :func:`kmeans_assign_stats`   — fused assignments + per-centroid
  ``(sums, counts, inertia)`` Lloyd statistics in one streaming pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.kmeans_assign.kernel import (
    kmeans_assign_batched_kernel,
    kmeans_assign_kernel,
    kmeans_pair_assign_hist_kernel,
    kmeans_stats_kernel,
)
from repro.kernels.kmeans_assign.ref import (
    kmeans_assign_batched_ref,
    kmeans_assign_ref,
    kmeans_pair_assign_hist_ref,
    kmeans_stats_ref,
)

_CENTROID_PAD = 1.0e6  # padded centroids sit ~1e12 away -> never win argmin


def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def _route_to_ref(impl: str, interpret: bool) -> bool:
    """True when the jnp oracle should run instead of the kernel."""
    if impl not in ("auto", "jnp", "pallas"):
        raise ValueError(f"impl must be 'auto'|'jnp'|'pallas', got {impl!r}")
    return impl == "jnp" or (
        impl == "auto" and jax.default_backend() != "tpu" and not interpret
    )


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def kmeans_assign(
    x: jax.Array, centroids: jax.Array, *, bn: int = 1024, interpret: bool = False
) -> jax.Array:
    """``(n, s), (k, s) -> (n,)`` int32 fused distance+argmin."""
    n, s = x.shape
    k, _ = centroids.shape
    sp = _round_up(s, 128)
    kp = _round_up(k, 8)
    bn_ = min(bn, _round_up(n, 8))
    np_ = _round_up(n, bn_)
    xp = jnp.pad(x, ((0, np_ - n), (0, sp - s)))
    cp = jnp.pad(centroids, ((0, 0), (0, sp - s)))
    cp = jnp.pad(cp, ((0, kp - k), (0, 0)), constant_values=_CENTROID_PAD)
    out = kmeans_assign_kernel(xp, cp, bn=bn_, interpret=interpret)
    return out[:n, 0]


def _pad_batched(x: jax.Array, centroids: jax.Array, bn: int):
    """Shared batched padding: returns (xp, cp, bn_, n, k, s).

    ``bn`` is a caller-supplied chunk size (e.g. SuCoConfig.block_n) and
    may be arbitrary; the kernel block size ``bn_`` is rounded up to a
    lane multiple (128) so the n-axis block shapes lower on real TPUs —
    the weights row makes bn the *minor* dim of one input.
    """
    _, n, s = x.shape
    k = centroids.shape[1]
    sp = _round_up(s, 128)
    kp = _round_up(k, 8)
    bn_ = min(_round_up(bn, 128), _round_up(n, 128))
    np_ = _round_up(n, bn_)
    xp = jnp.pad(x, ((0, 0), (0, np_ - n), (0, sp - s)))
    cp = jnp.pad(centroids, ((0, 0), (0, 0), (0, sp - s)))
    cp = jnp.pad(cp, ((0, 0), (0, kp - k), (0, 0)), constant_values=_CENTROID_PAD)
    return xp, cp, bn_, n, k, s


@functools.partial(jax.jit, static_argnames=("bn", "impl", "interpret"))
def kmeans_assign_batched(
    x: jax.Array,
    centroids: jax.Array,
    *,
    bn: int = 1024,
    impl: str = "auto",
    interpret: bool = False,
) -> jax.Array:
    """``(B, n, s), (B, k, s) -> (B, n)`` int32 batched fused distance+argmin.

    ``impl``: "jnp" | "pallas" | "auto" (pallas iff running on TPU).
    """
    if _route_to_ref(impl, interpret):
        return kmeans_assign_batched_ref(x, centroids)
    xp, cp, bn_, n, _, _ = _pad_batched(x, centroids, bn)
    out = kmeans_assign_batched_kernel(xp, cp, bn=bn_, interpret=interpret)
    return out[:, :n, 0]


@functools.partial(jax.jit, static_argnames=("bn", "impl", "with_assign", "interpret"))
def kmeans_assign_stats(
    x: jax.Array,
    centroids: jax.Array,
    *,
    bn: int = 1024,
    impl: str = "auto",
    with_assign: bool = True,
    interpret: bool = False,
) -> tuple[jax.Array | None, jax.Array, jax.Array, jax.Array]:
    """Fused Lloyd statistics: ``(B, n, s), (B, k, s) ->``
    ``(assign (B, n) int32 | None, sums (B, k, s) f32, counts (B, k) f32,
    inertia (B,) f32)`` — one streaming pass, no ``(n, k)`` intermediate.

    ``impl``: "jnp" | "pallas" | "auto" (pallas iff running on TPU; the
    jnp oracle is dense and only for small-n validation).
    ``with_assign=False`` skips the ``(B, n)`` assignment output — use it
    for Lloyd iterations, which consume only the statistics.
    """
    if _route_to_ref(impl, interpret):
        a, sums, counts, inertia = kmeans_stats_ref(x, centroids)
        return (a if with_assign else None), sums, counts, inertia
    xp, cp, bn_, n, k, s = _pad_batched(x, centroids, bn)
    np_ = xp.shape[1]
    w = (jnp.arange(np_, dtype=jnp.int32) < n).astype(jnp.float32)[None, :]
    a, sums, counts, inertia = kmeans_stats_kernel(
        xp, cp, w, bn=bn_, with_assign=with_assign, interpret=interpret
    )
    a_out = a[:, :n, 0] if with_assign else None
    return a_out, sums[:, :k, :s], counts[:, :k], inertia[:, 0]


@functools.partial(jax.jit, static_argnames=("bn", "impl", "interpret"))
def kmeans_pair_assign_hist(
    x: jax.Array,
    centroids: jax.Array,
    *,
    bn: int = 1024,
    impl: str = "auto",
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused final assignment + IMI occupancy histogram:
    ``(2*Ns, n, s), (2*Ns, k, s) -> (assign (2*Ns, n) int32,
    cell_counts (Ns, k*k) int32)``.

    SuCo's build needs both the final assignments *and* the per-subspace
    IMI cell occupancy ``bincount(a1 * k + a2)``; this op produces both in
    one streaming kernel pass — the histogram accumulates on the MXU as a
    matmul of the two halves' one-hots into a revisiting ``(k, k)`` tile,
    so no second pass over the assignments (and no scatter) ever runs.

    ``impl``: "jnp" | "pallas" | "auto" (pallas iff running on TPU).
    """
    b = x.shape[0]
    if b % 2:
        raise ValueError(f"paired layout needs an even batch, got B={b}")
    if _route_to_ref(impl, interpret):
        return kmeans_pair_assign_hist_ref(x, centroids)
    ns = b // 2
    xp, cp, bn_, n, k, _ = _pad_batched(x, centroids, bn)
    np_ = xp.shape[1]
    w = (jnp.arange(np_, dtype=jnp.int32) < n).astype(jnp.float32)[None, :]
    a1, a2, counts = kmeans_pair_assign_hist_kernel(
        xp, cp, w, ns=ns, bn=bn_, interpret=interpret
    )
    a = jnp.concatenate([a1, a2], axis=0)[:, :n, 0]
    # padded centroid rows never win the argmin, so their occupancy rows/
    # columns are zero and the (real k)^2 slice is the exact histogram
    counts = counts[:, :k, :k].reshape(ns, k * k).astype(jnp.int32)
    return a, counts


__all__ = [
    "kmeans_assign",
    "kmeans_assign_batched",
    "kmeans_assign_stats",
    "kmeans_pair_assign_hist",
    "kmeans_assign_ref",
    "kmeans_assign_batched_ref",
    "kmeans_pair_assign_hist_ref",
    "kmeans_stats_ref",
]


# --------------------------------------------------------------------------
# jaxlint registry hook (see repro.analysis)
# --------------------------------------------------------------------------

#: Tile contract for the batched codebook kernels: the data block keeps the
#: subspace width on lanes and the point block on sublanes; accumulator
#: tiles (sums/counts/inertia) revisit across the point grid.
TILE_CONTRACT = {
    "sublane": 8,
    "lane": 128,
    "double_buffer": 2,
}


def jaxlint_entries():
    from repro.analysis.registry import JaxprEntry, TileEntry

    S = jax.ShapeDtypeStruct
    b, n, s, k, bn = 8, 2_048, 128, 32, 1_024

    def make_batched():
        return jax.make_jaxpr(
            lambda x, c: kmeans_assign_batched_kernel(x, c, bn=bn, interpret=True)
        )(S((b, n, s), jnp.float32), S((b, k, s), jnp.float32))

    def make_stats():
        return jax.make_jaxpr(
            lambda x, c, w: kmeans_stats_kernel(
                x, c, w, bn=bn, with_assign=True, interpret=True
            )
        )(
            S((b, n, s), jnp.float32),
            S((b, k, s), jnp.float32),
            S((1, n), jnp.float32),
        )

    def make_pair_hist():
        return jax.make_jaxpr(
            lambda x, c, w: kmeans_pair_assign_hist_kernel(
                x, c, w, ns=b // 2, bn=bn, interpret=True
            )
        )(
            S((b, n, s), jnp.float32),
            S((b, k, s), jnp.float32),
            S((1, n), jnp.float32),
        )

    def make_oracle():
        return jax.make_jaxpr(
            lambda x, c: kmeans_assign_stats(x, c, impl="jnp")
        )(S((b, n, s), jnp.float32), S((b, k, s), jnp.float32))

    return [
        TileEntry(
            name="kernels.kmeans_assign.batched",
            make=make_batched,
            contract={
                **TILE_CONTRACT,
                "block_align": {
                    0: ((1, 8), (2, 128)),  # x (1, bn, s)
                    1: ((1, 8), (2, 128)),  # centroids (1, k, s)
                    2: ((1, 8),),  # assign out (1, bn, 1)
                },
            },
            note="batched fused distance+argmin assignment",
        ),
        TileEntry(
            name="kernels.kmeans_assign.stats",
            make=make_stats,
            contract={
                **TILE_CONTRACT,
                "block_align": {
                    0: ((1, 8), (2, 128)),  # x (1, bn, s)
                    1: ((1, 8), (2, 128)),  # centroids (1, k, s)
                    2: ((1, 128),),  # weights (1, bn)
                    3: ((1, 8),),  # assign out (1, bn, 1)
                    4: ((1, 8), (2, 128)),  # sums (1, k, s)
                    5: ((1, 8),),  # counts (1, k)
                },
            },
            note="fused Lloyd sufficient statistics",
        ),
        TileEntry(
            name="kernels.kmeans_assign.pair_hist",
            make=make_pair_hist,
            contract={
                **TILE_CONTRACT,
                "block_align": {
                    0: ((1, 8), (2, 128)),  # x first halves (1, bn, s)
                    1: ((1, 8), (2, 128)),  # x second halves (1, bn, s)
                    2: ((1, 8), (2, 128)),  # centroids 1 (1, k, s)
                    3: ((1, 8), (2, 128)),  # centroids 2 (1, k, s)
                    4: ((1, 128),),  # weights (1, bn)
                    5: ((1, 8),),  # a1 out (1, bn, 1)
                    6: ((1, 8),),  # a2 out (1, bn, 1)
                    7: ((1, 8), (2, 128)),  # counts (1, kh, kw)
                },
            },
            note="fused pair assignment + MXU IMI occupancy histogram",
        ),
        JaxprEntry(
            name="kernels.kmeans_assign.oracle",
            make=make_oracle,
            rules=("bounded-intermediate", "pinned-accumulator"),
            budget_bytes=4 * 2 * b * n * max(k, s),
            note="jnp oracle of the Lloyd statistics (dense, small-n only)",
        ),
    ]
