"""jit'd wrapper for kmeans_assign: padding + kernel dispatch."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.kmeans_assign.kernel import kmeans_assign_kernel
from repro.kernels.kmeans_assign.ref import kmeans_assign_ref

_CENTROID_PAD = 1.0e6  # padded centroids sit ~1e12 away -> never win argmin


def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def kmeans_assign(
    x: jax.Array, centroids: jax.Array, *, bn: int = 1024, interpret: bool = False
) -> jax.Array:
    """``(n, s), (k, s) -> (n,)`` int32 fused distance+argmin."""
    n, s = x.shape
    k, _ = centroids.shape
    sp = _round_up(s, 128)
    kp = _round_up(k, 8)
    bn_ = min(bn, _round_up(n, 8))
    np_ = _round_up(n, bn_)
    xp = jnp.pad(x, ((0, np_ - n), (0, sp - s)))
    cp = jnp.pad(centroids, ((0, 0), (0, sp - s)))
    cp = jnp.pad(cp, ((0, kp - k), (0, 0)), constant_values=_CENTROID_PAD)
    out = kmeans_assign_kernel(xp, cp, bn=bn_, interpret=interpret)
    return out[:n, 0]


__all__ = ["kmeans_assign", "kmeans_assign_ref"]
