"""Fused K-means assignment Pallas kernels: distance + argmin in one pass.

For ``x: (n, s)`` and ``centroids: (k, s)`` produces ``argmin_c ||x - c||^2``
without materialising the ``(n, k)`` distance matrix in HBM.  The whole
codebook (sqrt(K) ~ 50 rows) lives in VMEM for every grid step; points
stream through in ``bn`` blocks.

Three entry points share that structure:

* :func:`kmeans_assign_kernel` — single problem, assignments only.
* :func:`kmeans_assign_batched_kernel` — ``(B, n, s)`` batched layout (the
  SuCo build trains ``B = 2*Ns`` codebooks at once); grid ``(B, n/bn)``.
* :func:`kmeans_stats_kernel` — the streaming-Lloyd workhorse: per grid
  step it additionally folds the block's one-hot into per-centroid
  ``(sums, counts, inertia)`` accumulator tiles that revisit across the
  (innermost) point-block grid dimension — one kernel pass yields the
  complete Lloyd sufficient statistics with nothing of size ``(n, k)``
  ever leaving VMEM (the ``sc_score`` revisiting-tile pattern).

Padding contract (enforced by ops.py): pad dims with 0 (no distance effect),
pad centroid *rows* with a large constant so they never win the argmin, pad
point rows freely for assign-only kernels (junk assignments are sliced
off); the stats kernel additionally takes a ``(1, n)`` weight row that
zeroes padded points out of the accumulators.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, c_ref, out_ref):
    xb = x_ref[...].astype(jnp.float32)  # (bn, s)
    cb = c_ref[...].astype(jnp.float32)  # (k, s)
    xn = jnp.sum(xb * xb, axis=1, keepdims=True)  # (bn, 1)
    cn = jnp.sum(cb * cb, axis=1, keepdims=True).T  # (1, k)
    cross = jax.lax.dot_general(
        xb,
        cb,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    d2 = xn + cn - 2.0 * cross  # (bn, k)
    out_ref[...] = jnp.argmin(d2, axis=1).astype(jnp.int32)[:, None]


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def kmeans_assign_kernel(
    x: jax.Array, centroids: jax.Array, *, bn: int = 1024, interpret: bool = False
) -> jax.Array:
    """Caller pre-pads: n % bn == 0; s, k already VMEM-friendly. -> (n, 1)."""
    n, s = x.shape
    k, _ = centroids.shape
    grid = (n // bn,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, s), lambda i: (i, 0)),
            pl.BlockSpec((k, s), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.int32),
        interpret=interpret,
    )(x, centroids)


def _sqdist_block(x_ref, c_ref):
    """VMEM distance tile: ``(bn, s), (k, s) -> (bn, k)`` fp32."""
    xb = x_ref[0].astype(jnp.float32)
    cb = c_ref[0].astype(jnp.float32)
    xn = jnp.sum(xb * xb, axis=1, keepdims=True)  # (bn, 1)
    cn = jnp.sum(cb * cb, axis=1, keepdims=True).T  # (1, k)
    cross = jax.lax.dot_general(
        xb,
        cb,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return xn + cn - 2.0 * cross


def _batched_kernel(x_ref, c_ref, out_ref):
    d2 = _sqdist_block(x_ref, c_ref)  # (bn, k)
    out_ref[...] = jnp.argmin(d2, axis=1).astype(jnp.int32)[None, :, None]


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def kmeans_assign_batched_kernel(
    x: jax.Array, centroids: jax.Array, *, bn: int = 1024, interpret: bool = False
) -> jax.Array:
    """``(B, n, s), (B, k, s) -> (B, n, 1)`` batched fused distance+argmin.

    Caller pre-pads: n % bn == 0; s, k already VMEM-friendly.  One codebook
    per outer grid step; each codebook's points stream in ``bn`` blocks.
    """
    b, n, s = x.shape
    k = centroids.shape[1]
    grid = (b, n // bn)
    return pl.pallas_call(
        _batched_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn, s), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, k, s), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn, 1), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n, 1), jnp.int32),
        interpret=interpret,
    )(x, centroids)


def _pair_assign_hist_kernel(
    x1_ref, x2_ref, c1_ref, c2_ref, w_ref, a1_ref, a2_ref, counts_ref
):
    j = pl.program_id(1)  # point-block index (innermost -> counts revisit)

    @pl.when(j == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    d1 = _sqdist_block(x1_ref, c1_ref)  # (bn, k)
    d2 = _sqdist_block(x2_ref, c2_ref)  # (bn, k)
    a1 = jnp.argmin(d1, axis=1)  # (bn,)
    a2 = jnp.argmin(d2, axis=1)
    a1_ref[...] = a1.astype(jnp.int32)[None, :, None]
    a2_ref[...] = a2.astype(jnp.int32)[None, :, None]
    # The pair-cell histogram counts[c1, c2] factorises exactly as the
    # matmul of the two weighted one-hots — sum_p oh1[p, c1] * oh2[p, c2]
    # — so the (bn, k^2) flat-cell one-hot never exists: one (k, k) MXU
    # contraction per block, f32-exact (counts < 2^24), padded points
    # zeroed by the weight row.
    _, kh, kw = counts_ref.shape
    w = w_ref[...].astype(jnp.float32)[0]  # (bn,) 0/1 point weights
    oh1 = (
        a1[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, kh), 1)
    ).astype(jnp.float32) * w[:, None]  # (bn, kh)
    oh2 = (
        a2[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, kw), 1)
    ).astype(jnp.float32)  # (bn, kw)
    counts_ref[...] += jax.lax.dot_general(
        oh1,
        oh2,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[None]  # (1, kh, kw)


@functools.partial(jax.jit, static_argnames=("ns", "bn", "interpret"))
def kmeans_pair_assign_hist_kernel(
    x: jax.Array,  # (2*ns, n, s) paired half-subspace points
    centroids: jax.Array,  # (2*ns, k, s) paired codebooks
    weights: jax.Array,  # (1, n) 0/1 point weights
    *,
    ns: int,
    bn: int = 1024,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused final assignment + IMI occupancy histogram for SuCo's paired
    half-subspace layout: rows ``[:ns]`` of ``x``/``centroids`` are first
    halves, ``[ns:]`` second halves of the same subspaces.

    ``-> (a1 (ns, n, 1) int32, a2 (ns, n, 1) int32, counts (ns, kh, kw)
    f32)`` where ``counts[i, c1, c2]`` is the weighted occupancy of IMI
    cell ``c1 * k + c2`` in subspace ``i`` — the histogram that used to be
    a second pass over the assignments rides the assignment kernel's grid.
    Both halves of a subspace are visited in the *same* grid step (the
    operands are passed twice with index maps offset by ``ns``), so the
    pair cell is known while both argmin rows are still in VMEM and the
    histogram accumulates into a revisiting ``(1, kh, kw)`` tile across
    the (innermost) point-block dimension.

    Caller pre-pads ``n % bn == 0`` and sizes ``kh``/``kw`` of the counts
    tile; padded centroid rows must never win the argmin and padded points
    carry weight 0.
    """
    _, n, s = x.shape
    k = centroids.shape[1]
    kh = -(-k // 8) * 8
    kw = -(-k // 128) * 128
    grid = (ns, n // bn)
    return pl.pallas_call(
        _pair_assign_hist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn, s), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bn, s), lambda i, j: (i + ns, j, 0)),
            pl.BlockSpec((1, k, s), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, k, s), lambda i, j: (i + ns, 0, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, bn, 1), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bn, 1), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, kh, kw), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((ns, n, 1), jnp.int32),
            jax.ShapeDtypeStruct((ns, n, 1), jnp.int32),
            jax.ShapeDtypeStruct((ns, kh, kw), jnp.float32),
        ],
        interpret=interpret,
    )(x, x, centroids, centroids, weights)


def _accumulate_stats(x_ref, c_ref, w_ref, sums_ref, counts_ref, inertia_ref):
    """Shared stats body: distance + argmin + weighted one-hot fold into the
    revisiting accumulator tiles.  Returns the block's argmin row."""
    j = pl.program_id(1)  # point-block index (innermost -> accumulators revisit)

    @pl.when(j == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)
        inertia_ref[...] = jnp.zeros_like(inertia_ref)

    d2 = _sqdist_block(x_ref, c_ref)  # (bn, k)
    k = d2.shape[1]
    a = jnp.argmin(d2, axis=1)  # (bn,)
    w = w_ref[...].astype(jnp.float32)[0]  # (bn,) 0/1 point weights
    # One-hot on the VPU (2D iota — TPU disallows 1D), weighted so padded
    # points vanish from every accumulator.
    oh = (a[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)).astype(
        jnp.float32
    ) * w[:, None]  # (bn, k)
    xb = x_ref[0].astype(jnp.float32)  # (bn, s)
    sums_ref[...] += jax.lax.dot_general(
        oh,
        xb,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[None]  # (1, k, s)
    counts_ref[...] += jnp.sum(oh, axis=0)[None, :]  # (1, k)
    inertia_ref[...] += jnp.sum(jnp.min(d2, axis=1) * w)[None, None]  # (1, 1)
    return a


def _stats_kernel(x_ref, c_ref, w_ref, assign_ref, sums_ref, counts_ref, inertia_ref):
    a = _accumulate_stats(x_ref, c_ref, w_ref, sums_ref, counts_ref, inertia_ref)
    assign_ref[...] = a.astype(jnp.int32)[None, :, None]


def _stats_only_kernel(x_ref, c_ref, w_ref, sums_ref, counts_ref, inertia_ref):
    _accumulate_stats(x_ref, c_ref, w_ref, sums_ref, counts_ref, inertia_ref)


@functools.partial(jax.jit, static_argnames=("bn", "with_assign", "interpret"))
def kmeans_stats_kernel(
    x: jax.Array,
    centroids: jax.Array,
    weights: jax.Array,
    *,
    bn: int = 1024,
    with_assign: bool = True,
    interpret: bool = False,
) -> tuple[jax.Array | None, jax.Array, jax.Array, jax.Array]:
    """Fused Lloyd sufficient statistics for ``B`` batched codebooks.

    ``x: (B, n, s)``, ``centroids: (B, k, s)``, ``weights: (1, n)`` (0 for
    padded points) -> ``(assign (B, n, 1) int32 | None, sums (B, k, s)
    f32, counts (B, k) f32, inertia (B, 1) f32)``.

    Grid ``(B, n/bn)`` with the point-block axis innermost so the
    ``sums/counts/inertia`` output tiles revisit: each block's weighted
    one-hot is folded on the MXU while the block is already resident for
    the argmin — the ``(n, k)`` one-hot/distance matrices never exist
    outside a single ``(bn, k)`` VMEM tile.  ``with_assign=False`` drops
    the ``(B, n)`` assignment output entirely — Lloyd iterations only
    need the statistics, and XLA cannot DCE an unused pallas_call output,
    so keeping it would write B*n*4 bytes of dead HBM traffic per
    iteration.  Caller pre-pads n % bn == 0.
    """
    b, n, s = x.shape
    k = centroids.shape[1]
    grid = (b, n // bn)
    in_specs = [
        pl.BlockSpec((1, bn, s), lambda i, j: (i, j, 0)),
        pl.BlockSpec((1, k, s), lambda i, j: (i, 0, 0)),
        pl.BlockSpec((1, bn), lambda i, j: (0, j)),
    ]
    stats_specs = (
        pl.BlockSpec((1, k, s), lambda i, j: (i, 0, 0)),
        pl.BlockSpec((1, k), lambda i, j: (i, 0)),
        pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
    )
    stats_shapes = (
        jax.ShapeDtypeStruct((b, k, s), jnp.float32),
        jax.ShapeDtypeStruct((b, k), jnp.float32),
        jax.ShapeDtypeStruct((b, 1), jnp.float32),
    )
    if not with_assign:
        sums, counts, inertia = pl.pallas_call(
            _stats_only_kernel,
            grid=grid,
            in_specs=in_specs,
            out_specs=stats_specs,
            out_shape=stats_shapes,
            interpret=interpret,
        )(x, centroids, weights)
        return None, sums, counts, inertia
    return pl.pallas_call(
        _stats_kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=(pl.BlockSpec((1, bn, 1), lambda i, j: (i, j, 0)),) + stats_specs,
        out_shape=(jax.ShapeDtypeStruct((b, n, 1), jnp.int32),) + stats_shapes,
        interpret=interpret,
    )(x, centroids, weights)
