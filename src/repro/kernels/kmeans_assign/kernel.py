"""Fused K-means assignment Pallas kernel: distance + argmin in one pass.

For ``x: (n, s)`` and ``centroids: (k, s)`` produces ``argmin_c ||x - c||^2``
without materialising the ``(n, k)`` distance matrix in HBM.  The whole
codebook (sqrt(K) ~ 50 rows) lives in VMEM for every grid step; points
stream through in ``bn`` blocks.

Padding contract (enforced by ops.py): pad dims with 0 (no distance effect),
pad centroid *rows* with a large constant so they never win the argmin, pad
point rows freely (junk assignments are sliced off).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, c_ref, out_ref):
    xb = x_ref[...].astype(jnp.float32)  # (bn, s)
    cb = c_ref[...].astype(jnp.float32)  # (k, s)
    xn = jnp.sum(xb * xb, axis=1, keepdims=True)  # (bn, 1)
    cn = jnp.sum(cb * cb, axis=1, keepdims=True).T  # (1, k)
    cross = jax.lax.dot_general(
        xb,
        cb,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    d2 = xn + cn - 2.0 * cross  # (bn, k)
    out_ref[...] = jnp.argmin(d2, axis=1).astype(jnp.int32)[:, None]


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def kmeans_assign_kernel(
    x: jax.Array, centroids: jax.Array, *, bn: int = 1024, interpret: bool = False
) -> jax.Array:
    """Caller pre-pads: n % bn == 0; s, k already VMEM-friendly. -> (n, 1)."""
    n, s = x.shape
    k, _ = centroids.shape
    grid = (n // bn,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, s), lambda i: (i, 0)),
            pl.BlockSpec((k, s), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.int32),
        interpret=interpret,
    )(x, centroids)
