"""Blocked pairwise squared-L2 Pallas TPU kernel.

Computes ``D[i, j] = ||q_i - x_j||^2`` for ``q: (m, d)``, ``x: (n, d)`` as
``|q|^2 + |x|^2 - 2 q x^T`` with the contraction blocked over ``d`` so the
MXU does the heavy lifting and the working set stays in VMEM:

  grid = (m/bm, n/bn, d/bk)    (k innermost -> sequential accumulation)
  per step:  acc += rowsum(qk^2) + colsum(xk^2) - 2 qk @ xk^T

Because slice norms sum to full norms over the k-loop, no separate norm pass
is needed.  Accumulation is fp32 regardless of input dtype (bf16 inputs hit
the MXU natively).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, x_ref, out_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    qk = q_ref[...].astype(jnp.float32)  # (bm, bk)
    xk = x_ref[...].astype(jnp.float32)  # (bn, bk)
    qn = jnp.sum(qk * qk, axis=1, keepdims=True)  # (bm, 1)
    xn = jnp.sum(xk * xk, axis=1, keepdims=True).T  # (1, bn)
    cross = jax.lax.dot_general(
        qk,
        xk,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] += qn + xn - 2.0 * cross

    @pl.when(k == nk - 1)
    def _clamp():
        out_ref[...] = jnp.maximum(out_ref[...], 0.0)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def pairwise_sqdist_kernel(
    q: jax.Array,
    x: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Caller must pre-pad: m % bm == n % bn == d % bk == 0 (see ops.py)."""
    m, d = q.shape
    n, _ = x.shape
    nk = d // bk
    grid = (m // bm, n // bn, nk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(q, x)
