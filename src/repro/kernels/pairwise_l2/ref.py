"""Pure-jnp oracle for the pairwise_l2 kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_sqdist_ref(q: jax.Array, x: jax.Array) -> jax.Array:
    """``(m, d), (n, d) -> (m, n)`` squared L2, fp32 accumulation."""
    qf = q.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    qn = jnp.sum(qf * qf, axis=-1)
    xn = jnp.sum(xf * xf, axis=-1)
    cross = jnp.einsum("md,nd->mn", qf, xf, preferred_element_type=jnp.float32)
    return jnp.maximum(qn[:, None] + xn[None, :] - 2.0 * cross, 0.0)
