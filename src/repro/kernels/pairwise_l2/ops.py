"""jit'd public wrapper for the pairwise_l2 kernel: pads to block multiples,
invokes the Pallas kernel, slices the result back."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.pairwise_l2.kernel import pairwise_sqdist_kernel
from repro.kernels.pairwise_l2.ref import pairwise_sqdist_ref


def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def pairwise_sqdist(
    q: jax.Array,
    x: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Pairwise squared L2 ``(m, d), (n, d) -> (m, n)`` via the Pallas kernel.

    Zero padding is harmless for this computation (pad rows produce junk
    rows/cols that are sliced off; pad dims contribute 0 to every norm).
    """
    m, d = q.shape
    n, _ = x.shape
    bm_ = min(bm, _round_up(m, 8))
    bn_ = min(bn, _round_up(n, 128))
    bk_ = min(bk, _round_up(d, 128))
    mp, np_, dp = _round_up(m, bm_), _round_up(n, bn_), _round_up(d, bk_)
    qp = jnp.pad(q, ((0, mp - m), (0, dp - d)))
    xp = jnp.pad(x, ((0, np_ - n), (0, dp - d)))
    out = pairwise_sqdist_kernel(qp, xp, bm=bm_, bn=bn_, bk=bk_, interpret=interpret)
    return out[:m, :n]


__all__ = ["pairwise_sqdist", "pairwise_sqdist_ref"]


# --------------------------------------------------------------------------
# jaxlint registry hook (see repro.analysis)
# --------------------------------------------------------------------------

#: Tile contract: classic MXU matmul tiling — every block is a full
#: (sublane, lane) tile in both dims.
TILE_CONTRACT = {
    "sublane": 8,
    "lane": 128,
    "double_buffer": 2,
    "block_align": {
        0: ((0, 8), (1, 128)),  # q (bm, bk)
        1: ((0, 8), (1, 128)),  # x (bn, bk)
        2: ((0, 8), (1, 128)),  # out (bm, bn)
    },
}


def jaxlint_entries():
    from repro.analysis.registry import JaxprEntry, TileEntry

    S = jax.ShapeDtypeStruct
    m, n, d = 256, 512, 256

    def make_kernel():
        return jax.make_jaxpr(
            lambda q, x: pairwise_sqdist_kernel(
                q, x, bm=128, bn=128, bk=128, interpret=True
            )
        )(S((m, d), jnp.float32), S((n, d), jnp.float32))

    def make_oracle():
        return jax.make_jaxpr(lambda q, x: pairwise_sqdist_ref(q, x))(
            S((m, d), jnp.float32), S((n, d), jnp.float32)
        )

    return [
        TileEntry(
            name="kernels.pairwise_l2.kernel",
            make=make_kernel,
            contract=TILE_CONTRACT,
            note="blocked pairwise squared-L2 on the MXU",
        ),
        JaxprEntry(
            name="kernels.pairwise_l2.oracle",
            make=make_oracle,
            rules=("bounded-intermediate", "pinned-accumulator"),
            budget_bytes=4 * 2 * m * n,
            note="jnp oracle of the pairwise-distance kernel",
        ),
    ]
