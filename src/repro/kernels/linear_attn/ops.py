"""jit'd wrapper for the chunked linear-attention kernel.

Handles (B, H, T, D) <-> (BH, T, D) reshapes and pads T to a chunk multiple
(pad tokens: w=1, k=0, q=0 — they neither read nor write state).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.linear_attn.kernel import linear_attn_kernel
from repro.kernels.linear_attn.ref import linear_attn_chunked_jnp, linear_attn_ref


def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


@functools.partial(jax.jit, static_argnames=("chunk", "mode", "interpret", "impl"))
def linear_attention(
    q: jax.Array,  # (B, H, T, dk)
    k: jax.Array,
    v: jax.Array,  # (B, H, T, dv)
    w: jax.Array,  # (B, H, T, dk)
    u: jax.Array | None = None,  # (H, dk) bonus, rwkv mode only
    *,
    chunk: int = 64,
    mode: str = "rwkv",  # "rwkv" (exclusive+bonus) | "gla" | "ssd"
    interpret: bool = False,
    impl: str = "auto",
) -> jax.Array:
    b, h, t, dk = q.shape
    dv = v.shape[-1]
    shift = 1 if mode == "rwkv" else 0
    if u is None:
        u = jnp.zeros((h, dk), q.dtype)
    u_b = jnp.broadcast_to(u[None], (b, h, dk)).reshape(b * h, 1, dk)

    def flat(a):
        return a.reshape(b * h, t, a.shape[-1])

    qf, kf, vf, wf = flat(q), flat(k), flat(v), flat(w)
    if impl == "scan":
        o, _ = linear_attn_ref(qf, kf, vf, wf, u_b, shift=shift)
        return o.reshape(b, h, t, dv)

    tp = _round_up(t, chunk)
    if tp != t:
        pad = ((0, 0), (0, tp - t), (0, 0))
        qf = jnp.pad(qf, pad)
        kf = jnp.pad(kf, pad)
        vf = jnp.pad(vf, pad)
        wf = jnp.pad(wf, pad, constant_values=1.0)
    if impl in ("ref", "chunked") or (impl == "auto" and jax.default_backend() != "tpu"):
        # chunked-jnp: numerically identical math to the Pallas kernel and
        # HLO-representative of it (see ref.linear_attn_chunked_jnp)
        o, _ = linear_attn_chunked_jnp(qf, kf, vf, wf, u_b, chunk=chunk, shift=shift)
        return o[:, :t].reshape(b, h, t, dv)
    o, _ = linear_attn_kernel(
        qf, kf, vf, wf, u_b, chunk=chunk, shift=shift, interpret=interpret
    )
    return o[:, :t].reshape(b, h, t, dv)


def linear_attention_with_state(
    qf: jax.Array,  # (BH, T, dk)
    kf: jax.Array,
    vf: jax.Array,
    wf: jax.Array,
    u_b: jax.Array,  # (BH, 1, dk)
    *,
    chunk: int = 64,
    shift: int = 1,
) -> tuple[jax.Array, jax.Array]:
    """Chunked path returning (o, final_state) — used by prefill."""
    t = qf.shape[1]
    tp = _round_up(t, chunk)
    if tp != t:
        pad = ((0, 0), (0, tp - t), (0, 0))
        qf = jnp.pad(qf, pad)
        kf = jnp.pad(kf, pad)
        vf = jnp.pad(vf, pad)
        wf = jnp.pad(wf, pad, constant_values=1.0)
    o, s = linear_attn_chunked_jnp(qf, kf, vf, wf, u_b, chunk=chunk, shift=shift)
    return o[:, :t], s


__all__ = ["linear_attention", "linear_attention_with_state", "linear_attn_ref",
           "linear_attn_kernel"]
