"""Chunked gated linear-attention Pallas kernel (RWKV6 / GLA / Mamba2-SSD).

Recurrence (per head, state ``S: (dk, dv)``):

    exclusive ("rwkv", with bonus u):   o_t = q_t S_{t-1} + (q_t . (u * k_t)) v_t
                                        S_t = diag(w_t) S_{t-1} + k_t v_t^T
    inclusive ("gla"/"ssd"):            S_t = diag(w_t) S_{t-1} + k_t v_t^T
                                        o_t = q_t S_t

TPU adaptation: the sequential scan is reformulated chunk-parallel.  With
``lb = cumsum(log w)`` inside a chunk (lb_0 = 0) and ``shift = 1`` for the
exclusive form:

    inter:  o_t += (q_t * exp(lb_{t-shift})) @ S_chunk_start
    intra:  A[t, j] = sum_k q_tk k_jk exp(lb_{t-shift,k} - lb_{j,k}),  j <= t-shift
            o_t += A[t, :] @ v
    bonus:  o_t += (q_t . (u * k_t)) v_t            (exclusive only)
    state:  S <- diag(exp(lb_C)) S + (k * exp(lb_C - lb))^T @ v

All exponents are differences of monotone log-decays, hence <= 0 — no
overflow regardless of chunk length (the naive ``b_i / b_j`` cumprod-ratio
form overflows for small decay; see DESIGN.md).

Grid = (batch*heads, T/C); chunk axis innermost so the fp32 VMEM scratch
``S`` carries across grid steps; it is reset when a new head begins.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_EPS = 1e-6


def _kernel(q_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_final_ref, s_ref, *, shift: int, nc: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _reset():
        s_ref[...] = jnp.zeros_like(s_ref)

    qb = q_ref[0].astype(jnp.float32)  # (C, dk)
    kb = k_ref[0].astype(jnp.float32)  # (C, dk)
    vb = v_ref[0].astype(jnp.float32)  # (C, dv)
    wb = w_ref[0].astype(jnp.float32)  # (C, dk)
    ub = u_ref[0].astype(jnp.float32)  # (1, dk)

    c = qb.shape[0]
    lw = jnp.log(jnp.clip(wb, _EPS, 1.0))
    lb = jnp.cumsum(lw, axis=0)  # (C, dk), inclusive
    if shift:
        lbq = jnp.concatenate([jnp.zeros_like(lb[:1]), lb[:-1]], axis=0)
    else:
        lbq = lb

    s0 = s_ref[...]  # (dk, dv)

    # inter-chunk
    o = jax.lax.dot(
        qb * jnp.exp(lbq), s0, preferred_element_type=jnp.float32
    )  # (C, dv)

    # intra-chunk: A[t, j] = sum_k q_tk k_jk exp(lbq_t - lb_j)_k,  j <= t-shift
    decay = jnp.exp(lbq[:, None, :] - lb[None, :, :])  # (C, C, dk)
    a = jnp.einsum("tk,jk,tjk->tj", qb, kb, decay)
    t_ids = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    j_ids = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    a = jnp.where(j_ids <= t_ids - shift, a, 0.0)
    o = o + jax.lax.dot(a, vb, preferred_element_type=jnp.float32)

    if shift:  # bonus diagonal term (rwkv6's u)
        diag = jnp.sum(qb * ub * kb, axis=1, keepdims=True)  # (C, 1)
        o = o + diag * vb

    o_ref[0] = o.astype(o_ref.dtype)

    # state update
    decay_out = jnp.exp(lb[-1:, :] - lb)  # (C, dk), exponent <= 0
    s_new = jnp.exp(lb[-1])[:, None] * s0 + jax.lax.dot(
        (kb * decay_out).T, vb, preferred_element_type=jnp.float32
    )
    s_ref[...] = s_new

    @pl.when(ci == nc - 1)
    def _emit_state():
        s_final_ref[0] = s_new.astype(s_final_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "shift", "interpret"))
def linear_attn_kernel(
    q: jax.Array,  # (BH, T, dk)
    k: jax.Array,  # (BH, T, dk)
    v: jax.Array,  # (BH, T, dv)
    w: jax.Array,  # (BH, T, dk) decay in (0, 1]
    u: jax.Array,  # (BH, 1, dk) bonus (zeros for gla/ssd)
    *,
    chunk: int = 64,
    shift: int = 1,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Caller pre-pads T to a chunk multiple. Returns (o, final_state)."""
    bh, t, dk = q.shape
    dv = v.shape[-1]
    nc = t // chunk
    grid = (bh, nc)
    kern = functools.partial(_kernel, shift=shift, nc=nc)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, dk), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, dk, dv), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, dv), q.dtype),
            jax.ShapeDtypeStruct((bh, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(q, k, v, w, u)
