"""Sequential-scan oracle for the chunked linear-attention kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_EPS = 1e-6


@functools.partial(jax.jit, static_argnames=("shift",))
def linear_attn_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    *,
    shift: int = 1,
    initial_state: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Token-by-token recurrence; shapes as in the kernel. fp32 math."""
    bh, t, dk = q.shape
    dv = v.shape[-1]
    qf, kf, vf = (a.astype(jnp.float32) for a in (q, k, v))
    wf = jnp.clip(w.astype(jnp.float32), _EPS, 1.0)
    uf = u.astype(jnp.float32).reshape(bh, dk)

    s0 = (
        jnp.zeros((bh, dk, dv), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def step(s, inp):
        qt, kt, vt, wt = inp  # (bh, dk) ... (bh, dv)
        if shift:
            o = jnp.einsum("bk,bkv->bv", qt, s) + (
                jnp.sum(qt * uf * kt, axis=1, keepdims=True) * vt
            )
            s = wt[:, :, None] * s + kt[:, :, None] * vt[:, None, :]
        else:
            s = wt[:, :, None] * s + kt[:, :, None] * vt[:, None, :]
            o = jnp.einsum("bk,bkv->bv", qt, s)
        return s, o

    xs = (
        jnp.moveaxis(qf, 1, 0),
        jnp.moveaxis(kf, 1, 0),
        jnp.moveaxis(vf, 1, 0),
        jnp.moveaxis(wf, 1, 0),
    )
    s_fin, o = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(o, 0, 1).astype(q.dtype), s_fin


@functools.partial(jax.jit, static_argnames=("chunk", "shift"))
def linear_attn_chunked_jnp(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    *,
    chunk: int = 64,
    shift: int = 1,
) -> tuple[jax.Array, jax.Array]:
    """Pure-jnp port of the chunked kernel math (same log-space form).

    This is the CPU/backbone path: its HLO is representative of the TPU
    kernel (T/chunk loop iterations of chunk-sized matmuls) — unlike the
    token-by-token scan, whose 4096-iteration loop inflates dry-run memory
    terms by ~chunk x.  Caller must pad T to a chunk multiple.
    """
    from repro.models.shard_ctx import constrain

    bh, t, dk = q.shape
    dv = v.shape[-1]
    assert t % chunk == 0, "pad T to a chunk multiple"
    nc = t // chunk
    c = chunk
    # shard the merged batch*heads dim over the whole mesh (no-op outside a
    # sharding context); see EXPERIMENTS.md §Perf iteration 4
    q = constrain(q, "batch_heads", None, None)
    k = constrain(k, "batch_heads", None, None)
    v = constrain(v, "batch_heads", None, None)
    w = constrain(w, "batch_heads", None, None)
    qf = q.astype(jnp.float32).reshape(bh, nc, c, dk).transpose(1, 0, 2, 3)
    kf = k.astype(jnp.float32).reshape(bh, nc, c, dk).transpose(1, 0, 2, 3)
    vf = v.astype(jnp.float32).reshape(bh, nc, c, dv).transpose(1, 0, 2, 3)
    wf = jnp.clip(w.astype(jnp.float32), _EPS, 1.0).reshape(bh, nc, c, dk)
    wf = wf.transpose(1, 0, 2, 3)
    uf = u.astype(jnp.float32).reshape(bh, 1, -1)

    t_ids = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    j_ids = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    mask = j_ids <= t_ids - shift

    def body(s0, inp):
        qb, kb, vb, wb = inp  # (bh, c, ...)
        lb = jnp.cumsum(jnp.log(wb), axis=1)  # (bh, c, dk)
        lbq = (
            jnp.concatenate([jnp.zeros_like(lb[:, :1]), lb[:, :-1]], axis=1)
            if shift else lb
        )
        o = jnp.einsum("bck,bkv->bcv", qb * jnp.exp(lbq), s0)
        decay = jnp.exp(lbq[:, :, None, :] - lb[:, None, :, :])  # (bh,c,c,dk)
        a = jnp.einsum("btk,bjk,btjk->btj", qb, kb, decay)
        a = jnp.where(mask[None], a, 0.0)
        o = o + jnp.einsum("btj,bjv->btv", a, vb)
        if shift:
            diag = jnp.sum(qb * uf * kb, axis=-1, keepdims=True)
            o = o + diag * vb
        dec_out = jnp.exp(lb[:, -1:, :] - lb)  # (bh, c, dk)
        s_new = jnp.exp(lb[:, -1])[:, :, None] * s0 + jnp.einsum(
            "bck,bcv->bkv", kb * dec_out, vb
        )
        return s_new, o

    s0 = jnp.zeros((bh, dk, dv), jnp.float32)
    # nested remat: recompute the (bh, c, c, dk) decay tensor in the chunk
    # backward instead of stacking it across all chunks (550 GB/layer at
    # B=256, T=4k before this fix)
    s_fin, o = jax.lax.scan(jax.checkpoint(body), s0, (qf, kf, vf, wf))
    o = o.transpose(1, 0, 2, 3).reshape(bh, t, dv)
    return o.astype(q.dtype), s_fin
