"""Synthetic ANN datasets + exact ground truth + quality metrics.

The paper evaluates on SIFT/Deep/SPACEV/GIST etc.  Those corpora are not
available offline, so we provide parameterised generators that reproduce the
*structural* properties that matter for subspace collision:

* ``gaussian_mixture`` — clustered data, the regime of SIFT/Deep (low LID);
* ``correlated``       — anisotropic covariance (distance mass concentrated
  in a few dims — exactly the failure mode Figure 1 motivates);
* ``uniform``          — iid data, the hard/no-structure regime (high LID);
* ``zipf_mixture``     — heavily skewed cluster sizes (stress for the IMI).

Every generator is deterministic in ``seed`` and returns float32.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

__all__ = [
    "Dataset",
    "gaussian_mixture",
    "correlated",
    "uniform",
    "zipf_mixture",
    "make_queries",
    "exact_knn",
    "recall",
    "mean_relative_error",
    "GENERATORS",
]


@dataclasses.dataclass
class Dataset:
    name: str
    x: np.ndarray  # (n, d) float32
    queries: np.ndarray  # (m, d) float32
    gt_ids: np.ndarray  # (m, k) int64 exact NN ids
    gt_dists: np.ndarray  # (m, k) float32 exact squared L2


def uniform(n: int, d: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, d)).astype(np.float32)


def gaussian_mixture(
    n: int, d: int, seed: int = 0, *, n_clusters: int = 256, spread: float = 5.0
) -> np.ndarray:
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_clusters, d)) * spread
    who = rng.integers(0, n_clusters, n)
    return (centers[who] + rng.normal(size=(n, d))).astype(np.float32)


def correlated(n: int, d: int, seed: int = 0, *, decay: float = 0.9) -> np.ndarray:
    """Anisotropic data: variance decays geometrically across dims."""
    scales = decay ** np.arange(d)
    base = gaussian_mixture(n, d, seed, n_clusters=128)
    return (base * scales[None, :]).astype(np.float32)


def zipf_mixture(n: int, d: int, seed: int = 0, *, n_clusters: int = 256) -> np.ndarray:
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_clusters, d)) * 5.0
    p = 1.0 / np.arange(1, n_clusters + 1)
    p /= p.sum()
    who = rng.choice(n_clusters, size=n, p=p)
    return (centers[who] + rng.normal(size=(n, d))).astype(np.float32)


GENERATORS: dict[str, Callable[..., np.ndarray]] = {
    "uniform": uniform,
    "gaussian_mixture": gaussian_mixture,
    "correlated": correlated,
    "zipf_mixture": zipf_mixture,
}


def make_queries(x: np.ndarray, m: int, seed: int = 1, *, noise: float = 0.1) -> np.ndarray:
    """Paper protocol: queries are (perturbed) held-out dataset points."""
    rng = np.random.default_rng(seed)
    idx = rng.choice(x.shape[0], size=m, replace=False)
    q = x[idx] + noise * rng.normal(size=(m, x.shape[1]))
    return q.astype(np.float32)


def exact_knn(
    x: np.ndarray, q: np.ndarray, k: int, *, metric: str = "l2", block: int = 262144
) -> tuple[np.ndarray, np.ndarray]:
    """Blocked exact k-NN (the ground-truth oracle). Returns (ids, dists)."""
    m = q.shape[0]
    best_d = np.full((m, k), np.inf, dtype=np.float64)
    best_i = np.zeros((m, k), dtype=np.int64)
    for start in range(0, x.shape[0], block):
        xb = x[start : start + block]
        if metric == "l2":
            d2 = (
                (q.astype(np.float64) ** 2).sum(1)[:, None]
                + (xb.astype(np.float64) ** 2).sum(1)[None, :]
                - 2.0 * q.astype(np.float64) @ xb.astype(np.float64).T
            )
            np.maximum(d2, 0.0, out=d2)
        elif metric == "l1":
            d2 = np.abs(q[:, None, :].astype(np.float64) - xb[None, :, :]).sum(-1)
        else:
            raise ValueError(metric)
        ids = np.argpartition(d2, min(k, d2.shape[1] - 1), axis=1)[:, :k]
        dd = np.take_along_axis(d2, ids, axis=1)
        cat_d = np.concatenate([best_d, dd], axis=1)
        cat_i = np.concatenate([best_i, ids + start], axis=1)
        sel = np.argsort(cat_d, axis=1, kind="stable")[:, :k]
        best_d = np.take_along_axis(cat_d, sel, axis=1)
        best_i = np.take_along_axis(cat_i, sel, axis=1)
    return best_i, best_d.astype(np.float32)


def make_dataset(
    kind: str, n: int, d: int, m: int = 100, k: int = 50, seed: int = 0
) -> Dataset:
    x = GENERATORS[kind](n, d, seed)
    q = make_queries(x, m, seed + 1)
    ids, dists = exact_knn(x, q, k)
    return Dataset(f"{kind}-{n}x{d}", x, q, ids, dists)


# ----------------------------- metrics ------------------------------------


def recall(result_ids: np.ndarray, gt_ids: np.ndarray) -> float:
    """Mean |R ∩ R*| / k over queries (paper §5.1)."""
    k = gt_ids.shape[1]
    hits = [
        len(set(map(int, r[:k])) & set(map(int, g))) / k
        for r, g in zip(result_ids, gt_ids)
    ]
    return float(np.mean(hits))


def mean_relative_error(result_dists: np.ndarray, gt_dists: np.ndarray) -> float:
    """MRE over *metric* distances (paper §5.1). Inputs are squared L2 —
    converted via sqrt; zero ground-truth distances are guarded."""
    r = np.sqrt(np.maximum(np.asarray(result_dists, np.float64), 0.0))
    g = np.sqrt(np.maximum(np.asarray(gt_dists, np.float64), 0.0))
    g = np.maximum(g, 1e-12)
    return float(np.mean((r - g) / g))
