from repro.data.datasets import (
    Dataset,
    GENERATORS,
    exact_knn,
    gaussian_mixture,
    correlated,
    uniform,
    zipf_mixture,
    make_dataset,
    make_queries,
    recall,
    mean_relative_error,
)

__all__ = [
    "Dataset",
    "GENERATORS",
    "exact_knn",
    "gaussian_mixture",
    "correlated",
    "uniform",
    "zipf_mixture",
    "make_dataset",
    "make_queries",
    "recall",
    "mean_relative_error",
]
