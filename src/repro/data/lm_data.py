"""Deterministic synthetic LM data pipeline.

Sequences follow a learnable pattern (per-sequence modular stride with a
noisy token every ``noise_every`` positions), so a small model's loss drops
fast — useful for end-to-end training demos and convergence tests.

Determinism contract: ``batch_at(step)`` is a pure function of
``(seed, step, global_batch)`` — after a restart the pipeline resumes at the
exact batch it would have produced, giving exactly-once sample delivery
without any data-loader state in the checkpoint.  Sharding: each data shard
slices its rows from the same global batch, so the pipeline is elastic too.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["LMDataConfig", "SyntheticLM"]


@dataclasses.dataclass(frozen=True)
class LMDataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    max_stride: int = 8
    noise_every: int = 16


class SyntheticLM:
    def __init__(self, cfg: LMDataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng((c.seed, step))
        start = rng.integers(0, c.vocab_size, size=(c.global_batch, 1))
        stride = rng.integers(1, c.max_stride + 1, size=(c.global_batch, 1))
        pos = np.arange(c.seq_len + 1)[None, :]
        seq = (start + stride * pos) % c.vocab_size
        noise_mask = (pos % c.noise_every) == (c.noise_every - 1)
        noise = rng.integers(0, c.vocab_size, size=seq.shape)
        seq = np.where(noise_mask, noise, seq)
        return {
            "tokens": seq[:, :-1].astype(np.int32),
            "labels": seq[:, 1:].astype(np.int32),
        }

    def shard_rows(self, batch: dict, shard: int, n_shards: int) -> dict:
        per = self.cfg.global_batch // n_shards
        sl = slice(shard * per, (shard + 1) * per)
        return {k: v[sl] for k, v in batch.items()}
