"""Elastic scaling for the sharded SuCo index.

The index layout is a pure function of (dataset order, config): points are
range-sharded over the point axes and subspaces over the model axis.  That
makes re-scaling mechanical:

* mesh grows/shrinks along the point axes  -> re-slice point ranges
  (``reshard_index`` just device_puts with the new layout; cell_ids are
  per-point so no recomputation is needed);
* mesh model axis changes                  -> subspace ownership moves, but
  centroids/counts are replicated along point axes already, so the same
  device_put applies;
* a worker is lost mid-build              -> rebuild only its point range
  (deterministic k-means given the replicated centroids) or reload its
  shard from the checkpoint manifest.

Checkpoints store the logical (unsharded) arrays — see train.checkpoint —
so this module is thin glue: layout in, layout out.
"""

from __future__ import annotations

import jax

from repro.core.suco import SuCoIndex
from repro.distributed.engine import DistSuCoConfig, index_shardings

__all__ = ["reshard_index", "index_to_host", "index_from_host"]


def reshard_index(new_mesh, cfg: DistSuCoConfig, index: SuCoIndex) -> SuCoIndex:
    """Move an index (from any previous mesh) onto ``new_mesh``."""
    sh = index_shardings(new_mesh, cfg)
    return SuCoIndex(
        centroids1=jax.device_put(index.centroids1, sh["centroids"]),
        centroids2=jax.device_put(index.centroids2, sh["centroids"]),
        cell_ids=jax.device_put(index.cell_ids, sh["cell_ids"]),
        cell_counts=jax.device_put(index.cell_counts, sh["cell_counts"]),
        spec=index.spec,
        sqrt_k=index.sqrt_k,
    )


def index_to_host(index: SuCoIndex) -> dict:
    """Materialise the logical index on host (checkpoint payload)."""
    import numpy as np

    return {
        "centroids1": np.asarray(index.centroids1),  # jaxlint: sync-ok
        "centroids2": np.asarray(index.centroids2),  # jaxlint: sync-ok
        "cell_ids": np.asarray(index.cell_ids),  # jaxlint: sync-ok
        "cell_counts": np.asarray(index.cell_counts),  # jaxlint: sync-ok
    }


def index_from_host(payload: dict, spec, sqrt_k: int, mesh=None, cfg=None) -> SuCoIndex:
    import jax.numpy as jnp

    idx = SuCoIndex(
        centroids1=jnp.asarray(payload["centroids1"]),
        centroids2=jnp.asarray(payload["centroids2"]),
        cell_ids=jnp.asarray(payload["cell_ids"]),
        cell_counts=jnp.asarray(payload["cell_counts"]),
        spec=spec,
        sqrt_k=sqrt_k,
    )
    if mesh is not None and cfg is not None:
        idx = reshard_index(mesh, cfg, idx)
    return idx
