"""Distributed SuCo engine: multi-pod index build + query via shard_map.

Sharding layout (DESIGN.md §5) over mesh axes ``(pod, data, model)``:

  X            (n, d)         P((pod, data), model)   points x dim-slices
  cell_ids     (Ns, n)        P(model, (pod, data))   subspaces x points
  cell_counts  (Ns, K)        P(model, None)          global counts
  centroids    (Ns, sqrtK, h) P(model, None, None)
  queries      (mq, d)        P(None, model)          replicated over points

Requirements (asserted): ``Ns % model == 0`` and ``d % Ns == 0`` — each
model rank owns ``Ns/model`` whole subspaces, i.e. a contiguous dim slice.
The single-pod mesh is the same code with ``point_axes=("data",)``.

Query data flow per query chunk (``block_n > 0``, the default): the local
point shard is itself streamed in blocks of ``block_n`` points —

  per data block:  local collision counts -> psum(SC-score, model) [int8]
                   -> merge into a carried per-query top-(beta n_loc) pool
  pool            ->  partial-distance re-rank -> psum(model)
  local top-k     ->  all_gather((dist,id), point axes) -> top-k.

Peak per-rank query memory is O(q_chunk * (block_n + beta n_loc)) instead
of O(q_chunk * n_loc); ``block_n=0`` keeps the dense-per-shard reference
path.  The only collectives are tiny int8 psums per data block, one fp32
psum over (mq, beta*n_local), and a k-sized gather: communication is
O(n_local) per device and independent of the *global* dataset size — the
design scales to thousands of nodes.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.suco import (
    DEFAULT_BATCH_BUCKETS,
    SuCoIndex,
    _cell_ranks_and_cut,
    activate_cells_sorted,
    batch_bucket,
    load_index_artifact,
)
from repro.core import subspace as sub
from repro.core.distances import pairwise_sqdist
from repro.core.kmeans import assign_scan, block_batched, lloyd_stats_scan
from repro.core.sc_linear import candidate_pool_size, merge_topk_pool
from repro.core.tuning import autotune_build_block_n, autotune_tiles
from repro.distributed.compat import pcast_varying, shard_map_compat
from repro.kernels.sc_score.ops import sc_scores_cells

__all__ = [
    "DistSuCoConfig",
    "resolved_query_block_n",
    "index_shardings",
    "shard_index",
    "build_sharded",
    "query_sharded",
    "ShardedSuCoEngine",
    "ShardedEnginePool",
]


@dataclasses.dataclass(frozen=True)
class DistSuCoConfig:
    n_subspaces: int = 16
    sqrt_k: int = 64
    kmeans_iters: int = 10
    alpha: float = 0.03
    beta: float = 0.003
    k: int = 50
    q_chunk: int = 32  # queries processed per scan step (bounds the
    # (q_chunk, n_local) score block)
    block_n: int | None = None  # data points scored per streaming block;
    # None = autotune from the backend memory limits and the per-shard
    # problem shape (repro.core.tuning.autotune_tiles); 0 = dense
    # per-shard scoring (the small-n reference path)
    build_block_n: int | None = 4096  # points per streaming Lloyd chunk in
    # the sharded build; None = autotune (autotune_build_block_n); 0 =
    # dense per-shard one-hot updates (the reference path — materialises
    # (2ns_loc, n_loc, sqrt_k) every iteration)
    point_axes: tuple[str, ...] = ("data",)
    model_axis: str = "model"
    seed: int = 0
    tuning_backend: str | None = None  # backend whose memory limits the
    # block-size autotuner plans against; None = the active jax backend.
    # Pin it (e.g. "tpu") when AOT-lowering on a different host than the
    # one that will serve, so the resolved scan structure matches
    # production exactly.

    @property
    def n_cells(self) -> int:
        return self.sqrt_k**2


def _n_point_shards(mesh: Mesh, cfg: DistSuCoConfig) -> int:
    return math.prod(mesh.shape[a] for a in cfg.point_axes)


def resolved_query_block_n(mesh: Mesh, cfg: DistSuCoConfig, n: int, d: int) -> int:
    """The per-shard streaming block the sharded query step will use.

    ``cfg.block_n=None`` autotunes from the memory limits of
    ``cfg.tuning_backend`` (the active backend when unset) and the *local*
    problem shape (shard points, dim slice, ``q_chunk`` queries, per-shard
    candidate pool); explicit values (0 = dense) pass through.
    Deterministic per ``(shape, backend)`` — pin ``tuning_backend`` when
    lowering ahead of time on a different host class, so AOT lowering and
    live serving agree.
    """
    if cfg.block_n is not None:
        if cfg.block_n < 0:
            raise ValueError(
                f"block_n must be >= 0 (0 = dense) or None (autotune), "
                f"got {cfg.block_n}"
            )
        return cfg.block_n
    n_loc = max(n // _n_point_shards(mesh, cfg), 1)
    d_loc = max(d // mesh.shape[cfg.model_axis], 1)
    m_cand = candidate_pool_size(n_loc, cfg.k, cfg.beta)
    return autotune_tiles(
        n_loc, d_loc, cfg.q_chunk, m_cand,
        n_subspaces=max(cfg.n_subspaces // mesh.shape[cfg.model_axis], 1),
        n_cells=cfg.n_cells,
        backend=cfg.tuning_backend,
    ).block_n


def _check(mesh: Mesh, cfg: DistSuCoConfig, d: int) -> tuple[int, int]:
    tp = mesh.shape[cfg.model_axis]
    if cfg.n_subspaces % tp:
        raise ValueError(f"Ns={cfg.n_subspaces} must divide by model={tp}")
    if d % cfg.n_subspaces:
        raise ValueError(f"d={d} must divide by Ns={cfg.n_subspaces}")
    ns_loc = cfg.n_subspaces // tp
    s = d // cfg.n_subspaces
    return ns_loc, s


def index_shardings(mesh: Mesh, cfg: DistSuCoConfig) -> dict[str, NamedSharding]:
    pa = cfg.point_axes
    return dict(
        x=NamedSharding(mesh, P(pa, cfg.model_axis)),
        cell_ids=NamedSharding(mesh, P(cfg.model_axis, pa)),
        cell_counts=NamedSharding(mesh, P(cfg.model_axis, None)),
        centroids=NamedSharding(mesh, P(cfg.model_axis, None, None)),
        queries=NamedSharding(mesh, P(None, cfg.model_axis)),
        replicated=NamedSharding(mesh, P()),
    )


def shard_index(mesh: Mesh, cfg: DistSuCoConfig, index: SuCoIndex) -> SuCoIndex:
    """Place a locally-built SuCoIndex onto the mesh with the engine layout."""
    sh = index_shardings(mesh, cfg)
    return SuCoIndex(
        centroids1=jax.device_put(index.centroids1, sh["centroids"]),
        centroids2=jax.device_put(index.centroids2, sh["centroids"]),
        cell_ids=jax.device_put(index.cell_ids, sh["cell_ids"]),
        cell_counts=jax.device_put(index.cell_counts, sh["cell_counts"]),
        spec=index.spec,
        sqrt_k=index.sqrt_k,
    )


def _split_local(x_loc: jax.Array, ns_loc: int, s: int) -> tuple[jax.Array, jax.Array, int]:
    """``(n_loc, ns_loc * s) -> 2 x (ns_loc, n_loc, h1)`` half views (padded)."""
    n_loc = x_loc.shape[0]
    xs = x_loc.reshape(n_loc, ns_loc, s).transpose(1, 0, 2)  # (ns, n, s)
    h1 = (s + 1) // 2
    a = xs[..., :h1]
    b = xs[..., h1:]
    if b.shape[-1] < h1:
        b = jnp.pad(b, ((0, 0), (0, 0), (0, h1 - b.shape[-1])))
    return a, b, h1


# --------------------------------------------------------------------------
# Build
# --------------------------------------------------------------------------


def build_sharded(mesh: Mesh, x: jax.Array, cfg: DistSuCoConfig) -> SuCoIndex:
    """Distributed Algorithm 2: K-means via psum'd sufficient statistics.

    ``cfg.build_block_n > 0`` (the default) streams each shard's points
    through the chunked Lloyd scan (:func:`repro.core.kmeans.
    lloyd_stats_scan`): every iteration each shard folds its chunks into
    per-centroid ``(sums, counts)`` accumulators and only those tiny
    ``(2ns_loc, sqrt_k, h1)`` partials are psum'd — nothing of size
    ``(n_loc, sqrt_k)`` is ever live, and the collective volume per
    iteration is independent of n.  ``build_block_n=0`` keeps the dense
    per-shard one-hot reference path; both produce identical cell_ids.
    """
    n, d = x.shape
    ns_loc, s = _check(mesh, cfg, d)
    pa = cfg.point_axes
    all_point_axes = pa
    sqrt_k = cfg.sqrt_k
    build_block_n = cfg.build_block_n
    if build_block_n is None:  # autotune from the per-shard build shape
        build_block_n = autotune_build_block_n(
            max(n // _n_point_shards(mesh, cfg), 1), d,
            sqrt_k=sqrt_k, n_subspaces=cfg.n_subspaces,
            backend=cfg.tuning_backend,
        )
    if build_block_n < 0:
        raise ValueError(
            f"build_block_n must be >= 0 (0 = dense), got {build_block_n}"
        )

    def _build(x_loc: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
        a, b, h1 = _split_local(x_loc, ns_loc, s)
        cb = jnp.concatenate([a, b], axis=0)  # (2ns_loc, n_loc, h1)
        n_loc = cb.shape[1]
        chunked = build_block_n > 0
        cast = lambda t: pcast_varying(t, tuple(mesh.axis_names))
        if chunked:
            blocks, valid = block_batched(cb, build_block_n)

        # deterministic init: the first sqrt_k points of point-shard 0
        shard_idx = jnp.zeros((), jnp.int32)
        for ax in all_point_axes:
            # mesh.shape[ax] is static — avoids jax.lax.axis_size (newer jax only)
            shard_idx = shard_idx * mesh.shape[ax] + jax.lax.axis_index(ax)
        first = (shard_idx == 0).astype(cb.dtype)
        init = jax.lax.psum(cb[:, :sqrt_k, :] * first, all_point_axes)

        def lloyd(c, _):
            # c: (2ns_loc, sqrt_k, h1)
            if chunked:
                sums, cnts, _ = lloyd_stats_scan(blocks, valid, c, cast_init=cast)
                sums = sums.astype(cb.dtype)
                cnts = cnts.astype(cb.dtype)
            else:
                d2 = jax.vmap(lambda xx, cc: pairwise_sqdist(xx, cc, impl="jnp"))(cb, c)
                assign = jnp.argmin(d2, axis=-1)  # (2ns, n_loc)
                oh = jax.nn.one_hot(assign, sqrt_k, dtype=cb.dtype)  # (2ns, n_loc, k)
                sums = jnp.einsum("bnk,bnh->bkh", oh, cb)
                cnts = jnp.sum(oh, axis=1)  # (2ns, k)
            sums = jax.lax.psum(sums, all_point_axes)
            cnts = jax.lax.psum(cnts, all_point_axes)
            new = sums / jnp.maximum(cnts, 1.0)[..., None]
            new = jnp.where(cnts[..., None] > 0, new, c)
            return new.astype(c.dtype), None

        c_fin, _ = jax.lax.scan(lloyd, init, None, length=cfg.kmeans_iters)

        if chunked:
            # pair_sqrt_k fuses the IMI occupancy histogram into the
            # assignment scan — no second pass over cell_ids (PR 3).
            assign, _, counts = assign_scan(
                blocks, valid, c_fin, cast_init=cast, pair_sqrt_k=sqrt_k
            )
            assign = assign[:, :n_loc]  # (2ns, n_loc) int32
        else:
            d2 = jax.vmap(lambda xx, cc: pairwise_sqdist(xx, cc, impl="jnp"))(cb, c_fin)
            assign = jnp.argmin(d2, axis=-1).astype(jnp.int32)  # (2ns, n_loc)
            counts = None
        a1, a2 = assign[:ns_loc], assign[ns_loc:]
        cell_ids = a1 * sqrt_k + a2  # (ns_loc, n_loc)
        if counts is None:
            counts = jax.vmap(
                lambda cc: jnp.bincount(cc, length=sqrt_k * sqrt_k).astype(jnp.int32)
            )(cell_ids)
        counts = jax.lax.psum(counts, all_point_axes)
        return c_fin[:ns_loc], c_fin[ns_loc:], cell_ids, counts

    fn = jax.jit(
        shard_map_compat(
            _build,
            mesh=mesh,
            in_specs=P(pa, cfg.model_axis),
            out_specs=(
                P(cfg.model_axis, None, None),
                P(cfg.model_axis, None, None),
                P(cfg.model_axis, pa),
                P(cfg.model_axis, None),
            ),
        )
    )
    c1, c2, cell_ids, counts = fn(x)
    spec = sub.contiguous_spec(d, cfg.n_subspaces)
    return SuCoIndex(c1, c2, cell_ids, counts, spec=spec, sqrt_k=sqrt_k)


# --------------------------------------------------------------------------
# Query
# --------------------------------------------------------------------------


def make_query_fn(mesh: Mesh, cfg: DistSuCoConfig, n: int, d: int, mq: int):
    """Build the jitted sharded query step: (x, index arrays, q) -> (ids, dists).

    Returned fn signature: f(x, c1, c2, cell_ids, counts, q).
    """
    ns_loc, s = _check(mesh, cfg, d)
    pa = cfg.point_axes
    k = cfg.k
    n_pt_shards = math.prod(mesh.shape[a] for a in pa)
    n_loc = n // n_pt_shards
    target = sub.collision_count(n, cfg.alpha)
    m_cand = candidate_pool_size(n_loc, k, cfg.beta)
    q_chunk = min(cfg.q_chunk, mq)
    if mq % q_chunk:
        raise ValueError(f"mq={mq} must divide by q_chunk={q_chunk}")
    block_n = resolved_query_block_n(mesh, cfg, n, d)
    bn = min(block_n, n_loc) if block_n else 0
    n_blocks = -(-n_loc // bn) if bn else 0
    int_max = jnp.iinfo(jnp.int32).max

    def _query(x_loc, c1, c2, cell_ids, counts, q_loc):
        # x_loc: (n_loc, ns_loc*s); q_loc: (mq, ns_loc*s)
        shard_idx = jnp.zeros((), jnp.int32)
        for ax in pa:
            # mesh.shape[ax] is static — avoids jax.lax.axis_size (newer jax only)
            shard_idx = shard_idx * mesh.shape[ax] + jax.lax.axis_index(ax)
        offset = shard_idx * n_loc

        qa, qb, _ = _split_local(q_loc, ns_loc, s)  # (ns_loc, mq, h1)
        d1 = jax.vmap(lambda qq, cc: pairwise_sqdist(qq, cc, impl="jnp"))(qa, c1)
        d2 = jax.vmap(lambda qq, cc: pairwise_sqdist(qq, cc, impl="jnp"))(qb, c2)
        # (ns_loc, mq, sqrt_k)

        def _dense_candidates(d1c, d2c):
            """Reference path: full (q_chunk, n_loc) scores on this shard."""

            def per_sub(acc, inp):
                d1_i, d2_i, cells_i, counts_i = inp

                def per_query(d1_q, d2_q):
                    mask = activate_cells_sorted(d1_q, d2_q, counts_i, target)
                    return jnp.take(mask, cells_i)  # (n_loc,)

                coll = jax.vmap(per_query)(d1_i, d2_i)  # (q_chunk, n_loc)
                return acc + coll.astype(jnp.int8), None

            init = jnp.zeros((q_chunk, n_loc), jnp.int8)
            # mark the carry as device-varying so scan types match (shard_map VMA)
            init = pcast_varying(init, tuple(mesh.axis_names))
            scores, _ = jax.lax.scan(per_sub, init, (d1c, d2c, cell_ids, counts))
            scores = jax.lax.psum(scores, cfg.model_axis)  # full SC-scores
            _, cand = jax.lax.top_k(scores.astype(jnp.int32), m_cand)
            return cand  # (q_chunk, m_cand) local ids

        def _streaming_candidates(d1c, d2c):
            """Tiled path: stream the shard in blocks of bn points, carrying
            a per-query top-m_cand pool — never materialises the
            (q_chunk, n_loc) score matrix.  The (score desc, id asc) merge
            order equals top_k's tie-break, so candidates match the dense
            path exactly."""

            def per_sub_rank(d1_i, d2_i, counts_i):
                return jax.vmap(
                    lambda a, b: _cell_ranks_and_cut(a, b, counts_i, target)
                )(d1_i, d2_i)

            # (ns_loc, q_chunk, K), (ns_loc, q_chunk)
            ranks, cuts = jax.vmap(per_sub_rank)(d1c, d2c, counts)
            cells_pad = jnp.pad(cell_ids, ((0, 0), (0, n_blocks * bn - n_loc)))
            cells_blk = cells_pad.reshape(ns_loc, n_blocks, bn).transpose(1, 0, 2)

            def blk_step(carry, inp):
                pool_s, pool_i = carry
                blk, cells_b = inp  # (), (ns_loc, bn)
                # impl="auto": fused Pallas chunked kernel on TPU, jnp oracle
                # elsewhere — same dispatch as the single-host streaming path.
                part = sc_scores_cells(ranks, cuts, cells_b)  # (q_chunk, bn)
                s = jax.lax.psum(part.astype(jnp.int8), cfg.model_axis)
                s = s.astype(jnp.int32)
                lids = blk * bn + jnp.arange(bn, dtype=jnp.int32)
                valid = lids < n_loc  # mask block padding past the shard end
                s = jnp.where(valid[None, :], s, -1)
                ids_b = jnp.broadcast_to(
                    jnp.where(valid, lids, int_max), (q_chunk, bn)
                )
                return merge_topk_pool(pool_s, pool_i, s, ids_b), None

            init = (
                jnp.full((q_chunk, m_cand), -1, jnp.int32),
                jnp.full((q_chunk, m_cand), int_max, jnp.int32),
            )
            init = pcast_varying(init, tuple(mesh.axis_names))
            (pool_s, pool_i), _ = jax.lax.scan(
                blk_step, init, (jnp.arange(n_blocks, dtype=jnp.int32), cells_blk)
            )
            return pool_i  # (q_chunk, m_cand) local ids

        def chunk_fn(qc_idx):
            sl = lambda a: jax.lax.dynamic_slice_in_dim(a, qc_idx * q_chunk, q_chunk, axis=1)
            d1c, d2c = sl(d1), sl(d2)  # (ns_loc, q_chunk, sqrt_k)

            if bn:
                cand = _streaming_candidates(d1c, d2c)
            else:
                cand = _dense_candidates(d1c, d2c)
            # partial-distance re-rank over this rank's dim slice
            q_blk = jax.lax.dynamic_slice_in_dim(q_loc, qc_idx * q_chunk, q_chunk, axis=0)
            xc = jnp.take(x_loc, cand, axis=0)  # (qc, m_cand, d_loc)
            diff = xc - q_blk[:, None, :]
            part = jnp.sum(diff * diff, axis=-1)  # (qc, m_cand)
            full = jax.lax.psum(part, cfg.model_axis)
            neg, pos = jax.lax.top_k(-full, k)
            ids = jnp.take_along_axis(cand, pos, axis=1) + offset
            return ids.astype(jnp.int32), -neg

        n_chunks = mq // q_chunk
        ids, dists = jax.lax.map(chunk_fn, jnp.arange(n_chunks))
        ids = ids.reshape(mq, k)
        dists = dists.reshape(mq, k)

        # global top-k merge over point shards
        all_ids = jax.lax.all_gather(ids, pa, axis=0, tiled=False)
        all_d = jax.lax.all_gather(dists, pa, axis=0, tiled=False)
        all_ids = jnp.moveaxis(all_ids, 0, 1).reshape(mq, -1)
        all_d = jnp.moveaxis(all_d, 0, 1).reshape(mq, -1)
        neg, pos = jax.lax.top_k(-all_d, k)
        final_ids = jnp.take_along_axis(all_ids, pos, axis=1)
        return final_ids, -neg

    return jax.jit(
        shard_map_compat(
            _query,
            mesh=mesh,
            in_specs=(
                P(pa, cfg.model_axis),
                P(cfg.model_axis, None, None),
                P(cfg.model_axis, None, None),
                P(cfg.model_axis, pa),
                P(cfg.model_axis, None),
                P(None, cfg.model_axis),
            ),
            out_specs=(P(None, None), P(None, None)),
            # The final (ids, dists) are bitwise-identical on every shard
            # (all_gather + deterministic top_k), but the replication/VMA
            # analysis cannot prove it through gather+top_k — disable the check.
            check=False,
        )
    )


def query_sharded(
    mesh: Mesh, cfg: DistSuCoConfig, x: jax.Array, index: SuCoIndex, q: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Convenience wrapper: builds and invokes the sharded query step."""
    fn = make_query_fn(mesh, cfg, x.shape[0], x.shape[1], q.shape[0])
    return fn(x, index.centroids1, index.centroids2, index.cell_ids, index.cell_counts, q)


# --------------------------------------------------------------------------
# ShardedSuCoEngine: the multi-device serving counterpart of SuCoEngine
# --------------------------------------------------------------------------


def _bucket_mq(m: int, buckets: Sequence[int], q_chunk: int) -> int:
    b = batch_bucket(m, buckets)
    if b > q_chunk:
        b = -(-b // q_chunk) * q_chunk
    return b


class ShardedSuCoEngine:
    """Sharded serving engine — :class:`repro.core.suco.SuCoEngine` across a
    mesh.

    Shares the single-host engine's two serving contracts: the **artifact
    format** (``SuCoIndex.save``/``load`` npz — an index persisted by a
    single-host build loads straight onto the mesh via
    :func:`shard_index`) and the **bucketing policy**
    (:func:`repro.core.suco.batch_bucket`, additionally rounded up to a
    ``q_chunk`` multiple, the sharded query step's scan granularity).  One
    compiled query executable per bucket; after :meth:`warmup` covers the
    traffic mix, ``compile_count`` stays flat.  ``k`` is part of the
    engine's ``DistSuCoConfig`` (per-shard candidate pools are sized from
    it), so heterogeneous-k traffic runs one sharded engine per k.
    """

    def __init__(
        self,
        mesh: Mesh,
        cfg: DistSuCoConfig,
        x: jax.Array,
        index: SuCoIndex,
        *,
        batch_buckets: Sequence[int] = DEFAULT_BATCH_BUCKETS,
    ):
        self.mesh = mesh
        self.cfg = cfg
        self._sh = index_shardings(mesh, cfg)
        self.x = jax.device_put(x, self._sh["x"])
        self.index = shard_index(mesh, cfg, index)
        self.batch_buckets = tuple(batch_buckets)
        self._fns: dict[int, object] = {}

    # ---- lifecycle -------------------------------------------------------

    @classmethod
    def build(
        cls,
        mesh: Mesh,
        cfg: DistSuCoConfig,
        x: jax.Array,
        *,
        batch_buckets: Sequence[int] = DEFAULT_BATCH_BUCKETS,
    ) -> "ShardedSuCoEngine":
        """Distributed Algorithm 2 (:func:`build_sharded`) -> engine."""
        sh = index_shardings(mesh, cfg)
        x = jax.device_put(x, sh["x"])
        return cls(mesh, cfg, x, build_sharded(mesh, x, cfg),
                   batch_buckets=batch_buckets)

    @classmethod
    def from_artifact(
        cls,
        path,
        mesh: Mesh,
        cfg: DistSuCoConfig,
        x: jax.Array,
        *,
        batch_buckets: Sequence[int] = DEFAULT_BATCH_BUCKETS,
    ) -> "ShardedSuCoEngine":
        """Serve a ``SuCoIndex.save`` artifact across the mesh."""
        index, _ = load_index_artifact(path)
        return cls(mesh, cfg, x, index, batch_buckets=batch_buckets)

    def save(self, path, config=None) -> None:
        """Persist the index artifact (gathers the sharded arrays)."""
        local = jax.device_put(self.index, jax.devices()[0])
        local.save(path, config)

    # ---- bucketing -------------------------------------------------------

    def bucket_mq(self, m: int) -> int:
        """The padded query-batch size serving ``m`` queries: the shared
        :func:`batch_bucket` policy, rounded up to a ``q_chunk`` multiple
        when the bucket exceeds one chunk (``make_query_fn`` scans the
        batch in ``q_chunk`` slices)."""
        return _bucket_mq(m, self.batch_buckets, self.cfg.q_chunk)

    @staticmethod
    def aot_query_fn(
        mesh: Mesh,
        cfg: DistSuCoConfig,
        n: int,
        d: int,
        m: int,
        *,
        batch_buckets: Sequence[int] = DEFAULT_BATCH_BUCKETS,
    ):
        """Ahead-of-time form of the serving path: ``-> (query fn, mq)``.

        Applies the engine's bucketing policy to ``m`` and returns the
        jitted sharded query step a live engine would dispatch that bucket
        to, plus the padded batch size ``mq`` — so compile-only drivers
        (the 1B dry-run) lower exactly the executable production serves,
        without materialising any data.
        """
        mq = _bucket_mq(m, batch_buckets, cfg.q_chunk)
        return make_query_fn(mesh, cfg, n, d, mq), mq

    # ---- query -----------------------------------------------------------

    def _fn_for(self, mq: int):
        fn = self._fns.get(mq)
        if fn is None:
            n, d = self.x.shape
            fn = make_query_fn(self.mesh, self.cfg, n, d, mq)
            self._fns[mq] = fn
        return fn

    def _invoke(self, b: int, q_padded: jax.Array) -> tuple[jax.Array, jax.Array]:
        q_padded = jax.device_put(q_padded, self._sh["queries"])
        idx = self.index
        return self._fn_for(b)(
            self.x, idx.centroids1, idx.centroids2, idx.cell_ids,
            idx.cell_counts, q_padded,
        )

    def query(self, q: jax.Array) -> tuple[jax.Array, jax.Array]:
        """``q: (m, d) -> (ids (m, k), dists (m, k))`` global top-k."""
        q = jnp.asarray(q)
        m = q.shape[0]
        b = self.bucket_mq(m)
        if b != m:
            q = jnp.pad(q, ((0, b - m), (0, 0)))
        ids, dists = self._invoke(b, q)
        return ids[:m], dists[:m]

    def warmup(self, batch_sizes: Sequence[int] = (1,)) -> int:
        """Pre-compile one executable per bucket covering the traffic mix."""
        before = self.compile_count
        d = self.x.shape[1]
        for b in sorted({self.bucket_mq(m) for m in batch_sizes}):
            jax.block_until_ready(  # jaxlint: sync-ok — warmup, off hot path
                self._invoke(b, jnp.zeros((b, d), self.x.dtype))[0]
            )
        return self.compile_count - before

    @property
    def compile_count(self) -> int:
        """Number of compiled sharded query executables (one per bucket)."""
        return len(self._fns)


# --------------------------------------------------------------------------
# ShardedEnginePool: per-k engines for heterogeneous-k sharded traffic
# --------------------------------------------------------------------------


class ShardedEnginePool:
    """Per-``k`` pool of :class:`ShardedSuCoEngine` over one placed dataset.

    A sharded engine bakes ``k`` into its config (per-shard candidate
    pools are sized ``candidate_pool_size(n_local, k, beta)``), so
    heterogeneous-``k``
    traffic cannot share one engine without retracing or serialising on a
    single ``k``.  The pool places ``(x, index)`` on the mesh exactly once
    and keeps one engine per ``k`` — all sharing the placed arrays (a
    ``device_put`` onto the sharding they already carry is a no-op), the
    artifact format, and the bucketing policy — so each request binds to
    the pre-warmed ``(bucket, k)`` executable of its ``k``'s engine.
    After :meth:`warmup` covers the traffic mix, the pool-wide
    ``compile_count`` stays flat: the zero-retrace invariant holds across
    every ``k``.
    """

    def __init__(
        self,
        mesh: Mesh,
        cfg: DistSuCoConfig,
        x: jax.Array,
        index: SuCoIndex,
        *,
        ks: Sequence[int] = (),
        batch_buckets: Sequence[int] = DEFAULT_BATCH_BUCKETS,
    ):
        self.mesh = mesh
        self.cfg = cfg
        self._sh = index_shardings(mesh, cfg)
        self.x = jax.device_put(x, self._sh["x"])
        self.index = shard_index(mesh, cfg, index)
        self.batch_buckets = tuple(batch_buckets)
        self._engines: dict[int, ShardedSuCoEngine] = {}
        self._dead: set[int] = set()  # k-classes whose engine raised
        self._rebound: dict[int, str] = {}  # dead k -> failure reason
        for k in ks:
            self.engine_for(k)

    # ---- lifecycle -------------------------------------------------------

    @classmethod
    def build(
        cls,
        mesh: Mesh,
        cfg: DistSuCoConfig,
        x: jax.Array,
        *,
        ks: Sequence[int] = (),
        batch_buckets: Sequence[int] = DEFAULT_BATCH_BUCKETS,
    ) -> "ShardedEnginePool":
        """Distributed Algorithm 2 (:func:`build_sharded`) -> pool."""
        sh = index_shardings(mesh, cfg)
        x = jax.device_put(x, sh["x"])
        return cls(mesh, cfg, x, build_sharded(mesh, x, cfg), ks=ks,
                   batch_buckets=batch_buckets)

    @classmethod
    def from_artifact(
        cls,
        path,
        mesh: Mesh,
        cfg: DistSuCoConfig,
        x: jax.Array,
        *,
        ks: Sequence[int] = (),
        batch_buckets: Sequence[int] = DEFAULT_BATCH_BUCKETS,
    ) -> "ShardedEnginePool":
        """Serve a ``SuCoIndex.save`` artifact across the mesh, per-k pooled."""
        index, _ = load_index_artifact(path)
        return cls(mesh, cfg, x, index, ks=ks, batch_buckets=batch_buckets)

    def save(self, path, config=None) -> None:
        """Persist the shared index artifact (gathers the sharded arrays)."""
        local = jax.device_put(self.index, jax.devices()[0])
        local.save(path, config)

    # ---- binding ---------------------------------------------------------

    @property
    def ks(self) -> tuple[int, ...]:
        """The ``k`` values with live engines."""
        return tuple(sorted(self._engines))

    @property
    def dead_ks(self) -> tuple[int, ...]:
        """k-classes marked dead by :meth:`query_resilient` (their traffic
        is rebound to healthy engines until :meth:`revive`)."""
        return tuple(sorted(self._dead))

    def engine_for(self, k: int) -> ShardedSuCoEngine:
        """The pool member serving ``k`` (created on first use: a cold
        engine compiles on its first query, so pre-declare the traffic's
        ``k`` mix via ``ks=``/:meth:`warmup` to keep serving retrace-free)."""
        eng = self._engines.get(k)
        if eng is None:
            if not 1 <= k <= self.x.shape[0]:
                raise ValueError(f"k={k} must be in [1, n={self.x.shape[0]}]")
            eng = ShardedSuCoEngine(
                self.mesh,
                dataclasses.replace(self.cfg, k=k),
                self.x,
                self.index,
                batch_buckets=self.batch_buckets,
            )
            self._engines[k] = eng
        return eng

    # ---- query -----------------------------------------------------------

    def query(self, q: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
        """``q: (m, d), k -> (ids (m, k), dists (m, k))`` global top-k via
        the per-``k`` engine's bucketed executable."""
        return self.engine_for(k).query(q)

    # ---- fault tolerance -------------------------------------------------

    def _rebind_target(self, k: int) -> int:
        """The healthy k-class serving a dead ``k``: the smallest live
        ``k' >= k`` (its top-k' answer truncates to an *exact* top-k),
        else the largest live ``k' < k`` (a shorter answer, still
        quantified — the caller sees ``degraded=True`` either way)."""
        live = [kk for kk in sorted(self._engines) if kk not in self._dead]
        if not live:
            raise RuntimeError(
                f"ShardedEnginePool: no healthy engines left to rebind k={k} "
                f"(dead: {sorted(self._dead)})"
            )
        for kk in live:
            if kk >= k:
                return kk
        return live[-1]

    def revive(self, k: int) -> None:
        """Return a dead k-class to service (the recover half of a chaos
        degrade/recover cycle).  A fresh engine replaces the dead one so a
        poisoned ``query`` binding does not linger."""
        if k in self._dead:
            self._dead.discard(k)
            self._rebound.pop(k, None)
            self._engines.pop(k, None)
            self.engine_for(k)

    def query_resilient(
        self, q: jax.Array, k: int
    ) -> tuple[jax.Array, jax.Array, dict]:
        """:meth:`query` that survives a dead/raising per-``k`` engine.

        A non-``ValueError`` failure (a real engine does not raise on a
        well-formed query — this is a dying shard binding) marks the
        k-class dead and rebinds the request to a healthy engine
        (:meth:`_rebind_target`); the answer is truncated to ``k`` when
        the stand-in serves a larger k' (exact), or returned shorter when
        only a smaller k' survives.  Returns ``(ids, dists, info)`` with
        ``info = {"degraded": bool, "served_by": k', "reason": str}`` so
        callers can mark degraded answers instead of silently passing
        them off as primary ones.  ``ValueError`` (malformed input) is
        re-raised unchanged — a bad query must not kill a healthy engine.
        """
        if k not in self._dead:
            try:
                ids, dists = self.engine_for(k).query(q)
                return ids, dists, {"degraded": False, "served_by": k, "reason": ""}
            except ValueError:
                raise
            except Exception as e:
                self._dead.add(k)
                self._rebound[k] = f"{type(e).__name__}: {e}"
        k2 = self._rebind_target(k)
        ids, dists = self.engine_for(k2).query(q)
        if k2 > k:
            ids, dists = ids[..., :k], dists[..., :k]
        return ids, dists, {
            "degraded": True,
            "served_by": k2,
            "reason": f"k={k} engine dead ({self._rebound.get(k, 'unknown')}), "
                      f"rebound to k={k2}",
        }

    def warmup(
        self,
        batch_sizes: Sequence[int] = (1,),
        ks: Sequence[int] | None = None,
    ) -> int:
        """Pre-compile one executable per (bucket, k) over the traffic mix;
        returns the number of fresh compiles.  ``ks=None`` warms the
        engines already in the pool."""
        ks = self.ks if ks is None else ks
        return sum(self.engine_for(k).warmup(batch_sizes) for k in sorted(set(ks)))

    @property
    def compile_count(self) -> int:
        """Pool-wide compiled executables (sum of per-k jit caches) — the
        zero-retrace serving invariant is that this is flat after warmup."""
        return sum(e.compile_count for e in self._engines.values())

    @staticmethod
    def aot_query_fn(
        mesh: Mesh,
        cfg: DistSuCoConfig,
        n: int,
        d: int,
        m: int,
        k: int,
        *,
        batch_buckets: Sequence[int] = DEFAULT_BATCH_BUCKETS,
    ):
        """Ahead-of-time form of one pool binding: the jitted sharded query
        step a live pool would dispatch an ``(m, k)`` request to, plus the
        padded batch size — :meth:`ShardedSuCoEngine.aot_query_fn` with
        ``k`` bound the way :meth:`engine_for` binds it."""
        return ShardedSuCoEngine.aot_query_fn(
            mesh, dataclasses.replace(cfg, k=k), n, d, m,
            batch_buckets=batch_buckets,
        )
