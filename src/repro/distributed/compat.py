"""Version-compat shims for jax SPMD APIs used by the distributed engine.

The pinned jax 0.4.37 predates two APIs the engine targets:

* ``jax.shard_map`` (top-level, with ``check_vma``) — 0.4.37 only has
  ``jax.experimental.shard_map.shard_map`` with the older ``check_rep``
  replication check.
* ``jax.lax.pcast`` (varying-manual-axes casts) — 0.4.37's shard_map has
  no VMA type system, so the cast is a no-op there.

Both shims dispatch on feature presence, not version strings, so they keep
working as the environment's jax moves forward.
"""

from __future__ import annotations

from typing import Any, Callable

import jax

__all__ = ["shard_map_compat", "pcast_varying"]


def shard_map_compat(
    f: Callable, *, mesh, in_specs, out_specs, check: bool = True
) -> Callable:
    """``jax.shard_map`` where available, else the experimental spelling.

    ``check=False`` disables the replication/VMA output check (the engine
    needs this for gather+top_k outputs the analyses cannot prove
    replicated).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check
    )


def pcast_varying(x: Any, axis_names: tuple[str, ...]) -> Any:
    """Mark ``x`` as device-varying over ``axis_names`` (no-op pre-VMA)."""
    if hasattr(jax.lax, "pcast"):
        return jax.lax.pcast(x, axis_names, to="varying")
    return x
