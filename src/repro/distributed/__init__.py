from repro.distributed.engine import (
    DistSuCoConfig,
    ShardedEnginePool,
    ShardedSuCoEngine,
    build_sharded,
    index_shardings,
    make_query_fn,
    query_sharded,
    shard_index,
)
from repro.distributed.elastic import reshard_index, index_to_host, index_from_host

__all__ = [
    "DistSuCoConfig",
    "ShardedEnginePool",
    "ShardedSuCoEngine",
    "build_sharded",
    "index_shardings",
    "make_query_fn",
    "query_sharded",
    "shard_index",
    "reshard_index",
    "index_to_host",
    "index_from_host",
]
