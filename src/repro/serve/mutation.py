"""Drift-triggered re-cluster and warm handoff for a mutating serving index.

The engine layer gives live mutation its mechanics — slot inserts against
frozen centroids (:meth:`repro.core.suco.SuCoEngine.insert`), tombstoned
deletes (:meth:`~repro.core.suco.SuCoEngine.delete`), and the atomic warm
:meth:`~repro.core.suco.SuCoEngine.swap`.  This module adds the *policy*
that decides when mutation has degraded the index enough to rebuild it,
and the orchestration that performs the rebuild without the serving
process dropping a request:

* :class:`DriftMonitor` — compares the live per-subspace cell-occupancy
  distribution against a baseline snapshot (total-variation distance),
  alongside the tombstone dead fraction, the slot fill fraction, and the
  ratio of insert assignment inertia to the baseline corpus inertia.
  TaCo's observation (PAPERS.md) is the design driver: re-cluster when
  the *observed* collision/occupancy statistics drift from what the
  centroids were trained on, not on a wall-clock timer.
* :class:`MutationManager` — owns the insert/delete/re-index lifecycle
  over an :class:`~repro.serve.ann.AnnServer`: external-key bookkeeping
  across slot renumbering, the ``minibatch`` re-cluster of the live
  corpus into a successor engine, per-level warmup of the successor over
  exactly the ``(bucket, k)`` traffic the old surface has served, and
  the final :meth:`~repro.serve.ann.AnnServer.swap`.

The handoff contract (``docs/index_mutation.md``): the successor is
warmed *before* the swap, the swap itself is in-place adoption on the
old engine objects, and queued requests ride through — so across the
whole re-index, ``retraces_after_warmup == 0`` on both engines and no
request is dropped, failed, or served a tombstoned id.

CPU-scale usage sketch (see ``tests/test_mutation_serving.py``)::

    manager = MutationManager(server, build_config)
    manager.insert(new_rows)          # slot inserts, no retrace
    manager.delete(stale_keys)        # tombstones, invisible next batch
    report = manager.maybe_reindex()  # re-cluster + warm swap if drifted
"""

from __future__ import annotations

import dataclasses
import math
import threading

import jax.numpy as jnp
import numpy as np

from repro.core.suco import (
    CapacityError,
    SuCoConfig,
    SuCoEngine,
    assign_points,
    build_index,
)
from repro.serve.ann import AnnServer, DegradationLadder

__all__ = [
    "DriftReport",
    "DriftMonitor",
    "MutationManager",
    "ReindexInProgressError",
    "warm_like",
]


class ReindexInProgressError(RuntimeError):
    """A re-index is already in flight: the single-flight guard rejects a
    second one (and rejects inserts/deletes while an *asynchronous*
    prepare is pending, so the gathered corpus cannot go stale under
    the prepare's feet)."""


@dataclasses.dataclass(frozen=True)
class DriftReport:
    """One drift observation: the statistics and which thresholds fired."""

    tv_distance: float  # max over subspaces, occupancy vs baseline
    dead_fraction: float  # tombstoned fraction of assigned slots
    fill_fraction: float  # assigned slots / capacity
    inertia_ratio: float  # insert assignment inertia / baseline (1.0 = none)
    reasons: tuple[str, ...]  # empty = no re-cluster needed

    @property
    def triggered(self) -> bool:
        return bool(self.reasons)


def _occupancy(counts: np.ndarray) -> np.ndarray:
    """Per-subspace live-count distribution ``(Ns, K) -> (Ns, K)`` rows
    summing to 1 (uniform for an empty subspace, so TV stays defined)."""
    counts = np.maximum(counts.astype(np.float64), 0.0)
    tot = counts.sum(axis=1, keepdims=True)
    k = counts.shape[1]
    return np.where(tot > 0, counts / np.maximum(tot, 1.0), 1.0 / k)


class DriftMonitor:
    """Occupancy/inertia drift detector against a captured baseline.

    :meth:`capture` snapshots the engine's live per-subspace cell
    occupancy and the mean per-point assignment inertia of the live
    corpus under the current centroids; :meth:`observe` compares the
    engine's current statistics against that snapshot and returns a
    :class:`DriftReport` whose ``reasons`` name every threshold crossed:

    * ``tv_threshold`` — maximum per-subspace total-variation distance
      between the live occupancy distribution and the baseline.  Inserts
      landing in cells the build never filled (or deletes hollowing out
      built cells) move this; it is the distributional analogue of the
      collision-count drift TaCo re-clusters on.
    * ``max_dead_fraction`` — tombstones carry a real cost (scored then
      masked), so a mostly-dead slot range wants compaction.
    * ``max_fill_fraction`` — re-index *before* inserts start raising
      :class:`~repro.core.suco.CapacityError`.
    * ``inertia_ratio_threshold`` — inserted points assigning with much
      higher inertia than the corpus the centroids were trained on means
      the codebooks no longer describe the incoming data.
    """

    def __init__(
        self,
        *,
        tv_threshold: float = 0.15,
        max_dead_fraction: float = 0.25,
        max_fill_fraction: float = 0.9,
        inertia_ratio_threshold: float = 2.0,
    ):
        if not 0.0 < tv_threshold <= 1.0:
            raise ValueError(f"tv_threshold must be in (0, 1], got {tv_threshold}")
        if not 0.0 < max_dead_fraction <= 1.0:
            raise ValueError(
                f"max_dead_fraction must be in (0, 1], got {max_dead_fraction}"
            )
        if not 0.0 < max_fill_fraction <= 1.0:
            raise ValueError(
                f"max_fill_fraction must be in (0, 1], got {max_fill_fraction}"
            )
        if inertia_ratio_threshold <= 1.0:
            raise ValueError(
                "inertia_ratio_threshold must be > 1, got "
                f"{inertia_ratio_threshold}"
            )
        self.tv_threshold = tv_threshold
        self.max_dead_fraction = max_dead_fraction
        self.max_fill_fraction = max_fill_fraction
        self.inertia_ratio_threshold = inertia_ratio_threshold
        self._baseline: np.ndarray | None = None
        self._baseline_inertia = 0.0

    def capture(self, engine: SuCoEngine) -> "DriftMonitor":
        """Snapshot ``engine``'s live statistics as the new baseline."""
        counts = np.asarray(engine.index.cell_counts)  # jaxlint: sync-ok — baseline snapshot
        self._baseline = _occupancy(counts)
        self._baseline_inertia = _corpus_inertia(engine)
        return self

    def observe(self, engine: SuCoEngine) -> DriftReport:
        """Compare ``engine``'s live statistics against the baseline."""
        if self._baseline is None:
            raise ValueError("no baseline captured — call capture(engine) first")
        counts = np.asarray(engine.index.cell_counts)  # jaxlint: sync-ok — drift statistics
        occ = _occupancy(counts)
        tv = float(np.max(0.5 * np.abs(occ - self._baseline).sum(axis=1)))
        assigned = int(engine._next_slot)
        dead = (assigned - engine.n_live) / max(assigned, 1)
        cap = engine.capacity
        fill = assigned / cap if cap else 1.0
        base = self._baseline_inertia
        per_insert = engine.insert_inertia_per_point
        ratio = per_insert / base if (per_insert > 0 and base > 0) else 1.0
        reasons = []
        if tv >= self.tv_threshold:
            reasons.append(f"occupancy tv {tv:.3f} >= {self.tv_threshold}")
        if dead >= self.max_dead_fraction:
            reasons.append(f"dead fraction {dead:.3f} >= {self.max_dead_fraction}")
        if fill >= self.max_fill_fraction:
            reasons.append(f"fill fraction {fill:.3f} >= {self.max_fill_fraction}")
        if ratio >= self.inertia_ratio_threshold:
            reasons.append(
                f"insert inertia ratio {ratio:.2f} >= "
                f"{self.inertia_ratio_threshold}"
            )
        return DriftReport(
            tv_distance=tv,
            dead_fraction=float(dead),
            fill_fraction=float(fill),
            inertia_ratio=float(ratio),
            reasons=tuple(reasons),
        )


def _corpus_inertia(engine: SuCoEngine) -> float:
    """Mean per-point assignment inertia of the live corpus under the
    engine's current centroids — the baseline the insert-inertia drift
    signal is a ratio against.  One chunked assignment pass."""
    keys, x_live = _live_rows(engine)
    if len(x_live) == 0:
        return 0.0
    idx = engine.index
    _, _, inertia = assign_points(
        jnp.asarray(x_live),
        idx.centroids1,
        idx.centroids2,
        spec=idx.spec,
        sqrt_k=idx.sqrt_k,
        block_n=engine.policy.block_n,
    )
    return float(inertia) / len(x_live)


def _live_rows(engine: SuCoEngine) -> tuple[np.ndarray, np.ndarray]:
    """``(slot_ids, rows)`` of the live (assigned, non-tombstoned) points."""
    assigned = int(engine._next_slot)
    if engine.index.tombstone is None:
        live = np.ones(assigned, bool)
    else:
        live = ~np.asarray(engine.index.tombstone[:assigned])  # jaxlint: sync-ok — host gather for re-index
    x = np.asarray(engine.x[:assigned])  # jaxlint: sync-ok — host gather for re-index
    return np.flatnonzero(live), np.compress(live, x, axis=0)


def warm_like(new_engine: SuCoEngine, old_engine: SuCoEngine) -> int:
    """Pre-compile ``new_engine`` over exactly the ``(bucket, k)`` pairs
    ``old_engine`` has served — the warm-handoff precondition of
    :meth:`~repro.core.suco.SuCoEngine.swap`.  Returns fresh compiles."""
    fresh = 0
    for b, k in sorted(old_engine._buckets_seen):
        fresh += new_engine.warmup([b], [k])
    return fresh


class MutationManager:
    """Insert/delete/re-index lifecycle over a serving :class:`AnnServer`.

    Answers carry engine *slot* ids, and a re-index renumbers slots (the
    live corpus compacts into a fresh engine).  The manager therefore
    tracks a stable external key per slot: :meth:`insert` assigns (or
    accepts) keys, :meth:`delete` tombstones by key, and :meth:`keys_of`
    maps a query answer's slot ids back to keys — valid for the engine
    generation the answer was served on, which is why callers translate
    ids at retire time (exactly what the mutate-while-serving test does).

    :meth:`reindex` is the warm handoff: gather the live rows on the
    host, ``minibatch``-re-cluster them into a successor engine with
    ``capacity_factor`` headroom, warm the successor (level-for-level
    when the server carries a degradation ladder) over the old surface's
    seen traffic, then :meth:`~repro.serve.ann.AnnServer.swap`.
    :meth:`maybe_reindex` gates that on the :class:`DriftMonitor`;
    :meth:`insert` retries through a re-index once when the engine is
    out of slots (``auto_reindex``).
    """

    def __init__(
        self,
        server: AnnServer,
        config: SuCoConfig,
        *,
        monitor: DriftMonitor | None = None,
        capacity_factor: float = 2.0,
        auto_reindex: bool = True,
        stats_seed: int = 0,
    ):
        if capacity_factor < 1.0:
            raise ValueError(
                f"capacity_factor must be >= 1, got {capacity_factor}"
            )
        self.server = server
        self.config = config
        self.capacity_factor = float(capacity_factor)
        self.auto_reindex = auto_reindex
        self.stats_seed = stats_seed
        self.monitor = DriftMonitor() if monitor is None else monitor
        self.monitor.capture(self.engine)
        self.reindexes = 0
        n0 = int(self.engine._next_slot)
        self._keys = np.arange(n0, dtype=np.int64)
        self._next_key = n0
        # A repro.serve.durability.Durability (or None) — wired by
        # Durability.attach; a committed re-index is WAL-logged through it.
        self.durability = None
        self._reindex_lock = threading.Lock()  # single-flight claim
        self._reindexing = False
        self._pending: _ReindexJob | None = None

    @property
    def engine(self) -> SuCoEngine:
        """The server's base engine (a chaos proxy delegates through)."""
        return self.server.engine

    # ---- key bookkeeping -------------------------------------------------

    def keys_of(self, slot_ids) -> np.ndarray:
        """External keys for engine slot ids of the *current* generation."""
        return self._keys[np.asarray(slot_ids)]  # jaxlint: sync-ok — host id translation

    def live_keys(self) -> np.ndarray:
        """Keys of the currently live points."""
        slots, _ = _live_rows(self.engine)
        return self._keys[slots]

    # ---- mutation --------------------------------------------------------

    def insert(self, x_new, keys=None) -> np.ndarray:
        """Insert rows, routed through the server (ladder siblings rebind);
        returns their external keys.  Out of slots + ``auto_reindex`` →
        one re-index (with headroom for the batch) and a retry."""
        x_new = np.atleast_2d(np.asarray(x_new))  # jaxlint: sync-ok — host payload
        b = x_new.shape[0]
        if keys is None:
            keys = np.arange(self._next_key, self._next_key + b, dtype=np.int64)
        keys = np.asarray(keys, dtype=np.int64)  # jaxlint: sync-ok — host key list
        if keys.shape != (b,):
            raise ValueError(f"keys must be ({b},), got {keys.shape}")
        if np.isin(keys, self._keys).any():
            raise ValueError("keys must be fresh — at least one is already in use")
        self._check_no_pending("insert")
        try:
            self.server.insert(x_new, keys=keys)
        except CapacityError:
            if not self.auto_reindex:
                raise
            self.reindex(min_free=b)
            self.server.insert(x_new, keys=keys)
        self._keys = np.concatenate([self._keys, keys])
        if b:
            self._next_key = max(self._next_key, int(keys.max()) + 1)
        return keys

    def delete(self, keys) -> int:
        """Tombstone points by external key; returns newly-deleted count.
        Unknown keys are ignored (delete is idempotent end to end)."""
        keys = np.asarray(keys)  # jaxlint: sync-ok — host key list
        slots = np.flatnonzero(np.isin(self._keys, keys))
        if slots.size == 0:
            return 0
        self._check_no_pending("delete")
        return self.server.delete(slots)

    # ---- re-index handoff ------------------------------------------------

    def check(self) -> DriftReport:
        """One drift observation against the current baseline."""
        return self.monitor.observe(self.engine)

    def maybe_reindex(self) -> DriftReport:
        """Observe drift; re-cluster + warm swap when any threshold fired."""
        report = self.check()
        if report.triggered:
            self.reindex()
        return report

    def _check_no_pending(self, op: str) -> None:
        if self._pending is not None:
            raise ReindexInProgressError(
                f"{op} rejected: an asynchronous re-index prepare is pending "
                "— finish_reindex() first (mutating now would invalidate the "
                "gathered corpus the successor is being built from)"
            )

    def _claim(self) -> None:
        with self._reindex_lock:
            if self._reindexing:
                raise ReindexInProgressError(
                    "a re-index is already in flight — the single-flight "
                    "guard admits one at a time"
                )
            self._reindexing = True

    def _release(self) -> None:
        with self._reindex_lock:
            self._reindexing = False

    def _gather(self, capacity: int | None, min_free: int) -> "_Gathered":
        """Phase 1, on the caller (serving) thread: host-gather everything
        the off-thread prepare needs, so the prepare never reads live
        mutable state (the old ladder's ``_buckets_seen`` sets mutate
        under traffic — they are *copied* here)."""
        slots, x_live = _live_rows(self.engine)
        live_keys = self._keys[slots]
        n_live = len(x_live)
        if n_live == 0:
            raise ValueError("cannot re-index an empty live corpus")
        if capacity is None:
            capacity = int(math.ceil(n_live * self.capacity_factor))
        capacity = max(capacity, n_live + min_free)
        old = self.engine
        old_ladder = self.server.ladder
        if old_ladder is not None:
            seen = tuple(sorted(e._buckets_seen) for e in old_ladder.engines)
            ladder_meta = (
                old_ladder.max_level,
                old_ladder.m_stat,
                old_ladder.sigma_stat,
            )
        else:
            seen = (sorted(old._buckets_seen),)
            ladder_meta = None
        return _Gathered(
            x_live=x_live,
            live_keys=live_keys,
            capacity=int(capacity),
            min_free=int(min_free),
            dtype=np.asarray(old.x).dtype,  # jaxlint: sync-ok — dtype probe
            policy=dataclasses.replace(old.policy),  # fresh traffic histogram
            seen=seen,
            ladder_meta=ladder_meta,
        )

    def _build_successor(self, g: "_Gathered") -> "_Prepared":
        """Phase 2, safe to run off-thread: re-cluster the gathered corpus
        and warm a successor surface.  Touches nothing on the incumbent —
        an exception (or an injected crash) here leaves the server
        serving exactly as before."""
        cfg = dataclasses.replace(self.config, build_mode="minibatch")
        x_dev = jnp.asarray(g.x_live, dtype=g.dtype)
        index = build_index(x_dev, cfg)
        if self.durability is not None:
            self.durability.reach("reindex.mid-prepare")
        successor = SuCoEngine(x_dev, index, g.policy, capacity=g.capacity)
        ladder = None
        if g.ladder_meta is not None:
            levels, m_stat, sigma_stat = g.ladder_meta
            ladder = DegradationLadder(
                successor,
                levels=levels,
                stats=(m_stat, sigma_stat),
                stats_seed=self.stats_seed,
            )
            for pairs, new_e in zip(g.seen, ladder.engines):
                for b, k in pairs:
                    new_e.warmup([b], [k])
        else:
            for b, k in g.seen[0]:
                successor.warmup([b], [k])
        return _Prepared(gathered=g, successor=successor, ladder=ladder)

    def _commit(self, p: "_Prepared") -> SuCoEngine:
        """Phase 3, on the caller thread: the warm swap and bookkeeping.
        With a durability root attached the committed re-index is
        WAL-logged (resolved capacity — replay rebuilds the identical
        successor) as the last step, after the in-memory state it
        describes exists."""
        dur = self.durability
        if dur is not None:
            dur._in_reindex = True
        try:
            self.server.swap(p.successor, ladder=p.ladder)
        finally:
            if dur is not None:
                dur._in_reindex = False
        # The cutover itself is done; reclaim the predecessor executables
        # here, off the serving surface (the manager runs between steps).
        for e in (
            self.server.ladder.engines if self.server.ladder is not None
            else [self.engine]
        ):
            e.release_retired()
        self._keys = p.gathered.live_keys
        self.monitor.capture(self.engine)
        self.reindexes += 1
        if dur is not None:
            dur.log_reindex(
                capacity=p.gathered.capacity, min_free=p.gathered.min_free
            )
        return self.engine

    def reindex(self, *, capacity: int | None = None, min_free: int = 0) -> SuCoEngine:
        """Re-cluster the live corpus and hand the server over warm.

        Gathers the live rows, rebuilds with the manager's build config
        forced to ``minibatch`` (the re-cluster must not need a dense
        ``(n, K)`` pass while serving), wraps the fresh index in a
        successor engine with ``capacity_factor`` slot headroom, warms it
        — level-for-level when a degradation ladder is installed — over
        the old surface's seen ``(bucket, k)`` traffic, and swaps.  Keys
        compact with the corpus, the drift baseline re-captures, and the
        successor engine (post-adoption, ``server.engine``) is returned.

        Failure containment: everything up to the swap builds a private
        successor — an exception anywhere in the prepare leaves the
        incumbent serving untouched.  Single-flight: a concurrent
        ``reindex``/``reindex_async`` raises
        :class:`ReindexInProgressError`.
        """
        self._check_no_pending("reindex")
        self._claim()
        try:
            prepared = self._build_successor(self._gather(capacity, min_free))
            return self._commit(prepared)
        finally:
            self._release()

    # ---- asynchronous prepare (off the serving thread) -------------------

    def reindex_async(
        self, *, capacity: int | None = None, min_free: int = 0
    ) -> "_ReindexJob":
        """Start the re-cluster prepare off-thread and return immediately:
        the server keeps answering while the successor builds.  The
        returned job is also stored; :meth:`finish_reindex` joins it and
        commits the warm swap (on the caller's thread — the swap itself
        stays between serving steps).  A prepare failure is contained:
        ``finish_reindex`` re-raises it and the incumbent is untouched.

        The prepare runs on the durability maintenance thread when one is
        attached (the same off-serving-path thread that group-commits the
        WAL), else on a dedicated daemon thread.
        """
        self._check_no_pending("reindex_async")
        self._claim()
        try:
            job = _ReindexJob(self, self._gather(capacity, min_free))
        except BaseException:
            self._release()
            raise
        self._pending = job
        dur = self.durability
        if dur is not None and dur.worker is not None:
            dur.worker.submit(job.run)
        else:
            threading.Thread(
                target=job.run, name="suco-reindex-prepare", daemon=True
            ).start()
        return job

    def finish_reindex(self, *, timeout: float | None = None) -> SuCoEngine:
        """Join the pending asynchronous prepare and commit the swap.

        If the prepare raised (including an injected :class:`CrashPoint`),
        the exception is re-raised here, the pending job is cleared, and
        the incumbent engine keeps serving — nothing was mutated.
        """
        job = self._pending
        if job is None:
            raise ValueError("no asynchronous re-index is pending")
        try:
            prepared = job.wait(timeout=timeout)
        except TimeoutError:
            raise  # still pending — call finish_reindex() again
        except BaseException:
            self._pending = None
            self._release()
            raise
        try:
            return self._commit(prepared)
        finally:
            self._pending = None
            self._release()

    # ---- durability ------------------------------------------------------

    def save(self, path) -> None:
        """One-shot durable save of the whole serving stack — engine,
        ladder stats, warm surface, and this manager's key table — as an
        atomic, checksummed artifact-v3 file.
        :func:`repro.serve.durability.load_serving_stack` round-trips it."""
        from repro.serve.durability import save_stack  # lazy: avoid cycle

        save_stack(path, self.server, self, config=self.config)


@dataclasses.dataclass(frozen=True)
class _Gathered:
    """Host snapshot handed from the serving thread to the prepare."""

    x_live: np.ndarray
    live_keys: np.ndarray
    capacity: int
    min_free: int
    dtype: np.dtype
    policy: object
    seen: tuple  # per-level sorted (bucket, k) lists, copied
    ladder_meta: tuple | None  # (levels, m_stat, sigma_stat) or None


@dataclasses.dataclass(frozen=True)
class _Prepared:
    gathered: _Gathered
    successor: SuCoEngine
    ladder: DegradationLadder | None


class _ReindexJob:
    """One asynchronous prepare: runs :meth:`MutationManager._build_successor`
    wherever it is scheduled, captures any failure (``BaseException`` — an
    injected crash must not kill the worker thread), and hands the result
    back on :meth:`wait`."""

    def __init__(self, manager: MutationManager, gathered: _Gathered):
        self._manager = manager
        self._gathered = gathered
        self._done = threading.Event()
        self._result: _Prepared | None = None
        self._error: BaseException | None = None

    def run(self) -> None:
        try:
            self._result = self._manager._build_successor(self._gathered)
        except BaseException as e:  # noqa: BLE001 — containment by design
            self._error = e
        finally:
            self._done.set()

    @property
    def done(self) -> bool:
        return self._done.is_set()

    def wait(self, *, timeout: float | None = None) -> _Prepared:
        if not self._done.wait(timeout=timeout):
            raise TimeoutError("re-index prepare still running")
        if self._error is not None:
            raise self._error
        return self._result
