"""Durability for the mutable serving index: WAL, snapshots, recovery.

PR 9 made the serving index mutable online; this module makes that
mutable state *durable*.  The contract (``docs/durability.md``):

* every acknowledged ``insert``/``delete``/``reindex`` appends a
  CRC32-checksummed, length-prefixed record to a write-ahead log
  (:class:`WriteAheadLog`) **after** the in-memory apply and **before**
  the call returns — a redo log: an acknowledged mutation is always
  fully framed on disk, an unacknowledged one may be lost;
* the fsync policy decides when a framed record is *storage*-durable:
  ``"always"`` fsyncs per record (the serving path pays the fsync),
  ``"group"`` (default) marks the log dirty and lets the off-serving-path
  :class:`MaintenanceWorker` thread group-commit within
  ``flush_interval_s`` — the serving path never blocks on storage, which
  jaxlint's host-sync audit proves statically (every ``os.fsync`` in this
  package carries a ``# jaxlint: sync-ok`` annotation naming the
  off-path context) — and ``"off"`` trusts the OS page cache;
* :meth:`Durability.snapshot` writes an atomic artifact-v3 checkpoint
  (content-checksummed npz via :meth:`repro.core.suco.SuCoIndex.save`)
  embedding the full serving sidecar — corpus rows, capacity layout,
  engine policy, warm ``(level, bucket, k)`` surface, degradation-ladder
  stats, the :class:`~repro.serve.mutation.MutationManager` key table,
  and the WAL high-water mark — then truncates the log back to the
  oldest *retained* snapshot (``snapshot_keep``), so a corrupt newest
  snapshot can still fall back to its predecessor plus a longer replay;
* :func:`recover` loads the newest snapshot that passes the content
  checksums, truncates any torn WAL tail (first bad/short frame — never
  behind an acknowledged fsync, because acknowledged records are fully
  framed), replays the tail through the real mutation surface
  (``server.insert`` / ``server.delete`` / ``manager.reindex`` — all
  deterministic, so recovery is bit-identical to the original apply),
  and re-warms the executables the pre-crash surface had compiled.

Crash-point instrumentation: every write/rename/fsync boundary calls
``reach(point)`` on an injected :class:`~repro.serve.chaos.CrashInjector`
(see ``CRASH_POINTS`` there); the recovery drill in
:mod:`repro.serve.chaos` kills the stack at each point and asserts
bit-identical recovery of the acknowledged prefix.
"""

from __future__ import annotations

import dataclasses
import os
import struct
import threading
import zlib
from collections import deque
from pathlib import Path
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.core.suco import (
    ArtifactError,
    EnginePolicy,
    SuCoConfig,
    SuCoEngine,
    load_index_artifact,
)
from repro.core.tuning import TileConfig
from repro.serve.ann import AnnServer, DegradationLadder

__all__ = [
    "WAL_MAGIC",
    "WalRecord",
    "encode_record",
    "decode_records",
    "WriteAheadLog",
    "MaintenanceWorker",
    "DurabilityConfig",
    "Durability",
    "RecoveryError",
    "RecoveryReport",
    "RecoveryResult",
    "recover",
    "save_stack",
    "load_serving_stack",
    "state_fingerprint",
    "fingerprint_diff",
]


class RecoveryError(RuntimeError):
    """Recovery cannot proceed (no valid snapshot, or replay diverged)."""


# --------------------------------------------------------------------------
# WAL record codec
# --------------------------------------------------------------------------

WAL_MAGIC = b"SUCOWAL1"

_KIND_TO_CODE = {"insert": 1, "delete": 2, "reindex": 3}
_CODE_TO_KIND = {v: k for k, v in _KIND_TO_CODE.items()}


@dataclasses.dataclass(frozen=True, eq=False)
class WalRecord:
    """One logged mutation.  ``seq`` is assigned by the WAL at append time
    (monotone, gapless within a log generation); which payload fields are
    set depends on ``kind``:

    * ``"insert"`` — ``rows`` (engine-dtype ``(b, d)``), ``slots`` (the
      acknowledged engine slots, replay-divergence check), ``keys`` (the
      external key table entries);
    * ``"delete"`` — ``slots`` (tombstoned engine slots);
    * ``"reindex"`` — the **resolved** ``capacity`` and ``min_free`` of
      the committed re-cluster, so replaying the record rebuilds the
      bit-identical successor (``build_index`` is deterministic given the
      live rows and the config seed).
    """

    kind: str
    seq: int = -1
    keys: np.ndarray | None = None
    slots: np.ndarray | None = None
    rows: np.ndarray | None = None
    capacity: int = -1
    min_free: int = 0

    def __eq__(self, other) -> bool:
        if not isinstance(other, WalRecord):
            return NotImplemented

        def arr_eq(a, b):
            if a is None or b is None:
                return a is None and b is None
            return a.dtype == b.dtype and np.array_equal(a, b)

        return (
            self.kind == other.kind
            and self.seq == other.seq
            and self.capacity == other.capacity
            and self.min_free == other.min_free
            and arr_eq(self.keys, other.keys)
            and arr_eq(self.slots, other.slots)
            and arr_eq(self.rows, other.rows)
        )


def _enc_arr(a: np.ndarray) -> bytes:
    a = np.ascontiguousarray(a)
    ds = a.dtype.str.encode()
    out = [struct.pack("<B", len(ds)), ds, struct.pack("<B", a.ndim)]
    out += [struct.pack("<q", s) for s in a.shape]
    out.append(a.tobytes())
    return b"".join(out)


def _dec_arr(buf: bytes, off: int) -> tuple[np.ndarray, int]:
    (dlen,) = struct.unpack_from("<B", buf, off)
    off += 1
    dtype = np.dtype(buf[off : off + dlen].decode())
    off += dlen
    (ndim,) = struct.unpack_from("<B", buf, off)
    off += 1
    shape = []
    for _ in range(ndim):
        (s,) = struct.unpack_from("<q", buf, off)
        off += 8
        shape.append(int(s))
    count = int(np.prod(shape, dtype=np.int64)) if shape else 1
    nbytes = count * dtype.itemsize
    if off + nbytes > len(buf):
        raise ValueError("array payload truncated")
    a = np.frombuffer(buf[off : off + nbytes], dtype=dtype).reshape(shape)
    return a.copy(), off + nbytes


def _encode_payload(rec: WalRecord) -> bytes:
    code = _KIND_TO_CODE.get(rec.kind)
    if code is None:
        raise ValueError(f"unknown WAL record kind {rec.kind!r}")
    head = struct.pack("<BQ", code, rec.seq)
    if rec.kind == "insert":
        return head + _enc_arr(rec.keys) + _enc_arr(rec.slots) + _enc_arr(rec.rows)
    if rec.kind == "delete":
        return head + _enc_arr(rec.slots)
    if rec.kind == "reindex":
        return head + struct.pack("<qq", rec.capacity, rec.min_free)
    raise ValueError(f"unknown WAL record kind {rec.kind!r}")


def _decode_payload(payload: bytes) -> WalRecord:
    code, seq = struct.unpack_from("<BQ", payload, 0)
    off = struct.calcsize("<BQ")
    kind = _CODE_TO_KIND.get(code)
    if kind is None:
        raise ValueError(f"unknown WAL record code {code}")
    if kind == "insert":
        keys, off = _dec_arr(payload, off)
        slots, off = _dec_arr(payload, off)
        rows, off = _dec_arr(payload, off)
        return WalRecord(kind=kind, seq=int(seq), keys=keys, slots=slots, rows=rows)
    if kind == "delete":
        slots, off = _dec_arr(payload, off)
        return WalRecord(kind=kind, seq=int(seq), slots=slots)
    capacity, min_free = struct.unpack_from("<qq", payload, off)
    return WalRecord(
        kind=kind, seq=int(seq), capacity=int(capacity), min_free=int(min_free)
    )


def encode_record(rec: WalRecord) -> bytes:
    """Frame one record: ``<u32 length><u32 crc32(payload)><payload>``."""
    payload = _encode_payload(rec)
    return struct.pack("<II", len(payload), zlib.crc32(payload) & 0xFFFFFFFF) + payload


def decode_records(data: bytes, offset: int = 0) -> tuple[list[WalRecord], int]:
    """Decode framed records with torn-tail tolerance.

    Stops at the first incomplete frame, CRC mismatch, or undecodable
    payload and returns ``(records, end_offset)`` where ``end_offset`` is
    the byte boundary of the last *valid* record — everything after it is
    the torn tail a crashed writer left behind.
    """
    records: list[WalRecord] = []
    off = offset
    n = len(data)
    while True:
        if off + 8 > n:
            break
        length, crc = struct.unpack_from("<II", data, off)
        if off + 8 + length > n:
            break
        payload = data[off + 8 : off + 8 + length]
        if zlib.crc32(payload) & 0xFFFFFFFF != crc:
            break
        try:
            rec = _decode_payload(payload)
        except Exception:
            break
        records.append(rec)
        off += 8 + length
    return records, off


# --------------------------------------------------------------------------
# Write-ahead log
# --------------------------------------------------------------------------


class WriteAheadLog:
    """Append-only, CRC-framed redo log with a configurable fsync policy.

    ``append`` writes and *flushes* the frame (the record is visible to
    the OS — it survives a process kill; only a host power loss can take
    it, and then only under ``fsync != "always"`` before the next group
    commit).  Opening an existing log truncates any torn tail in place,
    so a crashed writer's half-frame never poisons the next generation.

    Thread-safe: ``append``/``flush``/``truncate`` serialise on one lock
    (the group-commit flush runs on the maintenance thread while the
    serving thread appends).
    """

    def __init__(self, path, *, fsync: str = "group", crash=None):
        if fsync not in ("always", "group", "off"):
            raise ValueError(
                f"fsync policy must be 'always', 'group' or 'off', got {fsync!r}"
            )
        self.path = Path(path)
        self.fsync_policy = fsync
        self._crash = crash
        self._lock = threading.Lock()
        self.next_seq = 0
        self.appended_seq = -1  # last fully framed record
        self.synced_seq = -1  # last record covered by an fsync
        self._dirty = False
        self.torn_bytes_dropped = 0
        exists = self.path.exists() and self.path.stat().st_size > 0
        if exists:
            records, valid, dropped = self.read(self.path)
            if valid == 0:
                # Unreadable header: the whole file is torn — start over.
                self.torn_bytes_dropped = dropped
                self._f = self._create()
            else:
                if dropped:
                    with open(self.path, "r+b") as f:
                        f.truncate(valid)
                    self.torn_bytes_dropped = dropped
                if records:
                    self.next_seq = records[-1].seq + 1
                    self.appended_seq = records[-1].seq
                    # Everything framed on disk is the durable baseline of
                    # this generation.
                    self.synced_seq = records[-1].seq
                self._f = open(self.path, "ab")
        else:
            self._f = self._create()

    def _create(self):
        f = open(self.path, "wb")
        f.write(WAL_MAGIC)
        f.flush()
        os.fsync(f.fileno())  # jaxlint: sync-ok — one-time log creation
        return f

    # -- crash-point plumbing ------------------------------------------------

    def _reach(self, point: str) -> None:
        if self._crash is not None:
            self._crash.reach(point)

    def _armed(self, point: str) -> bool:
        return (
            self._crash is not None
            and getattr(self._crash, "armed", None) == point
            and not getattr(self._crash, "fired", False)
        )

    # -- logging -------------------------------------------------------------

    def append(self, rec: WalRecord) -> int:
        """Frame-and-flush one record; returns its assigned ``seq``.

        Under ``fsync="always"`` the record is storage-durable before the
        return; under ``"group"`` the log is marked dirty for the next
        maintenance-thread :meth:`flush`; under ``"off"`` the OS decides.
        """
        with self._lock:
            rec = dataclasses.replace(rec, seq=self.next_seq)
            buf = encode_record(rec)
            self._reach("wal.append.pre")
            if self._armed("wal.append.torn"):
                # Simulated mid-frame kill: half the frame reaches the OS,
                # then the process dies.  Recovery must truncate it.
                self._f.write(buf[: max(len(buf) // 2, 1)])
                self._f.flush()
                self._reach("wal.append.torn")
            self._f.write(buf)
            self._f.flush()
            self._reach("wal.append.post-write")
            self.next_seq = rec.seq + 1
            self.appended_seq = rec.seq
            if self.fsync_policy == "always":
                # Per-record durability is this policy's explicit contract:
                # the caller opted into paying storage latency per mutation.
                os.fsync(self._f.fileno())  # jaxlint: sync-ok — per-record fsync policy (explicit opt-in, not the default serving path)
                self.synced_seq = rec.seq
                self._reach("wal.fsync.post")
            elif self.fsync_policy == "group":
                self._dirty = True
            return rec.seq

    def flush(self) -> bool:
        """Group-commit: fsync if any record was appended since the last
        flush.  Runs on the maintenance thread (or an explicit off-path
        caller) — never on the serving path."""
        with self._lock:
            if not self._dirty:
                return False
            os.fsync(self._f.fileno())  # jaxlint: sync-ok — group-commit on the maintenance thread, off the serving path
            self.synced_seq = self.appended_seq
            self._dirty = False
            self._reach("wal.fsync.post")
            return True

    def truncate(self, upto_seq: int) -> None:
        """Drop records with ``seq <= upto_seq`` (now covered by a durable
        snapshot): atomically rewrite the tail into a fresh log file."""
        with self._lock:
            self._f.flush()
            records, _, _ = self.read(self.path)
            tail = [r for r in records if r.seq > upto_seq]
            tmp = self.path.with_name(self.path.name + ".tmp")
            with open(tmp, "wb") as f:
                f.write(WAL_MAGIC)
                for r in tail:
                    f.write(encode_record(r))
                f.flush()
                os.fsync(f.fileno())  # jaxlint: sync-ok — snapshot-time log truncation, off the serving path
            self._reach("wal.truncate.post-write")
            self._f.close()
            os.replace(tmp, self.path)
            self._reach("wal.truncate.post-rename")
            self._f = open(self.path, "ab")
            self._dirty = False

    @staticmethod
    def read(path) -> tuple[list[WalRecord], int, int]:
        """Parse a log file -> ``(records, valid_bytes, dropped_bytes)``.

        ``valid_bytes`` is the boundary of the last whole record (header
        included); ``dropped_bytes`` is the torn tail beyond it.  A
        missing file is an empty log; an unreadable header drops the
        whole file.
        """
        path = Path(path)
        if not path.exists():
            return [], 0, 0
        data = path.read_bytes()
        if len(data) < len(WAL_MAGIC) or data[: len(WAL_MAGIC)] != WAL_MAGIC:
            return [], 0, len(data)
        records, end = decode_records(data, len(WAL_MAGIC))
        return records, end, len(data) - end

    def close(self) -> None:
        try:
            self._f.close()
        except OSError:
            pass


# --------------------------------------------------------------------------
# Maintenance thread: group-commit flush + async re-index prepare
# --------------------------------------------------------------------------


class MaintenanceWorker:
    """One daemon thread for everything durable that must stay off the
    serving path: the group-commit WAL flush (every ``interval_s`` while
    dirty) and submitted jobs (the asynchronous ``reindex`` prepare —
    :meth:`repro.serve.mutation.MutationManager.reindex_async`).

    Jobs run one at a time in submission order; a job's exception is the
    job's problem (the re-index job object captures it for
    ``finish_reindex`` to re-raise) — the worker thread itself never
    dies, so the flush cadence survives a failed re-cluster.
    """

    def __init__(self, flush: Callable[[], bool], interval_s: float = 0.010):
        if interval_s <= 0:
            raise ValueError(f"interval_s must be > 0, got {interval_s}")
        self._flush = flush
        self._interval = float(interval_s)
        self._jobs: deque[Callable[[], None]] = deque()
        self._cond = threading.Condition()
        self._stop = False
        self.last_flush_error: BaseException | None = None
        self._thread = threading.Thread(
            target=self._run, name="suco-durability", daemon=True
        )
        self._thread.start()

    def submit(self, fn: Callable[[], None]) -> None:
        with self._cond:
            if self._stop:
                raise RuntimeError("maintenance worker is stopped")
            self._jobs.append(fn)
            self._cond.notify()

    def stop(self, *, timeout: float = 5.0) -> None:
        with self._cond:
            self._stop = True
            self._cond.notify()
        self._thread.join(timeout=timeout)

    def _run(self) -> None:
        while True:
            fn = None
            with self._cond:
                if self._stop and not self._jobs:
                    break
            # Flush outside the condition lock: fsync latency must not
            # block submit().
            try:
                self._flush()
                self.last_flush_error = None
            except BaseException as e:  # noqa: BLE001 — worker must survive
                self.last_flush_error = e
            with self._cond:
                if self._jobs:
                    fn = self._jobs.popleft()
                elif not self._stop:
                    self._cond.wait(timeout=self._interval)
                    if self._jobs:
                        fn = self._jobs.popleft()
            if fn is not None:
                # The job wrapper (mutation._ReindexJob.run) captures its
                # own exceptions; a bare callable that raises must not
                # kill the flush loop either.
                try:
                    fn()
                except BaseException:  # noqa: BLE001
                    pass


# --------------------------------------------------------------------------
# Durability orchestration
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DurabilityConfig:
    """Knobs for one durability root.

    ``fsync``: ``"always"`` (per-record, serving path pays),
    ``"group"`` (default: bounded-interval group commit on the
    maintenance thread) or ``"off"`` (page cache only).
    ``snapshot_keep`` >= 2 retains a fallback snapshot — the WAL is only
    truncated back to the *oldest retained* snapshot's high-water mark,
    so a corrupt newest snapshot still recovers with zero acknowledged
    loss (longer replay).
    """

    fsync: str = "group"
    flush_interval_s: float = 0.010
    snapshot_keep: int = 2
    snapshot_on_reindex: bool = True
    snapshot_on_swap: bool = True

    def __post_init__(self):
        if self.fsync not in ("always", "group", "off"):
            raise ValueError(
                "fsync policy must be 'always', 'group' or 'off', got "
                f"{self.fsync!r}"
            )
        if self.flush_interval_s <= 0:
            raise ValueError(
                f"flush_interval_s must be > 0, got {self.flush_interval_s}"
            )
        if self.snapshot_keep < 1:
            raise ValueError(
                f"snapshot_keep must be >= 1, got {self.snapshot_keep}"
            )


def _snapshot_covered(path: Path) -> int:
    """Records covered by a ``snapshot-NNN.npz`` file, parsed from its name."""
    return int(path.name[len("snapshot-") : -len(".npz")])


class Durability:
    """The durability root: one WAL + rolling snapshots for one serving
    stack.  Wire it with :meth:`attach`; the server's mutation surface
    (``AnnServer.insert``/``delete``/``swap``) and the
    :class:`~repro.serve.mutation.MutationManager` call the ``log_*`` /
    ``note_swap`` hooks — all no-ops while ``replaying`` (recovery drives
    the same surface and must not re-log).
    """

    def __init__(
        self,
        root,
        config: DurabilityConfig | None = None,
        *,
        crash=None,
        start_worker: bool | None = None,
    ):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.config = DurabilityConfig() if config is None else config
        self._crash = crash
        self.wal = WriteAheadLog(
            self.root / "wal.log", fsync=self.config.fsync, crash=crash
        )
        self.server: AnnServer | None = None
        self.manager = None
        self.replaying = False
        self._in_reindex = False
        if start_worker is None:
            start_worker = self.config.fsync == "group"
        self.worker = (
            MaintenanceWorker(self.wal.flush, self.config.flush_interval_s)
            if start_worker
            else None
        )

    # -- wiring --------------------------------------------------------------

    def attach(self, server: AnnServer, manager=None) -> "Durability":
        """Point the serving stack's durability hooks at this root."""
        self.server = server
        server.durability = self
        if manager is not None:
            self.manager = manager
            manager.durability = self
        return self

    def reach(self, point: str) -> None:
        """Crash-point hook for collaborators (the re-index prepare)."""
        if self._crash is not None:
            self._crash.reach(point)

    # -- logging hooks (called by AnnServer / MutationManager) ---------------

    def log_insert(self, rows, slots, *, keys=None) -> int | None:
        if self.replaying:
            return None
        dtype = np.dtype(self.server.engine.x.dtype)
        rows = np.atleast_2d(np.asarray(rows)).astype(dtype, copy=False)  # jaxlint: sync-ok — host copy of the acknowledged insert payload
        slots = np.atleast_1d(np.asarray(slots)).astype(np.int64)  # jaxlint: sync-ok — host slot ids
        keys = (
            slots
            if keys is None
            else np.atleast_1d(np.asarray(keys)).astype(np.int64)  # jaxlint: sync-ok — host key ids
        )
        return self.wal.append(
            WalRecord(kind="insert", keys=keys, slots=slots, rows=rows)
        )

    def log_delete(self, slots) -> int | None:
        if self.replaying:
            return None
        slots = np.atleast_1d(np.asarray(slots)).astype(np.int64)  # jaxlint: sync-ok — host slot ids
        return self.wal.append(WalRecord(kind="delete", slots=slots))

    def log_reindex(self, *, capacity: int, min_free: int = 0) -> int | None:
        """Log a committed re-index (resolved capacity, so replay rebuilds
        the identical successor), then checkpoint if configured — the
        re-cluster already paid a full pass over the corpus; the snapshot
        is marginal and resets the replay horizon."""
        if self.replaying:
            return None
        seq = self.wal.append(
            WalRecord(kind="reindex", capacity=int(capacity), min_free=int(min_free))
        )
        if self.config.snapshot_on_reindex:
            self.snapshot()
        return seq

    def note_swap(self) -> None:
        """A bare ``server.swap`` installed an engine the WAL cannot replay
        (arbitrary out-of-band state) — checkpoint immediately so the new
        surface is durable.  Manager-driven re-indexes suppress this (the
        replayable ``reindex`` record covers them)."""
        if self.replaying or self._in_reindex:
            return
        if self.config.snapshot_on_swap:
            self.snapshot()

    def flush(self) -> bool:
        """Explicit group-commit (tests / shutdown); off the serving path."""
        return self.wal.flush()

    # -- checkpointing -------------------------------------------------------

    def snapshot(self) -> Path:
        """Write an atomic, checksummed checkpoint and shrink the WAL.

        The artifact lands under a ``.writing`` name first (itself written
        atomically by ``SuCoIndex.save``), then ``os.replace``s onto its
        final ``snapshot-<records-covered>.npz`` name — a kill anywhere
        in between leaves either the old snapshot set intact or the new
        snapshot fully visible, never a half-written file under a live
        name.  The WAL is truncated back to the oldest snapshot this
        root still retains.
        """
        if self.server is None:
            raise ValueError("attach(server) before snapshot()")
        self.reach("snapshot.pre")
        hwm = self.wal.appended_seq
        extras = _collect_extras(self.server, self.manager, wal_seq=hwm)
        cfg = self.manager.config if self.manager is not None else None
        final = self.root / f"snapshot-{hwm + 1:012d}.npz"
        writing = self.root / (final.name + ".writing")
        self.server.engine.save(writing, cfg, extras=extras)
        self.reach("snapshot.post-write")
        os.replace(writing, final)
        self.reach("snapshot.post-rename")
        snaps = sorted(self.root.glob("snapshot-*.npz"), reverse=True)
        retained = snaps[: self.config.snapshot_keep]
        for old in snaps[self.config.snapshot_keep :]:
            old.unlink(missing_ok=True)
        # Truncate only past what the OLDEST retained snapshot covers: if
        # the newest ever fails its checksums, the fallback snapshot plus
        # the longer WAL tail still reconstructs every acknowledged record.
        self.wal.truncate(min(_snapshot_covered(p) for p in retained) - 1)
        return final

    def close(self) -> None:
        """Orderly shutdown: final group-commit, stop the worker, close."""
        if self.worker is not None:
            self.worker.stop()
            self.worker = None
        self.wal.flush()
        self.wal.close()

    def abandon(self) -> None:
        """Simulate process death (drills): drop everything without the
        final flush — whatever the OS has is what recovery gets."""
        if self.worker is not None:
            self.worker.stop(timeout=0.1)
            self.worker = None
        self.wal.close()


# --------------------------------------------------------------------------
# Serving-state sidecar (artifact-v3 extras)
# --------------------------------------------------------------------------


def _policy_extras(policy: EnginePolicy) -> dict[str, np.ndarray]:
    ex = {
        "policy_alpha": np.asarray(policy.alpha, np.float64),  # jaxlint: sync-ok — host policy scalar
        "policy_beta": np.asarray(policy.beta, np.float64),  # jaxlint: sync-ok — host policy scalar
        "policy_metric": np.asarray(policy.metric),  # jaxlint: sync-ok — host policy scalar
        "policy_mode": np.asarray(policy.mode),  # jaxlint: sync-ok — host policy scalar
        "policy_score_impl": np.asarray(policy.score_impl),  # jaxlint: sync-ok — host policy scalar
        "policy_merge_impl": np.asarray(policy.merge_impl),  # jaxlint: sync-ok — host policy scalar
        "policy_block_n": np.asarray(policy.block_n, np.int64),  # jaxlint: sync-ok — host policy scalar
        "policy_batch_buckets": np.asarray(policy.batch_buckets, np.int64),  # jaxlint: sync-ok — host policy scalar
    }
    if policy.tiles is not None:
        t = policy.tiles
        ex["policy_tiles"] = np.asarray(
            [t.block_n, t.bm, t.bn, t.survivor_cap], np.int64
        )
    return ex


def _policy_from_extras(extras) -> EnginePolicy:
    kw = dict(
        alpha=float(extras["policy_alpha"][()]),
        beta=float(extras["policy_beta"][()]),
        metric=str(extras["policy_metric"][()]),
        mode=str(extras["policy_mode"][()]),
        score_impl=str(extras["policy_score_impl"][()]),
        merge_impl=str(extras["policy_merge_impl"][()]),
        block_n=int(extras["policy_block_n"][()]),
        batch_buckets=tuple(int(v) for v in extras["policy_batch_buckets"]),
    )
    if "policy_tiles" in extras:
        kw["tiles"] = TileConfig(*(int(v) for v in extras["policy_tiles"]))
    return EnginePolicy(**kw)


def _collect_extras(server: AnnServer, manager, *, wal_seq: int) -> dict:
    """The full serving-state sidecar for one artifact-v3 checkpoint."""
    e = server.engine
    next_slot = int(e._next_slot)
    x = np.asarray(e.x)  # jaxlint: sync-ok — checkpoint gather, off the serving path
    capacity = e._capacity if e._capacity is not None else x.shape[0]
    extras: dict[str, np.ndarray] = {
        # Slots >= next_slot are zero-initialised padding by construction;
        # recovery re-pads with zeros, so the slice is lossless.
        "x": x[:next_slot],
        "mutable": np.asarray(0 if e._capacity is None else 1, np.int64),  # jaxlint: sync-ok — host layout scalar
        "capacity": np.asarray(capacity, np.int64),  # jaxlint: sync-ok — host layout scalar
        "next_slot": np.asarray(next_slot, np.int64),  # jaxlint: sync-ok — host layout scalar
        "wal_seq": np.asarray(wal_seq, np.int64),  # jaxlint: sync-ok — host layout scalar
        "insert_inertia": np.asarray(e._insert_inertia, np.float64),  # jaxlint: sync-ok — host layout scalar
        "inserted": np.asarray(e._inserted, np.int64),  # jaxlint: sync-ok — host layout scalar
    }
    extras.update(_policy_extras(e.policy))
    engines = server.ladder.engines if server.ladder is not None else [e]
    triples = sorted(
        {
            (lv, b, k)
            for lv, eng in enumerate(engines)
            for (b, k) in eng._buckets_seen
        }
    )
    extras["warm_triples"] = np.asarray(triples, np.int64).reshape(-1, 3)  # jaxlint: sync-ok — host warm-surface list
    if server.ladder is not None:
        extras["ladder_levels"] = np.asarray(server.ladder.max_level, np.int64)  # jaxlint: sync-ok — host ladder scalar
        extras["ladder_m_stat"] = np.asarray(server.ladder.m_stat, np.float64)  # jaxlint: sync-ok — host ladder scalar
        extras["ladder_sigma_stat"] = np.asarray(  # jaxlint: sync-ok — host ladder scalar
            server.ladder.sigma_stat, np.float64
        )
    if manager is not None:
        extras["mm_keys"] = np.asarray(manager._keys, np.int64)  # jaxlint: sync-ok — host key table
        extras["mm_next_key"] = np.asarray(manager._next_key, np.int64)  # jaxlint: sync-ok — host key scalar
        extras["mm_reindexes"] = np.asarray(manager.reindexes, np.int64)  # jaxlint: sync-ok — host counter
        if manager.monitor._baseline is not None:
            extras["drift_baseline"] = np.asarray(  # jaxlint: sync-ok — host drift baseline
                manager.monitor._baseline, np.float64
            )
            extras["drift_baseline_inertia"] = np.asarray(  # jaxlint: sync-ok — host drift scalar
                manager.monitor._baseline_inertia, np.float64
            )
    return extras


def _rebuild_stack(
    index,
    cfg,
    extras,
    *,
    policy=None,
    config=None,
    server_cls=AnnServer,
    server_kwargs=None,
    manager_kwargs=None,
    durability=None,
):
    """Reconstruct ``(engine, ladder, server, manager)`` from a loaded
    artifact + sidecar.  Shared by :func:`recover` and
    :func:`load_serving_stack`."""
    pol = policy if policy is not None else _policy_from_extras(extras)
    capacity = int(extras["capacity"][()])
    next_slot = int(extras["next_slot"][()])
    mutable = bool(int(extras.get("mutable", np.asarray(1))[()]))
    x_part = np.asarray(extras["x"])  # jaxlint: sync-ok — npz payload is host data
    x_full = np.zeros((capacity, x_part.shape[1]), dtype=x_part.dtype)
    x_full[: len(x_part)] = x_part
    engine = SuCoEngine(
        jnp.asarray(x_full), index, pol, capacity=capacity if mutable else None
    )
    engine._next_slot = next_slot
    engine._insert_inertia = float(extras["insert_inertia"][()])
    engine._inserted = int(extras["inserted"][()])
    ladder = None
    if "ladder_levels" in extras:
        ladder = DegradationLadder(
            engine,
            levels=int(extras["ladder_levels"][()]),
            stats=(
                float(extras["ladder_m_stat"][()]),
                float(extras["ladder_sigma_stat"][()]),
            ),
        )
        ladder.rebind()
    server = server_cls(
        engine, ladder=ladder, durability=durability, **(server_kwargs or {})
    )
    manager = None
    if "mm_keys" in extras:
        mcfg = config if config is not None else cfg
        if mcfg is None:
            raise RecoveryError(
                "snapshot carries a MutationManager key table but no build "
                "config — pass config=SuCoConfig(...) to rebuild the manager"
            )
        from repro.serve.mutation import MutationManager  # lazy: avoid cycle

        manager = MutationManager(server, mcfg, **(manager_kwargs or {}))
        manager._keys = np.asarray(extras["mm_keys"], np.int64).copy()  # jaxlint: sync-ok — npz payload is host data
        manager._next_key = int(extras["mm_next_key"][()])
        manager.reindexes = int(extras.get("mm_reindexes", np.asarray(0))[()])
        if "drift_baseline" in extras:
            manager.monitor._baseline = np.asarray(  # jaxlint: sync-ok — npz payload is host data
                extras["drift_baseline"], np.float64
            ).copy()
            manager.monitor._baseline_inertia = float(
                extras["drift_baseline_inertia"][()]
            )
    return engine, ladder, server, manager


def _warm_from_extras(server: AnnServer, extras) -> int:
    """Re-compile exactly the ``(level, bucket, k)`` surface the snapshot
    recorded; returns fresh compiles.  After this, the recovered stack
    serves the pre-crash traffic mix with zero retraces."""
    warmed = 0
    triples = np.asarray(  # jaxlint: sync-ok — npz payload is host data
        extras.get("warm_triples", np.zeros((0, 3), np.int64)), np.int64
    ).reshape(-1, 3)
    for lv, b, k in triples:
        eng = (
            server.ladder.engine_for(int(lv))
            if server.ladder is not None
            else server.engine
        )
        warmed += eng.warmup([int(b)], [int(k)])
    return warmed


# --------------------------------------------------------------------------
# Plain save/load (satellite: keys survive without a WAL)
# --------------------------------------------------------------------------


def save_stack(path, server: AnnServer, manager=None, *, config=None) -> None:
    """One-shot durable save of a serving stack (no WAL): the artifact-v3
    checkpoint with the full sidecar — external keys included — written
    atomically.  :func:`load_serving_stack` round-trips it."""
    extras = _collect_extras(server, manager, wal_seq=-1)
    if config is None and manager is not None:
        config = manager.config
    server.engine.save(path, config, extras=extras)


def load_serving_stack(
    path,
    *,
    policy=None,
    config=None,
    server_cls=AnnServer,
    server_kwargs=None,
    manager_kwargs=None,
    warm: bool = True,
):
    """Rebuild ``(server, manager)`` from a :func:`save_stack` artifact
    (or any snapshot).  ``manager`` is ``None`` when the artifact carries
    no key table (a plain engine save)."""
    index, cfg, extras = load_index_artifact(path, return_extras=True)
    if "x" not in extras:
        raise ArtifactError(
            f"{path!s}: artifact has no serving-state sidecar (extra_x) — "
            "write it with save_stack()/Durability.snapshot(), not the bare "
            "SuCoIndex.save()"
        )
    _, _, server, manager = _rebuild_stack(
        index,
        cfg,
        extras,
        policy=policy,
        config=config,
        server_cls=server_cls,
        server_kwargs=server_kwargs,
        manager_kwargs=manager_kwargs,
    )
    if warm:
        _warm_from_extras(server, extras)
    return server, manager


# --------------------------------------------------------------------------
# Recovery
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RecoveryReport:
    """What one :func:`recover` did."""

    snapshot_path: str
    snapshot_records: int  # mutation records the loaded snapshot covers
    snapshots_skipped: int  # corrupt newer snapshots fallen past
    wal_records: int  # valid records in the log
    replayed: int  # records past the snapshot's high-water mark
    dropped_bytes: int  # torn tail truncated
    warmed: int  # executables re-compiled from the recorded warm surface

    @property
    def applied_records(self) -> int:
        """Mutation records reflected in the recovered state."""
        return self.snapshot_records + self.replayed


@dataclasses.dataclass(frozen=True, eq=False)
class RecoveryResult:
    server: AnnServer
    manager: object
    durability: Durability
    report: RecoveryReport


def _apply_record(server: AnnServer, manager, rec: WalRecord) -> None:
    """Replay one record through the real mutation surface (deterministic,
    so the rebuilt state is bit-identical to the original apply)."""
    if rec.kind == "insert":
        slots = server.insert(np.asarray(rec.rows))  # jaxlint: sync-ok — host WAL payload
        got = np.asarray(slots, np.int64)  # jaxlint: sync-ok — host replay check
        if rec.slots is not None and not np.array_equal(got, rec.slots):
            raise RecoveryError(
                f"replay diverged on insert seq={rec.seq}: engine assigned "
                f"slots starting {got[:4].tolist()}, log recorded "
                f"{rec.slots[:4].tolist()}"
            )
        if manager is not None and rec.keys is not None:
            manager._keys = np.concatenate([manager._keys, rec.keys])
            if len(rec.keys):
                manager._next_key = max(
                    manager._next_key, int(rec.keys.max()) + 1
                )
    elif rec.kind == "delete":
        server.delete(rec.slots)
    elif rec.kind == "reindex":
        if manager is None:
            raise RecoveryError(
                f"reindex record seq={rec.seq} needs a MutationManager, but "
                "the snapshot carries no key table"
            )
        manager.reindex(capacity=rec.capacity, min_free=rec.min_free)
    else:  # pragma: no cover — decode_records rejects unknown kinds
        raise RecoveryError(f"unknown WAL record kind {rec.kind!r}")


def recover(
    root,
    *,
    policy=None,
    config=None,
    durability_config: DurabilityConfig | None = None,
    server_cls=AnnServer,
    server_kwargs=None,
    manager_kwargs=None,
    crash=None,
    start_worker: bool | None = None,
) -> RecoveryResult:
    """Rebuild a serving stack from a durability root after a crash.

    Algorithm (``docs/durability.md``):

    1. delete stray partials (``*.writing`` / ``*.tmp`` — atomic-rename
       staging files a kill left behind; never a live name);
    2. load the newest snapshot whose content checksums verify, falling
       back past corrupt ones (``snapshots_skipped``);
    3. open the WAL — torn tail truncated at the first bad frame, which
       is never behind an acknowledged fsync (acknowledged records are
       fully framed before the ack);
    4. rebuild engine/ladder/server/manager from the sidecar, replay
       every record past the snapshot's high-water mark through the real
       mutation surface, and re-warm the recorded executable surface.

    The returned stack is attached to a fresh :class:`Durability` over
    the same root, continuing the same WAL — ready to serve and log.
    """
    root = Path(root)
    if not root.is_dir():
        raise RecoveryError(f"{root!s} is not a durability root")
    for stray in list(root.glob("*.writing")) + list(root.glob("*.tmp")):
        stray.unlink(missing_ok=True)
    snaps = sorted(root.glob("snapshot-*.npz"), reverse=True)
    skipped = 0
    loaded = None
    for p in snaps:
        try:
            index, cfg, extras = load_index_artifact(p, return_extras=True)
        except ArtifactError:
            skipped += 1
            continue
        if "x" not in extras or "wal_seq" not in extras:
            skipped += 1
            continue
        loaded = (p, index, cfg, extras)
        break
    if loaded is None:
        raise RecoveryError(
            f"no valid snapshot under {root!s} "
            f"({len(snaps)} candidates, {skipped} corrupt or sidecar-free)"
        )
    p, index, cfg, extras = loaded
    hwm = int(extras["wal_seq"][()])
    dur = Durability(
        root, durability_config, crash=crash, start_worker=start_worker
    )
    dur.wal.next_seq = max(dur.wal.next_seq, hwm + 1)
    records, _, _ = WriteAheadLog.read(root / "wal.log")
    tail = [r for r in records if r.seq > hwm]
    _, ladder, server, manager = _rebuild_stack(
        index,
        cfg,
        extras,
        policy=policy,
        config=config,
        server_cls=server_cls,
        server_kwargs=server_kwargs,
        manager_kwargs=manager_kwargs,
        durability=dur,
    )
    dur.attach(server, manager)
    dur.replaying = True
    try:
        for rec in tail:
            _apply_record(server, manager, rec)
    finally:
        dur.replaying = False
    warmed = _warm_from_extras(server, extras)
    if ladder is not None:
        ladder.rebind()
    report = RecoveryReport(
        snapshot_path=str(p),
        snapshot_records=hwm + 1,
        snapshots_skipped=skipped,
        wal_records=len(records),
        replayed=len(tail),
        dropped_bytes=dur.wal.torn_bytes_dropped,
        warmed=warmed,
    )
    return RecoveryResult(
        server=server, manager=manager, durability=dur, report=report
    )


# --------------------------------------------------------------------------
# Bit-identity fingerprints (the drill's comparison unit)
# --------------------------------------------------------------------------


def state_fingerprint(server: AnnServer, manager=None) -> dict[str, np.ndarray]:
    """Every array that defines the serving state, as host copies — two
    stacks serve identical answers iff their fingerprints are equal."""
    e = server.engine
    idx = e.index
    fp = {
        "x": np.asarray(e.x),  # jaxlint: sync-ok — offline fingerprint gather
        "cell_ids": np.asarray(idx.cell_ids),  # jaxlint: sync-ok — offline fingerprint gather
        "cell_counts": np.asarray(idx.cell_counts),  # jaxlint: sync-ok — offline fingerprint gather
        "centroids1": np.asarray(idx.centroids1),  # jaxlint: sync-ok — offline fingerprint gather
        "centroids2": np.asarray(idx.centroids2),  # jaxlint: sync-ok — offline fingerprint gather
        "tombstone": (
            np.asarray(idx.tombstone)  # jaxlint: sync-ok — offline fingerprint gather
            if idx.tombstone is not None
            else np.zeros(0, bool)
        ),
        "next_slot": np.asarray(int(e._next_slot), np.int64),  # jaxlint: sync-ok — host slot scalar
        "capacity": np.asarray(  # jaxlint: sync-ok — host capacity scalar
            -1 if e._capacity is None else int(e._capacity), np.int64
        ),
        "n_live": np.asarray(int(e.n_live), np.int64),  # jaxlint: sync-ok — host count scalar
    }
    if manager is not None:
        fp["keys"] = np.asarray(manager._keys, np.int64).copy()  # jaxlint: sync-ok — host key table
        fp["next_key"] = np.asarray(int(manager._next_key), np.int64)  # jaxlint: sync-ok — host key scalar
    return fp


def fingerprint_diff(a: dict, b: dict) -> tuple[str, ...]:
    """Names of fingerprint entries that differ (empty = bit-identical)."""
    names = sorted(set(a) | set(b))
    return tuple(
        n
        for n in names
        if n not in a or n not in b or not np.array_equal(a[n], b[n])
    )
