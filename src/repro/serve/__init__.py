"""Serving layer: continuous-batching server + decode caches.

The implementations live in repro.launch.serve (driver + Server) and
repro.models.decode / repro.models.prefill (cache mechanics); re-exported
here as the public serving API.
"""

from repro.launch.serve import Request, Server
from repro.models.decode import decode_step, init_cache
from repro.models.prefill import prefill

__all__ = ["Request", "Server", "decode_step", "init_cache", "prefill"]
