"""Serving layer: continuous-batching servers + decode caches.

Two backends share the admission-queue / step-boundary batching design:

* LLM decode — ``repro.launch.serve`` (driver + ``Server``) over
  ``repro.models.decode`` / ``repro.models.prefill`` cache mechanics;
* k-ANN — :mod:`repro.serve.ann` (``AnnServer``) over the persistent
  batched :class:`~repro.core.suco.SuCoEngine`.

Both are re-exported here as the public serving API.
"""

from repro.launch.serve import Request, Server
from repro.models.decode import decode_step, init_cache
from repro.models.prefill import prefill
from repro.serve.ann import (
    AnnRequest,
    AnnServer,
    AsyncAnnServer,
    DegradationLadder,
    OverloadController,
    StepRecord,
    latency_summary,
)
from repro.serve.chaos import (
    CRASH_POINTS,
    ChaosConfig,
    ChaosEngine,
    ChaosError,
    CrashInjector,
    CrashPoint,
    DrillReport,
    DrillStep,
    ReplayReport,
    VirtualClock,
    drill_steps,
    flood_trace,
    kill_pool_engine,
    recovery_drill,
    replay,
    wrap_ladder,
)
from repro.serve.durability import (
    Durability,
    DurabilityConfig,
    RecoveryError,
    RecoveryReport,
    RecoveryResult,
    WalRecord,
    WriteAheadLog,
    load_serving_stack,
    recover,
    save_stack,
)
from repro.serve.mutation import (
    DriftMonitor,
    DriftReport,
    MutationManager,
    ReindexInProgressError,
)

__all__ = [
    "Request",
    "Server",
    "decode_step",
    "init_cache",
    "prefill",
    "AnnRequest",
    "AnnServer",
    "AsyncAnnServer",
    "DegradationLadder",
    "OverloadController",
    "StepRecord",
    "latency_summary",
    "ChaosConfig",
    "ChaosEngine",
    "ChaosError",
    "ReplayReport",
    "VirtualClock",
    "flood_trace",
    "kill_pool_engine",
    "replay",
    "wrap_ladder",
    "CRASH_POINTS",
    "CrashInjector",
    "CrashPoint",
    "DrillReport",
    "DrillStep",
    "drill_steps",
    "recovery_drill",
    "Durability",
    "DurabilityConfig",
    "RecoveryError",
    "RecoveryReport",
    "RecoveryResult",
    "WalRecord",
    "WriteAheadLog",
    "load_serving_stack",
    "recover",
    "save_stack",
    "DriftMonitor",
    "DriftReport",
    "MutationManager",
    "ReindexInProgressError",
]
